//! E2 — NoC scaling study (paper Sec. III).
//!
//! Saturation sweeps (offered load -> latency/throughput) per topology
//! and traffic pattern on the flit-level wormhole simulator, plus the
//! size-scaling row the "performance up-scaling" claim needs.

#[path = "util.rs"]
mod util;

use archytas::noc::{traffic, NocParams, NocSim, Topology};
use archytas::sim::Rng;

fn sweep(name: &str, mk: impl Fn() -> Topology, pattern: traffic::Pattern) {
    println!("-- {name}, {pattern:?} --");
    println!(
        "{:>8} {:>12} {:>12} {:>14}",
        "load", "avg lat", "p99 lat", "flits/node/cyc"
    );
    for rate in [0.01, 0.05, 0.10, 0.20, 0.35] {
        let topo = mk();
        let nodes = topo.nodes();
        let mut sim = NocSim::new(topo, NocParams::default());
        let mut rng = Rng::new(42);
        let inj = traffic::generate(pattern, nodes, rate, 64, 1500, &mut rng);
        let rep = traffic::drive(&mut sim, inj, 3_000_000);
        println!(
            "{:>8.2} {:>12.1} {:>12.1} {:>14.4}",
            rate, rep.avg_latency, rep.p99_latency, rep.throughput
        );
    }
}

fn main() {
    util::banner("E2", "NoC saturation & scaling (flit-level wormhole sim)");
    sweep("mesh 4x4", || Topology::mesh(4, 4).unwrap(), traffic::Pattern::Uniform);
    sweep("torus 4x4", || Topology::torus(4, 4).unwrap(), traffic::Pattern::Uniform);
    sweep(
        "mesh 4x4",
        || Topology::mesh(4, 4).unwrap(),
        traffic::Pattern::Hotspot { hot_permille: 300 },
    );
    sweep("mesh 4x4", || Topology::mesh(4, 4).unwrap(), traffic::Pattern::Transpose { w: 4 });

    println!("\n-- size scaling at load 0.05, uniform --");
    println!("{:>10} {:>8} {:>12} {:>14} {:>12}", "mesh", "nodes", "avg lat", "flits/node/cyc", "sim wall");
    for side in [2usize, 4, 6, 8, 12, 16] {
        let (rep, wall) = util::time_once(|| {
            let topo = Topology::mesh(side, side).unwrap();
            let nodes = topo.nodes();
            let mut sim = NocSim::new(topo, NocParams::default());
            let mut rng = Rng::new(7);
            let inj = traffic::generate(traffic::Pattern::Uniform, nodes, 0.05, 64, 800, &mut rng);
            traffic::drive(&mut sim, inj, 2_000_000)
        });
        println!(
            "{:>7}x{:<3} {:>8} {:>12.1} {:>14.4} {:>12}",
            side,
            side,
            side * side,
            rep.avg_latency,
            rep.throughput,
            util::fmt_time(wall)
        );
    }
    println!("\nexpected shape: latency knee at saturation; torus ~2x bisection of mesh;");
    println!("hotspot saturates earliest; per-node throughput ~flat with size at low load.");
}
