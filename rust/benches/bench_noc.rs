//! E2 — NoC scaling study (paper Sec. III).
//!
//! Saturation sweeps (offered load -> latency/throughput) per topology
//! and traffic pattern on the flit-level wormhole simulator, the
//! size-scaling row the "performance up-scaling" claim needs (now up to
//! 64x64 — computed route tables), the hot-loop throughput row (the
//! event-wheel `NocSim` vs the retained pre-rewrite `RefNocSim`), and
//! the thread-scaling row: shard-parallel stepping at 1/2/4/8 threads
//! with a bit-identity golden check that panics on divergence (the CI
//! parallel-determinism smoke).

#[path = "util.rs"]
mod util;

use archytas::noc::refsim::RefNocSim;
use archytas::noc::{traffic, NocParams, NocSim, Topology};
use archytas::sim::Rng;

fn sweep(name: &str, mk: impl Fn() -> Topology, pattern: traffic::Pattern) {
    println!("-- {name}, {pattern:?} --");
    println!(
        "{:>8} {:>12} {:>12} {:>14}",
        "load", "avg lat", "p99 lat", "flits/node/cyc"
    );
    for rate in [0.01, 0.05, 0.10, 0.20, 0.35] {
        let topo = mk();
        let nodes = topo.nodes();
        let mut sim = NocSim::new(topo, NocParams::default());
        let mut rng = Rng::new(42);
        let inj = traffic::generate(pattern, nodes, rate, 64, 1500, &mut rng);
        let rep = traffic::drive(&mut sim, inj, 3_000_000);
        println!(
            "{:>8.2} {:>12.1} {:>12.1} {:>14.4}",
            rate, rep.avg_latency, rep.p99_latency, rep.throughput
        );
    }
}

/// Hot-loop throughput: 16x16 mesh, uniform random at mid injection rate,
/// identical workload on the event-wheel simulator and the pre-rewrite
/// reference. Prints simulated cycles/sec for both — the perf trajectory
/// line CI greps for — and cross-checks that the reports stay
/// bit-identical (golden determinism).
fn hot_loop_throughput() {
    println!("\n-- hot loop: 16x16 mesh, uniform, load 0.08 (event wheel vs reference) --");
    // 32-byte single-flit packets at 0.08/node/cycle: ~2/3 of the mesh's
    // uniform-traffic saturation point, so both simulators drain.
    let mut rng = Rng::new(42);
    let schedule = traffic::generate(traffic::Pattern::Uniform, 256, 0.08, 32, 1500, &mut rng);

    // Clone outside the timed regions so both sides pay identical setup
    // (each drive then sorts its own already-sorted copy).
    let mut sched_new = Some(schedule.clone());
    let mut sim = NocSim::new(Topology::mesh(16, 16).unwrap(), NocParams::default());
    let (rep, wall_new) = util::time_once(|| {
        traffic::drive(&mut sim, sched_new.take().expect("timed once"), 3_000_000)
    });

    let mut sched_ref = Some(schedule);
    let mut rsim = RefNocSim::new(Topology::mesh(16, 16).unwrap(), NocParams::default());
    let (rref, wall_ref) = util::time_once(|| {
        archytas::noc::refsim::drive(&mut rsim, sched_ref.take().expect("timed once"), 3_000_000)
    });

    let cps_new = rep.cycles as f64 / wall_new;
    let cps_ref = rref.cycles as f64 / wall_ref;
    println!(
        "  event-wheel: {:>10} cyc in {:>10}  =  {:>12.0} cycles/sec",
        rep.cycles,
        util::fmt_time(wall_new),
        cps_new
    );
    println!(
        "  reference:   {:>10} cyc in {:>10}  =  {:>12.0} cycles/sec",
        rref.cycles,
        util::fmt_time(wall_ref),
        cps_ref
    );
    println!("  speedup: {:.2}x", cps_new / cps_ref);
    let golden_ok = rep.cycles == rref.cycles
        && rep.delivered == rref.delivered
        && rep.flit_hops == rref.flit_hops
        && rep.avg_latency.to_bits() == rref.avg_latency.to_bits();
    println!("  golden match: {}", if golden_ok { "ok" } else { "MISMATCH" });
    assert!(golden_ok, "event-wheel sim diverged from reference");
}

/// Thread-scaling row: shard-parallel stepping on a 32x32 mesh at mid
/// load, one row per thread count, with a golden check — every report
/// must match the threads=1 bits exactly (the determinism contract), so
/// any parallel divergence panics the bench and fails CI.
fn thread_scaling() {
    println!("\n-- parallel stepping: 32x32 mesh, uniform, load 0.05 (threads sweep) --");
    let nodes = 32 * 32;
    let mut rng = Rng::new(7);
    let schedule = traffic::generate(traffic::Pattern::Uniform, nodes, 0.05, 64, 600, &mut rng);
    println!(
        "{:>8} {:>10} {:>12} {:>14} {:>9} {:>8}",
        "threads", "cycles", "sim wall", "cycles/sec", "speedup", "golden"
    );
    let mut golden: Option<(u64, usize, u64, u64, u64)> = None;
    let mut base_cps = 0.0f64;
    for threads in [1usize, 2, 4, 8] {
        let params = NocParams { threads, ..NocParams::default() };
        let mut sim = NocSim::new(Topology::mesh(32, 32).unwrap(), params);
        let mut sched = Some(schedule.clone());
        let (rep, wall) = util::time_once(|| {
            traffic::drive(&mut sim, sched.take().expect("timed once"), 3_000_000)
        });
        let sig = (
            rep.cycles,
            rep.delivered,
            rep.flit_hops,
            rep.avg_latency.to_bits(),
            rep.p99_latency.to_bits(),
        );
        let ok = *golden.get_or_insert(sig) == sig;
        let cps = rep.cycles as f64 / wall;
        if threads == 1 {
            base_cps = cps;
        }
        println!(
            "{:>8} {:>10} {:>12} {:>14.0} {:>8.2}x {:>8}",
            threads,
            rep.cycles,
            util::fmt_time(wall),
            cps,
            cps / base_cps,
            if ok { "ok" } else { "MISMATCH" }
        );
        assert!(ok, "threads={threads} diverged from the threads=1 golden report");
    }
}

fn main() {
    util::banner("E2", "NoC saturation & scaling (flit-level wormhole sim)");
    sweep("mesh 4x4", || Topology::mesh(4, 4).unwrap(), traffic::Pattern::Uniform);
    sweep("torus 4x4", || Topology::torus(4, 4).unwrap(), traffic::Pattern::Uniform);
    sweep(
        "mesh 4x4",
        || Topology::mesh(4, 4).unwrap(),
        traffic::Pattern::Hotspot { hot_permille: 300 },
    );
    sweep("mesh 4x4", || Topology::mesh(4, 4).unwrap(), traffic::Pattern::Transpose { w: 4 });

    println!("\n-- size scaling at load 0.05, uniform --");
    println!(
        "{:>10} {:>8} {:>12} {:>14} {:>12} {:>14}",
        "mesh", "nodes", "avg lat", "flits/node/cyc", "sim wall", "cycles/sec"
    );
    // 32/64-side rows are the ROADMAP's large-mesh goal: feasible now
    // that mesh routing is computed (no O(n²) route tables).
    for side in [2usize, 4, 6, 8, 12, 16, 32, 64] {
        let (rep, wall) = util::time_once(|| {
            let topo = Topology::mesh(side, side).unwrap();
            let nodes = topo.nodes();
            let mut sim = NocSim::new(topo, NocParams::default());
            let mut rng = Rng::new(7);
            let inj = traffic::generate(traffic::Pattern::Uniform, nodes, 0.05, 64, 800, &mut rng);
            traffic::drive(&mut sim, inj, 2_000_000)
        });
        println!(
            "{:>7}x{:<3} {:>8} {:>12.1} {:>14.4} {:>12} {:>14.0}",
            side,
            side,
            side * side,
            rep.avg_latency,
            rep.throughput,
            util::fmt_time(wall),
            rep.cycles as f64 / wall
        );
    }

    hot_loop_throughput();
    thread_scaling();

    println!("\nexpected shape: latency knee at saturation; torus ~2x bisection of mesh;");
    println!("hotspot saturates earliest; per-node throughput ~flat with size at low load;");
    println!("threads sweep: golden 'ok' on every row, speedup growing with threads.");
}
