//! Shared helpers for the bench harnesses (criterion is not in the
//! offline image; benches are `harness = false` binaries that print the
//! paper-style tables — DESIGN.md §6).
#![allow(dead_code)] // each bench uses a subset of these helpers

use std::time::Instant;

/// Wall-clock one run of `f` in seconds.
pub fn time_once<T>(mut f: impl FnMut() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Mean wall-clock seconds over `iters` runs (after one warmup).
pub fn time_avg(iters: usize, mut f: impl FnMut()) -> f64 {
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

/// Pretty time for table cells.
pub fn fmt_time(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1} us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.2} s", s)
    }
}

/// Section banner.
pub fn banner(id: &str, title: &str) {
    println!("\n================================================================");
    println!("{id}: {title}");
    println!("================================================================");
}
