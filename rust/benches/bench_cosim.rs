//! E-COSIM — coordinator co-simulation engine throughput.
//!
//! The event-driven calendar engine (`coordinator::cosim`) vs the
//! retained one-pass list scheduler (`coordinator::refexec::cosim_ref`)
//! on identical lowered programs, over both bundled fabric configs.
//! Prints scheduled steps/second for both engines — the CI perf-smoke
//! line — and panics if any report field diverges (the same golden
//! contract `tests/cosim_golden.rs` enforces). Note the list scheduler is
//! a single O(n) pass, so it is the throughput *ceiling*; the calendar
//! engine buys incremental re-simulation and event-stream interleaving,
//! and this table tracks how much of the ceiling it keeps.

#[path = "util.rs"]
mod util;

use archytas::accel::Precision;
use archytas::compiler::lowering::lower;
use archytas::compiler::mapper::{map_graph, MapStrategy};
use archytas::compiler::FabricProgram;
use archytas::coordinator::{cosim, cosim_ref, ExecReport};
use archytas::fabric::Fabric;
use archytas::testutil::bundled_fabric;
use archytas::workloads;

fn golden_check(a: &ExecReport, b: &ExecReport, tag: &str) {
    let ok = a.bit_identical(b);
    println!("  golden match: {}", if ok { "ok" } else { "MISMATCH" });
    assert!(ok, "{tag}: event-driven co-sim diverged from the list scheduler");
}

fn engine_row(fabric: &Fabric, prog: &FabricProgram, tag: &str) {
    let steps = prog.steps.len();
    let iters = (200_000 / steps.max(1)).clamp(3, 200);
    let mut ev_rep = None;
    let ev = util::time_avg(iters, || {
        ev_rep = Some(cosim(fabric, prog).unwrap());
    });
    let mut ref_rep = None;
    let rf = util::time_avg(iters, || {
        ref_rep = Some(cosim_ref(fabric, prog).unwrap());
    });
    let ev_sps = steps as f64 / ev;
    let rf_sps = steps as f64 / rf;
    println!("\n-- cosim hot loop: {tag} ({steps} steps, {iters} iters) --");
    println!(
        "  event-driven:   {:>10}/run  =  {:>12.0} steps/sec",
        util::fmt_time(ev),
        ev_sps
    );
    println!(
        "  list scheduler: {:>10}/run  =  {:>12.0} steps/sec",
        util::fmt_time(rf),
        rf_sps
    );
    println!("  relative: {:.2}x of the list-scheduler ceiling", ev_sps / rf_sps);
    golden_check(&ev_rep.unwrap(), &ref_rep.unwrap(), tag);
}

fn main() {
    util::banner(
        "E-COSIM",
        "coordinator co-sim: event calendar vs list scheduler (golden-checked)",
    );
    for cfg in ["edge16.toml", "homogeneous_npu.toml"] {
        let fabric = bundled_fabric(cfg);
        let mlp = workloads::mlp(32, 256, &[512, 256, 128], 10, 1).unwrap();
        let vit = workloads::vit(&workloads::VitParams::default(), 2).unwrap();
        for (wname, g) in [("mlp", &mlp), ("vit", &vit)] {
            for strategy in [MapStrategy::RoundRobin, MapStrategy::Greedy] {
                let m = map_graph(g, &fabric, strategy, Precision::Int8).unwrap();
                let prog = lower(g, &fabric, &m).unwrap();
                engine_row(&fabric, &prog, &format!("{cfg}/{wname}/{strategy:?}"));
            }
        }
    }
    println!("\nexpected shape: both engines report identical timing/energy; the");
    println!("calendar engine trades some single-pass speed for incremental re-sim.");
}
