//! E10 — heterogeneity ablation (paper Sec. III): the same mixed workload
//! on a heterogeneous fabric vs homogeneous fabrics of comparable area,
//! across mapping strategies. The paper's core architectural bet is that
//! the heterogeneous fabric Pareto-dominates on perf/W for mixed
//! AI pipelines.

#[path = "util.rs"]
mod util;

use archytas::accel::Precision;
use archytas::compiler::lowering::lower;
use archytas::compiler::mapper::{map_graph, MapStrategy};
use archytas::config::FabricConfig;
use archytas::coordinator::cosim;
use archytas::fabric::Fabric;
use archytas::ir::Graph;
use archytas::workloads;

fn run(fabric: &Fabric, graphs: &[Graph], strategy: MapStrategy, p: Precision) -> (u64, f64) {
    let mut cycles = 0u64;
    let mut energy = 0.0;
    for g in graphs {
        let m = map_graph(g, fabric, strategy, p).unwrap();
        let prog = lower(g, fabric, &m).unwrap();
        let r = cosim(fabric, &prog).unwrap();
        cycles += r.cycles;
        energy += r.metrics.total_energy_pj();
    }
    (cycles, energy)
}

fn main() {
    util::banner("E10", "heterogeneous vs homogeneous fabrics (equal-ish area)");
    let hetero = Fabric::build(
        FabricConfig::from_toml(&std::fs::read_to_string(
            archytas::repo_root().join("configs/edge16.toml"),
        ).unwrap()).unwrap(),
    )
    .unwrap();
    let homo = Fabric::build(
        FabricConfig::from_toml(&std::fs::read_to_string(
            archytas::repo_root().join("configs/homogeneous_npu.toml"),
        ).unwrap()).unwrap(),
    )
    .unwrap();
    // Mixed pipeline: vision transformer + CNN + classifier MLP.
    let graphs = vec![
        workloads::vit(&workloads::VitParams::default(), 0).unwrap(),
        workloads::cnn_edge(2, 1).unwrap(),
        workloads::mlp(8, 256, &[128, 64], 10, 2).unwrap(),
    ];
    println!(
        "{:<18} {:>9} | {:<8} {:>12} {:>12} {:>12}",
        "fabric", "area mm²", "strategy", "cycles", "energy nJ", "nJ*ms (EDP)"
    );
    for (name, fabric, precisions) in [
        ("heterogeneous", &hetero, vec![Precision::Analog]),
        ("homogeneous-npu", &homo, vec![Precision::Int8]),
    ] {
        for strategy in [MapStrategy::RoundRobin, MapStrategy::Greedy] {
            for &p in &precisions {
                let ((cy, en), _) = util::time_once(|| run(fabric, &graphs, strategy, p));
                let ms = cy as f64 / (fabric.cfg.freq_ghz * 1e9) * 1e3;
                println!(
                    "{:<18} {:>9.1} | {:<8} {:>12} {:>12.1} {:>12.2}",
                    name,
                    fabric.total_area().mm2,
                    format!("{strategy:?}"),
                    cy,
                    en / 1e3,
                    en / 1e3 * ms
                );
            }
        }
    }
    // Quantified claim (greedy mapping, device-preferred precisions).
    // Equal-area framing: the fabrics differ in silicon cost, so the
    // deployable metric is EDP normalized by die area (perf/W per mm² —
    // exactly the paper's "performance and energy efficiency" budget).
    let (hc, he) = run(&hetero, &graphs, MapStrategy::Greedy, Precision::Analog);
    let (nc, ne) = run(&homo, &graphs, MapStrategy::Greedy, Precision::Int8);
    let edp_h = he * hc as f64;
    let edp_n = ne * nc as f64;
    println!("\nraw EDP ratio (homo/hetero): {:.2}x", edp_n / edp_h);
    println!(
        "area-normalized EDP advantage (homo/hetero, EDP*mm²): {:.2}x",
        (edp_n * homo.total_area().mm2) / (edp_h * hetero.total_area().mm2)
    );
    println!("expected shape: heterogeneous matches or beats raw EDP with ~30% less");
    println!("silicon -> clear win once area-normalized; greedy mapping is what");
    println!("unlocks it (round-robin wastes the specialists).");
}
