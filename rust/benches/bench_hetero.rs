//! E10 — heterogeneity ablation (paper Sec. III): the same mixed workload
//! on a heterogeneous fabric vs homogeneous fabrics of comparable area,
//! across mapping strategies. The paper's core architectural bet is that
//! the heterogeneous fabric Pareto-dominates on perf/W for mixed
//! AI pipelines.
//!
//! Since the kind-aware cost model landed, the bench also compares
//! *kind-aware* pricing (`hetero_mixed.toml`, `model = "kind"`: photonic
//! warm-up, crossbar ADC/DAC + wear, neuromorphic spike-rate energy, PIM
//! offload/contention) against the kind-blind invariant estimate on the
//! same fabric, and panics on two golden divergences (the
//! `tests/kindcost_golden.rs` contract, re-checked in CI's bench run):
//!
//! * **kind-blind parity** — on edge16, the default `map_graph` (which
//!   estimates through the fabric's configured model) must reproduce the
//!   `map_graph_with(InvariantCost)` mapping bit for bit;
//! * **kind-aware movement** — on the mixed config, kind-aware pricing
//!   must move at least one workload's placement vs the invariant
//!   estimate (otherwise the model feeds the mapper nothing).
//!
//! The evidence bundle lands in `rust/BENCH_hetero.json`
//! (`archytas.bench_hetero.v1`), cat'd by the CI summary.

#[path = "util.rs"]
mod util;

use archytas::accel::Precision;
use archytas::compiler::lowering::lower;
use archytas::compiler::mapper::{map_graph, map_graph_with, MapStrategy};
use archytas::config::FabricConfig;
use archytas::coordinator::{cosim, cosim_with};
use archytas::fabric::{Fabric, InvariantCost};
use archytas::ir::Graph;
use archytas::workloads;

fn load(config: &str) -> Fabric {
    Fabric::build(
        FabricConfig::from_toml(
            &std::fs::read_to_string(archytas::repo_root().join("configs").join(config))
                .unwrap(),
        )
        .unwrap(),
    )
    .unwrap()
}

fn run(fabric: &Fabric, graphs: &[Graph], strategy: MapStrategy, p: Precision) -> (u64, f64) {
    let mut cycles = 0u64;
    let mut energy = 0.0;
    for g in graphs {
        let m = map_graph(g, fabric, strategy, p).unwrap();
        let prog = lower(g, fabric, &m).unwrap();
        let r = cosim(fabric, &prog).unwrap();
        cycles += r.cycles;
        energy += r.metrics.total_energy_pj();
    }
    (cycles, energy)
}

/// The kind-blind twin of [`run`]: mapping and pricing both through the
/// invariant model, ignoring the fabric's configured one.
fn run_blind(fabric: &Fabric, graphs: &[Graph], strategy: MapStrategy, p: Precision) -> (u64, f64) {
    let mut cycles = 0u64;
    let mut energy = 0.0;
    for g in graphs {
        let m = map_graph_with(g, fabric, strategy, p, &InvariantCost).unwrap();
        let prog = lower(g, fabric, &m).unwrap();
        let r = cosim_with(fabric, &prog, &InvariantCost).unwrap();
        cycles += r.cycles;
        energy += r.metrics.total_energy_pj();
    }
    (cycles, energy)
}

/// Golden 1: on edge16 (invariant default model) the mapper seam is
/// kind-blind — `map_graph` ≡ `map_graph_with(InvariantCost)`, bit for
/// bit, per strategy and workload. Panics on divergence.
fn kind_blind_golden(fabric: &Fabric, graphs: &[Graph]) {
    for (gi, g) in graphs.iter().enumerate() {
        for strategy in [MapStrategy::RoundRobin, MapStrategy::Greedy] {
            let dflt = map_graph(g, fabric, strategy, Precision::Analog).unwrap();
            let inv = map_graph_with(g, fabric, strategy, Precision::Analog, &InvariantCost)
                .unwrap();
            assert!(
                dflt.assign == inv.assign
                    && dflt.precision == inv.precision
                    && dflt.est_cycles == inv.est_cycles
                    && dflt.est_energy_pj.to_bits() == inv.est_energy_pj.to_bits(),
                "graph {gi} {strategy:?}: kind-blind mapping diverged from invariant"
            );
        }
    }
    println!("  golden match (edge16 map_graph ≡ invariant estimate): ok");
}

/// Golden 2: on the mixed config, kind-aware pricing moves at least one
/// placement vs the invariant estimate. Returns how many
/// (graph, strategy) cells moved; panics if none did.
fn kind_moves_golden(fabric: &Fabric, graphs: &[Graph]) -> usize {
    let model = fabric.cost_model();
    let mut moved = 0usize;
    for g in graphs {
        for strategy in [MapStrategy::Greedy, MapStrategy::Ilp] {
            let kind =
                map_graph_with(g, fabric, strategy, Precision::Analog, model.as_ref()).unwrap();
            let inv = map_graph_with(g, fabric, strategy, Precision::Analog, &InvariantCost)
                .unwrap();
            if kind.assign != inv.assign {
                moved += 1;
            }
        }
    }
    assert!(moved > 0, "kind-aware pricing moved no placement on hetero_mixed");
    println!("  golden match (kind-aware mapping moves placements): ok ({moved} cells)");
    moved
}

struct RowOut {
    fabric: &'static str,
    model: &'static str,
    strategy: &'static str,
    cycles: u64,
    energy_pj: f64,
}

fn jf(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string() // JSON has no Infinity/NaN
    }
}

fn write_bundle(rows: &[RowOut], moved_cells: usize) {
    let stamp = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let row_json: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                concat!(
                    "    {{\"fabric\":\"{}\",\"model\":\"{}\",\"strategy\":\"{}\",",
                    "\"cycles\":{},\"energy_pj\":{},\"edp\":{}}}"
                ),
                r.fabric,
                r.model,
                r.strategy,
                r.cycles,
                jf(r.energy_pj),
                jf(r.energy_pj * r.cycles as f64)
            )
        })
        .collect();
    let json = format!(
        concat!(
            "{{\n",
            "  \"schema\": \"archytas.bench_hetero.v1\",\n",
            "  \"stamp\": {{\"unix_secs\":{},",
            "\"configs\":[\"edge16.toml\",\"homogeneous_npu.toml\",\"hetero_mixed.toml\"]}},\n",
            "  \"golden\": {{\"kind_blind_mapping_bit_identical\":true,",
            "\"kind_aware_mapping_moved_placements\":true,",
            "\"kind_moved_cells\":{}}},\n",
            "  \"rows\": [\n{}\n  ]\n",
            "}}\n"
        ),
        stamp,
        moved_cells,
        row_json.join(",\n")
    );
    let path = archytas::repo_root().join("BENCH_hetero.json");
    std::fs::write(&path, json).expect("writing BENCH_hetero.json");
    println!("\nwrote {}", path.display());
}

fn main() {
    util::banner("E10", "heterogeneous vs homogeneous fabrics (equal-ish area)");
    let hetero = load("edge16.toml");
    let homo = load("homogeneous_npu.toml");
    let mixed = load("hetero_mixed.toml");
    // Mixed pipeline: vision transformer + CNN + classifier MLP.
    let graphs = vec![
        workloads::vit(&workloads::VitParams::default(), 0).unwrap(),
        workloads::cnn_edge(2, 1).unwrap(),
        workloads::mlp(8, 256, &[128, 64], 10, 2).unwrap(),
    ];
    let mut rows = Vec::new();
    println!(
        "{:<18} {:>9} | {:<8} {:>12} {:>12} {:>12}",
        "fabric", "area mm²", "strategy", "cycles", "energy nJ", "nJ*ms (EDP)"
    );
    for (name, fabric, p) in [
        ("heterogeneous", &hetero, Precision::Analog),
        ("homogeneous-npu", &homo, Precision::Int8),
    ] {
        for strategy in [MapStrategy::RoundRobin, MapStrategy::Greedy] {
            let ((cy, en), _) = util::time_once(|| run(fabric, &graphs, strategy, p));
            let ms = cy as f64 / (fabric.cfg.freq_ghz * 1e9) * 1e3;
            println!(
                "{:<18} {:>9.1} | {:<8} {:>12} {:>12.1} {:>12.2}",
                name,
                fabric.total_area().mm2,
                format!("{strategy:?}"),
                cy,
                en / 1e3,
                en / 1e3 * ms
            );
            rows.push(RowOut {
                fabric: name,
                model: "invariant",
                strategy: if strategy == MapStrategy::Greedy { "greedy" } else { "round_robin" },
                cycles: cy,
                energy_pj: en,
            });
        }
    }
    // Quantified claim (greedy mapping, device-preferred precisions).
    // Equal-area framing: the fabrics differ in silicon cost, so the
    // deployable metric is EDP normalized by die area (perf/W per mm² —
    // exactly the paper's "performance and energy efficiency" budget).
    let (hc, he) = run(&hetero, &graphs, MapStrategy::Greedy, Precision::Analog);
    let (nc, ne) = run(&homo, &graphs, MapStrategy::Greedy, Precision::Int8);
    let edp_h = he * hc as f64;
    let edp_n = ne * nc as f64;
    println!("\nraw EDP ratio (homo/hetero): {:.2}x", edp_n / edp_h);
    println!(
        "area-normalized EDP advantage (homo/hetero, EDP*mm²): {:.2}x",
        (edp_n * homo.total_area().mm2) / (edp_h * hetero.total_area().mm2)
    );

    println!("\n-- kind-aware vs generic pricing (hetero_mixed.toml, model = \"kind\") --");
    kind_blind_golden(&hetero, &graphs);
    let moved = kind_moves_golden(&mixed, &graphs);
    println!(
        "{:<18} {:<10} {:>12} {:>12} {:>12}",
        "model", "strategy", "cycles", "energy nJ", "EDP ratio"
    );
    for strategy in [MapStrategy::Greedy, MapStrategy::Ilp] {
        let sname = if strategy == MapStrategy::Greedy { "greedy" } else { "ilp" };
        let (bc, be) = run_blind(&mixed, &graphs, strategy, Precision::Analog);
        let (kc, ke) = run(&mixed, &graphs, strategy, Precision::Analog);
        let ratio = (ke * kc as f64) / (be * bc as f64);
        println!(
            "{:<18} {:<10} {:>12} {:>12.1} {:>12.2}",
            "generic(blind)", sname, bc, be / 1e3, 1.0
        );
        println!(
            "{:<18} {:<10} {:>12} {:>12.1} {:>12.2}",
            "kind-aware", sname, kc, ke / 1e3, ratio
        );
        rows.push(RowOut {
            fabric: "hetero-mixed",
            model: "invariant",
            strategy: sname,
            cycles: bc,
            energy_pj: be,
        });
        rows.push(RowOut {
            fabric: "hetero-mixed",
            model: "kind",
            strategy: sname,
            cycles: kc,
            energy_pj: ke,
        });
    }
    write_bundle(&rows, moved);
    println!("\nexpected shape: heterogeneous matches or beats raw EDP with ~30% less");
    println!("silicon -> clear win once area-normalized; greedy mapping is what");
    println!("unlocks it (round-robin wastes the specialists). On the mixed fabric");
    println!("the kind-aware model surfaces the costs the invariant estimate hides");
    println!("(cold photonic warm-up, crossbar conversion + wear) and its mappings");
    println!("route around the taxed tiles.");
}
