//! E6 — precision tuning (paper Sec. V.C, Fig. 2): error-budget sweep,
//! measured fixed-point error, speedup/energy estimates, and tuner wall
//! time per workload.

#[path = "util.rs"]
mod util;

use archytas::compiler::precision::{tune, Interval, TunerConfig};
use archytas::ir::interp::Mat;
use archytas::workloads;

fn main() {
    util::banner("E6", "TAFFO-style precision tuning");
    let models = vec![
        ("mlp-64", workloads::mlp(4, 64, &[48, 24], 10, 0).unwrap()),
        ("mlp-256", workloads::mlp(8, 256, &[128, 64], 10, 0).unwrap()),
        ("vit-tiny", workloads::vit(&workloads::VitParams::default(), 0).unwrap()),
    ];
    println!(
        "{:<10} {:>8} {:>10} {:>10} {:>9} {:>9} {:>10}",
        "model", "budget", "narrowed", "meas-err", "speedup", "energy", "tuner wall"
    );
    for (name, g) in models {
        let shape = g.nodes[0].shape;
        let mut rng = archytas::sim::Rng::new(11);
        let calib = Mat::new(
            shape,
            (0..shape[0] * shape[1]).map(|_| rng.range_f64(-2.0, 2.0) as f32).collect(),
        )
        .unwrap();
        for budget in [0.01f32, 0.05, 0.2] {
            let cfg = TunerConfig {
                input_hints: vec![Interval::new(-4.0, 4.0)],
                error_budget: budget,
                words: vec![8, 16, 32],
            };
            let (rep, wall) = util::time_once(|| tune(&g, &calib, &cfg).unwrap());
            println!(
                "{:<10} {:>8.2} {:>10} {:>10.4} {:>8.2}x {:>8.2}x {:>10}",
                name,
                budget,
                rep.narrowed,
                rep.measured_rel_err,
                rep.est_speedup,
                rep.est_energy_ratio,
                util::fmt_time(wall)
            );
        }
    }
    println!("\nexpected shape: speedup/energy improve with budget; error always within");
    println!("budget (the tuner *measures* via fixed-point simulation, it never guesses).");
}
