//! E-ADMIT — multi-program admission engine throughput.
//!
//! A serving runtime admits a stream of programs into one live calendar.
//! This bench compares three ways to simulate a K-program burst arriving
//! at t=0, golden-checked against each other and against `cosim` of the
//! concatenated program (panic on any bit divergence — the same contract
//! `tests/admission_golden.rs` enforces):
//!
//! * **rebuild-world**: re-run `cosim` on the growing concatenation after
//!   every arrival — what a calendar-less simulator must do to price
//!   request i against the queueing of requests 0..i (O(K²) steps);
//! * **sequential admit**: one live `CosimSession`, admit + drain per
//!   request — incremental re-simulation prices only the new program;
//! * **batched admit**: `AdmissionQueue::admit_all` + one drain — the
//!   burst path.

#[path = "util.rs"]
mod util;

use archytas::accel::Precision;
use archytas::compiler::lowering::lower;
use archytas::compiler::mapper::{map_graph, MapStrategy};
use archytas::compiler::FabricProgram;
use archytas::coordinator::{cosim, AdmissionQueue, CosimSession, ExecReport};
use archytas::fabric::Fabric;
use archytas::testutil::{bundled_fabric, merge_programs};
use archytas::workloads;

fn golden_check(a: &ExecReport, b: &ExecReport, tag: &str) {
    let merged_ok = a.cycles == b.cycles
        && a.step_done == b.step_done
        && a.tile_busy == b.tile_busy
        && a.transfer_cycles == b.transfer_cycles
        && a.exec_steps == b.exec_steps
        && a.metrics == b.metrics
        && a.metrics.total_energy_pj().to_bits() == b.metrics.total_energy_pj().to_bits();
    println!("  golden match ({tag}): {}", if merged_ok { "ok" } else { "MISMATCH" });
    assert!(merged_ok, "{tag}: admission engine diverged");
}

fn burst_row(fabric: &Fabric, cfg: &str, k: usize) {
    // K small heterogeneous requests (three mlp shapes cycled).
    let shapes: Vec<FabricProgram> = [(4usize, 64usize, 32usize), (8, 32, 16), (2, 48, 24)]
        .iter()
        .enumerate()
        .map(|(i, &(b, inp, hid))| {
            let g = workloads::mlp(b, inp, &[hid], 10, i as u64 + 1).unwrap();
            let m = map_graph(&g, fabric, MapStrategy::Greedy, Precision::Int8).unwrap();
            lower(&g, fabric, &m).unwrap()
        })
        .collect();
    let progs: Vec<FabricProgram> =
        (0..k).map(|i| shapes[i % shapes.len()].clone()).collect();
    let total_steps: usize = progs.iter().map(|p| p.steps.len()).sum();

    let iters = 5;
    // Rebuild-world baseline: concat prefix re-cosim per arrival.
    let mut rebuild_rep = None;
    let rebuild = util::time_avg(iters, || {
        let mut rep = None;
        for i in 1..=progs.len() {
            let prefix: Vec<&FabricProgram> = progs[..i].iter().collect();
            rep = Some(cosim(fabric, &merge_programs(&prefix)).unwrap());
        }
        rebuild_rep = rep;
    });
    // Sequential one-at-a-time admission into one live session.
    let mut seq_rep = None;
    let seq = util::time_avg(iters, || {
        let mut s = CosimSession::new(fabric);
        for p in &progs {
            s.admit_at(p, 0).unwrap();
            s.run_to_drain().unwrap();
        }
        seq_rep = Some(s.report().unwrap());
    });
    // Batched admission: queue everything, drain once.
    let mut batch_rep = None;
    let batched = util::time_avg(iters, || {
        let mut q = AdmissionQueue::new();
        for p in &progs {
            q.push(p.clone(), 0);
        }
        let mut s = CosimSession::new(fabric);
        q.admit_all(&mut s).unwrap();
        batch_rep = Some(s.report().unwrap());
    });

    println!(
        "\n-- admission burst: {cfg}, {k} programs ({total_steps} steps) --"
    );
    println!(
        "  rebuild-world:    {:>10}/burst  =  {:>9.0} programs/sec",
        util::fmt_time(rebuild),
        k as f64 / rebuild
    );
    println!(
        "  sequential admit: {:>10}/burst  =  {:>9.0} programs/sec  ({:.1}x rebuild)",
        util::fmt_time(seq),
        k as f64 / seq,
        rebuild / seq
    );
    println!(
        "  batched admit:    {:>10}/burst  =  {:>9.0} programs/sec  ({:.1}x rebuild)",
        util::fmt_time(batched),
        k as f64 / batched,
        rebuild / batched
    );

    // Golden: all three agree with the merged-schedule oracle, bit for
    // bit (the rebuild baseline's final report IS the oracle).
    let oracle = rebuild_rep.unwrap();
    let seq_rep = seq_rep.unwrap();
    let batch_rep = batch_rep.unwrap();
    golden_check(&seq_rep, &oracle, "sequential vs cosim(concat)");
    golden_check(&batch_rep, &oracle, "batched vs cosim(concat)");
    assert!(
        batch_rep.bit_identical(&seq_rep),
        "batched and sequential admission diverged (spans included)"
    );
}

fn main() {
    util::banner(
        "E-ADMIT",
        "batched vs sequential admission vs rebuild-the-world (golden-checked)",
    );
    for cfg in ["edge16.toml", "homogeneous_npu.toml"] {
        let fabric = bundled_fabric(cfg);
        for k in [16, 64] {
            burst_row(&fabric, cfg, k);
        }
    }
    println!("\nexpected shape: sequential admission beats rebuild-world by ~K/2");
    println!("(it prices each step once); batching removes the per-request drain");
    println!("bookkeeping on top. All modes are bit-identical to the merged oracle.");
}
