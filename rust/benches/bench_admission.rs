//! E-ADMIT — multi-program admission engine throughput.
//!
//! A serving runtime admits a stream of programs into one live calendar.
//! This bench compares three ways to simulate a K-program burst arriving
//! at t=0, golden-checked against each other and against `cosim` of the
//! concatenated program (panic on any bit divergence — the same contract
//! `tests/admission_golden.rs` enforces):
//!
//! * **rebuild-world**: re-run `cosim` on the growing concatenation after
//!   every arrival — what a calendar-less simulator must do to price
//!   request i against the queueing of requests 0..i (O(K²) steps);
//! * **sequential admit**: one live `CosimSession`, admit + drain per
//!   request — incremental re-simulation prices only the new program;
//! * **batched admit**: `AdmissionQueue::admit_all` + one drain — the
//!   burst path.
//!
//! It then runs the shard-parallel threads sweep (1/2/4/8 worker
//! threads over one time-varying stream, every report bit-checked
//! against the sequential engine) and writes the whole evidence bundle —
//! timings, golden verdicts, stamp — to `rust/BENCH_admission.json`,
//! which CI greps alongside `BENCH_faults.json`.

#[path = "util.rs"]
mod util;

use std::sync::Arc;

use archytas::accel::Precision;
use archytas::compiler::lowering::lower;
use archytas::compiler::mapper::{map_graph, MapStrategy};
use archytas::compiler::FabricProgram;
use archytas::coordinator::{cosim, AdmissionQueue, CosimSession, ExecReport, StraddleStats};
use archytas::fabric::{CongestionKnobs, CostModel, DvfsKnobs, Fabric, VaryingCost};
use archytas::sim::Cycle;
use archytas::testutil::{bundled_fabric, merge_programs};
use archytas::workloads;

const SWEEP_THREADS: [usize; 4] = [1, 2, 4, 8];

fn golden_check(a: &ExecReport, b: &ExecReport, tag: &str) {
    let merged_ok = a.cycles == b.cycles
        && a.step_done == b.step_done
        && a.tile_busy == b.tile_busy
        && a.transfer_cycles == b.transfer_cycles
        && a.exec_steps == b.exec_steps
        && a.metrics == b.metrics
        && a.metrics.total_energy_pj().to_bits() == b.metrics.total_energy_pj().to_bits();
    println!("  golden match ({tag}): {}", if merged_ok { "ok" } else { "MISMATCH" });
    assert!(merged_ok, "{tag}: admission engine diverged");
}

/// K small heterogeneous requests (three mlp shapes cycled).
fn request_shapes(fabric: &Fabric) -> Vec<FabricProgram> {
    [(4usize, 64usize, 32usize), (8, 32, 16), (2, 48, 24)]
        .iter()
        .enumerate()
        .map(|(i, &(b, inp, hid))| {
            let g = workloads::mlp(b, inp, &[hid], 10, i as u64 + 1).unwrap();
            let m = map_graph(&g, fabric, MapStrategy::Greedy, Precision::Int8).unwrap();
            lower(&g, fabric, &m).unwrap()
        })
        .collect()
}

/// The congestion+DVFS pricing model the time-varying rows share.
fn varying_model() -> Arc<dyn CostModel> {
    Arc::new(VaryingCost::congestion_dvfs(
        512,
        CongestionKnobs { alpha: 0.5, cap: 4.0 },
        DvfsKnobs { window: 4, warm_frac: 0.5, hot_frac: 0.85, warm_scale: 0.75, hot_scale: 0.5 },
    ))
}

/// Returns (rebuild, sequential, batched) seconds per burst.
fn burst_row(fabric: &Fabric, cfg: &str, k: usize) -> (f64, f64, f64) {
    let shapes = request_shapes(fabric);
    let progs: Vec<FabricProgram> =
        (0..k).map(|i| shapes[i % shapes.len()].clone()).collect();
    let total_steps: usize = progs.iter().map(|p| p.steps.len()).sum();

    let iters = 5;
    // Rebuild-world baseline: concat prefix re-cosim per arrival.
    let mut rebuild_rep = None;
    let rebuild = util::time_avg(iters, || {
        let mut rep = None;
        for i in 1..=progs.len() {
            let prefix: Vec<&FabricProgram> = progs[..i].iter().collect();
            rep = Some(cosim(fabric, &merge_programs(&prefix)).unwrap());
        }
        rebuild_rep = rep;
    });
    // Sequential one-at-a-time admission into one live session.
    let mut seq_rep = None;
    let seq = util::time_avg(iters, || {
        let mut s = CosimSession::new(fabric);
        for p in &progs {
            s.admit_at(p, 0).unwrap();
            s.run_to_drain().unwrap();
        }
        seq_rep = Some(s.report().unwrap());
    });
    // Batched admission: queue everything, drain once.
    let mut batch_rep = None;
    let batched = util::time_avg(iters, || {
        let mut q = AdmissionQueue::new();
        for p in &progs {
            q.push(p.clone(), 0);
        }
        let mut s = CosimSession::new(fabric);
        q.admit_all(&mut s).unwrap();
        batch_rep = Some(s.report().unwrap());
    });

    println!(
        "\n-- admission burst: {cfg}, {k} programs ({total_steps} steps) --"
    );
    println!(
        "  rebuild-world:    {:>10}/burst  =  {:>9.0} programs/sec",
        util::fmt_time(rebuild),
        k as f64 / rebuild
    );
    println!(
        "  sequential admit: {:>10}/burst  =  {:>9.0} programs/sec  ({:.1}x rebuild)",
        util::fmt_time(seq),
        k as f64 / seq,
        rebuild / seq
    );
    println!(
        "  batched admit:    {:>10}/burst  =  {:>9.0} programs/sec  ({:.1}x rebuild)",
        util::fmt_time(batched),
        k as f64 / batched,
        rebuild / batched
    );

    // Golden: all three agree with the merged-schedule oracle, bit for
    // bit (the rebuild baseline's final report IS the oracle).
    let oracle = rebuild_rep.unwrap();
    let seq_rep = seq_rep.unwrap();
    let batch_rep = batch_rep.unwrap();
    golden_check(&seq_rep, &oracle, "sequential vs cosim(concat)");
    golden_check(&batch_rep, &oracle, "batched vs cosim(concat)");
    assert!(
        batch_rep.bit_identical(&seq_rep),
        "batched and sequential admission diverged (spans included)"
    );
    (rebuild, seq, batched)
}

/// Time-varying row: a staggered K-request stream priced by the
/// congestion+DVFS model. Compares the live session (horizon
/// invalidation + settle fixed point, incremental) against rebuilding a
/// fresh session per arrival (the calendar-less baseline), golden-checked
/// bit-for-bit — the `tests/costmodel_golden.rs` contract under load.
/// Returns (rebuild, incremental) seconds per stream.
fn varying_row(fabric: &Fabric, cfg: &str, k: usize) -> (f64, f64) {
    let model = varying_model();
    let shapes = request_shapes(fabric);
    let progs: Vec<(FabricProgram, Cycle)> = (0..k)
        .map(|i| (shapes[i % shapes.len()].clone(), i as Cycle * 400))
        .collect();
    let total_steps: usize = progs.iter().map(|(p, _)| p.steps.len()).sum();

    let iters = 3;
    // Rebuild-world baseline: fresh session over the whole prefix per
    // arrival (what a simulator without horizon invalidation must do to
    // price request i against load-dependent latency).
    let mut rebuild_rep = None;
    let rebuild = util::time_avg(iters, || {
        let mut rep = None;
        for i in 1..=progs.len() {
            let mut s = CosimSession::with_model(fabric, model.clone());
            for (p, at) in &progs[..i] {
                s.admit_at(p, *at).unwrap();
            }
            rep = Some(s.report().unwrap());
        }
        rebuild_rep = rep;
    });
    // Incremental: one live session, admit + drain per arrival — only
    // the horizon closure of each arrival is re-simulated.
    let mut inc_rep = None;
    let incremental = util::time_avg(iters, || {
        let mut s = CosimSession::with_model(fabric, model.clone());
        for (p, at) in &progs {
            s.admit_at(p, *at).unwrap();
            s.run_to_drain().unwrap();
        }
        inc_rep = Some(s.report().unwrap());
    });

    println!(
        "\n-- time-varying admission (congestion_dvfs): {cfg}, {k} programs ({total_steps} steps) --"
    );
    println!(
        "  rebuild-world:        {:>10}/stream  =  {:>9.0} programs/sec",
        util::fmt_time(rebuild),
        k as f64 / rebuild
    );
    println!(
        "  horizon invalidation: {:>10}/stream  =  {:>9.0} programs/sec  ({:.1}x rebuild)",
        util::fmt_time(incremental),
        k as f64 / incremental,
        rebuild / incremental
    );
    let inc_rep = inc_rep.unwrap();
    let rebuild_rep = rebuild_rep.unwrap();
    golden_check(
        &inc_rep,
        &rebuild_rep,
        "horizon invalidation vs rebuild-world (time-varying)",
    );
    assert!(
        inc_rep.bit_identical(&rebuild_rep),
        "time-varying incremental session diverged from the from-scratch oracle (spans included)"
    );
    (rebuild, incremental)
}

/// Shard-parallel sweep: one staggered time-varying stream simulated at
/// 1/2/4/8 worker threads. Every parallel report is bit-checked against
/// the sequential one (panic on divergence — the tentpole contract), and
/// the row reports simulated cycles/sec per thread count plus the
/// epoch-boundary-straddle telemetry (how often the phase-3 merge had to
/// re-price fires live — the sequential residue of the parallel drain).
/// Returns the stream's simulated cycle count and the per-thread-count
/// (seconds, straddle counters) rows.
fn threads_row(fabric: &Fabric, cfg: &str, k: usize) -> (Cycle, Vec<(usize, f64, StraddleStats)>) {
    let model = varying_model();
    let shapes = request_shapes(fabric);
    let progs: Vec<(FabricProgram, Cycle)> = (0..k)
        .map(|i| (shapes[i % shapes.len()].clone(), i as Cycle * 400))
        .collect();
    let total_steps: usize = progs.iter().map(|(p, _)| p.steps.len()).sum();

    println!(
        "\n-- shard-parallel admission (threads sweep): {cfg}, {k} programs ({total_steps} steps) --"
    );
    let iters = 3;
    let mut base_rep: Option<ExecReport> = None;
    let mut base_secs = f64::NAN;
    let mut rows = Vec::new();
    for threads in SWEEP_THREADS {
        let mut rep = None;
        let mut straddle = StraddleStats::default();
        let secs = util::time_avg(iters, || {
            let mut s = CosimSession::with_model(fabric, model.clone());
            s.set_threads(threads);
            if threads == 1 {
                // The acceptance contract: threads = 1 keeps the model
                // Arc itself (no wrapping on the sequential hot path).
                assert!(Arc::ptr_eq(s.cost_model(), &model));
            }
            for (p, at) in &progs {
                s.admit_at(p, *at).unwrap();
            }
            s.run_to_drain().unwrap();
            rep = Some(s.report().unwrap());
            // Deterministic per fresh session, so last iteration == all.
            straddle = s.straddle_stats();
        });
        let rep = rep.unwrap();
        match &base_rep {
            None => {
                base_secs = secs;
                base_rep = Some(rep);
            }
            Some(base) => {
                assert!(
                    rep.bit_identical(base),
                    "threads={threads} diverged from the sequential engine"
                );
            }
        }
        let cycles = base_rep.as_ref().unwrap().cycles;
        println!(
            concat!(
                "  threads={}:  {:>10}/stream  =  {:>12.0} cycles/sec  ",
                "({:.2}x threads=1)  straddled {}/{} batches, {} fires re-priced"
            ),
            threads,
            util::fmt_time(secs),
            cycles as f64 / secs,
            base_secs / secs,
            straddle.straddled_batches,
            straddle.batches,
            straddle.repriced_fires
        );
        rows.push((threads, secs, straddle));
    }
    golden_check(
        base_rep.as_ref().unwrap(),
        base_rep.as_ref().unwrap(),
        "threads sweep bit-identical at 1/2/4/8",
    );
    (base_rep.unwrap().cycles, rows)
}

fn jf(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string() // JSON has no Infinity/NaN
    }
}

/// The archsim-style evidence bundle: timings + golden verdicts + a
/// stamp tying the numbers to their inputs (CI cats this next to
/// `BENCH_faults.json`). Golden fields are literal `true` because every
/// row panics on divergence — reaching the write means they all held.
fn write_bundle(
    bursts: &[(String, usize, f64, f64, f64)],
    varying: (f64, f64),
    sweep_cycles: Cycle,
    sweep_rows: &[(usize, f64, StraddleStats)],
    sweep_programs: usize,
) {
    let stamp = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let burst_rows: Vec<String> = bursts
        .iter()
        .map(|(cfg, k, rebuild, seq, batched)| {
            format!(
                concat!(
                    "    {{\"config\":\"{}\",\"programs\":{},\"rebuild_secs\":{},",
                    "\"sequential_secs\":{},\"batched_secs\":{},",
                    "\"batched_speedup_vs_rebuild\":{}}}"
                ),
                cfg,
                k,
                jf(*rebuild),
                jf(*seq),
                jf(*batched),
                jf(rebuild / batched)
            )
        })
        .collect();
    let base = sweep_rows[0].1;
    let thread_rows: Vec<String> = sweep_rows
        .iter()
        .map(|(threads, secs, straddle)| {
            format!(
                concat!(
                    "      {{\"threads\":{},\"secs\":{},\"cycles_per_sec\":{},",
                    "\"speedup_vs_sequential\":{},\"parallel_batches\":{},",
                    "\"straddled_batches\":{},\"repriced_fires\":{}}}"
                ),
                threads,
                jf(*secs),
                jf(sweep_cycles as f64 / secs),
                jf(base / secs),
                straddle.batches,
                straddle.straddled_batches,
                straddle.repriced_fires
            )
        })
        .collect();
    let json = format!(
        concat!(
            "{{\n",
            "  \"schema\": \"archytas.bench_admission.v1\",\n",
            "  \"stamp\": {{\"unix_secs\":{},\"sweep_programs\":{},\"sweep_sim_cycles\":{}}},\n",
            "  \"golden\": {{\"burst_bit_identical\":true,",
            "\"varying_bit_identical\":true,",
            "\"threads_sweep_bit_identical\":true}},\n",
            "  \"burst\": [\n{}\n  ],\n",
            "  \"varying\": {{\"rebuild_secs\":{},\"incremental_secs\":{},\"speedup\":{}}},\n",
            "  \"threads_sweep\": {{\n",
            "    \"rows\": [\n{}\n    ]\n",
            "  }}\n",
            "}}\n"
        ),
        stamp,
        sweep_programs,
        sweep_cycles,
        burst_rows.join(",\n"),
        jf(varying.0),
        jf(varying.1),
        jf(varying.0 / varying.1),
        thread_rows.join(",\n")
    );
    let path = archytas::repo_root().join("BENCH_admission.json");
    std::fs::write(&path, json).expect("writing BENCH_admission.json");
    println!("\nwrote {}", path.display());
}

fn main() {
    util::banner(
        "E-ADMIT",
        "batched vs sequential admission vs rebuild-the-world (golden-checked)",
    );
    let mut bursts = Vec::new();
    for cfg in ["edge16.toml", "homogeneous_npu.toml"] {
        let fabric = bundled_fabric(cfg);
        for k in [16, 64] {
            let (rebuild, seq, batched) = burst_row(&fabric, cfg, k);
            bursts.push((cfg.to_string(), k, rebuild, seq, batched));
        }
    }
    // Time-varying pricing: smaller K (the rebuild baseline is O(K^2)
    // with settle passes on top).
    let fabric = bundled_fabric("edge16.toml");
    let varying = varying_row(&fabric, "edge16.toml", 16);
    // Shard-parallel drains: the 1/2/4/8-thread cycles/sec table.
    let sweep_programs = 24;
    let (sweep_cycles, sweep_rows) = threads_row(&fabric, "edge16.toml", sweep_programs);
    write_bundle(&bursts, varying, sweep_cycles, &sweep_rows, sweep_programs);
    println!("\nexpected shape: sequential admission beats rebuild-world by ~K/2");
    println!("(it prices each step once); batching removes the per-request drain");
    println!("bookkeeping on top. All modes are bit-identical to the merged oracle,");
    println!("the time-varying row bit-matches its from-scratch oracle, and the");
    println!("threads sweep bit-matches the sequential engine at every count.");
}
