//! E4 — MILP/SMT design-space exploration (paper Sec. III).
//!
//! Solve-time and evaluation-count comparison of the DSE methods across
//! fabric sizes, plus the solver micro-benchmarks (simplex/B&B and
//! DPLL+theory) that show the engines scale to the problem sizes the
//! toolchain feeds them.

#[path = "util.rs"]
mod util;

use archytas::dse::milp::{Milp, Sense};
use archytas::dse::{explore, ExploreConfig, ExploreMethod};

fn main() {
    util::banner("E4", "topology DSE: solver comparison");
    println!(
        "{:>7} {:<14} {:<12} {:>10} {:>9} {:>6} {:>10}",
        "nodes", "method", "winner", "est-lat", "evals", "sims", "wall"
    );
    for nodes in [16usize, 32, 64, 144] {
        for (name, method) in [
            ("exhaustive", ExploreMethod::Exhaustive),
            ("milp", ExploreMethod::Milp),
            ("smt", ExploreMethod::Smt),
            ("iterative-sim", ExploreMethod::IterativeSim),
        ] {
            let cfg = ExploreConfig { min_nodes: nodes, max_area: 80.0, ..Default::default() };
            let (r, wall) = util::time_once(|| explore(&cfg, method).unwrap());
            let best = &r.candidates[r.best];
            println!(
                "{:>7} {:<14} {:<12} {:>10.1} {:>9} {:>6} {:>10}",
                nodes,
                name,
                best.name,
                best.sim_latency.unwrap_or(best.est_latency),
                r.solver_evals,
                r.sim_evals,
                util::fmt_time(wall)
            );
        }
    }

    println!("\n-- MILP engine scaling (assignment problems) --");
    println!("{:>8} {:>8} {:>10} {:>10}", "tasks", "vars", "B&B nodes", "wall");
    for tasks in [4usize, 6, 8, 10] {
        let machines = tasks;
        let (sol, wall) = util::time_once(|| {
            let mut m = Milp::new();
            let mut v = vec![vec![0usize; machines]; tasks];
            for t in 0..tasks {
                for j in 0..machines {
                    // deterministic pseudo-costs
                    let c = ((t * 7 + j * 13) % 17 + 1) as f64;
                    v[t][j] = m.add_var(0.0, 1.0, c, true);
                }
            }
            for t in 0..tasks {
                m.add_constraint((0..machines).map(|j| (v[t][j], 1.0)).collect(), Sense::Eq, 1.0);
            }
            for j in 0..machines {
                m.add_constraint((0..tasks).map(|t| (v[t][j], 1.0)).collect(), Sense::Le, 1.0);
            }
            m.minimize().unwrap().unwrap()
        });
        println!(
            "{:>8} {:>8} {:>10} {:>10}",
            tasks,
            tasks * machines,
            sol.nodes,
            util::fmt_time(wall)
        );
    }
    println!("\nexpected shape: solvers match the exhaustive optimum with fewer");
    println!("evaluations; sim-in-the-loop adds ms-scale refinement only for the top-k.");
}
