//! E4 — design-space exploration throughput (paper Sec. III).
//!
//! Two layers of evidence:
//!
//! * the seed solver comparison (MILP/SMT/iterative-sim vs exhaustive
//!   analytic screening) across fabric sizes, unchanged — the analytic
//!   tier screens thousands of candidates per second and the solvers
//!   match its optimum with fewer evaluations;
//! * the batched incremental sweep (`dse::sweep`) on the golden config
//!   (`configs/dse_sweep.toml`, 96 candidate fabrics through the
//!   event-driven co-sim): candidates/sec for session reuse vs the
//!   rebuild-world oracle, across worker threads 1/2/4/8.
//!
//! The bench panics on two golden divergences (the `tests/dse_golden.rs`
//! contracts, re-checked in CI's bench run):
//!
//! * **incremental ≡ rebuild-world** — the session-reuse sweep must be
//!   bit-identical to rebuilding every candidate's world from scratch;
//! * **thread invariance** — the parallel group fan-out must return the
//!   same bits at every worker count.
//!
//! The evidence bundle lands in `rust/BENCH_dse.json`
//! (`archytas.bench_dse.v1`), cat'd by the CI summary.

#[path = "util.rs"]
mod util;

use archytas::dse::milp::{Milp, Sense};
use archytas::dse::{explore, sweep, sweep_rebuild, ExploreConfig, ExploreMethod, SweepSpec};

fn golden_spec() -> SweepSpec {
    let path = archytas::repo_root().join("configs/dse_sweep.toml");
    SweepSpec::from_toml(&std::fs::read_to_string(&path).expect("reading dse_sweep.toml"))
        .expect("golden sweep config must parse")
}

/// Golden 1: the incremental sweep reproduces the rebuild-world oracle
/// bit for bit — every makespan, energy bit and per-program span.
/// Panics on divergence.
fn incremental_golden(spec: &SweepSpec) {
    let inc = sweep(spec).expect("incremental sweep");
    let reb = sweep_rebuild(spec).expect("rebuild-world oracle");
    assert_eq!(inc.evals.len(), reb.evals.len());
    for (a, b) in inc.evals.iter().zip(&reb.evals) {
        assert!(
            a.bit_identical(b),
            "candidate {} ({}/{}/{}/{}): incremental sweep diverged from rebuild oracle",
            a.index,
            a.topology,
            a.mix,
            a.model,
            a.policy
        );
    }
    assert_eq!(inc.best(), reb.best());
    println!(
        "  golden match (incremental ≡ rebuild-world): ok ({} candidates, {} vs {} sessions)",
        inc.evals.len(),
        inc.sessions,
        reb.sessions
    );
}

/// Golden 2: the parallel group fan-out is thread-invariant. Panics if
/// any worker count moves a bit vs the sequential walk.
fn thread_invariance_golden(spec: &SweepSpec) {
    let one = sweep(spec).expect("threads=1");
    for threads in [2usize, 4, 8] {
        let s = SweepSpec { threads, ..spec.clone() };
        let many = sweep(&s).expect("parallel sweep");
        for (a, b) in one.evals.iter().zip(&many.evals) {
            assert!(
                a.bit_identical(b),
                "threads={threads}: candidate {} diverged from sequential sweep",
                a.index
            );
        }
    }
    println!("  golden match (thread-invariant fan-out, threads 2/4/8): ok");
}

struct RowOut {
    mode: &'static str,
    threads: usize,
    candidates: usize,
    wall_s: f64,
    cands_per_sec: f64,
}

fn jf(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string() // JSON has no Infinity/NaN
    }
}

fn write_bundle(rows: &[RowOut], reuse_speedup: f64, parallel_speedup: f64, best_threads: usize) {
    let stamp = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let row_json: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                concat!(
                    "    {{\"mode\":\"{}\",\"threads\":{},\"candidates\":{},",
                    "\"wall_s\":{},\"cands_per_sec\":{}}}"
                ),
                r.mode,
                r.threads,
                r.candidates,
                jf(r.wall_s),
                jf(r.cands_per_sec)
            )
        })
        .collect();
    let json = format!(
        concat!(
            "{{\n",
            "  \"schema\": \"archytas.bench_dse.v1\",\n",
            "  \"stamp\": {{\"unix_secs\":{},\"config\":\"dse_sweep.toml\"}},\n",
            "  \"golden\": {{\"incremental_bit_identical\":true,",
            "\"thread_invariant\":true}},\n",
            "  \"speedup\": {{\"session_reuse_1t\":{},",
            "\"incremental_best_vs_rebuild_1t\":{},\"best_threads\":{}}},\n",
            "  \"rows\": [\n{}\n  ]\n",
            "}}\n"
        ),
        stamp,
        jf(reuse_speedup),
        jf(parallel_speedup),
        best_threads,
        row_json.join(",\n")
    );
    let path = archytas::repo_root().join("BENCH_dse.json");
    std::fs::write(&path, json).expect("writing BENCH_dse.json");
    println!("\nwrote {}", path.display());
}

fn main() {
    util::banner("E4", "topology DSE: solver comparison");
    println!(
        "{:>7} {:<14} {:<12} {:>10} {:>9} {:>6} {:>10}",
        "nodes", "method", "winner", "est-lat", "evals", "sims", "wall"
    );
    let mut analytic_rate = 0.0;
    let mut analytic_cands = 0usize;
    for nodes in [16usize, 32, 64, 144] {
        for (name, method) in [
            ("exhaustive", ExploreMethod::Exhaustive),
            ("milp", ExploreMethod::Milp),
            ("smt", ExploreMethod::Smt),
            ("iterative-sim", ExploreMethod::IterativeSim),
        ] {
            let cfg = ExploreConfig { min_nodes: nodes, max_area: 80.0, ..Default::default() };
            let (r, wall) = util::time_once(|| explore(&cfg, method).unwrap());
            let best = &r.candidates[r.best];
            println!(
                "{:>7} {:<14} {:<12} {:>10.1} {:>9} {:>6} {:>10}",
                nodes,
                name,
                best.name,
                best.sim_latency.unwrap_or(best.est_latency),
                r.solver_evals,
                r.sim_evals,
                util::fmt_time(wall)
            );
            // The seed baseline for the throughput table: analytic
            // screening + flit refinement at the largest size.
            if nodes == 144 && method == ExploreMethod::IterativeSim {
                analytic_cands = r.candidates.len();
                analytic_rate = r.candidates.len() as f64 / wall;
            }
        }
    }

    println!("\n-- MILP engine scaling (assignment problems) --");
    println!("{:>8} {:>8} {:>10} {:>10}", "tasks", "vars", "B&B nodes", "wall");
    for tasks in [4usize, 6, 8, 10] {
        let machines = tasks;
        let (sol, wall) = util::time_once(|| {
            let mut m = Milp::new();
            let mut v = vec![vec![0usize; machines]; tasks];
            for t in 0..tasks {
                for j in 0..machines {
                    // deterministic pseudo-costs
                    let c = ((t * 7 + j * 13) % 17 + 1) as f64;
                    v[t][j] = m.add_var(0.0, 1.0, c, true);
                }
            }
            for t in 0..tasks {
                m.add_constraint((0..machines).map(|j| (v[t][j], 1.0)).collect(), Sense::Eq, 1.0);
            }
            for j in 0..machines {
                m.add_constraint((0..tasks).map(|t| (v[t][j], 1.0)).collect(), Sense::Le, 1.0);
            }
            m.minimize().unwrap().unwrap()
        });
        println!(
            "{:>8} {:>8} {:>10} {:>10}",
            tasks,
            tasks * machines,
            sol.nodes,
            util::fmt_time(wall)
        );
    }

    util::banner("E4b", "batched incremental sweep (dse_sweep.toml, co-sim measured)");
    let spec = golden_spec();
    let n = spec.candidates();
    incremental_golden(&spec);
    thread_invariance_golden(&spec);

    let mut rows = Vec::new();
    println!(
        "\n{:<22} {:>7} {:>11} {:>10} {:>14}",
        "mode", "threads", "candidates", "wall", "cands/sec"
    );
    let rebuild_wall = util::time_avg(3, || {
        sweep_rebuild(&spec).unwrap();
    });
    rows.push(RowOut {
        mode: "rebuild-world",
        threads: 1,
        candidates: n,
        wall_s: rebuild_wall,
        cands_per_sec: n as f64 / rebuild_wall,
    });
    println!(
        "{:<22} {:>7} {:>11} {:>10} {:>14.1}",
        "rebuild-world",
        1,
        n,
        util::fmt_time(rebuild_wall),
        n as f64 / rebuild_wall
    );
    let mut inc_1t = f64::INFINITY;
    let mut best_wall = f64::INFINITY;
    let mut best_threads = 1usize;
    for threads in [1usize, 2, 4, 8] {
        let s = SweepSpec { threads, ..spec.clone() };
        let wall = util::time_avg(3, || {
            sweep(&s).unwrap();
        });
        if threads == 1 {
            inc_1t = wall;
        }
        if wall < best_wall {
            best_wall = wall;
            best_threads = threads;
        }
        rows.push(RowOut {
            mode: "incremental",
            threads,
            candidates: n,
            wall_s: wall,
            cands_per_sec: n as f64 / wall,
        });
        println!(
            "{:<22} {:>7} {:>11} {:>10} {:>14.1}",
            "incremental",
            threads,
            n,
            util::fmt_time(wall),
            n as f64 / wall
        );
    }
    rows.push(RowOut {
        mode: "seed-analytic+flit",
        threads: 1,
        candidates: analytic_cands,
        wall_s: analytic_cands as f64 / analytic_rate,
        cands_per_sec: analytic_rate,
    });
    println!(
        "{:<22} {:>7} {:>11} {:>10} {:>14.1}   (analytic estimates, no co-sim)",
        "seed-analytic+flit",
        1,
        analytic_cands,
        util::fmt_time(analytic_cands as f64 / analytic_rate),
        analytic_rate
    );

    let reuse_speedup = rebuild_wall / inc_1t;
    let parallel_speedup = rebuild_wall / best_wall;
    println!("\nDSE sweep speedup (session reuse @1t vs rebuild @1t): {reuse_speedup:.2}x");
    println!(
        "DSE sweep speedup (incremental @{best_threads}t vs rebuild @1t): {parallel_speedup:.2}x"
    );
    write_bundle(&rows, reuse_speedup, parallel_speedup, best_threads);
    println!("\nexpected shape: solvers match the exhaustive optimum with fewer");
    println!("evaluations; the incremental sweep prices every candidate through the");
    println!("real co-sim while skipping the per-candidate world rebuild (one session");
    println!("per topology x mix x policy group, cost models walked via set_model),");
    println!("and the group fan-out scales with worker threads without moving a bit.");
}
