//! E9 — neuromorphic (event-driven SNN) study (paper Sec. II).
//!
//! Activity sweep on the Loihi-class core model vs running the same
//! synapse count dense on the NPU: finds the activity crossover below
//! which spiking wins — the deployment rule of thumb the paper's
//! neuromorphic leg needs.

#[path = "util.rs"]
mod util;

use archytas::accel::{Accelerator, Compute, DigitalNpu, Neuromorphic, Precision};

fn main() {
    util::banner("E9", "neuromorphic activity sweep");
    let snn = Neuromorphic::default();
    let npu = DigitalNpu::default();
    // An MLP layer as synapses: 1M synapses ~ 1024x1024 dense layer.
    let synapses = 1 << 20;
    let dense_equiv = Compute::MatMul { m: 1, k: 1024, n: 1024 };
    let npu_cost = npu.cost(&dense_equiv, Precision::Int8);
    let npu_pj = npu_cost.total_energy_pj();
    let npu_us = npu_cost.cycles as f64 / (npu.freq_ghz() * 1e9) * 1e6;

    println!(
        "{:>10} {:>12} {:>12} {:>12} {:>14}",
        "activity", "snn nJ", "snn us", "npu nJ", "snn wins energy"
    );
    let mut crossover: Option<f64> = None;
    for permille in [10u32, 25, 50, 100, 200, 350, 500, 750, 1000] {
        let act = permille as f64 / 1000.0;
        let c = Compute::SpikingLayer { synapses, activity: act };
        let m = snn.cost(&c, Precision::Analog);
        let wins = m.total_energy_pj() < npu_pj;
        if !wins && crossover.is_none() && permille > 10 {
            crossover = Some(act);
        }
        println!(
            "{:>10.3} {:>12.1} {:>12.2} {:>12.1} {:>14}",
            act,
            m.total_energy_pj() / 1e3,
            m.cycles as f64 / (snn.freq_ghz() * 1e9) * 1e6,
            npu_pj / 1e3,
            if wins { "yes" } else { "no" }
        );
    }
    println!(
        "\nNPU dense reference: {:.1} nJ, {:.2} us per layer pass",
        npu_pj / 1e3,
        npu_us
    );
    match crossover {
        Some(a) => println!("energy crossover at activity ~{a:.3}: SNN wins below, NPU above."),
        None => println!("SNN wins at every swept activity level."),
    }
    println!("expected shape: SNN energy linear in activity; crossover in the 10-50% band.");
}
