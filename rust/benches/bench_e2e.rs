//! E8 — end-to-end throughput/latency bench (the measurable half of
//! examples/uav_vision.rs): PJRT artifact execution latency per variant,
//! dynamic-batching serving throughput, and the coordinator's raw
//! co-simulation rate (the L3 perf target of DESIGN.md §7).

#[path = "util.rs"]
mod util;

use archytas::accel::Precision;
use archytas::compiler::lowering::lower;
use archytas::compiler::mapper::{map_graph, MapStrategy};
use archytas::config::FabricConfig;
use archytas::coordinator::serve::drive_server;
use archytas::coordinator::{cosim, BatchServer};
use archytas::fabric::Fabric;
use archytas::runtime::Runtime;
use archytas::workloads;

fn main() {
    util::banner("E8", "end-to-end: PJRT execution + serving + co-sim rate");
    let rt = match Runtime::open_default() {
        Ok(rt) => rt,
        Err(e) => {
            println!("skipping PJRT section ({e}); run `make artifacts` first");
            return cosim_rate_only();
        }
    };

    println!("-- artifact execution latency (batch of 4 frames) --");
    println!("{:<16} {:>12} {:>14}", "artifact", "ms/batch", "frames/s");
    for name in ["vit_digital", "vit_npu_int8", "vit_analog"] {
        let inputs = rt.registry().golden_inputs(name).unwrap();
        let exe = rt.executable(name).unwrap();
        let avg = util::time_avg(20, || {
            exe.run(&inputs).unwrap();
        });
        println!("{:<16} {:>12.3} {:>14.0}", name, avg * 1e3, 4.0 / avg);
    }

    println!("\n-- dynamic batching throughput (mlp_digital, 8x256 batch) --");
    let spec = rt.registry().spec("mlp_digital").unwrap();
    let (batch, feat) = (spec.inputs[0].dims[0], spec.inputs[0].dims[1]);
    let out_cols = spec.outputs[0].dims[1];
    for clients in [1usize, 4, 8] {
        let exe = rt.executable("mlp_digital").unwrap();
        let server = BatchServer::new(feat, out_cols, batch);
        let ((stats, _), wall) = util::time_once(|| {
            drive_server(
                &server,
                clients,
                64,
                move |c, i| {
                    let mut rng = archytas::sim::Rng::new((c * 31 + i) as u64);
                    (0..feat).map(|_| rng.normal() as f32).collect()
                },
                {
                    let exe = exe.clone();
                    move |input| Ok(exe.run(std::slice::from_ref(input))?.remove(0))
                },
            )
            .unwrap()
        });
        println!(
            "clients={clients}: {} req in {}  mean batch {:.2}  p50 {:.0} us  {:.0} req/s",
            stats.requests,
            util::fmt_time(wall),
            stats.mean_batch(),
            stats.p50_latency_us(),
            stats.throughput_rps(wall)
        );
    }

    cosim_rate_only();
}

fn cosim_rate_only() {
    println!("\n-- coordinator co-simulation rate (L3 perf target) --");
    let fabric = Fabric::build(
        FabricConfig::from_toml(&std::fs::read_to_string(
            archytas::repo_root().join("configs/edge16.toml"),
        ).unwrap()).unwrap(),
    )
    .unwrap();
    let g = workloads::vit(&workloads::VitParams::default(), 0).unwrap();
    let m = map_graph(&g, &fabric, MapStrategy::Greedy, Precision::Int8).unwrap();
    let prog = lower(&g, &fabric, &m).unwrap();
    let steps = prog.steps.len();
    let avg = util::time_avg(50, || {
        cosim(&fabric, &prog).unwrap();
    });
    println!(
        "cosim: {} steps in {} -> {:.0} steps/s ({:.1} full-model sims/s)",
        steps,
        util::fmt_time(avg),
        steps as f64 / avg,
        1.0 / avg
    );
    // mapping rate too (compile-path hot loop)
    let avg_map = util::time_avg(20, || {
        map_graph(&g, &fabric, MapStrategy::Greedy, Precision::Int8).unwrap();
    });
    println!("greedy map: {} per compile ({:.1} compiles/s)", util::fmt_time(avg_map), 1.0 / avg_map);
}
