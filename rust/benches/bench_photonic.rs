//! E7 — photonic accelerator study ("Processing-On-the-Flight", paper
//! Sec. II; calibration points: Feldmann'21, Xu'21 11-TOPS).
//!
//! MVM size sweep across devices: achieved TOPS, pJ/MAC, and the analog
//! accuracy of the functional twin (the Pallas crossbar kernel semantics
//! via the golden artifacts are exercised in E8; here the noise-accuracy
//! relation uses the crossbar ref model constants).

#[path = "util.rs"]
mod util;

use archytas::accel::{Accelerator, Compute, CpuCore, CrossbarNvm, DigitalNpu, Photonic, Precision};

fn main() {
    util::banner("E7", "photonic / analog MVM vs digital");
    let devices: Vec<(&str, Box<dyn Accelerator>, Precision)> = vec![
        ("photonic", Box::new(Photonic::default()), Precision::Analog),
        ("nvm-crossbar", Box::new(CrossbarNvm::default()), Precision::Analog),
        ("digital-npu", Box::new(DigitalNpu::default()), Precision::Int8),
        ("riscv-cpu", Box::new(CpuCore::default()), Precision::Int8),
    ];
    println!(
        "{:<14} {:>6} {:>10} {:>10} {:>10} {:>10}",
        "device", "N", "cycles", "TOPS", "pJ/MAC", "W"
    );
    for n in [64usize, 128, 256, 512, 1024] {
        for (name, dev, p) in &devices {
            let c = Compute::MatMul { m: n, k: n, n };
            let m = dev.cost(&c, *p);
            println!(
                "{:<14} {:>6} {:>10} {:>10.2} {:>10.3} {:>10.3}",
                name,
                n,
                m.cycles,
                m.tops(dev.freq_ghz()),
                m.total_energy_pj() / c.ops() as f64,
                m.watts(dev.freq_ghz()),
            );
        }
        println!();
    }

    println!("-- small-batch overhead (m=1 MVM, the laser/ADC tax) --");
    println!("{:<14} {:>10} {:>12}", "device", "pJ/MAC m=1", "pJ/MAC m=4096");
    for (name, dev, p) in &devices {
        let small = dev.cost(&Compute::MatMul { m: 1, k: 64, n: 64 }, *p);
        let big = dev.cost(&Compute::MatMul { m: 4096, k: 64, n: 64 }, *p);
        println!(
            "{:<14} {:>10.3} {:>12.3}",
            name,
            small.total_energy_pj() / (64.0 * 64.0),
            big.total_energy_pj() / (4096.0 * 64.0 * 64.0)
        );
    }
    println!("\nexpected shape: photonic tops the raw TOPS chart at large N with lowest");
    println!("pJ/MAC; the m=1 column shows the ADC/laser overhead crossover; CPU last.");
}
