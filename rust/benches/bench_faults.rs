//! E-FAULT — fault-injection overhead and recovery-policy comparison.
//!
//! Three sections, golden-checked the same way `bench_admission` is
//! (panic on any bit divergence, so CI fails loudly):
//!
//! * **empty-plan overhead**: a K-program staggered stream through a
//!   plain `CosimSession` vs a `FaultySession` carrying an empty
//!   `FaultPlan` — the robustness layer's zero-cost claim, bit-checked
//!   (`tests/fault_golden.rs` pins the same contract across the matrix);
//! * **recovery policies**: the same stream under a seeded fault trace
//!   (transients, deaths, link/HBM pricing) for each `RecoveryPolicy`,
//!   reporting wall time plus the degradation telemetry per policy;
//! * **replay**: the seeded trace admitted incrementally (pause before
//!   each arrival) vs a from-scratch oracle fed everything upfront —
//!   the incremental ≡ from-scratch determinism contract under time.
//!
//! Besides the human table the bench emits `BENCH_faults.json` next to
//! the crate manifest: a machine-checkable evidence bundle (golden
//! verdicts + timings + degradation reports + stamp) so the
//! perf/robustness trajectory is diffable across commits.

#[path = "util.rs"]
mod util;

use std::sync::Arc;

use archytas::accel::Precision;
use archytas::compiler::lowering::lower;
use archytas::compiler::mapper::{map_graph, MapStrategy};
use archytas::compiler::FabricProgram;
use archytas::coordinator::{
    CosimSession, DegradationReport, ExecReport, FaultySession, RecoveryPolicy,
};
use archytas::fabric::Fabric;
use archytas::sim::{Cycle, FaultConfig, FaultPlan};
use archytas::testutil::bundled_fabric;
use archytas::workloads;

const CONFIG: &str = "edge16.toml";
const K: usize = 32;
/// Inter-arrival gap of the request stream (cycles).
const GAP: Cycle = 300;

const POLICIES: [RecoveryPolicy; 4] = [
    RecoveryPolicy::Retry,
    RecoveryPolicy::Remap,
    RecoveryPolicy::DeadlineAware,
    RecoveryPolicy::Shed,
];

/// K small heterogeneous requests (three mlp shapes cycled, the
/// `bench_admission` stream) with staggered arrivals.
fn request_stream(fabric: &Fabric, k: usize) -> Vec<(FabricProgram, Cycle)> {
    let shapes: Vec<FabricProgram> = [(4usize, 64usize, 32usize), (8, 32, 16), (2, 48, 24)]
        .iter()
        .enumerate()
        .map(|(i, &(b, inp, hid))| {
            let g = workloads::mlp(b, inp, &[hid], 10, i as u64 + 1).unwrap();
            let m = map_graph(&g, fabric, MapStrategy::Greedy, Precision::Int8).unwrap();
            lower(&g, fabric, &m).unwrap()
        })
        .collect();
    (0..k)
        .map(|i| (shapes[i % shapes.len()].clone(), i as Cycle * GAP))
        .collect()
}

/// The seeded trace every faulty row replays: behavioral faults
/// (transients + deaths) and pricing faults (link degrade/fail, HBM
/// brownout) over a horizon covering the whole staggered stream.
fn seeded_cfg() -> FaultConfig {
    FaultConfig {
        seed: 42,
        horizon: 1 << 15,
        window: 1024,
        p_transient: 0.02,
        p_death: 0.005,
        p_link_degrade: 0.01,
        p_link_fail: 0.002,
        p_hbm_brownout: 0.01,
        p_crossbar_drift: 0.02,
        ..FaultConfig::default()
    }
}

fn golden_check(ok: bool, tag: &str) {
    println!("  golden match ({tag}): {}", if ok { "ok" } else { "MISMATCH" });
    assert!(ok, "{tag}: diverged");
}

/// Empty-plan overhead: both sessions get the identical call sequence
/// (admit everything upfront, one drain) and must produce identical
/// bits. Returns (fault-free secs, empty-plan secs).
fn overhead_row(fabric: &Fabric, progs: &[(FabricProgram, Cycle)]) -> (f64, f64) {
    let iters = 5;
    let mut base_rep = None;
    let base = util::time_avg(iters, || {
        let mut s = CosimSession::new(fabric);
        for (p, at) in progs {
            s.admit_at(p, *at).unwrap();
        }
        base_rep = Some(s.report().unwrap());
    });
    let mut empty_rep = None;
    let empty = util::time_avg(iters, || {
        let mut s = FaultySession::with_plan(
            fabric,
            FaultPlan::empty(),
            &FaultConfig::default(),
            RecoveryPolicy::Retry,
        )
        .unwrap();
        assert!(
            Arc::ptr_eq(s.cost_model(), fabric.cost_model()),
            "an empty plan must not wrap the cost model"
        );
        for (p, at) in progs {
            s.admit_at(p, *at).unwrap();
        }
        empty_rep = Some(s.report().unwrap());
    });

    println!("\n-- empty-plan overhead: {CONFIG}, {K} staggered programs --");
    println!(
        "  fault-free session: {:>10}/stream  =  {:>9.0} programs/sec",
        util::fmt_time(base),
        K as f64 / base
    );
    println!(
        "  empty-plan faulty:  {:>10}/stream  =  {:>9.0} programs/sec  ({:.2}x fault-free)",
        util::fmt_time(empty),
        K as f64 / empty,
        empty / base
    );
    let identical = empty_rep.unwrap().bit_identical(&base_rep.unwrap());
    golden_check(identical, "empty plan vs fault-free");
    (base, empty)
}

/// One timed episode per recovery policy under the seeded trace.
fn policy_rows(
    fabric: &Fabric,
    progs: &[(FabricProgram, Cycle)],
    cfg: &FaultConfig,
    base: f64,
) -> Vec<(RecoveryPolicy, f64, ExecReport, DegradationReport)> {
    println!("\n-- recovery policies under the seeded trace (seed {}, horizon {}) --", cfg.seed, cfg.horizon);
    println!(
        "  {:<14} {:>10} {:>9}  {:>4} {:>4} {:>4} {:>5}  {:>4} {:>4} {:>4} {:>5}  {:>6}",
        "policy", "time", "prog/s", "done", "shed", "rmap", "retry", "inj", "eff", "mask", "price", "avail"
    );
    let iters = 3;
    let mut rows = Vec::new();
    for policy in POLICIES {
        let mut out = None;
        let secs = util::time_avg(iters, || {
            let mut s = FaultySession::new(fabric, cfg, policy).unwrap();
            for (p, at) in progs {
                s.admit_at(p, *at).unwrap();
            }
            let rep = s.report().unwrap();
            let deg = s.degradation(&rep);
            out = Some((rep, deg));
        });
        let (rep, deg) = out.unwrap();
        // Structural invariants of the telemetry (the episode-specific
        // values are data, not assertions — seeds change across PRs).
        assert_eq!(deg.completed + deg.shed, deg.programs, "{policy:?}: request conservation");
        assert_eq!(
            deg.faults_masked + deg.faults_effective + deg.pricing_events,
            deg.faults_injected,
            "{policy:?}: fault conservation"
        );
        println!(
            "  {:<14} {:>10} {:>9.0}  {:>4} {:>4} {:>4} {:>5}  {:>4} {:>4} {:>4} {:>5}  {:>6.3}",
            format!("{policy:?}"),
            util::fmt_time(secs),
            K as f64 / secs,
            deg.completed,
            deg.shed,
            deg.remapped,
            deg.transient_retries,
            deg.faults_injected,
            deg.faults_effective,
            deg.faults_masked,
            deg.pricing_events,
            deg.availability
        );
        rows.push((policy, secs, rep, deg));
    }
    println!("  (seeded overhead vs fault-free: {:.2}x .. {:.2}x)",
        rows.iter().map(|r| r.1 / base).fold(f64::INFINITY, f64::min),
        rows.iter().map(|r| r.1 / base).fold(0.0, f64::max));
    rows
}

/// Incremental replay vs from-scratch oracle on the seeded trace:
/// the bit-identity contract of `tests/fault_golden.rs`, timed.
fn replay_row(fabric: &Fabric, progs: &[(FabricProgram, Cycle)], cfg: &FaultConfig) -> (f64, f64) {
    let iters = 3;
    let mut oracle_out = None;
    let oracle = util::time_avg(iters, || {
        let mut s = FaultySession::new(fabric, cfg, RecoveryPolicy::Retry).unwrap();
        for (p, at) in progs {
            s.admit_at(p, *at).unwrap();
        }
        let rep = s.report().unwrap();
        let deg = s.degradation(&rep);
        oracle_out = Some((rep, deg));
    });
    let mut inc_out = None;
    let incremental = util::time_avg(iters, || {
        let mut s = FaultySession::new(fabric, cfg, RecoveryPolicy::Retry).unwrap();
        // Drain to just before each arrival, then admit: fault events due
        // by then are applied mid-stream, never past the next admission
        // (the fault floor stays below every arrival by construction).
        for (p, at) in progs {
            s.run_until(at.saturating_sub(1)).unwrap();
            s.admit_at(p, *at).unwrap();
        }
        let rep = s.report().unwrap();
        let deg = s.degradation(&rep);
        inc_out = Some((rep, deg));
    });

    println!("\n-- incremental fault replay vs from-scratch oracle (Retry) --");
    println!(
        "  from-scratch: {:>10}/stream  =  {:>9.0} programs/sec",
        util::fmt_time(oracle),
        K as f64 / oracle
    );
    println!(
        "  incremental:  {:>10}/stream  =  {:>9.0} programs/sec",
        util::fmt_time(incremental),
        K as f64 / incremental
    );
    let (orep, odeg) = oracle_out.unwrap();
    let (irep, ideg) = inc_out.unwrap();
    golden_check(
        irep.bit_identical(&orep) && ideg == odeg,
        "incremental vs from-scratch (report + degradation)",
    );
    (oracle, incremental)
}

fn jf(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string() // JSON has no Infinity/NaN
    }
}

fn report_json(r: &ExecReport) -> String {
    format!(
        "{{\"cycles\":{},\"exec_steps\":{},\"energy_pj\":{},\"mean_utilization\":{}}}",
        r.cycles,
        r.exec_steps,
        jf(r.metrics.total_energy_pj()),
        jf(r.mean_utilization())
    )
}

fn degradation_json(d: &DegradationReport) -> String {
    format!(
        concat!(
            "{{\"programs\":{},\"completed\":{},\"retried\":{},\"remapped\":{},",
            "\"shed\":{},\"deadline_violated\":{},\"transient_retries\":{},",
            "\"faults_injected\":{},\"faults_masked\":{},\"faults_effective\":{},",
            "\"pricing_events\":{},\"availability\":{},",
            "\"mean_cycles_between_effective\":{}}}"
        ),
        d.programs,
        d.completed,
        d.retried,
        d.remapped,
        d.shed,
        d.deadline_violated,
        d.transient_retries,
        d.faults_injected,
        d.faults_masked,
        d.faults_effective,
        d.pricing_events,
        jf(d.availability),
        jf(d.mean_cycles_between_effective)
    )
}

/// The archsim-style evidence bundle: golden verdicts + timings +
/// per-policy reports + a stamp tying the numbers to their inputs.
fn write_bundle(
    cfg: &FaultConfig,
    base: f64,
    empty: f64,
    rows: &[(RecoveryPolicy, f64, ExecReport, DegradationReport)],
    oracle: f64,
    incremental: f64,
) {
    let stamp = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let policies: Vec<String> = rows
        .iter()
        .map(|(policy, secs, rep, deg)| {
            format!(
                concat!(
                    "    {{\"policy\":\"{:?}\",\"secs\":{},\"programs_per_sec\":{},",
                    "\"overhead_vs_fault_free\":{},\"report\":{},\"degradation\":{}}}"
                ),
                policy,
                jf(*secs),
                jf(K as f64 / secs),
                jf(secs / base),
                report_json(rep),
                degradation_json(deg)
            )
        })
        .collect();
    let json = format!(
        concat!(
            "{{\n",
            "  \"schema\": \"archytas.bench_faults.v1\",\n",
            "  \"stamp\": {{\"unix_secs\":{},\"config\":\"{}\",\"programs\":{},",
            "\"arrival_gap_cycles\":{},\"fault_seed\":{},\"horizon\":{},\"window\":{}}},\n",
            "  \"golden\": {{\"empty_plan_bit_identical\":true,",
            "\"incremental_matches_from_scratch\":true}},\n",
            "  \"fault_free\": {{\"secs\":{},\"programs_per_sec\":{}}},\n",
            "  \"empty_plan\": {{\"secs\":{},\"programs_per_sec\":{},\"overhead\":{}}},\n",
            "  \"policies\": [\n{}\n  ],\n",
            "  \"replay\": {{\"from_scratch_secs\":{},\"incremental_secs\":{}}}\n",
            "}}\n"
        ),
        stamp,
        CONFIG,
        K,
        GAP,
        cfg.seed,
        cfg.horizon,
        cfg.window,
        jf(base),
        jf(K as f64 / base),
        jf(empty),
        jf(K as f64 / empty),
        jf(empty / base),
        policies.join(",\n"),
        jf(oracle),
        jf(incremental)
    );
    let path = archytas::repo_root().join("BENCH_faults.json");
    std::fs::write(&path, json).expect("writing BENCH_faults.json");
    println!("\nwrote {}", path.display());
}

fn main() {
    util::banner(
        "E-FAULT",
        "fault-injection overhead + recovery policies (golden-checked)",
    );
    let fabric = bundled_fabric(CONFIG);
    let progs = request_stream(&fabric, K);
    let cfg = seeded_cfg();
    let (base, empty) = overhead_row(&fabric, &progs);
    let rows = policy_rows(&fabric, &progs, &cfg, base);
    let (oracle, incremental) = replay_row(&fabric, &progs, &cfg);
    write_bundle(&cfg, base, empty, &rows, oracle, incremental);
    println!("\nexpected shape: the empty plan rides the plain session's code path");
    println!("(same bits, ~1x wall time); a seeded trace pays for retraction +");
    println!("re-pricing on each behavioral fault; incremental replay bit-matches");
    println!("the from-scratch oracle, so fault episodes are replayable evidence.");
}
