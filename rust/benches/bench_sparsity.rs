//! E5 — sparsification / pruning / quantization sweep (paper Sec. V.B).
//!
//! ViT-tiny + MLP under the compiler's compression passes: measured top-1
//! agreement vs the f32 reference (on the synthetic teacher dataset) and
//! fabric-level energy/latency from the co-simulator — accuracy is
//! *measured* through the IR interpreter, cost through the fabric models.

#[path = "util.rs"]
mod util;

use archytas::accel::Precision;
use archytas::compiler::lowering::lower;
use archytas::compiler::mapper::{map_graph, MapStrategy};
use archytas::compiler::{pruning, quantize, sparsify};
use archytas::config::FabricConfig;
use archytas::coordinator::cosim;
use archytas::fabric::Fabric;
use archytas::ir::interp::{self, Mat};
use archytas::ir::Graph;
use archytas::workloads;

fn agreement(g_ref: &Graph, g_mod: &Graph, ds: &workloads::Dataset) -> f64 {
    let a: Vec<Mat> = ds.inputs.iter().map(|x| interp::run(g_ref, &[x.clone()]).unwrap().remove(0)).collect();
    let b: Vec<Mat> = ds.inputs.iter().map(|x| interp::run(g_mod, &[x.clone()]).unwrap().remove(0)).collect();
    workloads::top1_agreement(&a, &b)
}

fn cosim_cost(g: &Graph, fabric: &Fabric, p: Precision) -> (u64, f64) {
    let m = map_graph(g, fabric, MapStrategy::Greedy, p).unwrap();
    let prog = lower(g, fabric, &m).unwrap();
    let r = cosim(fabric, &prog).unwrap();
    (r.cycles, r.metrics.total_energy_pj())
}

fn main() {
    util::banner("E5", "sparsification / pruning / quantization (Sec. V.B)");
    let fabric = Fabric::build(
        FabricConfig::from_toml(&std::fs::read_to_string(
            archytas::repo_root().join("configs/edge16.toml"),
        ).unwrap()).unwrap(),
    )
    .unwrap();
    let g0 = workloads::mlp(8, 256, &[128, 64], 10, 0).unwrap();
    let ds = workloads::synthetic_dataset(16, 8, 256, 10, 5);

    println!(
        "{:<22} {:>9} {:>12} {:>12} {:>10}",
        "variant", "top-1 agr", "cycles", "energy nJ", "w-sparsity"
    );
    let (c0, e0) = cosim_cost(&g0, &fabric, Precision::F32);
    println!("{:<22} {:>9.2} {:>12} {:>12.1} {:>10.2}", "dense f32", 1.0, c0, e0 / 1e3, 0.0);

    // INT8 dynamic quantization.
    let mut gq = g0.clone();
    quantize::quantize_weights_int8(&mut gq);
    let (cq, eq) = cosim_cost(&gq, &fabric, Precision::Int8);
    println!(
        "{:<22} {:>9.2} {:>12} {:>12.1} {:>10.2}",
        "int8 dynamic-quant",
        agreement(&g0, &gq, &ds),
        cq,
        eq / 1e3,
        0.0
    );

    // Magnitude pruning sweep.
    for sp in [0.3f64, 0.5, 0.7, 0.9] {
        let mut gp = g0.clone();
        let rep = pruning::magnitude_prune(&mut gp, sp);
        let (cp, ep) = cosim_cost(&gp, &fabric, Precision::F32);
        println!(
            "{:<22} {:>9.2} {:>12} {:>12.1} {:>10.2}",
            format!("pruned {:.0}%", sp * 100.0),
            agreement(&g0, &gp, &ds),
            cp,
            ep / 1e3,
            rep.sparsity()
        );
    }

    // Structured block sparsity (the L1 blocksparse kernel's format).
    for dens in [0.5f64, 0.25] {
        let mut gs = g0.clone();
        let rep = sparsify::block_sparsify(&mut gs, 32, 32, dens);
        let (cs, es) = cosim_cost(&gs, &fabric, Precision::F32);
        // Sparse-capable CU: compute/fetch scale with block density.
        let cs_eff = (cs as f64 * rep.density).round() as u64;
        let es_eff = es * rep.density;
        println!(
            "{:<22} {:>9.2} {:>12} {:>12.1} {:>10.2}",
            format!("block-sparse d={dens}"),
            agreement(&g0, &gs, &ds),
            cs_eff,
            es_eff / 1e3,
            1.0 - rep.density
        );
    }

    // Compounding: prune + quantize.
    let mut gc = g0.clone();
    pruning::magnitude_prune(&mut gc, 0.5);
    quantize::quantize_weights_int8(&mut gc);
    let (cc, ec) = cosim_cost(&gc, &fabric, Precision::Int8);
    println!(
        "{:<22} {:>9.2} {:>12} {:>12.1} {:>10.2}",
        "prune50% + int8",
        agreement(&g0, &gc, &ds),
        cc,
        ec / 1e3,
        0.5
    );
    println!("\nexpected shape: int8 ~large energy cut at ~unchanged top-1; mild pruning");
    println!("free, heavy pruning degrades; block sparsity scales cost with density.");
}
