//! E1 — CU integration templates A/B/C (paper Fig. 1).
//!
//! The same GEMM workload on the same NPU accelerator behind each
//! template, plus an elementwise-heavy mix where template C's cluster
//! pays off. Reported per (template, layer size): end-to-end latency
//! (tile + NoC feed), energy, area — the quantitative version of the
//! figure's taxonomy.

#[path = "util.rs"]
mod util;

use archytas::accel::{Compute, DigitalNpu, Precision};
use archytas::config::FabricConfig;
use archytas::fabric::{Fabric, Template, Tile};

fn fabric() -> Fabric {
    Fabric::build(FabricConfig::from_toml("[noc]\nwidth = 2\nheight = 2\n[[cu]]\nkind = \"npu\"\ncount = 1\n").unwrap()).unwrap()
}

fn tile(template: Template) -> Tile {
    Tile::new(0, 1, Box::new(DigitalNpu::default()), template, 256 * 1024, 8)
}

fn main() {
    util::banner("E1", "Compute-Unit templates A/B/C (Fig. 1)");
    let f = fabric();
    println!(
        "{:<10} {:>10} | {:>12} {:>12} {:>10} {:>9}",
        "layer", "template", "latency cyc", "energy nJ", "noc bytes", "area mm²"
    );
    for (label, c) in [
        ("gemm-64", Compute::MatMul { m: 64, k: 64, n: 64 }),
        ("gemm-128", Compute::MatMul { m: 128, k: 128, n: 128 }),
        ("gemm-256", Compute::MatMul { m: 256, k: 256, n: 256 }),
        ("gemm-512", Compute::MatMul { m: 512, k: 512, n: 512 }),
        ("eltwise-1M", Compute::Elementwise { elems: 1 << 20 }),
    ] {
        for template in [Template::A, Template::B, Template::C] {
            let t = tile(template);
            let cost = t.execute(&c, Precision::Int8).unwrap();
            // End-to-end: feed the NoC share from HBM (template A pays
            // this per call; B/C amortize weights).
            let feed = f.feed(0, cost.noc_bytes);
            let e2e = cost.metrics.cycles + feed.cycles;
            println!(
                "{:<10} {:>10?} | {:>12} {:>12.1} {:>10} {:>9.2}",
                label,
                template,
                e2e,
                (cost.metrics.total_energy_pj() + feed.total_energy_pj()) / 1e3,
                cost.noc_bytes,
                t.area().mm2,
            );
        }
        println!();
    }
    // Where template C actually pays: accelerators WITHOUT a digital
    // vector path (analog crossbar/photonic tiles defer elementwise to a
    // slow periphery; the cluster absorbs it).
    println!("-- elementwise-1M on an analog crossbar tile: B vs C --");
    use archytas::accel::CrossbarNvm;
    for template in [Template::B, Template::C] {
        let t = Tile::new(0, 1, Box::new(CrossbarNvm::default()), template, 256 * 1024, 8);
        let c = Compute::Elementwise { elems: 1 << 20 };
        let cost = t.execute(&c, Precision::Analog).unwrap();
        println!(
            "  crossbar + {:?}: {:>9} cyc  {:>10.1} nJ",
            template,
            cost.metrics.cycles,
            cost.metrics.total_energy_pj() / 1e3
        );
    }
    println!("\nexpected shape: A lowest area but transfer-bound (streams weights every");
    println!("call); B best perf/W on weight-reuse GEMMs; C costs area and only pays");
    println!("off for accelerators without a digital vector path (analog tiles).");
}
