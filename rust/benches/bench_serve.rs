//! E-SERVE — sharded steady-state serving throughput.
//!
//! The serving tentpole: a `ShardedServer` replicates whole admission
//! sessions across N shards behind a deterministic seeded request
//! router and drives them with open-loop arrival streams. This bench
//! sweeps shards ∈ {1, 2, 4, 8} × arrival process ∈ {Poisson,
//! trace-driven with diurnal bursts} over one edge16 request stream and
//! reports, per row: admitted programs/sec (wall), simulated cycles/sec
//! (span over wall), and the p50/p99/p999 sojourn tail in fabric
//! cycles.
//!
//! Two goldens panic on divergence (the `tests/serve_golden.rs`
//! contract, re-checked here so CI's bench run pins it too):
//!
//! * **1-shard differential** — a 1-shard server fed the uniform trace
//!   `0, gap, 2·gap, …` must replay `CosimExecutor`'s open-loop
//!   admit/drain sequence request-for-request;
//! * **replay invariance** — every row's parallel fan-out must produce
//!   the byte-identical `ServeReport` of a sequential shard walk.
//!
//! The evidence bundle — rows, golden verdicts, stamp — lands in
//! `rust/BENCH_serve.json`, which CI cats next to
//! `BENCH_admission.json`.

#[path = "util.rs"]
mod util;

use archytas::accel::Precision;
use archytas::compiler::lowering::lower;
use archytas::compiler::mapper::{map_graph, MapStrategy};
use archytas::compiler::FabricProgram;
use archytas::coordinator::{CosimExecutor, ServeReport, ShardExec, ShardedServer};
use archytas::fabric::Fabric;
use archytas::sim::{ArrivalGen, ArrivalProcess, Cycle};
use archytas::testutil::bundled_fabric;
use archytas::workloads;

const SWEEP_SHARDS: [usize; 4] = [1, 2, 4, 8];
const ROUTER_SEED: u64 = 11;
const REQUESTS: usize = 48;

/// The per-request program every arrival admits (one mlp inference).
fn request_program(fabric: &Fabric) -> FabricProgram {
    let g = workloads::mlp(4, 64, &[32], 10, 7).unwrap();
    let m = map_graph(&g, fabric, MapStrategy::Greedy, Precision::Int8).unwrap();
    lower(&g, fabric, &m).unwrap()
}

/// Golden 1: the 1-shard server fed uniform pacing IS the open-loop
/// executor — same admit/drain sequence, same sojourn and makespan per
/// request (panic on any divergence).
fn one_shard_golden(fabric: &Fabric, prog: &FabricProgram) {
    let gap = 1_000;
    let k = 16;
    let mut gen = ArrivalGen::new(ArrivalProcess::Uniform { gap }, 0);
    let arrivals = gen.take_trace(k);
    let mut srv = ShardedServer::new(fabric, 1);
    let rep = srv.serve_trace(prog, &arrivals).unwrap();
    let mut exec = CosimExecutor::new(fabric, prog.clone(), gap);
    for (i, r) in rep.records.iter().enumerate() {
        let (makespan, sojourn) = exec.execute_batch_open_loop().unwrap();
        assert_eq!(r.sojourn, sojourn.unwrap(), "request {i}: sojourn diverged");
        assert_eq!(
            r.finished_at - r.admitted_at,
            makespan,
            "request {i}: makespan diverged"
        );
    }
    assert_eq!(rep.admitted, k, "uniform pacing must admit everything");
    println!("  golden match (1 shard ≡ CosimExecutor open loop): ok");
}

/// One sweep row: time the parallel fan-out over a fixed arrival trace,
/// then golden-check its merged report against a sequential shard walk
/// (panic on divergence — serving determinism leg 2/3). Returns wall
/// seconds and the (replay-checked) report.
fn serve_row(
    fabric: &Fabric,
    prog: &FabricProgram,
    arrivals: &[Cycle],
    shards: usize,
) -> (f64, ServeReport) {
    let iters = 3;
    let mut rep = None;
    let secs = util::time_avg(iters, || {
        let mut srv = ShardedServer::new(fabric, shards);
        srv.set_seed(ROUTER_SEED).unwrap();
        rep = Some(srv.serve_trace(prog, arrivals).unwrap());
    });
    let rep = rep.unwrap();
    let mut seq = ShardedServer::new(fabric, shards);
    seq.set_seed(ROUTER_SEED).unwrap();
    seq.set_shard_exec(ShardExec::Sequential);
    let seq_rep = seq.serve_trace(prog, arrivals).unwrap();
    assert!(
        rep == seq_rep,
        "shards={shards}: parallel fan-out diverged from the sequential shard walk"
    );
    (secs, rep)
}

/// Flattened row for the bundle.
struct RowOut {
    arrival: &'static str,
    shards: usize,
    secs: f64,
    speedup: f64,
    rep: ServeReport,
}

fn jf(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string() // JSON has no Infinity/NaN
    }
}

/// The archsim-style evidence bundle: per-row serving metrics + golden
/// verdicts + a stamp. Golden fields are literal `true` because every
/// row panics on divergence — reaching the write means they all held.
fn write_bundle(rows: &[RowOut]) {
    let stamp = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let row_json: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                concat!(
                    "    {{\"arrival\":\"{}\",\"shards\":{},\"requests\":{},",
                    "\"secs\":{},\"admitted\":{},\"shed\":{},\"degraded\":{},",
                    "\"admitted_per_sec\":{},\"sim_cycles_per_sec\":{},",
                    "\"p50_sojourn_cycles\":{},\"p99_sojourn_cycles\":{},",
                    "\"p999_sojourn_cycles\":{},\"speedup_vs_one_shard\":{}}}"
                ),
                r.arrival,
                r.shards,
                r.rep.records.len(),
                jf(r.secs),
                r.rep.admitted,
                r.rep.shed,
                r.rep.degraded,
                jf(r.rep.admitted as f64 / r.secs),
                jf(r.rep.span_cycles() as f64 / r.secs),
                jf(r.rep.p50_sojourn_cycles()),
                jf(r.rep.p99_sojourn_cycles()),
                jf(r.rep.p999_sojourn_cycles()),
                jf(r.speedup)
            )
        })
        .collect();
    let json = format!(
        concat!(
            "{{\n",
            "  \"schema\": \"archytas.bench_serve.v1\",\n",
            "  \"stamp\": {{\"unix_secs\":{},\"config\":\"edge16.toml\",",
            "\"requests_per_row\":{},\"router_seed\":{}}},\n",
            "  \"golden\": {{\"one_shard_bit_identical_to_cosim_executor\":true,",
            "\"parallel_sequential_replay\":true}},\n",
            "  \"rows\": [\n{}\n  ]\n",
            "}}\n"
        ),
        stamp,
        REQUESTS,
        ROUTER_SEED,
        row_json.join(",\n")
    );
    let path = archytas::repo_root().join("BENCH_serve.json");
    std::fs::write(&path, json).expect("writing BENCH_serve.json");
    println!("\nwrote {}", path.display());
}

fn main() {
    util::banner(
        "E-SERVE",
        "sharded steady-state serving: shards x arrival-process sweep (golden-checked)",
    );
    let fabric = bundled_fabric("edge16.toml");
    let prog = request_program(&fabric);

    println!("\n-- serving determinism goldens --");
    one_shard_golden(&fabric, &prog);

    // The two open-loop streams every shard count serves: memoryless
    // Poisson, and a recorded bursty trace under diurnal modulation.
    let poisson: Vec<Cycle> = ArrivalGen::new(ArrivalProcess::Poisson { mean_gap: 900 }, 42)
        .take(REQUESTS)
        .collect();
    let trace: Vec<Cycle> = ArrivalGen::new(
        ArrivalProcess::Trace { gaps: vec![0, 400, 2_500, 100, 1_200, 0, 800] },
        7,
    )
    .with_diurnal(60_000, 0.6)
    .take(REQUESTS)
    .collect();

    let mut rows = Vec::new();
    for (arrival, arrivals) in [("poisson", &poisson), ("trace_diurnal", &trace)] {
        println!(
            "\n-- open-loop serving: edge16.toml, {arrival}, {} requests --",
            arrivals.len()
        );
        let mut base_secs = f64::NAN;
        for shards in SWEEP_SHARDS {
            let (secs, rep) = serve_row(&fabric, &prog, arrivals, shards);
            if shards == 1 {
                base_secs = secs;
            }
            let speedup = base_secs / secs;
            println!(
                concat!(
                    "  shards={}:  {:>10}/stream  =  {:>8.0} admitted/sec,  ",
                    "{:>12.0} sim cycles/sec,  p50/p99/p999 sojourn = {:.0}/{:.0}/{:.0} cyc  ",
                    "({:.2}x 1 shard)"
                ),
                shards,
                util::fmt_time(secs),
                rep.admitted as f64 / secs,
                rep.span_cycles() as f64 / secs,
                rep.p50_sojourn_cycles(),
                rep.p99_sojourn_cycles(),
                rep.p999_sojourn_cycles(),
                speedup
            );
            rows.push(RowOut { arrival, shards, secs, speedup, rep });
        }
        println!("  golden match (parallel ≡ sequential shard walk, all counts): ok");
    }
    write_bundle(&rows);
    println!("\nexpected shape: replicated shards split the stream, so wall time per");
    println!("request drops roughly with the shard count while per-request sojourn");
    println!("falls too (each shard queues only its slice). Every row's parallel");
    println!("fan-out is bit-identical to a sequential walk of the same shards, and");
    println!("one shard replays the single-session open-loop executor exactly.");
}
