//! E3 — DRAM subsystem + PIM study (paper Sec. IV).
//!
//! Rows per (device, access mode): achieved bandwidth, energy, latency;
//! then the fetch-vs-PIM GEMV crossover and the compute-dense case where
//! PIM loses (the honest boundary of the technique).

#[path = "util.rs"]
mod util;

use archytas::dram::{DramKind, DramSim, DramTiming, PimCommand, Request};
use archytas::sim::Rng;

fn main() {
    util::banner("E3", "DRAM/PIM subsystem (JEDEC bank FSM + FR-FCFS)");
    println!(
        "{:<10} {:<8} {:>12} {:>10} {:>12} {:>10}",
        "device", "mode", "cycles", "GB/s", "energy nJ", "row-hit %"
    );
    for kind in [DramKind::Ddr4_2400, DramKind::Lpddr4_3200, DramKind::Hbm2] {
        let t = DramTiming::new(kind);
        for mode in ["stream", "random"] {
            let mut sim = DramSim::new(t);
            match mode {
                "stream" => {
                    for i in 0..2048 {
                        sim.enqueue(Request::read((i * t.row_bytes) as u64, t.row_bytes));
                    }
                }
                _ => {
                    let mut rng = Rng::new(3);
                    for _ in 0..2048 {
                        sim.enqueue(Request::read(
                            (rng.below(1 << 26)) as u64 & !63,
                            t.burst_bytes,
                        ));
                    }
                }
            }
            let st = sim.run_to_drain();
            println!(
                "{:<10} {:<8} {:>12} {:>10.2} {:>12.0} {:>10.1}",
                format!("{kind:?}"),
                mode,
                st.cycles,
                st.bandwidth_gbs(&t),
                st.metrics.total_energy_pj() / 1e3,
                st.row_hit_rate() * 100.0
            );
        }
    }

    println!("\n-- GEMV offload: fetch-to-core vs in-bank PIM (DDR4) --");
    let t = DramTiming::new(DramKind::Ddr4_2400);
    println!(
        "{:>8} {:>12} {:>12} {:>9} {:>9}",
        "MiB", "fetch cyc", "pim cyc", "speedup", "E saving"
    );
    for mb in [1usize, 4, 16, 64] {
        let bytes = mb << 20;
        let mut fetch = DramSim::new(t);
        for i in 0..(bytes / t.row_bytes) {
            fetch.enqueue(Request::read((i * t.row_bytes) as u64, t.row_bytes));
        }
        let fs = fetch.run_to_drain();
        let mut pim = DramSim::new(t);
        let macs = (bytes / 4) as u64 / t.banks as u64;
        for b in 0..t.banks {
            pim.enqueue(Request::pim((b * t.row_bytes) as u64, PimCommand::BankMac { macs }));
        }
        let ps = pim.run_to_drain();
        println!(
            "{:>8} {:>12} {:>12} {:>8.1}x {:>8.1}x",
            mb,
            fs.cycles,
            ps.cycles,
            fs.cycles as f64 / ps.cycles as f64,
            fs.metrics.total_energy_pj() / ps.metrics.total_energy_pj()
        );
    }

    println!("\n-- compute-dense boundary: GEMM with high reuse (PIM loses) --");
    // A 256x256x256 GEMM reuses every fetched byte 256 times: fetch cost
    // amortizes, while PIM still pays per-MAC bank occupancy.
    let macs: u64 = 256 * 256 * 256;
    let bytes_once: usize = 2 * 256 * 256 * 4;
    let mut fetch = DramSim::new(t);
    for i in 0..(bytes_once / t.row_bytes) {
        fetch.enqueue(Request::read((i * t.row_bytes) as u64, t.row_bytes));
    }
    let fs = fetch.run_to_drain();
    // NPU-side compute time at 128x128 MACs/cycle:
    let npu_cycles = macs / (128 * 128);
    let fetch_total = fs.cycles.max(npu_cycles);
    let mut pim = DramSim::new(t);
    let per_bank = macs / t.banks as u64;
    for b in 0..t.banks {
        pim.enqueue(Request::pim((b * t.row_bytes) as u64, PimCommand::BankMac { macs: per_bank }));
    }
    let ps = pim.run_to_drain();
    println!(
        "fetch+NPU: {} cyc   PIM-only: {} cyc   -> PIM {:.1}x SLOWER on compute-dense GEMM",
        fetch_total,
        ps.cycles,
        ps.cycles as f64 / fetch_total as f64
    );
    println!("\nexpected shape: PIM >=5x energy and >=2x latency on memory-bound GEMV;");
    println!("loses on compute-dense GEMM; random access far below streaming bandwidth.");
}
