//! Differential golden tests for the event-driven coordinator co-sim.
//!
//! `archytas::coordinator::refexec::cosim_ref` is the pre-rewrite
//! one-pass list scheduler kept verbatim; `archytas::coordinator::cosim`
//! is the event-driven engine on the shared simulation calendar. These
//! tests lower identical workloads across map strategies and both bundled
//! fabric configs and require **bit-identical** [`ExecReport`]s —
//! makespan, per-tile busy cycles, per-step completion times, transfer
//! cycles and energy bit patterns. The rewrite must change the engine's
//! complexity and memory shape, never its answers.

use archytas::accel::Precision;
use archytas::compiler::lowering::lower;
use archytas::compiler::mapper::{map_graph, MapStrategy};
use archytas::config::FabricConfig;
use archytas::coordinator::{cosim, cosim_ref, ExecReport};
use archytas::fabric::Fabric;
use archytas::ir::Graph;
use archytas::testutil::{bundled_fabric, prop};
use archytas::workloads;

/// Per-field asserts first (granular failure messages on divergence),
/// then the library's [`ExecReport::bit_identical`] golden contract so
/// fields added to the report later stay covered here automatically.
fn assert_reports_identical(a: &ExecReport, b: &ExecReport, tag: &str) {
    assert_eq!(a.cycles, b.cycles, "{tag}: makespan");
    assert_eq!(a.step_done, b.step_done, "{tag}: step_done");
    assert_eq!(a.tile_busy, b.tile_busy, "{tag}: tile_busy");
    assert_eq!(a.transfer_cycles, b.transfer_cycles, "{tag}: transfer_cycles");
    assert_eq!(a.exec_steps, b.exec_steps, "{tag}: exec_steps");
    // Energy bit patterns: total and every per-category accumulator.
    assert_eq!(
        a.metrics.total_energy_pj().to_bits(),
        b.metrics.total_energy_pj().to_bits(),
        "{tag}: total energy {} vs {}",
        a.metrics.total_energy_pj(),
        b.metrics.total_energy_pj()
    );
    let (ba, bb) = (a.metrics.breakdown(), b.metrics.breakdown());
    assert_eq!(ba.len(), bb.len(), "{tag}: breakdown categories");
    for ((ca, ea), (cb, eb)) in ba.iter().zip(&bb) {
        assert_eq!(ca, cb, "{tag}: breakdown order");
        assert_eq!(ea.to_bits(), eb.to_bits(), "{tag}: {ca} energy {ea} vs {eb}");
    }
    assert_eq!(a.metrics, b.metrics, "{tag}: metrics struct");
    assert_eq!(a.metrics.ops, b.metrics.ops, "{tag}: ops");
    assert_eq!(a.metrics.bytes_moved, b.metrics.bytes_moved, "{tag}: bytes");
    assert!(a.bit_identical(b), "{tag}: bit_identical contract");
}

fn differential(fabric: &Fabric, g: &Graph, strategy: MapStrategy, p: Precision, tag: &str) {
    let m = map_graph(g, fabric, strategy, p).unwrap();
    let prog = lower(g, fabric, &m).unwrap();
    let ev = cosim(fabric, &prog).unwrap();
    let re = cosim_ref(fabric, &prog).unwrap();
    assert!(ev.cycles > 0, "{tag}: trivial program");
    assert_reports_identical(&ev, &re, tag);
}

/// The acceptance matrix: ≥2 workloads × ≥2 map strategies × both bundled
/// fabric configs, all bit-identical between the engines.
#[test]
fn golden_matrix_workloads_strategies_configs() {
    let workloads: Vec<(&str, Graph)> = vec![
        ("mlp", workloads::mlp(8, 256, &[128, 64], 10, 0).unwrap()),
        ("vit", workloads::vit(&workloads::VitParams::default(), 0).unwrap()),
    ];
    for cfg in ["edge16.toml", "homogeneous_npu.toml"] {
        let fabric = bundled_fabric(cfg);
        for (wname, g) in &workloads {
            for strategy in [MapStrategy::RoundRobin, MapStrategy::Greedy] {
                let tag = format!("{cfg}/{wname}/{strategy:?}");
                differential(&fabric, g, strategy, Precision::Int8, &tag);
            }
        }
    }
}

/// The ILP mapper produces different (often denser) step graphs — cover
/// it on the heterogeneous config.
#[test]
fn golden_ilp_strategy() {
    let fabric = bundled_fabric("edge16.toml");
    let g = workloads::mlp(4, 64, &[32], 10, 7).unwrap();
    differential(&fabric, &g, MapStrategy::Ilp, Precision::Int8, "edge16/mlp/Ilp");
}

/// F32 exercises different accelerator cost paths (and template-A weight
/// streaming on the crossbar tiles).
#[test]
fn golden_f32_precision() {
    let fabric = bundled_fabric("edge16.toml");
    let g = workloads::vit(&workloads::VitParams::default(), 1).unwrap();
    differential(&fabric, &g, MapStrategy::Greedy, Precision::F32, "edge16/vit/f32");
}

/// Property-style sweep: random MLP shapes on a small inline fabric must
/// also match bit-for-bit (guards resource shapes the bundled configs
/// don't hit: tiny programs, single-hidden-layer chains, reused links).
#[test]
fn golden_random_mlps() {
    let fabric = Fabric::build(
        FabricConfig::from_toml(
            "[noc]\nwidth = 3\nheight = 3\n\
             [[cu]]\nkind = \"npu\"\ntemplate = \"B\"\ncount = 4\n\
             [[cu]]\nkind = \"cpu\"\ntemplate = \"C\"\ncount = 2\ncluster_cores = 4\n",
        )
        .unwrap(),
    )
    .unwrap();
    prop::check(12, |rng| {
        let batch = (rng.below(4) + 1) * 2;
        let inputs = (rng.below(4) + 1) * 16;
        let hidden = (rng.below(3) + 1) * 16;
        let layers: Vec<usize> =
            (0..rng.below(2) + 1).map(|_| hidden).collect();
        let g = workloads::mlp(batch, inputs, &layers, 8, rng.next_u64()).unwrap();
        let strategy = if rng.chance(0.5) { MapStrategy::Greedy } else { MapStrategy::RoundRobin };
        let m = map_graph(&g, &fabric, strategy, Precision::Int8)
            .map_err(|e| e.to_string())?;
        let prog = lower(&g, &fabric, &m).map_err(|e| e.to_string())?;
        let ev = cosim(&fabric, &prog).map_err(|e| e.to_string())?;
        let re = cosim_ref(&fabric, &prog).map_err(|e| e.to_string())?;
        if ev.cycles != re.cycles {
            return Err(format!("makespan {} vs {}", ev.cycles, re.cycles));
        }
        if !ev.bit_identical(&re) {
            return Err("reports not bit-identical".into());
        }
        Ok(())
    });
}
