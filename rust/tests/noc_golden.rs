//! Differential golden tests for the NoC event-wheel rewrite.
//!
//! `archytas::noc::refsim::RefNocSim` is the pre-rewrite simulator kept
//! verbatim (nested `VecDeque` buffers, per-cycle `Vec` draining, linear
//! neighbor scans). These tests drive it and the flat event-wheel
//! `NocSim` with identical seeded workloads and require **bit-identical**
//! reports and per-packet timelines — the refactor must change the clock
//! speed of the simulator, never its answers.

use archytas::noc::refsim::RefNocSim;
use archytas::noc::{traffic, NocParams, NocSim, SimReport, Topology};
use archytas::sim::{Cycle, Rng};

fn assert_reports_identical(a: &SimReport, b: &SimReport, tag: &str) {
    assert_eq!(a.cycles, b.cycles, "{tag}: cycles");
    assert_eq!(a.delivered, b.delivered, "{tag}: delivered");
    assert_eq!(a.in_flight, b.in_flight, "{tag}: in_flight");
    assert_eq!(
        a.avg_latency.to_bits(),
        b.avg_latency.to_bits(),
        "{tag}: avg_latency {} vs {}",
        a.avg_latency,
        b.avg_latency
    );
    assert_eq!(
        a.p99_latency.to_bits(),
        b.p99_latency.to_bits(),
        "{tag}: p99_latency {} vs {}",
        a.p99_latency,
        b.p99_latency
    );
    assert_eq!(a.flit_hops, b.flit_hops, "{tag}: flit_hops");
    assert_eq!(
        a.throughput.to_bits(),
        b.throughput.to_bits(),
        "{tag}: throughput {} vs {}",
        a.throughput,
        b.throughput
    );
    assert_eq!(a.metrics, b.metrics, "{tag}: metrics");
}

fn assert_packets_identical(sim: &NocSim, refsim: &RefNocSim, tag: &str) {
    assert_eq!(sim.packets().len(), refsim.packets().len(), "{tag}: packet count");
    for (i, (p, r)) in sim.packets().iter().zip(refsim.packets()).enumerate() {
        assert_eq!(
            (p.src, p.dst, p.flits, p.injected_at, p.ejected_at, p.hops),
            (r.src, r.dst, r.flits, r.injected_at, r.ejected_at, r.hops),
            "{tag}: packet {i}"
        );
    }
}

/// Burst workload: everything injected at cycle 0.
fn burst_case(topo: &Topology, params: NocParams, seed: u64, packets: usize, tag: &str) {
    let n = topo.nodes();
    let mut sim = NocSim::new(topo.clone(), params);
    let mut rsim = RefNocSim::new(topo.clone(), params);
    let mut rng = Rng::new(seed);
    for _ in 0..packets {
        let s = rng.below(n);
        let mut d = rng.below(n);
        while d == s {
            d = rng.below(n);
        }
        let bytes = 1 + rng.below(200);
        sim.inject(s, d, bytes);
        rsim.inject(s, d, bytes);
    }
    let a = sim.run_to_drain(1_000_000);
    let b = rsim.run_to_drain(1_000_000);
    assert_eq!(a.delivered, packets, "{tag}: all delivered");
    assert_reports_identical(&a, &b, tag);
    assert_packets_identical(&sim, &rsim, tag);
    assert_eq!(sim.drained(), rsim.drained(), "{tag}: drained");
}

/// Open-loop workload: seeded pattern traffic over time.
fn openloop_case(
    topo: &Topology,
    params: NocParams,
    pattern: traffic::Pattern,
    rate: f64,
    cycles: Cycle,
    seed: u64,
    tag: &str,
) {
    let n = topo.nodes();
    let mut rng = Rng::new(seed);
    let schedule = traffic::generate(pattern, n, rate, 64, cycles, &mut rng);
    let mut sim = NocSim::new(topo.clone(), params);
    let mut rsim = RefNocSim::new(topo.clone(), params);
    let a = traffic::drive(&mut sim, schedule.clone(), 2_000_000);
    let b = archytas::noc::refsim::drive(&mut rsim, schedule, 2_000_000);
    assert_reports_identical(&a, &b, tag);
    assert_packets_identical(&sim, &rsim, tag);
}

#[test]
fn golden_mesh_burst_matches_reference() {
    let topo = Topology::mesh(4, 4).unwrap();
    for seed in [1, 7, 99] {
        burst_case(&topo, NocParams::default(), seed, 250, &format!("mesh4x4 seed {seed}"));
    }
}

#[test]
fn golden_torus_burst_matches_reference() {
    let topo = Topology::torus(4, 4).unwrap();
    for seed in [3, 11] {
        burst_case(&topo, NocParams::default(), seed, 250, &format!("torus4x4 seed {seed}"));
    }
}

#[test]
fn golden_irregular_topologies_match_reference() {
    burst_case(&Topology::fattree(3).unwrap(), NocParams::default(), 5, 120, "fattree3");
    burst_case(&Topology::ring(8).unwrap(), NocParams::default(), 6, 100, "ring8");
    burst_case(&Topology::star(9).unwrap(), NocParams::default(), 8, 100, "star9");
}

#[test]
fn golden_mesh_openloop_uniform_matches_reference() {
    let topo = Topology::mesh(8, 8).unwrap();
    openloop_case(
        &topo,
        NocParams::default(),
        traffic::Pattern::Uniform,
        0.08,
        400,
        42,
        "mesh8x8 uniform",
    );
}

#[test]
fn golden_torus_openloop_hotspot_matches_reference() {
    let topo = Topology::torus(4, 4).unwrap();
    openloop_case(
        &topo,
        NocParams::default(),
        traffic::Pattern::Hotspot { hot_permille: 300 },
        0.15,
        500,
        17,
        "torus4x4 hotspot",
    );
}

#[test]
fn golden_nondefault_params_match_reference() {
    // Single VC, shallow buffers, 1-cycle routers: stresses wormhole
    // blocking, credit starvation and the wheel's same-slot drain path.
    let params = NocParams { vcs: 1, buf_flits: 2, router_latency: 1, ..NocParams::default() };
    burst_case(&Topology::mesh(4, 4).unwrap(), params, 23, 150, "mesh4x4 tight");
    let params = NocParams { vcs: 3, buf_flits: 8, router_latency: 5, ..NocParams::default() };
    burst_case(&Topology::torus(4, 4).unwrap(), params, 29, 150, "torus4x4 wide");
}

/// Threads sweep: shard-parallel stepping must reproduce the reference
/// simulator bit-for-bit at every thread count — the determinism
/// contract of the parallel rewrite (noc/sim.rs module docs).
#[test]
fn golden_threads_sweep_burst_matches_reference() {
    let topo = Topology::mesh(8, 8).unwrap();
    let n = topo.nodes();
    let mut workload = Vec::new();
    let mut rng = Rng::new(4242);
    for _ in 0..400 {
        let s = rng.below(n);
        let mut d = rng.below(n);
        while d == s {
            d = rng.below(n);
        }
        workload.push((s, d, 1 + rng.below(200)));
    }
    let mut rsim = RefNocSim::new(topo.clone(), NocParams::default());
    for &(s, d, b) in &workload {
        rsim.inject(s, d, b);
    }
    let golden = rsim.run_to_drain(1_000_000);
    for threads in [1usize, 2, 4, 8] {
        let params = NocParams { threads, ..NocParams::default() };
        let mut sim = NocSim::new(topo.clone(), params);
        for &(s, d, b) in &workload {
            sim.inject(s, d, b);
        }
        let rep = sim.run_to_drain(1_000_000);
        assert_reports_identical(&rep, &golden, &format!("mesh8x8 threads={threads}"));
        assert_packets_identical(&sim, &rsim, &format!("mesh8x8 threads={threads}"));
    }
}

/// Same sweep over open-loop traffic and non-default microarchitecture
/// parameters (single VC + 1-cycle routers stresses the same-slot wheel
/// paths under sharding).
#[test]
fn golden_threads_sweep_openloop_and_tight_params() {
    let topo = Topology::torus(6, 6).unwrap();
    let n = topo.nodes();
    let mut rng = Rng::new(77);
    let schedule = traffic::generate(traffic::Pattern::Uniform, n, 0.10, 64, 300, &mut rng);
    let mut rsim = RefNocSim::new(topo.clone(), NocParams::default());
    let golden = archytas::noc::refsim::drive(&mut rsim, schedule.clone(), 2_000_000);
    for threads in [2usize, 4, 8] {
        let params = NocParams { threads, ..NocParams::default() };
        let mut sim = NocSim::new(topo.clone(), params);
        let rep = traffic::drive(&mut sim, schedule.clone(), 2_000_000);
        assert_reports_identical(&rep, &golden, &format!("torus6x6 threads={threads}"));
        assert_packets_identical(&sim, &rsim, &format!("torus6x6 threads={threads}"));
    }

    let tight = NocParams { vcs: 1, buf_flits: 2, router_latency: 1, ..NocParams::default() };
    let mesh = Topology::mesh(5, 5).unwrap();
    let mut rsim = RefNocSim::new(mesh.clone(), tight);
    let mut rng = Rng::new(23);
    let mut workload = Vec::new();
    for _ in 0..150 {
        let s = rng.below(25);
        let mut d = rng.below(25);
        while d == s {
            d = rng.below(25);
        }
        workload.push((s, d, 1 + rng.below(160)));
    }
    for &(s, d, b) in &workload {
        rsim.inject(s, d, b);
    }
    let golden = rsim.run_to_drain(1_000_000);
    for threads in [2usize, 4, 8] {
        let params = NocParams { threads, ..tight };
        let mut sim = NocSim::new(mesh.clone(), params);
        for &(s, d, b) in &workload {
            sim.inject(s, d, b);
        }
        let rep = sim.run_to_drain(1_000_000);
        assert_reports_identical(&rep, &golden, &format!("mesh5x5 tight threads={threads}"));
        assert_packets_identical(&sim, &rsim, &format!("mesh5x5 tight threads={threads}"));
    }
}

#[test]
fn golden_incremental_stepping_matches_reference() {
    // run_for + late injections exercise mid-flight state equivalence,
    // not just end-of-drain equivalence.
    let topo = Topology::mesh(4, 4).unwrap();
    let mut sim = NocSim::new(topo.clone(), NocParams::default());
    let mut rsim = RefNocSim::new(topo, NocParams::default());
    let mut rng = Rng::new(13);
    for round in 0..5 {
        for _ in 0..30 {
            let s = rng.below(16);
            let mut d = rng.below(16);
            while d == s {
                d = rng.below(16);
            }
            let bytes = 16 + rng.below(120);
            sim.inject(s, d, bytes);
            rsim.inject(s, d, bytes);
        }
        sim.run_for(50);
        rsim.run_for(50);
        let a = sim.report();
        let b = rsim.report();
        assert_reports_identical(&a, &b, &format!("round {round}"));
    }
    let a = sim.run_to_drain(1_000_000);
    let b = rsim.run_to_drain(1_000_000);
    assert_reports_identical(&a, &b, "final drain");
    assert_packets_identical(&sim, &rsim, "final drain");
}
