//! Differential golden tests for the sharded steady-state serving layer
//! (`coordinator::shard`), pinning the serving determinism contract:
//!
//! * a 1-shard [`ShardedServer`] fed the uniform arrival trace is
//!   bit-identical to the closed-loop [`CosimExecutor`] /
//!   [`BatchServer::run_cosim`] path — every `ExecReport`, every
//!   `ProgramSpan`, the energy bits, and the cost-model `Arc` identity;
//! * a 1-shard degraded server fed a [`DegradedExecutor::admissions`]
//!   trace replays `run_degraded` outcome-for-outcome;
//! * N ∈ {2, 4, 8} shard runs are replay-invariant: same seed/config ⇒
//!   identical merged [`ServeReport`] and identical per-shard
//!   `ExecReport`s across shard execution order and thread count;
//! * long-run serving under pruning holds its memory footprint bounded
//!   over ≥ 10× the pruning horizon (the steady-state regression).

use std::sync::Arc;
use std::sync::mpsc;
use std::time::Instant;

use archytas::accel::Precision;
use archytas::compiler::lowering::lower;
use archytas::compiler::mapper::{map_graph, MapStrategy};
use archytas::compiler::{FabricProgram, Step};
use archytas::coordinator::{
    BatchServer, CosimExecutor, DegradedExecutor, ExecReport, FaultySession, RecoveryPolicy,
    ServeRequest, ShardExec, ShardedServer,
};
use archytas::fabric::{CongestionKnobs, CostModel, Fabric, VaryingCost};
use archytas::prop_assert;
use archytas::runtime::Tensor;
use archytas::sim::{
    ArrivalGen, ArrivalProcess, Cycle, FaultConfig, FaultEvent, FaultKind, FaultPlan,
};
use archytas::testutil::{bundled_fabric, prop};
use archytas::workloads;

const CONFIGS: [&str; 2] = ["edge16.toml", "homogeneous_npu.toml"];

fn lowered(fabric: &Fabric, strategy: MapStrategy) -> FabricProgram {
    let g = workloads::mlp(4, 64, &[32], 10, 7).unwrap();
    let m = map_graph(&g, fabric, strategy, Precision::Int8).unwrap();
    lower(&g, fabric, &m).unwrap()
}

fn assert_reports_identical(a: &ExecReport, b: &ExecReport, tag: &str) {
    assert_eq!(a.cycles, b.cycles, "{tag}: makespan");
    assert_eq!(a.step_done, b.step_done, "{tag}: step_done");
    assert_eq!(a.tile_busy, b.tile_busy, "{tag}: tile_busy");
    assert_eq!(a.transfer_cycles, b.transfer_cycles, "{tag}: transfer_cycles");
    assert_eq!(a.exec_steps, b.exec_steps, "{tag}: exec_steps");
    assert_eq!(
        a.metrics.total_energy_pj().to_bits(),
        b.metrics.total_energy_pj().to_bits(),
        "{tag}: energy bits"
    );
    assert!(a.bit_identical(b), "{tag}: bit_identical contract");
}

/// N=1 differential, half 1: a 1-shard server on the uniform trace
/// `0, gap, 2·gap, …` performs the exact admit/drain sequence of the
/// closed-loop [`CosimExecutor`] — per-request sojourns and makespans,
/// and the final session report, bit for bit — on both bundled configs.
#[test]
fn one_shard_uniform_trace_is_bit_identical_to_cosim_executor() {
    for cfg in CONFIGS {
        let fabric = bundled_fabric(cfg);
        let prog = lowered(&fabric, MapStrategy::Greedy);
        let gap: Cycle = 1_000;
        let k = 8;

        let mut srv = ShardedServer::new(&fabric, 1);
        let arrivals: Vec<Cycle> = (0..k).map(|i| i as Cycle * gap).collect();
        let rep = srv.serve_trace(&prog, &arrivals).unwrap();

        let mut exec = CosimExecutor::new(&fabric, prog, gap);
        for (i, r) in rep.records.iter().enumerate() {
            let (makespan, sojourn) = exec.execute_batch_open_loop().unwrap();
            assert_eq!(r.sojourn, sojourn.unwrap(), "{cfg}: request {i} sojourn");
            assert_eq!(r.finished_at - r.admitted_at, makespan, "{cfg}: request {i} makespan");
            assert_eq!(r.admitted_at, r.arrival, "{cfg}: plain shard admits at arrival");
        }
        assert_eq!(rep.admitted, k);
        assert_eq!((rep.shed, rep.degraded, rep.fault_shed), (0, 0, 0));
        let got = srv.shard_report(0).unwrap();
        let want = exec.session_mut().report().unwrap();
        assert_reports_identical(&got, &want, &format!("{cfg}/one-shard-uniform"));
    }
}

/// N=1 differential, half 2: the full [`BatchServer::run_cosim`] serving
/// loop (one request per batch, so one admission per formed batch) and
/// the 1-shard server report the same simulated series — and with an
/// explicit cost model, both stacks hold the same `Arc` (pinned
/// identity, not just equal pricing).
#[test]
fn one_shard_matches_batch_server_run_cosim_and_shares_the_model_arc() {
    let fabric = bundled_fabric("edge16.toml");
    let prog = lowered(&fabric, MapStrategy::Greedy);
    let gap: Cycle = 1_000;
    let k = 6;
    let model: Arc<dyn CostModel> =
        Arc::new(VaryingCost::congestion(512, CongestionKnobs { alpha: 0.5, cap: 4.0 }));

    let mut srv = ShardedServer::with_model(&fabric, 1, model.clone());
    assert!(
        Arc::ptr_eq(srv.shard_cost_model(0), &model),
        "the shard must hold the caller's model Arc, not a rebuild"
    );
    let arrivals: Vec<Cycle> = (0..k).map(|i| i as Cycle * gap).collect();
    let rep = srv.serve_trace(&prog, &arrivals).unwrap();

    let mut exec = CosimExecutor::with_model(&fabric, prog, gap, model.clone());
    assert!(Arc::ptr_eq(exec.cost_model(), &model));
    let (tx, rx) = mpsc::channel::<ServeRequest>();
    let mut replies = Vec::new();
    for i in 0..k {
        let (rtx, rrx) = mpsc::channel();
        tx.send(ServeRequest {
            sample: vec![i as f32, 0.0],
            reply: rtx,
            submitted: Instant::now(),
        })
        .unwrap();
        replies.push(rrx);
    }
    drop(tx);
    // max_batch = 1: every request forms its own batch, so the server
    // admits exactly one program per request — the sharded trace shape.
    let server = BatchServer::new(2, 1, 1);
    let stats = server
        .run_cosim(
            rx,
            |input| {
                let b = input.dims()[0];
                Tensor::new(vec![b, 1], (0..b).map(|i| input.data()[i * 2]).collect())
            },
            &mut exec,
        )
        .unwrap();
    for r in replies {
        r.recv().unwrap();
    }
    assert_eq!(stats.batches, k);
    let sojourns: Vec<Cycle> = rep.records.iter().map(|r| r.sojourn).collect();
    assert_eq!(sojourns, stats.sim_sojourn_cycles, "sojourn series");
    let makespans: Vec<Cycle> =
        rep.records.iter().map(|r| r.finished_at - r.admitted_at).collect();
    assert_eq!(makespans, stats.sim_cycles, "makespan series");
    let got = srv.shard_report(0).unwrap();
    let want = exec.session_mut().report().unwrap();
    assert_reports_identical(&got, &want, "one-shard vs run_cosim");
}

/// N=1 degraded differential: feeding a 1-shard degraded server the
/// *recorded admission trace* of a closed-loop [`DegradedExecutor`]
/// episode (which makes every fault-floor bump a no-op) replays it
/// outcome-for-outcome, span-for-span, report-for-report.
#[test]
fn one_shard_degraded_replays_run_degraded_from_the_admission_trace() {
    let fabric = bundled_fabric("edge16.toml");
    let prog = lowered(&fabric, MapStrategy::Greedy);
    // Kill the tile running the program's final layer mid-episode, with
    // a gap far below the death cycle so fault-floor bumps actually
    // happen in the closed-loop run.
    let victim = prog
        .steps
        .iter()
        .rev()
        .find_map(|s| match s {
            Step::Exec { tile, .. } => Some(*tile),
            _ => None,
        })
        .unwrap();
    let plan = FaultPlan::from_events(vec![FaultEvent {
        at: 50,
        kind: FaultKind::TileDeath { tile: victim },
    }]);
    let cfg = FaultConfig::default();
    let session =
        FaultySession::with_plan(&fabric, plan.clone(), &cfg, RecoveryPolicy::Retry).unwrap();
    let mut exec = DegradedExecutor::with_session(session, prog.clone(), 10);
    let mut rows = Vec::new();
    for _ in 0..6 {
        rows.push(exec.execute_batch_open_loop().unwrap());
    }
    let admissions = exec.admissions().to_vec();
    assert!(
        admissions.windows(2).all(|w| w[0] <= w[1]),
        "recorded admissions must be a valid arrival trace"
    );

    let mut srv =
        ShardedServer::degraded_with_plan(&fabric, 1, &plan, &cfg, RecoveryPolicy::Retry).unwrap();
    let rep = srv.serve_trace(&prog, &admissions).unwrap();
    let outcomes = exec.outcomes();
    for (i, r) in rep.records.iter().enumerate() {
        assert_eq!(r.arrival, admissions[i], "request {i} arrival");
        assert_eq!(r.admitted_at, admissions[i], "request {i}: bump must be a no-op");
        assert_eq!(r.finished_at - r.admitted_at, rows[i].0, "request {i} makespan");
        assert_eq!(r.outcome, Some(outcomes[i]), "request {i} recovery outcome");
    }
    assert_eq!(rep.fault_shed, 0, "retry policy never sheds here");
    let got = srv.shard_report(0).unwrap();
    let want = exec.session_mut().report().unwrap();
    assert_reports_identical(&got, &want, "one-shard degraded vs run_degraded");
}

/// One serving episode at the given shard execution order / thread
/// count, from identical seed and arrivals.
fn episode(
    fabric: &Fabric,
    prog: &FabricProgram,
    nshards: usize,
    arrivals: &[Cycle],
    exec: ShardExec,
    threads: usize,
) -> (archytas::coordinator::ServeReport, Vec<ExecReport>) {
    let mut srv = ShardedServer::new(fabric, nshards);
    srv.set_seed(5).unwrap();
    srv.set_shard_exec(exec);
    srv.set_threads(threads);
    let rep = srv.serve_trace(prog, arrivals).unwrap();
    let shards = srv.shard_reports().unwrap();
    (rep, shards)
}

/// The tentpole golden: N ∈ {2, 4, 8} shard runs from the same seed and
/// arrival trace are replay-invariant — identical merged report (all
/// integer fields, so `==` is bitwise) and bit-identical per-shard
/// `ExecReport`s — whether shards run sequentially, in reverse, or on
/// the worker pool, at 1 or 2 internal session threads.
#[test]
fn multi_shard_replay_is_invariant_across_exec_order_and_threads() {
    let fabric = bundled_fabric("edge16.toml");
    let prog = lowered(&fabric, MapStrategy::Greedy);
    let mut gen = ArrivalGen::new(ArrivalProcess::Poisson { mean_gap: 900 }, 42)
        .with_diurnal(50_000, 0.6);
    let arrivals = gen.take_trace(24);
    for nshards in [2usize, 4, 8] {
        let (want, want_shards) =
            episode(&fabric, &prog, nshards, &arrivals, ShardExec::Sequential, 1);
        assert_eq!(want.records.len(), 24);
        assert_eq!(want.admitted, 24);
        for (exec, threads) in [
            (ShardExec::Sequential, 1), // run-twice determinism
            (ShardExec::SequentialReversed, 1),
            (ShardExec::Parallel, 1),
            (ShardExec::Parallel, 2),
        ] {
            let tag = format!("shards={nshards}/{exec:?}/threads={threads}");
            let (got, got_shards) = episode(&fabric, &prog, nshards, &arrivals, exec, threads);
            assert_eq!(got, want, "{tag}: merged ServeReport");
            assert_eq!(got_shards.len(), want_shards.len());
            for (s, (a, b)) in got_shards.iter().zip(&want_shards).enumerate() {
                assert_reports_identical(a, b, &format!("{tag}: shard {s}"));
            }
        }
    }
}

/// Property: any (seed, shard count) pair replays — parallel execution
/// reproduces the sequential merged report exactly, and every request
/// routes inside the shard range.
#[test]
fn prop_random_seeds_replay_across_parallel_execution() {
    let fabric = bundled_fabric("edge16.toml");
    let prog = lowered(&fabric, MapStrategy::Greedy);
    prop::check(6, |rng| {
        let seed = rng.next_u64();
        let nshards = 2 + rng.below(7);
        let mut gen = ArrivalGen::new(ArrivalProcess::Poisson { mean_gap: 1_100 }, seed);
        let arrivals = gen.take_trace(10);
        let mut run = |exec: ShardExec| {
            let mut srv = ShardedServer::new(&fabric, nshards);
            srv.set_seed(seed).unwrap();
            srv.set_shard_exec(exec);
            srv.serve_trace(&prog, &arrivals)
        };
        let seq = run(ShardExec::Sequential).map_err(|e| e.to_string())?;
        let par = run(ShardExec::Parallel).map_err(|e| e.to_string())?;
        prop_assert!(seq == par, "seed {seed} x {nshards} shards diverged");
        prop_assert!(
            seq.records.iter().all(|r| r.shard < nshards),
            "routing escaped the shard range"
        );
        Ok(())
    });
}

/// The `[serve]` config path builds the same server the explicit API
/// does: `from_config` + `arrival_gen_from_config` serve the same report
/// as a hand-assembled twin.
#[test]
fn from_config_matches_the_hand_built_server() {
    use archytas::config::FabricConfig;
    use archytas::coordinator::{arrival_gen_from_config, OverloadPolicy};
    let fabric = Fabric::build(
        FabricConfig::from_toml(
            "[noc]\nwidth = 3\nheight = 3\n\
             [[cu]]\nkind = \"npu\"\ntemplate = \"B\"\ncount = 4\n\
             [serve]\nshards = 2\nseed = 11\narrival = \"poisson\"\n\
             mean_gap_cycles = 800\noverload = \"shed\"\nqueue_cap_cycles = 5000\n",
        )
        .unwrap(),
    )
    .unwrap();
    let g = workloads::mlp(4, 32, &[16], 8, 1).unwrap();
    let m = map_graph(&g, &fabric, MapStrategy::Greedy, Precision::Int8).unwrap();
    let prog = lower(&g, &fabric, &m).unwrap();

    let mut gen = arrival_gen_from_config(&fabric.cfg.serve).unwrap();
    let mut srv = ShardedServer::from_config(&fabric).unwrap();
    let rep = srv.serve(&prog, &mut gen, 12).unwrap();

    let mut twin_gen = ArrivalGen::new(ArrivalProcess::Poisson { mean_gap: 800 }, 11);
    let mut twin = ShardedServer::new(&fabric, 2);
    twin.set_seed(11).unwrap();
    twin.set_overload(OverloadPolicy::Shed, 5_000).unwrap();
    let want = twin.serve(&prog, &mut twin_gen, 12).unwrap();
    assert_eq!(rep, want, "config-built server diverged from the explicit build");
    assert_eq!(rep.records.len(), 12);
}

/// Steady-state footprint regression: under a bursty diurnal trace run
/// for ≥ 10× the pruning horizon, a pruning server's retained history
/// stays bounded (late-run footprint ≈ mid-run footprint) while the
/// unpruned twin grows without bound.
#[test]
fn long_run_footprint_stays_bounded_under_pruning() {
    let fabric = bundled_fabric("edge16.toml");
    let prog = lowered(&fabric, MapStrategy::Greedy);
    let horizon: Cycle = 30_000;
    // Bursty base gaps (back-to-back spikes then lulls) with diurnal
    // modulation on top.
    let mut gen = ArrivalGen::new(
        ArrivalProcess::Trace { gaps: vec![0, 0, 4_000, 200, 6_000, 0, 3_000] },
        3,
    )
    .with_diurnal(40_000, 0.7);

    let mut pruned = ShardedServer::new(&fabric, 2);
    pruned.set_seed(9).unwrap();
    pruned.set_prune(horizon, true);
    let mut unpruned = ShardedServer::new(&fabric, 2);
    unpruned.set_seed(9).unwrap();

    let chunks = 10;
    let per_chunk = 20;
    let mut footprints = Vec::new();
    let mut last_arrival = 0;
    for _ in 0..chunks {
        let arrivals = gen.take_trace(per_chunk);
        last_arrival = *arrivals.last().unwrap();
        let a = pruned.serve_trace(&prog, &arrivals).unwrap();
        let b = unpruned.serve_trace(&prog, &arrivals).unwrap();
        // Pruning is a memory policy, not a scheduling policy: the
        // merged serving records are identical.
        assert_eq!(a, b, "pruning changed the serving results");
        footprints.push(pruned.history_footprint());
    }
    assert!(
        last_arrival >= 10 * horizon,
        "trace too short for the regression: {last_arrival} < 10 x {horizon}"
    );
    let mid = footprints[chunks / 2];
    let last = *footprints.last().unwrap();
    assert!(mid > 0, "probe never observed retained history");
    assert!(
        last <= 2 * mid,
        "pruned footprint kept growing: mid {mid} -> last {last}"
    );
    assert!(
        2 * last < unpruned.history_footprint(),
        "pruning retained most of the history: {} vs {}",
        last,
        unpruned.history_footprint()
    );
    // The id table stays window-sized too, on every shard.
    let (_, ids) = pruned.queue_footprint();
    let (_, ids_unpruned) = unpruned.queue_footprint();
    assert!(ids < ids_unpruned, "pruned id table did not shrink: {ids} vs {ids_unpruned}");
}
