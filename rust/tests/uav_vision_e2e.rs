//! Tier-1 promotion of the UAV-vision scenario (examples/uav_vision.rs,
//! paper Sec. I use case): the ViT-tiny pipeline on the heterogeneous
//! edge-16 fabric, as an end-to-end test — fault-free golden first,
//! then a seeded-fault variant asserting the degradation report.
//!
//! The example's PJRT half needs the external XLA runtime (`pjrt`
//! feature) and stays in the example; everything the co-simulation half
//! computes — per-precision compilation, timing/energy, batched serving
//! through the dynamic batcher, and degraded serving on a fabric that
//! loses a tile mid-stream — is pinned here so `cargo test` exercises
//! the full stack the example demos.

use std::sync::mpsc;
use std::time::Instant;

use archytas::accel::Precision;
use archytas::compiler::lowering::lower;
use archytas::compiler::mapper::{map_graph, node_compute, MapStrategy};
use archytas::compiler::{FabricProgram, Step};
use archytas::config::FabricConfig;
use archytas::coordinator::{
    cosim, BatchServer, CosimExecutor, CosimSession, DegradedExecutor, FaultySession,
    RecoveryPolicy, ServeRequest, ShardedServer,
};
use archytas::fabric::Fabric;
use archytas::runtime::Tensor;
use archytas::sim::{FaultConfig, FaultEvent, FaultKind, FaultPlan};
use archytas::testutil::bundled_fabric;
use archytas::workloads;

/// The example's frame/classifier shape: 16x16 RGB frames, 10 classes.
const FRAME: usize = 16 * 16 * 3;
const CLASSES: usize = 10;

fn vit_graph() -> archytas::ir::Graph {
    workloads::vit(&workloads::VitParams::default(), 0).unwrap()
}

fn lowered(fabric: &Fabric, p: Precision) -> FabricProgram {
    let g = vit_graph();
    let m = map_graph(&g, fabric, MapStrategy::Greedy, p).unwrap();
    lower(&g, fabric, &m).unwrap()
}

/// Fault-free golden: the example's co-simulation table. Every
/// precision variant compiles onto the edge fabric, schedules every
/// compute node, produces a plausible timing/energy report — and the
/// whole pipeline is deterministic (two runs, identical bits) and
/// engine-consistent (one-shot co-sim ≡ admission session at t=0).
#[test]
fn uav_vision_cosim_golden() {
    let fabric = bundled_fabric("edge16.toml");
    let g = vit_graph();
    let compute_nodes =
        (0..g.len()).filter(|&id| node_compute(&g, id).is_some()).count();
    for p in [Precision::F32, Precision::Int8, Precision::Analog] {
        let tag = format!("{p:?}");
        let prog = lowered(&fabric, p);
        // The compiler scheduled every layer (the example's sanity tie).
        assert_eq!(prog.exec_steps(), compute_nodes, "{tag}: exec steps vs compute nodes");
        let rep = cosim(&fabric, &prog).unwrap();
        assert!(rep.cycles > 0, "{tag}");
        assert!(rep.metrics.total_energy_pj() > 0.0, "{tag}");
        let util = rep.mean_utilization();
        assert!(util > 0.0 && util <= 1.0, "{tag}: utilization {util}");
        // Deterministic: the same compile + co-sim reproduces the bits.
        assert!(cosim(&fabric, &lowered(&fabric, p)).unwrap().bit_identical(&rep), "{tag}");
        // Engine-consistent: t=0 admission folds to the same report.
        let mut s = CosimSession::new(&fabric);
        s.admit_at(&prog, 0).unwrap();
        assert!(s.report().unwrap().bit_identical(&rep), "{tag}: session vs cosim");
    }
}

/// Pre-queue `n` frame requests (deterministic synthetic frames) and
/// return the receiver plus the reply channels.
fn queue_frames(n: usize) -> (mpsc::Receiver<ServeRequest>, Vec<mpsc::Receiver<Vec<f32>>>) {
    let (tx, rx) = mpsc::channel::<ServeRequest>();
    let mut replies = Vec::new();
    for i in 0..n {
        let mut rng = archytas::sim::Rng::new(7919 + i as u64);
        let (rtx, rrx) = mpsc::channel();
        tx.send(ServeRequest {
            sample: (0..FRAME).map(|_| rng.normal() as f32).collect(),
            reply: rtx,
            submitted: Instant::now(),
        })
        .unwrap();
        replies.push(rrx);
    }
    (rx, replies)
}

/// Mock classifier standing in for the PJRT artifact: row -> its first
/// `CLASSES` elements (deterministic, shape-correct).
fn mock_classifier(input: &Tensor) -> archytas::Result<Tensor> {
    let b = input.dims()[0];
    let s = input.dims()[1];
    let mut out = Vec::with_capacity(b * CLASSES);
    for i in 0..b {
        out.extend_from_slice(&input.data()[i * s..i * s + CLASSES]);
    }
    Tensor::new(vec![b, CLASSES], out)
}

/// Fault-free serving golden: frames stream through the dynamic
/// batcher with the ViT program as the per-batch timing model. Every
/// request is answered with the mock classifier's exact output, and
/// every formed batch gets a positive simulated fabric makespan.
#[test]
fn uav_vision_serves_frames_with_simulated_latency() {
    let fabric = bundled_fabric("edge16.toml");
    let prog = lowered(&fabric, Precision::Int8);
    let solo = cosim(&fabric, &prog).unwrap();
    let mut sim = CosimExecutor::new(&fabric, prog, solo.cycles / 4);
    let (rx, replies) = queue_frames(10);
    let server = BatchServer::new(FRAME, CLASSES, 4);
    let stats = server.run_cosim(rx, mock_classifier, &mut sim).unwrap();
    assert_eq!(stats.requests, 10);
    assert!(stats.batches >= 3, "max_batch 4 over 10 frames");
    assert_eq!(stats.sim_cycles.len(), stats.batches);
    assert!(stats.sim_cycles.iter().all(|&c| c > 0));
    // Overlapping arrivals queue on shared tiles: later batches can
    // only be as fast as a solo run or slower.
    assert!(stats.sim_cycles.iter().all(|&c| c >= solo.cycles));
    for r in replies {
        let out = r.recv().unwrap();
        assert_eq!(out.len(), CLASSES);
    }
    let rep = sim.session_mut().report().unwrap();
    assert_eq!(rep.programs.len(), stats.batches);
}

/// Seeded-fault variant: the tile running the ViT head dies while the
/// first batch is in flight. Under the retry policy every batch
/// re-maps onto surviving silicon, nothing is shed, and the
/// degradation report quantifies exactly one effective fault.
#[test]
fn uav_vision_degrades_gracefully_when_a_tile_dies() {
    let fabric = bundled_fabric("edge16.toml");
    let prog = lowered(&fabric, Precision::Int8);
    let solo = cosim(&fabric, &prog).unwrap();
    let victim = prog
        .steps
        .iter()
        .rev()
        .find_map(|s| match s {
            Step::Exec { tile, .. } => Some(*tile),
            _ => None,
        })
        .expect("vit program has exec steps");
    let plan = FaultPlan::from_events(vec![FaultEvent {
        at: solo.cycles / 2,
        kind: FaultKind::TileDeath { tile: victim },
    }]);
    let cfg = FaultConfig::default();
    let session = FaultySession::with_plan(&fabric, plan, &cfg, RecoveryPolicy::Retry).unwrap();
    let mut sim = DegradedExecutor::with_session(session, prog, solo.cycles / 4);
    let (rx, replies) = queue_frames(10);
    let server = BatchServer::new(FRAME, CLASSES, 4);
    let stats = server.run_degraded(rx, mock_classifier, &mut sim).unwrap();
    assert_eq!(stats.requests, 10);
    assert_eq!(stats.sim_cycles.len(), stats.batches);
    // Functionally nothing is lost: every frame still gets its answer.
    for r in replies {
        assert_eq!(r.recv().unwrap().len(), CLASSES);
    }
    // Recovery telemetry: every batch survived by re-mapping off the
    // dead tile; none were shed, none retried in place.
    let outcomes = sim.outcomes();
    assert_eq!(outcomes.len(), stats.batches);
    assert!(outcomes.iter().all(|o| o.remapped), "every batch used the dead tile");
    assert!(outcomes.iter().all(|o| !o.shed), "retry policy must not shed");
    assert!(stats.sim_cycles.iter().all(|&c| c > 0), "no shed batch, no zero makespan");
    let (rep, deg) = sim.report_degraded().unwrap();
    assert_eq!(
        (deg.programs, deg.completed, deg.shed),
        (stats.batches, stats.batches, 0)
    );
    assert_eq!(deg.availability, 1.0);
    assert_eq!((deg.faults_injected, deg.faults_effective, deg.faults_masked), (1, 1, 0));
    assert!(deg.mean_cycles_between_effective.is_finite());
    assert!(deg.mean_cycles_between_effective > 0.0);
    // Nothing completed on dead silicon.
    assert_eq!(rep.tile_busy[victim], 0, "retained work on the dead tile");
    // The degraded stream is still a valid serving run: one span per
    // batch, all finishing after the death.
    assert_eq!(rep.programs.len(), stats.batches);
    assert!(rep.programs.iter().all(|p| p.finished_at > solo.cycles / 2));
}

/// Config-driven degraded serving: a TOML that pairs `[serve]` with a
/// live `[fault]` section must serve the ViT stream through
/// fault-injected shards — `ShardedServer::from_config` silently
/// building plain sessions was the PR's serving-path bug. Every admitted
/// frame carries a recovery outcome, the merged sojourn histogram
/// answers percentiles, and the whole episode replays bit for bit.
#[test]
fn uav_vision_serves_degraded_from_config() {
    let fabric = Fabric::build(
        FabricConfig::from_toml(
            "[noc]\ntopology = \"torus\"\nwidth = 4\nheight = 4\n\
             [[cu]]\nkind = \"npu\"\ntemplate = \"B\"\ncount = 8\n\
             [[cu]]\nkind = \"crossbar\"\ntemplate = \"A\"\ncount = 4\n\
             [serve]\nshards = 2\nseed = 3\n\
             [fault]\nhorizon = 40000000\nwindow = 65536\np_transient = 0.02\n",
        )
        .unwrap(),
    )
    .unwrap();
    let prog = lowered(&fabric, Precision::Int8);
    let mut srv = ShardedServer::from_config(&fabric).unwrap();
    let arrivals: Vec<u64> = (0..12u64).map(|i| i * 50_000).collect();
    let rep = srv.serve_trace(&prog, &arrivals).unwrap();
    assert_eq!(rep.records.len(), 12);
    assert!(
        rep.records.iter().all(|r| r.outcome.is_some()),
        "config-built shards must be fault-injected sessions"
    );
    assert!(rep.completed() > 0, "the stream must make progress under faults");
    assert!(rep.p50_sojourn_cycles() > 0.0);
    assert!(rep.p99_sojourn_cycles() >= rep.p50_sojourn_cycles());
    // from_config is deterministic end to end: a fresh server over the
    // same trace reproduces the report, histogram included.
    let mut again = ShardedServer::from_config(&fabric).unwrap();
    assert_eq!(again.serve_trace(&prog, &arrivals).unwrap(), rep);
}
