//! Cross-language integration: every AOT artifact, executed through the
//! PJRT runtime from Rust, must reproduce the golden outputs produced by
//! the Python (jax) reference at lowering time — bit-compatible numerics
//! across the language boundary.
//!
//! Requires `make artifacts` (the Makefile runs it before cargo test) and
//! the `pjrt` cargo feature — the offline default build substitutes a
//! stub engine, so these tests compile to nothing without it.
#![cfg(feature = "pjrt")]

use archytas::runtime::Runtime;

fn runtime() -> Runtime {
    Runtime::open_default().expect("artifacts/ missing — run `make artifacts`")
}

#[test]
fn all_artifacts_reproduce_golden_outputs() {
    let rt = runtime();
    let names = rt.artifact_names();
    assert!(names.len() >= 10, "expected full artifact set, got {names:?}");
    for name in names {
        let inputs = rt.registry().golden_inputs(&name).unwrap();
        let want = rt.registry().golden_outputs(&name).unwrap();
        let got = rt.run(&name, &inputs).unwrap_or_else(|e| panic!("{name}: {e:#}"));
        assert_eq!(got.len(), want.len(), "{name}: output arity");
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            let scale = w.data().iter().fold(1.0f32, |a, &v| a.max(v.abs()));
            let diff = g.max_abs_diff(w).unwrap();
            assert!(
                diff <= 2e-4 * scale,
                "{name} output {i}: max abs diff {diff} (scale {scale})"
            );
        }
    }
}

#[test]
fn executable_rejects_wrong_shapes() {
    let rt = runtime();
    let exe = rt.executable("gemm_64").unwrap();
    let bad = archytas::runtime::Tensor::zeros(vec![2, 2]);
    let good = archytas::runtime::Tensor::zeros(vec![64, 64]);
    assert!(exe.run(&[bad, good.clone()]).is_err());
    assert!(exe.run(&[good.clone()]).is_err(), "arity check");
}

#[test]
fn executables_are_cached() {
    let rt = runtime();
    let a = rt.executable("gemm_64").unwrap();
    let b = rt.executable("gemm_64").unwrap();
    assert!(std::sync::Arc::ptr_eq(&a, &b));
}

#[test]
fn gemm_matches_host_reference() {
    // Independent of golden files: run gemm_64 on fresh deterministic
    // inputs and compare with a host-side matmul.
    let rt = runtime();
    let mut rng = archytas::sim::Rng::new(123);
    let x = archytas::runtime::Tensor::random(vec![64, 64], &mut rng);
    let w = archytas::runtime::Tensor::random(vec![64, 64], &mut rng);
    let got = rt.run("gemm_64", &[x.clone(), w.clone()]).unwrap();
    let mut want = vec![0.0f32; 64 * 64];
    for i in 0..64 {
        for kk in 0..64 {
            let xv = x.at2(i, kk);
            for j in 0..64 {
                want[i * 64 + j] += xv * w.at2(kk, j);
            }
        }
    }
    let want = archytas::runtime::Tensor::new(vec![64, 64], want).unwrap();
    let diff = got[0].max_abs_diff(&want).unwrap();
    assert!(diff < 1e-3, "diff {diff}");
}
