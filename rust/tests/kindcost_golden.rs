//! Differential golden tests for the kind-aware cost model
//! (`[fabric.cost] model = "kind"`, `fabric::cost::KindCost`) and the
//! mapper's cost-model seam (`map_graph_with`).
//!
//! Contracts pinned here:
//!
//! * **Kind-blind bit-parity** — on every bundled config, the default
//!   `map_graph` (which now estimates through the fabric's configured
//!   cost model) reproduces the invariant-model mapping bit for bit:
//!   congestion/DVFS factors are exactly 1.0 at `start = 0` with a
//!   disabled occupancy, so threading the model through the mapper moves
//!   no bits until a model actually prices kinds differently. Sessions
//!   keep sharing the fabric's `Arc` (pointer identity, not a clone).
//! * **Kind-aware placements move** — on the mixed post-CMOS config the
//!   kind model's cold-photonic warm-up and crossbar conversion taxes
//!   change at least one golden workload's placement vs the invariant
//!   estimate, and the pricing actually bites end to end (cold photonic
//!   execs pay warm-up cycles + laser tuning energy).
//! * **Cross-engine fixed-point agreement** — the event engine, the
//!   iterated list scheduler and the admission session agree bit for bit
//!   under the kind model on the mixed fabric at t = 0.
//! * **Incremental ≡ from-scratch** — random admit/drain interleavings
//!   on the mixed fabric under `threads ∈ {1, 2, 4, 8}` bit-match a
//!   from-scratch session: the kind model's occupancy feedback obeys the
//!   strictly-earlier-epoch contract, so the horizon-invalidation rule
//!   stays exact and the shard-parallel drains deterministic.
//! * **TOML plumbing** — `configs/hetero_mixed.toml` builds the kind
//!   model, `cosim` prices through it implicitly, and the shared knobs
//!   (`window_epochs`/`warm_frac`/`alpha`/`cap`) round-trip against an
//!   explicitly constructed model.

use std::sync::Arc;

use archytas::accel::Precision;
use archytas::compiler::lowering::lower;
use archytas::compiler::mapper::{map_graph, map_graph_with, MapStrategy};
use archytas::compiler::FabricProgram;
use archytas::coordinator::{cosim, cosim_ref_with, cosim_with, CosimSession, ExecReport};
use archytas::fabric::{CostModel, Fabric, InvariantCost, KindCost, KindKnobs, TileKind};
use archytas::metrics::Category;
use archytas::sim::Cycle;
use archytas::testutil::{bundled_fabric, prop};
use archytas::workloads;

const STRATEGIES: [MapStrategy; 3] =
    [MapStrategy::RoundRobin, MapStrategy::Greedy, MapStrategy::Ilp];

fn workload(name: &str) -> archytas::ir::Graph {
    match name {
        "mlp" => workloads::mlp(4, 64, &[32], 10, 7).unwrap(),
        "vit" => {
            let p = workloads::VitParams {
                batch: 2,
                tokens: 8,
                dim: 32,
                depth: 1,
                mlp_ratio: 2,
                patch_dim: 16,
                classes: 10,
            };
            workloads::vit(&p, 3).unwrap()
        }
        other => panic!("unknown workload {other}"),
    }
}

/// The mixed config's model with its TOML knobs, built explicitly.
fn mixed_model() -> KindCost {
    KindCost::new(
        512,
        KindKnobs {
            photonic_window: 4,
            photonic_warm_frac: 0.25,
            pim_contention_alpha: 0.25,
            pim_contention_cap: 4.0,
            ..KindKnobs::default()
        },
    )
}

fn assert_identical(a: &ExecReport, b: &ExecReport, tag: &str) {
    assert_eq!(a.cycles, b.cycles, "{tag}: makespan");
    assert_eq!(a.step_done, b.step_done, "{tag}: step_done");
    assert_eq!(
        a.metrics.total_energy_pj().to_bits(),
        b.metrics.total_energy_pj().to_bits(),
        "{tag}: energy bits"
    );
    assert!(a.bit_identical(b), "{tag}: bit_identical contract");
}

/// (a) Kind-blind bit-parity: on every bundled config — including the
/// loaded one whose default model is congestion+DVFS — the default
/// `map_graph` equals `map_graph_with(InvariantCost)` bit for bit, so
/// the mapper seam alone reproduces the pre-seam placements. Sessions
/// keep sharing the fabric's model `Arc` rather than cloning it.
#[test]
fn kind_blind_mapping_is_bit_identical_across_configs() {
    for cfg in ["edge16.toml", "edge16_loaded.toml", "homogeneous_npu.toml"] {
        let fabric = bundled_fabric(cfg);
        for wname in ["mlp", "vit"] {
            let g = workload(wname);
            for strategy in STRATEGIES {
                for prefer in [Precision::Int8, Precision::Analog] {
                    let tag = format!("{cfg}/{wname}/{strategy:?}/{prefer:?}");
                    let dflt = map_graph(&g, &fabric, strategy, prefer).unwrap();
                    let inv =
                        map_graph_with(&g, &fabric, strategy, prefer, &InvariantCost).unwrap();
                    assert_eq!(dflt.assign, inv.assign, "{tag}: assign");
                    assert_eq!(dflt.precision, inv.precision, "{tag}: precision");
                    assert_eq!(dflt.est_cycles, inv.est_cycles, "{tag}: est_cycles");
                    assert_eq!(
                        dflt.est_energy_pj.to_bits(),
                        inv.est_energy_pj.to_bits(),
                        "{tag}: est_energy bits"
                    );
                }
            }
        }
        let fabric = bundled_fabric(cfg);
        let s = CosimSession::new(&fabric);
        assert!(
            Arc::ptr_eq(s.cost_model(), fabric.cost_model()),
            "{cfg}: session must share the fabric's model Arc"
        );
    }
}

/// (b) Kind-aware placements move: pricing the cold-photonic warm-up and
/// crossbar conversion taxes through the mapper changes at least one
/// golden workload's placement on the mixed config — the ROADMAP's
/// "mapper can prefer a warmed-up tile" seam, pinned.
#[test]
fn kind_aware_mapping_moves_placements_on_the_mixed_config() {
    let fabric = bundled_fabric("hetero_mixed.toml");
    let model = mixed_model();
    let mut moved = Vec::new();
    for wname in ["mlp", "vit"] {
        let g = workload(wname);
        for strategy in [MapStrategy::Greedy, MapStrategy::Ilp] {
            let kind = map_graph_with(&g, &fabric, strategy, Precision::Analog, &model).unwrap();
            let inv =
                map_graph_with(&g, &fabric, strategy, Precision::Analog, &InvariantCost).unwrap();
            if kind.assign != inv.assign {
                moved.push(format!("{wname}/{strategy:?}"));
            }
            // Cold photonic tiles pay 2k cycles per exec under the kind
            // estimate: a mapping that still uses them must never price
            // below the invariant estimate of the *same* assignment.
            let photonic_execs = kind
                .assign
                .iter()
                .flatten()
                .filter(|&&t| fabric.tiles[t].kind == TileKind::Photonic)
                .count();
            let inv_photonic = inv
                .assign
                .iter()
                .flatten()
                .filter(|&&t| fabric.tiles[t].kind == TileKind::Photonic)
                .count();
            assert!(
                photonic_execs <= inv_photonic,
                "{wname}/{strategy:?}: kind-aware mapping placed more execs on cold \
                 photonic tiles ({photonic_execs}) than the blind one ({inv_photonic})"
            );
        }
    }
    assert!(
        !moved.is_empty(),
        "kind-aware pricing moved no placement on any golden workload"
    );
}

/// The pricing bites end to end: a program mapped onto the mixed fabric
/// at Analog preference prices strictly higher in cycles under the kind
/// model than under the invariant floor (cold photonic warm-up, crossbar
/// conversion latency), and the warm-up's laser tuning energy lands in
/// the `Laser` category.
#[test]
fn kind_pricing_bites_on_the_mixed_config() {
    let fabric = bundled_fabric("hetero_mixed.toml");
    let g = workload("vit");
    let m = map_graph(&g, &fabric, MapStrategy::Greedy, Precision::Analog).unwrap();
    let prog = lower(&g, &fabric, &m).unwrap();
    let kind = cosim(&fabric, &prog).unwrap();
    let floor = cosim_with(&fabric, &prog, &InvariantCost).unwrap();
    assert!(kind.cycles >= floor.cycles, "kind pricing can never beat the invariant floor");
    let uses_photonic = m
        .assign
        .iter()
        .flatten()
        .any(|&t| fabric.tiles[t].kind == TileKind::Photonic);
    if uses_photonic {
        assert!(
            kind.metrics.energy(Category::Laser) > floor.metrics.energy(Category::Laser),
            "cold photonic execs must burn thermal-tuning laser energy"
        );
    }
    // Ops and bytes are schedule-invariant: the kind model moves time
    // and energy, never the work.
    assert_eq!(kind.metrics.ops, floor.metrics.ops);
    assert_eq!(kind.metrics.bytes_moved, floor.metrics.bytes_moved);
}

/// (c) Cross-engine fixed-point agreement at t = 0 on the mixed fabric:
/// the event engine's single self-consistent pass, the iterated list
/// scheduler and the admission session must land on identical bits under
/// the kind model — its occupancy reads are strictly earlier-epoch, so
/// the fixed point is unique.
#[test]
fn kind_model_agrees_across_engines_on_the_mixed_config() {
    let fabric = bundled_fabric("hetero_mixed.toml");
    assert_eq!(fabric.cost_model().name(), "kind");
    for (wname, strategy) in [("mlp", MapStrategy::Greedy), ("vit", MapStrategy::RoundRobin)] {
        let tag = format!("hetero_mixed/{wname}");
        let g = workload(wname);
        let m = map_graph(&g, &fabric, strategy, Precision::Analog).unwrap();
        let prog = lower(&g, &fabric, &m).unwrap();
        let ev = cosim(&fabric, &prog).unwrap();
        let re = cosim_ref_with(&fabric, &prog, fabric.cost_model().as_ref()).unwrap();
        assert_identical(&ev, &re, &format!("{tag}: event vs iterated-list"));
        let mut s = CosimSession::new(&fabric);
        s.admit_at(&prog, 0).unwrap();
        assert_identical(&s.report().unwrap(), &ev, &format!("{tag}: session vs event"));
    }
}

/// (d) Incremental ≡ from-scratch on the mixed fabric under the kind
/// model, across the session thread sweep: random interleavings of
/// admissions (at random times) and partial drains must bit-match a
/// from-scratch session with the same final programs — at every
/// `threads ∈ {1, 2, 4, 8}`.
#[test]
fn kind_incremental_matches_from_scratch_across_threads() {
    let fabric = bundled_fabric("hetero_mixed.toml");
    // A small pool of lowered programs to admit repeatedly.
    let progs: Vec<FabricProgram> = [
        ("mlp", MapStrategy::Greedy),
        ("vit", MapStrategy::RoundRobin),
        ("mlp", MapStrategy::RoundRobin),
    ]
    .into_iter()
    .map(|(wname, strategy)| {
        let g = workload(wname);
        let m = map_graph(&g, &fabric, strategy, Precision::Analog).unwrap();
        lower(&g, &fabric, &m).unwrap()
    })
    .collect();
    for threads in [1usize, 2, 4, 8] {
        prop::check(6, |rng| {
            let mut inc = CosimSession::new(&fabric);
            inc.set_threads(threads);
            let mut current: Vec<(usize, Cycle)> = Vec::new();
            for _ in 0..rng.below(4) + 1 {
                let roll = rng.below(10);
                if roll < 6 || current.is_empty() {
                    let pi = rng.below(progs.len());
                    let at = rng.below(20_000) as Cycle;
                    inc.admit_at(&progs[pi], at).map_err(|e| e.to_string())?;
                    current.push((pi, at));
                } else if roll < 8 {
                    inc.run_to_drain().map_err(|e| e.to_string())?;
                } else {
                    inc.run_until(rng.below(30_000) as Cycle).map_err(|e| e.to_string())?;
                }
            }
            let got = inc.report().map_err(|e| e.to_string())?;
            let mut fresh = CosimSession::new(&fabric);
            fresh.set_threads(threads);
            for &(pi, at) in &current {
                fresh.admit_at(&progs[pi], at).map_err(|e| e.to_string())?;
            }
            let want = fresh.report().map_err(|e| e.to_string())?;
            if !got.bit_identical(&want) {
                return Err(format!(
                    "threads={threads}: incremental diverged: cycles {} vs {}",
                    got.cycles, want.cycles
                ));
            }
            Ok(())
        });
    }
}

/// (e) TOML plumbing: `hetero_mixed.toml` builds the kind model, `cosim`
/// prices through it implicitly, and the shared `[fabric.cost]` knobs
/// round-trip (an explicit model with the same knobs reproduces the
/// bits).
#[test]
fn mixed_config_knobs_round_trip() {
    let fabric = bundled_fabric("hetero_mixed.toml");
    assert_eq!(fabric.cost_model().name(), "kind");
    let g = workload("mlp");
    let m = map_graph(&g, &fabric, MapStrategy::Greedy, Precision::Analog).unwrap();
    let prog = lower(&g, &fabric, &m).unwrap();
    let implicit = cosim(&fabric, &prog).unwrap();
    assert_identical(
        &cosim_with(&fabric, &prog, &mixed_model()).unwrap(),
        &implicit,
        "hetero_mixed: TOML knobs vs explicit model",
    );
    // The tile kinds the config declares survive the build, in group
    // order: 4 npu, 2 crossbar, 2 photonic, 2 neuromorphic, 2 pim_dram,
    // 2 cpu.
    let kinds: Vec<TileKind> = fabric.tiles.iter().map(|t| t.kind).collect();
    let want = [
        vec![TileKind::Npu; 4],
        vec![TileKind::Crossbar; 2],
        vec![TileKind::Photonic; 2],
        vec![TileKind::Neuromorphic; 2],
        vec![TileKind::PimDram; 2],
        vec![TileKind::Cpu; 2],
    ]
    .concat();
    assert_eq!(kinds, want, "hetero_mixed tile-kind layout");
}
