//! Property-based invariant tests over the simulators, solvers and
//! compiler passes (deliverable (c); uses the in-repo `testutil::prop`
//! harness — the offline image has no proptest).

use archytas::accel::{Compute, Precision};
use archytas::compiler::precision::{analyze_ranges, FixedFormat, Interval};
use archytas::compiler::{pruning, quantize, sparsify, FabricProgram, Step};
use archytas::config::FabricConfig;
use archytas::coordinator::CosimSession;
use archytas::dram::{DramKind, DramSim, DramTiming, Request};
use archytas::dse::milp::{Milp, Sense};
use archytas::dse::pareto_front;
use archytas::fabric::Fabric;
use archytas::ir::interp::{self, Mat};
use archytas::noc::{routing::RouteTable, traffic, NocParams, NocSim, Topology};
use archytas::sim::{Calendar, Cycle, EventWheel, Rng, StampedCalendar};
use archytas::testutil::prop;
use archytas::workloads;

fn random_topology(rng: &mut Rng) -> Topology {
    match rng.below(6) {
        0 => Topology::mesh(rng.below(5) + 2, rng.below(5) + 2).unwrap(),
        1 => Topology::torus(rng.below(4) + 2, rng.below(4) + 2).unwrap(),
        2 => Topology::ring(rng.below(12) + 3).unwrap(),
        3 => Topology::star(rng.below(12) + 3).unwrap(),
        4 => Topology::fattree(rng.below(3) + 2).unwrap(),
        _ => {
            // random connected graph: spanning chain + extra edges
            let n = rng.below(10) + 4;
            let mut edges: Vec<(usize, usize)> = (1..n).map(|i| (i - 1, i)).collect();
            for _ in 0..rng.below(n) {
                let a = rng.below(n);
                let b = rng.below(n);
                if a != b && !edges.contains(&(a.min(b), a.max(b)))
                    && !edges.contains(&(a.max(b), a.min(b)))
                {
                    edges.push((a, b));
                }
            }
            Topology::custom(n, &edges).unwrap()
        }
    }
}

/// Routing: on every topology, every (s,d) route terminates, is loop-free
/// (bounded by node count) and shortest for the table router.
#[test]
fn prop_routing_terminates_and_is_shortest() {
    prop::check(40, |rng| {
        let t = random_topology(rng);
        let rt = RouteTable::build(&t);
        let s = rng.below(t.nodes());
        let dist = t.distances(s);
        for d in 0..t.nodes() {
            if d == s {
                continue;
            }
            let len = rt.route_len(s, d);
            if len != dist[d] {
                return Err(format!("{s}->{d}: route {len} vs bfs {:?}", dist[d]));
            }
        }
        Ok(())
    });
}

/// NoC conservation: every injected packet is delivered exactly once and
/// the network fully drains; energy is exactly flit_hops * flit * 8 * pj.
#[test]
fn prop_noc_conservation() {
    prop::check(15, |rng| {
        let t = random_topology(rng);
        let n = t.nodes();
        if n < 2 {
            return Ok(());
        }
        let mut sim = NocSim::new(t, NocParams::default());
        let count = rng.below(60) + 5;
        for _ in 0..count {
            let s = rng.below(n);
            let mut d = rng.below(n);
            while d == s {
                d = rng.below(n);
            }
            sim.inject(s, d, rng.below(200) + 1);
        }
        let rep = sim.run_to_drain(3_000_000);
        if rep.delivered != count {
            return Err(format!("delivered {}/{count}", rep.delivered));
        }
        if !sim.drained() {
            return Err("not drained".into());
        }
        let expect_pj = rep.flit_hops as f64 * 32.0 * 8.0 * 0.15;
        let got = rep.metrics.total_energy_pj();
        if (got - expect_pj).abs() > 1e-6 * expect_pj.max(1.0) {
            return Err(format!("energy {got} vs {expect_pj}"));
        }
        Ok(())
    });
}

/// Shard-partition invariance: the parallel NoC step's determinism
/// contract says *any* valid contiguous partition of the node range —
/// one node per shard, everything in one shard, or random uneven cuts —
/// produces a bit-identical SimReport (side effects are order-merged in
/// global node order; see noc/sim.rs module docs).
#[test]
fn prop_shard_partition_invariance() {
    prop::check(8, |rng| {
        let t = random_topology(rng);
        let n = t.nodes();
        if n < 2 {
            return Ok(());
        }
        let count = rng.below(40) + 5;
        let mut workload = Vec::new();
        for _ in 0..count {
            let s = rng.below(n);
            let mut d = rng.below(n);
            while d == s {
                d = rng.below(n);
            }
            workload.push((s, d, rng.below(180) + 1));
        }
        let run = |bounds: Option<&[usize]>| {
            let mut sim = NocSim::new(t.clone(), NocParams::default());
            if let Some(b) = bounds {
                sim.set_shards(b);
            }
            for &(s, d, bytes) in &workload {
                sim.inject(s, d, bytes);
            }
            let r = sim.run_to_drain(3_000_000);
            (
                r.cycles,
                r.delivered,
                r.flit_hops,
                r.avg_latency.to_bits(),
                r.p99_latency.to_bits(),
                r.throughput.to_bits(),
            )
        };
        let base = run(None);
        // 1 node/shard, all-in-one (explicit), and a random uneven cut.
        let per_node: Vec<usize> = (0..=n).collect();
        let single: Vec<usize> = vec![0, n];
        let mut uneven: Vec<usize> = vec![0];
        for b in 1..n {
            if rng.chance(0.3) {
                uneven.push(b);
            }
        }
        uneven.push(n);
        for bounds in [per_node, single, uneven] {
            let got = run(Some(&bounds));
            if got != base {
                return Err(format!("partition {bounds:?}: {got:?} vs {base:?}"));
            }
        }
        Ok(())
    });
}

/// DRAM: random request mixes always drain; bytes moved = read+write
/// bursts * burst_bytes; latencies >= the device's minimum.
#[test]
fn prop_dram_drains_and_accounts_bytes() {
    prop::check(25, |rng| {
        let kind = [DramKind::Ddr4_2400, DramKind::Lpddr4_3200, DramKind::Hbm2]
            [rng.below(3)];
        let t = DramTiming::new(kind);
        let mut sim = DramSim::new(t);
        let count = rng.below(80) + 1;
        let mut expect_bytes = 0u64;
        for _ in 0..count {
            let addr = (rng.below(1 << 24)) as u64 & !63;
            let bytes = (rng.below(4) + 1) * t.burst_bytes;
            expect_bytes += bytes.div_ceil(t.burst_bytes) as u64 * t.burst_bytes as u64;
            if rng.chance(0.4) {
                sim.enqueue(Request::write(addr, bytes));
            } else {
                sim.enqueue(Request::read(addr, bytes));
            }
        }
        let st = sim.run_to_drain();
        if st.completed != count {
            return Err(format!("completed {}/{count}", st.completed));
        }
        if st.bytes != expect_bytes {
            return Err(format!("bytes {} vs {expect_bytes}", st.bytes));
        }
        let min_lat = (t.t_rcd + t.t_cl + t.t_burst) as f64;
        if st.avg_latency < min_lat {
            return Err(format!("latency {} < device min {min_lat}", st.avg_latency));
        }
        Ok(())
    });
}

/// MILP: random feasible bounded LPs — the returned point satisfies every
/// constraint and respects bounds; integer vars are integral.
#[test]
fn prop_milp_solutions_are_feasible() {
    prop::check(30, |rng| {
        let nvars = rng.below(5) + 1;
        let mut m = Milp::new();
        let mut bounds = Vec::new();
        for _ in 0..nvars {
            let lo = rng.range_f64(-5.0, 0.0);
            let hi = lo + rng.range_f64(0.5, 8.0);
            let cost = rng.range_f64(-3.0, 3.0);
            let int = rng.chance(0.5);
            m.add_var(lo, hi, cost, int);
            bounds.push((lo, hi, int));
        }
        let mut cons = Vec::new();
        for _ in 0..rng.below(4) {
            let coeffs: Vec<(usize, f64)> =
                (0..nvars).map(|v| (v, rng.range_f64(-2.0, 2.0))).collect();
            // rhs chosen so x = midpoints is feasible -> instance feasible
            let mid_val: f64 = coeffs
                .iter()
                .map(|&(v, c)| c * (bounds[v].0 + bounds[v].1) / 2.0)
                .sum();
            let rhs = mid_val + rng.range_f64(0.0, 5.0);
            m.add_constraint(coeffs.clone(), Sense::Le, rhs);
            cons.push((coeffs, rhs));
        }
        // midpoint integrality may break feasibility for int vars; skip
        // unsat results (None) rather than fail.
        let Some(sol) = m.minimize().map_err(|e| e.to_string())? else {
            return Ok(());
        };
        for (v, &(lo, hi, int)) in bounds.iter().enumerate() {
            let x = sol.x[v];
            if x < lo - 1e-6 || x > hi + 1e-6 {
                return Err(format!("x[{v}]={x} outside [{lo},{hi}]"));
            }
            if int && (x - x.round()).abs() > 1e-6 {
                return Err(format!("x[{v}]={x} not integral"));
            }
        }
        for (coeffs, rhs) in cons {
            let lhs: f64 = coeffs.iter().map(|&(v, c)| c * sol.x[v]).sum();
            if lhs > rhs + 1e-6 {
                return Err(format!("constraint violated: {lhs} > {rhs}"));
            }
        }
        Ok(())
    });
}

/// Pareto front: no front point dominates another; every non-front point
/// is dominated by some front point.
#[test]
fn prop_pareto_front_is_correct() {
    prop::check(50, |rng| {
        let n = rng.below(20) + 2;
        let dims = rng.below(3) + 2;
        let pts: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..dims).map(|_| rng.range_f64(0.0, 10.0)).collect())
            .collect();
        let front = pareto_front(&pts);
        let dominates = |a: &[f64], b: &[f64]| {
            a.iter().zip(b).all(|(x, y)| x <= y) && a.iter().zip(b).any(|(x, y)| x < y)
        };
        for &i in &front {
            for &j in &front {
                if i != j && dominates(&pts[i], &pts[j]) {
                    return Err(format!("front point {i} dominates front point {j}"));
                }
            }
        }
        for i in 0..n {
            if front.contains(&i) {
                continue;
            }
            let covered = front
                .iter()
                .any(|&f| dominates(&pts[f], &pts[i]) || pts[f] == pts[i]);
            if !covered {
                return Err(format!("non-front point {i} not dominated"));
            }
        }
        Ok(())
    });
}

/// Range analysis soundness on random MLPs with random hints.
#[test]
fn prop_range_analysis_sound() {
    prop::check(12, |rng| {
        let inputs = (rng.below(4) + 1) * 8;
        let hidden = (rng.below(4) + 1) * 8;
        let g = workloads::mlp(2, inputs, &[hidden], 4, rng.next_u64()).unwrap();
        let bound = rng.range_f64(0.5, 5.0);
        let iv = analyze_ranges(&g, &[Interval::new(-bound, bound)]).unwrap();
        let data: Vec<f32> = (0..2 * inputs)
            .map(|_| rng.range_f64(-bound, bound) as f32)
            .collect();
        let x = Mat::new([2, inputs], data).unwrap();
        let mut err = None;
        interp::run_with(&g, &[x], |id, m| {
            for &v in &m.data {
                if !iv[id].contains(v as f64) && err.is_none() {
                    err = Some(format!("node {id} value {v} outside {:?}", iv[id]));
                }
            }
        })
        .unwrap();
        err.map_or(Ok(()), Err)
    });
}

/// Fixed-point quantization error bound holds for random formats/values.
#[test]
fn prop_fixedpoint_error_bound() {
    prop::check(60, |rng| {
        let hi = rng.range_f64(0.1, 100.0);
        let r = Interval::new(-hi, hi);
        let word = [8u32, 12, 16, 24][rng.below(4)];
        let Some(f) = FixedFormat::for_range(&r, word) else {
            return Ok(());
        };
        for _ in 0..50 {
            let v = rng.range_f64(-hi, hi) as f32;
            let q = f.quantize(v);
            if ((q - v).abs() as f64) > f.error_bound() + 1e-7 {
                return Err(format!("{v} -> {q} exceeds bound {}", f.error_bound()));
            }
        }
        Ok(())
    });
}

/// Pruning invariants: requested sparsity achieved (±2%), surviving
/// weights unchanged, biases untouched.
#[test]
fn prop_pruning_preserves_survivors() {
    prop::check(20, |rng| {
        let g0 = workloads::mlp(2, 32, &[16], 8, rng.next_u64()).unwrap();
        let mut g1 = g0.clone();
        let sp = rng.range_f64(0.1, 0.9);
        let rep = pruning::magnitude_prune(&mut g1, sp);
        if (rep.sparsity() - sp).abs() > 0.03 {
            return Err(format!("sparsity {} vs requested {sp}", rep.sparsity()));
        }
        for (w0, w1) in g0.weights.iter().zip(&g1.weights) {
            for (a, b) in w0.data.iter().zip(&w1.data) {
                if *b != 0.0 && a != b {
                    return Err("survivor mutated".into());
                }
                if w0.shape[0] == 1 && a != b {
                    return Err("bias pruned".into());
                }
            }
        }
        Ok(())
    });
}

/// Quantized weights stay within one scale-step of the originals.
#[test]
fn prop_quantization_bounded_perturbation() {
    prop::check(20, |rng| {
        let g0 = workloads::mlp(2, 24, &[16], 4, rng.next_u64()).unwrap();
        let mut g1 = g0.clone();
        quantize::quantize_weights_int8(&mut g1);
        for (w0, w1) in g0.weights.iter().zip(&g1.weights) {
            if w0.shape[0] == 1 {
                continue;
            }
            let [k, n] = w0.shape;
            for j in 0..n {
                let amax = (0..k)
                    .map(|i| w0.data[i * n + j].abs())
                    .fold(0.0f32, f32::max);
                let step = if amax > 0.0 { amax / 127.0 } else { 1.0 };
                for i in 0..k {
                    let d = (w0.data[i * n + j] - w1.data[i * n + j]).abs();
                    if d > step / 2.0 + 1e-6 {
                        return Err(format!("perturbation {d} > step/2 {}", step / 2.0));
                    }
                }
            }
        }
        Ok(())
    });
}

/// Block sparsify: density monotone in keep parameter; norms only drop.
#[test]
fn prop_sparsify_monotone() {
    prop::check(15, |rng| {
        let g0 = workloads::mlp(2, 32, &[32], 8, rng.next_u64()).unwrap();
        let d_lo = rng.range_f64(0.2, 0.5);
        let d_hi = rng.range_f64(d_lo, 1.0);
        let mut g_lo = g0.clone();
        let mut g_hi = g0.clone();
        let r_lo = sparsify::block_sparsify(&mut g_lo, 16, 8, d_lo);
        let r_hi = sparsify::block_sparsify(&mut g_hi, 16, 8, d_hi);
        if r_lo.density > r_hi.density + 1e-9 {
            return Err(format!("density not monotone: {} vs {}", r_lo.density, r_hi.density));
        }
        if r_lo.norm_retained > r_hi.norm_retained + 1e-9 {
            return Err("norm not monotone".into());
        }
        Ok(())
    });
}

/// EventWheel lap-safety + exact-time delivery + FIFO tie-break: with a
/// deliberately tiny ring, random pushes (many far past the horizon, so
/// buckets hold several laps at once) must pop exactly at their cycle, in
/// push order within a cycle, and nothing may be lost or duplicated.
#[test]
fn prop_event_wheel_laps_exact_and_fifo() {
    prop::check(40, |rng| {
        let horizon = rng.below(6) + 1; // 1..6 -> rings of 2..8 buckets
        let mut w = EventWheel::with_horizon(horizon);
        let n = rng.below(80) + 1;
        let mut expect: std::collections::BTreeMap<Cycle, Vec<usize>> = Default::default();
        let max_t = 20 * (horizon as u64 + 1); // many laps
        for id in 0..n {
            let t = rng.below(max_t as usize) as Cycle;
            w.push(t, id);
            expect.entry(t).or_default().push(id);
        }
        if w.len() != n {
            return Err(format!("len {} after {n} pushes", w.len()));
        }
        for t in 0..=max_t {
            let due = w.take_due(t);
            let got: Vec<usize> = due.iter().map(|&(at, id)| {
                debug_assert_eq!(at, t);
                id
            }).collect();
            let want = expect.remove(&t).unwrap_or_default();
            if got != want {
                return Err(format!("at {t}: got {got:?} want {want:?}"));
            }
            w.recycle(due);
        }
        if !w.is_empty() {
            return Err(format!("{} events stranded past the sweep", w.len()));
        }
        Ok(())
    });
}

/// EventWheel push-while-draining (the NoC's credit-return shape: events
/// drained at cycle t schedule follow-ups at t+delta): every event must
/// surface exactly once, at its scheduled cycle, across bucket reuse.
#[test]
fn prop_event_wheel_push_while_draining() {
    prop::check(30, |rng| {
        let mut w = EventWheel::with_horizon(rng.below(4) + 2);
        // Each event carries (due_cycle, remaining_respawns).
        let seeds = rng.below(10) + 1;
        let mut outstanding = 0usize;
        for _ in 0..seeds {
            let t = rng.below(8) as Cycle;
            let hops = rng.below(5);
            w.push(t, (t, hops));
            outstanding += 1;
        }
        let mut now: Cycle = 0;
        let mut drained = 0usize;
        while !w.is_empty() {
            if now > 10_000 {
                return Err("wheel failed to drain".into());
            }
            let due = w.take_due(now);
            let spawn: Vec<(Cycle, usize)> = due.iter().map(|&(_, ev)| ev).collect();
            for (at, hops) in spawn {
                if at != now {
                    return Err(format!("event due {at} surfaced at {now}"));
                }
                drained += 1;
                if hops > 0 {
                    // respawn mid-drain, 1..=6 cycles out (can be the
                    // same bucket on a small ring)
                    let next = now + 1 + rng.below(6) as Cycle;
                    w.push(next, (next, hops - 1));
                    outstanding += 1;
                }
            }
            w.recycle(due);
            now += 1;
        }
        if drained != outstanding {
            return Err(format!("drained {drained} of {outstanding}"));
        }
        Ok(())
    });
}

/// Calendar (wheel + time index): `take_next` must visit strictly
/// increasing times, preserve FIFO order within a time, and conserve
/// every event — including pushes interleaved with draining.
#[test]
fn prop_calendar_time_ordered_and_lossless() {
    prop::check(30, |rng| {
        let mut c = Calendar::with_horizon(rng.below(5) + 1);
        let mut pushed = 0usize;
        let mut seen = 0usize;
        for id in 0..rng.below(60) + 1 {
            let t = rng.below(500) as Cycle;
            c.push(t, (t, id));
            pushed += 1;
        }
        let mut last: Option<Cycle> = None;
        while let Some((t, due)) = c.take_next() {
            if let Some(l) = last {
                if t <= l {
                    return Err(format!("time went {l} -> {t}"));
                }
            }
            last = Some(t);
            let mut prev_id: Option<usize> = None;
            for &(at, (want_t, id)) in &due {
                if at != t || want_t != t {
                    return Err(format!("event for {want_t} popped at {t} (slot {at})"));
                }
                // ids were pushed in increasing order per time
                if let Some(p) = prev_id {
                    if id <= p {
                        return Err(format!("FIFO broken at {t}: {p} then {id}"));
                    }
                }
                prev_id = Some(id);
                seen += 1;
            }
            // occasionally push more work strictly in the future
            if rng.chance(0.3) {
                let ft = t + 1 + rng.below(50) as Cycle;
                c.push(ft, (ft, usize::MAX / 2 + seen)); // ids stay increasing per fresh time
                pushed += 1;
            }
            c.recycle(due);
        }
        if seen != pushed {
            return Err(format!("saw {seen} of {pushed}"));
        }
        Ok(())
    });
}

/// Random synthetic DAG program over `nt` tiles: forward deps only
/// (duplicates allowed — the engine keeps them balanced on both sides),
/// mixing HBM loads, tile-to-tile transfers (including self-transfers,
/// which cost zero cycles) and Int8 matmul execs.
fn random_admission_program(rng: &mut Rng, nt: usize) -> FabricProgram {
    let n = rng.below(12) + 1;
    let mut steps = Vec::new();
    for i in 0..n {
        let mut deps: Vec<usize> = Vec::new();
        if i > 0 {
            for _ in 0..rng.below(3) {
                deps.push(rng.below(i));
            }
        }
        let step = match rng.below(3) {
            0 => Step::Load {
                tile: rng.below(nt),
                bytes: (rng.below(4000) + 1) as u64,
                node: 0,
                deps,
            },
            1 => Step::Transfer {
                from: rng.below(nt),
                to: rng.below(nt),
                bytes: (rng.below(4000) + 1) as u64,
                node: 0,
                deps,
            },
            _ => Step::Exec {
                tile: rng.below(nt),
                node: 0,
                compute: Compute::MatMul {
                    m: rng.below(8) + 1,
                    k: rng.below(8) + 1,
                    n: rng.below(8) + 1,
                },
                precision: Precision::Int8,
                deps,
            },
        };
        steps.push(step);
    }
    FabricProgram { steps, producer: Vec::new() }
}

/// Admission engine vs the invalidation oracle: random DAGs admitted at
/// random times (including the simulated past), random `replace`s (the
/// cost-model-bump primitive) and random full/partial drains in between
/// must leave the session bit-identical to a fresh one built from
/// scratch with the same final programs and times.
#[test]
fn prop_incremental_resimulation_matches_from_scratch() {
    let fabric = Fabric::build(
        FabricConfig::from_toml(
            "[noc]\nwidth = 3\nheight = 3\n\
             [[cu]]\nkind = \"npu\"\ntemplate = \"B\"\ncount = 4\n",
        )
        .unwrap(),
    )
    .unwrap();
    let nt = fabric.tile_count();
    prop::check(25, |rng| {
        let mut inc = CosimSession::new(&fabric);
        let mut current: Vec<(FabricProgram, Cycle)> = Vec::new();
        let mut handles = Vec::new();
        for _ in 0..rng.below(6) + 1 {
            let roll = rng.below(10);
            if roll < 5 || current.is_empty() {
                let p = random_admission_program(rng, nt);
                let at = rng.below(3000) as Cycle;
                handles.push(inc.admit_at(&p, at).map_err(|e| e.to_string())?);
                current.push((p, at));
            } else if roll < 7 {
                let slot = rng.below(current.len());
                let p = random_admission_program(rng, nt);
                let at = rng.below(3000) as Cycle;
                inc.replace(handles[slot], &p, at).map_err(|e| e.to_string())?;
                current[slot] = (p, at);
            } else if roll < 9 {
                inc.run_to_drain().map_err(|e| e.to_string())?;
            } else {
                inc.run_until(rng.below(4000) as Cycle).map_err(|e| e.to_string())?;
            }
        }
        let got = inc.report().map_err(|e| e.to_string())?;
        let mut fresh = CosimSession::new(&fabric);
        for (p, at) in &current {
            fresh.admit_at(p, *at).map_err(|e| e.to_string())?;
        }
        let want = fresh.report().map_err(|e| e.to_string())?;
        if !got.bit_identical(&want) {
            return Err(format!(
                "incremental diverged: cycles {} vs {}, steps {:?} vs {:?}",
                got.cycles, want.cycles, got.step_done, want.step_done
            ));
        }
        Ok(())
    });
}

/// StampedCalendar invariants under random interleavings of push,
/// cancel, cancel-then-readmit and take: every key surfaces exactly once
/// per live push, at its scheduled time, in push order within a time —
/// and never after a cancellation that outpaced it.
#[test]
fn prop_stamped_calendar_cancellation() {
    prop::check(40, |rng| {
        let mut c = StampedCalendar::with_horizon(rng.below(6) + 1);
        let keys = rng.below(12) + 1;
        // expected[key] = Some(time) when one live event is queued.
        let mut expected: Vec<Option<Cycle>> = vec![None; keys];
        let mut pushes = rng.below(60) + 5;
        let mut out = Vec::new();
        let mut live_target = 0usize;
        while pushes > 0 || !c.is_empty() {
            let act = rng.below(10);
            if act < 5 && pushes > 0 {
                let k = rng.below(keys);
                // single-live-event-per-key discipline (the engine's):
                // cancel first if one is queued.
                if expected[k].is_some() {
                    c.cancel(k);
                    expected[k] = None;
                    live_target -= 1;
                }
                let t = rng.below(500) as Cycle;
                c.push(t, k);
                expected[k] = Some(t);
                live_target += 1;
                pushes -= 1;
            } else if act < 6 {
                let k = rng.below(keys);
                if expected[k].is_some() {
                    c.cancel(k);
                    expected[k] = None;
                    live_target -= 1;
                }
            } else {
                match c.take_due_until(None, &mut out) {
                    None => {
                        if !c.is_empty() {
                            return Err("take returned None with live events".into());
                        }
                    }
                    Some(t) => {
                        for &k in &out {
                            if expected[k] != Some(t) {
                                return Err(format!(
                                    "key {k} surfaced at {t}, expected {:?}",
                                    expected[k]
                                ));
                            }
                            expected[k] = None;
                            live_target -= 1;
                        }
                    }
                }
            }
            if c.len() != live_target {
                return Err(format!("live count {} vs expected {live_target}", c.len()));
            }
        }
        if expected.iter().any(Option::is_some) {
            return Err("live events stranded".into());
        }
        Ok(())
    });
}

/// StampedCalendar FIFO ties across keys and push-while-draining: keys
/// pushed at one cycle surface in push order even when interleaved with
/// cancelled entries, and events pushed while draining (the re-enqueue
/// path) surface at their new times.
#[test]
fn prop_stamped_calendar_fifo_and_reenqueue() {
    prop::check(30, |rng| {
        let mut c = StampedCalendar::with_horizon(4);
        let n = rng.below(20) + 2;
        let t0: Cycle = 10;
        for k in 0..n {
            c.push(t0, k);
        }
        // Cancel a random subset, re-enqueueing half of it later.
        let mut expect_first: Vec<usize> = Vec::new();
        let mut reenqueued: Vec<usize> = Vec::new();
        for k in 0..n {
            if rng.chance(0.4) {
                c.cancel(k);
                if rng.chance(0.5) {
                    c.push(t0 + 7, k);
                    reenqueued.push(k);
                }
            } else {
                expect_first.push(k);
            }
        }
        let mut out = Vec::new();
        if expect_first.is_empty() {
            // Whole first batch cancelled: jump straight to the
            // re-enqueued batch (if any).
            match c.take_due_until(None, &mut out) {
                None => {
                    if !reenqueued.is_empty() {
                        return Err("lost re-enqueued events".into());
                    }
                }
                Some(t) => {
                    if (t, &out) != (t0 + 7, &reenqueued) {
                        return Err(format!("got {t}/{out:?} want {}/{reenqueued:?}", t0 + 7));
                    }
                }
            }
            return Ok(());
        }
        let t = c.take_due_until(None, &mut out);
        if t != Some(t0) || out != expect_first {
            return Err(format!("first batch {t:?}/{out:?} want {t0}/{expect_first:?}"));
        }
        if !reenqueued.is_empty() {
            let t = c.take_due_until(None, &mut out);
            if t != Some(t0 + 7) || out != reenqueued {
                return Err(format!("re-enqueued {t:?}/{out:?} want {reenqueued:?}"));
            }
        }
        if !c.is_empty() {
            return Err("stranded events".into());
        }
        Ok(())
    });
}

/// The open-loop traffic driver never loses packets at low load on any
/// regular topology.
#[test]
fn prop_traffic_driver_lossless() {
    prop::check(10, |rng| {
        let t = random_topology(rng);
        let n = t.nodes();
        if n < 3 {
            return Ok(());
        }
        let mut sim = NocSim::new(t, NocParams::default());
        let inj = traffic::generate(traffic::Pattern::Uniform, n, 0.02, 64, 500, rng);
        let total = inj.len();
        let rep = traffic::drive(&mut sim, inj, 2_000_000);
        if rep.delivered != total {
            return Err(format!("{}/{total} delivered", rep.delivered));
        }
        Ok(())
    });
}
