//! Differential golden tests for the cost-model layer (`fabric::cost`).
//!
//! Contracts pinned here:
//!
//! * **Invariant bit-parity** — under [`InvariantCost`] (explicit or the
//!   `[fabric.cost]` default), `cosim`, `cosim_with`, `cosim_ref`,
//!   `cosim_ref_with` and a `CosimSession` all reproduce the
//!   pre-cost-layer reports bit for bit across the full
//!   mlp/vit × RoundRobin/Greedy/Ilp × edge16/homogeneous matrix.
//! * **Cross-engine fixed-point agreement** — under the congestion/DVFS
//!   and kind-aware models, the event engine's single self-consistent
//!   pass, the
//!   iterated (Jacobi) list scheduler and the admission session's
//!   horizon-invalidation + settle loop reach the *same* unique fixed
//!   point, bit for bit.
//! * **Incremental ≡ from-scratch** — random admit/replace/partial-drain
//!   interleavings under time-varying models bit-match a session built
//!   from scratch with the same final programs and times (the horizon
//!   invalidation rule's exactness), including under the Priority
//!   policy.
//! * **TOML plumbing** — `configs/edge16_loaded.toml` builds the
//!   congestion+DVFS model and prices through it end to end.

use archytas::accel::{Compute, Precision};
use archytas::compiler::lowering::lower;
use archytas::compiler::mapper::{map_graph, MapStrategy};
use archytas::compiler::{FabricProgram, Step};
use archytas::coordinator::{
    cosim, cosim_ref, cosim_ref_with, cosim_with, AdmitMeta, AdmitPolicy, CosimSession, ExecReport,
};
use std::sync::Arc;

use archytas::fabric::{
    CongestionKnobs, CostModel, DvfsKnobs, Fabric, InvariantCost, KindCost, KindKnobs, VaryingCost,
};
use archytas::sim::{Cycle, Rng};
use archytas::testutil::{bundled_fabric, prop};
use archytas::workloads;

const CONFIGS: [&str; 2] = ["edge16.toml", "homogeneous_npu.toml"];
const STRATEGIES: [MapStrategy; 3] =
    [MapStrategy::RoundRobin, MapStrategy::Greedy, MapStrategy::Ilp];

fn workload(name: &str) -> archytas::ir::Graph {
    match name {
        "mlp" => workloads::mlp(4, 64, &[32], 10, 7).unwrap(),
        "vit" => {
            let p = workloads::VitParams {
                batch: 2,
                tokens: 8,
                dim: 32,
                depth: 1,
                mlp_ratio: 2,
                patch_dim: 16,
                classes: 10,
            };
            workloads::vit(&p, 3).unwrap()
        }
        other => panic!("unknown workload {other}"),
    }
}

fn lowered(fabric: &Fabric, wname: &str, strategy: MapStrategy) -> FabricProgram {
    let g = workload(wname);
    let m = map_graph(&g, fabric, strategy, Precision::Int8).unwrap();
    lower(&g, fabric, &m).unwrap()
}

/// The time-varying model shapes, on a deliberately short epoch so the
/// small test workloads cross many epoch boundaries. The kind-aware
/// model joins the sweep with default knobs: its occupancy feedback
/// (photonic warm-up, crossbar wear, PIM contention) obeys the same
/// strictly-earlier-epoch contract, so every engine/incremental golden
/// below must hold for it verbatim.
fn varying_models() -> Vec<(&'static str, Arc<dyn CostModel>)> {
    let cong = CongestionKnobs { alpha: 0.5, cap: 4.0 };
    let dvfs = DvfsKnobs {
        window: 3,
        warm_frac: 0.4,
        hot_frac: 0.8,
        warm_scale: 0.75,
        hot_scale: 0.5,
    };
    vec![
        ("congestion", Arc::new(VaryingCost::congestion(256, cong))),
        ("dvfs", Arc::new(VaryingCost::dvfs(256, dvfs))),
        ("congestion_dvfs", Arc::new(VaryingCost::congestion_dvfs(256, cong, dvfs))),
        ("kind", Arc::new(KindCost::new(256, KindKnobs::default()))),
    ]
}

fn assert_identical(a: &ExecReport, b: &ExecReport, tag: &str) {
    assert_eq!(a.cycles, b.cycles, "{tag}: makespan");
    assert_eq!(a.step_done, b.step_done, "{tag}: step_done");
    assert_eq!(a.tile_busy, b.tile_busy, "{tag}: tile_busy");
    assert_eq!(
        a.metrics.total_energy_pj().to_bits(),
        b.metrics.total_energy_pj().to_bits(),
        "{tag}: energy bits"
    );
    assert!(a.bit_identical(b), "{tag}: bit_identical contract");
}

/// (a) Invariant bit-parity: the explicit-model entry points and the
/// session must all match the default paths bit for bit across the full
/// golden matrix — the refactor moved the pricing seam without moving a
/// single bit.
#[test]
fn invariant_model_bit_parity_across_matrix() {
    let model = InvariantCost;
    for cfg in CONFIGS {
        let fabric = bundled_fabric(cfg);
        assert_eq!(fabric.cost_model().name(), "invariant", "{cfg}: default model");
        for wname in ["mlp", "vit"] {
            for strategy in STRATEGIES {
                let tag = format!("{cfg}/{wname}/{strategy:?}");
                let prog = lowered(&fabric, wname, strategy);
                let base = cosim(&fabric, &prog).unwrap();
                assert_identical(
                    &cosim_with(&fabric, &prog, &model).unwrap(),
                    &base,
                    &format!("{tag}: cosim_with(invariant)"),
                );
                assert_identical(
                    &cosim_ref(&fabric, &prog).unwrap(),
                    &base,
                    &format!("{tag}: cosim_ref"),
                );
                assert_identical(
                    &cosim_ref_with(&fabric, &prog, &model).unwrap(),
                    &base,
                    &format!("{tag}: cosim_ref_with(invariant)"),
                );
                let mut s = CosimSession::with_model(&fabric, std::sync::Arc::new(InvariantCost));
                s.admit_at(&prog, 0).unwrap();
                assert_identical(
                    &s.report().unwrap(),
                    &base,
                    &format!("{tag}: session(invariant)"),
                );
            }
        }
    }
}

/// (b) Cross-engine fixed-point agreement at t=0: three engines with
/// three different iteration strategies must land on identical bits —
/// the unique self-consistent schedule of the strictly-earlier-epoch
/// contract.
#[test]
fn varying_models_agree_across_engines_at_t0() {
    for cfg in CONFIGS {
        let fabric = bundled_fabric(cfg);
        for (wname, strategy) in [("mlp", MapStrategy::Greedy), ("vit", MapStrategy::RoundRobin)] {
            let prog = lowered(&fabric, wname, strategy);
            for (mname, model) in varying_models() {
                let tag = format!("{cfg}/{wname}/{mname}");
                let ev = cosim_with(&fabric, &prog, model.as_ref()).unwrap();
                let re = cosim_ref_with(&fabric, &prog, model.as_ref()).unwrap();
                assert_identical(&ev, &re, &format!("{tag}: event vs iterated-list"));
                let mut s = CosimSession::with_model(&fabric, model.clone());
                s.admit_at(&prog, 0).unwrap();
                let se = s.report().unwrap();
                assert_identical(&se, &ev, &format!("{tag}: session vs event"));
            }
        }
    }
}

/// Sanity: the models actually bite, on schedules built to force it.
/// A serial HBM load chain keeps a transfer resident in every epoch, so
/// congestion must stretch every post-epoch-0 load; a serial exec chain
/// keeps its tile ~100% busy, so DVFS must throttle it. Ops/bytes stay
/// schedule-invariant — only time moves.
#[test]
fn varying_models_actually_change_schedules() {
    let fabric = bundled_fabric("edge16.toml");
    // 10 back-to-back 64 KiB loads: each takes >100 cycles (HBM latency
    // floor), so with a 128-cycle epoch some load is resident in every
    // epoch of the chain.
    let load_chain = FabricProgram {
        steps: (0..10)
            .map(|i| Step::Load {
                tile: 0,
                bytes: 64 * 1024,
                node: 0,
                deps: if i == 0 { vec![] } else { vec![i - 1] },
            })
            .collect(),
        producer: Vec::new(),
    };
    // 10 back-to-back matmuls on tile 0: >=300 control cycles each, so
    // the tile busy fraction saturates the DVFS window.
    let exec_chain = FabricProgram {
        steps: (0..10)
            .map(|i| Step::Exec {
                tile: 0,
                node: 0,
                compute: Compute::MatMul { m: 16, k: 64, n: 32 },
                precision: Precision::Int8,
                deps: if i == 0 { vec![] } else { vec![i - 1] },
            })
            .collect(),
        producer: Vec::new(),
    };
    let run = |prog: &FabricProgram, model: Option<VaryingCost>| {
        let mut s = match model {
            Some(m) => CosimSession::with_model(&fabric, std::sync::Arc::new(m)),
            None => CosimSession::new(&fabric),
        };
        s.admit_at(prog, 0).unwrap();
        s.report().unwrap()
    };
    let base_load = run(&load_chain, None);
    let congested = run(
        &load_chain,
        Some(VaryingCost::congestion(128, CongestionKnobs { alpha: 1.0, cap: 8.0 })),
    );
    assert!(
        congested.cycles > base_load.cycles,
        "congestion must stretch the load chain: {} vs {}",
        congested.cycles,
        base_load.cycles
    );
    let base_exec = run(&exec_chain, None);
    let throttled = run(
        &exec_chain,
        Some(VaryingCost::dvfs(
            128,
            DvfsKnobs { window: 4, warm_frac: 0.2, hot_frac: 0.5, warm_scale: 0.8, hot_scale: 0.4 },
        )),
    );
    assert!(
        throttled.cycles > base_exec.cycles,
        "DVFS must throttle the hot tile: {} vs {}",
        throttled.cycles,
        base_exec.cycles
    );
    // Ops/bytes are schedule-invariant; only time moved.
    assert_eq!(congested.metrics.ops, base_load.metrics.ops);
    assert_eq!(congested.metrics.bytes_moved, base_load.metrics.bytes_moved);
    assert_eq!(throttled.metrics.ops, base_exec.metrics.ops);
    assert_eq!(throttled.metrics.bytes_moved, base_exec.metrics.bytes_moved);
}

/// Random synthetic DAG program over `nt` tiles (forward deps only),
/// mirroring the admission property generator.
fn random_program(rng: &mut Rng, nt: usize) -> FabricProgram {
    let n = rng.below(12) + 1;
    let mut steps = Vec::new();
    for i in 0..n {
        let mut deps: Vec<usize> = Vec::new();
        if i > 0 {
            for _ in 0..rng.below(3) {
                deps.push(rng.below(i));
            }
        }
        let step = match rng.below(3) {
            0 => Step::Load {
                tile: rng.below(nt),
                bytes: (rng.below(4000) + 1) as u64,
                node: 0,
                deps,
            },
            1 => Step::Transfer {
                from: rng.below(nt),
                to: rng.below(nt),
                bytes: (rng.below(4000) + 1) as u64,
                node: 0,
                deps,
            },
            _ => Step::Exec {
                tile: rng.below(nt),
                node: 0,
                compute: Compute::MatMul {
                    m: rng.below(8) + 1,
                    k: rng.below(8) + 1,
                    n: rng.below(8) + 1,
                },
                precision: Precision::Int8,
                deps,
            },
        };
        steps.push(step);
    }
    FabricProgram { steps, producer: Vec::new() }
}

fn small_fabric() -> Fabric {
    Fabric::build(
        archytas::config::FabricConfig::from_toml(
            "[noc]\nwidth = 3\nheight = 3\n\
             [[cu]]\nkind = \"npu\"\ntemplate = \"B\"\ncount = 4\n",
        )
        .unwrap(),
    )
    .unwrap()
}

/// (c) Random-perturbation property sweep: incremental sessions under
/// every time-varying model — admissions at random times (including the
/// simulated past), replaces and partial drains — must bit-match a
/// from-scratch session with the same final programs and times. This is
/// the horizon-invalidation + settle exactness contract.
#[test]
fn prop_varying_incremental_matches_from_scratch() {
    let fabric = small_fabric();
    let nt = fabric.tile_count();
    for (mname, model) in varying_models() {
        prop::check(15, |rng| {
            let mut inc = CosimSession::with_model(&fabric, model.clone());
            let mut current: Vec<(FabricProgram, Cycle)> = Vec::new();
            let mut handles = Vec::new();
            for _ in 0..rng.below(6) + 1 {
                let roll = rng.below(10);
                if roll < 5 || current.is_empty() {
                    let p = random_program(rng, nt);
                    let at = rng.below(3000) as Cycle;
                    handles.push(inc.admit_at(&p, at).map_err(|e| e.to_string())?);
                    current.push((p, at));
                } else if roll < 7 {
                    let slot = rng.below(current.len());
                    let p = random_program(rng, nt);
                    let at = rng.below(3000) as Cycle;
                    inc.replace(handles[slot], &p, at).map_err(|e| e.to_string())?;
                    current[slot] = (p, at);
                } else if roll < 9 {
                    inc.run_to_drain().map_err(|e| e.to_string())?;
                } else {
                    inc.run_until(rng.below(4000) as Cycle).map_err(|e| e.to_string())?;
                }
            }
            let got = inc.report().map_err(|e| e.to_string())?;
            let mut fresh = CosimSession::with_model(&fabric, model.clone());
            for (p, at) in &current {
                fresh.admit_at(p, *at).map_err(|e| e.to_string())?;
            }
            let want = fresh.report().map_err(|e| e.to_string())?;
            if !got.bit_identical(&want) {
                return Err(format!(
                    "{mname}: incremental diverged: cycles {} vs {}, steps {:?} vs {:?}",
                    got.cycles, want.cycles, got.step_done, want.step_done
                ));
            }
            Ok(())
        });
    }
}

/// The same exactness under a non-FIFO queue key: Priority policy with
/// random priorities, time-varying pricing, incremental vs from-scratch.
#[test]
fn prop_priority_policy_varying_matches_from_scratch() {
    let fabric = small_fabric();
    let nt = fabric.tile_count();
    let model = VaryingCost::congestion(256, CongestionKnobs { alpha: 0.5, cap: 4.0 });
    prop::check(10, |rng| {
        let mut inc = CosimSession::with_model(&fabric, std::sync::Arc::new(model));
        inc.set_policy(AdmitPolicy::Priority).map_err(|e| e.to_string())?;
        let mut current: Vec<(FabricProgram, Cycle, AdmitMeta)> = Vec::new();
        for _ in 0..rng.below(5) + 1 {
            let p = random_program(rng, nt);
            let at = rng.below(2000) as Cycle;
            let meta = AdmitMeta { priority: rng.below(4) as u32, ..Default::default() };
            inc.admit_with(&p, at, meta).map_err(|e| e.to_string())?;
            if rng.below(2) == 0 {
                inc.run_until(rng.below(3000) as Cycle).map_err(|e| e.to_string())?;
            }
            current.push((p, at, meta));
        }
        let got = inc.report().map_err(|e| e.to_string())?;
        let mut fresh = CosimSession::with_model(&fabric, std::sync::Arc::new(model));
        fresh.set_policy(AdmitPolicy::Priority).map_err(|e| e.to_string())?;
        for (p, at, meta) in &current {
            fresh.admit_with(p, *at, *meta).map_err(|e| e.to_string())?;
        }
        let want = fresh.report().map_err(|e| e.to_string())?;
        if !got.bit_identical(&want) {
            return Err(format!(
                "priority+varying diverged: cycles {} vs {}",
                got.cycles, want.cycles
            ));
        }
        Ok(())
    });
}

/// (d) TOML plumbing: the bundled loaded config builds the combined
/// model, `cosim` prices through it implicitly, and the knobs round-trip
/// (an explicitly constructed model with the same knobs reproduces the
/// bits).
#[test]
fn loaded_config_prices_through_the_configured_model() {
    let fabric = bundled_fabric("edge16_loaded.toml");
    assert_eq!(fabric.cost_model().name(), "congestion_dvfs");
    let prog = lowered(&fabric, "vit", MapStrategy::Greedy);
    let implicit = cosim(&fabric, &prog).unwrap();
    let explicit = VaryingCost::congestion_dvfs(
        512,
        CongestionKnobs { alpha: 0.5, cap: 4.0 },
        DvfsKnobs { window: 4, warm_frac: 0.5, hot_frac: 0.85, warm_scale: 0.75, hot_scale: 0.5 },
    );
    assert_identical(
        &cosim_with(&fabric, &prog, &explicit).unwrap(),
        &implicit,
        "edge16_loaded: TOML knobs vs explicit model",
    );
    // And the invariant floor is never slower than the loaded pricing.
    let floor = cosim_with(&fabric, &prog, &InvariantCost).unwrap();
    assert!(implicit.cycles >= floor.cycles);
    // The session default follows the fabric config too.
    let mut s = CosimSession::new(&fabric);
    assert_eq!(s.cost_model().name(), "congestion_dvfs");
    s.admit_at(&prog, 0).unwrap();
    assert_identical(&s.report().unwrap(), &implicit, "edge16_loaded: session default model");
}
