//! Differential golden tests for the fault-injection / graceful-
//! degradation layer (`sim::fault` + `coordinator::FaultySession`).
//!
//! Contracts pinned here:
//!
//! * **Empty-plan bit-identity** — a `FaultySession` carrying an empty
//!   [`FaultPlan`] is the fault-free stack bit for bit, across the
//!   golden mlp/vit × strategy × config matrix and under the
//!   time-varying cost models — and it does not even wrap the cost
//!   model (`Arc::ptr_eq` with the fabric's configured model).
//! * **Incremental ≡ from-scratch** — replaying a fault trace
//!   incrementally (admissions and `run_until` pauses interleaved with
//!   the events) bit-matches a from-scratch session admitting the same
//!   programs up front: `ExecReport::bit_identical` plus full
//!   [`DegradationReport`] and per-request [`RequestOutcome`] equality,
//!   deterministically and under every recovery policy.
//! * **Plan determinism** — [`FaultPlan::generate`] is a pure function
//!   of (config, tile kinds); recording and replaying through
//!   [`FaultPlan::from_events`] is the identity; events come out in
//!   canonical `(time, rank, resource)` order and respect the per-kind
//!   tile gating (drift only on crossbars, thermal only on photonics).
//! * **TOML plumbing** — a `[fault]` section parses into
//!   `FabricConfig::fault` and seeds `FaultySession::new`; an absent
//!   section is inert and keeps the exact fault-free code path.

use std::sync::Arc;

use archytas::accel::{Compute, Precision};
use archytas::compiler::lowering::lower;
use archytas::compiler::mapper::{map_graph, MapStrategy};
use archytas::compiler::{FabricProgram, Step};
use archytas::config::FabricConfig;
use archytas::coordinator::{
    cosim, AdmitMeta, CosimSession, ExecReport, FaultySession, RecoveryPolicy,
};
use archytas::fabric::Fabric;
use archytas::prop_assert;
use archytas::sim::{Cycle, FaultConfig, FaultEvent, FaultKind, FaultPlan, Rng};
use archytas::testutil::{bundled_fabric, prop};
use archytas::workloads;

const CONFIGS: [&str; 2] = ["edge16.toml", "homogeneous_npu.toml"];
const STRATEGIES: [MapStrategy; 3] =
    [MapStrategy::RoundRobin, MapStrategy::Greedy, MapStrategy::Ilp];
const POLICIES: [RecoveryPolicy; 4] = [
    RecoveryPolicy::Retry,
    RecoveryPolicy::Remap,
    RecoveryPolicy::DeadlineAware,
    RecoveryPolicy::Shed,
];

fn workload(name: &str) -> archytas::ir::Graph {
    match name {
        "mlp" => workloads::mlp(4, 64, &[32], 10, 7).unwrap(),
        "vit" => {
            let p = workloads::VitParams {
                batch: 2,
                tokens: 8,
                dim: 32,
                depth: 1,
                mlp_ratio: 2,
                patch_dim: 16,
                classes: 10,
            };
            workloads::vit(&p, 3).unwrap()
        }
        other => panic!("unknown workload {other}"),
    }
}

fn lowered(fabric: &Fabric, wname: &str, strategy: MapStrategy) -> FabricProgram {
    let g = workload(wname);
    let m = map_graph(&g, fabric, strategy, Precision::Int8).unwrap();
    lower(&g, fabric, &m).unwrap()
}

fn assert_identical(a: &ExecReport, b: &ExecReport, tag: &str) {
    assert_eq!(a.cycles, b.cycles, "{tag}: makespan");
    assert_eq!(a.step_done, b.step_done, "{tag}: step_done");
    assert_eq!(a.tile_busy, b.tile_busy, "{tag}: tile_busy");
    assert!(a.bit_identical(b), "{tag}: bit_identical contract");
}

/// Tile executing the program's final Exec step — work that is
/// certainly still uncompleted halfway through a solo episode.
fn last_exec_tile(prog: &FabricProgram) -> usize {
    prog.steps
        .iter()
        .rev()
        .find_map(|s| match s {
            Step::Exec { tile, .. } => Some(*tile),
            _ => None,
        })
        .expect("lowered program has an Exec step")
}

/// (a) Empty-plan bit-identity across the full golden matrix: the
/// fault layer threaded through admission must not move a single bit
/// when no faults are planned — and must not even wrap the cost model.
#[test]
fn empty_plan_is_bitwise_fault_free_across_the_matrix() {
    let cfg = FaultConfig::default();
    assert!(cfg.is_inert(), "the default fault config must be inert");
    for cname in CONFIGS {
        let fabric = bundled_fabric(cname);
        for wname in ["mlp", "vit"] {
            for strategy in STRATEGIES {
                let tag = format!("{cname}/{wname}/{strategy:?}");
                let prog = lowered(&fabric, wname, strategy);
                let base = cosim(&fabric, &prog).unwrap();
                let mut fs =
                    FaultySession::with_plan(&fabric, FaultPlan::empty(), &cfg, RecoveryPolicy::Retry)
                        .unwrap();
                assert!(
                    Arc::ptr_eq(fs.cost_model(), fabric.cost_model()),
                    "{tag}: empty plan must not wrap the cost model"
                );
                let h = fs.admit_at(&prog, 0).unwrap();
                let got = fs.report().unwrap();
                assert_identical(&got, &base, &tag);
                let deg = fs.degradation(&got);
                assert_eq!(
                    (deg.programs, deg.completed, deg.shed, deg.faults_injected),
                    (1, 1, 0, 0),
                    "{tag}: degradation counters"
                );
                assert_eq!(deg.availability, 1.0, "{tag}");
                assert_eq!(fs.outcome(h), Default::default(), "{tag}: outcome");
            }
        }
    }
}

/// With no faults the recovery policy is dead code: every policy
/// produces the same bits as the fault-free run.
#[test]
fn empty_plan_is_recovery_policy_invariant() {
    let fabric = bundled_fabric("edge16.toml");
    let prog = lowered(&fabric, "mlp", MapStrategy::Greedy);
    let base = cosim(&fabric, &prog).unwrap();
    for policy in POLICIES {
        let mut fs =
            FaultySession::with_plan(&fabric, FaultPlan::empty(), &FaultConfig::default(), policy)
                .unwrap();
        fs.admit_at(&prog, 0).unwrap();
        assert_identical(&fs.report().unwrap(), &base, &format!("{policy:?}"));
    }
}

/// Empty-plan bit-identity under the time-varying configured model
/// (`edge16_loaded.toml` → congestion+DVFS), with staggered admissions
/// and a mid-episode pause on both sides.
#[test]
fn empty_plan_is_noop_under_time_varying_models() {
    let fabric = bundled_fabric("edge16_loaded.toml");
    assert_eq!(fabric.cost_model().name(), "congestion_dvfs");
    let mlp = lowered(&fabric, "mlp", MapStrategy::Greedy);
    let vit = lowered(&fabric, "vit", MapStrategy::RoundRobin);
    let mut plain = CosimSession::new(&fabric);
    plain.admit_at(&mlp, 0).unwrap();
    plain.admit_at(&vit, 777).unwrap();
    plain.run_until(1_500).unwrap();
    plain.admit_at(&mlp, 3_000).unwrap();
    let want = plain.report().unwrap();
    let mut faulty = FaultySession::with_plan(
        &fabric,
        FaultPlan::empty(),
        &FaultConfig::default(),
        RecoveryPolicy::DeadlineAware,
    )
    .unwrap();
    assert!(Arc::ptr_eq(faulty.cost_model(), fabric.cost_model()));
    faulty.admit_at(&mlp, 0).unwrap();
    faulty.admit_at(&vit, 777).unwrap();
    faulty.run_until(1_500).unwrap();
    faulty.admit_at(&mlp, 3_000).unwrap();
    let got = faulty.report().unwrap();
    assert_identical(&got, &want, "edge16_loaded/varying");
    let deg = faulty.degradation(&got);
    assert_eq!((deg.programs, deg.completed, deg.faults_injected), (3, 3, 0));
}

/// The cost-model wrapping rule: purely-transient plans price nothing
/// and keep the base model's very `Arc`; any other kind (a death needs
/// quarantine pricing) swaps in the degraded wrapper.
#[test]
fn only_pricing_relevant_plans_wrap_the_cost_model() {
    let fabric = bundled_fabric("edge16.toml");
    let cfg = FaultConfig::default();
    let transients = FaultPlan::from_events(vec![
        FaultEvent { at: 10, kind: FaultKind::TileTransient { tile: 0 } },
        FaultEvent { at: 500, kind: FaultKind::TileTransient { tile: 3 } },
    ]);
    assert!(transients.is_pricing_inert());
    let s = FaultySession::with_plan(&fabric, transients, &cfg, RecoveryPolicy::Retry).unwrap();
    assert!(Arc::ptr_eq(s.cost_model(), fabric.cost_model()));
    let death = FaultPlan::from_events(vec![FaultEvent {
        at: 100,
        kind: FaultKind::TileDeath { tile: 0 },
    }]);
    let s = FaultySession::with_plan(&fabric, death, &cfg, RecoveryPolicy::Retry).unwrap();
    assert!(!Arc::ptr_eq(s.cost_model(), fabric.cost_model()));
    assert_eq!(s.cost_model().name(), "degraded");
    // Out-of-fabric tile indices are rejected up front.
    let bogus = FaultPlan::from_events(vec![FaultEvent {
        at: 1,
        kind: FaultKind::TileDeath { tile: fabric.tile_count() },
    }]);
    assert!(FaultySession::with_plan(&fabric, bogus, &cfg, RecoveryPolicy::Retry).is_err());
}

/// (c) Plan generation: deterministic in (config, kinds), seed-
/// sensitive, canonically ordered, kind-gated, record/replay-closed.
#[test]
fn generated_plans_are_deterministic_seeded_and_gated() {
    let fabric = bundled_fabric("edge16.toml");
    let kinds: Vec<&str> = fabric.tiles.iter().map(|t| t.accel.name()).collect();
    let cfg = FaultConfig {
        seed: 42,
        horizon: 1 << 16,
        window: 1024,
        p_transient: 0.02,
        p_death: 0.005,
        p_link_degrade: 0.01,
        p_link_fail: 0.004,
        p_hbm_brownout: 0.01,
        p_crossbar_drift: 0.05,
        p_photonic_thermal: 0.05,
        ..FaultConfig::default()
    };
    let a = FaultPlan::generate(&cfg, &kinds);
    assert!(!a.is_empty(), "premise: these rates over this horizon draw events");
    // Pure function of (config, kinds).
    assert_eq!(a, FaultPlan::generate(&cfg, &kinds));
    // Seed sensitivity.
    let b = FaultPlan::generate(&FaultConfig { seed: 43, ..cfg.clone() }, &kinds);
    assert_ne!(a, b, "seed must steer the draw stream");
    // Canonical (time, rank, resource) order — the replay order.
    for w in a.events().windows(2) {
        let key = |e: &FaultEvent| (e.at, e.kind.rank(), e.kind.resource());
        assert!(key(&w[0]) <= key(&w[1]), "events out of canonical order: {w:?}");
    }
    // Recording and replaying is the identity.
    assert_eq!(FaultPlan::from_events(a.events().to_vec()), a);
    // Bounds and per-kind tile gating.
    for ev in a.events() {
        assert!(ev.at < cfg.horizon, "{ev:?} beyond the horizon");
        match ev.kind {
            FaultKind::CrossbarDrift { tile, .. } => {
                assert_eq!(kinds[tile], "nvm-crossbar", "drift gated to crossbars: {ev:?}")
            }
            FaultKind::PhotonicThermal { tile, .. } => {
                assert_eq!(kinds[tile], "photonic", "thermal gated to photonics: {ev:?}")
            }
            FaultKind::TileTransient { tile } | FaultKind::TileDeath { tile } => {
                assert!(tile < kinds.len())
            }
            FaultKind::LinkDegrade { from, to, .. } | FaultKind::LinkFail { from, to, .. } => {
                assert!(from < kinds.len() && to < kinds.len() && from != to)
            }
            FaultKind::HbmBrownout { .. } => {}
        }
    }
    // edge16 has no photonic tiles, so the gate means zero thermal events.
    assert!(kinds.iter().all(|&k| k != "photonic"));
    assert!(
        a.events()
            .iter()
            .all(|e| !matches!(e.kind, FaultKind::PhotonicThermal { .. })),
        "thermal events on a photonic-free fabric"
    );
    // The inert default generates nothing.
    assert!(FaultPlan::generate(&FaultConfig::default(), &kinds).is_empty());
}

/// (b) Incremental ≡ from-scratch at golden scale: a mixed trace
/// (transient + death + HBM brownout + link degrade) over lowered
/// mlp/vit programs on the heterogeneous fabric. The incremental
/// session pauses twice mid-episode and admits the second program after
/// every event is processed; the oracle admits everything up front.
/// Reports, degradation telemetry and per-request outcomes must agree
/// bit for bit under every recovery policy.
#[test]
fn seeded_trace_incremental_matches_from_scratch() {
    let fabric = bundled_fabric("edge16.toml");
    let mlp = lowered(&fabric, "mlp", MapStrategy::Greedy);
    let vit = lowered(&fabric, "vit", MapStrategy::Greedy);
    let solo = cosim(&fabric, &mlp).unwrap();
    let mid = solo.cycles / 2;
    let victim = last_exec_tile(&mlp);
    let plan = FaultPlan::from_events(vec![
        FaultEvent { at: 5, kind: FaultKind::TileTransient { tile: victim } },
        FaultEvent { at: 10, kind: FaultKind::LinkDegrade { from: 0, to: 1, factor: 2.0, duration: mid } },
        FaultEvent { at: mid / 2, kind: FaultKind::HbmBrownout { factor: 1.5, duration: mid } },
        FaultEvent { at: mid, kind: FaultKind::TileDeath { tile: victim } },
    ]);
    let cfg = FaultConfig::default();
    let late = solo.cycles * 2;
    for policy in POLICIES {
        let tag = format!("{policy:?}");
        let mut oracle = FaultySession::with_plan(&fabric, plan.clone(), &cfg, policy).unwrap();
        let o1 = oracle.admit_at(&mlp, 0).unwrap();
        let o2 = oracle.admit_at(&vit, late).unwrap();
        let want = oracle.report().unwrap();
        let want_deg = oracle.degradation(&want);

        let mut inc = FaultySession::with_plan(&fabric, plan.clone(), &cfg, policy).unwrap();
        let h1 = inc.admit_at(&mlp, 0).unwrap();
        inc.run_until(mid / 4).unwrap();
        inc.run_until(mid + 1).unwrap();
        let h2 = inc.admit_at(&vit, late).unwrap();
        inc.run_until(late + 10).unwrap();
        let got = inc.report().unwrap();
        let got_deg = inc.degradation(&got);

        assert_identical(&got, &want, &tag);
        assert_eq!(got_deg, want_deg, "{tag}: degradation telemetry diverged");
        assert_eq!(inc.outcome(h1), oracle.outcome(o1), "{tag}: outcome 1");
        assert_eq!(inc.outcome(h2), oracle.outcome(o2), "{tag}: outcome 2");
        // The trace must actually bite: the death lands mid-flight.
        assert!(got_deg.faults_effective >= 1, "{tag}: trace was fully masked");
        if policy == RecoveryPolicy::Shed {
            assert!(got_deg.shed >= 1, "{tag}: shed policy must shed the afflicted request");
        }
        if policy == RecoveryPolicy::Retry {
            // Both events before `late` were processed live; all four
            // plan events (2 behavioral + 2 pricing) were injected.
            assert_eq!(got_deg.faults_injected, 4, "{tag}");
            assert_eq!(got_deg.pricing_events, 2, "{tag}");
            // Remapped off the dead tile, nothing shed.
            assert_eq!((got_deg.shed, got_deg.availability), (0, 1.0), "{tag}");
            assert!(inc.outcome(h1).remapped, "{tag}");
        }
    }
}

/// Random synthetic DAG program over `nt` tiles (forward deps only) —
/// the admission property generator's shape.
fn random_program(rng: &mut Rng, nt: usize) -> FabricProgram {
    let n = rng.below(12) + 1;
    let mut steps = Vec::new();
    for i in 0..n {
        let mut deps: Vec<usize> = Vec::new();
        if i > 0 {
            for _ in 0..rng.below(3) {
                deps.push(rng.below(i));
            }
        }
        let step = match rng.below(3) {
            0 => Step::Load {
                tile: rng.below(nt),
                bytes: (rng.below(4000) + 1) as u64,
                node: 0,
                deps,
            },
            1 => Step::Transfer {
                from: rng.below(nt),
                to: rng.below(nt),
                bytes: (rng.below(4000) + 1) as u64,
                node: 0,
                deps,
            },
            _ => Step::Exec {
                tile: rng.below(nt),
                node: 0,
                compute: Compute::MatMul {
                    m: rng.below(8) + 1,
                    k: rng.below(8) + 1,
                    n: rng.below(8) + 1,
                },
                precision: Precision::Int8,
                deps,
            },
        };
        steps.push(step);
    }
    FabricProgram { steps, producer: Vec::new() }
}

fn small_fabric() -> Fabric {
    Fabric::build(
        FabricConfig::from_toml(
            "[noc]\nwidth = 3\nheight = 3\n\
             [[cu]]\nkind = \"npu\"\ntemplate = \"B\"\ncount = 4\n",
        )
        .unwrap(),
    )
    .unwrap()
}

/// (b') Random-perturbation property sweep: random programs, random
/// fault traces, random recovery policy and random `run_until` pause
/// granularity — the incremental session must bit-match the pause-free
/// from-scratch oracle, including the degradation telemetry (the lazy
/// event rule is path-independent).
#[test]
fn prop_faulty_incremental_matches_from_scratch() {
    let fabric = small_fabric();
    let nt = fabric.tile_count();
    prop::check(15, |rng| {
        let mut events = Vec::new();
        for _ in 0..rng.below(5) {
            let at = (rng.below(4000) + 1) as Cycle;
            let kind = match rng.below(4) {
                // Deaths spare tiles nt-2.. so a same-kind re-map target
                // always exists (shed-for-lack-of-silicon is covered
                // deterministically elsewhere).
                0 => FaultKind::TileDeath { tile: rng.below(nt - 2) },
                1 => FaultKind::TileTransient { tile: rng.below(nt) },
                2 => FaultKind::HbmBrownout { factor: 1.5, duration: 2_000 },
                _ => {
                    let from = rng.below(nt);
                    FaultKind::LinkDegrade {
                        from,
                        to: (from + 1 + rng.below(nt - 1)) % nt,
                        factor: 2.0,
                        duration: 1_500,
                    }
                }
            };
            events.push(FaultEvent { at, kind });
        }
        let plan = FaultPlan::from_events(events);
        let policy = POLICIES[rng.below(POLICIES.len())];
        let cfg = FaultConfig::default();
        let mut admissions = Vec::new();
        for _ in 0..rng.below(4) + 1 {
            let p = random_program(rng, nt);
            let at = rng.below(3000) as Cycle;
            let deadline = if rng.below(3) == 0 {
                2_000 + rng.below(20_000) as Cycle
            } else {
                Cycle::MAX
            };
            admissions.push((p, at, AdmitMeta { priority: 0, deadline }));
        }
        let mut inc =
            FaultySession::with_plan(&fabric, plan.clone(), &cfg, policy).map_err(|e| e.to_string())?;
        let mut handles = Vec::new();
        for (p, at, meta) in &admissions {
            handles.push(inc.admit_with(p, *at, *meta).map_err(|e| e.to_string())?);
        }
        for _ in 0..rng.below(4) {
            inc.run_until(rng.below(6000) as Cycle).map_err(|e| e.to_string())?;
        }
        let got = inc.report().map_err(|e| e.to_string())?;
        let got_deg = inc.degradation(&got);
        let mut fresh =
            FaultySession::with_plan(&fabric, plan, &cfg, policy).map_err(|e| e.to_string())?;
        let mut oracle_handles = Vec::new();
        for (p, at, meta) in &admissions {
            oracle_handles.push(fresh.admit_with(p, *at, *meta).map_err(|e| e.to_string())?);
        }
        let want = fresh.report().map_err(|e| e.to_string())?;
        let want_deg = fresh.degradation(&want);
        prop_assert!(
            got.bit_identical(&want),
            "{policy:?}: incremental diverged: cycles {} vs {}, steps {:?} vs {:?}",
            got.cycles,
            want.cycles,
            got.step_done,
            want.step_done
        );
        prop_assert!(
            got_deg == want_deg,
            "{policy:?}: degradation diverged: {got_deg:?} vs {want_deg:?}"
        );
        for (h, o) in handles.iter().zip(&oracle_handles) {
            prop_assert!(
                inc.outcome(*h) == fresh.outcome(*o),
                "{policy:?}: outcome diverged: {:?} vs {:?}",
                inc.outcome(*h),
                fresh.outcome(*o)
            );
        }
        Ok(())
    });
}

/// (d) TOML plumbing: a `[fault]` section reaches `FabricConfig::fault`,
/// seeds a deterministic plan through `FaultySession::new`, and the
/// absent-section default stays on the exact fault-free path.
#[test]
fn fault_section_plumbs_from_toml() {
    let cfg = FabricConfig::from_toml(
        "[noc]\nwidth = 3\nheight = 3\n\
         [[cu]]\nkind = \"npu\"\ntemplate = \"B\"\ncount = 4\n\
         [fault]\n\
         seed = 9\n\
         horizon_cycles = 65536\n\
         window_cycles = 512\n\
         p_transient = 0.05\n\
         p_death = 0.01\n\
         detect_cycles = 24\n\
         max_retries = 3\n\
         backoff_base = 64\n",
    )
    .unwrap();
    assert!(!cfg.fault.is_inert());
    assert_eq!((cfg.fault.seed, cfg.fault.window), (9, 512));
    assert_eq!((cfg.fault.detect_cycles, cfg.fault.max_retries, cfg.fault.backoff_base), (24, 3, 64));
    let fabric = Fabric::build(cfg).unwrap();
    let mut s = FaultySession::new(&fabric, &fabric.cfg.fault, RecoveryPolicy::Retry).unwrap();
    assert!(!s.plan().is_empty(), "a seeded section must generate a plan");
    // Pure function of the config: a second session sees the same plan.
    let s2 = FaultySession::new(&fabric, &fabric.cfg.fault, RecoveryPolicy::Retry).unwrap();
    assert_eq!(s.plan(), s2.plan());
    // The seeded session serves an episode without violating the
    // degradation-accounting invariants.
    let prog = FabricProgram {
        steps: vec![Step::Exec {
            tile: 0,
            node: 0,
            compute: Compute::MatMul { m: 64, k: 64, n: 64 },
            precision: Precision::Int8,
            deps: Vec::new(),
        }],
        producer: Vec::new(),
    };
    s.admit_at(&prog, 0).unwrap();
    let rep = s.report().unwrap();
    let deg = s.degradation(&rep);
    assert_eq!(deg.completed + deg.shed, deg.programs);
    assert_eq!(deg.faults_masked + deg.faults_effective + deg.pricing_events, deg.faults_injected);
    // Absent section: inert config, empty plan, unwrapped model.
    let inert = FabricConfig::from_toml(
        "[noc]\nwidth = 3\nheight = 3\n[[cu]]\nkind = \"npu\"\ntemplate = \"B\"\ncount = 4\n",
    )
    .unwrap();
    assert!(inert.fault.is_inert());
    let f2 = Fabric::build(inert).unwrap();
    let s3 = FaultySession::new(&f2, &f2.cfg.fault, RecoveryPolicy::Retry).unwrap();
    assert!(s3.plan().is_empty());
    assert!(Arc::ptr_eq(s3.cost_model(), f2.cost_model()));
}

/// Threads-sweep leg for the fault layer: a FaultySession replay at
/// threads ∈ {2, 4, 8} must bit-match the sequential session — the
/// `ExecReport`, the `DegradationReport` and every per-request outcome.
/// Faults exercise the parallel drain's retraction path too: recovery
/// invalidates and re-prices mid-calendar, and that work drains through
/// the same staged shards.
#[test]
fn prop_faulty_replay_is_thread_count_invariant() {
    let fabric = small_fabric();
    let nt = fabric.tile_count();
    prop::check(8, |rng| {
        let mut events = Vec::new();
        for _ in 0..rng.below(5) {
            let at = (rng.below(4000) + 1) as Cycle;
            let kind = match rng.below(4) {
                0 => FaultKind::TileDeath { tile: rng.below(nt - 2) },
                1 => FaultKind::TileTransient { tile: rng.below(nt) },
                2 => FaultKind::HbmBrownout { factor: 1.5, duration: 2_000 },
                _ => {
                    let from = rng.below(nt);
                    FaultKind::LinkDegrade {
                        from,
                        to: (from + 1 + rng.below(nt - 1)) % nt,
                        factor: 2.0,
                        duration: 1_500,
                    }
                }
            };
            events.push(FaultEvent { at, kind });
        }
        let plan = FaultPlan::from_events(events);
        let policy = POLICIES[rng.below(POLICIES.len())];
        let cfg = FaultConfig::default();
        let mut admissions = Vec::new();
        for _ in 0..rng.below(3) + 1 {
            let p = random_program(rng, nt);
            let at = rng.below(3000) as Cycle;
            admissions.push((p, at));
        }
        let episode = |threads: usize| -> Result<_, String> {
            let mut s = FaultySession::with_plan(&fabric, plan.clone(), &cfg, policy)
                .map_err(|e| e.to_string())?;
            s.set_threads(threads);
            let mut handles = Vec::new();
            for (p, at) in &admissions {
                handles.push(s.admit_at(p, *at).map_err(|e| e.to_string())?);
            }
            let rep = s.report().map_err(|e| e.to_string())?;
            let deg = s.degradation(&rep);
            let outs: Vec<_> = handles.iter().map(|&h| s.outcome(h)).collect();
            Ok((rep, deg, outs))
        };
        let (want, want_deg, want_outs) = episode(1)?;
        for threads in [2usize, 4, 8] {
            let (got, got_deg, got_outs) = episode(threads)?;
            prop_assert!(
                got.bit_identical(&want),
                "{policy:?}: threads {threads} diverged: cycles {} vs {}",
                got.cycles,
                want.cycles
            );
            prop_assert!(
                got_deg == want_deg,
                "{policy:?}: threads {threads} degradation diverged: {got_deg:?} vs {want_deg:?}"
            );
            prop_assert!(
                got_outs == want_outs,
                "{policy:?}: threads {threads} outcomes diverged"
            );
        }
        Ok(())
    });
}
