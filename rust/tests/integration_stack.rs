//! Cross-module integration tests: compile→map→lower→co-simulate over
//! real fabrics and workloads, CLI round trips, and the functional/timing
//! tie-points of the E8 driver in miniature.

use archytas::accel::Precision;
use archytas::cli::{dispatch, Args};
use archytas::compiler::lowering::lower;
use archytas::compiler::mapper::{map_graph, MapStrategy};
use archytas::config::FabricConfig;
use archytas::coordinator::cosim;
use archytas::fabric::Fabric;
use archytas::workloads;

fn edge16() -> Fabric {
    Fabric::build(
        FabricConfig::from_toml(
            &std::fs::read_to_string(archytas::repo_root().join("configs/edge16.toml"))
                .unwrap(),
        )
        .unwrap(),
    )
    .unwrap()
}

#[test]
fn full_pipeline_all_workloads_all_strategies() {
    let fabric = edge16();
    let graphs = vec![
        workloads::mlp(8, 256, &[128, 64], 10, 0).unwrap(),
        workloads::vit(&workloads::VitParams::default(), 0).unwrap(),
        workloads::cnn_edge(2, 0).unwrap(),
    ];
    for g in &graphs {
        for strategy in [MapStrategy::RoundRobin, MapStrategy::Greedy] {
            for p in [Precision::F32, Precision::Int8, Precision::Analog] {
                let m = map_graph(g, &fabric, strategy, p).unwrap();
                let prog = lower(g, &fabric, &m).unwrap();
                let rep = cosim(&fabric, &prog).unwrap();
                assert!(rep.cycles > 0);
                assert!(rep.metrics.total_energy_pj() > 0.0);
            }
        }
    }
}

#[test]
fn int8_precision_is_cheaper_than_f32_on_fabric() {
    let fabric = edge16();
    let g = workloads::vit(&workloads::VitParams::default(), 1).unwrap();
    let run = |p| {
        let m = map_graph(&g, &fabric, MapStrategy::Greedy, p).unwrap();
        let prog = lower(&g, &fabric, &m).unwrap();
        cosim(&fabric, &prog).unwrap()
    };
    let f32r = run(Precision::F32);
    let i8r = run(Precision::Int8);
    assert!(i8r.cycles < f32r.cycles, "{} vs {}", i8r.cycles, f32r.cycles);
    assert!(i8r.metrics.total_energy_pj() < f32r.metrics.total_energy_pj());
}

#[test]
fn greedy_beats_round_robin_on_hetero_fabric() {
    let fabric = edge16();
    let g = workloads::vit(&workloads::VitParams::default(), 2).unwrap();
    let run = |s| {
        let m = map_graph(&g, &fabric, s, Precision::Int8).unwrap();
        let prog = lower(&g, &fabric, &m).unwrap();
        cosim(&fabric, &prog).unwrap().cycles
    };
    assert!(run(MapStrategy::Greedy) <= run(MapStrategy::RoundRobin));
}

#[test]
fn config_round_trip_through_cli_simulate() {
    let path = archytas::repo_root().join("configs/edge16.toml");
    let argv: Vec<String> = [
        "simulate",
        "--fabric",
        path.to_str().unwrap(),
        "--model",
        "mlp",
        "--precision",
        "analog",
        "--strategy",
        "greedy",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    let out = dispatch(&Args::parse(&argv).unwrap()).unwrap();
    assert!(out.contains("edge-16"), "{out}");
    assert!(out.contains("makespan"));
}

#[test]
fn cli_ilp_strategy_works_end_to_end() {
    let argv: Vec<String> =
        ["simulate", "--model", "mlp", "--strategy", "ilp", "--precision", "int8"]
            .iter()
            .map(|s| s.to_string())
            .collect();
    let out = dispatch(&Args::parse(&argv).unwrap()).unwrap();
    assert!(out.contains("Ilp"), "{out}");
}

#[test]
fn homogeneous_config_loads_and_runs() {
    let cfg = FabricConfig::from_toml(
        &std::fs::read_to_string(
            archytas::repo_root().join("configs/homogeneous_npu.toml"),
        )
        .unwrap(),
    )
    .unwrap();
    let fabric = Fabric::build(cfg).unwrap();
    assert_eq!(fabric.tile_count(), 15);
    let g = workloads::mlp(4, 64, &[32], 10, 3).unwrap();
    let m = map_graph(&g, &fabric, MapStrategy::Greedy, Precision::Int8).unwrap();
    let prog = lower(&g, &fabric, &m).unwrap();
    assert!(cosim(&fabric, &prog).unwrap().cycles > 0);
}

/// Functional + timing tie: the PJRT mlp artifact and the IR mlp graph
/// describe the same topology (same layer shapes) — the co-design
/// contract between python/compile/model.py and workloads::mlp.
#[test]
fn l2_and_l3_model_shapes_agree() {
    let rt = match archytas::runtime::Runtime::open_default() {
        Ok(rt) => rt,
        Err(_) => return, // artifacts not built in this environment
    };
    let spec = rt.registry().spec("mlp_digital").unwrap();
    assert_eq!(spec.inputs[0].dims, vec![8, 256]);
    assert_eq!(spec.outputs[0].dims, vec![8, 10]);
    let g = workloads::mlp(8, 256, &[128, 64], 10, 0).unwrap();
    assert_eq!(g.nodes[0].shape, [8, 256]);
    assert_eq!(g.nodes.last().unwrap().shape, [8, 10]);
}
