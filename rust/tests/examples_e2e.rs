//! Tier-1 promotion of the remaining example scenarios (mirroring the
//! UAV-vision promotion): `examples/pim_offload.rs`,
//! `examples/precision_tuning.rs` and `examples/noc_dse.rs` each print a
//! study table and assert its headline claim at the end — those claims
//! are pinned here so `cargo test` exercises them without running the
//! examples. Each scenario also gets the determinism golden the example
//! binaries can't express: a replay reproduces the numbers bit for bit.

use archytas::compiler::precision::{analyze_ranges, tune, Interval, TunerConfig};
use archytas::dram::{DramKind, DramSim, DramTiming, PimCommand, Request};
use archytas::dse::{explore, ExploreConfig, ExploreMethod};
use archytas::ir::interp::Mat;
use archytas::workloads;

/// One footprint of the E3 study: GEMV weights streamed to the core vs
/// in-bank PIM MACs, on one DRAM generation.
fn pim_pair(kind: DramKind, mb: usize) -> (u64, u64, f64, f64) {
    let t = DramTiming::new(kind);
    let bytes = mb * 1024 * 1024;
    let mut fetch = DramSim::new(t);
    for i in 0..(bytes / t.row_bytes) {
        fetch.enqueue(Request::read((i * t.row_bytes) as u64, t.row_bytes));
    }
    let fs = fetch.run_to_drain();
    let mut pim = DramSim::new(t);
    let macs = (bytes / 4) as u64 / t.banks as u64;
    for b in 0..t.banks {
        pim.enqueue(Request::pim((b * t.row_bytes) as u64, PimCommand::BankMac { macs }));
    }
    let ps = pim.run_to_drain();
    (fs.cycles, ps.cycles, fs.metrics.total_energy_pj(), ps.metrics.total_energy_pj())
}

/// E3 (pim_offload): for memory-bound GEMV, in-bank PIM beats
/// fetch-to-core on energy at every footprint and DRAM generation the
/// example sweeps — "bring the computation to the data", pinned — and
/// the JEDEC-timing simulation replays bit for bit.
#[test]
fn pim_offload_beats_weight_streaming_on_energy() {
    for kind in [DramKind::Ddr4_2400, DramKind::Lpddr4_3200, DramKind::Hbm2] {
        for mb in [1usize, 4] {
            let tag = format!("{kind:?}/{mb}MiB");
            let (fc, pc, fe, pe) = pim_pair(kind, mb);
            assert!(fc > 0 && pc > 0, "{tag}: empty run");
            assert!(pe < fe, "{tag}: PIM must win on energy ({pe} vs {fe} pJ)");
            // On the bandwidth-starved commodity part, moving only
            // results instead of the weight matrix also wins time.
            if kind == DramKind::Ddr4_2400 {
                assert!(pc < fc, "{tag}: PIM must win on cycles ({pc} vs {fc})");
            }
            // Determinism: the study replays to identical numbers.
            let (fc2, pc2, fe2, pe2) = pim_pair(kind, mb);
            assert_eq!((fc, pc), (fc2, pc2), "{tag}: cycles replay");
            assert_eq!(
                (fe.to_bits(), pe.to_bits()),
                (fe2.to_bits(), pe2.to_bits()),
                "{tag}: energy replay"
            );
        }
    }
}

/// E6 (precision_tuning): across the example's error-budget sweep, the
/// tuner's fixed-point graphs honour every budget on the measured
/// calibration error, and the tuning replays deterministically.
#[test]
fn precision_tuning_honours_every_error_budget() {
    let g = workloads::mlp(8, 256, &[128, 64], 10, 0).unwrap();
    let shape = g.nodes[0].shape;
    let mut rng = archytas::sim::Rng::new(42);
    let calib = Mat::new(
        shape,
        (0..shape[0] * shape[1]).map(|_| rng.range_f64(-2.0, 2.0) as f32).collect(),
    )
    .unwrap();
    // The hint-driven VRA stage produces a finite range for every node.
    let ranges = analyze_ranges(&g, &[Interval::new(-4.0, 4.0)]).unwrap();
    assert_eq!(ranges.len(), g.len());
    assert!(ranges.iter().all(|r| r.max_abs().is_finite()));
    for budget in [0.001f32, 0.01, 0.05, 0.2] {
        let cfg = TunerConfig {
            input_hints: vec![Interval::new(-4.0, 4.0)],
            error_budget: budget,
            words: vec![8, 16, 32],
        };
        let rep = tune(&g, &calib, &cfg).unwrap();
        assert!(
            rep.measured_rel_err <= budget + 1e-6,
            "budget {budget}: measured error {} blew through",
            rep.measured_rel_err
        );
        // Determinism: the same calibration set tunes to the same graph.
        let again = tune(&g, &calib, &cfg).unwrap();
        assert_eq!(rep.narrowed, again.narrowed, "budget {budget}: narrowed replay");
        assert_eq!(
            rep.measured_rel_err.to_bits(),
            again.measured_rel_err.to_bits(),
            "budget {budget}: error replay"
        );
        assert_eq!(rep.formats, again.formats, "budget {budget}: format replay");
    }
}

/// E4 (noc_dse): the three solver-backed exploration methods agree on
/// the analytic optimum, the simulation-refined method actually
/// simulates, every winner respects the area budget, and the Pareto
/// front is non-empty and replays deterministically.
#[test]
fn noc_dse_methods_agree_and_respect_budgets() {
    for nodes in [16usize, 32] {
        let cfg = ExploreConfig { min_nodes: nodes, max_area: 40.0, ..Default::default() };
        let tag = format!("nodes={nodes}");
        let ex = explore(&cfg, ExploreMethod::Exhaustive).unwrap();
        let best = &ex.candidates[ex.best];
        assert!(best.est_latency > 0.0, "{tag}");
        assert!(best.area <= cfg.max_area, "{tag}: winner over area budget");
        assert!(!ex.front.is_empty(), "{tag}: empty Pareto front");
        assert!(
            ex.front.iter().all(|&i| ex.candidates[i].est_latency > 0.0),
            "{tag}: degenerate front member"
        );
        // The solver methods land on the same analytic optimum.
        for method in [ExploreMethod::Milp, ExploreMethod::Smt] {
            let r = explore(&cfg, method).unwrap();
            assert_eq!(
                r.candidates[r.best].name, best.name,
                "{tag}: {method:?} disagrees with exhaustive"
            );
            assert!(r.solver_evals > 0, "{tag}: {method:?} never called the solver");
        }
        // Simulation-in-the-loop refinement measures its winner.
        let sim = explore(&cfg, ExploreMethod::IterativeSim).unwrap();
        assert!(sim.sim_evals > 0, "{tag}: refinement never simulated");
        assert!(
            sim.candidates[sim.best].sim_latency.is_some(),
            "{tag}: refined winner has no measured latency"
        );
        // Determinism: the exhaustive sweep replays bit for bit.
        let again = explore(&cfg, ExploreMethod::Exhaustive).unwrap();
        assert_eq!(again.best, ex.best, "{tag}: best replay");
        assert_eq!(again.front, ex.front, "{tag}: front replay");
        assert_eq!(
            again.candidates[again.best].est_latency.to_bits(),
            best.est_latency.to_bits(),
            "{tag}: latency replay"
        );
    }
}
