//! Differential golden tests for the multi-program admission engine
//! (`coordinator::admit`), pinning it to the single-program engines.
//!
//! The headline contract (see the module docs in `coordinator/exec.rs`):
//!
//! * (a) one program admitted at t=0 reproduces `exec::cosim` **and**
//!   `refexec::cosim_ref` bit-for-bit — makespan, per-step completions,
//!   tile busy cycles, transfer cycles, per-category energy bit patterns
//!   and the program span — across mlp/vit workloads, all three map
//!   strategies and both bundled fabric configs;
//! * (b) N programs admitted at t=0 equal a fresh-calendar oracle that
//!   replays the merged (concatenated) schedule through `cosim`;
//! * (c) staggered `admit_at` times equal the oracle built from scratch
//!   with the same offsets;
//! * incremental re-simulation after a program/cost change (`replace`)
//!   is bit-identical to a from-scratch oracle run.

use std::sync::Arc;

use archytas::accel::Precision;
use archytas::compiler::lowering::lower;
use archytas::compiler::mapper::{map_graph, MapStrategy};
use archytas::compiler::FabricProgram;
use archytas::coordinator::{cosim, cosim_ref, CosimSession, ExecReport};
use archytas::fabric::{CongestionKnobs, CostModel, DvfsKnobs, Fabric, VaryingCost};
use archytas::prop_assert;
use archytas::sim::Cycle;
use archytas::testutil::{bundled_fabric, merge_programs, prop};
use archytas::workloads;

const CONFIGS: [&str; 2] = ["edge16.toml", "homogeneous_npu.toml"];
const STRATEGIES: [MapStrategy; 3] =
    [MapStrategy::RoundRobin, MapStrategy::Greedy, MapStrategy::Ilp];

/// The two workload families of the matrix. Kept small so the full
/// config × strategy × workload product (including the ILP mapper's
/// branch-and-bound) stays fast.
fn workload(name: &str) -> archytas::ir::Graph {
    match name {
        "mlp" => workloads::mlp(4, 64, &[32], 10, 7).unwrap(),
        "vit" => {
            let p = workloads::VitParams {
                batch: 2,
                tokens: 8,
                dim: 32,
                depth: 1,
                mlp_ratio: 2,
                patch_dim: 16,
                classes: 10,
            };
            workloads::vit(&p, 3).unwrap()
        }
        other => panic!("unknown workload {other}"),
    }
}

fn lowered(fabric: &Fabric, wname: &str, strategy: MapStrategy) -> FabricProgram {
    let g = workload(wname);
    let m = map_graph(&g, fabric, strategy, Precision::Int8).unwrap();
    lower(&g, fabric, &m).unwrap()
}

/// Field-by-field asserts (granular diagnostics), then the library's
/// `bit_identical` contract (which now also covers the program spans).
fn assert_reports_identical(a: &ExecReport, b: &ExecReport, tag: &str) {
    assert_eq!(a.cycles, b.cycles, "{tag}: makespan");
    assert_eq!(a.step_done, b.step_done, "{tag}: step_done");
    assert_eq!(a.tile_busy, b.tile_busy, "{tag}: tile_busy");
    assert_eq!(a.transfer_cycles, b.transfer_cycles, "{tag}: transfer_cycles");
    assert_eq!(a.exec_steps, b.exec_steps, "{tag}: exec_steps");
    assert_eq!(
        a.metrics.total_energy_pj().to_bits(),
        b.metrics.total_energy_pj().to_bits(),
        "{tag}: total energy {} vs {}",
        a.metrics.total_energy_pj(),
        b.metrics.total_energy_pj()
    );
    assert_eq!(a.programs.len(), b.programs.len(), "{tag}: span count");
    for (i, (sa, sb)) in a.programs.iter().zip(&b.programs).enumerate() {
        assert!(sa.bit_identical(sb), "{tag}: span {i}: {sa:?} vs {sb:?}");
    }
    assert!(a.bit_identical(b), "{tag}: bit_identical contract");
}

/// (a) Single program at t=0: the session must replay both single-program
/// engines bit-for-bit over the full workload × strategy × config matrix.
#[test]
fn single_program_matrix_matches_both_engines() {
    for cfg in CONFIGS {
        let fabric = bundled_fabric(cfg);
        for wname in ["mlp", "vit"] {
            for strategy in STRATEGIES {
                let tag = format!("{cfg}/{wname}/{strategy:?}");
                let prog = lowered(&fabric, wname, strategy);
                let mut s = CosimSession::new(&fabric);
                s.admit_at(&prog, 0).unwrap();
                let got = s.report().unwrap();
                assert!(got.cycles > 0, "{tag}: trivial program");
                let ev = cosim(&fabric, &prog).unwrap();
                let re = cosim_ref(&fabric, &prog).unwrap();
                assert_reports_identical(&got, &ev, &format!("{tag} vs cosim"));
                assert_reports_identical(&got, &re, &format!("{tag} vs cosim_ref"));
            }
        }
    }
}

/// (b) N programs admitted at t=0 equal the fresh-calendar oracle that
/// replays the merged schedule: `cosim`/`cosim_ref` of the concatenation
/// (merged fields; the session additionally reports one span per
/// program, whose integer counters tile the merged totals exactly).
#[test]
fn batch_at_zero_matches_merged_oracle() {
    for cfg in CONFIGS {
        let fabric = bundled_fabric(cfg);
        let p1 = lowered(&fabric, "mlp", MapStrategy::Greedy);
        let p2 = lowered(&fabric, "vit", MapStrategy::RoundRobin);
        let p3 = lowered(&fabric, "mlp", MapStrategy::RoundRobin);
        let mut s = CosimSession::new(&fabric);
        s.admit_at(&p1, 0).unwrap();
        s.admit_at(&p2, 0).unwrap();
        s.admit_at(&p3, 0).unwrap();
        let got = s.report().unwrap();
        let merged = merge_programs(&[&p1, &p2, &p3]);
        for oracle in [cosim(&fabric, &merged).unwrap(), cosim_ref(&fabric, &merged).unwrap()] {
            let tag = format!("{cfg}/batch3");
            assert_eq!(got.cycles, oracle.cycles, "{tag}: makespan");
            assert_eq!(got.step_done, oracle.step_done, "{tag}: step_done");
            assert_eq!(got.tile_busy, oracle.tile_busy, "{tag}: tile_busy");
            assert_eq!(got.transfer_cycles, oracle.transfer_cycles, "{tag}: transfer");
            assert_eq!(got.exec_steps, oracle.exec_steps, "{tag}: exec_steps");
            assert_eq!(
                got.metrics.total_energy_pj().to_bits(),
                oracle.metrics.total_energy_pj().to_bits(),
                "{tag}: energy bits"
            );
            assert_eq!(got.metrics, oracle.metrics, "{tag}: metrics struct");
        }
        // Spans tile the merged totals exactly (integer counters).
        assert_eq!(got.programs.len(), 3);
        let steps: usize = got.programs.iter().map(|p| p.steps).sum();
        let execs: usize = got.programs.iter().map(|p| p.exec_steps).sum();
        let transfer: Cycle = got.programs.iter().map(|p| p.transfer_cycles).sum();
        let ops: u64 = got.programs.iter().map(|p| p.ops).sum();
        let bytes: u64 = got.programs.iter().map(|p| p.bytes_moved).sum();
        assert_eq!(steps, got.step_done.len());
        assert_eq!(execs, got.exec_steps);
        assert_eq!(transfer, got.transfer_cycles);
        assert_eq!(ops, got.metrics.ops);
        assert_eq!(bytes, got.metrics.bytes_moved);
        assert_eq!(
            got.cycles,
            got.programs.iter().map(|p| p.finished_at).max().unwrap()
        );
    }
}

/// (c) Staggered admission times: interleaving admits with drains (the
/// serving shape — including an admit into the simulated *past*) equals
/// the oracle session built from scratch with the same offsets.
#[test]
fn staggered_admission_matches_from_scratch_oracle() {
    for cfg in CONFIGS {
        let fabric = bundled_fabric(cfg);
        let p1 = lowered(&fabric, "mlp", MapStrategy::Greedy);
        let p2 = lowered(&fabric, "vit", MapStrategy::Greedy);
        let p3 = lowered(&fabric, "mlp", MapStrategy::RoundRobin);
        // Offsets: p2 lands mid-flight of p1 (run_until pause), p3 lands
        // retroactively before both after everything drained.
        let mut inc = CosimSession::new(&fabric);
        inc.admit_at(&p1, 50).unwrap();
        let solo = cosim(&fabric, &p1).unwrap();
        inc.run_until(50 + solo.cycles / 2).unwrap();
        inc.admit_at(&p2, 50 + solo.cycles / 3).unwrap();
        inc.run_to_drain().unwrap();
        inc.admit_at(&p3, 0).unwrap();
        let got = inc.report().unwrap();

        let mut fresh = CosimSession::new(&fabric);
        fresh.admit_at(&p1, 50).unwrap();
        fresh.admit_at(&p2, 50 + solo.cycles / 3).unwrap();
        fresh.admit_at(&p3, 0).unwrap();
        let want = fresh.report().unwrap();
        assert_reports_identical(&got, &want, &format!("{cfg}/staggered"));
    }
}

/// Incremental re-simulation after a program/cost change: `replace` a
/// drained program with a re-lowered variant (different precision — a
/// genuine cost-model bump through the start-time-aware fabric hooks)
/// and require bit-identity with a from-scratch oracle, across both
/// configs and all three map strategies.
#[test]
fn replace_matches_from_scratch_across_matrix() {
    for cfg in CONFIGS {
        let fabric = bundled_fabric(cfg);
        for strategy in STRATEGIES {
            let tag = format!("{cfg}/{strategy:?}/replace");
            let keep = lowered(&fabric, "mlp", strategy);
            let old = lowered(&fabric, "vit", strategy);
            // The "cost bump": same workload re-mapped at F32 — every
            // Exec/Load step re-prices through the fabric hooks.
            let g = workload("vit");
            let m = map_graph(&g, &fabric, strategy, Precision::F32).unwrap();
            let bumped = lower(&g, &fabric, &m).unwrap();

            let mut inc = CosimSession::new(&fabric);
            inc.admit_at(&keep, 0).unwrap();
            let h = inc.admit_at(&old, 25).unwrap();
            inc.run_to_drain().unwrap();
            inc.replace(h, &bumped, 25).unwrap();
            let got = inc.report().unwrap();

            let mut fresh = CosimSession::new(&fabric);
            fresh.admit_at(&keep, 0).unwrap();
            fresh.admit_at(&bumped, 25).unwrap();
            let want = fresh.report().unwrap();
            assert_reports_identical(&got, &want, &tag);
        }
    }
}

/// `invalidate` (re-price without content change) must be a bit-exact
/// no-op on a time-invariant cost model — the hook seam contract.
#[test]
fn invalidate_reprices_to_identical_bits() {
    let fabric = bundled_fabric("edge16.toml");
    let p1 = lowered(&fabric, "mlp", MapStrategy::Greedy);
    let p2 = lowered(&fabric, "vit", MapStrategy::Greedy);
    let mut s = CosimSession::new(&fabric);
    let h1 = s.admit_at(&p1, 0).unwrap();
    s.admit_at(&p2, 10).unwrap();
    let before = s.report().unwrap();
    s.invalidate(h1).unwrap();
    let after = s.report().unwrap();
    assert_reports_identical(&before, &after, "invalidate/noop");
}

/// The time-varying model family used by the parallel-drain sweeps: a
/// short epoch so the test workloads cross many epoch boundaries, both
/// congestion and DVFS mechanisms live.
fn varying_model() -> Arc<dyn CostModel> {
    let cong = CongestionKnobs { alpha: 0.5, cap: 4.0 };
    let dvfs = DvfsKnobs {
        window: 4,
        warm_frac: 0.5,
        hot_frac: 0.85,
        warm_scale: 0.75,
        hot_scale: 0.5,
    };
    Arc::new(VaryingCost::congestion_dvfs(512, cong, dvfs))
}

/// One staggered serving episode (mid-flight pause + retroactive
/// admission) at the given thread count / partition, on either the
/// invariant (`varying = false`) or congestion/DVFS model.
fn sweep_episode(
    fabric: &Fabric,
    progs: &[FabricProgram],
    varying: bool,
    threads: usize,
    shards: Option<&[usize]>,
) -> ExecReport {
    let mut s = if varying {
        CosimSession::with_model(fabric, varying_model())
    } else {
        CosimSession::new(fabric)
    };
    s.set_threads(threads);
    if let Some(b) = shards {
        s.set_shards(Some(b)).unwrap();
    }
    s.admit_at(&progs[0], 0).unwrap();
    s.run_until(400).unwrap();
    for (k, p) in progs.iter().enumerate().skip(1) {
        s.admit_at(p, 250 * k as Cycle).unwrap();
    }
    s.run_to_drain().unwrap();
    s.report().unwrap()
}

/// The tentpole golden: threads ∈ {1, 2, 4, 8} shard-parallel sessions
/// are bit-identical to the sequential engine across both configs, mixed
/// workloads/strategies, and both the invariant and the congestion/DVFS
/// time-varying models — every `ExecReport` field and every
/// `ProgramSpan`, including the f64 energy fold bits.
#[test]
fn threads_sweep_bit_identical_across_matrix() {
    for cfg in CONFIGS {
        let fabric = bundled_fabric(cfg);
        let progs = [
            lowered(&fabric, "mlp", MapStrategy::Greedy),
            lowered(&fabric, "vit", MapStrategy::RoundRobin),
            lowered(&fabric, "mlp", MapStrategy::RoundRobin),
            lowered(&fabric, "vit", MapStrategy::Greedy),
        ];
        for varying in [false, true] {
            let want = sweep_episode(&fabric, &progs, varying, 1, None);
            for threads in [2, 4, 8] {
                let got = sweep_episode(&fabric, &progs, varying, threads, None);
                assert_reports_identical(
                    &got,
                    &want,
                    &format!("{cfg}/varying={varying}/threads={threads}"),
                );
            }
        }
    }
}

/// Shard-partition invariance: per-resource fences, a single forced
/// shard, and random uneven partitions must all reproduce the sequential
/// bits — the determinism contract holds for *every* valid partition,
/// not just the balanced default.
#[test]
fn prop_shard_partition_invariance() {
    let fabric = bundled_fabric("edge16.toml");
    let progs = [
        lowered(&fabric, "mlp", MapStrategy::Greedy),
        lowered(&fabric, "vit", MapStrategy::RoundRobin),
        lowered(&fabric, "mlp", MapStrategy::RoundRobin),
    ];
    for varying in [false, true] {
        let want = sweep_episode(&fabric, &progs, varying, 1, None);
        // The initial resource domain (tiles + HBM; links join the last
        // shard as they materialize).
        let nres = if varying {
            CosimSession::with_model(&fabric, varying_model()).resource_count()
        } else {
            CosimSession::new(&fabric).resource_count()
        };
        // Per-resource fences: one shard per initial resource.
        let per_res: Vec<usize> = (0..=nres).collect();
        let got = sweep_episode(&fabric, &progs, varying, 4, Some(&per_res));
        assert_reports_identical(&got, &want, &format!("varying={varying}/per-resource"));
        // Single forced shard: the staged path at one shard.
        let got = sweep_episode(&fabric, &progs, varying, 1, Some(&[0, nres]));
        assert_reports_identical(&got, &want, &format!("varying={varying}/single-shard"));
        // Random uneven partitions.
        prop::check(6, |rng| {
            let mut bounds = vec![0usize];
            let mut at = 0usize;
            while at < nres {
                at = (at + 1 + rng.below(nres.div_ceil(2))).min(nres);
                bounds.push(at);
            }
            let threads = 1 + rng.below(8);
            let got = sweep_episode(&fabric, &progs, varying, threads, Some(&bounds));
            prop_assert!(
                got.bit_identical(&want),
                "varying={varying}: partition {bounds:?} at {threads} threads diverged"
            );
            Ok(())
        });
    }
}
