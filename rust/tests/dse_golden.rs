//! Golden tests for batched incremental DSE on the fast engines
//! (`dse::sweep`) and the explorer's measurement-engine seam
//! (`dse::explorer::SimEngine`).
//!
//! Contracts pinned here:
//!
//! * **Incremental ≡ rebuild-world** — on the golden sweep config
//!   (`configs/dse_sweep.toml`, 96 candidate fabrics), the session-reuse
//!   sweep (`dse::sweep`) is bit-identical to the rebuild-world oracle
//!   (`dse::sweep_rebuild`): every makespan, every energy bit, every
//!   per-program span. Config-diffs mapped onto `CosimSession::set_model`
//!   invalidation move no bits vs a fresh world.
//! * **Thread invariance** — the sweep's group fan-out returns the same
//!   bits at every worker count (results merge in canonical candidate
//!   order, never completion order).
//! * **Method agreement** — with `sim_top_k = 1`, Exhaustive, MILP, SMT
//!   and IterativeSim land on the same analytic optimum (compared by
//!   `est_latency`, which is tie-safe where winner *names* are not), and
//!   IterativeSim's winner carries a measurement.
//! * **Analytic vs measured ranking sanity** — on the mixed post-CMOS
//!   config (`configs/hetero_mixed.toml`, kind-aware cost model), the
//!   co-sim engine fills latency *and* energy for the refined top-k, the
//!   measured ranking is internally consistent, the Pareto front is
//!   measured-only, and replay is bit-identical.

use archytas::config::FabricConfig;
use archytas::dse::{
    explore, sweep, sweep_rebuild, ExploreConfig, ExploreMethod, SimEngine, SweepSpec,
};

fn golden_spec() -> SweepSpec {
    let path = archytas::repo_root().join("configs/dse_sweep.toml");
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()));
    SweepSpec::from_toml(&text).expect("golden sweep config must parse")
}

#[test]
fn incremental_sweep_matches_rebuild_oracle_bitwise() {
    let spec = golden_spec();
    assert_eq!(spec.candidates(), 96, "golden sweep shape drifted");
    let inc = sweep(&spec).expect("incremental sweep");
    let reb = sweep_rebuild(&spec).expect("rebuild-world oracle");
    assert_eq!(inc.evals.len(), 96);
    assert_eq!(reb.evals.len(), 96);
    for (a, b) in inc.evals.iter().zip(&reb.evals) {
        assert!(
            a.bit_identical(b),
            "candidate {} ({}/{}/{}/{}) diverged from the rebuild oracle:\n  inc {:?}\n  reb {:?}",
            a.index,
            a.topology,
            a.mix,
            a.model,
            a.policy,
            (a.makespan, a.energy_pj, a.bytes_moved),
            (b.makespan, b.energy_pj, b.bytes_moved),
        );
    }
    // Session economy: 12 groups × 2 policies vs 96 worlds; 3 re-prices
    // per session walk the 4-model axis.
    assert_eq!(inc.sessions, 24);
    assert_eq!(inc.reprices, 72);
    assert_eq!(reb.sessions, 96);
    // Both pick the same winner, deterministically.
    assert_eq!(inc.best(), reb.best());
    // Every candidate actually simulated something.
    for e in &inc.evals {
        assert!(e.makespan > 0, "{}: empty makespan", e.index);
        assert!(e.energy_pj.is_finite() && e.energy_pj > 0.0, "{}: bad energy", e.index);
        assert_eq!(e.spans.len(), spec.programs, "{}: span count", e.index);
    }
}

#[test]
fn sweep_is_thread_invariant() {
    let base = golden_spec();
    let one = sweep(&base).expect("threads=1");
    for threads in [2, 4, 8] {
        let spec = SweepSpec { threads, ..base.clone() };
        let many = sweep(&spec).expect("parallel sweep");
        assert_eq!(many.evals.len(), one.evals.len());
        for (a, b) in one.evals.iter().zip(&many.evals) {
            assert!(
                a.bit_identical(b),
                "threads={threads}: candidate {} diverged",
                a.index
            );
        }
    }
}

#[test]
fn explore_methods_agree_with_top1_refinement() {
    let cfg = ExploreConfig { sim_top_k: 1, ..ExploreConfig::default() };
    let ex = explore(&cfg, ExploreMethod::Exhaustive).unwrap();
    let milp = explore(&cfg, ExploreMethod::Milp).unwrap();
    let smt = explore(&cfg, ExploreMethod::Smt).unwrap();
    let iter = explore(&cfg, ExploreMethod::IterativeSim).unwrap();
    // Tie-safe agreement: compare the winning estimate, not the name —
    // distinct families can score identically, and the solvers are free
    // to break exact ties differently.
    let lat = |r: &archytas::dse::ExploreResult| r.candidates[r.best].est_latency;
    assert_eq!(lat(&ex).to_bits(), lat(&milp).to_bits(), "MILP winner estimate");
    assert_eq!(lat(&ex).to_bits(), lat(&smt).to_bits(), "SMT winner estimate");
    // With k = 1 IterativeSim refines exactly the analytic front-runner.
    assert_eq!(lat(&ex).to_bits(), lat(&iter).to_bits(), "IterativeSim winner estimate");
    assert_eq!(iter.sim_evals, 1);
    assert!(iter.candidates[iter.best].sim_latency.is_some());
    // The flit engine measures latency only; the analytic front stands.
    assert!(iter.candidates[iter.best].sim_energy_pj.is_none());
    assert_eq!(iter.front, ex.front);
}

#[test]
fn cosim_engine_ranking_sanity_on_hetero_mixed() {
    let path = archytas::repo_root().join("configs/hetero_mixed.toml");
    let fabric_cfg = FabricConfig::from_toml(&std::fs::read_to_string(path).unwrap()).unwrap();
    let cfg = ExploreConfig {
        min_nodes: 16,
        max_area: 40.0,
        sim_top_k: 3,
        engine: SimEngine::Cosim,
        fabric: Some(fabric_cfg),
        ..ExploreConfig::default()
    };
    let r = explore(&cfg, ExploreMethod::IterativeSim).unwrap();
    assert_eq!(r.sim_evals, 3, "three candidates must be co-sim measured");
    let measured: Vec<_> =
        r.candidates.iter().filter(|c| c.sim_latency.is_some()).collect();
    assert_eq!(measured.len(), 3);
    for c in &measured {
        let lat = c.sim_latency.unwrap();
        let en = c.sim_energy_pj.unwrap();
        assert!(lat.is_finite() && lat > 0.0, "{}: bad measured latency {lat}", c.name);
        assert!(en.is_finite() && en > 0.0, "{}: bad measured energy {en}", c.name);
    }
    // Measured ranking is internally consistent: the winner has the
    // minimum measured latency among the refined set.
    let best = &r.candidates[r.best];
    assert!(measured
        .iter()
        .all(|c| c.sim_latency.unwrap() >= best.sim_latency.unwrap()));
    // Under the co-sim engine the Pareto front mixes no analytic energy:
    // every front member is a measured candidate.
    assert!(!r.front.is_empty());
    for &i in &r.front {
        assert!(
            r.candidates[i].sim_energy_pj.is_some(),
            "front member {} is unmeasured",
            r.candidates[i].name
        );
    }
    // Bit-identical replay: measurement goes through the deterministic
    // co-sim, so the full result reproduces exactly.
    let r2 = explore(&cfg, ExploreMethod::IterativeSim).unwrap();
    assert_eq!(r.best, r2.best);
    assert_eq!(r.front, r2.front);
    for (a, b) in r.candidates.iter().zip(&r2.candidates) {
        assert_eq!(
            a.sim_latency.map(f64::to_bits),
            b.sim_latency.map(f64::to_bits),
            "{}: latency replay",
            a.name
        );
        assert_eq!(
            a.sim_energy_pj.map(f64::to_bits),
            b.sim_energy_pj.map(f64::to_bits),
            "{}: energy replay",
            a.name
        );
    }
}
