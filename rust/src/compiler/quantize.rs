//! Dynamic INT8 quantization with activation calibration (paper Sec. V.B:
//! "reduces the precision of model weights and activations during
//! inference, often down to INT8 ... without significant loss in
//! accuracy").
//!
//! Semantics mirror the L1 qmatmul kernel: symmetric per-output-channel
//! weight scales, per-tensor activation scales calibrated on sample
//! inputs; the IR interpreter hook simulates the quantized execution so
//! accuracy is *measured*, not assumed.

use crate::ir::interp::{self, Mat};
use crate::ir::{Graph, NodeId, OpKind};
use crate::Result;

/// Quantization report.
#[derive(Debug, Clone)]
pub struct QuantReport {
    /// Per-tensor weight quantization SNR (dB), worst tensor.
    pub worst_weight_snr_db: f64,
    /// Calibrated per-node activation scales (max-abs / 127).
    pub act_scales: Vec<f32>,
    /// Quantized weight tensors count.
    pub tensors: usize,
}

fn quantize_dequantize(v: f32, scale: f32) -> f32 {
    if scale == 0.0 {
        return 0.0;
    }
    (v / scale).round().clamp(-127.0, 127.0) * scale
}

/// Quantize all matmul weights in place (per-output-channel symmetric
/// INT8, stored dequantized — exactly what the analog/digital tile
/// realises). Returns per-tensor SNR stats.
pub fn quantize_weights_int8(g: &mut Graph) -> QuantReport {
    let mut worst_snr = f64::INFINITY;
    let mut tensors = 0;
    for w in &mut g.weights {
        if w.shape[0] == 1 {
            continue; // vectors stay f32 (bias is added in f32)
        }
        tensors += 1;
        let [k, n] = w.shape;
        let mut sig = 0.0f64;
        let mut noise = 0.0f64;
        for j in 0..n {
            let mut amax = 0.0f32;
            for i in 0..k {
                amax = amax.max(w.data[i * n + j].abs());
            }
            let scale = if amax > 0.0 { amax / 127.0 } else { 1.0 };
            for i in 0..k {
                let old = w.data[i * n + j];
                let new = quantize_dequantize(old, scale);
                sig += (old as f64) * (old as f64);
                noise += ((old - new) as f64) * ((old - new) as f64);
                w.data[i * n + j] = new;
            }
        }
        let snr = if noise == 0.0 { f64::INFINITY } else { 10.0 * (sig / noise).log10() };
        worst_snr = worst_snr.min(snr);
    }
    QuantReport { worst_weight_snr_db: worst_snr, act_scales: Vec::new(), tensors }
}

/// Calibrate per-node activation scales by running `samples` through the
/// f32 graph and recording max-abs per node output.
pub fn calibrate_activations(g: &Graph, samples: &[Mat]) -> Result<Vec<f32>> {
    let mut maxabs = vec![0.0f32; g.len()];
    for s in samples {
        interp::run_with(g, std::slice::from_ref(s), |id, m| {
            let mx = m.data.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
            maxabs[id] = maxabs[id].max(mx);
        })?;
    }
    Ok(maxabs.iter().map(|&m| if m > 0.0 { m / 127.0 } else { 1.0 }).collect())
}

/// Run the graph with simulated INT8 activation quantization after the
/// quantization-relevant nodes (matmul outputs), using calibrated scales.
pub fn run_quantized(g: &Graph, input: &Mat, act_scales: &[f32]) -> Result<Vec<Mat>> {
    interp::run_with(g, std::slice::from_ref(input), |id: NodeId, m: &mut Mat| {
        if matches!(g.nodes[id].kind, OpKind::MatMul) {
            let s = act_scales[id];
            for v in &mut m.data {
                *v = quantize_dequantize(*v, s);
            }
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;

    #[test]
    fn weight_snr_is_high() {
        let mut g = workloads::mlp(2, 64, &[32], 10, 1).unwrap();
        let rep = quantize_weights_int8(&mut g);
        assert!(rep.worst_weight_snr_db > 35.0, "{}", rep.worst_weight_snr_db);
        assert_eq!(rep.tensors, 2); // 64x32 and 32x10 matrices
    }

    #[test]
    fn weights_land_on_grid() {
        let mut g = workloads::mlp(1, 16, &[8], 4, 2).unwrap();
        quantize_weights_int8(&mut g);
        let w = &g.weights[0];
        let [k, n] = w.shape;
        for j in 0..n {
            let amax = (0..k).map(|i| w.data[i * n + j].abs()).fold(0.0f32, f32::max);
            if amax == 0.0 {
                continue;
            }
            let scale = amax / 127.0;
            for i in 0..k {
                let q = w.data[i * n + j] / scale;
                assert!((q - q.round()).abs() < 1e-3, "{q}");
            }
        }
    }

    #[test]
    fn e5_quantized_accuracy_tracks_f32() {
        let g0 = workloads::mlp(8, 64, &[48, 24], 10, 3).unwrap();
        let mut gq = g0.clone();
        quantize_weights_int8(&mut gq);
        let ds = workloads::synthetic_dataset(8, 8, 64, 10, 7);
        let scales = calibrate_activations(&g0, &ds.inputs).unwrap();
        let o0: Vec<Mat> =
            ds.inputs.iter().map(|x| interp::run(&g0, &[x.clone()]).unwrap().remove(0)).collect();
        let oq: Vec<Mat> = ds
            .inputs
            .iter()
            .map(|x| run_quantized(&gq, x, &scales).unwrap().remove(0))
            .collect();
        let agree = workloads::top1_agreement(&o0, &oq);
        assert!(agree > 0.9, "agreement {agree}");
    }

    #[test]
    fn calibration_covers_activations() {
        let g = workloads::mlp(4, 32, &[16], 4, 4).unwrap();
        let ds = workloads::synthetic_dataset(4, 4, 32, 4, 8);
        let scales = calibrate_activations(&g, &ds.inputs).unwrap();
        assert_eq!(scales.len(), g.len());
        assert!(scales.iter().all(|&s| s > 0.0));
    }

    #[test]
    fn quantize_dequantize_saturates() {
        assert_eq!(quantize_dequantize(1e9, 1.0), 127.0);
        assert_eq!(quantize_dequantize(-1e9, 1.0), -127.0);
        assert_eq!(quantize_dequantize(0.4, 1.0), 0.0);
    }
}
