//! The ARCHYTAS compiler stack (paper Sec. V, Fig. 2): passes that map AI
//! kernels onto the heterogeneous fabric.
//!
//! * [`pruning`] — magnitude pruning (Sec. V.B).
//! * [`sparsify`] — structured block sparsification (Sec. V.B + the
//!   Sec. III microarchitectural sparsity support).
//! * [`quantize`] — dynamic INT8 quantization with calibration (Sec. V.B).
//! * [`precision`] — TAFFO-style precision tuning: interval value-range
//!   analysis from programmer hints, fixed-point type allocation, and
//!   static error/performance estimation (Sec. V.C).
//! * [`mapper`] — layer-to-CU assignment over a [`crate::fabric::Fabric`].
//! * [`lowering`] — mapped graph → [`FabricProgram`] of transfer/compute
//!   steps the coordinator co-simulates.

pub mod lowering;
pub mod mapper;
pub mod precision;
pub mod pruning;
pub mod quantize;
pub mod sparsify;

pub use lowering::{FabricProgram, Step};
pub use mapper::{map_graph, map_graph_with, MapStrategy, Mapping};
