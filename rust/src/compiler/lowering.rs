//! Lowering: mapped IR graph → [`FabricProgram`], the transfer/compute
//! step list the coordinator co-simulates (and executes functionally via
//! the PJRT artifacts).

use anyhow::ensure;

use crate::accel::{Compute, Precision};
use crate::fabric::{Fabric, Template};
use crate::ir::Graph;
use crate::Result;

use super::mapper::{node_compute, Mapping};

/// One program step. `deps` are indices of steps that must complete
/// first (the coordinator exploits the remaining parallelism).
#[derive(Debug, Clone)]
pub enum Step {
    /// Stage `bytes` from HBM into tile-local memory.
    Load { tile: usize, bytes: u64, node: usize, deps: Vec<usize> },
    /// Move `bytes` from one tile to another over the NoC.
    Transfer { from: usize, to: usize, bytes: u64, node: usize, deps: Vec<usize> },
    /// Run a compute op on a tile.
    Exec { tile: usize, node: usize, compute: Compute, precision: Precision, deps: Vec<usize> },
}

impl Step {
    pub fn deps(&self) -> &[usize] {
        match self {
            Step::Load { deps, .. } | Step::Transfer { deps, .. } | Step::Exec { deps, .. } => deps,
        }
    }

    pub fn node(&self) -> usize {
        match self {
            Step::Load { node, .. } | Step::Transfer { node, .. } | Step::Exec { node, .. } => {
                *node
            }
        }
    }
}

/// A lowered program.
#[derive(Debug, Clone, Default)]
pub struct FabricProgram {
    pub steps: Vec<Step>,
    /// Step producing each graph node's value (for result lookup).
    pub producer: Vec<Option<usize>>,
}

/// Lower a mapped graph. Weight staging: templates B/C load weights once
/// (TCDM-resident); template A streams weights with every invocation
/// (its defining cost, paper Fig. 1).
pub fn lower(g: &Graph, fabric: &Fabric, mapping: &Mapping) -> Result<FabricProgram> {
    g.validate()?;
    let mut prog = FabricProgram { steps: Vec::new(), producer: vec![None; g.len()] };
    // Weight residency: weight idx -> loaded-on-tile step.
    let mut resident: std::collections::HashMap<(usize, usize), usize> =
        std::collections::HashMap::new();
    for id in 0..g.len() {
        let Some(tile) = mapping.assign[id] else { continue };
        let c = node_compute(g, id).unwrap();
        let p = mapping.precision[id];
        let mut deps = Vec::new();
        // Operand transfers from producing tiles.
        for &inp in &g.nodes[id].inputs {
            match mapping.assign[inp] {
                Some(src_tile) if src_tile != tile => {
                    let bytes = (g.nodes[inp].shape[0] * g.nodes[inp].shape[1] * 4) as u64;
                    let step = Step::Transfer {
                        from: src_tile,
                        to: tile,
                        bytes,
                        node: inp,
                        deps: prog.producer[inp].into_iter().collect(),
                    };
                    prog.steps.push(step);
                    deps.push(prog.steps.len() - 1);
                }
                Some(_) => {
                    // same tile: just depend on the producer
                    if let Some(s) = prog.producer[inp] {
                        deps.push(s);
                    }
                }
                None => {
                    // Input or weight from HBM.
                    let is_weight = g.matmul_weight_idx(&g.nodes[id])
                        .map(|w| matches!(g.nodes[inp].kind, crate::ir::OpKind::Weight { idx } if idx == w))
                        .unwrap_or(false)
                        || matches!(g.nodes[inp].kind, crate::ir::OpKind::Weight { .. });
                    let bytes = (g.nodes[inp].shape[0] * g.nodes[inp].shape[1] * 4) as u64;
                    if is_weight && fabric.tiles[tile].template != Template::A {
                        // Load once per (weight-node, tile).
                        let key = (inp, tile);
                        let step_id = match resident.get(&key) {
                            Some(&s) => s,
                            None => {
                                prog.steps.push(Step::Load {
                                    tile,
                                    bytes,
                                    node: inp,
                                    deps: vec![],
                                });
                                let s = prog.steps.len() - 1;
                                resident.insert(key, s);
                                s
                            }
                        };
                        deps.push(step_id);
                    } else {
                        prog.steps.push(Step::Load { tile, bytes, node: inp, deps: vec![] });
                        deps.push(prog.steps.len() - 1);
                    }
                }
            }
        }
        prog.steps.push(Step::Exec { tile, node: id, compute: c, precision: p, deps });
        prog.producer[id] = Some(prog.steps.len() - 1);
    }
    // Sanity: dependencies point backwards.
    for (i, s) in prog.steps.iter().enumerate() {
        ensure!(s.deps().iter().all(|&d| d < i), "forward dep in step {i}");
    }
    Ok(prog)
}

impl FabricProgram {
    pub fn exec_steps(&self) -> usize {
        self.steps.iter().filter(|s| matches!(s, Step::Exec { .. })).count()
    }

    pub fn transfer_bytes(&self) -> u64 {
        self.steps
            .iter()
            .map(|s| match s {
                Step::Load { bytes, .. } | Step::Transfer { bytes, .. } => *bytes,
                Step::Exec { .. } => 0,
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::mapper::{map_graph, MapStrategy};
    use crate::config::FabricConfig;
    use crate::workloads;

    fn fabric(template: &str) -> Fabric {
        Fabric::build(
            FabricConfig::from_toml(&format!(
                "[noc]\nwidth = 3\nheight = 3\n[[cu]]\nkind = \"npu\"\ntemplate = \"{template}\"\ncount = 4\n"
            ))
            .unwrap(),
        )
        .unwrap()
    }

    fn lowered(template: &str) -> (Graph, Fabric, FabricProgram) {
        let g = workloads::mlp(4, 64, &[32], 10, 1).unwrap();
        let f = fabric(template);
        let m = map_graph(&g, &f, MapStrategy::Greedy, Precision::Int8).unwrap();
        let p = lower(&g, &f, &m).unwrap();
        (g, f, p)
    }

    use crate::ir::Graph;

    #[test]
    fn program_covers_all_compute_nodes() {
        let (g, _, p) = lowered("B");
        let compute_nodes =
            (0..g.len()).filter(|&id| node_compute(&g, id).is_some()).count();
        assert_eq!(p.exec_steps(), compute_nodes);
        for id in 0..g.len() {
            if node_compute(&g, id).is_some() {
                assert!(p.producer[id].is_some());
            }
        }
    }

    #[test]
    fn deps_are_acyclic_and_backward() {
        let (_, _, p) = lowered("B");
        for (i, s) in p.steps.iter().enumerate() {
            assert!(s.deps().iter().all(|&d| d < i));
        }
    }

    #[test]
    fn template_a_streams_more_than_b() {
        let (_, _, pa) = lowered("A");
        let (_, _, pb) = lowered("B");
        assert!(
            pa.transfer_bytes() >= pb.transfer_bytes(),
            "A {} vs B {}",
            pa.transfer_bytes(),
            pb.transfer_bytes()
        );
    }

    #[test]
    fn weight_loads_are_deduplicated_on_b() {
        let (g, _, p) = lowered("B");
        // Each weight node feeding a matmul should be loaded exactly once
        // per tile it is used on.
        let mut loads_per_node: std::collections::HashMap<usize, usize> = Default::default();
        for s in &p.steps {
            if let Step::Load { node, .. } = s {
                *loads_per_node.entry(*node).or_insert(0) += 1;
            }
        }
        for (node, count) in loads_per_node {
            if matches!(g.nodes[node].kind, crate::ir::OpKind::Weight { .. })
                && g.nodes[node].shape[0] > 1
            {
                assert!(count <= 2, "weight node {node} loaded {count} times");
            }
        }
    }
}
