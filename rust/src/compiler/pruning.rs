//! Magnitude pruning (paper Sec. V.B: "systematically removes redundant
//! or non-informative weights, typically after training").

use crate::ir::Graph;

/// Pruning report (per graph).
#[derive(Debug, Clone, PartialEq)]
pub struct PruneReport {
    /// Weights zeroed / total.
    pub pruned: usize,
    pub total: usize,
    /// Fraction of weight L2 norm retained (accuracy-loss proxy).
    pub norm_retained: f64,
}

impl PruneReport {
    pub fn sparsity(&self) -> f64 {
        self.pruned as f64 / self.total as f64
    }
}

/// Zero the smallest-magnitude `sparsity` fraction of every weight
/// matrix (per-tensor thresholding; biases/norm params are skipped — they
/// are tiny and disproportionately important).
pub fn magnitude_prune(g: &mut Graph, sparsity: f64) -> PruneReport {
    assert!((0.0..1.0).contains(&sparsity), "sparsity in [0,1)");
    let mut pruned = 0;
    let mut total = 0;
    let mut norm_before = 0.0f64;
    let mut norm_after = 0.0f64;
    for w in &mut g.weights {
        if w.shape[0] == 1 {
            continue; // bias / LN vector
        }
        total += w.data.len();
        norm_before += w.data.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>();
        let mut mags: Vec<f32> = w.data.iter().map(|v| v.abs()).collect();
        mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let cut = (sparsity * mags.len() as f64) as usize;
        if cut == 0 {
            norm_after += w.data.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>();
            continue;
        }
        let threshold = mags[cut - 1];
        for v in &mut w.data {
            if v.abs() <= threshold && pruned < total {
                *v = 0.0;
                pruned += 1;
            }
        }
        norm_after += w.data.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>();
    }
    PruneReport {
        pruned,
        total,
        norm_retained: if norm_before == 0.0 { 1.0 } else { (norm_after / norm_before).sqrt() },
    }
}

/// Measured fraction of zero weights in prunable tensors.
pub fn measured_sparsity(g: &Graph) -> f64 {
    let (mut zeros, mut total) = (0usize, 0usize);
    for w in &g.weights {
        if w.shape[0] == 1 {
            continue;
        }
        zeros += w.data.iter().filter(|&&v| v == 0.0).count();
        total += w.data.len();
    }
    if total == 0 {
        0.0
    } else {
        zeros as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::interp::{run, Mat};
    use crate::workloads;

    #[test]
    fn prunes_to_requested_sparsity() {
        let mut g = workloads::mlp(2, 64, &[32], 10, 1).unwrap();
        let rep = magnitude_prune(&mut g, 0.5);
        assert!((rep.sparsity() - 0.5).abs() < 0.02, "{}", rep.sparsity());
        assert!((measured_sparsity(&g) - 0.5).abs() < 0.02);
        assert!(rep.norm_retained > 0.8, "small weights carry little norm");
    }

    #[test]
    fn zero_sparsity_is_identity() {
        let mut g = workloads::mlp(2, 32, &[16], 4, 2).unwrap();
        let before = g.weights.clone();
        let rep = magnitude_prune(&mut g, 0.0);
        assert_eq!(rep.pruned, 0);
        for (a, b) in g.weights.iter().zip(&before) {
            assert_eq!(a.data, b.data);
        }
    }

    #[test]
    fn biases_survive() {
        let mut g = workloads::mlp(2, 32, &[16], 4, 3).unwrap();
        // make biases nonzero
        for w in &mut g.weights {
            if w.shape[0] == 1 {
                w.data.iter_mut().for_each(|v| *v = 1.0);
            }
        }
        magnitude_prune(&mut g, 0.9);
        for w in &g.weights {
            if w.shape[0] == 1 {
                assert!(w.data.iter().all(|&v| v == 1.0));
            }
        }
    }

    #[test]
    fn mild_pruning_preserves_top1() {
        // E5 shape: 30% magnitude pruning barely moves decisions.
        let g0 = workloads::mlp(8, 64, &[48, 24], 10, 4).unwrap();
        let mut g1 = g0.clone();
        magnitude_prune(&mut g1, 0.3);
        let ds = workloads::synthetic_dataset(8, 8, 64, 10, 9);
        let o0: Vec<Mat> = ds.inputs.iter().map(|x| run(&g0, &[x.clone()]).unwrap().remove(0)).collect();
        let o1: Vec<Mat> = ds.inputs.iter().map(|x| run(&g1, &[x.clone()]).unwrap().remove(0)).collect();
        let agree = workloads::top1_agreement(&o0, &o1);
        assert!(agree > 0.8, "agreement {agree}");
    }

    #[test]
    fn heavy_pruning_degrades_more_than_mild() {
        let g0 = workloads::mlp(8, 64, &[48, 24], 10, 5).unwrap();
        let mut mild = g0.clone();
        let mut heavy = g0.clone();
        let rm = magnitude_prune(&mut mild, 0.2);
        let rh = magnitude_prune(&mut heavy, 0.95);
        assert!(rh.norm_retained < rm.norm_retained);
    }
}
