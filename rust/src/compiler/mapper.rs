//! Layer-to-CU mapping: assign every compute node of an IR graph to a
//! tile of the fabric (paper Sec. V: "support the mapping of AI
//! computationally and/or memory intensive kernels to the accelerators").
//!
//! Three strategies, compared in the E10 bench:
//! * `RoundRobin` — naive baseline.
//! * `Greedy` — earliest-completion-time list scheduling with transport
//!   awareness (the production default).
//! * `Ilp` — makespan-minimizing MILP over the matmul nodes (ArchEx-style
//!   exact reference for small graphs).
//!
//! # The cost-model seam
//!
//! Every placement estimate routes through a
//! [`crate::fabric::CostModel`] at `start = 0` with a disabled
//! occupancy: [`map_graph`] uses the fabric's configured model,
//! [`map_graph_with`] takes an explicit one. At that evaluation point
//! every kind-blind model (invariant, congestion, DVFS — their factors
//! are exactly 1.0 at epoch 0) reproduces the direct fabric-primitive
//! estimates bit-for-bit, so placements are unchanged for existing
//! configs; a kind-aware model ([`crate::fabric::KindCost`]) feeds
//! accelerator affinity (photonic cold-start, crossbar interface
//! overhead, PIM feed discounts) into the placement decision —
//! `tests/kindcost_golden.rs` pins that it actually moves placements on
//! the mixed-kind config.

use anyhow::{bail, ensure};

use crate::accel::{Compute, Precision};
use crate::dse::milp::{Milp, Sense};
use crate::fabric::{CostModel, Fabric, Occupancy};
use crate::ir::{Graph, OpKind};
use crate::Result;

/// Mapping strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MapStrategy {
    RoundRobin,
    Greedy,
    Ilp,
}

/// The mapping result.
#[derive(Debug, Clone)]
pub struct Mapping {
    /// node -> tile index (None for data nodes).
    pub assign: Vec<Option<usize>>,
    /// node -> precision it runs at.
    pub precision: Vec<Precision>,
    /// Estimated makespan, fabric cycles (greedy schedule estimate).
    pub est_cycles: u64,
    /// Estimated total energy, pJ.
    pub est_energy_pj: f64,
}

/// The `Compute` descriptor of a node, if it is a compute node.
pub fn node_compute(g: &Graph, id: usize) -> Option<Compute> {
    let n = &g.nodes[id];
    match n.kind {
        OpKind::MatMul => {
            let a = g.nodes[n.inputs[0]].shape;
            Some(Compute::MatMul { m: a[0], k: a[1], n: n.shape[1] })
        }
        OpKind::Input | OpKind::Weight { .. } => None,
        _ => Some(Compute::Elementwise { elems: n.shape[0] * n.shape[1] }),
    }
}

/// Best precision a tile can run a node at, given the preference order.
///
/// The preference encodes the *numeric contract* of the compiled model:
/// an f32 model must not silently run on an analog device, an int8 model
/// may fall back to f32 (exact superset), and an analog-tolerant model
/// (noise-aware training / calibration, Sec. V.B) may use anything.
fn pick_precision(fabric: &Fabric, tile: usize, c: &Compute, prefer: Precision)
    -> Option<Precision> {
    let t = &fabric.tiles[tile];
    let chain: &[Precision] = match prefer {
        Precision::Analog => &[Precision::Analog, Precision::Int8, Precision::F32],
        Precision::Int8 => &[Precision::Int8, Precision::F32],
        Precision::F32 => &[Precision::F32],
    };
    // Elementwise on a cluster tile works regardless of accel precision.
    if matches!(c, Compute::Elementwise { .. }) && t.cluster.is_some() {
        return Some(Precision::F32);
    }
    chain.iter().copied().find(|&p| t.accel.supports(p))
}

/// Map the graph onto the fabric, estimating through the fabric's
/// configured cost model (module docs, cost-model seam).
pub fn map_graph(
    g: &Graph,
    fabric: &Fabric,
    strategy: MapStrategy,
    prefer: Precision,
) -> Result<Mapping> {
    map_graph_with(g, fabric, strategy, prefer, fabric.cost_model().as_ref())
}

/// Map the graph onto the fabric, estimating through an explicit cost
/// model at `start = 0` with a disabled occupancy.
pub fn map_graph_with(
    g: &Graph,
    fabric: &Fabric,
    strategy: MapStrategy,
    prefer: Precision,
    model: &dyn CostModel,
) -> Result<Mapping> {
    ensure!(fabric.tile_count() > 0, "empty fabric");
    match strategy {
        MapStrategy::RoundRobin => round_robin(g, fabric, prefer, model),
        MapStrategy::Greedy => greedy(g, fabric, prefer, model),
        MapStrategy::Ilp => ilp(g, fabric, prefer, model),
    }
}

fn round_robin(
    g: &Graph,
    fabric: &Fabric,
    prefer: Precision,
    model: &dyn CostModel,
) -> Result<Mapping> {
    let mut assign = vec![None; g.len()];
    let mut precision = vec![Precision::F32; g.len()];
    let mut next = 0usize;
    for id in 0..g.len() {
        let Some(c) = node_compute(g, id) else { continue };
        // find the next capable tile
        let mut placed = false;
        for off in 0..fabric.tile_count() {
            let t = (next + off) % fabric.tile_count();
            if let Some(p) = pick_precision(fabric, t, &c, prefer) {
                assign[id] = Some(t);
                precision[id] = p;
                next = t + 1;
                placed = true;
                break;
            }
        }
        if !placed {
            bail!("no tile can run node {} ({})", id, g.nodes[id].name);
        }
    }
    let (cy, en) = estimate(g, fabric, &assign, &precision, model)?;
    Ok(Mapping { assign, precision, est_cycles: cy, est_energy_pj: en })
}

fn greedy(
    g: &Graph,
    fabric: &Fabric,
    prefer: Precision,
    model: &dyn CostModel,
) -> Result<Mapping> {
    let occ = Occupancy::disabled();
    let mut assign = vec![None; g.len()];
    let mut precision = vec![Precision::F32; g.len()];
    let mut tile_free = vec![0u64; fabric.tile_count()];
    // node -> (ready time, producing tile)
    let mut ready: Vec<(u64, Option<usize>)> = vec![(0, None); g.len()];
    for id in 0..g.len() {
        let Some(c) = node_compute(g, id) else {
            // Data nodes are "ready at 0 from HBM".
            ready[id] = (0, None);
            continue;
        };
        let inputs_ready = g.nodes[id]
            .inputs
            .iter()
            .map(|&i| ready[i].0)
            .max()
            .unwrap_or(0);
        let mut best: Option<(u64, usize, Precision)> = None;
        for t in 0..fabric.tile_count() {
            let Some(p) = pick_precision(fabric, t, &c, prefer) else { continue };
            let cost = model.execute(fabric, t, &c, p, 0, &occ)?;
            // Transport from the producing tile (or HBM) of the largest
            // input.
            let src = g.nodes[id]
                .inputs
                .iter()
                .filter_map(|&i| ready[i].1)
                .last();
            let src_node = src.map(|s| fabric.tiles[s].node).unwrap_or(fabric.hbm_node);
            let tr = model.transport(fabric, src_node, fabric.tiles[t].node, cost.noc_bytes, 0, &occ);
            let start = inputs_ready.max(tile_free[t]);
            let finish = start + tr.cycles + cost.metrics.cycles;
            if best.map_or(true, |(f, _, _)| finish < f) {
                best = Some((finish, t, p));
            }
        }
        let Some((finish, t, p)) = best else {
            bail!("no tile can run node {} ({})", id, g.nodes[id].name);
        };
        assign[id] = Some(t);
        precision[id] = p;
        tile_free[t] = finish;
        ready[id] = (finish, Some(t));
    }
    let (cy, en) = estimate(g, fabric, &assign, &precision, model)?;
    Ok(Mapping { assign, precision, est_cycles: cy, est_energy_pj: en })
}

fn ilp(g: &Graph, fabric: &Fabric, prefer: Precision, model: &dyn CostModel) -> Result<Mapping> {
    let occ = Occupancy::disabled();
    // Exact makespan assignment for the matmul nodes (elementwise nodes
    // follow their producer's tile afterwards): min T s.t. per-tile
    // summed cycles <= T, each matmul on exactly one capable tile.
    let matmuls: Vec<usize> = (0..g.len())
        .filter(|&id| matches!(g.nodes[id].kind, OpKind::MatMul))
        .collect();
    ensure!(!matmuls.is_empty(), "graph has no matmuls to map");
    let mut m = Milp::new();
    let big = 1e9;
    let t_var = m.add_var(0.0, big, 1.0, false); // makespan
    // x[i][t]
    let mut x = vec![vec![None; fabric.tile_count()]; matmuls.len()];
    let mut costs = vec![vec![0.0; fabric.tile_count()]; matmuls.len()];
    let mut precs = vec![vec![Precision::F32; fabric.tile_count()]; matmuls.len()];
    for (mi, &id) in matmuls.iter().enumerate() {
        let c = node_compute(g, id).unwrap();
        for t in 0..fabric.tile_count() {
            if let Some(p) = pick_precision(fabric, t, &c, prefer) {
                let cost = model.execute(fabric, t, &c, p, 0, &occ)?;
                let tr = model.feed(fabric, t, cost.noc_bytes, 0, &occ);
                x[mi][t] = Some(m.add_var(0.0, 1.0, 0.0, true));
                costs[mi][t] = (cost.metrics.cycles + tr.cycles) as f64;
                precs[mi][t] = p;
            }
        }
        let row: Vec<(usize, f64)> = x[mi]
            .iter()
            .filter_map(|v| v.map(|v| (v, 1.0)))
            .collect();
        ensure!(!row.is_empty(), "node {id} unmappable");
        m.add_constraint(row, Sense::Eq, 1.0);
        // Valid inequality: a node's own cost on its chosen tile is a
        // lower bound on the makespan (implied by the per-tile load rows
        // for every integral point, so the optimum is unchanged). The LP
        // relaxation without it bounds T only by total-load/tiles, which
        // collapses to near-zero on symmetric instances — on the
        // homogeneous config the branch-and-bound then enumerates
        // permutations of equivalent assignments until it trips its node
        // limit (observed: 2 nodes on 15 equal tiles already costs ~450
        // B&B nodes, 8 nodes exceeds the 100k cap). With the per-node
        // rows the first integral incumbent matches the LP bound and the
        // search collapses to a handful of nodes.
        let jrow: Vec<(usize, f64)> = x[mi]
            .iter()
            .enumerate()
            .filter_map(|(t, v)| v.map(|v| (v, costs[mi][t])))
            .chain([(t_var, -1.0)])
            .collect();
        m.add_constraint(jrow, Sense::Le, 0.0);
    }
    for t in 0..fabric.tile_count() {
        let mut row: Vec<(usize, f64)> = Vec::new();
        for (mi, _) in matmuls.iter().enumerate() {
            if let Some(v) = x[mi][t] {
                row.push((v, costs[mi][t]));
            }
        }
        if !row.is_empty() {
            row.push((t_var, -1.0));
            m.add_constraint(row, Sense::Le, 0.0);
        }
    }
    let sol = m
        .minimize()?
        .ok_or_else(|| anyhow::anyhow!("ILP mapping infeasible"))?;
    let mut assign = vec![None; g.len()];
    let mut precision = vec![Precision::F32; g.len()];
    for (mi, &id) in matmuls.iter().enumerate() {
        for t in 0..fabric.tile_count() {
            if let Some(v) = x[mi][t] {
                if sol.x[v] > 0.5 {
                    assign[id] = Some(t);
                    precision[id] = precs[mi][t];
                }
            }
        }
    }
    // Elementwise nodes follow their first mapped producer (or tile 0).
    for id in 0..g.len() {
        if assign[id].is_some() {
            continue;
        }
        let Some(c) = node_compute(g, id) else { continue };
        let producer = g.nodes[id]
            .inputs
            .iter()
            .filter_map(|&i| assign[i])
            .next();
        let mut t = producer.unwrap_or(0);
        if pick_precision(fabric, t, &c, prefer).is_none() {
            t = (0..fabric.tile_count())
                .find(|&tt| pick_precision(fabric, tt, &c, prefer).is_some())
                .ok_or_else(|| anyhow::anyhow!("node {id} unmappable"))?;
        }
        assign[id] = Some(t);
        precision[id] = pick_precision(fabric, t, &c, prefer).unwrap();
    }
    let (cy, en) = estimate(g, fabric, &assign, &precision, model)?;
    Ok(Mapping { assign, precision, est_cycles: cy, est_energy_pj: en })
}

/// Serial-schedule estimate of a mapping (the lowering/coordinator
/// recompute this precisely with overlap; this is the mapper's metric).
fn estimate(
    g: &Graph,
    fabric: &Fabric,
    assign: &[Option<usize>],
    precision: &[Precision],
    model: &dyn CostModel,
) -> Result<(u64, f64)> {
    let occ = Occupancy::disabled();
    let mut cycles = 0u64;
    let mut energy = 0.0f64;
    let mut loc: Vec<Option<usize>> = vec![None; g.len()];
    for id in 0..g.len() {
        let Some(t) = assign[id] else { continue };
        let c = node_compute(g, id).unwrap();
        let cost = model.execute(fabric, t, &c, precision[id], 0, &occ)?;
        let src = g.nodes[id].inputs.iter().filter_map(|&i| loc[i]).last();
        let src_node = src.map(|s| fabric.tiles[s].node).unwrap_or(fabric.hbm_node);
        let tr = model.transport(fabric, src_node, fabric.tiles[t].node, cost.noc_bytes, 0, &occ);
        cycles += cost.metrics.cycles + tr.cycles;
        energy += cost.metrics.total_energy_pj() + tr.total_energy_pj();
        loc[id] = Some(t);
    }
    Ok((cycles, energy))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FabricConfig;
    use crate::workloads;

    fn fabric() -> Fabric {
        Fabric::build(
            FabricConfig::from_toml(
                r#"
[noc]
width = 3
height = 3

[[cu]]
kind = "npu"
template = "B"
count = 3

[[cu]]
kind = "crossbar"
template = "A"
count = 2

[[cu]]
kind = "cpu"
template = "C"
count = 1
"#,
            )
            .unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn all_strategies_produce_complete_mappings() {
        let g = workloads::mlp(4, 64, &[32], 10, 1).unwrap();
        let f = fabric();
        for s in [MapStrategy::RoundRobin, MapStrategy::Greedy, MapStrategy::Ilp] {
            let m = map_graph(&g, &f, s, Precision::Int8).unwrap();
            for id in 0..g.len() {
                let is_compute = node_compute(&g, id).is_some();
                assert_eq!(m.assign[id].is_some(), is_compute, "{s:?} node {id}");
            }
            assert!(m.est_cycles > 0);
            assert!(m.est_energy_pj > 0.0);
        }
    }

    #[test]
    fn assignments_respect_capabilities() {
        let g = workloads::mlp(4, 64, &[32], 10, 2).unwrap();
        let f = fabric();
        let m = map_graph(&g, &f, MapStrategy::Greedy, Precision::Analog).unwrap();
        for id in 0..g.len() {
            if let Some(t) = m.assign[id] {
                let c = node_compute(&g, id).unwrap();
                let on_cluster = matches!(c, Compute::Elementwise { .. })
                    && f.tiles[t].cluster.is_some();
                assert!(
                    on_cluster || f.tiles[t].accel.supports(m.precision[id]),
                    "node {id} on tile {t} at {:?}",
                    m.precision[id]
                );
            }
        }
    }

    #[test]
    fn greedy_not_worse_than_round_robin() {
        let g = workloads::vit(&workloads::VitParams::default(), 1).unwrap();
        let f = fabric();
        let rr = map_graph(&g, &f, MapStrategy::RoundRobin, Precision::Int8).unwrap();
        let gr = map_graph(&g, &f, MapStrategy::Greedy, Precision::Int8).unwrap();
        assert!(
            gr.est_cycles <= rr.est_cycles,
            "greedy {} vs rr {}",
            gr.est_cycles,
            rr.est_cycles
        );
    }

    #[test]
    fn ilp_balances_matmuls() {
        let g = workloads::mlp(8, 64, &[64, 64], 10, 3).unwrap();
        let f = fabric();
        let m = map_graph(&g, &f, MapStrategy::Ilp, Precision::Int8).unwrap();
        // 3 matmuls over >=3 capable tiles: the makespan optimum never
        // stacks all on one tile.
        let mut used = std::collections::HashSet::new();
        for id in 0..g.len() {
            if matches!(g.nodes[id].kind, OpKind::MatMul) {
                used.insert(m.assign[id].unwrap());
            }
        }
        assert!(used.len() >= 2, "{used:?}");
    }

    #[test]
    fn ilp_handles_symmetric_fabrics() {
        // Homogeneous tiles make the assignment MILP fully symmetric;
        // without the per-node makespan rows the B&B enumerated
        // equivalent permutations until its node limit. A depth-1 ViT has
        // 8 matmuls — solve must stay effectively instant.
        let f = Fabric::build(
            FabricConfig::from_toml(
                "[noc]\nwidth = 4\nheight = 4\n\
                 [[cu]]\nkind = \"npu\"\ntemplate = \"B\"\ncount = 15\n",
            )
            .unwrap(),
        )
        .unwrap();
        let p = workloads::VitParams { depth: 1, ..Default::default() };
        let g = workloads::vit(&p, 5).unwrap();
        let m = map_graph(&g, &f, MapStrategy::Ilp, Precision::Int8).unwrap();
        let mut used = std::collections::HashSet::new();
        for id in 0..g.len() {
            if matches!(g.nodes[id].kind, OpKind::MatMul) {
                used.insert(m.assign[id].unwrap());
            }
        }
        // 8 matmuls over 15 equal tiles: optimum spreads them out.
        assert!(used.len() >= 4, "{used:?}");
    }

    #[test]
    fn cost_model_seam_preserves_kind_blind_placements() {
        // map_graph routes estimates through the fabric's cost model at
        // start 0 with occupancy disabled: for every kind-blind model
        // that point prices exactly like the direct fabric primitives,
        // so the mapping (assignment, precisions, estimates) is
        // bit-identical to an explicit InvariantCost walk.
        use crate::fabric::{CongestionKnobs, DvfsKnobs, InvariantCost, VaryingCost};
        let g = workloads::mlp(4, 64, &[32], 10, 5).unwrap();
        let f = fabric();
        for s in [MapStrategy::RoundRobin, MapStrategy::Greedy, MapStrategy::Ilp] {
            let base = map_graph_with(&g, &f, s, Precision::Int8, &InvariantCost).unwrap();
            let via_default = map_graph(&g, &f, s, Precision::Int8).unwrap();
            let varying = VaryingCost::congestion_dvfs(
                512,
                CongestionKnobs::default(),
                DvfsKnobs::default(),
            );
            let via_varying = map_graph_with(&g, &f, s, Precision::Int8, &varying).unwrap();
            for m in [&via_default, &via_varying] {
                assert_eq!(m.assign, base.assign, "{s:?}");
                assert_eq!(m.precision, base.precision, "{s:?}");
                assert_eq!(m.est_cycles, base.est_cycles, "{s:?}");
                assert_eq!(m.est_energy_pj.to_bits(), base.est_energy_pj.to_bits(), "{s:?}");
            }
        }
    }

    #[test]
    fn analog_preference_uses_crossbars() {
        let g = workloads::mlp(4, 64, &[32], 10, 4).unwrap();
        let f = fabric();
        let m = map_graph(&g, &f, MapStrategy::Greedy, Precision::Analog).unwrap();
        let analog_used = (0..g.len()).any(|id| {
            m.assign[id].map_or(false, |t| f.tiles[t].accel.name() == "nvm-crossbar")
                && m.precision[id] == Precision::Analog
        });
        assert!(analog_used);
    }
}
