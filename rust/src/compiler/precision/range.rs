//! Value-range analysis: sound interval propagation through the IR
//! (TAFFO's VRA stage).

use crate::ir::{Graph, OpKind};
use crate::Result;

/// A closed interval `[lo, hi]`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Interval {
    pub lo: f64,
    pub hi: f64,
}

impl Interval {
    pub fn new(lo: f64, hi: f64) -> Self {
        assert!(lo <= hi, "bad interval [{lo}, {hi}]");
        Interval { lo, hi }
    }

    pub fn point(v: f64) -> Self {
        Interval { lo: v, hi: v }
    }

    pub fn contains(&self, v: f64) -> bool {
        // Tolerance for f32->f64 roundoff at the bounds.
        let eps = 1e-6 * (1.0 + self.hi.abs().max(self.lo.abs()));
        v >= self.lo - eps && v <= self.hi + eps
    }

    pub fn max_abs(&self) -> f64 {
        self.lo.abs().max(self.hi.abs())
    }

    pub fn add(&self, o: &Interval) -> Interval {
        Interval::new(self.lo + o.lo, self.hi + o.hi)
    }

    pub fn mul_scalar(&self, s: f64) -> Interval {
        let (a, b) = (self.lo * s, self.hi * s);
        Interval::new(a.min(b), a.max(b))
    }

    pub fn union(&self, o: &Interval) -> Interval {
        Interval::new(self.lo.min(o.lo), self.hi.max(o.hi))
    }

    pub fn relu(&self) -> Interval {
        Interval::new(self.lo.max(0.0), self.hi.max(0.0))
    }
}

/// Propagate input-range hints through the graph; returns one interval
/// per node (sound: the true value of every element lies inside).
///
/// Matmul bound: with x ∈ [lo, hi] per element and the *actual* weight
/// matrix w, each output = Σ_k x_k w_kj is bounded per column by
/// Σ_k max(lo·w, hi·w) — we use the column's positive/negative mass,
/// then take the worst column (per-tensor interval).
pub fn analyze_ranges(g: &Graph, input_hints: &[Interval]) -> Result<Vec<Interval>> {
    g.validate()?;
    let mut iv: Vec<Interval> = Vec::with_capacity(g.len());
    let mut next_input = 0;
    for node in &g.nodes {
        let get = |id: usize| -> Interval { iv[id] };
        let out = match &node.kind {
            OpKind::Input => {
                anyhow::ensure!(next_input < input_hints.len(), "missing hint");
                let h = input_hints[next_input];
                next_input += 1;
                h
            }
            OpKind::Weight { idx } => {
                let w = &g.weights[*idx];
                let lo = w.data.iter().cloned().fold(f32::INFINITY, f32::min) as f64;
                let hi = w.data.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
                Interval::new(lo.min(hi), hi.max(lo))
            }
            OpKind::MatMul => {
                let x = get(node.inputs[0]);
                // Use actual weights when rhs is a Weight node (the
                // common case); otherwise fall back to interval product.
                if let Some(idx) = g.matmul_weight_idx(node) {
                    let w = &g.weights[idx];
                    let [k, n] = w.shape;
                    let mut worst_lo = 0.0f64;
                    let mut worst_hi = 0.0f64;
                    for j in 0..n {
                        let (mut lo, mut hi) = (0.0f64, 0.0f64);
                        for i in 0..k {
                            let wij = w.data[i * n + j] as f64;
                            let (a, b) = (x.lo * wij, x.hi * wij);
                            lo += a.min(b);
                            hi += a.max(b);
                        }
                        worst_lo = worst_lo.min(lo);
                        worst_hi = worst_hi.max(hi);
                    }
                    Interval::new(worst_lo, worst_hi)
                } else {
                    let y = get(node.inputs[1]);
                    let k = g.nodes[node.inputs[0]].shape[1] as f64;
                    let cands = [x.lo * y.lo, x.lo * y.hi, x.hi * y.lo, x.hi * y.hi];
                    let lo = cands.iter().cloned().fold(f64::INFINITY, f64::min) * k;
                    let hi = cands.iter().cloned().fold(f64::NEG_INFINITY, f64::max) * k;
                    Interval::new(lo.min(0.0), hi.max(0.0))
                }
            }
            OpKind::BiasAdd => {
                let x = get(node.inputs[0]);
                let b = get(node.inputs[1]);
                x.add(&b)
            }
            OpKind::Add => get(node.inputs[0]).add(&get(node.inputs[1])),
            OpKind::Relu => get(node.inputs[0]).relu(),
            OpKind::Gelu => {
                let x = get(node.inputs[0]);
                // gelu(x) ∈ [min(0, lo) - 0.17, max(0, hi)]
                Interval::new(x.lo.min(0.0) - 0.17, x.hi.max(0.0))
            }
            OpKind::Softmax => Interval::new(0.0, 1.0),
            OpKind::LayerNorm { gain, bias } => {
                // |(x-mu)/sigma| <= sqrt(n-1); scaled by gain, shifted by
                // bias (actual weight values).
                let n = node.shape[1] as f64;
                let z = (n - 1.0).sqrt();
                let gmax = g.weights[*gain]
                    .data
                    .iter()
                    .map(|v| v.abs())
                    .fold(0.0f32, f32::max) as f64;
                let bmax = g.weights[*bias]
                    .data
                    .iter()
                    .map(|v| v.abs())
                    .fold(0.0f32, f32::max) as f64;
                Interval::new(-z * gmax - bmax, z * gmax + bmax)
            }
            OpKind::MeanPool { .. } => get(node.inputs[0]),
            OpKind::Scale { factor } => get(node.inputs[0]).mul_scalar(*factor as f64),
        };
        iv.push(out);
    }
    Ok(iv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::interp::{run_with, Mat};
    use crate::workloads;

    #[test]
    fn interval_ops() {
        let a = Interval::new(-1.0, 2.0);
        let b = Interval::new(0.5, 1.0);
        assert_eq!(a.add(&b), Interval::new(-0.5, 3.0));
        assert_eq!(a.relu(), Interval::new(0.0, 2.0));
        assert_eq!(a.mul_scalar(-2.0), Interval::new(-4.0, 2.0));
        assert_eq!(a.union(&b), Interval::new(-1.0, 2.0));
        assert!(a.contains(0.0) && !a.contains(3.0));
        assert_eq!(a.max_abs(), 2.0);
    }

    /// THE soundness property: empirical values never escape the
    /// propagated intervals (sampled over random inputs within hints).
    #[test]
    fn ranges_are_sound_for_mlp_and_vit() {
        let graphs = vec![
            workloads::mlp(4, 32, &[24, 16], 8, 1).unwrap(),
            workloads::vit(&workloads::VitParams::default(), 2).unwrap(),
        ];
        for g in graphs {
            let hint = Interval::new(-3.0, 3.0);
            let iv = analyze_ranges(&g, &[hint]).unwrap();
            let shape = g.nodes[0].shape;
            let mut rng = crate::sim::Rng::new(42);
            for _ in 0..3 {
                let data: Vec<f32> = (0..shape[0] * shape[1])
                    .map(|_| rng.range_f64(-3.0, 3.0) as f32)
                    .collect();
                let x = Mat::new(shape, data).unwrap();
                run_with(&g, &[x], |id, m| {
                    for &v in &m.data {
                        assert!(
                            iv[id].contains(v as f64),
                            "node {} ({}) value {v} outside {:?}",
                            id,
                            g.nodes[id].name,
                            iv[id]
                        );
                    }
                })
                .unwrap();
            }
        }
    }

    #[test]
    fn softmax_range_is_unit() {
        let g = workloads::vit(&workloads::VitParams::default(), 3).unwrap();
        let iv = analyze_ranges(&g, &[Interval::new(-1.0, 1.0)]).unwrap();
        for n in &g.nodes {
            if matches!(n.kind, OpKind::Softmax) {
                assert_eq!(iv[n.id], Interval::new(0.0, 1.0));
            }
        }
    }

    #[test]
    fn tighter_hints_tighter_ranges() {
        let g = workloads::mlp(2, 32, &[16], 4, 5).unwrap();
        let wide = analyze_ranges(&g, &[Interval::new(-10.0, 10.0)]).unwrap();
        let tight = analyze_ranges(&g, &[Interval::new(-1.0, 1.0)]).unwrap();
        let out = g.outputs[0];
        assert!(tight[out].max_abs() < wide[out].max_abs());
    }
}
