//! TAFFO-style precision tuning (paper Sec. V.C, Fig. 2).
//!
//! The pipeline re-implements TAFFO's mechanism over our NN IR instead of
//! LLVM/MLIR (substitution table, DESIGN.md §2):
//!
//! 1. **Hints** — the programmer annotates input value ranges.
//! 2. **Value-range analysis** ([`range`]) — interval arithmetic
//!    propagates sound bounds through every node.
//! 3. **Type allocation** ([`fixedpoint`]) — per-node fixed-point Qm.n
//!    formats chosen from the ranges.
//! 4. **Conversion + static estimation** ([`tuner`]) — the fixed-point
//!    execution is *simulated* on the IR interpreter to measure true
//!    error, and cost deltas come from the accelerator models.

pub mod fixedpoint;
pub mod range;
pub mod tuner;

pub use fixedpoint::FixedFormat;
pub use range::{analyze_ranges, Interval};
pub use tuner::{tune, TuneReport, TunerConfig};
