//! The precision tuner: greedy per-node bit-width allocation under an
//! output-error budget, with measured (simulated) error and accelerator-
//! model cost estimation — TAFFO's "static estimation of the performance
//! impact" realized against our fabric models.

use crate::accel::{Accelerator, Compute, DigitalNpu, Precision};
use crate::ir::interp::{self, Mat};
use crate::ir::{Graph, OpKind};
use crate::Result;

use super::fixedpoint::FixedFormat;
use super::range::{analyze_ranges, Interval};

/// Tuner configuration.
#[derive(Debug, Clone)]
pub struct TunerConfig {
    /// Input range hints (one per graph input).
    pub input_hints: Vec<Interval>,
    /// Relative output error budget (vs f32 reference, max-abs / scale).
    pub error_budget: f32,
    /// Candidate word sizes, tried narrow-first per node.
    pub words: Vec<u32>,
}

impl Default for TunerConfig {
    fn default() -> Self {
        TunerConfig {
            input_hints: vec![Interval::new(-4.0, 4.0)],
            error_budget: 0.05,
            words: vec![8, 16, 32],
        }
    }
}

/// Per-node allocation + measured results.
#[derive(Debug, Clone)]
pub struct TuneReport {
    /// Chosen format per node (None = stays f32).
    pub formats: Vec<Option<FixedFormat>>,
    /// Measured relative output error with the final allocation.
    pub measured_rel_err: f32,
    /// Estimated speedup vs all-f32 on the digital NPU model.
    pub est_speedup: f64,
    /// Estimated energy ratio (tuned / f32).
    pub est_energy_ratio: f64,
    /// Nodes narrowed below 32 bits.
    pub narrowed: usize,
}

fn simulate(g: &Graph, input: &Mat, formats: &[Option<FixedFormat>]) -> Result<Vec<Mat>> {
    interp::run_with(g, std::slice::from_ref(input), |id, m| {
        if let Some(f) = formats[id] {
            for v in &mut m.data {
                *v = f.quantize(*v);
            }
        }
    })
}

fn rel_err(g: &Graph, reference: &[Mat], input: &Mat, formats: &[Option<FixedFormat>])
    -> Result<f32> {
    let got = simulate(g, input, formats)?;
    let mut worst = 0.0f32;
    for (r, q) in reference.iter().zip(&got) {
        worst = worst.max(q.rel_err(r));
    }
    Ok(worst)
}

/// Estimated (cycles, energy) of the graph's matmuls on the NPU, given a
/// word size per matmul node (<=8 -> int8 path, else f32 path).
fn est_cost(g: &Graph, formats: &[Option<FixedFormat>]) -> (f64, f64) {
    let npu = DigitalNpu::default();
    let (mut cycles, mut energy) = (0.0, 0.0);
    for n in &g.nodes {
        if n.kind != OpKind::MatMul {
            continue;
        }
        let a = g.nodes[n.inputs[0]].shape;
        let c = Compute::MatMul { m: a[0], k: a[1], n: n.shape[1] };
        let p = match formats[n.id] {
            Some(f) if f.word_bits() <= 8 => Precision::Int8,
            _ => Precision::F32,
        };
        let m = npu.cost(&c, p);
        cycles += m.cycles as f64;
        energy += m.total_energy_pj();
    }
    (cycles, energy)
}

/// Run the TAFFO pipeline: ranges -> allocation -> greedy narrowing under
/// the error budget (validated on `calib`), -> static cost estimate.
pub fn tune(g: &Graph, calib: &Mat, cfg: &TunerConfig) -> Result<TuneReport> {
    let ranges = analyze_ranges(g, &cfg.input_hints)?;
    let reference = interp::run(g, std::slice::from_ref(calib))?;

    // Start all-f32 (None), then greedily narrow each node to the
    // narrowest word that keeps the *cumulative* measured error in budget.
    let mut formats: Vec<Option<FixedFormat>> = vec![None; g.len()];
    let mut narrowed = 0;
    for id in 0..g.len() {
        // Inputs/weights are converted by the surrounding code in TAFFO;
        // here every value-producing node is a candidate.
        if matches!(g.nodes[id].kind, OpKind::Input) {
            continue;
        }
        for &w in &cfg.words {
            let Some(f) = FixedFormat::for_range(&ranges[id], w) else {
                continue;
            };
            let mut trial = formats.clone();
            trial[id] = Some(f);
            if rel_err(g, &reference, calib, &trial)? <= cfg.error_budget {
                formats = trial;
                if w < 32 {
                    narrowed += 1;
                }
                break;
            }
        }
    }

    let measured = rel_err(g, &reference, calib, &formats)?;
    let (c_f32, e_f32) = est_cost(g, &vec![None; g.len()]);
    let (c_tuned, e_tuned) = est_cost(g, &formats);
    Ok(TuneReport {
        formats,
        measured_rel_err: measured,
        est_speedup: if c_tuned > 0.0 { c_f32 / c_tuned } else { 1.0 },
        est_energy_ratio: if e_f32 > 0.0 { e_tuned / e_f32 } else { 1.0 },
        narrowed,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;

    fn calib(g: &Graph) -> Mat {
        let s = g.nodes[0].shape;
        let mut rng = crate::sim::Rng::new(77);
        Mat::new(s, (0..s[0] * s[1]).map(|_| rng.range_f64(-2.0, 2.0) as f32).collect())
            .unwrap()
    }

    #[test]
    fn tuned_error_within_budget() {
        let g = workloads::mlp(4, 32, &[24], 8, 1).unwrap();
        let cfg = TunerConfig { error_budget: 0.05, ..Default::default() };
        let rep = tune(&g, &calib(&g), &cfg).unwrap();
        assert!(rep.measured_rel_err <= 0.05, "{}", rep.measured_rel_err);
        assert!(rep.narrowed > 0, "something must narrow");
    }

    #[test]
    fn e6_speedup_energy_shape() {
        // Narrowing matmuls to <=8-bit must speed up and save energy on
        // the NPU model (the E6 headline shape).
        let g = workloads::mlp(8, 64, &[48], 10, 2).unwrap();
        let cfg = TunerConfig { error_budget: 0.20, ..Default::default() };
        let rep = tune(&g, &calib(&g), &cfg).unwrap();
        assert!(rep.est_speedup > 1.2, "{}", rep.est_speedup);
        assert!(rep.est_energy_ratio < 0.9, "{}", rep.est_energy_ratio);
    }

    #[test]
    fn tight_budget_narrows_less() {
        let g = workloads::mlp(4, 32, &[24], 8, 3).unwrap();
        let x = calib(&g);
        let loose = tune(&g, &x, &TunerConfig { error_budget: 0.3, ..Default::default() })
            .unwrap();
        let tight = tune(&g, &x, &TunerConfig { error_budget: 0.001, ..Default::default() })
            .unwrap();
        let bits = |r: &TuneReport| -> u32 {
            r.formats.iter().flatten().map(|f| f.word_bits()).sum()
        };
        // tighter budget -> wider words (or fewer narrowed nodes)
        assert!(
            tight.measured_rel_err <= 0.001 + 1e-6,
            "{}",
            tight.measured_rel_err
        );
        assert!(bits(&tight) >= bits(&loose) || tight.narrowed <= loose.narrowed);
    }

    #[test]
    fn formats_respect_ranges() {
        let g = workloads::mlp(2, 16, &[8], 4, 4).unwrap();
        let cfg = TunerConfig::default();
        let ranges = analyze_ranges(&g, &cfg.input_hints).unwrap();
        let rep = tune(&g, &calib(&g), &cfg).unwrap();
        for (id, f) in rep.formats.iter().enumerate() {
            if let Some(f) = f {
                assert!(
                    f.max_value() + f.step() >= ranges[id].max_abs(),
                    "node {id}: format {f:?} cannot hold {:?}",
                    ranges[id]
                );
            }
        }
    }
}
