//! Fixed-point type allocation and simulated conversion (TAFFO's
//! data-type allocation + code conversion stages).

use super::range::Interval;

/// A signed fixed-point format Qm.n: 1 sign bit, `int_bits` integer bits,
/// `frac_bits` fractional bits (word = 1 + m + n).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FixedFormat {
    pub int_bits: u32,
    pub frac_bits: u32,
}

impl FixedFormat {
    /// Smallest format of `word_bits` total that covers `range` without
    /// overflow: integer bits from the magnitude bound, remainder goes to
    /// fraction. Returns None if the range cannot fit at all.
    pub fn for_range(range: &Interval, word_bits: u32) -> Option<FixedFormat> {
        assert!(word_bits >= 2);
        let m = range.max_abs().max(1e-30);
        // need int_bits >= ceil(log2(m + 1ulp)); +1e-9 guards exact powers
        let int_bits = m.log2().floor().max(-1.0) as i64 + 1;
        let int_bits = int_bits.max(0) as u32;
        if int_bits > word_bits - 1 {
            return None;
        }
        Some(FixedFormat { int_bits, frac_bits: word_bits - 1 - int_bits })
    }

    pub fn word_bits(&self) -> u32 {
        1 + self.int_bits + self.frac_bits
    }

    /// Representable magnitude bound.
    pub fn max_value(&self) -> f64 {
        2f64.powi(self.int_bits as i32) - self.step()
    }

    /// Quantization step (1 ulp).
    pub fn step(&self) -> f64 {
        2f64.powi(-(self.frac_bits as i32))
    }

    /// Worst-case rounding error per value.
    pub fn error_bound(&self) -> f64 {
        self.step() / 2.0
    }

    /// Round-to-nearest conversion with saturation.
    pub fn quantize(&self, v: f32) -> f32 {
        let step = self.step();
        let q = (v as f64 / step).round() * step;
        let lim = self.max_value();
        q.clamp(-lim - self.step(), lim) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn format_fits_range() {
        let f = FixedFormat::for_range(&Interval::new(-3.0, 5.0), 16).unwrap();
        assert_eq!(f.int_bits, 3); // 5 needs 3 integer bits
        assert_eq!(f.frac_bits, 12);
        assert_eq!(f.word_bits(), 16);
        assert!(f.max_value() >= 5.0);
    }

    #[test]
    fn subunit_ranges_get_all_fraction() {
        let f = FixedFormat::for_range(&Interval::new(-0.4, 0.4), 8).unwrap();
        assert_eq!(f.int_bits, 0);
        assert_eq!(f.frac_bits, 7);
    }

    #[test]
    fn huge_range_cannot_fit_tiny_word() {
        assert!(FixedFormat::for_range(&Interval::new(-1e9, 1e9), 8).is_none());
    }

    #[test]
    fn quantize_rounds_and_saturates() {
        let f = FixedFormat { int_bits: 2, frac_bits: 5 }; // Q2.5, step 1/32
        assert_eq!(f.quantize(0.5), 0.5);
        assert!((f.quantize(0.51) - 0.5).abs() <= f.step() as f32);
        assert!(f.quantize(100.0) <= f.max_value() as f32);
        assert!(f.quantize(-100.0) >= (-f.max_value() - f.step()) as f32);
    }

    #[test]
    fn error_bound_holds_for_random_values() {
        let f = FixedFormat::for_range(&Interval::new(-2.0, 2.0), 12).unwrap();
        let mut rng = crate::sim::Rng::new(9);
        for _ in 0..1000 {
            let v = rng.range_f64(-2.0, 2.0) as f32;
            let q = f.quantize(v);
            assert!(
                ((q - v).abs() as f64) <= f.error_bound() + 1e-9,
                "{v} -> {q}"
            );
        }
    }

    #[test]
    fn wider_word_smaller_error() {
        let r = Interval::new(-1.0, 1.0);
        let f8 = FixedFormat::for_range(&r, 8).unwrap();
        let f16 = FixedFormat::for_range(&r, 16).unwrap();
        assert!(f16.error_bound() < f8.error_bound() / 100.0);
    }
}
