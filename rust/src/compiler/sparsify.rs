//! Structured (block-wise) sparsification — paper Sec. V.B "structured
//! (block-wise or filter-level) approaches" and the Sec. III
//! "microarchitectural support for tensor sparsification".
//!
//! Mirrors the block-ELL encoder of the L1 kernel
//! (python/compile/kernels/blocksparse.py): weight matrices are cut into
//! (bk × bn) blocks, the lowest-Frobenius-norm blocks of each output
//! block-column are zeroed to reach the target density, and the surviving
//! density is what a sparse-capable CU's fetch/compute cost scales with.

use crate::ir::Graph;

/// Per-graph sparsification report.
#[derive(Debug, Clone, PartialEq)]
pub struct SparsifyReport {
    /// Surviving block fraction (weighted by block count).
    pub density: f64,
    pub blocks_kept: usize,
    pub blocks_total: usize,
    /// Fraction of weight L2 norm retained.
    pub norm_retained: f64,
}

/// Apply block sparsification to all prunable (non-vector) weights whose
/// dimensions are block-aligned; others are left dense.
pub fn block_sparsify(g: &mut Graph, bk: usize, bn: usize, keep_density: f64)
    -> SparsifyReport {
    assert!(keep_density > 0.0 && keep_density <= 1.0);
    let (mut kept, mut total) = (0usize, 0usize);
    let (mut n_before, mut n_after) = (0.0f64, 0.0f64);
    for w in &mut g.weights {
        let [k, n] = w.shape;
        if k == 1 || k % bk != 0 || n % bn != 0 {
            continue;
        }
        n_before += w.data.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>();
        let (kb, nb) = (k / bk, n / bn);
        for j in 0..nb {
            // Rank this block-column's K-blocks by Frobenius norm.
            let mut norms: Vec<(f64, usize)> = (0..kb)
                .map(|i| {
                    let mut s = 0.0f64;
                    for r in 0..bk {
                        for c in 0..bn {
                            let v = w.data[(i * bk + r) * n + j * bn + c] as f64;
                            s += v * v;
                        }
                    }
                    (s, i)
                })
                .collect();
            norms.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
            let keep = ((keep_density * kb as f64).ceil() as usize).max(1);
            total += kb;
            kept += keep.min(kb);
            for &(_, i) in norms.iter().skip(keep) {
                for r in 0..bk {
                    for c in 0..bn {
                        w.data[(i * bk + r) * n + j * bn + c] = 0.0;
                    }
                }
            }
        }
        n_after += w.data.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>();
    }
    SparsifyReport {
        density: if total == 0 { 1.0 } else { kept as f64 / total as f64 },
        blocks_kept: kept,
        blocks_total: total,
        norm_retained: if n_before == 0.0 { 1.0 } else { (n_after / n_before).sqrt() },
    }
}

/// Measured block density of one weight matrix (fraction of blocks with
/// any nonzero).
pub fn block_density(w: &crate::ir::WeightTensor, bk: usize, bn: usize) -> f64 {
    let [k, n] = w.shape;
    if k % bk != 0 || n % bn != 0 {
        return 1.0;
    }
    let (kb, nb) = (k / bk, n / bn);
    let mut nonzero = 0;
    for i in 0..kb {
        for j in 0..nb {
            let any = (0..bk).any(|r| {
                (0..bn).any(|c| w.data[(i * bk + r) * n + j * bn + c] != 0.0)
            });
            if any {
                nonzero += 1;
            }
        }
    }
    nonzero as f64 / (kb * nb) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;

    #[test]
    fn hits_target_density() {
        let mut g = workloads::mlp(2, 64, &[64], 16, 1).unwrap();
        let rep = block_sparsify(&mut g, 16, 16, 0.5);
        assert!((rep.density - 0.5).abs() < 0.15, "{}", rep.density);
        for w in &g.weights {
            if w.shape[0] > 1 && w.shape[0] % 16 == 0 && w.shape[1] % 16 == 0 {
                let d = block_density(w, 16, 16);
                assert!(d <= 0.66, "{d}");
            }
        }
    }

    #[test]
    fn full_density_is_identity() {
        let mut g = workloads::mlp(2, 32, &[32], 8, 2).unwrap();
        let before = g.weights.clone();
        let rep = block_sparsify(&mut g, 16, 8, 1.0);
        assert_eq!(rep.density, 1.0);
        for (a, b) in g.weights.iter().zip(&before) {
            assert_eq!(a.data, b.data);
        }
    }

    #[test]
    fn misaligned_weights_left_dense() {
        let mut g = workloads::mlp(2, 50, &[30], 7, 3).unwrap();
        let rep = block_sparsify(&mut g, 16, 16, 0.25);
        // 50x30, 30x7 are not 16-aligned -> untouched.
        assert_eq!(rep.blocks_total, 0);
        assert_eq!(rep.density, 1.0);
    }

    #[test]
    fn keeps_high_norm_blocks() {
        let mut g = workloads::mlp(2, 32, &[32], 8, 4).unwrap();
        // Boost one block so it must survive.
        {
            let w = &mut g.weights[0]; // 32x32
            for r in 0..16 {
                for c in 0..16 {
                    w.data[r * 32 + c] = 10.0;
                }
            }
        }
        block_sparsify(&mut g, 16, 16, 0.5);
        let w = &g.weights[0];
        assert!(w.data[0] == 10.0, "boosted block survived");
        let rep_norm: f64 = w.data.iter().map(|&v| (v as f64).powi(2)).sum();
        assert!(rep_norm >= 16.0 * 16.0 * 100.0 * 0.99);
    }
}
