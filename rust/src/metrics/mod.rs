//! Metrics: energy / latency / area accounting and the roofline model used
//! to sanity-check every accelerator estimate (Williams et al., cited as
//! [60] in the paper).

use std::collections::BTreeMap;
use std::fmt;

/// Energy bookkeeping category.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Category {
    Compute,
    Noc,
    Dram,
    Sram,
    Adc,
    Laser,
    Leakage,
    Host,
}

impl fmt::Display for Category {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Category::Compute => "compute",
            Category::Noc => "noc",
            Category::Dram => "dram",
            Category::Sram => "sram",
            Category::Adc => "adc",
            Category::Laser => "laser",
            Category::Leakage => "leakage",
            Category::Host => "host",
        };
        f.write_str(s)
    }
}

/// Accumulated energy (pJ, by category), cycles, and op/byte counters.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Metrics {
    energy_pj: BTreeMap<Category, f64>,
    pub cycles: u64,
    pub ops: u64,
    pub bytes_moved: u64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_energy(&mut self, cat: Category, pj: f64) {
        debug_assert!(pj >= 0.0, "negative energy {pj} for {cat}");
        *self.energy_pj.entry(cat).or_insert(0.0) += pj;
    }

    pub fn energy(&self, cat: Category) -> f64 {
        self.energy_pj.get(&cat).copied().unwrap_or(0.0)
    }

    pub fn total_energy_pj(&self) -> f64 {
        self.energy_pj.values().sum()
    }

    /// Merge another metrics record (parallel components run concurrently,
    /// so the caller decides whether cycles add or max; this adds).
    pub fn absorb(&mut self, other: &Metrics) {
        for (cat, pj) in &other.energy_pj {
            self.add_energy(*cat, *pj);
        }
        self.cycles += other.cycles;
        self.ops += other.ops;
        self.bytes_moved += other.bytes_moved;
    }

    /// Copy with a replaced cycle count (for overlap accounting where the
    /// caller merges latency separately from energy).
    pub fn with_cycles(&self, cycles: u64) -> Metrics {
        let mut m = self.clone();
        m.cycles = cycles;
        m
    }

    /// Merge keeping the max latency (components in parallel).
    pub fn absorb_parallel(&mut self, other: &Metrics) {
        for (cat, pj) in &other.energy_pj {
            self.add_energy(*cat, *pj);
        }
        self.cycles = self.cycles.max(other.cycles);
        self.ops += other.ops;
        self.bytes_moved += other.bytes_moved;
    }

    /// Wall-clock seconds at the given clock.
    pub fn seconds(&self, freq_ghz: f64) -> f64 {
        self.cycles as f64 / (freq_ghz * 1e9)
    }

    /// Average power in watts at the given clock.
    pub fn watts(&self, freq_ghz: f64) -> f64 {
        let s = self.seconds(freq_ghz);
        if s == 0.0 {
            0.0
        } else {
            self.total_energy_pj() * 1e-12 / s
        }
    }

    /// Tera-ops per second at the given clock.
    pub fn tops(&self, freq_ghz: f64) -> f64 {
        let s = self.seconds(freq_ghz);
        if s == 0.0 {
            0.0
        } else {
            self.ops as f64 / s / 1e12
        }
    }

    /// Energy efficiency: pJ per op.
    pub fn pj_per_op(&self) -> f64 {
        if self.ops == 0 {
            0.0
        } else {
            self.total_energy_pj() / self.ops as f64
        }
    }

    /// One-line summary for bench tables.
    pub fn summary(&self, freq_ghz: f64) -> String {
        format!(
            "{:>10} cyc  {:>9.3} us  {:>10.1} nJ  {:>7.2} pJ/op  {:>7.3} W",
            self.cycles,
            self.seconds(freq_ghz) * 1e6,
            self.total_energy_pj() / 1e3,
            self.pj_per_op(),
            self.watts(freq_ghz),
        )
    }

    /// Per-category energy breakdown, descending. Uses the IEEE 754
    /// total order so a NaN entry (e.g. a poisoned accumulator from a
    /// bad config in release builds) sorts deterministically instead of
    /// panicking the report path.
    pub fn breakdown(&self) -> Vec<(Category, f64)> {
        let mut v: Vec<_> = self.energy_pj.iter().map(|(c, e)| (*c, *e)).collect();
        v.sort_by(|a, b| b.1.total_cmp(&a.1));
        v
    }
}

/// Roofline model: attainable throughput given operational intensity.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Roofline {
    /// Peak compute, ops/s.
    pub peak_ops: f64,
    /// Peak memory bandwidth, bytes/s.
    pub mem_bw: f64,
}

impl Roofline {
    /// Attainable ops/s at `intensity` ops/byte.
    pub fn attainable(&self, intensity: f64) -> f64 {
        (self.mem_bw * intensity).min(self.peak_ops)
    }

    /// Intensity where memory- and compute-bound regimes meet.
    pub fn knee(&self) -> f64 {
        self.peak_ops / self.mem_bw
    }

    /// Fraction of peak achieved by a kernel of given intensity & measured
    /// throughput.
    pub fn efficiency(&self, intensity: f64, achieved_ops: f64) -> f64 {
        achieved_ops / self.attainable(intensity)
    }
}

/// Silicon area accounting in mm² (for the equal-area DSE comparisons).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Area {
    pub mm2: f64,
}

impl Area {
    pub fn new(mm2: f64) -> Self {
        Area { mm2 }
    }
}

impl std::ops::Add for Area {
    type Output = Area;
    fn add(self, rhs: Area) -> Area {
        Area { mm2: self.mm2 + rhs.mm2 }
    }
}

impl std::iter::Sum for Area {
    fn sum<I: Iterator<Item = Area>>(iter: I) -> Area {
        iter.fold(Area::default(), |a, b| a + b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn energy_accumulates_by_category() {
        let mut m = Metrics::new();
        m.add_energy(Category::Compute, 10.0);
        m.add_energy(Category::Compute, 5.0);
        m.add_energy(Category::Dram, 20.0);
        assert_eq!(m.energy(Category::Compute), 15.0);
        assert_eq!(m.total_energy_pj(), 35.0);
        assert_eq!(m.breakdown()[0].0, Category::Dram);
    }

    #[test]
    fn breakdown_survives_nan_energy() {
        // Regression: `breakdown` used `partial_cmp(..).unwrap()`, which
        // panics the whole report path if any accumulator went NaN (a
        // bad config can produce that in release, where `add_energy`'s
        // debug_assert is compiled out). total_cmp must sort it
        // deterministically instead: NaN first (it is "largest" in the
        // IEEE total order), finite entries still descending.
        let mut m = Metrics::new();
        m.energy_pj.insert(Category::Compute, 10.0);
        m.energy_pj.insert(Category::Dram, f64::NAN);
        m.energy_pj.insert(Category::Noc, 20.0);
        let v = m.breakdown();
        assert_eq!(v.len(), 3);
        assert_eq!(v[0].0, Category::Dram);
        assert!(v[0].1.is_nan());
        assert_eq!(v[1], (Category::Noc, 20.0));
        assert_eq!(v[2], (Category::Compute, 10.0));
    }

    #[test]
    fn absorb_serial_vs_parallel() {
        let mut a = Metrics { cycles: 100, ops: 10, ..Default::default() };
        let b = Metrics { cycles: 70, ops: 5, ..Default::default() };
        let mut p = a.clone();
        a.absorb(&b);
        assert_eq!(a.cycles, 170);
        p.absorb_parallel(&b);
        assert_eq!(p.cycles, 100);
        assert_eq!(p.ops, 15);
    }

    #[test]
    fn derived_rates() {
        let mut m = Metrics { cycles: 1000, ops: 2000, ..Default::default() };
        m.add_energy(Category::Compute, 4000.0);
        // 1 GHz -> 1 us; 2000 ops / 1e-6 s = 2e9 ops/s = 0.002 TOPS
        assert!((m.seconds(1.0) - 1e-6).abs() < 1e-12);
        assert!((m.tops(1.0) - 0.002).abs() < 1e-9);
        assert!((m.pj_per_op() - 2.0).abs() < 1e-12);
        // 4000 pJ over 1 us = 4 mW
        assert!((m.watts(1.0) - 0.004).abs() < 1e-9);
    }

    #[test]
    fn roofline_knee_and_regimes() {
        let r = Roofline { peak_ops: 100e12, mem_bw: 1e12 };
        assert_eq!(r.knee(), 100.0);
        assert_eq!(r.attainable(10.0), 10e12); // memory bound
        assert_eq!(r.attainable(1000.0), 100e12); // compute bound
        assert!((r.efficiency(1000.0, 50e12) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn area_sums() {
        let total: Area = [Area::new(1.5), Area::new(2.5)].into_iter().sum();
        assert_eq!(total.mm2, 4.0);
    }
}
