//! Workload definitions: the AI models the paper's use cases run
//! (UAV vision CNN, ViT, MLP — Sec. I and V.B) expressed in the IR, with
//! deterministic synthetic weights/datasets (substitution for the defense
//! imagery we do not have; DESIGN.md §2).

use crate::ir::{interp::Mat, Graph, WeightTensor};
use crate::sim::Rng;
use crate::Result;

/// Deterministic Xavier-ish weight matrix.
fn dense(rng: &mut Rng, k: usize, n: usize) -> WeightTensor {
    let s = (2.0 / (k + n) as f64).sqrt();
    let data = (0..k * n).map(|_| (rng.normal() * s) as f32).collect();
    WeightTensor::new([k, n], data).unwrap()
}

fn vecw(rng: &mut Rng, n: usize, scale: f64, offset: f32) -> WeightTensor {
    let data = (0..n).map(|_| (rng.normal() * scale) as f32 + offset).collect();
    WeightTensor::new([1, n], data).unwrap()
}

/// MLP classifier: inputs -> hidden... -> classes (matches the L2
/// `MlpConfig` topology).
pub fn mlp(batch: usize, inputs: usize, hidden: &[usize], classes: usize, seed: u64)
    -> Result<Graph> {
    let mut rng = Rng::new(seed);
    let mut g = Graph::new();
    let mut x = g.input([batch, inputs], "x")?;
    let dims: Vec<usize> =
        std::iter::once(inputs).chain(hidden.iter().copied()).chain([classes]).collect();
    for i in 0..dims.len() - 1 {
        let w = g.weight(dense(&mut rng, dims[i], dims[i + 1]), &format!("fc{i}/w"))?;
        let b = g.weight(vecw(&mut rng, dims[i + 1], 0.0, 0.0), &format!("fc{i}/b"))?;
        x = g.matmul(x, w, &format!("fc{i}"))?;
        x = g.bias_add(x, b, &format!("fc{i}/bias"))?;
        if i + 2 < dims.len() {
            x = g.relu(x, &format!("fc{i}/relu"))?;
        }
    }
    g.mark_output(x);
    g.validate()?;
    Ok(g)
}

/// ViT-tiny encoder matching python/compile/model.py's `ViTConfig`
/// (attention expressed as explicit matmuls over flattened tokens; the
/// per-head attention matrix product is approximated with a single
/// tokens×tokens matmul per block — the mapper/DSE see the same op mix
/// and byte counts as the L2 model).
pub struct VitParams {
    pub batch: usize,
    pub tokens: usize,
    pub dim: usize,
    pub depth: usize,
    pub mlp_ratio: usize,
    pub patch_dim: usize,
    pub classes: usize,
}

impl Default for VitParams {
    fn default() -> Self {
        VitParams { batch: 4, tokens: 16, dim: 64, depth: 2, mlp_ratio: 2, patch_dim: 48, classes: 10 }
    }
}

pub fn vit(p: &VitParams, seed: u64) -> Result<Graph> {
    let mut rng = Rng::new(seed);
    let mut g = Graph::new();
    let rows = p.batch * p.tokens;
    let x = g.input([rows, p.patch_dim], "patches")?;
    let we = g.weight(dense(&mut rng, p.patch_dim, p.dim), "embed/w")?;
    let be = g.weight(vecw(&mut rng, p.dim, 0.0, 0.0), "embed/b")?;
    let mut h = g.matmul(x, we, "embed")?;
    h = g.bias_add(h, be, "embed/bias")?;
    for blk in 0..p.depth {
        let pfx = format!("block{blk}");
        // LN1
        let g1 = g.weights.len();
        g.weights.push(vecw(&mut rng, p.dim, 0.02, 1.0));
        let b1 = g.weights.len();
        g.weights.push(vecw(&mut rng, p.dim, 0.0, 0.0));
        let z = g.layer_norm(h, g1, b1, &format!("{pfx}/ln1"))?;
        // QKV projection
        let wqkv = g.weight(dense(&mut rng, p.dim, 3 * p.dim), &format!("{pfx}/qkv/w"))?;
        let qkv = g.matmul(z, wqkv, &format!("{pfx}/qkv"))?;
        // Attention core approximated as scores+mix matmuls at the same
        // cost: [rows, 3d] -> scores [rows, tokens] -> mix [rows, d].
        let wsc = g.weight(dense(&mut rng, 3 * p.dim, p.tokens), &format!("{pfx}/scores/w"))?;
        let scores = g.matmul(qkv, wsc, &format!("{pfx}/scores"))?;
        let scaled = g.scale(scores, 1.0 / (p.dim as f32).sqrt(), &format!("{pfx}/scale"))?;
        let att = g.softmax(scaled, &format!("{pfx}/softmax"))?;
        let wmix = g.weight(dense(&mut rng, p.tokens, p.dim), &format!("{pfx}/mix/w"))?;
        let mixed = g.matmul(att, wmix, &format!("{pfx}/mix"))?;
        let wproj = g.weight(dense(&mut rng, p.dim, p.dim), &format!("{pfx}/proj/w"))?;
        let proj = g.matmul(mixed, wproj, &format!("{pfx}/proj"))?;
        h = g.add(h, proj, &format!("{pfx}/res1"))?;
        // MLP
        let g2 = g.weights.len();
        g.weights.push(vecw(&mut rng, p.dim, 0.02, 1.0));
        let b2 = g.weights.len();
        g.weights.push(vecw(&mut rng, p.dim, 0.0, 0.0));
        let z2 = g.layer_norm(h, g2, b2, &format!("{pfx}/ln2"))?;
        let hdim = p.mlp_ratio * p.dim;
        let w1 = g.weight(dense(&mut rng, p.dim, hdim), &format!("{pfx}/mlp1/w"))?;
        let m1 = g.matmul(z2, w1, &format!("{pfx}/mlp1"))?;
        let a1 = g.gelu(m1, &format!("{pfx}/gelu"))?;
        let w2 = g.weight(dense(&mut rng, hdim, p.dim), &format!("{pfx}/mlp2/w"))?;
        let m2 = g.matmul(a1, w2, &format!("{pfx}/mlp2"))?;
        h = g.add(h, m2, &format!("{pfx}/res2"))?;
    }
    let gf = g.weights.len();
    g.weights.push(vecw(&mut rng, p.dim, 0.02, 1.0));
    let bf = g.weights.len();
    g.weights.push(vecw(&mut rng, p.dim, 0.0, 0.0));
    let hn = g.layer_norm(h, gf, bf, "ln_f")?;
    let pooled = g.mean_pool(hn, p.tokens, "pool")?;
    let wh = g.weight(dense(&mut rng, p.dim, p.classes), "head/w")?;
    let logits = g.matmul(pooled, wh, "head")?;
    g.mark_output(logits);
    g.validate()?;
    Ok(g)
}

/// Edge-CNN for UAV vision, lowered to GEMMs (im2col form): conv layers
/// become `[pixels, k²·cin] x [k²·cin, cout]` matmuls — the standard way
/// NPU tiles consume convolutions.
pub fn cnn_edge(batch: usize, seed: u64) -> Result<Graph> {
    let mut rng = Rng::new(seed);
    let mut g = Graph::new();
    // 32x32x3 input, 3 conv stages (stride-2 each) + classifier.
    let stages: [(usize, usize, usize); 3] = [
        // (pixels_out, k2cin, cout)
        (16 * 16, 3 * 3 * 3, 16),
        (8 * 8, 3 * 3 * 16, 32),
        (4 * 4, 3 * 3 * 32, 64),
    ];
    let mut x = g.input([batch * stages[0].0, stages[0].1], "im2col0")?;
    for (i, &(pix, k2cin, cout)) in stages.iter().enumerate() {
        let _ = pix;
        let w = g.weight(dense(&mut rng, k2cin, cout), &format!("conv{i}/w"))?;
        let b = g.weight(vecw(&mut rng, cout, 0.0, 0.0), &format!("conv{i}/b"))?;
        x = g.matmul(x, w, &format!("conv{i}"))?;
        x = g.bias_add(x, b, &format!("conv{i}/bias"))?;
        x = g.relu(x, &format!("conv{i}/relu"))?;
        if i + 1 < stages.len() {
            // Re-layout to the next stage's im2col shape: model as a pool
            // (pixel downsample) then a widening weightless reshape is
            // not representable — we approximate with mean-pool to the
            // next pixel count and a 1x1 expansion matmul.
            let cur_rows = g.nodes[x].shape[0];
            let next_rows = batch * stages[i + 1].0;
            let group = cur_rows / next_rows;
            x = g.mean_pool(x, group, &format!("pool{i}"))?;
            let wx = g.weight(
                dense(&mut rng, g.nodes[x].shape[1], stages[i + 1].1),
                &format!("expand{i}/w"),
            )?;
            x = g.matmul(x, wx, &format!("expand{i}"))?;
        }
    }
    let pooled = g.mean_pool(x, 4 * 4, "gap")?;
    let wh = g.weight(dense(&mut rng, 64, 10), "head/w")?;
    let logits = g.matmul(pooled, wh, "head")?;
    g.mark_output(logits);
    g.validate()?;
    Ok(g)
}

/// Synthetic dataset: deterministic inputs + labels from a hidden teacher
/// (linear rule), so "accuracy" is measurable without real data.
pub struct Dataset {
    pub inputs: Vec<Mat>,
    pub labels: Vec<usize>,
}

pub fn synthetic_dataset(samples: usize, rows: usize, cols: usize, classes: usize, seed: u64)
    -> Dataset {
    let mut rng = Rng::new(seed ^ 0xDA7A);
    // hidden teacher: class = argmax(W_t . mean_row)
    let teacher: Vec<f32> =
        (0..cols * classes).map(|_| rng.normal() as f32).collect();
    let mut inputs = Vec::with_capacity(samples);
    let mut labels = Vec::with_capacity(samples);
    for _ in 0..samples {
        let data: Vec<f32> = (0..rows * cols).map(|_| rng.normal() as f32).collect();
        let mut mean = vec![0.0f32; cols];
        for r in 0..rows {
            for c in 0..cols {
                mean[c] += data[r * cols + c] / rows as f32;
            }
        }
        let mut best = 0;
        let mut bestv = f32::NEG_INFINITY;
        for cl in 0..classes {
            let v: f32 = (0..cols).map(|c| mean[c] * teacher[c * classes + cl]).sum();
            if v > bestv {
                bestv = v;
                best = cl;
            }
        }
        inputs.push(Mat::new([rows, cols], data).unwrap());
        labels.push(best);
    }
    Dataset { inputs, labels }
}

/// Top-1 agreement between two logit sets (accuracy proxy for passes).
pub fn top1_agreement(a: &[Mat], b: &[Mat]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut agree = 0usize;
    let mut total = 0usize;
    for (ma, mb) in a.iter().zip(b) {
        for i in 0..ma.shape[0] {
            let arg = |m: &Mat| {
                (0..m.shape[1])
                    .max_by(|&x, &y| m.at(i, x).partial_cmp(&m.at(i, y)).unwrap())
                    .unwrap()
            };
            if arg(ma) == arg(mb) {
                agree += 1;
            }
            total += 1;
        }
    }
    agree as f64 / total as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::interp;

    #[test]
    fn mlp_runs_and_is_deterministic() {
        let g = mlp(4, 256, &[128, 64], 10, 7).unwrap();
        assert_eq!(g.nodes.last().unwrap().shape, [4, 10]);
        let ds = synthetic_dataset(2, 4, 256, 10, 1);
        let o1 = interp::run(&g, &[ds.inputs[0].clone()]).unwrap();
        let o2 = interp::run(&g, &[ds.inputs[0].clone()]).unwrap();
        assert_eq!(o1[0], o2[0]);
        let g2 = mlp(4, 256, &[128, 64], 10, 7).unwrap();
        let o3 = interp::run(&g2, &[ds.inputs[0].clone()]).unwrap();
        assert_eq!(o1[0], o3[0]);
    }

    #[test]
    fn vit_builds_and_runs() {
        let p = VitParams::default();
        let g = vit(&p, 0).unwrap();
        assert_eq!(g.nodes.last().unwrap().shape, [p.batch, p.classes]);
        // 1 embed + depth*(qkv, scores, mix, proj, mlp1, mlp2) + head
        let mms = g.nodes.iter().filter(|n| n.kind == crate::ir::OpKind::MatMul).count();
        assert_eq!(mms, 1 + p.depth * 6 + 1);
        let x = Mat::new(
            [p.batch * p.tokens, p.patch_dim],
            (0..p.batch * p.tokens * p.patch_dim).map(|i| (i % 17) as f32 * 0.1).collect(),
        )
        .unwrap();
        let out = interp::run(&g, &[x]).unwrap();
        assert_eq!(out[0].shape, [p.batch, p.classes]);
        assert!(out[0].data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn cnn_builds_and_runs() {
        let g = cnn_edge(2, 3).unwrap();
        let shape = g.nodes[0].shape;
        let x = Mat::new(shape, vec![0.1; shape[0] * shape[1]]).unwrap();
        let out = interp::run(&g, &[x]).unwrap();
        assert_eq!(out[0].shape, [2, 10]);
    }

    #[test]
    fn dataset_labels_learnable() {
        // The teacher rule should give a non-uniform, deterministic
        // label distribution.
        let ds = synthetic_dataset(64, 4, 32, 10, 5);
        let ds2 = synthetic_dataset(64, 4, 32, 10, 5);
        assert_eq!(ds.labels, ds2.labels);
        let distinct: std::collections::HashSet<_> = ds.labels.iter().collect();
        assert!(distinct.len() > 2);
    }

    #[test]
    fn top1_agreement_bounds() {
        let a = vec![Mat::new([2, 3], vec![1., 0., 0., 0., 1., 0.]).unwrap()];
        let same = top1_agreement(&a, &a.clone());
        assert_eq!(same, 1.0);
        let b = vec![Mat::new([2, 3], vec![0., 0., 1., 0., 0., 1.]).unwrap()];
        assert_eq!(top1_agreement(&a, &b), 0.0);
    }
}
