//! Mixed-Integer Linear Programming: dense two-phase primal simplex with
//! Bland's rule + best-first branch-and-bound (the ArchEx-style engine of
//! paper Sec. III).
//!
//! Scope: the DSE and mapping problems here are small (tens of variables,
//! tens of constraints), so a dense tableau is the right tool — no
//! sparse factorization machinery.

use anyhow::{bail, ensure};

use crate::Result;

/// Constraint sense.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sense {
    Le,
    Ge,
    Eq,
}

/// One linear constraint `sum coeffs · x (sense) rhs`.
#[derive(Debug, Clone)]
pub struct Constraint {
    pub coeffs: Vec<(usize, f64)>,
    pub sense: Sense,
    pub rhs: f64,
}

#[derive(Debug, Clone)]
struct Var {
    lo: f64,
    hi: f64,
    cost: f64,
    integer: bool,
}

/// A MILP instance (minimization).
#[derive(Debug, Clone, Default)]
pub struct Milp {
    vars: Vec<Var>,
    cons: Vec<Constraint>,
}

/// Solver output.
#[derive(Debug, Clone)]
pub struct Solution {
    pub x: Vec<f64>,
    pub objective: f64,
    /// Branch-and-bound nodes explored (1 = pure LP).
    pub nodes: usize,
}

const EPS: f64 = 1e-9;

impl Milp {
    pub fn new() -> Self {
        Milp::default()
    }

    /// Add a variable with bounds `[lo, hi]` and objective coefficient
    /// `cost`. Returns its index.
    pub fn add_var(&mut self, lo: f64, hi: f64, cost: f64, integer: bool) -> usize {
        assert!(lo <= hi, "bad bounds");
        assert!(lo.is_finite() && hi.is_finite(), "bounds must be finite");
        self.vars.push(Var { lo, hi, cost, integer });
        self.vars.len() - 1
    }

    pub fn add_constraint(&mut self, coeffs: Vec<(usize, f64)>, sense: Sense, rhs: f64) {
        for &(v, _) in &coeffs {
            assert!(v < self.vars.len(), "unknown var {v}");
        }
        self.cons.push(Constraint { coeffs, sense, rhs });
    }

    pub fn num_vars(&self) -> usize {
        self.vars.len()
    }

    /// Solve the LP relaxation with extra bound overrides (for B&B).
    fn solve_lp(&self, lo: &[f64], hi: &[f64]) -> Result<Option<(Vec<f64>, f64)>> {
        // Shift variables to y = x - lo >= 0, with y <= hi - lo as rows.
        let n = self.vars.len();
        let mut rows: Vec<(Vec<f64>, f64)> = Vec::new(); // a·y <= b form rows, plus Eq handled as two
        let mut push = |coeffs: &[(usize, f64)], sense: Sense, rhs: f64| {
            let mut a = vec![0.0; n];
            let mut shift = 0.0;
            for &(v, c) in coeffs {
                a[v] += c;
                shift += c * lo[v];
            }
            let b = rhs - shift;
            match sense {
                Sense::Le => rows.push((a, b)),
                Sense::Ge => rows.push((a.iter().map(|c| -c).collect(), -b)),
                Sense::Eq => {
                    rows.push((a.clone(), b));
                    rows.push((a.iter().map(|c| -c).collect(), -b));
                }
            }
        };
        for c in &self.cons {
            push(&c.coeffs, c.sense, c.rhs);
        }
        for v in 0..n {
            if hi[v] - lo[v] < -EPS {
                return Ok(None); // contradictory bounds from branching
            }
            let mut a = vec![0.0; n];
            a[v] = 1.0;
            rows.push((a, hi[v] - lo[v]));
        }
        let m = rows.len();
        // Phase-conversion: ensure b >= 0 by introducing artificials where
        // needed; standard two-phase with slack on every row.
        // Tableau columns: n structural + m slacks + m artificials + rhs.
        let total = n + m + m;
        let mut t = vec![vec![0.0; total + 1]; m];
        let mut basis = vec![0usize; m];
        let mut need_artificial = vec![false; m];
        for (i, (a, b)) in rows.iter().enumerate() {
            let (mut a, mut b) = (a.clone(), *b);
            if b < 0.0 {
                for c in a.iter_mut() {
                    *c = -*c;
                }
                b = -b;
                // slack becomes surplus: coefficient -1
                t[i][n + i] = -1.0;
                need_artificial[i] = true;
            } else {
                t[i][n + i] = 1.0;
            }
            for (j, &c) in a.iter().enumerate() {
                t[i][j] = c;
            }
            t[i][total] = b;
            if need_artificial[i] {
                t[i][n + m + i] = 1.0;
                basis[i] = n + m + i;
            } else {
                basis[i] = n + i;
            }
        }

        // Phase 1: minimize sum of artificials.
        let mut cost1 = vec![0.0; total];
        for i in 0..m {
            if need_artificial[i] {
                cost1[n + m + i] = 1.0;
            }
        }
        let feasible = simplex_banned(&mut t, &mut basis, &cost1, total, total)?;
        let phase1_obj = objective_value(&t, &basis, &cost1, total);
        if !feasible || phase1_obj > 1e-6 {
            return Ok(None);
        }
        // Drive any degenerate basic artificials out of the basis before
        // phase 2 (otherwise a later pivot could re-grow them and return
        // an infeasible point). For each basic artificial row, pivot in
        // any structural/slack column with a nonzero coefficient; an
        // all-zero row is redundant and harmless.
        for i in 0..m {
            if basis[i] >= n + m {
                if let Some(j) = (0..n + m).find(|&j| t[i][j].abs() > 1e-7) {
                    let piv = t[i][j];
                    for v in t[i].iter_mut() {
                        *v /= piv;
                    }
                    for r in 0..m {
                        if r != i && t[r][j].abs() > EPS {
                            let f = t[r][j];
                            for col in 0..=total {
                                t[r][col] -= f * t[i][col];
                            }
                        }
                    }
                    basis[i] = j;
                }
            }
        }
        let mut cost2 = vec![0.0; total];
        for (v, var) in self.vars.iter().enumerate() {
            cost2[v] = var.cost;
        }
        if !simplex_banned(&mut t, &mut basis, &cost2, total, n + m)? {
            return Ok(None); // unbounded — callers use bounded vars, so treat as infeasible
        }
        let mut y = vec![0.0; n];
        for (i, &b) in basis.iter().enumerate() {
            if b < n {
                y[b] = t[i][total];
            }
        }
        let x: Vec<f64> = (0..n).map(|v| y[v] + lo[v]).collect();
        let obj: f64 = x.iter().zip(&self.vars).map(|(xi, v)| xi * v.cost).sum();
        Ok(Some((x, obj)))
    }

    /// Solve the MILP by best-first branch and bound. Returns None if
    /// infeasible.
    pub fn minimize(&self) -> Result<Option<Solution>> {
        ensure!(!self.vars.is_empty(), "no variables");
        let lo0: Vec<f64> = self.vars.iter().map(|v| v.lo).collect();
        let hi0: Vec<f64> = self.vars.iter().map(|v| v.hi).collect();
        let mut best: Option<Solution> = None;
        // Stack of (lo, hi) subproblems; DFS with bound pruning.
        let mut stack = vec![(lo0, hi0)];
        let mut nodes = 0usize;
        while let Some((lo, hi)) = stack.pop() {
            nodes += 1;
            if nodes > 100_000 {
                bail!("branch-and-bound node limit exceeded");
            }
            let Some((x, obj)) = self.solve_lp(&lo, &hi)? else {
                continue;
            };
            if let Some(b) = &best {
                if obj >= b.objective - 1e-9 {
                    continue; // bound prune
                }
            }
            // Most-fractional integer variable.
            let mut branch_var = None;
            let mut best_frac = 1e-6;
            for (v, var) in self.vars.iter().enumerate() {
                if !var.integer {
                    continue;
                }
                let f = (x[v] - x[v].round()).abs();
                if f > best_frac {
                    best_frac = f;
                    branch_var = Some(v);
                }
            }
            match branch_var {
                None => {
                    // Integral (within tolerance): round and accept.
                    let xi: Vec<f64> = self
                        .vars
                        .iter()
                        .enumerate()
                        .map(|(v, var)| if var.integer { x[v].round() } else { x[v] })
                        .collect();
                    let obj: f64 =
                        xi.iter().zip(&self.vars).map(|(x, v)| x * v.cost).sum();
                    if best.as_ref().map_or(true, |b| obj < b.objective - 1e-9) {
                        best = Some(Solution { x: xi, objective: obj, nodes });
                    }
                }
                Some(v) => {
                    let floor = x[v].floor();
                    let mut hi_left = hi.clone();
                    hi_left[v] = floor;
                    let mut lo_right = lo.clone();
                    lo_right[v] = floor + 1.0;
                    stack.push((lo.clone(), hi_left));
                    stack.push((lo_right, hi.clone()));
                }
            }
        }
        if let Some(s) = &mut best {
            s.nodes = nodes;
        }
        Ok(best)
    }
}

/// Primal simplex with Bland's rule on tableau `t` (rows m, cols total+1,
/// last col = rhs). Columns >= `ban_from` may never *enter* the basis
/// (used to freeze phase-1 artificials out in phase 2). Returns false if
/// unbounded.
fn simplex_banned(
    t: &mut [Vec<f64>],
    basis: &mut [usize],
    cost: &[f64],
    total: usize,
    ban_from: usize,
) -> Result<bool> {
    let m = t.len();
    let mut iters = 0usize;
    loop {
        iters += 1;
        if iters > 50_000 {
            bail!("simplex iteration limit");
        }
        // Reduced costs: c_j - c_B B^-1 A_j (tableau is kept in canonical
        // form, so reduced cost = cost[j] - sum_i cost[basis[i]] * t[i][j]).
        let mut entering = None;
        for j in 0..total.min(ban_from) {
            let mut rc = cost[j];
            for i in 0..m {
                rc -= cost[basis[i]] * t[i][j];
            }
            if rc < -EPS {
                entering = Some(j); // Bland: first improving index
                break;
            }
        }
        let Some(e) = entering else {
            return Ok(true); // optimal
        };
        // Ratio test (Bland: smallest index on ties).
        let mut leave: Option<usize> = None;
        let mut best_ratio = f64::INFINITY;
        for i in 0..m {
            if t[i][e] > EPS {
                let ratio = t[i][total] / t[i][e];
                if ratio < best_ratio - EPS
                    || (ratio < best_ratio + EPS
                        && leave.map_or(true, |l| basis[i] < basis[l]))
                {
                    best_ratio = ratio;
                    leave = Some(i);
                }
            }
        }
        let Some(l) = leave else {
            return Ok(false); // unbounded
        };
        // Pivot.
        let piv = t[l][e];
        for v in t[l].iter_mut() {
            *v /= piv;
        }
        for i in 0..m {
            if i != l && t[i][e].abs() > EPS {
                let f = t[i][e];
                for j in 0..=total {
                    t[i][j] -= f * t[l][j];
                }
            }
        }
        basis[l] = e;
    }
}

fn objective_value(t: &[Vec<f64>], basis: &[usize], cost: &[f64], total: usize) -> f64 {
    basis
        .iter()
        .enumerate()
        .map(|(i, &b)| cost[b] * t[i][total])
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pure_lp_optimum() {
        // min -x - 2y  s.t. x + y <= 4, x <= 3, y <= 2, x,y >= 0
        let mut m = Milp::new();
        let x = m.add_var(0.0, 3.0, -1.0, false);
        let y = m.add_var(0.0, 2.0, -2.0, false);
        m.add_constraint(vec![(x, 1.0), (y, 1.0)], Sense::Le, 4.0);
        let s = m.minimize().unwrap().unwrap();
        assert!((s.x[x] - 2.0).abs() < 1e-6, "{:?}", s.x);
        assert!((s.x[y] - 2.0).abs() < 1e-6);
        assert!((s.objective + 6.0).abs() < 1e-6);
    }

    #[test]
    fn integer_knapsack() {
        // max 5a + 4b + 3c, weights 2a + 3b + c <= 5, binary.
        let mut m = Milp::new();
        let a = m.add_var(0.0, 1.0, -5.0, true);
        let b = m.add_var(0.0, 1.0, -4.0, true);
        let c = m.add_var(0.0, 1.0, -3.0, true);
        m.add_constraint(vec![(a, 2.0), (b, 3.0), (c, 1.0)], Sense::Le, 5.0);
        let s = m.minimize().unwrap().unwrap();
        // best: a + c (value 8, weight 3) or a+b (9, weight 5)? a+b = 9.
        assert!((s.objective + 9.0).abs() < 1e-6, "{}", s.objective);
        assert_eq!(s.x[a].round() as i64, 1);
        assert_eq!(s.x[b].round() as i64, 1);
        assert_eq!(s.x[c].round() as i64, 0);
    }

    #[test]
    fn equality_constraints() {
        // min x + y s.t. x + y = 10, x - y = 2 -> x=6, y=4.
        let mut m = Milp::new();
        let x = m.add_var(0.0, 100.0, 1.0, false);
        let y = m.add_var(0.0, 100.0, 1.0, false);
        m.add_constraint(vec![(x, 1.0), (y, 1.0)], Sense::Eq, 10.0);
        m.add_constraint(vec![(x, 1.0), (y, -1.0)], Sense::Eq, 2.0);
        let s = m.minimize().unwrap().unwrap();
        assert!((s.x[x] - 6.0).abs() < 1e-6);
        assert!((s.x[y] - 4.0).abs() < 1e-6);
    }

    #[test]
    fn infeasible_detected() {
        let mut m = Milp::new();
        let x = m.add_var(0.0, 1.0, 1.0, false);
        m.add_constraint(vec![(x, 1.0)], Sense::Ge, 5.0);
        assert!(m.minimize().unwrap().is_none());
    }

    #[test]
    fn ge_constraints_and_negative_costs() {
        // min 3x + 2y s.t. x + y >= 4, x >= 1 -> x=1, y=3 (cost 9)
        let mut m = Milp::new();
        let x = m.add_var(0.0, 10.0, 3.0, false);
        let y = m.add_var(0.0, 10.0, 2.0, false);
        m.add_constraint(vec![(x, 1.0), (y, 1.0)], Sense::Ge, 4.0);
        m.add_constraint(vec![(x, 1.0)], Sense::Ge, 1.0);
        let s = m.minimize().unwrap().unwrap();
        assert!((s.objective - 9.0).abs() < 1e-6, "{}", s.objective);
    }

    #[test]
    fn assignment_problem() {
        // 3 tasks x 3 machines, cost matrix; each task exactly one
        // machine, each machine at most one task — classic ILP.
        let cost = [[4.0, 2.0, 8.0], [4.0, 3.0, 7.0], [3.0, 1.0, 6.0]];
        let mut m = Milp::new();
        let mut v = [[0usize; 3]; 3];
        for t in 0..3 {
            for mach in 0..3 {
                v[t][mach] = m.add_var(0.0, 1.0, cost[t][mach], true);
            }
        }
        for t in 0..3 {
            m.add_constraint((0..3).map(|j| (v[t][j], 1.0)).collect(), Sense::Eq, 1.0);
        }
        for j in 0..3 {
            m.add_constraint((0..3).map(|t| (v[t][j], 1.0)).collect(), Sense::Le, 1.0);
        }
        let s = m.minimize().unwrap().unwrap();
        // optimum: t0->m1(2)? then t2->m1 taken.. enumerate: best = 2+4+3?
        // t0->m1 (2), t1->m0 (4), t2... m2 (6) = 12; or t0->m0(4),
        // t1->m2(7), t2->m1(1) = 12; or t0->m1(2), t1->m2(7), t2->m0(3)=12.
        assert!((s.objective - 12.0).abs() < 1e-6, "{}", s.objective);
    }

    #[test]
    fn branching_actually_happens() {
        // LP relaxation is fractional: max x+y s.t. 2x+2y <= 3, binary.
        let mut m = Milp::new();
        let x = m.add_var(0.0, 1.0, -1.0, true);
        let y = m.add_var(0.0, 1.0, -1.0, true);
        m.add_constraint(vec![(x, 1.0), (y, 1.0)], Sense::Le, 1.5);
        let s = m.minimize().unwrap().unwrap();
        assert!((s.objective + 1.0).abs() < 1e-6);
        assert!(s.nodes > 1, "must branch, got {} nodes", s.nodes);
    }
}
