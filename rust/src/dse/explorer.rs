//! NoC topology design-space exploration (experiment E4).
//!
//! Implements the paper's three-stage methodology:
//! 1. **Analytic screening** — every candidate topology is costed with the
//!    floorplan + distance model (fast, no simulation).
//! 2. **Solver selection** — MILP (ArchEx-style budgeted argmin) or a
//!    SAT/difference-logic optimization loop picks the best candidate
//!    under area / radix / wirelength budgets.
//! 3. **Iterative simulation-in-the-loop** — the top analytic candidates
//!    are re-scored with the flit-level simulator, and the measured
//!    latency tightens the solver's constraint set ("deduce constraints
//!    to guide the solver to the optimal solution more quickly").

use std::collections::HashSet;

use anyhow::ensure;

use crate::accel::Precision;
use crate::compiler::lowering::lower;
use crate::compiler::mapper::{map_graph_with, MapStrategy};
use crate::config::FabricConfig;
use crate::coordinator::cosim_with;
use crate::fabric::Fabric;
use crate::noc::{traffic, Floorplan, NocParams, NocSim, Topology};
use crate::sim::Rng;
use crate::workloads;
use crate::Result;

use super::milp::{Milp, Sense};
use super::pareto::pareto_front;
use super::smt::{Lit, SmtSolver};

/// One candidate topology with its analytic scores.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub name: String,
    pub topo: Topology,
    /// Mean hop distance (analytic latency proxy).
    pub avg_hops: f64,
    /// Estimated mean packet latency, cycles (distance + serialization +
    /// contention inflation from bisection load).
    pub est_latency: f64,
    /// Router + wiring area proxy, mm².
    pub area: f64,
    /// Energy per KiB transported (pJ), floorplan-derated.
    pub energy_per_kib: f64,
    pub max_radix: usize,
    pub wirelength: usize,
    /// Measured latency from the configured [`SimEngine`] (filled by
    /// refinement): mean packet latency in cycles under
    /// [`SimEngine::Flit`], end-to-end workload makespan cycles under
    /// [`SimEngine::Cosim`].
    pub sim_latency: Option<f64>,
    /// Measured workload energy, pJ — [`SimEngine::Cosim`] only (the
    /// flit engine measures latency, not program energy).
    pub sim_energy_pj: Option<f64>,
}

/// The measurement engine behind `IterativeSim` refinement — the DSE
/// engine seam (see `dse` module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SimEngine {
    /// The seed path, byte-stable: cold-start flit-level `NocSim` +
    /// synthetic uniform traffic (latency only).
    #[default]
    Flit,
    /// The fast engines: build a [`Fabric`] over the candidate topology
    /// ([`Fabric::build_with_topology`]), map a probe workload through
    /// the fabric's configured cost model (`map_graph_with` — kind-aware
    /// when the config selects `model = "kind"`), and measure latency
    /// *and* energy with the event-driven co-sim (`cosim_with`).
    Cosim,
}

/// Exploration budgets + workload.
#[derive(Debug, Clone)]
pub struct ExploreConfig {
    /// Compute nodes the fabric must host.
    pub min_nodes: usize,
    /// Area budget, mm².
    pub max_area: f64,
    /// Max router radix (low-radix design principle).
    pub max_radix: usize,
    /// Offered load for the traffic model (packets/node/cycle).
    pub rate: f64,
    pub packet_bytes: usize,
    /// Candidates refined with the measurement engine.
    pub sim_top_k: usize,
    pub seed: u64,
    /// Measurement engine for refinement ([`SimEngine::Flit`] keeps the
    /// seed behavior byte for byte).
    pub engine: SimEngine,
    /// Fabric parameters for [`SimEngine::Cosim`] (tile mix, cost model,
    /// link constants; the `[noc]` topology fields are ignored — the
    /// candidate topology replaces them). `None` = a small homogeneous
    /// NPU fabric sized to `min_nodes`.
    pub fabric: Option<FabricConfig>,
}

impl Default for ExploreConfig {
    fn default() -> Self {
        ExploreConfig {
            min_nodes: 16,
            max_area: 10.0,
            max_radix: 5,
            rate: 0.05,
            packet_bytes: 64,
            sim_top_k: 3,
            seed: 7,
            engine: SimEngine::Flit,
            fabric: None,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExploreMethod {
    /// Analytic screening only.
    Exhaustive,
    /// MILP budgeted argmin over the screened candidates.
    Milp,
    /// SAT + difference-logic linear-search optimization.
    Smt,
    /// MILP + simulation-in-the-loop constraint tightening.
    IterativeSim,
}

/// Exploration output.
#[derive(Debug, Clone)]
pub struct ExploreResult {
    pub candidates: Vec<Candidate>,
    /// Index of the selected candidate.
    pub best: usize,
    /// Pareto-front indices over (est_latency, area, energy).
    pub front: Vec<usize>,
    /// Candidates evaluated by the solver / simulator.
    pub solver_evals: usize,
    pub sim_evals: usize,
}

/// Generate the candidate set for a target size.
pub fn candidates_for(nodes: usize) -> Vec<(String, Topology)> {
    let mut out: Vec<(String, Topology)> = Vec::new();
    let mut push = |name: String, t: Result<Topology>| {
        if let Ok(t) = t {
            if t.nodes() >= nodes && t.is_connected() {
                out.push((name, t));
            }
        }
    };
    // Meshes / tori around the target size. A w×h grid is isomorphic to
    // its h×w transpose (identical distances, degrees, floorplan), so
    // the dimension set is deduped on the sorted pair — the square loop
    // used to emit e.g. mesh4x5 AND mesh5x4 as distinct candidates,
    // double-counting them in every solver and the Pareto front.
    let side = (nodes as f64).sqrt().ceil() as usize;
    let mut seen_dims: HashSet<(usize, usize)> = HashSet::new();
    for w in [side, side + 1] {
        for h in [side.max(1), side + 1] {
            if w * h >= nodes && seen_dims.insert((w.min(h), w.max(h))) {
                push(format!("mesh{w}x{h}"), Topology::mesh(w, h));
                push(format!("torus{w}x{h}"), Topology::torus(w, h));
            }
        }
    }
    push(format!("ring{nodes}"), Topology::ring(nodes));
    push(format!("star{nodes}"), Topology::star(nodes));
    let down = (nodes as f64).sqrt().ceil() as usize;
    push(format!("fattree{down}"), Topology::fattree(down));
    // Low-radix custom: ring + evenly spaced chords (express links).
    // Membership via a normalized hash set — the old `edges.contains`
    // pair scan was O(n²) over the growing edge list.
    if nodes >= 8 {
        let mut edges: Vec<(usize, usize)> = (0..nodes).map(|i| (i, (i + 1) % nodes)).collect();
        let mut have: HashSet<(usize, usize)> =
            edges.iter().map(|&(a, b)| (a.min(b), a.max(b))).collect();
        let stride = nodes / 4;
        for i in (0..nodes).step_by(2) {
            let j = (i + stride) % nodes;
            if i != j && have.insert((i.min(j), i.max(j))) {
                edges.push((i, j));
            }
        }
        push(format!("chordal{nodes}"), Topology::custom(nodes, &edges));
    }
    out
}

/// Analytic scoring of one topology under the given workload.
pub fn score(name: &str, topo: Topology, cfg: &ExploreConfig) -> Candidate {
    let fp = Floorplan::place(&topo);
    let avg_hops = topo.avg_distance();
    let params = NocParams::default();
    let ser = (cfg.packet_bytes as f64 / params.flit_bytes as f64).ceil();
    // Contention inflation: offered bisection load / capacity.
    let flits_per_cycle = topo.nodes() as f64 * cfg.rate * ser;
    let bisection_cap = topo.bisection_links().max(1) as f64 * 2.0;
    let rho = (flits_per_cycle * 0.5 / bisection_cap).min(0.95);
    let base = avg_hops * params.router_latency as f64 + ser;
    let est_latency = base / (1.0 - rho);
    // Area: radix² crossbar per router + wiring.
    let router_area: f64 = (0..topo.nodes())
        .map(|n| ((topo.degree(n) + 1) as f64).powi(2) * 0.01)
        .sum();
    let area = router_area + fp.total_wirelength() as f64 * 0.02;
    let energy_per_kib = 1024.0 * 8.0
        * params.hop_energy_pj_per_bit
        * avg_hops
        * fp.avg_energy_scale();
    Candidate {
        name: name.to_string(),
        max_radix: topo.max_degree() + 1,
        wirelength: fp.total_wirelength(),
        topo,
        avg_hops,
        est_latency,
        area,
        energy_per_kib,
        sim_latency: None,
        sim_energy_pj: None,
    }
}

fn simulate_latency(c: &Candidate, cfg: &ExploreConfig) -> f64 {
    let mut sim = NocSim::new(c.topo.clone(), NocParams::default());
    let mut rng = Rng::new(cfg.seed);
    let inj = traffic::generate(
        traffic::Pattern::Uniform,
        c.topo.nodes(),
        cfg.rate,
        cfg.packet_bytes,
        2_000,
        &mut rng,
    );
    let rep = traffic::drive(&mut sim, inj, 3_000_000);
    rep.avg_latency
}

/// Fabric parameters for [`SimEngine::Cosim`] when the caller supplied
/// none: a homogeneous NPU fabric sized so its tiles (+ the HBM bridge
/// on node 0) fit every candidate with at least `min_nodes` nodes.
fn default_cosim_fabric(min_nodes: usize) -> Result<FabricConfig> {
    let tiles = min_nodes.saturating_sub(1).clamp(1, 12);
    let mut side = 2usize;
    while side * side < tiles + 1 {
        side += 1;
    }
    FabricConfig::from_toml(&format!(
        "[noc]\nwidth = {side}\nheight = {side}\n\
         [[cu]]\nkind = \"npu\"\ntemplate = \"B\"\ncount = {tiles}\n"
    ))
}

/// Measure one candidate on the fast engines: fabric over the candidate
/// topology, probe MLP mapped through the fabric's configured cost model
/// (kind-aware under `model = "kind"`), event-driven co-sim. Returns
/// (makespan cycles, total energy pJ) from the measured `ExecReport`.
fn measure_cosim(c: &Candidate, cfg: &ExploreConfig) -> Result<(f64, f64)> {
    let base = match &cfg.fabric {
        Some(f) => f.clone(),
        None => default_cosim_fabric(cfg.min_nodes)?,
    };
    let fabric = Fabric::build_with_topology(base, c.topo.clone())?;
    let g = workloads::mlp(4, 128, &[64], 10, cfg.seed)?;
    let model = fabric.cost_model().clone();
    let m = map_graph_with(&g, &fabric, MapStrategy::Greedy, Precision::Analog, model.as_ref())?;
    let prog = lower(&g, &fabric, &m)?;
    let rep = cosim_with(&fabric, &prog, model.as_ref())?;
    Ok((rep.cycles as f64, rep.metrics.total_energy_pj()))
}

/// Engine dispatch for refinement: measured latency plus (co-sim only)
/// measured energy.
fn measure(c: &Candidate, cfg: &ExploreConfig) -> Result<(f64, Option<f64>)> {
    match cfg.engine {
        SimEngine::Flit => Ok((simulate_latency(c, cfg), None)),
        SimEngine::Cosim => measure_cosim(c, cfg).map(|(lat, en)| (lat, Some(en))),
    }
}

fn feasible(c: &Candidate, cfg: &ExploreConfig) -> bool {
    c.area <= cfg.max_area && c.max_radix <= cfg.max_radix
}

/// Run the exploration.
pub fn explore(cfg: &ExploreConfig, method: ExploreMethod) -> Result<ExploreResult> {
    let mut cands: Vec<Candidate> = candidates_for(cfg.min_nodes)
        .into_iter()
        .map(|(n, t)| score(&n, t, cfg))
        .collect();
    ensure!(!cands.is_empty(), "no candidate topologies for {} nodes", cfg.min_nodes);
    let mut solver_evals = 0usize;
    let mut sim_evals = 0usize;

    let pick_analytic = |cands: &[Candidate]| -> Option<usize> {
        cands
            .iter()
            .enumerate()
            .filter(|(_, c)| feasible(c, cfg))
            .min_by(|a, b| a.1.est_latency.partial_cmp(&b.1.est_latency).unwrap())
            .map(|(i, _)| i)
    };

    let best = match method {
        ExploreMethod::Exhaustive => {
            solver_evals = cands.len();
            pick_analytic(&cands)
        }
        ExploreMethod::Milp => {
            // Binary selection MILP: pick exactly one candidate minimizing
            // latency under area/radix budgets (ArchEx-style).
            let mut m = Milp::new();
            let vars: Vec<usize> = cands
                .iter()
                .map(|c| m.add_var(0.0, 1.0, c.est_latency, true))
                .collect();
            m.add_constraint(vars.iter().map(|&v| (v, 1.0)).collect(), Sense::Eq, 1.0);
            m.add_constraint(
                vars.iter()
                    .zip(&cands)
                    .map(|(&v, c)| (v, c.area))
                    .collect(),
                Sense::Le,
                cfg.max_area,
            );
            for (v, c) in vars.iter().zip(&cands) {
                if c.max_radix > cfg.max_radix {
                    m.add_constraint(vec![(*v, 1.0)], Sense::Le, 0.0);
                }
            }
            let sol = m.minimize()?;
            solver_evals = sol.as_ref().map_or(0, |s| s.nodes);
            sol.and_then(|s| vars.iter().position(|&v| s.x[v] > 0.5))
        }
        ExploreMethod::Smt => {
            // Linear-search SAT optimization: exactly-one candidate;
            // exclude infeasible; repeatedly forbid everything at least
            // as slow as the incumbent until UNSAT.
            let mut order: Vec<usize> = (0..cands.len()).collect();
            order.sort_by(|&a, &b| {
                cands[a].est_latency.partial_cmp(&cands[b].est_latency).unwrap()
            });
            let mut s = SmtSolver::new();
            let vars: Vec<usize> = cands.iter().map(|_| s.new_var()).collect();
            s.add_clause(vars.iter().map(|&v| Lit::pos(v)).collect());
            for (i, &vi) in vars.iter().enumerate() {
                for &vj in vars.iter().skip(i + 1) {
                    s.add_clause(vec![Lit::neg(vi), Lit::neg(vj)]);
                }
                if !feasible(&cands[i], cfg) {
                    s.add_clause(vec![Lit::neg(vi)]);
                }
            }
            let mut incumbent = None;
            loop {
                solver_evals += 1;
                match s.solve()? {
                    None => break,
                    Some(model) => {
                        let chosen = vars.iter().position(|&v| model[v]).unwrap();
                        incumbent = Some(chosen);
                        // forbid all candidates with latency >= chosen's
                        for (i, &v) in vars.iter().enumerate() {
                            if cands[i].est_latency >= cands[chosen].est_latency {
                                s.add_clause(vec![Lit::neg(v)]);
                            }
                        }
                    }
                }
            }
            incumbent
        }
        ExploreMethod::IterativeSim => {
            // Analytic rank, then sim-refine the top-k feasible
            // candidates; measured latencies replace estimates and the
            // final choice is by measurement.
            let mut order: Vec<usize> = (0..cands.len())
                .filter(|&i| feasible(&cands[i], cfg))
                .collect();
            order.sort_by(|&a, &b| {
                cands[a].est_latency.partial_cmp(&cands[b].est_latency).unwrap()
            });
            for &i in order.iter().take(cfg.sim_top_k) {
                let (lat, energy) = measure(&cands[i], cfg)?;
                cands[i].sim_latency = Some(lat);
                cands[i].sim_energy_pj = energy;
                sim_evals += 1;
            }
            solver_evals = order.len();
            order
                .iter()
                .take(cfg.sim_top_k)
                .min_by(|&&a, &&b| {
                    cands[a]
                        .sim_latency
                        .unwrap()
                        .partial_cmp(&cands[b].sim_latency.unwrap())
                        .unwrap()
                })
                .copied()
        }
    };
    let best = best.ok_or_else(|| anyhow::anyhow!("no feasible topology under budgets"))?;
    let measured: Vec<usize> = (0..cands.len())
        .filter(|&i| cands[i].sim_latency.is_some() && cands[i].sim_energy_pj.is_some())
        .collect();
    let front = if cfg.engine == SimEngine::Cosim && !measured.is_empty() {
        // Measured-only front: measured workload pJ and analytic pJ/KiB
        // are different units, so mixing them in one domination check is
        // meaningless. The front is computed over the co-sim-measured
        // subset and mapped back to candidate indices; the flit engine
        // keeps the analytic front byte for byte.
        let points: Vec<Vec<f64>> = measured
            .iter()
            .map(|&i| {
                let c = &cands[i];
                vec![c.sim_latency.unwrap(), c.area, c.sim_energy_pj.unwrap()]
            })
            .collect();
        pareto_front(&points).into_iter().map(|k| measured[k]).collect()
    } else {
        let points: Vec<Vec<f64>> = cands
            .iter()
            .map(|c| vec![c.est_latency, c.area, c.energy_per_kib])
            .collect();
        pareto_front(&points)
    };
    Ok(ExploreResult { candidates: cands, best, front, solver_evals, sim_evals })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn candidate_generation_covers_families() {
        let cands = candidates_for(16);
        let names: Vec<&str> = cands.iter().map(|(n, _)| n.as_str()).collect();
        assert!(names.iter().any(|n| n.starts_with("mesh")));
        assert!(names.iter().any(|n| n.starts_with("torus")));
        assert!(names.iter().any(|n| n.starts_with("ring")));
        assert!(names.iter().any(|n| n.starts_with("star")));
        assert!(names.iter().any(|n| n.starts_with("fattree")));
        assert!(names.iter().any(|n| n.starts_with("chordal")));
        for (_, t) in &cands {
            assert!(t.is_connected());
        }
    }

    #[test]
    fn methods_agree_on_the_analytic_optimum() {
        let cfg = ExploreConfig::default();
        let ex = explore(&cfg, ExploreMethod::Exhaustive).unwrap();
        let milp = explore(&cfg, ExploreMethod::Milp).unwrap();
        let smt = explore(&cfg, ExploreMethod::Smt).unwrap();
        let lat = |r: &ExploreResult| r.candidates[r.best].est_latency;
        assert!((lat(&ex) - lat(&milp)).abs() < 1e-9);
        assert!((lat(&ex) - lat(&smt)).abs() < 1e-9);
    }

    #[test]
    fn budgets_bind() {
        // A tiny area budget must exclude the torus (long wrap wires &
        // radix-5 routers) and change the answer or fail.
        let loose = explore(&ExploreConfig::default(), ExploreMethod::Exhaustive).unwrap();
        let tight_cfg = ExploreConfig { max_radix: 3, ..Default::default() };
        let tight = explore(&tight_cfg, ExploreMethod::Exhaustive).unwrap();
        assert!(tight.candidates[tight.best].max_radix <= 3);
        // Ring/chordal class wins under radix pressure.
        assert_ne!(
            loose.candidates[loose.best].name,
            tight.candidates[tight.best].name
        );
    }

    #[test]
    fn pareto_front_nonempty_and_valid() {
        let r = explore(&ExploreConfig::default(), ExploreMethod::Exhaustive).unwrap();
        assert!(!r.front.is_empty());
        assert!(r.front.iter().all(|&i| i < r.candidates.len()));
    }

    #[test]
    fn iterative_sim_fills_measurements() {
        let cfg = ExploreConfig { sim_top_k: 2, ..Default::default() };
        let r = explore(&cfg, ExploreMethod::IterativeSim).unwrap();
        assert_eq!(r.sim_evals, 2);
        assert!(r.candidates[r.best].sim_latency.is_some());
        let measured = r.candidates.iter().filter(|c| c.sim_latency.is_some()).count();
        assert_eq!(measured, 2);
    }

    #[test]
    fn infeasible_budget_errors() {
        let cfg = ExploreConfig { max_area: 0.001, ..Default::default() };
        assert!(explore(&cfg, ExploreMethod::Exhaustive).is_err());
    }

    #[test]
    fn grid_candidates_are_deduped_on_transposition() {
        // 20 nodes: side 5, dims {5,6}² — 5x6 and 6x5 are isomorphic and
        // only one may survive.
        for nodes in [16, 20, 27] {
            let cands = candidates_for(nodes);
            let mut seen: HashSet<(char, usize, usize)> = HashSet::new();
            for (name, _) in &cands {
                if let Some(dims) = name
                    .strip_prefix("mesh")
                    .map(|d| ('m', d))
                    .or_else(|| name.strip_prefix("torus").map(|d| ('t', d)))
                {
                    let (fam, d) = dims;
                    let (w, h) = d.split_once('x').unwrap();
                    let (w, h): (usize, usize) = (w.parse().unwrap(), h.parse().unwrap());
                    assert!(
                        seen.insert((fam, w.min(h), w.max(h))),
                        "transposed duplicate {name} at {nodes} nodes"
                    );
                }
            }
        }
        let names: Vec<String> = candidates_for(20).into_iter().map(|(n, _)| n).collect();
        assert!(names.iter().any(|n| n == "mesh5x6"));
        assert!(!names.iter().any(|n| n == "mesh6x5"));
    }

    #[test]
    fn cosim_engine_measures_latency_and_energy() {
        let cfg = ExploreConfig {
            min_nodes: 9,
            max_area: 40.0,
            sim_top_k: 2,
            engine: SimEngine::Cosim,
            ..Default::default()
        };
        let r = explore(&cfg, ExploreMethod::IterativeSim).unwrap();
        assert_eq!(r.sim_evals, 2);
        let best = &r.candidates[r.best];
        assert!(best.sim_latency.unwrap() > 0.0);
        assert!(best.sim_energy_pj.unwrap() > 0.0);
        // Under Cosim the front is restricted to measured candidates.
        assert!(!r.front.is_empty());
        for &i in &r.front {
            assert!(r.candidates[i].sim_energy_pj.is_some());
        }
        // Bit-identical replay.
        let r2 = explore(&cfg, ExploreMethod::IterativeSim).unwrap();
        assert_eq!(r.best, r2.best);
        assert_eq!(best.sim_latency, r2.candidates[r2.best].sim_latency);
        assert_eq!(best.sim_energy_pj, r2.candidates[r2.best].sim_energy_pj);
    }

    #[test]
    fn flit_engine_keeps_the_analytic_front() {
        // The seed behavior: refinement under Flit never changes the
        // analytic Pareto front.
        let screen = explore(&ExploreConfig::default(), ExploreMethod::Exhaustive).unwrap();
        let refined =
            explore(&ExploreConfig::default(), ExploreMethod::IterativeSim).unwrap();
        assert_eq!(screen.front, refined.front);
        assert!(refined.candidates.iter().all(|c| c.sim_energy_pj.is_none()));
    }
}
