//! NoC topology design-space exploration (experiment E4).
//!
//! Implements the paper's three-stage methodology:
//! 1. **Analytic screening** — every candidate topology is costed with the
//!    floorplan + distance model (fast, no simulation).
//! 2. **Solver selection** — MILP (ArchEx-style budgeted argmin) or a
//!    SAT/difference-logic optimization loop picks the best candidate
//!    under area / radix / wirelength budgets.
//! 3. **Iterative simulation-in-the-loop** — the top analytic candidates
//!    are re-scored with the flit-level simulator, and the measured
//!    latency tightens the solver's constraint set ("deduce constraints
//!    to guide the solver to the optimal solution more quickly").

use anyhow::ensure;

use crate::noc::{traffic, Floorplan, NocParams, NocSim, Topology};
use crate::sim::Rng;
use crate::Result;

use super::milp::{Milp, Sense};
use super::pareto::pareto_front;
use super::smt::{Lit, SmtSolver};

/// One candidate topology with its analytic scores.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub name: String,
    pub topo: Topology,
    /// Mean hop distance (analytic latency proxy).
    pub avg_hops: f64,
    /// Estimated mean packet latency, cycles (distance + serialization +
    /// contention inflation from bisection load).
    pub est_latency: f64,
    /// Router + wiring area proxy, mm².
    pub area: f64,
    /// Energy per KiB transported (pJ), floorplan-derated.
    pub energy_per_kib: f64,
    pub max_radix: usize,
    pub wirelength: usize,
    /// Measured latency from the flit simulator (filled by refinement).
    pub sim_latency: Option<f64>,
}

/// Exploration budgets + workload.
#[derive(Debug, Clone)]
pub struct ExploreConfig {
    /// Compute nodes the fabric must host.
    pub min_nodes: usize,
    /// Area budget, mm².
    pub max_area: f64,
    /// Max router radix (low-radix design principle).
    pub max_radix: usize,
    /// Offered load for the traffic model (packets/node/cycle).
    pub rate: f64,
    pub packet_bytes: usize,
    /// Candidates refined with the flit simulator.
    pub sim_top_k: usize,
    pub seed: u64,
}

impl Default for ExploreConfig {
    fn default() -> Self {
        ExploreConfig {
            min_nodes: 16,
            max_area: 10.0,
            max_radix: 5,
            rate: 0.05,
            packet_bytes: 64,
            sim_top_k: 3,
            seed: 7,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExploreMethod {
    /// Analytic screening only.
    Exhaustive,
    /// MILP budgeted argmin over the screened candidates.
    Milp,
    /// SAT + difference-logic linear-search optimization.
    Smt,
    /// MILP + simulation-in-the-loop constraint tightening.
    IterativeSim,
}

/// Exploration output.
#[derive(Debug, Clone)]
pub struct ExploreResult {
    pub candidates: Vec<Candidate>,
    /// Index of the selected candidate.
    pub best: usize,
    /// Pareto-front indices over (est_latency, area, energy).
    pub front: Vec<usize>,
    /// Candidates evaluated by the solver / simulator.
    pub solver_evals: usize,
    pub sim_evals: usize,
}

/// Generate the candidate set for a target size.
pub fn candidates_for(nodes: usize) -> Vec<(String, Topology)> {
    let mut out: Vec<(String, Topology)> = Vec::new();
    let mut push = |name: String, t: Result<Topology>| {
        if let Ok(t) = t {
            if t.nodes() >= nodes && t.is_connected() {
                out.push((name, t));
            }
        }
    };
    // Meshes / tori around the target size.
    let side = (nodes as f64).sqrt().ceil() as usize;
    for w in [side, side + 1] {
        for h in [side.max(1), side + 1] {
            if w * h >= nodes {
                push(format!("mesh{w}x{h}"), Topology::mesh(w, h));
                push(format!("torus{w}x{h}"), Topology::torus(w, h));
            }
        }
    }
    push(format!("ring{nodes}"), Topology::ring(nodes));
    push(format!("star{nodes}"), Topology::star(nodes));
    let down = (nodes as f64).sqrt().ceil() as usize;
    push(format!("fattree{down}"), Topology::fattree(down));
    // Low-radix custom: ring + evenly spaced chords (express links).
    if nodes >= 8 {
        let mut edges: Vec<(usize, usize)> = (0..nodes).map(|i| (i, (i + 1) % nodes)).collect();
        let stride = nodes / 4;
        for i in (0..nodes).step_by(2) {
            let j = (i + stride) % nodes;
            if i != j && !edges.contains(&(i, j)) && !edges.contains(&(j, i)) {
                edges.push((i, j));
            }
        }
        push(format!("chordal{nodes}"), Topology::custom(nodes, &edges));
    }
    out
}

/// Analytic scoring of one topology under the given workload.
pub fn score(name: &str, topo: Topology, cfg: &ExploreConfig) -> Candidate {
    let fp = Floorplan::place(&topo);
    let avg_hops = topo.avg_distance();
    let params = NocParams::default();
    let ser = (cfg.packet_bytes as f64 / params.flit_bytes as f64).ceil();
    // Contention inflation: offered bisection load / capacity.
    let flits_per_cycle = topo.nodes() as f64 * cfg.rate * ser;
    let bisection_cap = topo.bisection_links().max(1) as f64 * 2.0;
    let rho = (flits_per_cycle * 0.5 / bisection_cap).min(0.95);
    let base = avg_hops * params.router_latency as f64 + ser;
    let est_latency = base / (1.0 - rho);
    // Area: radix² crossbar per router + wiring.
    let router_area: f64 = (0..topo.nodes())
        .map(|n| ((topo.degree(n) + 1) as f64).powi(2) * 0.01)
        .sum();
    let area = router_area + fp.total_wirelength() as f64 * 0.02;
    let energy_per_kib = 1024.0 * 8.0
        * params.hop_energy_pj_per_bit
        * avg_hops
        * fp.avg_energy_scale();
    Candidate {
        name: name.to_string(),
        max_radix: topo.max_degree() + 1,
        wirelength: fp.total_wirelength(),
        topo,
        avg_hops,
        est_latency,
        area,
        energy_per_kib,
        sim_latency: None,
    }
}

fn simulate_latency(c: &Candidate, cfg: &ExploreConfig) -> f64 {
    let mut sim = NocSim::new(c.topo.clone(), NocParams::default());
    let mut rng = Rng::new(cfg.seed);
    let inj = traffic::generate(
        traffic::Pattern::Uniform,
        c.topo.nodes(),
        cfg.rate,
        cfg.packet_bytes,
        2_000,
        &mut rng,
    );
    let rep = traffic::drive(&mut sim, inj, 3_000_000);
    rep.avg_latency
}

fn feasible(c: &Candidate, cfg: &ExploreConfig) -> bool {
    c.area <= cfg.max_area && c.max_radix <= cfg.max_radix
}

/// Run the exploration.
pub fn explore(cfg: &ExploreConfig, method: ExploreMethod) -> Result<ExploreResult> {
    let mut cands: Vec<Candidate> = candidates_for(cfg.min_nodes)
        .into_iter()
        .map(|(n, t)| score(&n, t, cfg))
        .collect();
    ensure!(!cands.is_empty(), "no candidate topologies for {} nodes", cfg.min_nodes);
    let mut solver_evals = 0usize;
    let mut sim_evals = 0usize;

    let pick_analytic = |cands: &[Candidate]| -> Option<usize> {
        cands
            .iter()
            .enumerate()
            .filter(|(_, c)| feasible(c, cfg))
            .min_by(|a, b| a.1.est_latency.partial_cmp(&b.1.est_latency).unwrap())
            .map(|(i, _)| i)
    };

    let best = match method {
        ExploreMethod::Exhaustive => {
            solver_evals = cands.len();
            pick_analytic(&cands)
        }
        ExploreMethod::Milp => {
            // Binary selection MILP: pick exactly one candidate minimizing
            // latency under area/radix budgets (ArchEx-style).
            let mut m = Milp::new();
            let vars: Vec<usize> = cands
                .iter()
                .map(|c| m.add_var(0.0, 1.0, c.est_latency, true))
                .collect();
            m.add_constraint(vars.iter().map(|&v| (v, 1.0)).collect(), Sense::Eq, 1.0);
            m.add_constraint(
                vars.iter()
                    .zip(&cands)
                    .map(|(&v, c)| (v, c.area))
                    .collect(),
                Sense::Le,
                cfg.max_area,
            );
            for (v, c) in vars.iter().zip(&cands) {
                if c.max_radix > cfg.max_radix {
                    m.add_constraint(vec![(*v, 1.0)], Sense::Le, 0.0);
                }
            }
            let sol = m.minimize()?;
            solver_evals = sol.as_ref().map_or(0, |s| s.nodes);
            sol.and_then(|s| vars.iter().position(|&v| s.x[v] > 0.5))
        }
        ExploreMethod::Smt => {
            // Linear-search SAT optimization: exactly-one candidate;
            // exclude infeasible; repeatedly forbid everything at least
            // as slow as the incumbent until UNSAT.
            let mut order: Vec<usize> = (0..cands.len()).collect();
            order.sort_by(|&a, &b| {
                cands[a].est_latency.partial_cmp(&cands[b].est_latency).unwrap()
            });
            let mut s = SmtSolver::new();
            let vars: Vec<usize> = cands.iter().map(|_| s.new_var()).collect();
            s.add_clause(vars.iter().map(|&v| Lit::pos(v)).collect());
            for (i, &vi) in vars.iter().enumerate() {
                for &vj in vars.iter().skip(i + 1) {
                    s.add_clause(vec![Lit::neg(vi), Lit::neg(vj)]);
                }
                if !feasible(&cands[i], cfg) {
                    s.add_clause(vec![Lit::neg(vi)]);
                }
            }
            let mut incumbent = None;
            loop {
                solver_evals += 1;
                match s.solve()? {
                    None => break,
                    Some(model) => {
                        let chosen = vars.iter().position(|&v| model[v]).unwrap();
                        incumbent = Some(chosen);
                        // forbid all candidates with latency >= chosen's
                        for (i, &v) in vars.iter().enumerate() {
                            if cands[i].est_latency >= cands[chosen].est_latency {
                                s.add_clause(vec![Lit::neg(v)]);
                            }
                        }
                    }
                }
            }
            incumbent
        }
        ExploreMethod::IterativeSim => {
            // Analytic rank, then sim-refine the top-k feasible
            // candidates; measured latencies replace estimates and the
            // final choice is by measurement.
            let mut order: Vec<usize> = (0..cands.len())
                .filter(|&i| feasible(&cands[i], cfg))
                .collect();
            order.sort_by(|&a, &b| {
                cands[a].est_latency.partial_cmp(&cands[b].est_latency).unwrap()
            });
            for &i in order.iter().take(cfg.sim_top_k) {
                let lat = simulate_latency(&cands[i], cfg);
                cands[i].sim_latency = Some(lat);
                sim_evals += 1;
            }
            solver_evals = order.len();
            order
                .iter()
                .take(cfg.sim_top_k)
                .min_by(|&&a, &&b| {
                    cands[a]
                        .sim_latency
                        .unwrap()
                        .partial_cmp(&cands[b].sim_latency.unwrap())
                        .unwrap()
                })
                .copied()
        }
    };
    let best = best.ok_or_else(|| anyhow::anyhow!("no feasible topology under budgets"))?;
    let points: Vec<Vec<f64>> = cands
        .iter()
        .map(|c| vec![c.est_latency, c.area, c.energy_per_kib])
        .collect();
    let front = pareto_front(&points);
    Ok(ExploreResult { candidates: cands, best, front, solver_evals, sim_evals })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn candidate_generation_covers_families() {
        let cands = candidates_for(16);
        let names: Vec<&str> = cands.iter().map(|(n, _)| n.as_str()).collect();
        assert!(names.iter().any(|n| n.starts_with("mesh")));
        assert!(names.iter().any(|n| n.starts_with("torus")));
        assert!(names.iter().any(|n| n.starts_with("ring")));
        assert!(names.iter().any(|n| n.starts_with("star")));
        assert!(names.iter().any(|n| n.starts_with("fattree")));
        assert!(names.iter().any(|n| n.starts_with("chordal")));
        for (_, t) in &cands {
            assert!(t.is_connected());
        }
    }

    #[test]
    fn methods_agree_on_the_analytic_optimum() {
        let cfg = ExploreConfig::default();
        let ex = explore(&cfg, ExploreMethod::Exhaustive).unwrap();
        let milp = explore(&cfg, ExploreMethod::Milp).unwrap();
        let smt = explore(&cfg, ExploreMethod::Smt).unwrap();
        let lat = |r: &ExploreResult| r.candidates[r.best].est_latency;
        assert!((lat(&ex) - lat(&milp)).abs() < 1e-9);
        assert!((lat(&ex) - lat(&smt)).abs() < 1e-9);
    }

    #[test]
    fn budgets_bind() {
        // A tiny area budget must exclude the torus (long wrap wires &
        // radix-5 routers) and change the answer or fail.
        let loose = explore(&ExploreConfig::default(), ExploreMethod::Exhaustive).unwrap();
        let tight_cfg = ExploreConfig { max_radix: 3, ..Default::default() };
        let tight = explore(&tight_cfg, ExploreMethod::Exhaustive).unwrap();
        assert!(tight.candidates[tight.best].max_radix <= 3);
        // Ring/chordal class wins under radix pressure.
        assert_ne!(
            loose.candidates[loose.best].name,
            tight.candidates[tight.best].name
        );
    }

    #[test]
    fn pareto_front_nonempty_and_valid() {
        let r = explore(&ExploreConfig::default(), ExploreMethod::Exhaustive).unwrap();
        assert!(!r.front.is_empty());
        assert!(r.front.iter().all(|&i| i < r.candidates.len()));
    }

    #[test]
    fn iterative_sim_fills_measurements() {
        let cfg = ExploreConfig { sim_top_k: 2, ..Default::default() };
        let r = explore(&cfg, ExploreMethod::IterativeSim).unwrap();
        assert_eq!(r.sim_evals, 2);
        assert!(r.candidates[r.best].sim_latency.is_some());
        let measured = r.candidates.iter().filter(|c| c.sim_latency.is_some()).count();
        assert_eq!(measured, 2);
    }

    #[test]
    fn infeasible_budget_errors() {
        let cfg = ExploreConfig { max_area: 0.001, ..Default::default() };
        assert!(explore(&cfg, ExploreMethod::Exhaustive).is_err());
    }
}
