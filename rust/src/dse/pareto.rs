//! Pareto-front extraction for cost/performance DSE plots.

/// Indices of the Pareto-optimal points when *minimizing* every
/// objective. Ties: a point dominated by an identical point keeps only
/// the first occurrence.
pub fn pareto_front(points: &[Vec<f64>]) -> Vec<usize> {
    let mut front = Vec::new();
    'outer: for (i, p) in points.iter().enumerate() {
        for (j, q) in points.iter().enumerate() {
            if i == j {
                continue;
            }
            let dominates = q.iter().zip(p).all(|(a, b)| a <= b)
                && q.iter().zip(p).any(|(a, b)| a < b);
            let identical_earlier = j < i && q == p;
            if dominates || identical_earlier {
                continue 'outer;
            }
        }
        front.push(i);
    }
    front
}

/// Hypervolume-style scalar score (product of normalized slack to a
/// reference point) — a quick "is this front better" metric for the
/// iterative explorer.
pub fn front_quality(points: &[Vec<f64>], front: &[usize], reference: &[f64]) -> f64 {
    front
        .iter()
        .map(|&i| {
            points[i]
                .iter()
                .zip(reference)
                .map(|(v, r)| ((r - v) / r).max(0.0))
                .product::<f64>()
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simple_front() {
        let pts = vec![
            vec![1.0, 5.0], // front
            vec![2.0, 4.0], // front
            vec![3.0, 3.0], // front
            vec![3.0, 5.0], // dominated by (1,5)? no: 1<=3 and 5<=5 and 1<3 -> dominated
            vec![2.5, 4.5], // dominated by (2,4)
        ];
        assert_eq!(pareto_front(&pts), vec![0, 1, 2]);
    }

    #[test]
    fn identical_points_keep_one() {
        let pts = vec![vec![1.0, 1.0], vec![1.0, 1.0]];
        assert_eq!(pareto_front(&pts), vec![0]);
    }

    #[test]
    fn single_point() {
        assert_eq!(pareto_front(&[vec![3.0]]), vec![0]);
    }

    #[test]
    fn all_nondominated_in_anti_chain() {
        let pts = vec![vec![1.0, 3.0], vec![2.0, 2.0], vec![3.0, 1.0]];
        assert_eq!(pareto_front(&pts), vec![0, 1, 2]);
    }

    #[test]
    fn quality_prefers_better_fronts() {
        let reference = vec![10.0, 10.0];
        let good = vec![vec![1.0, 1.0]];
        let bad = vec![vec![9.0, 9.0]];
        let qg = front_quality(&good, &[0], &reference);
        let qb = front_quality(&bad, &[0], &reference);
        assert!(qg > qb);
    }
}
