//! SMT-lite: a DPLL SAT core with a lazy difference-logic theory
//! (the "Boolean techniques, such as Satisfiability Modulo Theory" leg of
//! the paper's DSE toolchain).
//!
//! Architecture is the standard lazy-SMT loop: DPLL (unit propagation +
//! branching + chronological backtracking) enumerates Boolean models;
//! each partial model's enabled difference atoms `x_a - x_b <= c` are
//! checked for consistency with Bellman-Ford negative-cycle detection;
//! inconsistent subsets come back as blocking clauses.

use anyhow::ensure;

use crate::Result;

/// A literal: positive or negated Boolean variable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Lit {
    pub var: usize,
    pub positive: bool,
}

impl Lit {
    pub fn pos(var: usize) -> Lit {
        Lit { var, positive: true }
    }

    pub fn neg(var: usize) -> Lit {
        Lit { var, positive: false }
    }
}

/// Difference-logic atom `x_a - x_b <= c`, attached to a Boolean var:
/// when that var is true, the constraint must hold.
#[derive(Debug, Clone, Copy)]
pub struct DiffConstraint {
    pub a: usize,
    pub b: usize,
    pub c: i64,
}

/// The solver.
#[derive(Debug, Default)]
pub struct SmtSolver {
    nvars: usize,
    clauses: Vec<Vec<Lit>>,
    /// theory[boolean var] = Some(difference constraint).
    theory: Vec<Option<DiffConstraint>>,
    /// Number of integer (difference-logic) variables.
    int_vars: usize,
}

/// Assignment state in DPLL.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Val {
    Unassigned,
    True,
    False,
}

impl SmtSolver {
    pub fn new() -> Self {
        SmtSolver::default()
    }

    /// Fresh Boolean variable.
    pub fn new_var(&mut self) -> usize {
        self.nvars += 1;
        self.theory.push(None);
        self.nvars - 1
    }

    /// Fresh Boolean variable tied to a difference atom over integer
    /// variables `a`, `b` (auto-registered).
    pub fn new_diff_var(&mut self, d: DiffConstraint) -> usize {
        let v = self.new_var();
        self.int_vars = self.int_vars.max(d.a + 1).max(d.b + 1);
        self.theory[v] = Some(d);
        v
    }

    pub fn add_clause(&mut self, lits: Vec<Lit>) {
        debug_assert!(lits.iter().all(|l| l.var < self.nvars));
        self.clauses.push(lits);
    }

    /// Theory check: all difference atoms whose Boolean is true must be
    /// jointly satisfiable. Bellman-Ford on the constraint graph
    /// (edge b->a with weight c for x_a - x_b <= c); negative cycle =
    /// conflict. Returns the conflicting atom set on failure.
    fn theory_check(&self, assign: &[Val]) -> Option<Vec<usize>> {
        let mut edges: Vec<(usize, usize, i64, usize)> = Vec::new();
        for (v, d) in self.theory.iter().enumerate() {
            if let (Some(d), Val::True) = (d, assign[v]) {
                edges.push((d.b, d.a, d.c, v));
            }
        }
        if edges.is_empty() || self.int_vars == 0 {
            return None;
        }
        let n = self.int_vars;
        let mut dist = vec![0i64; n];
        for it in 0..=n {
            let mut changed = false;
            for &(from, to, w, _) in &edges {
                if dist[from] + w < dist[to] {
                    dist[to] = dist[from] + w;
                    changed = true;
                }
            }
            if !changed {
                return None;
            }
            if it == n {
                // Conservative conflict: all enabled atoms (a full MUS
                // extractor is overkill at this scale).
                return Some(edges.iter().map(|&(_, _, _, v)| v).collect());
            }
        }
        None
    }

    fn unit_propagate(&self, assign: &mut [Val]) -> bool {
        loop {
            let mut changed = false;
            for clause in &self.clauses {
                let mut unassigned: Option<Lit> = None;
                let mut n_unassigned = 0;
                let mut satisfied = false;
                for &l in clause {
                    match (assign[l.var], l.positive) {
                        (Val::True, true) | (Val::False, false) => {
                            satisfied = true;
                            break;
                        }
                        (Val::Unassigned, _) => {
                            n_unassigned += 1;
                            unassigned = Some(l);
                        }
                        _ => {}
                    }
                }
                if satisfied {
                    continue;
                }
                match n_unassigned {
                    0 => return false, // conflict
                    1 => {
                        let l = unassigned.unwrap();
                        assign[l.var] = if l.positive { Val::True } else { Val::False };
                        changed = true;
                    }
                    _ => {}
                }
            }
            if !changed {
                return true;
            }
        }
    }

    /// Solve; returns a model (Boolean assignment) if SAT.
    pub fn solve(&mut self) -> Result<Option<Vec<bool>>> {
        let mut assign = vec![Val::Unassigned; self.nvars];
        let mut steps = 0usize;
        let sat = self.dpll(&mut assign, &mut steps)?;
        Ok(if sat {
            Some(assign.iter().map(|&v| v == Val::True).collect())
        } else {
            None
        })
    }

    fn dpll(&mut self, assign: &mut Vec<Val>, steps: &mut usize) -> Result<bool> {
        *steps += 1;
        ensure!(*steps < 2_000_000, "DPLL step limit");
        let saved = assign.clone();
        if !self.unit_propagate(assign) {
            *assign = saved;
            return Ok(false);
        }
        // Theory consistency on the partial model (atoms set true so far).
        if let Some(conflict) = self.theory_check(assign) {
            let clause: Vec<Lit> = conflict.into_iter().map(Lit::neg).collect();
            self.clauses.push(clause);
            *assign = saved;
            return Ok(false);
        }
        let Some(v) = assign.iter().position(|&x| x == Val::Unassigned) else {
            return Ok(true); // complete + theory-consistent
        };
        for &val in &[Val::True, Val::False] {
            let snapshot = assign.clone();
            assign[v] = val;
            if self.dpll(assign, steps)? {
                return Ok(true);
            }
            *assign = snapshot;
        }
        *assign = saved;
        Ok(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sat_simple() {
        let mut s = SmtSolver::new();
        let a = s.new_var();
        let b = s.new_var();
        s.add_clause(vec![Lit::pos(a), Lit::pos(b)]);
        s.add_clause(vec![Lit::neg(a)]);
        let m = s.solve().unwrap().unwrap();
        assert!(!m[a] && m[b]);
    }

    #[test]
    fn unsat_simple() {
        let mut s = SmtSolver::new();
        let a = s.new_var();
        s.add_clause(vec![Lit::pos(a)]);
        s.add_clause(vec![Lit::neg(a)]);
        assert!(s.solve().unwrap().is_none());
    }

    #[test]
    fn three_sat_unsat_instance() {
        // (a|b|c)(¬a|b)(¬b|c)(¬c|a)(¬a|¬b|¬c): implication cycle forces
        // a=b=c, first clause forces all-true, last forbids it.
        let mut s = SmtSolver::new();
        let (a, b, c) = (s.new_var(), s.new_var(), s.new_var());
        s.add_clause(vec![Lit::pos(a), Lit::pos(b), Lit::pos(c)]);
        s.add_clause(vec![Lit::neg(a), Lit::pos(b)]);
        s.add_clause(vec![Lit::neg(b), Lit::pos(c)]);
        s.add_clause(vec![Lit::neg(c), Lit::pos(a)]);
        s.add_clause(vec![Lit::neg(a), Lit::neg(b), Lit::neg(c)]);
        assert!(s.solve().unwrap().is_none());
    }

    #[test]
    fn difference_logic_consistent() {
        // x < y, y < z, x - z <= 5: consistent.
        let mut s = SmtSolver::new();
        let d1 = s.new_diff_var(DiffConstraint { a: 0, b: 1, c: -1 });
        let d2 = s.new_diff_var(DiffConstraint { a: 1, b: 2, c: -1 });
        let d3 = s.new_diff_var(DiffConstraint { a: 0, b: 2, c: 5 });
        s.add_clause(vec![Lit::pos(d1)]);
        s.add_clause(vec![Lit::pos(d2)]);
        s.add_clause(vec![Lit::pos(d3)]);
        assert!(s.solve().unwrap().is_some());
    }

    #[test]
    fn difference_logic_cycle_unsat() {
        // x < y and y < x.
        let mut s = SmtSolver::new();
        let d1 = s.new_diff_var(DiffConstraint { a: 0, b: 1, c: -1 });
        let d2 = s.new_diff_var(DiffConstraint { a: 1, b: 0, c: -1 });
        s.add_clause(vec![Lit::pos(d1)]);
        s.add_clause(vec![Lit::pos(d2)]);
        assert!(s.solve().unwrap().is_none());
    }

    #[test]
    fn theory_guides_boolean_choice() {
        // d2 forced; d1 would close a negative cycle with d2; clause
        // (d1 | d3) must resolve to d3.
        let mut s = SmtSolver::new();
        let d1 = s.new_diff_var(DiffConstraint { a: 0, b: 1, c: -3 });
        let d2 = s.new_diff_var(DiffConstraint { a: 1, b: 0, c: -3 });
        let d3 = s.new_var();
        s.add_clause(vec![Lit::pos(d2)]);
        s.add_clause(vec![Lit::pos(d1), Lit::pos(d3)]);
        let m = s.solve().unwrap().unwrap();
        assert!(m[d3]);
        assert!(!(m[d1] && m[d2]));
    }

    #[test]
    fn ordering_synthesis() {
        // Three tasks, pairwise strict orders, model must be a total
        // order (3 of 6 atoms true, acyclic).
        let mut s = SmtSolver::new();
        let mut before = std::collections::HashMap::new();
        for i in 0..3usize {
            for j in 0..3usize {
                if i != j {
                    let v = s.new_diff_var(DiffConstraint { a: i, b: j, c: -1 });
                    before.insert((i, j), v);
                }
            }
        }
        for i in 0..3 {
            for j in (i + 1)..3 {
                let ij = before[&(i, j)];
                let ji = before[&(j, i)];
                s.add_clause(vec![Lit::pos(ij), Lit::pos(ji)]);
                s.add_clause(vec![Lit::neg(ij), Lit::neg(ji)]);
            }
        }
        let m = s.solve().unwrap().unwrap();
        let trues = before.values().filter(|&&v| m[v]).count();
        assert_eq!(trues, 3);
    }
}
