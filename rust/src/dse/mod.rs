//! Design-space exploration (paper Sec. III): "methods for efficient
//! design space exploration to find optimal architectures, using both
//! Mixed-Integer Linear Programming (MILP), following the approach in
//! ArchEx, and Boolean techniques, such as Satisfiability Modulo Theory
//! (SMT) ... System-level simulation will also be introduced using an
//! iterative optimisation approach to speed up the execution and deduce
//! constraints to guide the solver to the optimal solution more quickly."
//!
//! * [`milp`] — dense two-phase simplex + branch-and-bound MILP solver.
//! * [`smt`] — DPLL SAT core with a lazy difference-logic theory.
//! * [`explorer`] — NoC topology DSE: analytic screening, MILP/SMT
//!   candidate selection, iterative simulation-in-the-loop refinement.
//! * [`pareto`] — Pareto-front extraction for the cost/performance plots.

pub mod explorer;
pub mod milp;
pub mod pareto;
pub mod smt;

pub use explorer::{explore, Candidate, ExploreConfig, ExploreMethod, ExploreResult};
pub use milp::{Constraint, Milp, Sense, Solution as MilpSolution};
pub use pareto::pareto_front;
pub use smt::{DiffConstraint, Lit, SmtSolver};
