//! Design-space exploration (paper Sec. III): "methods for efficient
//! design space exploration to find optimal architectures, using both
//! Mixed-Integer Linear Programming (MILP), following the approach in
//! ArchEx, and Boolean techniques, such as Satisfiability Modulo Theory
//! (SMT) ... System-level simulation will also be introduced using an
//! iterative optimisation approach to speed up the execution and deduce
//! constraints to guide the solver to the optimal solution more quickly."
//!
//! * [`milp`] — dense two-phase simplex + branch-and-bound MILP solver.
//! * [`smt`] — DPLL SAT core with a lazy difference-logic theory.
//! * [`explorer`] — NoC topology DSE: analytic screening, MILP/SMT
//!   candidate selection, iterative simulation-in-the-loop refinement.
//! * [`pareto`] — Pareto-front extraction for the cost/performance plots.
//! * [`sweep`] — batched incremental sweeps over full candidate fabrics
//!   `{topology × tile mix × cost model × admission policy}` on the fast
//!   engines (session reuse + parallel groups).
//!
//! # DSE evaluation contract
//!
//! Three evaluation tiers, cheapest first, each pinned against the next:
//!
//! 1. **Analytic screening** (`explorer::score`) — closed-form distance
//!    / bisection / floorplan estimates. No simulation; used to rank and
//!    to prune. This path is frozen: it must stay byte-identical across
//!    refactors because the solver goldens and the Pareto plots are
//!    pinned to it.
//! 2. **Flit-level refinement** ([`SimEngine::Flit`]) — the seed
//!    `IterativeSim` behavior: a cold `NocSim` measures mean packet
//!    latency under synthetic traffic for the analytic top-k.
//! 3. **Fast-engine measurement** ([`SimEngine::Cosim`] and the
//!    [`sweep`] layer) — candidates are built into real fabrics
//!    ([`crate::fabric::Fabric::build_with_topology`]), a probe workload
//!    is mapped through the fabric's cost model (kind-aware under
//!    `model = "kind"`), and the event-driven co-sim measures latency
//!    *and* energy. Under `Cosim` the Pareto front is computed over the
//!    measured subset only — measured workload pJ and analytic pJ/KiB
//!    are different units and must never meet in one domination check.
//!
//! Every measured tier obeys the repo determinism contract: results are
//! pure functions of (spec, seed), bit-identical at every thread count
//! and shard partition. Incremental evaluation (session reuse via
//! `CosimSession::set_model`) must be bit-identical to rebuilding the
//! world from scratch — `sweep::sweep_rebuild` is the differential
//! oracle, and `tests/dse_golden.rs` + `bench_dse` enforce the
//! equivalence on every run.

pub mod explorer;
pub mod milp;
pub mod pareto;
pub mod smt;
pub mod sweep;

pub use explorer::{
    explore, Candidate, ExploreConfig, ExploreMethod, ExploreResult, SimEngine,
};
pub use milp::{Constraint, Milp, Sense, Solution as MilpSolution};
pub use pareto::pareto_front;
pub use smt::{DiffConstraint, Lit, SmtSolver};
pub use sweep::{
    sweep, sweep_rebuild, CandidateEval, MixVariant, PolicyVariant, SweepResult, SweepSpec,
    TopoVariant,
};
