//! Batched incremental DSE on the fast engines: thousands of candidate
//! fabrics per second via session reuse and parallel sweeps.
//!
//! A **sweep** evaluates the cross product
//! `{topology} × {tile mix} × {cost model} × {admission policy}`
//! through the event-driven co-sim — not the analytic screening model.
//! The naive way rebuilds the world per candidate: fabric placement,
//! mapping, lowering, session construction, then a drain. Most of that
//! work is identical between neighbouring candidates, so the sweep
//! shares it:
//!
//! * **Structure sharing** — candidates with the same (topology, mix)
//!   share one [`Fabric`], one mapping and one lowered program set
//!   (probe MLPs mapped through the *base* cost model, so every
//!   candidate in a group prices the identical step structure).
//! * **Session reuse** — per (topology, mix, policy) one persistent
//!   [`CosimSession`] is admitted once; the cost-model axis is walked
//!   with [`CosimSession::set_model`], which maps the config diff onto
//!   the PR 5 invalidation machinery (retract every priced step, keep
//!   programs/queues/DAG state) instead of a rebuild. Policy cannot be
//!   diffed — it reorders frozen admission keys — so it stays a session
//!   axis.
//! * **Parallel fan-out** — (topology, mix) groups are independent, so
//!   they fan out across a [`WorkerPool`], chunked by
//!   [`load_fences`] over a deterministic per-group weight. Each worker
//!   writes into disjoint result slots; the merge walks candidates in
//!   canonical order.
//!
//! # Determinism contract
//!
//! The canonical candidate index is
//! `((t·M + m)·P + p)·C + c` for topology `t`, mix `m`, policy `p`,
//! model `c`. [`sweep`] returns evaluations in exactly that order and
//! is **bit-identical at every thread count**: each candidate's result
//! is a pure function of the spec (workers share nothing mutable), and
//! the merge order is the canonical order, never completion order.
//! Errors are surfaced deterministically too — the failing group with
//! the lowest canonical index wins, regardless of which worker hit it
//! first.
//!
//! [`sweep_rebuild`] is the differential oracle: the same candidates
//! evaluated the slow way (fresh world per candidate). The golden tests
//! and `bench_dse` hold `sweep ≡ sweep_rebuild` bit-for-bit; the bench
//! reports the throughput ratio.

use anyhow::{anyhow, bail, ensure, Context, Error};

use crate::accel::Precision;
use crate::compiler::lowering::lower;
use crate::compiler::mapper::{map_graph_with, MapStrategy};
use crate::compiler::FabricProgram;
use crate::config::{parse_document, CuConfig, Document, FabricConfig};
use crate::coordinator::{AdmitMeta, AdmitPolicy, CosimSession, ProgramSpan};
use crate::fabric::{cost::model_variant, make_accelerator, Fabric};
use crate::noc::Topology;
use crate::sim::{load_fences, Cycle, WorkerPool};
use crate::workloads;
use crate::Result;

/// One topology axis point: the raw spec string (kept as the label) and
/// the built shape.
#[derive(Debug, Clone)]
pub struct TopoVariant {
    pub name: String,
    pub topo: Topology,
}

/// One tile-mix axis point: the raw spec string and the CU groups it
/// expands to (template/TCDM defaults from [`CuConfig`]).
#[derive(Debug, Clone)]
pub struct MixVariant {
    pub name: String,
    pub cus: Vec<CuConfig>,
}

/// One admission-policy axis point.
#[derive(Debug, Clone)]
pub struct PolicyVariant {
    pub name: String,
    pub policy: AdmitPolicy,
}

/// A parsed sweep: base fabric parameters plus the four candidate axes.
///
/// TOML shape (`[sweep]` rides in the same document as the base fabric
/// config; the base `[noc]` width×height must fit the largest mix):
///
/// ```toml
/// [sweep]
/// topologies = ["mesh:8x8", "torus:8x8", "ring:24"]
/// mixes      = ["npu:12", "npu:8+crossbar:4"]
/// models     = ["invariant", "congestion", "dvfs", "kind"]
/// policies   = ["fifo", "priority"]
/// programs   = 2
/// seed       = 7
/// threads    = 4
/// ```
#[derive(Debug, Clone)]
pub struct SweepSpec {
    pub base: FabricConfig,
    pub topologies: Vec<TopoVariant>,
    pub mixes: Vec<MixVariant>,
    pub models: Vec<String>,
    pub policies: Vec<PolicyVariant>,
    /// Probe programs admitted per session (distinct seeds, staggered
    /// priorities/deadlines so the policy axis actually reorders work).
    pub programs: usize,
    pub seed: u64,
    /// Worker threads for the group fan-out (results are bit-identical
    /// at every value).
    pub threads: usize,
}

/// One evaluated candidate fabric (canonical order; see module docs).
#[derive(Debug, Clone)]
pub struct CandidateEval {
    /// Canonical index `((t·M + m)·P + p)·C + c`.
    pub index: usize,
    pub topology: String,
    pub mix: String,
    pub model: String,
    pub policy: String,
    /// Measured makespan across the probe programs, fabric cycles.
    pub makespan: Cycle,
    pub energy_pj: f64,
    pub bytes_moved: u64,
    /// Per-program spans in admission order.
    pub spans: Vec<ProgramSpan>,
}

impl CandidateEval {
    /// Bit-level equality (energy compared by f64 bit pattern) — the
    /// incremental-vs-rebuild and thread-invariance goldens.
    pub fn bit_identical(&self, other: &CandidateEval) -> bool {
        self.index == other.index
            && self.topology == other.topology
            && self.mix == other.mix
            && self.model == other.model
            && self.policy == other.policy
            && self.makespan == other.makespan
            && self.energy_pj.to_bits() == other.energy_pj.to_bits()
            && self.bytes_moved == other.bytes_moved
            && self.spans.len() == other.spans.len()
            && self.spans.iter().zip(&other.spans).all(|(a, b)| a.bit_identical(b))
    }
}

/// Sweep output: evaluations in canonical candidate order plus the
/// session-economy counters the bench reports.
#[derive(Debug, Clone)]
pub struct SweepResult {
    pub evals: Vec<CandidateEval>,
    /// Sessions constructed (`groups × policies` for the incremental
    /// path, one per candidate for the rebuild oracle).
    pub sessions: usize,
    /// `set_model` re-prices performed (0 for the rebuild oracle).
    pub reprices: usize,
}

impl SweepResult {
    /// Index of the best candidate: minimum makespan, ties broken by
    /// canonical index (deterministic).
    pub fn best(&self) -> Option<usize> {
        let mut best: Option<usize> = None;
        for (i, e) in self.evals.iter().enumerate() {
            if best.is_none_or(|b| e.makespan < self.evals[b].makespan) {
                best = Some(i);
            }
        }
        best
    }
}

fn parse_topology(s: &str) -> Result<TopoVariant> {
    let (family, dims) = s
        .split_once(':')
        .ok_or_else(|| anyhow!("topology {s:?} must be \"family:dims\""))?;
    let topo = match family.trim() {
        fam @ ("mesh" | "torus") => {
            let (w, h) = dims
                .split_once('x')
                .ok_or_else(|| anyhow!("{fam} dims {dims:?} must be \"WxH\""))?;
            let w: usize = w.trim().parse().with_context(|| format!("topology {s:?}"))?;
            let h: usize = h.trim().parse().with_context(|| format!("topology {s:?}"))?;
            if fam == "mesh" {
                Topology::mesh(w, h)?
            } else {
                Topology::torus(w, h)?
            }
        }
        fam @ ("ring" | "star" | "fattree") => {
            let n: usize = dims.trim().parse().with_context(|| format!("topology {s:?}"))?;
            match fam {
                "ring" => Topology::ring(n)?,
                "star" => Topology::star(n)?,
                _ => Topology::fattree(n)?,
            }
        }
        other => bail!("unknown topology family {other:?} in {s:?}"),
    };
    ensure!(topo.is_connected(), "topology {s:?} is disconnected");
    Ok(TopoVariant { name: s.to_string(), topo })
}

fn parse_mix(s: &str) -> Result<MixVariant> {
    let mut cus = Vec::new();
    for part in s.split('+') {
        let (kind, count) = part
            .split_once(':')
            .ok_or_else(|| anyhow!("mix component {part:?} must be \"kind:count\""))?;
        let kind = kind.trim();
        let count: usize = count.trim().parse().with_context(|| format!("mix {s:?}"))?;
        ensure!(count > 0, "mix {s:?}: zero-count component {part:?}");
        make_accelerator(kind).with_context(|| format!("mix {s:?}"))?;
        cus.push(CuConfig { kind: kind.to_string(), count, ..CuConfig::default() });
    }
    ensure!(!cus.is_empty(), "empty mix spec");
    Ok(MixVariant { name: s.to_string(), cus })
}

fn parse_policy(s: &str) -> Result<PolicyVariant> {
    let policy = match s {
        "fifo" => AdmitPolicy::Fifo,
        "priority" => AdmitPolicy::Priority,
        "deadline" => AdmitPolicy::Deadline,
        other => bail!("unknown admission policy {other:?}"),
    };
    Ok(PolicyVariant { name: s.to_string(), policy })
}

impl SweepSpec {
    pub fn from_toml(text: &str) -> Result<Self> {
        let doc = parse_document(text).context("parsing sweep config")?;
        Self::from_document(&doc)
    }

    pub fn from_document(doc: &Document) -> Result<Self> {
        let base = FabricConfig::from_document(doc)?;
        let axis = |key: &str, default: &[&str]| -> Result<Vec<String>> {
            match doc.get(key) {
                None => Ok(default.iter().map(|s| s.to_string()).collect()),
                Some(v) => v
                    .as_str_array()
                    .map(|v| v.iter().map(|s| s.to_string()).collect())
                    .ok_or_else(|| anyhow!("{key} must be an array of strings")),
            }
        };
        let topologies = axis("sweep.topologies", &["mesh:4x4"])?
            .iter()
            .map(|s| parse_topology(s))
            .collect::<Result<Vec<_>>>()?;
        let mixes = axis("sweep.mixes", &["npu:4"])?
            .iter()
            .map(|s| parse_mix(s))
            .collect::<Result<Vec<_>>>()?;
        let models = axis("sweep.models", &["invariant"])?;
        for m in &models {
            model_variant(&base.cost, m).with_context(|| format!("sweep model {m:?}"))?;
        }
        let policies = axis("sweep.policies", &["fifo"])?
            .iter()
            .map(|s| parse_policy(s))
            .collect::<Result<Vec<_>>>()?;
        let spec = SweepSpec {
            base,
            topologies,
            mixes,
            models,
            policies,
            programs: doc.get_int("sweep.programs", 2) as usize,
            seed: doc.get_int("sweep.seed", 7) as u64,
            threads: doc.get_int("sweep.threads", 1) as usize,
        };
        spec.validate()?;
        Ok(spec)
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(!self.topologies.is_empty(), "sweep needs at least one topology");
        ensure!(!self.mixes.is_empty(), "sweep needs at least one mix");
        ensure!(!self.models.is_empty(), "sweep needs at least one cost model");
        ensure!(!self.policies.is_empty(), "sweep needs at least one policy");
        ensure!(
            (1..=64).contains(&self.programs),
            "sweep.programs must be in 1..=64, got {}",
            self.programs
        );
        ensure!(
            (1..=1024).contains(&self.threads),
            "sweep.threads must be in 1..=1024, got {}",
            self.threads
        );
        for m in &self.mixes {
            let tiles: usize = m.cus.iter().map(|c| c.count).sum();
            for t in &self.topologies {
                ensure!(
                    tiles < t.topo.nodes(),
                    "mix {:?} ({} tiles + HBM) does not fit topology {:?} ({} nodes)",
                    m.name,
                    tiles,
                    t.name,
                    t.topo.nodes()
                );
            }
        }
        Ok(())
    }

    /// Total candidates in the sweep.
    pub fn candidates(&self) -> usize {
        self.topologies.len() * self.mixes.len() * self.policies.len() * self.models.len()
    }
}

/// Per-group scaffold shared by every candidate in the group: the built
/// fabric plus the probe programs mapped/lowered **once** through the
/// base cost model (structure sharing — see module docs).
struct GroupWorld<'s> {
    fabric: Fabric,
    progs: Vec<FabricProgram>,
    spec: &'s SweepSpec,
}

impl<'s> GroupWorld<'s> {
    fn build(spec: &'s SweepSpec, t: usize, m: usize) -> Result<Self> {
        let topo = &spec.topologies[t];
        let mix = &spec.mixes[m];
        let mut cfg = spec.base.clone();
        cfg.cus = mix.cus.clone();
        let fabric = Fabric::build_with_topology(cfg, topo.topo.clone())
            .with_context(|| format!("candidate fabric {} / {}", topo.name, mix.name))?;
        let mut progs = Vec::with_capacity(spec.programs);
        for k in 0..spec.programs {
            let g = workloads::mlp(4, 64, &[48], 10, spec.seed.wrapping_add(k as u64))?;
            let map = map_graph_with(
                &g,
                &fabric,
                MapStrategy::Greedy,
                Precision::Int8,
                fabric.cost_model().as_ref(),
            )?;
            progs.push(lower(&g, &fabric, &map)?);
        }
        Ok(GroupWorld { fabric, progs, spec })
    }

    /// Admit the probe programs into `sess` with policy-discriminating
    /// metadata: all at cycle 0, later programs more urgent (priority)
    /// and earlier-deadlined (EDF), so Fifo / Priority / Deadline each
    /// order the contention differently.
    fn admit_probes(&self, sess: &mut CosimSession<'_>) -> Result<()> {
        for (k, prog) in self.progs.iter().enumerate() {
            let meta = AdmitMeta {
                priority: (k + 1) as u32,
                deadline: (self.spec.programs - k) as Cycle * 100_000,
            };
            sess.admit_with(prog, 0, meta)?;
        }
        Ok(())
    }

    fn eval(&self, sess: &mut CosimSession<'_>, t: usize, m: usize, p: usize, c: usize)
        -> Result<CandidateEval> {
        let spec = self.spec;
        let rep = sess.report()?;
        let (mn, pn, cn) = (spec.mixes.len(), spec.policies.len(), spec.models.len());
        Ok(CandidateEval {
            index: ((t * mn + m) * pn + p) * cn + c,
            topology: spec.topologies[t].name.clone(),
            mix: spec.mixes[m].name.clone(),
            model: spec.models[c].clone(),
            policy: spec.policies[p].name.clone(),
            makespan: rep.cycles,
            energy_pj: rep.metrics.total_energy_pj(),
            bytes_moved: rep.metrics.bytes_moved,
            spans: rep.programs,
        })
    }
}

/// Evaluate one (topology, mix) group incrementally, writing the
/// `policies × models` candidates into `out` (slot `p·C + c`).
fn eval_group(spec: &SweepSpec, g: usize, out: &mut [Option<CandidateEval>]) -> Result<()> {
    let mn = spec.mixes.len();
    let (t, m) = (g / mn, g % mn);
    let world = GroupWorld::build(spec, t, m)?;
    let cn = spec.models.len();
    for (p, pol) in spec.policies.iter().enumerate() {
        let mut sess =
            CosimSession::with_model(&world.fabric, model_variant(&spec.base.cost, &spec.models[0])?);
        sess.set_threads(1);
        sess.set_policy(pol.policy)?;
        world.admit_probes(&mut sess)?;
        for c in 0..cn {
            if c > 0 {
                sess.set_model(model_variant(&spec.base.cost, &spec.models[c])?)?;
            }
            out[p * cn + c] = Some(world.eval(&mut sess, t, m, p, c)?);
        }
    }
    Ok(())
}

/// Deterministic per-group cost proxy for [`load_fences`]: pricing work
/// scales with the candidate count and (through BFS transport queries)
/// the node count; structure work with the tile count.
fn group_weight(spec: &SweepSpec, g: usize) -> u64 {
    let mn = spec.mixes.len();
    let (t, m) = (g / mn, g % mn);
    let tiles: usize = spec.mixes[m].cus.iter().map(|c| c.count).sum();
    let per_group = spec.policies.len() * spec.models.len();
    (spec.topologies[t].topo.nodes() + 4 * tiles) as u64 * per_group as u64
}

/// Run the sweep incrementally (session reuse + parallel groups). See
/// the module docs for the determinism contract.
pub fn sweep(spec: &SweepSpec) -> Result<SweepResult> {
    spec.validate()?;
    let (mn, pn, cn) = (spec.mixes.len(), spec.policies.len(), spec.models.len());
    let groups = spec.topologies.len() * mn;
    let per_group = pn * cn;
    let mut slots: Vec<Option<CandidateEval>> = (0..groups * per_group).map(|_| None).collect();
    let mut gerrs: Vec<Option<Error>> = (0..groups).map(|_| None).collect();
    let threads = spec.threads.clamp(1, groups);
    if threads <= 1 {
        for (g, err) in gerrs.iter_mut().enumerate() {
            let out = &mut slots[g * per_group..(g + 1) * per_group];
            if let Err(e) = eval_group(spec, g, out) {
                *err = Some(e);
            }
        }
    } else {
        let weights: Vec<u64> = (0..groups).map(|g| group_weight(spec, g)).collect();
        let fences = load_fences(&weights, threads);
        // Disjoint per-chunk views over the slot and error arrays, cut at
        // group boundaries; chunk 0 runs on the calling thread.
        let mut chunks: Vec<(usize, &mut [Option<CandidateEval>], &mut [Option<Error>])> =
            Vec::with_capacity(fences.len() - 1);
        let mut slot_tail: &mut [Option<CandidateEval>] = &mut slots;
        let mut err_tail: &mut [Option<Error>] = &mut gerrs;
        for w in fences.windows(2) {
            let gcount = w[1] - w[0];
            let (s, sr) = std::mem::take(&mut slot_tail).split_at_mut(gcount * per_group);
            let (e, er) = std::mem::take(&mut err_tail).split_at_mut(gcount);
            slot_tail = sr;
            err_tail = er;
            chunks.push((w[0], s, e));
        }
        let mut pool = WorkerPool::new(threads - 1);
        let run_chunk = |g0: usize, s: &mut [Option<CandidateEval>], e: &mut [Option<Error>]| {
            for (gi, err) in e.iter_mut().enumerate() {
                let out = &mut s[gi * per_group..(gi + 1) * per_group];
                if let Err(x) = eval_group(spec, g0 + gi, out) {
                    *err = Some(x);
                }
            }
        };
        let mut it = chunks.into_iter();
        let head = it.next();
        pool.scoped(|scope| {
            for (g0, s, e) in it {
                scope.execute(move || run_chunk(g0, s, e));
            }
            if let Some((g0, s, e)) = head {
                run_chunk(g0, s, e);
            }
        });
    }
    // Deterministic merge: the lowest-indexed failing group wins; else
    // every slot is filled and already in canonical order.
    for (g, err) in gerrs.iter_mut().enumerate() {
        if let Some(e) = err.take() {
            let (t, m) = (g / mn, g % mn);
            return Err(e.context(format!(
                "sweep group {g} ({} / {})",
                spec.topologies[t].name, spec.mixes[m].name
            )));
        }
    }
    let evals: Vec<CandidateEval> =
        slots.into_iter().map(|s| s.expect("unfilled sweep slot")).collect();
    Ok(SweepResult {
        evals,
        sessions: groups * pn,
        reprices: groups * pn * (cn - 1),
    })
}

/// The rebuild-world oracle: every candidate gets a fresh fabric,
/// mapping, lowering and session (no sharing, sequential). Bit-identical
/// to [`sweep`] by the incremental-evaluation contract; the throughput
/// gap between the two is what `bench_dse` measures.
pub fn sweep_rebuild(spec: &SweepSpec) -> Result<SweepResult> {
    spec.validate()?;
    let (mn, pn, cn) = (spec.mixes.len(), spec.policies.len(), spec.models.len());
    let mut evals = Vec::with_capacity(spec.candidates());
    for t in 0..spec.topologies.len() {
        for m in 0..mn {
            for p in 0..pn {
                for c in 0..cn {
                    let world = GroupWorld::build(spec, t, m)?;
                    let mut sess = CosimSession::with_model(
                        &world.fabric,
                        model_variant(&spec.base.cost, &spec.models[c])?,
                    );
                    sess.set_threads(1);
                    sess.set_policy(spec.policies[p].policy)?;
                    world.admit_probes(&mut sess)?;
                    evals.push(world.eval(&mut sess, t, m, p, c)?);
                }
            }
        }
    }
    let sessions = evals.len();
    Ok(SweepResult { evals, sessions, reprices: 0 })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec_toml() -> &'static str {
        r#"
[noc]
width = 3
height = 3

[[cu]]
kind = "npu"
count = 4

[sweep]
topologies = ["mesh:3x3", "ring:10"]
mixes = ["npu:4", "npu:2+crossbar:2"]
models = ["invariant", "congestion"]
policies = ["fifo", "priority"]
programs = 2
seed = 11
"#
    }

    #[test]
    fn spec_parses_axes_and_defaults() {
        let spec = SweepSpec::from_toml(spec_toml()).unwrap();
        assert_eq!(spec.topologies.len(), 2);
        assert_eq!(spec.topologies[1].topo.nodes(), 10);
        assert_eq!(spec.mixes[1].cus.len(), 2);
        assert_eq!(spec.mixes[1].cus[1].kind, "crossbar");
        assert_eq!(spec.models, vec!["invariant", "congestion"]);
        assert_eq!(spec.policies[1].policy, AdmitPolicy::Priority);
        assert_eq!(spec.programs, 2);
        assert_eq!(spec.threads, 1);
        assert_eq!(spec.candidates(), 16);
        // Bare fabric config (no [sweep]) still parses, with defaults.
        let d = SweepSpec::from_toml("[noc]\nwidth = 3\nheight = 3\n[[cu]]\ncount = 4\n")
            .unwrap();
        assert_eq!(d.candidates(), 1);
        assert_eq!(d.topologies[0].name, "mesh:4x4");
    }

    #[test]
    fn bad_axis_strings_rejected() {
        let bad = |patch: &str| {
            let text = spec_toml().replace("topologies = [\"mesh:3x3\", \"ring:10\"]", patch);
            SweepSpec::from_toml(&text)
        };
        assert!(bad("topologies = [\"hypercube:4\"]").is_err());
        assert!(bad("topologies = [\"mesh:9\"]").is_err());
        assert!(bad("topologies = [\"ring:3\"]").is_err(), "mix no longer fits");
        let text = spec_toml().replace("\"npu:2+crossbar:2\"", "\"npu:0\"");
        assert!(SweepSpec::from_toml(&text).is_err());
        let text = spec_toml().replace("\"congestion\"", "\"quantum\"");
        assert!(SweepSpec::from_toml(&text).is_err());
        let text = spec_toml().replace("\"priority\"", "\"lifo\"");
        assert!(SweepSpec::from_toml(&text).is_err());
    }

    #[test]
    fn incremental_matches_rebuild_bitwise() {
        let spec = SweepSpec::from_toml(spec_toml()).unwrap();
        let inc = sweep(&spec).unwrap();
        let reb = sweep_rebuild(&spec).unwrap();
        assert_eq!(inc.evals.len(), 16);
        assert_eq!(reb.evals.len(), 16);
        for (a, b) in inc.evals.iter().zip(&reb.evals) {
            assert!(a.bit_identical(b), "candidate {} diverged: {a:?} vs {b:?}", a.index);
        }
        // Session economy: 4 groups × 2 policies vs one world per
        // candidate; one re-price per extra model.
        assert_eq!(inc.sessions, 8);
        assert_eq!(inc.reprices, 8);
        assert_eq!(reb.sessions, 16);
        assert_eq!(reb.reprices, 0);
    }

    #[test]
    fn thread_count_never_changes_results() {
        let base = SweepSpec::from_toml(spec_toml()).unwrap();
        let one = sweep(&base).unwrap();
        for threads in [2, 4, 8] {
            let spec = SweepSpec { threads, ..base.clone() };
            let many = sweep(&spec).unwrap();
            for (a, b) in one.evals.iter().zip(&many.evals) {
                assert!(a.bit_identical(b), "threads={threads} diverged at {}", a.index);
            }
        }
    }

    #[test]
    fn canonical_order_and_best_are_deterministic() {
        let spec = SweepSpec::from_toml(spec_toml()).unwrap();
        let r = sweep(&spec).unwrap();
        for (i, e) in r.evals.iter().enumerate() {
            assert_eq!(e.index, i);
            assert_eq!(e.spans.len(), 2);
            assert!(e.makespan > 0);
            assert!(e.energy_pj > 0.0);
        }
        // Axis labels cycle in canonical order: model fastest, then
        // policy, then mix, then topology.
        assert_eq!(r.evals[0].model, "invariant");
        assert_eq!(r.evals[1].model, "congestion");
        assert_eq!(r.evals[2].policy, "priority");
        assert_eq!(r.evals[4].mix, "npu:2+crossbar:2");
        assert_eq!(r.evals[8].topology, "ring:10");
        let best = r.best().unwrap();
        assert!(r.evals.iter().all(|e| e.makespan >= r.evals[best].makespan));
        let first_min =
            r.evals.iter().position(|e| e.makespan == r.evals[best].makespan).unwrap();
        assert_eq!(best, first_min, "ties must resolve to the lowest index");
    }

    #[test]
    fn group_failure_is_surfaced_with_context() {
        // Parse-time validation catches bad model names, so break the
        // spec after parsing: every group fails, and the merge must
        // surface the lowest-indexed group with its labels attached.
        let mut spec = SweepSpec::from_toml(spec_toml()).unwrap();
        spec.models[1] = "no-such-model".into();
        let err = format!("{:#}", sweep(&spec).unwrap_err());
        assert!(err.contains("sweep group 0"), "missing group context: {err}");
        assert!(err.contains("mesh:3x3"), "missing topology label: {err}");
    }
}
