//! Batched admission + incremental re-simulation: the multi-program
//! co-simulation layer on the shared calendar.
//!
//! [`super::exec::cosim`] replays exactly one lowered program on a fresh
//! calendar. A serving runtime has the opposite shape: a *stream* of
//! programs arriving over simulated time, sharing one fabric. This module
//! keeps the calendar (and every resource's state) **alive across
//! requests**:
//!
//! * [`CosimSession::admit_at`] inserts a program's steps into the live
//!   resource queues at an arbitrary simulated time — including times in
//!   the already-simulated past (a late-arriving high-priority request);
//! * [`CosimSession::replace`] swaps a program's content and/or admission
//!   time in place — the "program or cost model changed" primitive of a
//!   DSE loop (re-lower at a different precision, bump a workload);
//! * both re-enqueue only the **invalidated closure** (see below), so a
//!   request admitted into a quiescent calendar with a thousand finished
//!   programs costs O(the resource queues it touches + its own steps),
//!   not O(world) — finished programs on *other* resources are never
//!   revisited (pruning drained programs from long-lived shared queues
//!   is the remaining step for unbounded serving runs; see ROADMAP);
//! * [`AdmissionQueue`] batches admissions so a burst prices each step
//!   exactly once instead of draining per request.
//!
//! # Determinism and the FIFO contract
//!
//! Every resource (tile, the HBM port, each active (src, dst) link)
//! serves its steps in ascending `(admit time, admission sequence, step
//! index)` order, and a step starts at `max(dependency ready, resource
//! free)` — the same recurrence as the single-program engine. The key is
//! a total order consistent across all queues with all dependencies
//! pointing backwards, so the multi-program schedule is deadlock-free and
//! uniquely determined. Consequences, pinned by `tests/admission_golden.rs`:
//!
//! * one program admitted at t=0 is **bit-identical** to `exec::cosim`
//!   and `refexec::cosim_ref` (report fields, energy bit patterns);
//! * N programs admitted at t=0 are bit-identical to `exec::cosim` of
//!   the concatenated program;
//! * any admit/replace/run interleaving is bit-identical to a fresh
//!   session built from scratch with the same final programs and times.
//!
//! # Invalidation closure
//!
//! When a program is admitted, replaced or re-priced, the steps whose
//! schedule can change are exactly:
//!
//! 1. the changed program's own steps (they are fresh or re-priced);
//! 2. every step positioned *after* an inserted/removed/invalidated step
//!    in its resource queue (its queue predecessor chain changed);
//! 3. transitively: dependency successors of any invalidated step, and
//!    rule 2 applied again to those.
//!
//! Steps outside the closure keep their completed state byte for byte —
//! no step before an invalidated one in any queue, and no dependency of
//! a valid step, is ever touched, which is what makes the incremental
//! re-run provably equal to the from-scratch oracle. Pending completion
//! events of invalidated in-flight steps are retracted via the
//! generation-stamped calendar ([`crate::sim::StampedCalendar`]) and
//! re-pushed at their recomputed finish times.
//!
//! Step costs come from the start-time-aware fabric hooks
//! ([`crate::fabric::Fabric::feed_at`] / `transport_at` /
//! [`crate::fabric::Tile::execute_at`] ...), priced at each step's true
//! multi-program start cycle — this layer is the first caller for which
//! those `_at` seams carry real congestion information.

use std::collections::{HashMap, HashSet};

use anyhow::ensure;

use crate::compiler::{FabricProgram, Step};
use crate::fabric::Fabric;
use crate::metrics::{Category, Metrics};
use crate::sim::{Cycle, StampedCalendar};
use crate::Result;

use super::exec::{ExecReport, ProgramSpan};

/// Identifies an admitted program within its [`CosimSession`]. The index
/// doubles as the admission sequence used for FIFO tie-breaking and is
/// stable across [`CosimSession::replace`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ProgramHandle(usize);

impl ProgramHandle {
    /// Position of this program in [`CosimSession`] admission order
    /// (== its index in [`ExecReport::programs`]).
    pub fn index(&self) -> usize {
        self.0
    }
}

/// Dynamic per-step state.
#[derive(Debug, Clone)]
struct StepRec {
    /// Resource id serving this step (tile | HBM port | link).
    res: u32,
    /// Position in the resource queue (maintained across insertions).
    qpos: u32,
    started: bool,
    completed: bool,
    finish: Cycle,
    /// Step duration in cycles (finish - start).
    dur: Cycle,
    /// Unresolved dependency count.
    pending: u32,
    /// Max over admit time and resolved dependencies' completion times.
    ready_at: Cycle,
    /// Step cost with cycles zeroed (the fold unit of the report).
    cost: Metrics,
}

/// One admitted program.
#[derive(Debug)]
struct Prog {
    admit_at: Cycle,
    steps: Vec<Step>,
    rec: Vec<StepRec>,
    /// Global id of step 0 (ids `base..base + steps.len()`).
    base: usize,
    /// Successor adjacency, CSR over (intra-program) dependency edges.
    succ_off: Vec<usize>,
    succ: Vec<u32>,
}

/// A resource's wake queue: step ids in `(admit, seq, idx)` order.
#[derive(Debug, Default)]
struct ResQueue {
    steps: Vec<usize>,
    /// Started steps form the prefix `0..cursor`.
    cursor: usize,
    /// Finish time of the last started step.
    free: Cycle,
    /// A started-but-uncompleted step occupies the resource.
    busy: bool,
}

/// A live multi-program co-simulation over one fabric: the admission
/// engine. See the module docs for the determinism and invalidation
/// contracts.
///
/// Error handling: a pricing error (e.g. an `Exec` step whose tile cannot
/// run its precision) surfaces from `admit_at`/`replace`/`run*` and
/// leaves the session in an unspecified (but memory-safe) state — build
/// programs through the compiler, which only emits supported steps.
pub struct CosimSession<'f> {
    fabric: &'f Fabric,
    progs: Vec<Prog>,
    res: Vec<ResQueue>,
    /// Sparse link resources per active (src tile, dst tile) pair.
    link_ids: HashMap<(usize, usize), usize>,
    /// Global step id -> (program, local index).
    id_map: Vec<(u32, u32)>,
    cal: StampedCalendar,
    /// Reusable completion-batch scratch.
    batch: Vec<usize>,
}

/// Price one step starting at `start`: returns (cost with cycles zeroed,
/// duration). Identical to the single-program engine's cost path.
fn price(fabric: &Fabric, step: &Step, start: Cycle) -> Result<(Metrics, Cycle)> {
    Ok(match step {
        Step::Load { tile, bytes, .. } => {
            let cost = fabric.feed_at(*tile, *bytes, start);
            let cyc = cost.cycles;
            (cost.with_cycles(0), cyc)
        }
        Step::Transfer { from, to, bytes, .. } => {
            let src = fabric.tiles[*from].node;
            let dst = fabric.tiles[*to].node;
            let cost = fabric.transport_at(src, dst, *bytes, start);
            let cyc = cost.cycles;
            (cost.with_cycles(0), cyc)
        }
        Step::Exec { tile, compute, precision, .. } => {
            let cost = fabric.tiles[*tile].execute_at(compute, *precision, start)?;
            let cyc = cost.metrics.cycles;
            (cost.metrics.with_cycles(0), cyc)
        }
    })
}

impl<'f> CosimSession<'f> {
    /// An empty session over `fabric` (resources: one queue per tile,
    /// one for the HBM port; link queues appear as programs use pairs).
    pub fn new(fabric: &'f Fabric) -> Self {
        let nt = fabric.tile_count();
        CosimSession {
            fabric,
            progs: Vec::new(),
            res: (0..nt + 1).map(|_| ResQueue::default()).collect(),
            link_ids: HashMap::new(),
            id_map: Vec::new(),
            cal: StampedCalendar::with_horizon(256),
            batch: Vec::new(),
        }
    }

    /// Number of admitted programs.
    pub fn programs(&self) -> usize {
        self.progs.len()
    }

    /// True when no completion events are pending (all admitted work has
    /// been simulated to completion or nothing was admitted).
    pub fn is_quiescent(&self) -> bool {
        self.cal.is_empty()
    }

    /// Admit `prog` into the live calendar at simulated cycle `at`.
    /// Steps become runnable no earlier than `at`; resource FIFO order is
    /// `(admit time, admission sequence, step index)`. `at` may lie in
    /// the already-simulated past — affected steps of other programs are
    /// invalidated and re-simulated (see module docs).
    pub fn admit_at(&mut self, prog: &FabricProgram, at: Cycle) -> Result<ProgramHandle> {
        let slot = self.progs.len();
        self.install(slot, prog, at)?;
        Ok(ProgramHandle(slot))
    }

    /// Replace program `h` (content and admission time) in place — the
    /// "program or cost model changed" primitive. Only the invalidation
    /// closure of the change is re-simulated.
    pub fn replace(&mut self, h: ProgramHandle, prog: &FabricProgram, at: Cycle) -> Result<()> {
        ensure!(h.0 < self.progs.len(), "stale program handle {}", h.0);
        self.install(h.0, prog, at)
    }

    /// Force re-pricing and re-simulation of program `h` (and its
    /// invalidation closure) without changing its content — for callers
    /// whose external cost context changed. Equivalent to `replace` with
    /// the same program.
    pub fn invalidate(&mut self, h: ProgramHandle) -> Result<()> {
        ensure!(h.0 < self.progs.len(), "stale program handle {}", h.0);
        let prog = FabricProgram {
            steps: self.progs[h.0].steps.clone(),
            producer: Vec::new(),
        };
        let at = self.progs[h.0].admit_at;
        self.install(h.0, &prog, at)
    }

    /// Drain every pending completion event; errors if steps remain
    /// unfinished afterwards (impossible for forward-dep programs — the
    /// queue order is a consistent total order, see module docs).
    pub fn run_to_drain(&mut self) -> Result<()> {
        self.drain(None)?;
        let incomplete = self
            .progs
            .iter()
            .flat_map(|p| &p.rec)
            .filter(|r| !r.completed)
            .count();
        ensure!(incomplete == 0, "admission co-sim stalled: {incomplete} steps incomplete");
        Ok(())
    }

    /// Drain completion events up to and including simulated cycle `t`,
    /// leaving later work in flight — programs admitted afterwards land
    /// in a genuinely running calendar (their displaced steps' pending
    /// completions are retracted via generation stamps).
    pub fn run_until(&mut self, t: Cycle) -> Result<()> {
        self.drain(Some(t))
    }

    /// Drain to quiescence and fold the merged report: identical field
    /// semantics to [`super::exec::cosim`], with one [`ProgramSpan`] per
    /// admitted program. Step-ordered data (`step_done`, the energy fold)
    /// runs in `(admission sequence, step index)` order, so a single
    /// program admitted at t=0 reproduces `cosim` bit for bit, and N
    /// programs at t=0 reproduce `cosim` of the concatenated program.
    pub fn report(&mut self) -> Result<ExecReport> {
        self.run_to_drain()?;
        let nt = self.fabric.tile_count();
        let mut total = Metrics::new();
        let mut tile_busy = vec![0 as Cycle; nt];
        let mut step_done = Vec::new();
        let mut transfer_cycles: Cycle = 0;
        let mut exec_steps = 0usize;
        let mut makespan: Cycle = 0;
        let mut programs = Vec::with_capacity(self.progs.len());
        for pr in &self.progs {
            let span =
                Self::fold_program(pr, &mut total, Some(tile_busy.as_mut_slice()), &mut step_done);
            exec_steps += span.exec_steps;
            transfer_cycles += span.transfer_cycles;
            makespan = makespan.max(pr.rec.iter().map(|r| r.finish).max().unwrap_or(0));
            programs.push(span);
        }
        total.cycles = makespan;
        // Fabric-level leakage over the merged episode (same charge as
        // the single-program engines).
        total.add_energy(
            Category::Leakage,
            makespan as f64 * self.fabric.tile_count() as f64 * 0.5,
        );
        Ok(ExecReport {
            cycles: makespan,
            metrics: total,
            tile_busy,
            step_done,
            transfer_cycles,
            exec_steps,
            programs,
        })
    }

    /// Per-program span of `h` — O(program), so the serving path reads
    /// each request's simulated latency without folding the whole world.
    /// Meaningful only once the program has fully completed (call after
    /// [`CosimSession::run_to_drain`]): all steps are folded, and an
    /// in-flight program's unfinished steps would contribute zeroed
    /// placeholders.
    pub fn span(&self, h: ProgramHandle) -> ProgramSpan {
        debug_assert!(
            self.progs[h.0].rec.iter().all(|r| r.completed),
            "span({}) read while the program is still in flight",
            h.0
        );
        Self::fold_program(&self.progs[h.0], &mut Metrics::new(), None, &mut Vec::new())
    }

    /// Fold one program's steps in step order into the merged
    /// accumulators and return its span. The per-program energy is folded
    /// independently in the same order, so it equals a solo run's
    /// pre-leakage energy bit for bit.
    fn fold_program(
        pr: &Prog,
        total: &mut Metrics,
        mut tile_busy: Option<&mut [Cycle]>,
        step_done: &mut Vec<Cycle>,
    ) -> ProgramSpan {
        let mut penergy = Metrics::new();
        let mut p_exec = 0usize;
        let mut p_transfer: Cycle = 0;
        let mut finished = pr.admit_at;
        for (step, rec) in pr.steps.iter().zip(&pr.rec) {
            total.absorb_parallel(&rec.cost);
            penergy.absorb_parallel(&rec.cost);
            step_done.push(rec.finish);
            finished = finished.max(rec.finish);
            if let Step::Exec { tile, .. } = step {
                if let Some(tb) = tile_busy.as_deref_mut() {
                    tb[*tile] += rec.dur;
                }
                p_exec += 1;
            } else {
                p_transfer += rec.dur;
            }
        }
        ProgramSpan {
            admitted_at: pr.admit_at,
            finished_at: finished,
            steps: pr.rec.len(),
            exec_steps: p_exec,
            transfer_cycles: p_transfer,
            ops: penergy.ops,
            bytes_moved: penergy.bytes_moved,
            energy_pj: penergy.total_energy_pj(),
        }
    }

    /// Install `prog` into `slot` (fresh admission when `slot` is one
    /// past the end, replacement otherwise): validate, splice the steps
    /// into the resource queues, invalidate the closure, and re-seed the
    /// wake chain.
    fn install(&mut self, slot: usize, prog: &FabricProgram, at: Cycle) -> Result<()> {
        let nt = self.fabric.tile_count();
        for (i, s) in prog.steps.iter().enumerate() {
            for &d in s.deps() {
                ensure!(d < i, "step {i} depends on non-earlier step {d} (forward deps required)");
            }
            match s {
                Step::Load { tile, .. } | Step::Exec { tile, .. } => {
                    ensure!(*tile < nt, "step {i}: tile {tile} out of range")
                }
                Step::Transfer { from, to, .. } => ensure!(
                    *from < nt && *to < nt,
                    "step {i}: transfer {from}->{to} out of range"
                ),
            }
        }

        let mut seeds: Vec<usize> = Vec::new();
        let mut touched: Vec<usize> = Vec::new();
        if slot < self.progs.len() {
            self.remove_program_steps(slot, &mut seeds, &mut touched);
        }

        // Build the program's static structures. A replacement reuses
        // the outgoing program's global-id range when it fits (its
        // in-flight events were cancelled above and consumed ids hold
        // no queued events, so generation stamps keep any stale entry
        // dead) — the replace/invalidate re-pricing loop then runs with
        // bounded id/generation state; only a *growing* replacement
        // allocates a fresh range.
        let n = prog.steps.len();
        let base = if slot < self.progs.len() && n <= self.progs[slot].rec.len() {
            self.progs[slot].base
        } else {
            let b = self.id_map.len();
            for idx in 0..n {
                self.id_map.push((slot as u32, idx as u32));
            }
            b
        };
        let mut res_of = Vec::with_capacity(n);
        for s in &prog.steps {
            let r = match s {
                Step::Load { .. } => nt,
                Step::Exec { tile, .. } => *tile,
                Step::Transfer { from, to, .. } => {
                    let next = self.res.len();
                    let id = *self.link_ids.entry((*from, *to)).or_insert(next);
                    if id == next {
                        self.res.push(ResQueue::default());
                    }
                    id
                }
            };
            res_of.push(r);
        }
        let mut succ_off = vec![0usize; n + 1];
        for s in &prog.steps {
            for &d in s.deps() {
                succ_off[d + 1] += 1;
            }
        }
        for i in 0..n {
            succ_off[i + 1] += succ_off[i];
        }
        let mut succ = vec![0u32; succ_off[n]];
        let mut cursor: Vec<usize> = succ_off[..n].to_vec();
        for (i, s) in prog.steps.iter().enumerate() {
            for &d in s.deps() {
                succ[cursor[d]] = i as u32;
                cursor[d] += 1;
            }
        }
        let rec: Vec<StepRec> = prog
            .steps
            .iter()
            .enumerate()
            .map(|(i, s)| StepRec {
                res: res_of[i] as u32,
                qpos: 0,
                started: false,
                completed: false,
                finish: 0,
                dur: 0,
                pending: s.deps().len() as u32,
                ready_at: at,
                cost: Metrics::new(),
            })
            .collect();
        let built = Prog {
            admit_at: at,
            steps: prog.steps.clone(),
            rec,
            base,
            succ_off,
            succ,
        };
        if slot == self.progs.len() {
            self.progs.push(built);
        } else {
            self.progs[slot] = built;
        }

        // Splice the new steps into their queues at the FIFO position,
        // seeding every displaced (later-keyed) entry.
        let mut by_res: Vec<(usize, Vec<usize>)> = Vec::new();
        for (idx, &r) in res_of.iter().enumerate() {
            if let Some(pos) = by_res.iter().position(|&(rr, _)| rr == r) {
                by_res[pos].1.push(base + idx);
            } else {
                by_res.push((r, vec![base + idx]));
            }
        }
        for (r, ids) in by_res {
            let pos = self.res[r].steps.partition_point(|&id2| {
                let (p2, _) = self.id_map[id2];
                let p2 = p2 as usize;
                let t2 = self.progs[p2].admit_at;
                t2 < at || (t2 == at && p2 < slot)
            });
            seeds.extend_from_slice(&self.res[r].steps[pos..]);
            self.res[r].steps.splice(pos..pos, ids);
            if !touched.contains(&r) {
                touched.push(r);
            }
        }
        for &r in &touched {
            self.renumber_queue(r);
        }

        // Affected set = structurally changed queues + every resource
        // owning a closure-invalidated step. Resources outside it kept
        // their exact state, so rebuilding and waking only these makes
        // an admission O(affected queues + own steps), not O(world):
        // between operations no resource ever has an idle dep-ready
        // unstarted head (wakes are always exhausted), so an untouched
        // resource cannot need a wake.
        let mut affected = touched;
        self.invalidate_closure(seeds, &mut affected);
        affected.sort_unstable();
        self.rebuild_resource_state(&affected);
        for &r in &affected {
            self.wake_head(r)?;
        }
        Ok(())
    }

    /// Retire program `slot`'s current steps: cancel in-flight completion
    /// events and excise the ids from their queues, seeding every entry
    /// positioned at or after the first removal in each queue.
    fn remove_program_steps(&mut self, slot: usize, seeds: &mut Vec<usize>, touched: &mut Vec<usize>) {
        let base = self.progs[slot].base;
        for (idx, rec) in self.progs[slot].rec.iter().enumerate() {
            if rec.started && !rec.completed {
                self.cal.cancel(base + idx);
            }
            let r = rec.res as usize;
            if !touched.contains(&r) {
                touched.push(r);
            }
        }
        for &r in touched.iter() {
            let old = std::mem::take(&mut self.res[r].steps);
            let mut kept = Vec::with_capacity(old.len());
            let mut min_removed = usize::MAX;
            for id in old {
                if self.id_map[id].0 as usize == slot {
                    min_removed = min_removed.min(kept.len());
                } else {
                    kept.push(id);
                }
            }
            if min_removed != usize::MAX {
                seeds.extend_from_slice(&kept[min_removed..]);
            }
            self.res[r].steps = kept;
        }
    }

    fn renumber_queue(&mut self, r: usize) {
        for k in 0..self.res[r].steps.len() {
            let (p, i) = self.id_map[self.res[r].steps[k]];
            self.progs[p as usize].rec[i as usize].qpos = k as u32;
        }
    }

    /// Propagate the invalidation closure from `seeds`: reset each
    /// reached step (retracting its pending completion event), follow
    /// dependency successors, and extend along resource-queue suffixes.
    /// Afterwards recompute pending counts and ready times from the
    /// surviving completed frontier. Every resource owning an
    /// invalidated step is appended to `affected` (so the caller can
    /// rebuild/wake only those instead of the world).
    fn invalidate_closure(&mut self, seeds: Vec<usize>, affected: &mut Vec<usize>) {
        let mut work = seeds;
        let mut visited: HashSet<usize> = HashSet::new();
        let mut order: Vec<usize> = Vec::new();
        // Lowest invalidated queue position seen per resource: suffix
        // entries beyond it are already in the closure.
        let mut min_pos: HashMap<usize, usize> = HashMap::new();
        while let Some(id) = work.pop() {
            if !visited.insert(id) {
                continue;
            }
            order.push(id);
            let (p, i) = self.id_map[id];
            let (p, i) = (p as usize, i as usize);
            let (started, completed, r, qpos) = {
                let rec = &self.progs[p].rec[i];
                (rec.started, rec.completed, rec.res as usize, rec.qpos as usize)
            };
            if started && !completed {
                self.cal.cancel(id);
            }
            {
                let rec = &mut self.progs[p].rec[i];
                rec.started = false;
                rec.completed = false;
            }
            if !affected.contains(&r) {
                affected.push(r);
            }
            for s in self.progs[p].succ_off[i]..self.progs[p].succ_off[i + 1] {
                let j = self.progs[p].succ[s] as usize;
                work.push(self.progs[p].base + j);
            }
            let cur = min_pos.entry(r).or_insert(usize::MAX);
            if qpos < *cur {
                let hi = (*cur).min(self.res[r].steps.len());
                work.extend_from_slice(&self.res[r].steps[qpos + 1..hi]);
                *cur = qpos;
            }
        }
        for &id in &order {
            let (p, i) = self.id_map[id];
            let (p, i) = (p as usize, i as usize);
            let (pending, ready) = {
                let pr = &self.progs[p];
                let mut pending = 0u32;
                let mut ready = pr.admit_at;
                for &d in pr.steps[i].deps() {
                    let dr = &pr.rec[d];
                    if dr.completed {
                        ready = ready.max(dr.finish);
                    } else {
                        pending += 1;
                    }
                }
                (pending, ready)
            };
            let rec = &mut self.progs[p].rec[i];
            rec.pending = pending;
            rec.ready_at = ready;
        }
    }

    /// Re-derive the given resources' cursor / free / busy from their
    /// queues' started prefixes (started steps always form a prefix:
    /// starts are strictly in queue order and invalidation only clears
    /// suffixes). Resources outside an install's affected set are
    /// untouched by it, so their cached state stays valid.
    fn rebuild_resource_state(&mut self, resources: &[usize]) {
        let (progs, id_map) = (&self.progs, &self.id_map);
        let rec_of = |id: usize| {
            let (p, i) = id_map[id];
            &progs[p as usize].rec[i as usize]
        };
        for &r in resources {
            let rq = &self.res[r];
            let mut cursor = 0usize;
            while cursor < rq.steps.len() && rec_of(rq.steps[cursor]).started {
                cursor += 1;
            }
            let (free, busy) = if cursor == 0 {
                (0, false)
            } else {
                let rec = rec_of(rq.steps[cursor - 1]);
                (rec.finish, !rec.completed)
            };
            let rq = &mut self.res[r];
            rq.cursor = cursor;
            rq.free = free;
            rq.busy = busy;
        }
    }

    /// If resource `r` is idle and its next queued step is
    /// dependency-ready, start the step: price it at `max(ready, free)`
    /// and push its completion event.
    fn wake_head(&mut self, r: usize) -> Result<()> {
        let rq = &self.res[r];
        if rq.busy || rq.cursor >= rq.steps.len() {
            return Ok(());
        }
        let id = rq.steps[rq.cursor];
        let (p, i) = self.id_map[id];
        let (p, i) = (p as usize, i as usize);
        if self.progs[p].rec[i].pending != 0 {
            return Ok(());
        }
        let start = self.progs[p].rec[i].ready_at.max(self.res[r].free);
        let (cost, dur) = price(self.fabric, &self.progs[p].steps[i], start)?;
        {
            let rec = &mut self.progs[p].rec[i];
            rec.started = true;
            rec.finish = start + dur;
            rec.dur = dur;
            rec.cost = cost;
        }
        let rq = &mut self.res[r];
        rq.free = start + dur;
        rq.busy = true;
        rq.cursor += 1;
        self.cal.push(start + dur, id);
        Ok(())
    }

    /// Drain completion batches in time order (bounded by `until`).
    fn drain(&mut self, until: Option<Cycle>) -> Result<()> {
        let mut batch = std::mem::take(&mut self.batch);
        while let Some(t) = self.cal.take_due_until(until, &mut batch) {
            for &id in &batch {
                let (p, i) = self.id_map[id];
                let (p, i) = (p as usize, i as usize);
                let r = {
                    let rec = &mut self.progs[p].rec[i];
                    debug_assert!(rec.started && !rec.completed && rec.finish == t);
                    rec.completed = true;
                    rec.res as usize
                };
                self.res[r].busy = false;
                self.wake_head(r)?;
                let (s0, s1) = {
                    let pr = &self.progs[p];
                    (pr.succ_off[i], pr.succ_off[i + 1])
                };
                for s in s0..s1 {
                    let j = self.progs[p].succ[s] as usize;
                    let wake = {
                        let rec = &mut self.progs[p].rec[j];
                        rec.pending -= 1;
                        rec.ready_at = rec.ready_at.max(t);
                        if rec.pending == 0 { Some(rec.res as usize) } else { None }
                    };
                    if let Some(rr) = wake {
                        self.wake_head(rr)?;
                    }
                }
            }
        }
        self.batch = batch;
        Ok(())
    }
}

/// Deterministic admission batching: requests accumulate in arrival
/// order and flush into a [`CosimSession`] in one pass, so a burst of
/// programs is admitted (and the calendar re-seeded) without draining
/// between requests. `bench_admission` measures the win over
/// one-at-a-time admit+drain.
#[derive(Debug, Default)]
pub struct AdmissionQueue {
    entries: Vec<(FabricProgram, Cycle)>,
}

impl AdmissionQueue {
    pub fn new() -> Self {
        AdmissionQueue::default()
    }

    /// Queue `prog` for admission at simulated cycle `at`.
    pub fn push(&mut self, prog: FabricProgram, at: Cycle) {
        self.entries.push((prog, at));
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Admit every queued program, in push order, returning the handles.
    pub fn admit_all(&mut self, session: &mut CosimSession) -> Result<Vec<ProgramHandle>> {
        let mut handles = Vec::with_capacity(self.entries.len());
        for (prog, at) in self.entries.drain(..) {
            handles.push(session.admit_at(&prog, at)?);
        }
        Ok(handles)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::Precision;
    use crate::compiler::lowering::lower;
    use crate::compiler::mapper::{map_graph, MapStrategy};
    use crate::config::FabricConfig;
    use crate::coordinator::{cosim, cosim_ref};
    use crate::workloads;

    fn fabric() -> Fabric {
        Fabric::build(
            FabricConfig::from_toml(
                "[noc]\nwidth = 3\nheight = 3\n\
                 [[cu]]\nkind = \"npu\"\ntemplate = \"B\"\ncount = 4\n",
            )
            .unwrap(),
        )
        .unwrap()
    }

    fn program(f: &Fabric, seed: u64) -> FabricProgram {
        let g = workloads::mlp(4, 32, &[32, 16], 8, seed).unwrap();
        let m = map_graph(&g, f, MapStrategy::Greedy, Precision::Int8).unwrap();
        lower(&g, f, &m).unwrap()
    }

    #[test]
    fn single_program_at_zero_matches_cosim_bitwise() {
        let f = fabric();
        let p = program(&f, 1);
        let mut s = CosimSession::new(&f);
        s.admit_at(&p, 0).unwrap();
        let got = s.report().unwrap();
        let want = cosim(&f, &p).unwrap();
        let want_ref = cosim_ref(&f, &p).unwrap();
        assert!(got.bit_identical(&want), "session vs event engine");
        assert!(got.bit_identical(&want_ref), "session vs list scheduler");
    }

    #[test]
    fn report_is_repeatable_and_incremental_admit_extends_it() {
        let f = fabric();
        let p1 = program(&f, 1);
        let p2 = program(&f, 2);
        let mut s = CosimSession::new(&f);
        let h1 = s.admit_at(&p1, 0).unwrap();
        let a = s.report().unwrap();
        let b = s.report().unwrap();
        assert!(a.bit_identical(&b), "re-reporting a quiescent session");
        let h2 = s.admit_at(&p2, a.cycles + 100).unwrap();
        let c = s.report().unwrap();
        assert_eq!(c.programs.len(), 2);
        // Tail admission after quiescence must not disturb program 1.
        assert!(c.programs[h1.index()].bit_identical(&a.programs[0]));
        assert_eq!(c.programs[h2.index()].admitted_at, a.cycles + 100);
        assert!(c.cycles >= a.cycles);
    }

    #[test]
    fn retroactive_admission_matches_fresh_session() {
        let f = fabric();
        let p1 = program(&f, 3);
        let p2 = program(&f, 4);
        // Incremental: admit p1 at t=500, drain, then admit p2 at t=0 —
        // in the simulated past, displacing p1's already-run steps.
        let mut inc = CosimSession::new(&f);
        inc.admit_at(&p1, 500).unwrap();
        inc.run_to_drain().unwrap();
        inc.admit_at(&p2, 0).unwrap();
        let got = inc.report().unwrap();
        // Oracle: fresh session, same programs and times, same sequence.
        let mut fresh = CosimSession::new(&f);
        fresh.admit_at(&p1, 500).unwrap();
        fresh.admit_at(&p2, 0).unwrap();
        let want = fresh.report().unwrap();
        assert!(got.bit_identical(&want));
    }

    #[test]
    fn replace_reprices_only_that_program() {
        let f = fabric();
        let p1 = program(&f, 5);
        let p2 = program(&f, 6);
        let p2b = program(&f, 7);
        let mut inc = CosimSession::new(&f);
        let _h1 = inc.admit_at(&p1, 0).unwrap();
        let h2 = inc.admit_at(&p2, 10).unwrap();
        inc.run_to_drain().unwrap();
        inc.replace(h2, &p2b, 10).unwrap();
        let got = inc.report().unwrap();
        let mut fresh = CosimSession::new(&f);
        fresh.admit_at(&p1, 0).unwrap();
        fresh.admit_at(&p2b, 10).unwrap();
        let want = fresh.report().unwrap();
        assert!(got.bit_identical(&want));
    }

    #[test]
    fn run_until_pauses_mid_flight() {
        let f = fabric();
        let p1 = program(&f, 8);
        let mut s = CosimSession::new(&f);
        let h = s.admit_at(&p1, 0).unwrap();
        let full = {
            let mut s2 = CosimSession::new(&f);
            s2.admit_at(&p1, 0).unwrap();
            s2.report().unwrap()
        };
        s.run_until(full.cycles / 2).unwrap();
        assert!(!s.is_quiescent(), "work must remain in flight");
        let got = s.report().unwrap();
        assert!(got.bit_identical(&full));
        assert_eq!(s.span(h).finished_at, full.cycles);
    }

    #[test]
    fn admission_queue_batches_in_push_order() {
        let f = fabric();
        let mut q = AdmissionQueue::new();
        assert!(q.is_empty());
        q.push(program(&f, 1), 0);
        q.push(program(&f, 2), 0);
        assert_eq!(q.len(), 2);
        let mut s = CosimSession::new(&f);
        let hs = q.admit_all(&mut s).unwrap();
        assert!(q.is_empty());
        assert_eq!(hs.iter().map(ProgramHandle::index).collect::<Vec<_>>(), [0, 1]);
        let mut seq = CosimSession::new(&f);
        seq.admit_at(&program(&f, 1), 0).unwrap();
        seq.run_to_drain().unwrap();
        seq.admit_at(&program(&f, 2), 0).unwrap();
        let a = s.report().unwrap();
        let b = seq.report().unwrap();
        assert!(a.bit_identical(&b), "batched vs one-at-a-time admission");
    }

    #[test]
    fn rejects_malformed_programs() {
        let f = fabric();
        let mut s = CosimSession::new(&f);
        let bad = FabricProgram {
            steps: vec![Step::Load { tile: 0, bytes: 64, node: 0, deps: vec![0] }],
            producer: Vec::new(),
        };
        assert!(s.admit_at(&bad, 0).is_err(), "self-dependency");
        let bad_tile = FabricProgram {
            steps: vec![Step::Load { tile: 99, bytes: 64, node: 0, deps: vec![] }],
            producer: Vec::new(),
        };
        assert!(s.admit_at(&bad_tile, 0).is_err(), "tile out of range");
    }
}
