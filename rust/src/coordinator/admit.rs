//! Batched admission + incremental re-simulation: the multi-program
//! co-simulation layer on the shared calendar.
//!
//! [`super::exec::cosim`] replays exactly one lowered program on a fresh
//! calendar. A serving runtime has the opposite shape: a *stream* of
//! programs arriving over simulated time, sharing one fabric. This module
//! keeps the calendar (and every resource's state) **alive across
//! requests**:
//!
//! * [`CosimSession::admit_at`] inserts a program's steps into the live
//!   resource queues at an arbitrary simulated time — including times in
//!   the already-simulated past (a late-arriving high-priority request);
//! * [`CosimSession::replace`] swaps a program's content and/or admission
//!   time in place — the "program or cost model changed" primitive of a
//!   DSE loop (re-lower at a different precision, bump a workload);
//! * both re-enqueue only the **invalidated closure** (see below), so a
//!   request admitted into a quiescent calendar with a thousand finished
//!   programs costs O(the resource queues it touches + its own steps),
//!   not O(world) — finished programs on *other* resources are never
//!   revisited, and [`CosimSession::prune_completed_before`] bounds the
//!   shared queues themselves for unbounded serving runs;
//! * [`AdmissionQueue`] batches admissions so a burst prices each step
//!   exactly once instead of draining per request.
//!
//! # Determinism and the queue-key contract
//!
//! Every resource (tile, the HBM port, each active (src, dst) link)
//! serves its steps in ascending `(program key, step index)` order, and a
//! step starts at `max(dependency ready, resource free)` — the same
//! recurrence as the single-program engine. The program key is chosen by
//! the session's [`AdmitPolicy`]:
//!
//! * [`AdmitPolicy::Fifo`] (default) — `(admit time, admission seq)`:
//!   exactly the PR 4 order, so every pre-policy report is unchanged;
//! * [`AdmitPolicy::Priority`] — `(admit time, inverted priority,
//!   admission seq)`: among same-instant admissions, higher
//!   [`AdmitMeta::priority`] is served first;
//! * [`AdmitPolicy::Deadline`] — `(deadline, admit time, admission
//!   seq)`: earliest-deadline-first across the whole stream.
//!
//! Any such key is a total order on programs, and dependencies are
//! intra-program pointing backwards in step index, so the multi-program
//! schedule stays deadlock-free and uniquely determined under every
//! policy. Consequences, pinned by `tests/admission_golden.rs`:
//!
//! * one program admitted at t=0 is **bit-identical** to `exec::cosim`
//!   and `refexec::cosim_ref` (report fields, energy bit patterns);
//! * N programs admitted at t=0 are bit-identical to `exec::cosim` of
//!   the concatenated program;
//! * any admit/replace/run interleaving is bit-identical to a fresh
//!   session built from scratch with the same final programs and times.
//!
//! # Invalidation: structural closure + time horizon
//!
//! When a program is admitted, replaced or re-priced, the steps whose
//! schedule can change are exactly:
//!
//! 1. the changed program's own steps (they are fresh or re-priced);
//! 2. every step positioned *after* an inserted/removed/invalidated step
//!    in its resource queue (its queue predecessor chain changed);
//! 3. transitively: dependency successors of any invalidated step, and
//!    rule 2 applied again to those.
//!
//! That **structural closure** is complete for a time-invariant cost
//! model, and steps outside it keep their completed state byte for byte.
//! Under a *time-varying* model ([`crate::fabric::TimeDependence::
//! VaryingAfter`]) prices also depend on occupancy, so a perturbation at
//! simulated time `t` additionally invalidates **every scheduled step
//! with start ≥ t** (the *horizon closure*); if the closure itself
//! reaches a started step with an earlier start, the horizon is lowered
//! to it and re-applied until stable. Pending completion events of
//! invalidated in-flight steps are retracted via the generation-stamped
//! calendar ([`crate::sim::StampedCalendar`]) and re-pushed at their
//! recomputed finish times, and every registered occupancy span is
//! retracted integer-exactly ([`crate::fabric::Occupancy`]).
//!
//! # The settle loop (occupancy-coupled fixed point)
//!
//! Re-simulating after a horizon invalidation prices steps against the
//! occupancy registered *so far*, which may still change as later-priced
//! steps register (admissions at out-of-order times price eagerly). So
//! for time-varying models [`CosimSession::run_to_drain`] finishes with
//! a **fixed-point re-pricing loop**: re-price every settled step with
//! start ≥ the dirty horizon against the final occupancy; if any price
//! diverges, horizon-invalidate from the earliest divergent start,
//! re-drain, and repeat. Because models read occupancy of **strictly
//! earlier epochs** only (the `fabric::cost` purity contract), each pass
//! finalizes at least one more epoch prefix — after a pass starting at
//! `t`, every contribution to epochs `< epoch(t)` comes from steps
//! starting before `t` (unchanged), so steps starting in `epoch(t)` are
//! final and the next divergence lies in a strictly later epoch. The
//! loop therefore converges in at most `(makespan − t₀)/epoch + 2`
//! passes; a hard cap ([`MAX_SETTLE_PASSES`]) guards against models that
//! violate the contract. The same stratification makes the
//! self-consistent schedule **unique**, which is why an incremental
//! session bit-matches a from-scratch session (and, at t=0, the single
//! program engines) under congestion/DVFS models —
//! `tests/costmodel_golden.rs` pins all of it.
//!
//! # Shard-parallel epoch execution (the PR 3 contract, one layer up)
//!
//! With `threads > 1` (config `[session] threads`, or
//! [`CosimSession::set_threads`]) the drain loop executes each calendar
//! batch — all completions due at one simulated instant, the session's
//! *epoch barrier* — in three phases instead of one sequential pass:
//!
//! 1. **Bookkeeping (sequential, canonical order).** Completions are
//!    applied and dependency successors decremented in exactly the
//!    sequential order; every wake whose fire condition holds (idle
//!    resource, dependency-ready head) is *staged*: its start cycle
//!    `max(ready, free)` is final at stage time — a firing head has
//!    `pending == 0`, so every dependency (including same-batch ones)
//!    already contributed to `ready_at`, and a resource fires at most
//!    once per batch (it turns busy) — but its pricing is deferred.
//! 2. **Pricing (shard-parallel).** Resources are partitioned into
//!    contiguous index ranges (*shards*); each shard prices its staged
//!    fires on the [`crate::sim::WorkerPool`] against the batch-start
//!    occupancy snapshot, holding a disjoint `&mut` view of its own
//!    [`ResQueue`] slice (it advances `free`) and buffering `(cost,
//!    duration)` — and any pricing error — in per-shard scratch. The
//!    snapshot read is sound because cost models read occupancy of
//!    **strictly earlier epochs** only (the `fabric::cost` purity
//!    contract): a same-batch predecessor fire can only perturb this
//!    fire's price if it starts in a *strictly earlier* epoch.
//! 3. **Merge (sequential, canonical order).** Fires commit in staging
//!    order — the exact order the sequential loop would have priced
//!    them — writing records, registering occupancy, and re-pushing
//!    completions, so the calendar's FIFO tie-breaks (and hence every
//!    later batch's order, every `ExecReport`/`ProgramSpan` bit, and
//!    the f64 energy fold order) replay the sequential schedule
//!    exactly. The one case where the snapshot price could differ —
//!    an already-committed fire of this batch starting in a strictly
//!    earlier epoch than the committing fire — is detected by tracking
//!    the minimum committed start epoch and re-priced inline against
//!    the live occupancy, which at that point equals the sequential
//!    loop's occupancy state bit for bit. A pricing error surfaces at
//!    its canonical fire position (earlier fires commit, the session
//!    stays memory-safe-but-unspecified, as documented above).
//!
//! `threads = 1` (the default) takes the pre-parallel sequential path
//! verbatim — no per-epoch allocation, no pool, same cost-model `Arc`.
//! The partition is exposed to property tests via
//! [`CosimSession::set_shards`]; `tests/admission_golden.rs` and
//! `tests/fault_golden.rs` pin threads ∈ {1, 2, 4, 8} and adversarial
//! partitions bit-identical across the golden matrix. Install/settle
//! wakes stay sequential — only drain batches fan out, which is where
//! O(active resources) work per instant lives. How often the merge's
//! live re-price fires on real streams is telemetry now:
//! [`StraddleStats`] / [`CosimSession::straddle_stats`], surfaced per
//! thread count by `bench_admission`. One layer further up,
//! [`super::shard`] replicates whole sessions — each shard an
//! independent `CosimSession`/[`FaultySession`] with its own `threads`
//! — behind a deterministic request router; its serving determinism
//! contract (hash routing, canonical merge order, replay guarantee)
//! composes with, and is documented alongside, this one.
//!
//! # Pruning and the admission floor
//!
//! Drained programs stay in the shared resource queues, so an unbounded
//! serving run's splice/renumber cost would grow with history.
//! [`CosimSession::prune_completed_before`]`(t)` removes the queue
//! entries of every program that fully completed before `t` *and* whose
//! queue key sorts below `t`, recycles their global-id ranges for future
//! admissions, and raises the **admission floor** to `t`: from then on
//! admissions/replaces below the floor (by time or queue key) are
//! rejected, so pruned history can never be displaced and every report
//! stays bit-identical to an unpruned session. Pruning is a perf/memory
//! operation, never a semantic one.
//!
//! Step costs come from the session's cost model
//! ([`crate::fabric::CostModel`]; [`CosimSession::new`] uses the
//! fabric's configured `[fabric.cost]` model,
//! [`CosimSession::with_model`] takes an explicit handle), priced at
//! each step's true multi-program start cycle with the live occupancy
//! aggregates — this layer is the first caller for which the cost seam
//! carries real cross-program congestion information.
//!
//! # Fault injection and graceful degradation
//!
//! [`FaultySession`] is the *recovery* half of the robustness layer
//! (injection: [`crate::sim::fault`]; pricing:
//! [`crate::fabric::DegradedCost`]). It wraps a [`CosimSession`] and a
//! seeded [`FaultPlan`], processing fault events strictly in the plan's
//! canonical order, each applied after draining the session to the
//! event's cycle — so the machine state a fault observes is a
//! deterministic function of (admissions, plan), never of pause
//! granularity or call order. Behavioral faults recover by
//! **whole-request restart**: the afflicted program's steps (including
//! in-flight ones, retracted via the stamped calendar) are replaced in
//! place, either with the same content later (transient retry with
//! exponential backoff), with content re-mapped off dead silicon
//! (first alive same-accelerator-kind tile by ascending index), or
//! with an empty program (shedding). The [`RecoveryPolicy`] selects
//! among these; pricing faults act purely through the pre-materialized
//! `DegradedCost` wrapper and need no runtime action.
//!
//! **Incremental ≡ from-scratch, with faults.** The fault layer keeps
//! the session's replay contract: any interleaving of admissions and
//! `run_until` pauses produces bit-identical reports to a fresh
//! `FaultySession` given the same admissions up front, pinned by
//! `tests/fault_golden.rs`. Three mechanisms carry the proof:
//!
//! 1. events apply at plan-determined instants against drained state,
//!    so extra pauses change nothing;
//! 2. the **fault floor** (the last processed event's cycle) rejects
//!    admissions arriving earlier *and* admissions whose invalidation
//!    closure would displace any step scheduled before it, so the
//!    history every already-applied fault observed stays frozen;
//! 3. a late admission **replays the processed death prefix** at
//!    admit time — walking processed `TileDeath` events in order and
//!    re-mapping/shedding exactly as the event loop would have, with
//!    restart time `max(arrival, death + detect)` — so admitting after
//!    a death equals having been admitted before it.
//!
//! Recovery retraction may legitimately re-flow *unafflicted* programs
//! (freed resources pull queued steps earlier); a quarantine sweep
//! after every recovery re-checks all dead tiles and re-maps any
//! program whose uncompleted work landed on one, so no final schedule
//! keeps live work on dead silicon. Under a time-varying base model
//! the same caveat as plain sessions applies: mid-flight prices are
//! provisional until the next full drain settles the fixed point, and
//! the fixed point's uniqueness is what makes the final bits
//! path-independent.

use std::collections::{BTreeSet, HashMap, HashSet};
use std::sync::Arc;

use anyhow::ensure;

use crate::compiler::{FabricProgram, Step};
use crate::fabric::{CostModel, DegradedCost, Fabric, Occupancy};
use crate::metrics::{Category, Metrics};
use crate::sim::{Cycle, FaultConfig, FaultEvent, FaultKind, FaultPlan, StampedCalendar, WorkerPool};
use crate::Result;

use super::exec::{ExecReport, ProgramSpan};

/// Hard cap on settle passes — generous (the epoch-prefix argument
/// bounds real convergence by `makespan / epoch + 2`); hitting it means
/// the cost model violates the strictly-earlier-epoch purity contract.
pub const MAX_SETTLE_PASSES: usize = 4096;

/// Identifies an admitted program within its [`CosimSession`]. The index
/// doubles as the admission sequence used for FIFO tie-breaking and is
/// stable across [`CosimSession::replace`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ProgramHandle(usize);

impl ProgramHandle {
    /// Position of this program in [`CosimSession`] admission order
    /// (== its index in [`ExecReport::programs`]).
    pub fn index(&self) -> usize {
        self.0
    }
}

/// Queue-key policy of a session (see the module docs for the exact key
/// per variant). Fixed before the first admission.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AdmitPolicy {
    /// `(admit time, admission seq)` — the PR 4 order.
    #[default]
    Fifo,
    /// `(admit time, inverted priority, admission seq)`.
    Priority,
    /// `(deadline, admit time, admission seq)` — EDF.
    Deadline,
}

/// Per-program admission metadata consumed by the non-FIFO policies
/// (ignored under [`AdmitPolicy::Fifo`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AdmitMeta {
    /// Larger = more urgent under [`AdmitPolicy::Priority`].
    pub priority: u32,
    /// Absolute-deadline cycle under [`AdmitPolicy::Deadline`].
    pub deadline: Cycle,
}

impl Default for AdmitMeta {
    fn default() -> Self {
        AdmitMeta { priority: 0, deadline: Cycle::MAX }
    }
}

/// The program-level queue key (lexicographic; step index is appended
/// implicitly by per-program step order).
fn prog_key(policy: AdmitPolicy, at: Cycle, meta: AdmitMeta, seq: usize) -> [u64; 3] {
    match policy {
        AdmitPolicy::Fifo => [at, seq as u64, 0],
        AdmitPolicy::Priority => [at, (u32::MAX - meta.priority) as u64, seq as u64],
        AdmitPolicy::Deadline => [meta.deadline, at, seq as u64],
    }
}

/// Dynamic per-step state.
#[derive(Debug, Clone)]
struct StepRec {
    /// Resource id serving this step (tile | HBM port | link).
    res: u32,
    /// Position in the resource queue (maintained across insertions).
    qpos: u32,
    started: bool,
    completed: bool,
    /// Scheduled start cycle (valid while `started`).
    start: Cycle,
    finish: Cycle,
    /// Step duration in cycles (finish - start).
    dur: Cycle,
    /// Unresolved dependency count.
    pending: u32,
    /// Max over admit time and resolved dependencies' completion times.
    ready_at: Cycle,
    /// Step cost with cycles zeroed (the fold unit of the report).
    cost: Metrics,
}

/// One admitted program.
#[derive(Debug)]
struct Prog {
    admit_at: Cycle,
    meta: AdmitMeta,
    /// Policy queue key (see [`prog_key`]).
    key: [u64; 3],
    steps: Vec<Step>,
    rec: Vec<StepRec>,
    /// Global id of step 0 (ids `base..base + steps.len()`).
    base: usize,
    /// Successor adjacency, CSR over (intra-program) dependency edges.
    succ_off: Vec<usize>,
    succ: Vec<u32>,
    /// Uncompleted step count (the O(1) drain/telemetry counter).
    remaining: usize,
    /// Cached span, maintained eagerly when the last step completes and
    /// dropped on any invalidation — [`CosimSession::span`] is O(1).
    span_cache: Option<ProgramSpan>,
    /// Queue entries removed + id range recycled; frozen history.
    pruned: bool,
    /// Per-step history (`steps`/`rec`/CSR) dropped at prune time
    /// ([`CosimSession::set_discard_pruned`]); the span cache is the
    /// only surviving telemetry.
    discarded: bool,
}

/// A resource's wake queue: step ids in `(program key, step idx)` order.
#[derive(Debug, Default)]
struct ResQueue {
    steps: Vec<usize>,
    /// Started steps form the prefix `0..cursor`.
    cursor: usize,
    /// Finish time of the last started step.
    free: Cycle,
    /// A started-but-uncompleted step occupies the resource.
    busy: bool,
    /// Historical pricing mass: cycles of step duration priced on this
    /// resource over the session's lifetime (monotone — invalidated
    /// steps keep their contribution; re-prices add again). Purely a
    /// load *heuristic* feeding [`CosimSession::refresh_shard_bounds`]'s
    /// load-aware fences; never read by the simulation itself, so its
    /// drift across code paths cannot perturb results (any fence
    /// partition is bit-identical by the shard contract).
    load: u64,
}

/// One staged wake of the parallel drain's bookkeeping phase: resource
/// `res` fires step `id` at `start`; pricing is deferred to the shard
/// phase (see the module docs' shard-parallel section).
#[derive(Debug, Clone, Copy)]
struct Fire {
    /// Global step id.
    id: usize,
    /// Resource the step fires on (selects the shard).
    res: u32,
    /// `max(ready_at, free)` — final at staging time.
    start: Cycle,
}

/// Per-shard scratch of the parallel pricing phase (reused across
/// batches; cross-shard effects live here until the sequential merge).
#[derive(Debug, Default)]
struct PriceScratch {
    /// This shard's fires, as ascending indices into the batch fire list.
    fires: Vec<u32>,
    /// `(cost, duration)` per entry of `fires` (a prefix on error).
    out: Vec<(Metrics, Cycle)>,
    /// Merge cursor into `out`.
    taken: usize,
    /// First pricing error: (fire-list index, error). Surfaced by the
    /// merge at its canonical position.
    err: Option<(u32, anyhow::Error)>,
}

/// A live multi-program co-simulation over one fabric: the admission
/// engine. See the module docs for the determinism, invalidation and
/// settle contracts.
///
/// Error handling: a pricing error (e.g. an `Exec` step whose tile cannot
/// run its precision) surfaces from `admit_at`/`replace`/`run*` and
/// leaves the session in an unspecified (but memory-safe) state — build
/// programs through the compiler, which only emits supported steps. The
/// same applies to perturbations rejected for reaching below the pruned
/// admission floor.
/// Epoch-boundary-straddle telemetry of the shard-parallel drain
/// (ROADMAP PR 7 follow-up (m)). Phase 2 prices every staged fire
/// against the batch-start occupancy snapshot; when a batch's fires
/// straddle an epoch boundary, the later-epoch fires may legally read
/// occupancy committed earlier in the same batch, so the phase-3 merge
/// re-prices them against live state. That re-price is correct but
/// sequential — if it dominates, the parallel drain degrades toward the
/// sequential engine, and the remedy would be splitting batches at
/// epoch fences up front. These counters make that call data:
/// `bench_admission` surfaces them per thread count in its table and in
/// `BENCH_admission.json`.
///
/// Counters accumulate over the session's lifetime and only the
/// parallel drain path updates them (`threads == 1` leaves them zero).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StraddleStats {
    /// Parallel batches executed (batches with at least one staged fire).
    pub batches: u64,
    /// Batches in which at least one fire was re-priced live at merge.
    pub straddled_batches: u64,
    /// Total fires re-priced live at merge.
    pub repriced_fires: u64,
}

pub struct CosimSession<'f> {
    fabric: &'f Fabric,
    /// The pricing seam: every resource query routes through this.
    model: Arc<dyn CostModel>,
    /// `Some(epoch)` when the model is time-varying.
    epoch: Option<Cycle>,
    policy: AdmitPolicy,
    /// Live occupancy aggregates (inert under an invariant model).
    occ: Occupancy,
    progs: Vec<Prog>,
    res: Vec<ResQueue>,
    /// Sparse link resources per active (src tile, dst tile) pair.
    link_ids: HashMap<(usize, usize), usize>,
    /// Global step id -> (program, local index).
    id_map: Vec<(u32, u32)>,
    cal: StampedCalendar,
    /// Reusable completion-batch scratch.
    batch: Vec<usize>,
    /// Earliest perturbation since the last settle (time-varying only).
    dirty_from: Option<Cycle>,
    /// Admissions/replaces below this are rejected (raised by pruning).
    admit_floor: Cycle,
    /// Recycled global-id ranges from pruned programs: `(base, len)`.
    free_ranges: Vec<(usize, usize)>,
    /// Drop pruned programs' per-step history (see
    /// [`CosimSession::set_discard_pruned`]).
    discard_pruned: bool,
    /// Worker threads for shard-parallel drains (1 = the exact
    /// sequential path; see the module docs' shard-parallel section).
    threads: usize,
    /// Explicit shard partition for property tests
    /// ([`CosimSession::set_shards`]); `None` = equal split.
    shard_override: Option<Vec<usize>>,
    /// Effective shard bounds of the current parallel drain (reused).
    shard_bounds: Vec<usize>,
    /// Reusable per-resource weight scratch for the load-aware fences.
    load_scratch: Vec<u64>,
    /// Persistent workers (shards − 1; shard 0 runs on the caller),
    /// spawned lazily on the first multi-shard drain.
    pool: Option<WorkerPool>,
    /// Reusable staged-fire list of the parallel drain.
    fires: Vec<Fire>,
    /// Reusable per-shard pricing scratch.
    price_scratch: Vec<PriceScratch>,
    /// Start-ordered `(start, global id)` index over *started* steps,
    /// maintained only under a time-varying model: makes horizon-seed
    /// collection and the settle re-price scan O(affected · log n)
    /// instead of O(world) (PR 5 follow-up (h)). Invariant-model
    /// sessions never touch it.
    start_index: BTreeSet<(Cycle, usize)>,
    /// Epoch-boundary-straddle telemetry of the parallel drain.
    straddle: StraddleStats,
}

/// Price one step starting at `start` through the cost model: returns
/// (cost with cycles zeroed, duration). Identical to the single-program
/// engines' cost path.
fn price(
    model: &dyn CostModel,
    fabric: &Fabric,
    step: &Step,
    start: Cycle,
    occ: &Occupancy,
) -> Result<(Metrics, Cycle)> {
    Ok(match step {
        Step::Load { tile, bytes, .. } => {
            let cost = model.feed(fabric, *tile, *bytes, start, occ);
            let cyc = cost.cycles;
            (cost.with_cycles(0), cyc)
        }
        Step::Transfer { from, to, bytes, .. } => {
            let src = fabric.tiles[*from].node;
            let dst = fabric.tiles[*to].node;
            let cost = model.transport(fabric, src, dst, *bytes, start, occ);
            let cyc = cost.cycles;
            (cost.with_cycles(0), cyc)
        }
        Step::Exec { tile, compute, precision, .. } => {
            let cost = model.execute(fabric, *tile, compute, *precision, start, occ)?;
            let cyc = cost.metrics.cycles;
            (cost.metrics.with_cycles(0), cyc)
        }
    })
}

/// Price one shard's staged fires against the batch-start occupancy
/// snapshot (parallel drain, phase 2): advance `free` through the
/// shard's disjoint queue view (`queues` covers resources `r0..`),
/// buffer `(cost, duration)` in fire order for the sequential merge,
/// and stop at the first pricing error (recorded with its fire index so
/// the merge surfaces it at its canonical position). Runs on pool
/// workers — everything it reads is shared-immutable for the phase.
#[allow(clippy::too_many_arguments)]
fn price_shard(
    scr: &mut PriceScratch,
    queues: &mut [ResQueue],
    r0: usize,
    fires: &[Fire],
    model: &dyn CostModel,
    fabric: &Fabric,
    occ: &Occupancy,
    progs: &[Prog],
    id_map: &[(u32, u32)],
) {
    for &fk in &scr.fires {
        let f = fires[fk as usize];
        let (p, i) = id_map[f.id];
        match price(model, fabric, &progs[p as usize].steps[i as usize], f.start, occ) {
            Ok((cost, dur)) => {
                let q = &mut queues[f.res as usize - r0];
                q.free = f.start + dur;
                q.load = q.load.saturating_add(dur);
                scr.out.push((cost, dur));
            }
            Err(e) => {
                scr.err = Some((fk, e));
                return;
            }
        }
    }
}

impl<'f> CosimSession<'f> {
    /// An empty session over `fabric` using the fabric's configured cost
    /// model (resources: one queue per tile, one for the HBM port; link
    /// queues appear as programs use pairs).
    pub fn new(fabric: &'f Fabric) -> Self {
        Self::with_model(fabric, fabric.cost_model().clone())
    }

    /// An empty session pricing through an explicit cost model.
    pub fn with_model(fabric: &'f Fabric, model: Arc<dyn CostModel>) -> Self {
        let nt = fabric.tile_count();
        let epoch = model.time_dependence().epoch();
        let occ = match epoch {
            Some(w) => Occupancy::new(w),
            None => Occupancy::disabled(),
        };
        CosimSession {
            fabric,
            model,
            epoch,
            policy: AdmitPolicy::default(),
            occ,
            progs: Vec::new(),
            res: (0..nt + 1).map(|_| ResQueue::default()).collect(),
            link_ids: HashMap::new(),
            id_map: Vec::new(),
            cal: StampedCalendar::with_horizon(256),
            batch: Vec::new(),
            dirty_from: None,
            admit_floor: 0,
            free_ranges: Vec::new(),
            discard_pruned: false,
            threads: fabric.cfg.session.threads.max(1),
            shard_override: None,
            shard_bounds: Vec::new(),
            load_scratch: Vec::new(),
            pool: None,
            fires: Vec::new(),
            price_scratch: Vec::new(),
            start_index: BTreeSet::new(),
            straddle: StraddleStats::default(),
        }
    }

    /// Epoch-boundary-straddle counters of the parallel drain (see
    /// [`StraddleStats`]); zero while `threads == 1`.
    pub fn straddle_stats(&self) -> StraddleStats {
        self.straddle
    }

    /// Worker threads used by shard-parallel drains (1 = sequential).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Set the drain parallelism. `1` (the default, also configurable as
    /// `[session] threads`) restores the exact sequential hot path; any
    /// value is bit-identical to it (module docs, shard-parallel
    /// section). May be called at any time between drains.
    pub fn set_threads(&mut self, threads: usize) {
        self.threads = threads.max(1);
        if self.threads == 1 {
            self.pool = None;
        }
    }

    /// Override the resource-shard partition used by parallel drains —
    /// the property-test seam for partition invariance. `bounds` are
    /// ascending resource-index fences `[0, b1, .., res_count]`; each
    /// consecutive pair is one shard. New resources appearing after the
    /// call (links materialize on first use) join the last shard. Any
    /// valid partition is bit-identical to any other and to the
    /// sequential engine. Pass `None` to restore the default equal
    /// split over `min(threads, resources)` shards.
    pub fn set_shards(&mut self, bounds: Option<&[usize]>) -> Result<()> {
        match bounds {
            None => self.shard_override = None,
            Some(b) => {
                ensure!(
                    b.len() >= 2 && b[0] == 0,
                    "shard bounds must start at 0 and name at least one shard"
                );
                ensure!(
                    b.windows(2).all(|w| w[0] < w[1]),
                    "shard bounds must be strictly increasing"
                );
                ensure!(
                    *b.last().unwrap() <= self.res.len(),
                    "shard bound {} exceeds resource count {}",
                    b.last().unwrap(),
                    self.res.len()
                );
                self.shard_override = Some(b.to_vec());
            }
        }
        Ok(())
    }

    /// Current resource count (tiles + HBM port + materialized links) —
    /// the domain [`CosimSession::set_shards`] fences partition.
    pub fn resource_count(&self) -> usize {
        self.res.len()
    }

    /// The session's cost model (the per-session pricing seam `serve`
    /// exposes).
    pub fn cost_model(&self) -> &Arc<dyn CostModel> {
        &self.model
    }

    /// The session's queue-key policy.
    pub fn policy(&self) -> AdmitPolicy {
        self.policy
    }

    /// Select the queue-key policy. Must be called before the first
    /// admission — the key is baked into every queue position.
    pub fn set_policy(&mut self, policy: AdmitPolicy) -> Result<()> {
        ensure!(
            self.progs.is_empty(),
            "admission policy must be set before the first admission"
        );
        self.policy = policy;
        Ok(())
    }

    /// Swap the session's cost model **in place**, invalidating and
    /// repricing every admitted step under the new model — the
    /// incremental-DSE primitive (`dse::sweep`): the fabric, the
    /// resource queues, the admitted programs and their dependency
    /// structure all survive, so stepping a sweep's model axis costs one
    /// full reprice instead of rebuild-world (fabric build + mapping +
    /// lowering + re-admission).
    ///
    /// Semantics: afterwards the session is observationally identical —
    /// bit for bit, spans and reports — to a fresh
    /// [`CosimSession::with_model`] over the new model with the same
    /// programs admitted at the same times (pinned by the in-module
    /// equivalence tests and `tests/dse_golden.rs`). This holds across
    /// time-dependence changes in either direction: occupancy aggregates
    /// and the start-ordered index are rebuilt for the new model's
    /// epoch, and the settle fixed point is re-seeded from the earliest
    /// admission.
    ///
    /// Rejected after [`CosimSession::prune_completed_before`]: pruned
    /// programs froze history priced under the old model that can no
    /// longer be repriced.
    pub fn set_model(&mut self, model: Arc<dyn CostModel>) -> Result<()> {
        ensure!(
            self.admit_floor == 0 && self.progs.iter().all(|p| !p.pruned),
            "set_model on a pruned session: frozen history cannot be repriced"
        );
        // Invalidate the whole world under the OLD model/occupancy (the
        // closure retracts calendar events, occupancy spans and
        // start-index entries priced under it).
        let seeds: Vec<usize> =
            self.progs.iter().flat_map(|p| p.base..p.base + p.steps.len()).collect();
        let mut affected = Vec::new();
        if !seeds.is_empty() {
            self.invalidate_closure(seeds, &mut affected);
        }
        // Swap the pricing world: model, epoch, fresh occupancy.
        self.epoch = model.time_dependence().epoch();
        self.occ = match self.epoch {
            Some(w) => Occupancy::new(w),
            None => Occupancy::disabled(),
        };
        self.model = model;
        self.start_index.clear();
        debug_assert!(self.cal.is_empty(), "full invalidation left calendar events");
        // Settle horizon for the new model: everything is dirty from the
        // earliest admission (time-varying models only; the settle loop
        // converges to the unique fixed point from any floor <= it).
        self.dirty_from = if self.epoch.is_some() {
            self.progs.iter().filter(|p| !p.steps.is_empty()).map(|p| p.admit_at).min()
        } else {
            None
        };
        // Restart execution exactly as a fresh install would: re-derive
        // the affected resources' queue state, then wake their heads
        // (priced under the NEW model).
        affected.sort_unstable();
        self.rebuild_resource_state(&affected);
        for r in affected {
            self.wake_head(r)?;
        }
        Ok(())
    }

    /// Number of admitted programs (pruned ones included).
    pub fn programs(&self) -> usize {
        self.progs.len()
    }

    /// True when no completion events are pending (all admitted work has
    /// been simulated to completion or nothing was admitted).
    pub fn is_quiescent(&self) -> bool {
        self.cal.is_empty()
    }

    /// Current admission floor (0 until [`CosimSession::
    /// prune_completed_before`] raises it).
    pub fn admit_floor(&self) -> Cycle {
        self.admit_floor
    }

    /// Footprint probe for the long-run regression tests: (longest
    /// resource queue, global-id table length).
    pub fn queue_footprint(&self) -> (usize, usize) {
        let longest = self.res.iter().map(|r| r.steps.len()).max().unwrap_or(0);
        (longest, self.id_map.len())
    }

    /// Opt in to dropping pruned programs' per-step history (`steps`,
    /// `rec`, the CSR successor arrays) at
    /// [`CosimSession::prune_completed_before`] time, bounding long-run
    /// serving memory: with discarding on, retained history is
    /// proportional to the live window rather than to every request ever
    /// served. The span cache survives, so [`CosimSession::span`] stays
    /// exact for discarded programs; [`CosimSession::report`], whose
    /// merged energy fold needs every per-step record, errors once any
    /// program has been discarded.
    pub fn set_discard_pruned(&mut self, on: bool) {
        self.discard_pruned = on;
    }

    /// Retained per-step history across all programs (steps + records) —
    /// the footprint probe for the discard-pruned regression test.
    pub fn history_footprint(&self) -> usize {
        self.progs.iter().map(|p| p.steps.len() + p.rec.len()).sum()
    }

    /// Admit `prog` into the live calendar at simulated cycle `at` with
    /// default metadata. Steps become runnable no earlier than `at`;
    /// resource order follows the session's [`AdmitPolicy`] key. `at`
    /// may lie in the already-simulated past — affected steps of other
    /// programs are invalidated and re-simulated (see module docs).
    pub fn admit_at(&mut self, prog: &FabricProgram, at: Cycle) -> Result<ProgramHandle> {
        self.admit_with(prog, at, AdmitMeta::default())
    }

    /// Admit with explicit priority/deadline metadata.
    pub fn admit_with(
        &mut self,
        prog: &FabricProgram,
        at: Cycle,
        meta: AdmitMeta,
    ) -> Result<ProgramHandle> {
        let slot = self.progs.len();
        self.install(slot, prog, at, meta)?;
        Ok(ProgramHandle(slot))
    }

    /// Replace program `h` (content and admission time) in place — the
    /// "program or cost model changed" primitive. Keeps the program's
    /// admission metadata; only the invalidation closure of the change
    /// is re-simulated.
    pub fn replace(&mut self, h: ProgramHandle, prog: &FabricProgram, at: Cycle) -> Result<()> {
        ensure!(h.0 < self.progs.len(), "stale program handle {}", h.0);
        let meta = self.progs[h.0].meta;
        self.replace_with(h, prog, at, meta)
    }

    /// Replace program `h` with new content, admission time and metadata.
    pub fn replace_with(
        &mut self,
        h: ProgramHandle,
        prog: &FabricProgram,
        at: Cycle,
        meta: AdmitMeta,
    ) -> Result<()> {
        ensure!(h.0 < self.progs.len(), "stale program handle {}", h.0);
        self.install(h.0, prog, at, meta)
    }

    /// Force re-pricing and re-simulation of program `h` (and its
    /// invalidation closure) without changing its content — for callers
    /// whose external cost context changed. Equivalent to `replace` with
    /// the same program.
    pub fn invalidate(&mut self, h: ProgramHandle) -> Result<()> {
        ensure!(h.0 < self.progs.len(), "stale program handle {}", h.0);
        let prog = FabricProgram {
            steps: self.progs[h.0].steps.clone(),
            producer: Vec::new(),
        };
        let at = self.progs[h.0].admit_at;
        let meta = self.progs[h.0].meta;
        self.install(h.0, &prog, at, meta)
    }

    /// Drain every pending completion event and, under a time-varying
    /// model, run the settle loop to the occupancy fixed point; errors if
    /// steps remain unfinished (impossible for forward-dep programs — the
    /// queue order is a consistent total order, see module docs).
    pub fn run_to_drain(&mut self) -> Result<()> {
        self.drain(None)?;
        let incomplete: usize = self.progs.iter().map(|p| p.remaining).sum();
        ensure!(incomplete == 0, "admission co-sim stalled: {incomplete} steps incomplete");
        if self.epoch.is_some() {
            self.settle()?;
        }
        Ok(())
    }

    /// Drain completion events up to and including simulated cycle `t`,
    /// leaving later work in flight — programs admitted afterwards land
    /// in a genuinely running calendar (their displaced steps' pending
    /// completions are retracted via generation stamps). Under a
    /// time-varying model, mid-flight prices are provisional until the
    /// next full drain settles the fixed point.
    pub fn run_until(&mut self, t: Cycle) -> Result<()> {
        self.drain(Some(t))
    }

    /// Drain to quiescence (settling time-varying prices) and fold the
    /// merged report: identical field semantics to
    /// [`super::exec::cosim`], with one [`ProgramSpan`] per admitted
    /// program. Step-ordered data (`step_done`, the energy fold) runs in
    /// `(admission sequence, step index)` order, so a single program
    /// admitted at t=0 reproduces `cosim` bit for bit, and N programs at
    /// t=0 reproduce `cosim` of the concatenated program.
    pub fn report(&mut self) -> Result<ExecReport> {
        self.run_to_drain()?;
        ensure!(
            self.progs.iter().all(|p| !p.discarded),
            "report() needs per-step history, but pruned programs were \
             discarded (set_discard_pruned); use span() per program instead"
        );
        let nt = self.fabric.tile_count();
        let mut total = Metrics::new();
        let mut tile_busy = vec![0 as Cycle; nt];
        let mut step_done = Vec::new();
        let mut transfer_cycles: Cycle = 0;
        let mut exec_steps = 0usize;
        let mut makespan: Cycle = 0;
        let mut programs = Vec::with_capacity(self.progs.len());
        for pr in &self.progs {
            let span = Self::fold_program(
                pr,
                &mut total,
                Some(tile_busy.as_mut_slice()),
                Some(&mut step_done),
            );
            debug_assert!(
                pr.span_cache.as_ref().is_none_or(|c| c.bit_identical(&span)),
                "span cache diverged from the fold"
            );
            exec_steps += span.exec_steps;
            transfer_cycles += span.transfer_cycles;
            makespan = makespan.max(pr.rec.iter().map(|r| r.finish).max().unwrap_or(0));
            programs.push(span);
        }
        total.cycles = makespan;
        // Fabric-level leakage over the merged episode (same charge as
        // the single-program engines).
        total.add_energy(
            Category::Leakage,
            makespan as f64 * self.fabric.tile_count() as f64 * 0.5,
        );
        Ok(ExecReport {
            cycles: makespan,
            metrics: total,
            tile_busy,
            step_done,
            transfer_cycles,
            exec_steps,
            programs,
        })
    }

    /// Per-program span of `h` — O(1): served from the cache maintained
    /// at program completion (dropped and rebuilt across invalidations),
    /// so the serving path reads each request's simulated latency without
    /// folding anything. Meaningful only once the program has fully
    /// completed (call after [`CosimSession::run_to_drain`]): an
    /// in-flight program's unfinished steps would contribute zeroed
    /// placeholders to the fallback fold.
    pub fn span(&self, h: ProgramHandle) -> ProgramSpan {
        if let Some(s) = &self.progs[h.0].span_cache {
            return s.clone();
        }
        debug_assert!(
            self.progs[h.0].rec.iter().all(|r| r.completed),
            "span({}) read while the program is still in flight",
            h.0
        );
        Self::fold_program(&self.progs[h.0], &mut Metrics::new(), None, None)
    }

    /// Fold one program's steps in step order into the merged
    /// accumulators and return its span. The per-program energy is folded
    /// independently in the same order, so it equals a solo run's
    /// pre-leakage energy bit for bit.
    fn fold_program(
        pr: &Prog,
        total: &mut Metrics,
        mut tile_busy: Option<&mut [Cycle]>,
        step_done: Option<&mut Vec<Cycle>>,
    ) -> ProgramSpan {
        let mut penergy = Metrics::new();
        let mut p_exec = 0usize;
        let mut p_transfer: Cycle = 0;
        let mut finished = pr.admit_at;
        let mut done = step_done;
        for (step, rec) in pr.steps.iter().zip(&pr.rec) {
            total.absorb_parallel(&rec.cost);
            penergy.absorb_parallel(&rec.cost);
            if let Some(sd) = done.as_deref_mut() {
                sd.push(rec.finish);
            }
            finished = finished.max(rec.finish);
            if let Step::Exec { tile, .. } = step {
                if let Some(tb) = tile_busy.as_deref_mut() {
                    tb[*tile] += rec.dur;
                }
                p_exec += 1;
            } else {
                p_transfer += rec.dur;
            }
        }
        ProgramSpan {
            admitted_at: pr.admit_at,
            finished_at: finished,
            steps: pr.rec.len(),
            exec_steps: p_exec,
            transfer_cycles: p_transfer,
            ops: penergy.ops,
            bytes_moved: penergy.bytes_moved,
            energy_pj: penergy.total_energy_pj(),
        }
    }

    /// Span of `pr` alone (cache fill path).
    fn compute_span(pr: &Prog) -> ProgramSpan {
        Self::fold_program(pr, &mut Metrics::new(), None, None)
    }

    /// Install `prog` into `slot` (fresh admission when `slot` is one
    /// past the end, replacement otherwise): validate, splice the steps
    /// into the resource queues at their policy-key position, invalidate
    /// the structural + (time-varying) horizon closure, and re-seed the
    /// wake chain.
    fn install(&mut self, slot: usize, prog: &FabricProgram, at: Cycle, meta: AdmitMeta) -> Result<()> {
        let nt = self.fabric.tile_count();
        ensure!(
            at >= self.admit_floor,
            "admission at cycle {at} lies below the pruned horizon {}",
            self.admit_floor
        );
        let key = prog_key(self.policy, at, meta, slot);
        ensure!(
            key[0] >= self.admit_floor,
            "queue key {} (policy {:?}) lies below the pruned horizon {}",
            key[0],
            self.policy,
            self.admit_floor
        );
        if slot < self.progs.len() {
            ensure!(!self.progs[slot].pruned, "program {slot} was pruned; its history is frozen");
            ensure!(
                self.progs[slot].admit_at >= self.admit_floor
                    && self.progs[slot].key[0] >= self.admit_floor,
                "replacing program {slot} would perturb history below the pruned horizon {}",
                self.admit_floor
            );
        }
        for (i, s) in prog.steps.iter().enumerate() {
            for &d in s.deps() {
                ensure!(d < i, "step {i} depends on non-earlier step {d} (forward deps required)");
            }
            match s {
                Step::Load { tile, .. } | Step::Exec { tile, .. } => {
                    ensure!(*tile < nt, "step {i}: tile {tile} out of range")
                }
                Step::Transfer { from, to, .. } => ensure!(
                    *from < nt && *to < nt,
                    "step {i}: transfer {from}->{to} out of range"
                ),
            }
        }

        // Perturbation time: the earliest simulated instant whose
        // occupancy/schedule this install can change.
        let mut t_pert = at;
        let mut seeds: Vec<usize> = Vec::new();
        let mut touched: Vec<usize> = Vec::new();
        if slot < self.progs.len() {
            t_pert = t_pert.min(self.progs[slot].admit_at);
            let removed_min = self.remove_program_steps(slot, &mut seeds, &mut touched);
            t_pert = t_pert.min(removed_min);
        }

        // Build the program's static structures. A replacement reuses
        // the outgoing program's global-id range when it fits (its
        // in-flight events were cancelled above and consumed ids hold
        // no queued events, so generation stamps keep any stale entry
        // dead) — the replace/invalidate re-pricing loop then runs with
        // bounded id/generation state. Otherwise a range recycled from a
        // pruned program is reused (first fit) before growing the table.
        let n = prog.steps.len();
        let fits_outgoing = slot < self.progs.len() && n <= self.progs[slot].rec.len();
        let free_slot = if fits_outgoing || n == 0 {
            None
        } else {
            self.free_ranges.iter().position(|&(_, len)| len >= n)
        };
        let base = if fits_outgoing {
            self.progs[slot].base
        } else if let Some(pos) = free_slot {
            let (b, flen) = self.free_ranges[pos];
            if flen == n {
                self.free_ranges.swap_remove(pos);
            } else {
                self.free_ranges[pos] = (b + n, flen - n);
            }
            for (idx, entry) in self.id_map[b..b + n].iter_mut().enumerate() {
                *entry = (slot as u32, idx as u32);
            }
            b
        } else {
            let b = self.id_map.len();
            for idx in 0..n {
                self.id_map.push((slot as u32, idx as u32));
            }
            b
        };
        let mut res_of = Vec::with_capacity(n);
        for s in &prog.steps {
            let r = match s {
                Step::Load { .. } => nt,
                Step::Exec { tile, .. } => *tile,
                Step::Transfer { from, to, .. } => {
                    let next = self.res.len();
                    let id = *self.link_ids.entry((*from, *to)).or_insert(next);
                    if id == next {
                        self.res.push(ResQueue::default());
                    }
                    id
                }
            };
            res_of.push(r);
        }
        let mut succ_off = vec![0usize; n + 1];
        for s in &prog.steps {
            for &d in s.deps() {
                succ_off[d + 1] += 1;
            }
        }
        for i in 0..n {
            succ_off[i + 1] += succ_off[i];
        }
        let mut succ = vec![0u32; succ_off[n]];
        let mut cursor: Vec<usize> = succ_off[..n].to_vec();
        for (i, s) in prog.steps.iter().enumerate() {
            for &d in s.deps() {
                succ[cursor[d]] = i as u32;
                cursor[d] += 1;
            }
        }
        let rec: Vec<StepRec> = prog
            .steps
            .iter()
            .enumerate()
            .map(|(i, s)| StepRec {
                res: res_of[i] as u32,
                qpos: 0,
                started: false,
                completed: false,
                start: 0,
                finish: 0,
                dur: 0,
                pending: s.deps().len() as u32,
                ready_at: at,
                cost: Metrics::new(),
            })
            .collect();
        let mut built = Prog {
            admit_at: at,
            meta,
            key,
            steps: prog.steps.clone(),
            rec,
            base,
            succ_off,
            succ,
            remaining: n,
            span_cache: None,
            pruned: false,
            discarded: false,
        };
        if n == 0 {
            built.span_cache = Some(Self::compute_span(&built));
        }
        if slot == self.progs.len() {
            self.progs.push(built);
        } else {
            self.progs[slot] = built;
        }

        // Splice the new steps into their queues at the policy-key
        // position, seeding every displaced (later-keyed) entry.
        let mut by_res: Vec<(usize, Vec<usize>)> = Vec::new();
        for (idx, &r) in res_of.iter().enumerate() {
            if let Some(pos) = by_res.iter().position(|&(rr, _)| rr == r) {
                by_res[pos].1.push(base + idx);
            } else {
                by_res.push((r, vec![base + idx]));
            }
        }
        for (r, ids) in by_res {
            let pos = self.res[r].steps.partition_point(|&id2| {
                let (p2, _) = self.id_map[id2];
                self.progs[p2 as usize].key < key
            });
            seeds.extend_from_slice(&self.res[r].steps[pos..]);
            self.res[r].steps.splice(pos..pos, ids);
            if !touched.contains(&r) {
                touched.push(r);
            }
        }
        for &r in &touched {
            self.renumber_queue(r);
        }

        // Affected set = structurally changed queues + every resource
        // owning a closure-invalidated step. Resources outside it kept
        // their exact state, so rebuilding and waking only these makes
        // an admission O(affected queues + own steps), not O(world):
        // between operations no resource ever has an idle dep-ready
        // unstarted head (wakes are always exhausted), so an untouched
        // resource cannot need a wake.
        //
        // Time-varying models widen the closure to the horizon: every
        // started step with start >= the perturbation time is seeded,
        // and if the closure reaches a started step scheduled even
        // earlier (possible under non-FIFO keys), the horizon is lowered
        // and re-applied until stable.
        let mut affected = touched;
        let mut hor = t_pert;
        if self.epoch.is_some() {
            self.collect_horizon_seeds(hor, slot, &mut seeds);
        }
        let mut low = self.invalidate_closure(seeds, &mut affected).min(t_pert);
        if self.epoch.is_some() {
            while low < hor {
                hor = low;
                let mut extra = Vec::new();
                self.collect_horizon_seeds(hor, usize::MAX, &mut extra);
                if extra.is_empty() {
                    break;
                }
                low = low.min(self.invalidate_closure(extra, &mut affected));
            }
            self.dirty_from = Some(self.dirty_from.map_or(low, |d| d.min(low)));
        }
        ensure!(
            low >= self.admit_floor,
            "invalidation reached simulated cycle {low}, below the pruned horizon {} \
             (prune less history or admit later)",
            self.admit_floor
        );
        affected.sort_unstable();
        self.rebuild_resource_state(&affected);
        for &r in &affected {
            self.wake_head(r)?;
        }
        Ok(())
    }

    /// Retire program `slot`'s current steps: cancel in-flight completion
    /// events, retract registered occupancy spans, and excise the ids
    /// from their queues, seeding every entry positioned at or after the
    /// first removal in each queue. Returns the minimum start cycle of
    /// any removed *started* step (`Cycle::MAX` if none) — the occupancy
    /// perturbation floor of the removal.
    fn remove_program_steps(
        &mut self,
        slot: usize,
        seeds: &mut Vec<usize>,
        touched: &mut Vec<usize>,
    ) -> Cycle {
        let base = self.progs[slot].base;
        let mut min_start = Cycle::MAX;
        for idx in 0..self.progs[slot].rec.len() {
            let (started, completed, start, finish, r) = {
                let rec = &self.progs[slot].rec[idx];
                (rec.started, rec.completed, rec.start, rec.finish, rec.res as usize)
            };
            if started {
                min_start = min_start.min(start);
                if !completed {
                    self.cal.cancel(base + idx);
                }
                if self.occ.is_tracking() {
                    self.occ.remove_step(&self.progs[slot].steps[idx], start, finish);
                }
                if self.epoch.is_some() {
                    self.start_index.remove(&(start, base + idx));
                }
            }
            if !touched.contains(&r) {
                touched.push(r);
            }
        }
        for &r in touched.iter() {
            let old = std::mem::take(&mut self.res[r].steps);
            let mut kept = Vec::with_capacity(old.len());
            let mut min_removed = usize::MAX;
            for id in old {
                if self.id_map[id].0 as usize == slot {
                    min_removed = min_removed.min(kept.len());
                } else {
                    kept.push(id);
                }
            }
            if min_removed != usize::MAX {
                seeds.extend_from_slice(&kept[min_removed..]);
            }
            self.res[r].steps = kept;
        }
        min_start
    }

    fn renumber_queue(&mut self, r: usize) {
        for k in 0..self.res[r].steps.len() {
            let (p, i) = self.id_map[self.res[r].steps[k]];
            self.progs[p as usize].rec[i as usize].qpos = k as u32;
        }
    }

    /// True when every step of `pr` is known to lie strictly before
    /// `from` — a fully-completed program whose cached span finished
    /// earlier (starts <= finishes < from). Lets the oracle seed scan
    /// skip drained history instead of walking O(world) steps; the live
    /// paths now serve the same question from `start_index`.
    #[cfg(test)]
    fn finished_before(pr: &Prog, from: Cycle) -> bool {
        pr.span_cache.as_ref().is_some_and(|c| c.finished_at < from)
    }

    /// Push every started, unpruned step with start >= `from` (skipping
    /// program `skip`) — the horizon seed set of a time-varying
    /// perturbation at `from`. Served from the start-ordered index in
    /// O(affected · log n) instead of scanning the world (PR 5
    /// follow-up (h)); the closure a seed set produces is independent
    /// of seed order, so swapping the program-major scan for start
    /// order changes no bit (`prop_horizon_seed_index_matches_scan`
    /// pins the sets equal).
    fn collect_horizon_seeds(&self, from: Cycle, skip: usize, out: &mut Vec<usize>) {
        debug_assert!(self.epoch.is_some(), "horizon seeds exist only under time-varying models");
        for &(_, id) in self.start_index.range((from, 0)..) {
            if self.id_map[id].0 as usize == skip {
                continue;
            }
            out.push(id);
        }
    }

    /// The pre-index O(world) seed scan, kept as the differential
    /// oracle for the index (see the property tests).
    #[cfg(test)]
    fn collect_horizon_seeds_scan(&self, from: Cycle, skip: usize, out: &mut Vec<usize>) {
        for (pi, pr) in self.progs.iter().enumerate() {
            if pi == skip || pr.pruned || Self::finished_before(pr, from) {
                continue;
            }
            for (i, rec) in pr.rec.iter().enumerate() {
                if rec.started && rec.start >= from {
                    out.push(pr.base + i);
                }
            }
        }
    }

    /// Propagate the invalidation closure from `seeds`: reset each
    /// reached step (retracting its pending completion event and its
    /// occupancy spans), follow dependency successors, and extend along
    /// resource-queue suffixes. Afterwards recompute pending counts and
    /// ready times from the surviving completed frontier. Every resource
    /// owning an invalidated step is appended to `affected` (so the
    /// caller can rebuild/wake only those instead of the world). Returns
    /// the minimum start cycle over the *started* steps it reset
    /// (`Cycle::MAX` if none) — the caller's horizon floor.
    fn invalidate_closure(&mut self, seeds: Vec<usize>, affected: &mut Vec<usize>) -> Cycle {
        let mut min_start = Cycle::MAX;
        let mut work = seeds;
        let mut visited: HashSet<usize> = HashSet::new();
        let mut order: Vec<usize> = Vec::new();
        // Lowest invalidated queue position seen per resource: suffix
        // entries beyond it are already in the closure.
        let mut min_pos: HashMap<usize, usize> = HashMap::new();
        while let Some(id) = work.pop() {
            if !visited.insert(id) {
                continue;
            }
            order.push(id);
            let (p, i) = self.id_map[id];
            let (p, i) = (p as usize, i as usize);
            let (started, completed, start, finish, r, qpos) = {
                let rec = &self.progs[p].rec[i];
                (
                    rec.started,
                    rec.completed,
                    rec.start,
                    rec.finish,
                    rec.res as usize,
                    rec.qpos as usize,
                )
            };
            if started {
                min_start = min_start.min(start);
                if !completed {
                    self.cal.cancel(id);
                }
                if self.occ.is_tracking() {
                    self.occ.remove_step(&self.progs[p].steps[i], start, finish);
                }
                if self.epoch.is_some() {
                    self.start_index.remove(&(start, id));
                }
            }
            if completed {
                self.progs[p].remaining += 1;
                self.progs[p].span_cache = None;
            }
            debug_assert!(
                self.progs[p].remaining == 0 || self.progs[p].span_cache.is_none(),
                "span cache must not outlive an invalidation"
            );
            {
                let rec = &mut self.progs[p].rec[i];
                rec.started = false;
                rec.completed = false;
            }
            if !affected.contains(&r) {
                affected.push(r);
            }
            for s in self.progs[p].succ_off[i]..self.progs[p].succ_off[i + 1] {
                let j = self.progs[p].succ[s] as usize;
                work.push(self.progs[p].base + j);
            }
            let cur = min_pos.entry(r).or_insert(usize::MAX);
            if qpos < *cur {
                let hi = (*cur).min(self.res[r].steps.len());
                work.extend_from_slice(&self.res[r].steps[qpos + 1..hi]);
                *cur = qpos;
            }
        }
        for &id in &order {
            let (p, i) = self.id_map[id];
            let (p, i) = (p as usize, i as usize);
            let (pending, ready) = {
                let pr = &self.progs[p];
                let mut pending = 0u32;
                let mut ready = pr.admit_at;
                for &d in pr.steps[i].deps() {
                    let dr = &pr.rec[d];
                    if dr.completed {
                        ready = ready.max(dr.finish);
                    } else {
                        pending += 1;
                    }
                }
                (pending, ready)
            };
            let rec = &mut self.progs[p].rec[i];
            rec.pending = pending;
            rec.ready_at = ready;
        }
        min_start
    }

    /// Re-derive the given resources' cursor / free / busy from their
    /// queues' started prefixes (started steps always form a prefix:
    /// starts are strictly in queue order and invalidation only clears
    /// suffixes). Resources outside an install's affected set are
    /// untouched by it, so their cached state stays valid.
    fn rebuild_resource_state(&mut self, resources: &[usize]) {
        let (progs, id_map) = (&self.progs, &self.id_map);
        let rec_of = |id: usize| {
            let (p, i) = id_map[id];
            &progs[p as usize].rec[i as usize]
        };
        for &r in resources {
            let rq = &self.res[r];
            let mut cursor = 0usize;
            while cursor < rq.steps.len() && rec_of(rq.steps[cursor]).started {
                cursor += 1;
            }
            let (free, busy) = if cursor == 0 {
                (0, false)
            } else {
                let rec = rec_of(rq.steps[cursor - 1]);
                (rec.finish, !rec.completed)
            };
            let rq = &mut self.res[r];
            rq.cursor = cursor;
            rq.free = free;
            rq.busy = busy;
        }
    }

    /// If resource `r` is idle and its next queued step is
    /// dependency-ready, start the step: price it at `max(ready, free)`
    /// through the cost model, register its occupancy span, and push its
    /// completion event.
    fn wake_head(&mut self, r: usize) -> Result<()> {
        let rq = &self.res[r];
        if rq.busy || rq.cursor >= rq.steps.len() {
            return Ok(());
        }
        let id = rq.steps[rq.cursor];
        let (p, i) = self.id_map[id];
        let (p, i) = (p as usize, i as usize);
        if self.progs[p].rec[i].pending != 0 {
            return Ok(());
        }
        let start = self.progs[p].rec[i].ready_at.max(self.res[r].free);
        let (cost, dur) =
            price(self.model.as_ref(), self.fabric, &self.progs[p].steps[i], start, &self.occ)?;
        {
            let rec = &mut self.progs[p].rec[i];
            rec.started = true;
            rec.start = start;
            rec.finish = start + dur;
            rec.dur = dur;
            rec.cost = cost;
        }
        if self.occ.is_tracking() {
            self.occ.add_step(&self.progs[p].steps[i], start, start + dur);
        }
        if self.epoch.is_some() {
            self.start_index.insert((start, id));
        }
        let rq = &mut self.res[r];
        rq.free = start + dur;
        rq.busy = true;
        rq.cursor += 1;
        rq.load = rq.load.saturating_add(dur);
        self.cal.push(start + dur, id);
        Ok(())
    }

    /// Drain completion batches in time order (bounded by `until`):
    /// dispatch to the sequential path (threads = 1, the exact
    /// pre-parallel loop) or the shard-parallel path (module docs,
    /// shard-parallel section). An explicit [`CosimSession::set_shards`]
    /// partition forces the parallel structure even at one shard, so
    /// property tests cover the staged path itself.
    fn drain(&mut self, until: Option<Cycle>) -> Result<()> {
        if self.threads <= 1 && self.shard_override.is_none() {
            self.drain_seq(until)
        } else {
            self.drain_parallel(until)
        }
    }

    /// The sequential drain loop (threads = 1): wake and price inline,
    /// in canonical batch order.
    fn drain_seq(&mut self, until: Option<Cycle>) -> Result<()> {
        let mut batch = std::mem::take(&mut self.batch);
        while let Some(t) = self.cal.take_due_until(until, &mut batch) {
            for &id in &batch {
                let (p, i) = self.id_map[id];
                let (p, i) = (p as usize, i as usize);
                let (r, finished_prog) = {
                    let pr = &mut self.progs[p];
                    let rec = &mut pr.rec[i];
                    debug_assert!(rec.started && !rec.completed && rec.finish == t);
                    rec.completed = true;
                    let r = rec.res as usize;
                    pr.remaining -= 1;
                    (r, pr.remaining == 0)
                };
                if finished_prog {
                    let span = Self::compute_span(&self.progs[p]);
                    self.progs[p].span_cache = Some(span);
                }
                self.res[r].busy = false;
                self.wake_head(r)?;
                let (s0, s1) = {
                    let pr = &self.progs[p];
                    (pr.succ_off[i], pr.succ_off[i + 1])
                };
                for s in s0..s1 {
                    let j = self.progs[p].succ[s] as usize;
                    let wake = {
                        let rec = &mut self.progs[p].rec[j];
                        rec.pending -= 1;
                        rec.ready_at = rec.ready_at.max(t);
                        if rec.pending == 0 { Some(rec.res as usize) } else { None }
                    };
                    if let Some(rr) = wake {
                        self.wake_head(rr)?;
                    }
                }
            }
        }
        self.batch = batch;
        Ok(())
    }

    /// Effective shard fences for this drain: the explicit override
    /// (its last fence raised to cover link resources that materialized
    /// after [`CosimSession::set_shards`]), or a *load-aware* split of
    /// the resource range over `min(threads, resources)` shards (ROADMAP
    /// follow-up (l)): fences cut by the historical pricing mass each
    /// resource accumulated ([`ResQueue::load`], via
    /// [`crate::sim::pool::load_fences`]), so a hot HBM or link queue no
    /// longer serializes a shard. A cold session (all-zero history)
    /// reproduces the old uniform count split exactly, and fence
    /// placement never affects results — every valid partition is
    /// bit-identical by the shard contract (pinned by the
    /// partition-invariance property tests).
    fn refresh_shard_bounds(&mut self) {
        self.shard_bounds.clear();
        if let Some(b) = &self.shard_override {
            self.shard_bounds.extend_from_slice(b);
            *self.shard_bounds.last_mut().unwrap() = self.res.len();
        } else {
            let n = self.res.len();
            let shards = self.threads.min(n).max(1);
            if shards <= 1 {
                self.shard_bounds.extend([0, n]);
            } else {
                self.load_scratch.clear();
                self.load_scratch.extend(self.res.iter().map(|r| r.load));
                self.shard_bounds
                    .extend(crate::sim::pool::load_fences(&self.load_scratch, shards));
            }
        }
        debug_assert!(self.shard_bounds.windows(2).all(|w| w[0] < w[1]));
    }

    /// Test probe: the fences the next parallel drain would use.
    #[cfg(test)]
    fn current_fences_for_test(&mut self) -> Vec<usize> {
        self.refresh_shard_bounds();
        self.shard_bounds.clone()
    }

    /// Test probe: per-resource accumulated pricing mass.
    #[cfg(test)]
    fn resource_loads_for_test(&self) -> Vec<u64> {
        self.res.iter().map(|r| r.load).collect()
    }

    /// Bookkeeping-phase twin of [`CosimSession::wake_head`]: evaluate
    /// the fire condition at the same point in the canonical order and
    /// claim the resource, but defer pricing (and everything derived
    /// from the duration — `finish`, `free`, occupancy, the completion
    /// push) to the shard phase + merge. The staged `start` is final:
    /// a firing head has `pending == 0`, so every dependency already
    /// contributed to `ready_at`, and the resource fires at most once
    /// per batch (`busy` blocks re-entry until the merge).
    fn stage_wake(&mut self, r: usize, fires: &mut Vec<Fire>) {
        let rq = &self.res[r];
        if rq.busy || rq.cursor >= rq.steps.len() {
            return;
        }
        let id = rq.steps[rq.cursor];
        let (p, i) = self.id_map[id];
        let (p, i) = (p as usize, i as usize);
        if self.progs[p].rec[i].pending != 0 {
            return;
        }
        let start = self.progs[p].rec[i].ready_at.max(rq.free);
        {
            let rec = &mut self.progs[p].rec[i];
            rec.started = true;
            rec.start = start;
        }
        let rq = &mut self.res[r];
        rq.busy = true;
        rq.cursor += 1;
        fires.push(Fire { id, res: r as u32, start });
    }

    /// The shard-parallel drain (module docs, shard-parallel section):
    /// per batch, sequential bookkeeping stages fires in canonical
    /// order, shards price them in parallel against the batch-start
    /// occupancy snapshot through disjoint `&mut` queue views, and a
    /// sequential merge commits in staging order — bit-identical to
    /// [`CosimSession::drain_seq`] at every thread count and partition.
    fn drain_parallel(&mut self, until: Option<Cycle>) -> Result<()> {
        self.refresh_shard_bounds();
        let nshards = self.shard_bounds.len() - 1;
        if self.price_scratch.len() < nshards {
            self.price_scratch.resize_with(nshards, PriceScratch::default);
        }
        if nshards > 1
            && self.pool.as_ref().map_or(true, |p| p.workers() < nshards - 1)
        {
            self.pool = Some(WorkerPool::new(nshards - 1));
        }
        let mut batch = std::mem::take(&mut self.batch);
        let mut fires = std::mem::take(&mut self.fires);
        let mut result = Ok(());
        'batches: while let Some(t) = self.cal.take_due_until(until, &mut batch) {
            // Phase 1 — sequential bookkeeping in canonical order.
            fires.clear();
            for &id in &batch {
                let (p, i) = self.id_map[id];
                let (p, i) = (p as usize, i as usize);
                let (r, finished_prog) = {
                    let pr = &mut self.progs[p];
                    let rec = &mut pr.rec[i];
                    debug_assert!(rec.started && !rec.completed && rec.finish == t);
                    rec.completed = true;
                    let r = rec.res as usize;
                    pr.remaining -= 1;
                    (r, pr.remaining == 0)
                };
                if finished_prog {
                    let span = Self::compute_span(&self.progs[p]);
                    self.progs[p].span_cache = Some(span);
                }
                self.res[r].busy = false;
                self.stage_wake(r, &mut fires);
                let (s0, s1) = {
                    let pr = &self.progs[p];
                    (pr.succ_off[i], pr.succ_off[i + 1])
                };
                for s in s0..s1 {
                    let j = self.progs[p].succ[s] as usize;
                    let wake = {
                        let rec = &mut self.progs[p].rec[j];
                        rec.pending -= 1;
                        rec.ready_at = rec.ready_at.max(t);
                        if rec.pending == 0 { Some(rec.res as usize) } else { None }
                    };
                    if let Some(rr) = wake {
                        self.stage_wake(rr, &mut fires);
                    }
                }
            }
            if fires.is_empty() {
                continue;
            }
            self.straddle.batches += 1;
            let mut repriced_here = 0u64;

            // Phase 2 — shard-parallel pricing against the batch-start
            // occupancy snapshot.
            for scr in &mut self.price_scratch[..nshards] {
                scr.fires.clear();
                scr.out.clear();
                scr.taken = 0;
                scr.err = None;
            }
            for (k, f) in fires.iter().enumerate() {
                let s = self.shard_bounds.partition_point(|&b| b <= f.res as usize) - 1;
                self.price_scratch[s].fires.push(k as u32);
            }
            {
                let CosimSession {
                    fabric,
                    model,
                    occ,
                    progs,
                    res,
                    id_map,
                    pool,
                    price_scratch,
                    shard_bounds,
                    ..
                } = self;
                let fabric: &Fabric = *fabric;
                let model: &dyn CostModel = model.as_ref();
                let occ: &Occupancy = occ;
                let progs: &[Prog] = progs;
                let id_map: &[(u32, u32)] = id_map;
                let fires_ro: &[Fire] = &fires;
                if nshards == 1 {
                    price_shard(&mut price_scratch[0], res, 0, fires_ro, model, fabric, occ, progs, id_map);
                } else {
                    let mut res_rest: &mut [ResQueue] = res;
                    let mut scr_rest: &mut [PriceScratch] = &mut price_scratch[..nshards];
                    let pool = pool.as_mut().expect("multi-shard drains own a worker pool");
                    pool.scoped(|scope| {
                        let mut own: Option<(&mut PriceScratch, &mut [ResQueue], usize)> = None;
                        for s in 0..nshards {
                            let width = shard_bounds[s + 1] - shard_bounds[s];
                            let (rs, rest) = std::mem::take(&mut res_rest).split_at_mut(width);
                            res_rest = rest;
                            let (scr, rest) =
                                std::mem::take(&mut scr_rest).split_first_mut().expect("scratch per shard");
                            scr_rest = rest;
                            if s == 0 {
                                // Shard 0 runs on this thread below, so
                                // N shards cost N−1 handoffs.
                                own = Some((scr, rs, shard_bounds[s]));
                            } else {
                                let r0 = shard_bounds[s];
                                scope.execute(move || {
                                    price_shard(scr, rs, r0, fires_ro, model, fabric, occ, progs, id_map);
                                });
                            }
                        }
                        let (scr, rs, r0) = own.expect("at least one shard");
                        price_shard(scr, rs, r0, fires_ro, model, fabric, occ, progs, id_map);
                    });
                }
            }

            // Phase 3 — sequential merge in staging (= canonical) order.
            let mut err_at = u32::MAX;
            for scr in &self.price_scratch[..nshards] {
                if let Some((fk, _)) = &scr.err {
                    err_at = err_at.min(*fk);
                }
            }
            // Minimum committed start epoch: a later fire whose start
            // epoch is strictly greater may read a committed fire's
            // occupancy, so it re-prices against the live state (which
            // right then replays the sequential loop's exactly).
            let mut min_epoch: Option<Cycle> = None;
            for (k, f) in fires.iter().enumerate() {
                if k as u32 == err_at {
                    let scr = self
                        .price_scratch
                        .iter_mut()
                        .find(|s| s.err.as_ref().is_some_and(|(fk, _)| *fk == err_at))
                        .expect("recorded error");
                    result = Err(scr.err.take().expect("recorded error").1);
                    break 'batches;
                }
                let sh = self.shard_bounds.partition_point(|&b| b <= f.res as usize) - 1;
                let scr = &mut self.price_scratch[sh];
                let (mut cost, mut dur) = scr.out[scr.taken].clone();
                scr.taken += 1;
                let (p, i) = self.id_map[f.id];
                let (p, i) = (p as usize, i as usize);
                if let Some(w) = self.epoch {
                    let e = f.start / w;
                    if min_epoch.is_some_and(|m| m < e) {
                        match price(self.model.as_ref(), self.fabric, &self.progs[p].steps[i], f.start, &self.occ) {
                            Ok((c2, d2)) => {
                                cost = c2;
                                dur = d2;
                                self.res[f.res as usize].free = f.start + dur;
                                repriced_here += 1;
                            }
                            Err(e) => {
                                result = Err(e);
                                break 'batches;
                            }
                        }
                    }
                    min_epoch = Some(min_epoch.map_or(e, |m| m.min(e)));
                    self.start_index.insert((f.start, f.id));
                }
                {
                    let rec = &mut self.progs[p].rec[i];
                    rec.finish = f.start + dur;
                    rec.dur = dur;
                    rec.cost = cost;
                }
                if self.occ.is_tracking() {
                    self.occ.add_step(&self.progs[p].steps[i], f.start, f.start + dur);
                }
                self.cal.push(f.start + dur, f.id);
            }
            if repriced_here > 0 {
                self.straddle.straddled_batches += 1;
                self.straddle.repriced_fires += repriced_here;
            }
        }
        self.batch = batch;
        self.fires = fires;
        result
    }

    /// The occupancy fixed point (time-varying models only; see the
    /// module docs for the convergence argument): re-price every settled
    /// step with start >= the dirty horizon against the final occupancy;
    /// on divergence, horizon-invalidate from the earliest divergent
    /// start, re-drain, repeat.
    fn settle(&mut self) -> Result<()> {
        let Some(mut from) = self.dirty_from.take() else { return Ok(()) };
        let mut passes = 0usize;
        loop {
            // Walk settled steps in ascending start order via the index
            // (PR 5 follow-up (h)): the first divergence IS the minimum
            // divergent start, so the re-price scan stops there instead
            // of pricing the whole world per pass.
            let mut div: Option<Cycle> = None;
            for &(s, id) in self.start_index.range((from, 0)..) {
                let (p, i) = self.id_map[id];
                let (p, i) = (p as usize, i as usize);
                let pr = &self.progs[p];
                debug_assert!(pr.rec[i].started && pr.rec[i].start == s && !pr.pruned);
                let rec = &pr.rec[i];
                let (cost, dur) =
                    price(self.model.as_ref(), self.fabric, &pr.steps[i], rec.start, &self.occ)?;
                if dur != rec.dur || cost != rec.cost {
                    div = Some(rec.start);
                    break;
                }
            }
            let Some(t) = div else { return Ok(()) };
            passes += 1;
            ensure!(
                passes <= MAX_SETTLE_PASSES,
                "settle loop did not converge in {MAX_SETTLE_PASSES} passes \
                 (cost model reads non-strictly-earlier epochs?)"
            );
            let mut seeds = Vec::new();
            self.collect_horizon_seeds(t, usize::MAX, &mut seeds);
            let mut affected = Vec::new();
            let low = self.invalidate_closure(seeds, &mut affected);
            debug_assert!(low >= t, "horizon invalidation reached below its own floor");
            affected.sort_unstable();
            self.rebuild_resource_state(&affected);
            for &r in &affected {
                self.wake_head(r)?;
            }
            self.drain(None)?;
            from = t;
        }
    }

    /// Prune the queue entries of every program that fully completed
    /// before cycle `t` (and whose queue key sorts below `t`), recycling
    /// their global-id ranges, and raise the admission floor to `t`:
    /// later perturbations below the floor are rejected, so the pruned
    /// history can never be displaced and reports stay bit-identical to
    /// an unpruned session. Drains (and, for time-varying models,
    /// settles) first. Returns the number of queue entries removed.
    pub fn prune_completed_before(&mut self, t: Cycle) -> Result<usize> {
        self.run_to_drain()?;
        let mut prunable = vec![false; self.progs.len()];
        let mut any = false;
        for (pi, pr) in self.progs.iter().enumerate() {
            if pr.pruned || pr.remaining != 0 || pr.key[0] >= t {
                continue;
            }
            let finished = match &pr.span_cache {
                Some(s) => s.finished_at,
                None => pr.rec.iter().map(|r| r.finish).max().unwrap_or(pr.admit_at),
            };
            if finished < t {
                prunable[pi] = true;
                any = true;
            }
        }
        self.admit_floor = self.admit_floor.max(t);
        if !any {
            return Ok(0);
        }
        let mut removed = 0usize;
        let mut touched: Vec<usize> = Vec::new();
        for r in 0..self.res.len() {
            let before = self.res[r].steps.len();
            let id_map = &self.id_map;
            self.res[r].steps.retain(|&id| !prunable[id_map[id].0 as usize]);
            if self.res[r].steps.len() != before {
                removed += before - self.res[r].steps.len();
                touched.push(r);
            }
        }
        for &r in &touched {
            self.renumber_queue(r);
        }
        self.rebuild_resource_state(&touched);
        for (pi, pr) in self.progs.iter_mut().enumerate() {
            if prunable[pi] {
                pr.pruned = true;
                if !pr.rec.is_empty() {
                    self.free_ranges.push((pr.base, pr.rec.len()));
                }
                // Frozen history leaves the start index (its id range
                // may be recycled; pruned steps must never seed a
                // horizon again).
                if self.epoch.is_some() {
                    for (idx, rec) in pr.rec.iter().enumerate() {
                        debug_assert!(rec.started && rec.completed);
                        self.start_index.remove(&(rec.start, pr.base + idx));
                    }
                }
                if self.discard_pruned {
                    // The span cache is primed (the program completed a
                    // full drain), so span() keeps serving exact
                    // telemetry; only report()'s merged fold loses its
                    // inputs, and report() checks for that.
                    debug_assert!(pr.span_cache.is_some());
                    pr.discarded = true;
                    pr.steps = Vec::new();
                    pr.rec = Vec::new();
                    pr.succ_off = Vec::new();
                    pr.succ = Vec::new();
                }
            }
        }
        Ok(removed)
    }
}

/// Deterministic admission batching: requests accumulate in arrival
/// order and flush into a [`CosimSession`] in one pass, so a burst of
/// programs is admitted (and the calendar re-seeded) without draining
/// between requests. `bench_admission` measures the win over
/// one-at-a-time admit+drain.
#[derive(Debug, Default)]
pub struct AdmissionQueue {
    entries: Vec<(FabricProgram, Cycle, AdmitMeta)>,
}

impl AdmissionQueue {
    pub fn new() -> Self {
        AdmissionQueue::default()
    }

    /// Queue `prog` for admission at simulated cycle `at`.
    pub fn push(&mut self, prog: FabricProgram, at: Cycle) {
        self.entries.push((prog, at, AdmitMeta::default()));
    }

    /// Queue with explicit priority/deadline metadata.
    pub fn push_with(&mut self, prog: FabricProgram, at: Cycle, meta: AdmitMeta) {
        self.entries.push((prog, at, meta));
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Admit every queued program, in push order, returning the handles.
    pub fn admit_all(&mut self, session: &mut CosimSession) -> Result<Vec<ProgramHandle>> {
        let mut handles = Vec::with_capacity(self.entries.len());
        for (prog, at, meta) in self.entries.drain(..) {
            handles.push(session.admit_with(&prog, at, meta)?);
        }
        Ok(handles)
    }
}

/// How the recovery engine responds to a behavioral fault (see the
/// module docs' fault section).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RecoveryPolicy {
    /// Transients retry in place with exponential backoff; permanent
    /// tile death escalates to re-mapping (there is nowhere to retry).
    #[default]
    Retry,
    /// Pessimistic: a transient also re-maps off the suspect tile (if a
    /// same-kind alternative exists), treating glitching silicon as
    /// about to fail.
    Remap,
    /// Like [`RecoveryPolicy::Retry`], but a restart that cannot meet
    /// the request's [`AdmitMeta::deadline`] is shed instead of
    /// rescheduled.
    DeadlineAware,
    /// Any behavioral fault sheds the afflicted request immediately.
    Shed,
}

/// Per-request recovery outcome, surfaced by the serving layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct RequestOutcome {
    /// Transient faults absorbed (each adds a detect + backoff delay).
    pub attempts: u32,
    /// At least one transient retry was scheduled.
    pub retried: bool,
    /// Content was re-mapped off dead/suspect silicon at least once.
    pub remapped: bool,
    /// Dropped: replaced by an empty program, producing no output.
    pub shed: bool,
}

/// Aggregate degradation telemetry for one faulty episode. Every field
/// is path-independent: an incremental session and a from-scratch
/// replay of the same admissions produce equal reports
/// (`tests/fault_golden.rs`).
#[derive(Debug, Clone, PartialEq)]
pub struct DegradationReport {
    /// Requests admitted (shed ones included).
    pub programs: usize,
    /// Requests that produced output (not shed).
    pub completed: usize,
    /// Requests that absorbed at least one transient retry.
    pub retried: usize,
    /// Requests re-mapped off dead/suspect silicon.
    pub remapped: usize,
    /// Requests dropped by policy or for lack of healthy silicon.
    pub shed: usize,
    /// Requests with a finite deadline that was missed (shed counts as
    /// missed).
    pub deadline_violated: usize,
    /// Total transient retry attempts across all requests.
    pub transient_retries: u64,
    /// Plan events processed (behavioral + pricing).
    pub faults_injected: usize,
    /// Behavioral events that found no afflicted work (idle silicon).
    pub faults_masked: usize,
    /// Behavioral events that afflicted at least one request.
    pub faults_effective: usize,
    /// Pricing events processed (materialized in the cost wrapper).
    pub pricing_events: usize,
    /// completed / programs (1.0 for an empty episode).
    pub availability: f64,
    /// MTTF-style aggregate: episode cycles per effective behavioral
    /// fault (infinite when none hit).
    pub mean_cycles_between_effective: f64,
}

/// Recovery bookkeeping for one admitted request.
#[derive(Debug, Clone)]
struct ReqState {
    /// Original admission cycle (restarts re-admit no earlier).
    arrival: Cycle,
    meta: AdmitMeta,
    /// Current content (tracks re-maps; empty once shed).
    steps: Vec<Step>,
    attempts: u32,
    retried: bool,
    remapped: bool,
    shed: bool,
}

/// The tile ids a step references (execution site or transfer
/// endpoints) — the death-affliction predicate's footprint.
fn step_tiles(s: &Step) -> [Option<usize>; 2] {
    match s {
        Step::Load { tile, .. } | Step::Exec { tile, .. } => [Some(*tile), None],
        Step::Transfer { from, to, .. } => [Some(*from), Some(*to)],
    }
}

/// True when any step references a tile marked in `avoid`
/// (`avoid[t] != Cycle::MAX`).
fn references_avoided(steps: &[Step], avoid: &[Cycle]) -> bool {
    steps
        .iter()
        .any(|s| step_tiles(s).iter().flatten().any(|&t| avoid[t] != Cycle::MAX))
}

/// Re-map every step off the avoided tiles: an avoided execution site or
/// transfer endpoint moves to the first non-avoided tile of the same
/// accelerator kind (ascending tile index — deterministic); `None` when
/// some needed kind has no healthy tile left. Only fabric tile indices
/// are rewritten — `node` fields are IR graph-node ids and ride along.
fn remap_steps(steps: &[Step], avoid: &[Cycle], fabric: &Fabric) -> Option<Vec<Step>> {
    let target = |t: usize| -> Option<usize> {
        if avoid[t] == Cycle::MAX {
            return Some(t);
        }
        let kind = fabric.tiles[t].accel.name();
        (0..fabric.tile_count())
            .find(|&c| avoid[c] == Cycle::MAX && fabric.tiles[c].accel.name() == kind)
    };
    let mut out = Vec::with_capacity(steps.len());
    for s in steps {
        out.push(match s {
            Step::Load { tile, bytes, node, deps } => Step::Load {
                tile: target(*tile)?,
                bytes: *bytes,
                node: *node,
                deps: deps.clone(),
            },
            Step::Transfer { from, to, bytes, node, deps } => Step::Transfer {
                from: target(*from)?,
                to: target(*to)?,
                bytes: *bytes,
                node: *node,
                deps: deps.clone(),
            },
            Step::Exec { tile, node, compute, precision, deps } => Step::Exec {
                tile: target(*tile)?,
                node: *node,
                compute: compute.clone(),
                precision: *precision,
                deps: deps.clone(),
            },
        });
    }
    Some(out)
}

/// A [`CosimSession`] under a seeded [`FaultPlan`]: the graceful-
/// degradation engine. See the module docs' fault section for the event
/// model, the [`RecoveryPolicy`] semantics and the determinism
/// contract. Error handling matches the inner session: a rejected
/// admission or recovery action leaves the pair in an unspecified (but
/// memory-safe) state.
pub struct FaultySession<'f> {
    inner: CosimSession<'f>,
    plan: FaultPlan,
    policy: RecoveryPolicy,
    /// Detection latency: restarts land at `fault + detect` earliest.
    detect: Cycle,
    /// Transient attempts beyond this shed the request.
    max_retries: u32,
    /// Exponential backoff base for transient retries.
    backoff: Cycle,
    /// Next unprocessed plan event.
    next_ev: usize,
    /// Cycle of the last processed event: the frozen-history floor.
    fault_floor: Cycle,
    /// Death cycle per tile (`Cycle::MAX` = alive), processed events
    /// only — the behavioral twin of the cost wrapper's timeline.
    dead_at: Vec<Cycle>,
    /// Per-plan-event "afflicted at least one request" flags.
    hit: Vec<bool>,
    /// Parallel to the inner session's program slots.
    reqs: Vec<ReqState>,
}

impl<'f> FaultySession<'f> {
    /// Generate the plan from `cfg` over `fabric`'s tile kinds and wrap
    /// the fabric's configured cost model. `cfg` is validated; recovery
    /// knobs (`detect_cycles`, `max_retries`, `backoff_base`) are read
    /// from it.
    pub fn new(fabric: &'f Fabric, cfg: &FaultConfig, policy: RecoveryPolicy) -> Result<Self> {
        let kinds: Vec<&str> = fabric.tiles.iter().map(|t| t.accel.name()).collect();
        let plan = FaultPlan::generate(cfg, &kinds);
        Self::with_model(fabric, fabric.cost_model().clone(), plan, cfg, policy)
    }

    /// Wrap an explicit (recorded / hand-written) plan over the fabric's
    /// configured cost model.
    pub fn with_plan(
        fabric: &'f Fabric,
        plan: FaultPlan,
        cfg: &FaultConfig,
        policy: RecoveryPolicy,
    ) -> Result<Self> {
        Self::with_model(fabric, fabric.cost_model().clone(), plan, cfg, policy)
    }

    /// Wrap an explicit plan over an explicit base cost model. When the
    /// plan needs no pricing (only transients, or empty), the base model
    /// is used untouched — the same `Arc`, so an empty-plan session is
    /// the fault-free session, bit for bit; otherwise the base is
    /// wrapped in a [`DegradedCost`] materialized from the plan.
    pub fn with_model(
        fabric: &'f Fabric,
        base: Arc<dyn CostModel>,
        plan: FaultPlan,
        cfg: &FaultConfig,
        policy: RecoveryPolicy,
    ) -> Result<Self> {
        let nt = fabric.tile_count();
        for ev in plan.events() {
            let ok = match ev.kind {
                FaultKind::TileTransient { tile }
                | FaultKind::TileDeath { tile }
                | FaultKind::CrossbarDrift { tile, .. }
                | FaultKind::PhotonicThermal { tile, .. } => tile < nt,
                FaultKind::LinkDegrade { from, to, .. }
                | FaultKind::LinkFail { from, to, .. } => from < nt && to < nt,
                FaultKind::HbmBrownout { .. } => true,
            };
            ensure!(ok, "fault plan references a tile outside the fabric: {:?}", ev.kind);
        }
        // Dead-tile quarantine pricing needs the wrapper too, so only a
        // purely-transient (or empty) plan skips it.
        let needs_wrapper =
            plan.events().iter().any(|e| !matches!(e.kind, FaultKind::TileTransient { .. }));
        let model: Arc<dyn CostModel> = if needs_wrapper {
            Arc::new(DegradedCost::from_plan(base, fabric, &plan))
        } else {
            base
        };
        Ok(FaultySession {
            inner: CosimSession::with_model(fabric, model),
            hit: vec![false; plan.len()],
            dead_at: vec![Cycle::MAX; nt],
            plan,
            policy,
            detect: cfg.detect_cycles,
            max_retries: cfg.max_retries,
            backoff: cfg.backoff_base.max(1),
            next_ev: 0,
            fault_floor: 0,
            reqs: Vec::new(),
        })
    }

    /// The wrapped session (reports, spans, footprint probes).
    pub fn session(&self) -> &CosimSession<'f> {
        &self.inner
    }

    /// Forward of [`CosimSession::set_threads`] — faulty replay is
    /// pinned bit-identical across thread counts by
    /// `tests/fault_golden.rs` like the plain session.
    pub fn set_threads(&mut self, threads: usize) {
        self.inner.set_threads(threads);
    }

    /// Forward of [`CosimSession::set_shards`].
    pub fn set_shards(&mut self, bounds: Option<&[usize]>) -> Result<()> {
        self.inner.set_shards(bounds)
    }

    /// Forward of [`CosimSession::straddle_stats`].
    pub fn straddle_stats(&self) -> StraddleStats {
        self.inner.straddle_stats()
    }

    /// Forward of [`CosimSession::set_discard_pruned`] — the long-run
    /// serving knob works identically through the fault layer.
    pub fn set_discard_pruned(&mut self, on: bool) {
        self.inner.set_discard_pruned(on)
    }

    /// Forward of [`CosimSession::queue_footprint`].
    pub fn queue_footprint(&self) -> (usize, usize) {
        self.inner.queue_footprint()
    }

    /// Retained per-step history including this layer's per-request
    /// recovery copies (the steady-state footprint probe; see
    /// [`CosimSession::history_footprint`]).
    pub fn history_footprint(&self) -> usize {
        self.inner.history_footprint()
            + self.reqs.iter().map(|r| r.steps.len()).sum::<usize>()
    }

    /// Forward of [`CosimSession::prune_completed_before`], additionally
    /// releasing pruned requests' retained recovery content: a pruned
    /// request completed strictly before `t`, so no future fault can
    /// afflict it (the affliction scans skip completed records) and its
    /// `steps` copy is dead weight in a steady-state serving run.
    pub fn prune_completed_before(&mut self, t: Cycle) -> Result<usize> {
        let removed = self.inner.prune_completed_before(t)?;
        for (p, req) in self.reqs.iter_mut().enumerate() {
            if self.inner.progs[p].pruned && !req.steps.is_empty() {
                req.steps = Vec::new();
            }
        }
        Ok(removed)
    }

    /// The session's effective cost model (the degraded wrapper when the
    /// plan prices anything, the base model otherwise).
    pub fn cost_model(&self) -> &Arc<dyn CostModel> {
        self.inner.cost_model()
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    pub fn recovery_policy(&self) -> RecoveryPolicy {
        self.policy
    }

    /// Plan events processed so far.
    pub fn faults_processed(&self) -> usize {
        self.next_ev
    }

    /// Cycle of the last processed event: admissions may not arrive
    /// before it (the frozen-history floor of the determinism contract).
    pub fn fault_floor(&self) -> Cycle {
        self.fault_floor
    }

    /// Number of admitted requests.
    pub fn programs(&self) -> usize {
        self.reqs.len()
    }

    /// Per-request recovery outcome.
    pub fn outcome(&self, h: ProgramHandle) -> RequestOutcome {
        let r = &self.reqs[h.0];
        RequestOutcome {
            attempts: r.attempts,
            retried: r.retried,
            remapped: r.remapped,
            shed: r.shed,
        }
    }

    /// Forwarded to [`CosimSession::set_policy`] (before any admission).
    pub fn set_policy(&mut self, policy: AdmitPolicy) -> Result<()> {
        self.inner.set_policy(policy)
    }

    /// Admit at `at` with default metadata (see
    /// [`FaultySession::admit_with`]).
    pub fn admit_at(&mut self, prog: &FabricProgram, at: Cycle) -> Result<ProgramHandle> {
        self.admit_with(prog, at, AdmitMeta::default())
    }

    /// Admit `prog` at simulated cycle `at`. Admissions may not arrive
    /// before the fault floor (the last processed event's cycle) nor
    /// displace any step scheduled before it — the history every
    /// already-applied fault observed is frozen. The processed
    /// `TileDeath` prefix is replayed against the new content (re-map or
    /// shed per policy, restart no earlier than `death + detect`), so
    /// admitting after a death is equivalent to having been admitted
    /// before it — the mechanism behind incremental ≡ from-scratch.
    pub fn admit_with(
        &mut self,
        prog: &FabricProgram,
        at: Cycle,
        meta: AdmitMeta,
    ) -> Result<ProgramHandle> {
        ensure!(
            at >= self.fault_floor,
            "admission at cycle {at} lies before already-processed faults (floor {})",
            self.fault_floor
        );
        let mut steps = prog.steps.clone();
        let mut restart_at = at;
        let mut remapped = false;
        let mut shed = false;
        let mut dead = vec![Cycle::MAX; self.inner.fabric.tile_count()];
        for i in 0..self.next_ev {
            let ev = self.plan.events()[i];
            let FaultKind::TileDeath { tile } = ev.kind else { continue };
            dead[tile] = dead[tile].min(ev.at);
            if shed || !references_avoided(&steps, &dead) {
                continue;
            }
            // This death would have afflicted the request had it been
            // admitted before the event — replay the same recovery.
            self.hit[i] = true;
            let t2 = restart_at.max(ev.at.saturating_add(self.detect));
            match self.policy {
                RecoveryPolicy::Shed => shed = true,
                RecoveryPolicy::DeadlineAware if t2 > meta.deadline => shed = true,
                _ => match remap_steps(&steps, &dead, self.inner.fabric) {
                    Some(s2) => {
                        steps = s2;
                        restart_at = t2;
                        remapped = true;
                    }
                    None => shed = true,
                },
            }
        }
        let (content, admit_time) = if shed {
            (FabricProgram::default(), at)
        } else {
            (FabricProgram { steps, producer: Vec::new() }, restart_at)
        };
        // Frozen-history guard: raise the inner admission floor to the
        // fault floor for the duration of this install, so its existing
        // closure check rejects any admission whose invalidation would
        // displace a step some processed fault already observed.
        // (Recovery restarts run *without* the raise: they legitimately
        // perturb below the current event, identically on every path.)
        let saved = self.inner.admit_floor;
        self.inner.admit_floor = saved.max(self.fault_floor);
        let installed = self.inner.admit_with(&content, admit_time, meta);
        self.inner.admit_floor = saved;
        let h = installed?;
        debug_assert_eq!(h.0, self.reqs.len(), "request table tracks inner slots");
        self.reqs.push(ReqState {
            arrival: at,
            meta,
            steps: content.steps,
            attempts: 0,
            retried: false,
            remapped,
            shed,
        });
        Ok(h)
    }

    /// Drain to simulated cycle `t`, applying due fault events in plan
    /// order along the way.
    pub fn run_until(&mut self, t: Cycle) -> Result<()> {
        self.process_events(Some(t))?;
        self.inner.run_until(t)
    }

    /// Drain all admitted work to completion, applying fault events in
    /// plan order along the way. Events are processed *lazily*: once no
    /// completion is pending, later plan events are left for a future
    /// admission's drain (they would only hit idle silicon now, and
    /// deferring them keeps the fault floor from outrunning the served
    /// timeline). The same lazy rule governs [`FaultySession::run_until`]
    /// so the processed-event count is path-independent.
    pub fn run_to_drain(&mut self) -> Result<()> {
        self.process_events(None)?;
        self.inner.run_to_drain()
    }

    /// Drain ([`FaultySession::run_to_drain`]) and fold the inner
    /// session's merged report.
    pub fn report(&mut self) -> Result<ExecReport> {
        self.process_events(None)?;
        self.inner.report()
    }

    /// Per-request span (inner session cache; exact for shed requests
    /// too — an empty program's span is zero-length at its arrival).
    pub fn span(&self, h: ProgramHandle) -> ProgramSpan {
        self.inner.span(h)
    }

    /// Degradation telemetry for the episode (pass the report the
    /// episode folded — its spans supply per-request finish times).
    pub fn degradation(&self, exec: &ExecReport) -> DegradationReport {
        let programs = self.reqs.len();
        let mut completed = 0usize;
        let mut retried = 0usize;
        let mut remapped = 0usize;
        let mut shed = 0usize;
        let mut deadline_violated = 0usize;
        let mut transient_retries = 0u64;
        for (p, r) in self.reqs.iter().enumerate() {
            if r.shed {
                shed += 1;
            } else {
                completed += 1;
            }
            if r.retried {
                retried += 1;
            }
            if r.remapped {
                remapped += 1;
            }
            transient_retries += u64::from(r.attempts);
            if r.meta.deadline != Cycle::MAX
                && (r.shed
                    || exec.programs.get(p).is_none_or(|s| s.finished_at > r.meta.deadline))
            {
                deadline_violated += 1;
            }
        }
        let behavioral = self.plan.events()[..self.next_ev]
            .iter()
            .filter(|e| e.kind.is_behavioral())
            .count();
        let effective = self.hit[..self.next_ev].iter().filter(|&&h| h).count();
        DegradationReport {
            programs,
            completed,
            retried,
            remapped,
            shed,
            deadline_violated,
            transient_retries,
            faults_injected: self.next_ev,
            faults_masked: behavioral - effective,
            faults_effective: effective,
            pricing_events: self.next_ev - behavioral,
            availability: if programs == 0 { 1.0 } else { completed as f64 / programs as f64 },
            mean_cycles_between_effective: if effective == 0 {
                f64::INFINITY
            } else {
                exec.cycles as f64 / effective as f64
            },
        }
    }

    /// Apply plan events in canonical order: each event waits for the
    /// session to drain to its cycle, observes the machine state there,
    /// and recovers per policy. Events are applied *lazily* — an event
    /// observing a quiescent calendar (no pending completions at all)
    /// is deferred, not consumed. Laziness is part of the determinism
    /// contract: whether the calendar is quiescent after draining to
    /// `ev.at` is a pure function of the admitted set, so every
    /// admission/pause interleaving makes the same processed/deferred
    /// decision per event, and `faults_processed` / the degradation
    /// counters are path-independent. (A deferred event re-attempts on
    /// the next run; an admission landing before a deferred event is
    /// legal — the event then afflicts it exactly as a from-scratch
    /// replay would.)
    fn process_events(&mut self, until: Option<Cycle>) -> Result<()> {
        while self.next_ev < self.plan.len() {
            let ev = self.plan.events()[self.next_ev];
            if until.is_some_and(|t| ev.at > t) {
                break;
            }
            self.inner.run_until(ev.at)?;
            if self.inner.is_quiescent() {
                break;
            }
            self.apply_event(self.next_ev, ev)?;
            self.fault_floor = self.fault_floor.max(ev.at);
            self.next_ev += 1;
        }
        Ok(())
    }

    fn apply_event(&mut self, i: usize, ev: FaultEvent) -> Result<()> {
        match ev.kind {
            FaultKind::TileTransient { tile } => {
                if let Some(p) = self.executing_on(tile, ev.at) {
                    self.hit[i] = true;
                    self.recover_transient(p, tile, ev.at)?;
                    // A retry's retraction can re-flow other programs
                    // onto previously-dead silicon — re-check.
                    self.quarantine_sweep(ev.at)?;
                }
            }
            FaultKind::TileDeath { tile } => {
                self.dead_at[tile] = self.dead_at[tile].min(ev.at);
                if self.quarantine_sweep(ev.at)? {
                    self.hit[i] = true;
                }
            }
            // Pricing kinds are pre-materialized in the cost wrapper.
            _ => {}
        }
        Ok(())
    }

    /// The request whose step occupies `tile` at the fault instant:
    /// started strictly before `at` (so a request admitted later —
    /// necessarily at/after the fault floor — can never be afflicted,
    /// on any admission path), still uncompleted after draining to
    /// `at`. At most one exists: a resource runs one step at a time.
    fn executing_on(&self, tile: usize, at: Cycle) -> Option<usize> {
        for (p, req) in self.reqs.iter().enumerate() {
            if req.shed {
                continue;
            }
            for rec in &self.inner.progs[p].rec {
                if rec.res as usize == tile && rec.started && !rec.completed && rec.start < at {
                    return Some(p);
                }
            }
        }
        None
    }

    /// Lowest-handle request with an uncompleted step referencing a
    /// dead tile (execution site or transfer endpoint).
    fn find_afflicted(&self) -> Option<usize> {
        for (p, req) in self.reqs.iter().enumerate() {
            if req.shed {
                continue;
            }
            let pr = &self.inner.progs[p];
            for (s, rec) in pr.steps.iter().zip(&pr.rec) {
                if !rec.completed
                    && step_tiles(s).iter().flatten().any(|&t| self.dead_at[t] != Cycle::MAX)
                {
                    return Some(p);
                }
            }
        }
        None
    }

    /// Re-map (or shed) every request with uncompleted work on dead
    /// silicon, to a fixed point: each recovery's retraction may re-flow
    /// other programs, but a recovered request never re-references dead
    /// tiles, so the sweep acts at most once per request. Returns
    /// whether any request was afflicted.
    fn quarantine_sweep(&mut self, at: Cycle) -> Result<bool> {
        if self.dead_at.iter().all(|&d| d == Cycle::MAX) {
            return Ok(false);
        }
        let mut acted = false;
        while let Some(p) = self.find_afflicted() {
            acted = true;
            self.recover_death(p, at)?;
        }
        Ok(acted)
    }

    /// Whole-request restart after tile death: re-map off the current
    /// dead set at `max(arrival, at + detect)`, or shed per policy.
    fn recover_death(&mut self, p: usize, at: Cycle) -> Result<()> {
        let t2 = self.reqs[p].arrival.max(at.saturating_add(self.detect));
        let shed = match self.policy {
            RecoveryPolicy::Shed => true,
            RecoveryPolicy::DeadlineAware => t2 > self.reqs[p].meta.deadline,
            _ => false,
        };
        if shed {
            return self.shed(p);
        }
        match remap_steps(&self.reqs[p].steps, &self.dead_at, self.inner.fabric) {
            Some(steps) => self.restart(p, steps, t2, true),
            None => self.shed(p),
        }
    }

    /// Whole-request restart after a transient on `tile`: retry with
    /// exponential backoff (re-mapped off the suspect tile under
    /// [`RecoveryPolicy::Remap`]), shedding beyond `max_retries` or on a
    /// busted deadline under [`RecoveryPolicy::DeadlineAware`].
    fn recover_transient(&mut self, p: usize, tile: usize, at: Cycle) -> Result<()> {
        self.reqs[p].attempts += 1;
        let attempts = self.reqs[p].attempts;
        if matches!(self.policy, RecoveryPolicy::Shed) || attempts > self.max_retries {
            return self.shed(p);
        }
        let backoff = self.backoff.saturating_mul(1u64 << u64::from(attempts - 1).min(32));
        let t2 = at.saturating_add(self.detect).saturating_add(backoff);
        if matches!(self.policy, RecoveryPolicy::DeadlineAware) && t2 > self.reqs[p].meta.deadline
        {
            return self.shed(p);
        }
        let (steps, moved) = if matches!(self.policy, RecoveryPolicy::Remap) {
            let mut avoid = self.dead_at.clone();
            avoid[tile] = avoid[tile].min(at);
            match remap_steps(&self.reqs[p].steps, &avoid, self.inner.fabric) {
                Some(s) => {
                    let touched = references_avoided(&self.reqs[p].steps, &avoid);
                    (s, touched)
                }
                // No healthy same-kind alternative: retry in place (the
                // tile still works — the fault was transient).
                None => (self.reqs[p].steps.clone(), false),
            }
        } else {
            (self.reqs[p].steps.clone(), false)
        };
        self.reqs[p].retried = true;
        self.restart(p, steps, t2, moved)
    }

    /// Replace request `p` in the live calendar: retracts its in-flight
    /// steps (generation-stamped calendar entries), re-prices the
    /// invalidation closure, and re-admits the new content at `at`.
    fn restart(&mut self, p: usize, steps: Vec<Step>, at: Cycle, remapped: bool) -> Result<()> {
        let content = FabricProgram { steps, producer: Vec::new() };
        let meta = self.reqs[p].meta;
        self.inner.replace_with(ProgramHandle(p), &content, at, meta)?;
        self.reqs[p].steps = content.steps;
        if remapped {
            self.reqs[p].remapped = true;
        }
        Ok(())
    }

    /// Drop request `p`: its slot is replaced by an empty program at the
    /// original arrival (zero-length span, no output).
    fn shed(&mut self, p: usize) -> Result<()> {
        let meta = self.reqs[p].meta;
        let at = self.reqs[p].arrival;
        self.inner.replace_with(ProgramHandle(p), &FabricProgram::default(), at, meta)?;
        self.reqs[p].steps = Vec::new();
        self.reqs[p].shed = true;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::Precision;
    use crate::compiler::lowering::lower;
    use crate::compiler::mapper::{map_graph, MapStrategy};
    use crate::config::FabricConfig;
    use crate::coordinator::{cosim, cosim_ref};
    use crate::prop_assert;
    use crate::workloads;

    fn fabric() -> Fabric {
        Fabric::build(
            FabricConfig::from_toml(
                "[noc]\nwidth = 3\nheight = 3\n\
                 [[cu]]\nkind = \"npu\"\ntemplate = \"B\"\ncount = 4\n",
            )
            .unwrap(),
        )
        .unwrap()
    }

    fn program(f: &Fabric, seed: u64) -> FabricProgram {
        let g = workloads::mlp(4, 32, &[32, 16], 8, seed).unwrap();
        let m = map_graph(&g, f, MapStrategy::Greedy, Precision::Int8).unwrap();
        lower(&g, f, &m).unwrap()
    }

    #[test]
    fn single_program_at_zero_matches_cosim_bitwise() {
        let f = fabric();
        let p = program(&f, 1);
        let mut s = CosimSession::new(&f);
        s.admit_at(&p, 0).unwrap();
        let got = s.report().unwrap();
        let want = cosim(&f, &p).unwrap();
        let want_ref = cosim_ref(&f, &p).unwrap();
        assert!(got.bit_identical(&want), "session vs event engine");
        assert!(got.bit_identical(&want_ref), "session vs list scheduler");
    }

    #[test]
    fn report_is_repeatable_and_incremental_admit_extends_it() {
        let f = fabric();
        let p1 = program(&f, 1);
        let p2 = program(&f, 2);
        let mut s = CosimSession::new(&f);
        let h1 = s.admit_at(&p1, 0).unwrap();
        let a = s.report().unwrap();
        let b = s.report().unwrap();
        assert!(a.bit_identical(&b), "re-reporting a quiescent session");
        let h2 = s.admit_at(&p2, a.cycles + 100).unwrap();
        let c = s.report().unwrap();
        assert_eq!(c.programs.len(), 2);
        // Tail admission after quiescence must not disturb program 1.
        assert!(c.programs[h1.index()].bit_identical(&a.programs[0]));
        assert_eq!(c.programs[h2.index()].admitted_at, a.cycles + 100);
        assert!(c.cycles >= a.cycles);
    }

    #[test]
    fn retroactive_admission_matches_fresh_session() {
        let f = fabric();
        let p1 = program(&f, 3);
        let p2 = program(&f, 4);
        // Incremental: admit p1 at t=500, drain, then admit p2 at t=0 —
        // in the simulated past, displacing p1's already-run steps.
        let mut inc = CosimSession::new(&f);
        inc.admit_at(&p1, 500).unwrap();
        inc.run_to_drain().unwrap();
        inc.admit_at(&p2, 0).unwrap();
        let got = inc.report().unwrap();
        // Oracle: fresh session, same programs and times, same sequence.
        let mut fresh = CosimSession::new(&f);
        fresh.admit_at(&p1, 500).unwrap();
        fresh.admit_at(&p2, 0).unwrap();
        let want = fresh.report().unwrap();
        assert!(got.bit_identical(&want));
    }

    #[test]
    fn replace_reprices_only_that_program() {
        let f = fabric();
        let p1 = program(&f, 5);
        let p2 = program(&f, 6);
        let p2b = program(&f, 7);
        let mut inc = CosimSession::new(&f);
        let _h1 = inc.admit_at(&p1, 0).unwrap();
        let h2 = inc.admit_at(&p2, 10).unwrap();
        inc.run_to_drain().unwrap();
        inc.replace(h2, &p2b, 10).unwrap();
        let got = inc.report().unwrap();
        let mut fresh = CosimSession::new(&f);
        fresh.admit_at(&p1, 0).unwrap();
        fresh.admit_at(&p2b, 10).unwrap();
        let want = fresh.report().unwrap();
        assert!(got.bit_identical(&want));
    }

    #[test]
    fn run_until_pauses_mid_flight() {
        let f = fabric();
        let p1 = program(&f, 8);
        let mut s = CosimSession::new(&f);
        let h = s.admit_at(&p1, 0).unwrap();
        let full = {
            let mut s2 = CosimSession::new(&f);
            s2.admit_at(&p1, 0).unwrap();
            s2.report().unwrap()
        };
        s.run_until(full.cycles / 2).unwrap();
        assert!(!s.is_quiescent(), "work must remain in flight");
        let got = s.report().unwrap();
        assert!(got.bit_identical(&full));
        assert_eq!(s.span(h).finished_at, full.cycles);
    }

    #[test]
    fn admission_queue_batches_in_push_order() {
        let f = fabric();
        let mut q = AdmissionQueue::new();
        assert!(q.is_empty());
        q.push(program(&f, 1), 0);
        q.push(program(&f, 2), 0);
        assert_eq!(q.len(), 2);
        let mut s = CosimSession::new(&f);
        let hs = q.admit_all(&mut s).unwrap();
        assert!(q.is_empty());
        assert_eq!(hs.iter().map(ProgramHandle::index).collect::<Vec<_>>(), [0, 1]);
        let mut seq = CosimSession::new(&f);
        seq.admit_at(&program(&f, 1), 0).unwrap();
        seq.run_to_drain().unwrap();
        seq.admit_at(&program(&f, 2), 0).unwrap();
        let a = s.report().unwrap();
        let b = seq.report().unwrap();
        assert!(a.bit_identical(&b), "batched vs one-at-a-time admission");
    }

    #[test]
    fn rejects_malformed_programs() {
        let f = fabric();
        let mut s = CosimSession::new(&f);
        let bad = FabricProgram {
            steps: vec![Step::Load { tile: 0, bytes: 64, node: 0, deps: vec![0] }],
            producer: Vec::new(),
        };
        assert!(s.admit_at(&bad, 0).is_err(), "self-dependency");
        let bad_tile = FabricProgram {
            steps: vec![Step::Load { tile: 99, bytes: 64, node: 0, deps: vec![] }],
            producer: Vec::new(),
        };
        assert!(s.admit_at(&bad_tile, 0).is_err(), "tile out of range");
    }

    /// Priority policy: a same-instant burst serves higher priority
    /// first; the schedule is deterministic and independent of the
    /// admission call order (spans matched per program).
    #[test]
    fn priority_policy_is_deterministic_and_order_independent() {
        let f = fabric();
        let pa = program(&f, 11);
        let pb = program(&f, 12);
        let run = |first: (&FabricProgram, u32), second: (&FabricProgram, u32)| {
            let mut s = CosimSession::new(&f);
            s.set_policy(AdmitPolicy::Priority).unwrap();
            let h1 = s
                .admit_with(first.0, 0, AdmitMeta { priority: first.1, ..Default::default() })
                .unwrap();
            let h2 = s
                .admit_with(second.0, 0, AdmitMeta { priority: second.1, ..Default::default() })
                .unwrap();
            let rep = s.report().unwrap();
            (rep.programs[h1.index()].clone(), rep.programs[h2.index()].clone(), rep)
        };
        let (a1, a2, ra) = run((&pa, 1), (&pb, 9));
        let (b2, b1, rb) = run((&pb, 9), (&pa, 1));
        assert!(a1.bit_identical(&b1), "low-priority span must not depend on call order");
        assert!(a2.bit_identical(&b2), "high-priority span must not depend on call order");
        assert_eq!(ra.cycles, rb.cycles);
        // Determinism: repeating the exact sequence replays the bits.
        let (c1, c2, rc) = run((&pa, 1), (&pb, 9));
        assert!(c1.bit_identical(&a1) && c2.bit_identical(&a2));
        assert!(rc.bit_identical(&ra));
        // The high-priority program must not finish later than it would
        // have under plain FIFO in the same call order.
        let mut fifo = CosimSession::new(&f);
        fifo.admit_at(&pa, 0).unwrap();
        let hb = fifo.admit_at(&pb, 0).unwrap();
        let fifo_rep = fifo.report().unwrap();
        assert!(a2.finished_at <= fifo_rep.programs[hb.index()].finished_at);
    }

    /// Deadline policy: earliest deadline is served first regardless of
    /// admission sequence; determinism pinned by replay.
    #[test]
    fn deadline_policy_orders_by_deadline() {
        let f = fabric();
        let pa = program(&f, 13);
        let pb = program(&f, 14);
        let run = |d1: Cycle, d2: Cycle| {
            let mut s = CosimSession::new(&f);
            s.set_policy(AdmitPolicy::Deadline).unwrap();
            let h1 = s
                .admit_with(&pa, 0, AdmitMeta { deadline: d1, ..Default::default() })
                .unwrap();
            let h2 = s
                .admit_with(&pb, 0, AdmitMeta { deadline: d2, ..Default::default() })
                .unwrap();
            let rep = s.report().unwrap();
            (rep.programs[h1.index()].clone(), rep.programs[h2.index()].clone())
        };
        // pb has the earlier deadline even though admitted second.
        let (a_late, b_urgent) = run(1_000_000, 10);
        let (a_urgent, b_late) = run(10, 1_000_000);
        // The urgent program wins the shared resources in both runs.
        assert!(b_urgent.finished_at <= a_late.finished_at);
        assert!(a_urgent.finished_at <= b_late.finished_at);
        // Replay determinism.
        let (x, y) = run(1_000_000, 10);
        assert!(x.bit_identical(&a_late) && y.bit_identical(&b_urgent));
        // Incremental vs from-scratch under the policy.
        let mut inc = CosimSession::new(&f);
        inc.set_policy(AdmitPolicy::Deadline).unwrap();
        inc.admit_with(&pa, 0, AdmitMeta { deadline: 1_000_000, ..Default::default() }).unwrap();
        inc.run_to_drain().unwrap();
        inc.admit_with(&pb, 0, AdmitMeta { deadline: 10, ..Default::default() }).unwrap();
        let got = inc.report().unwrap();
        let mut fresh = CosimSession::new(&f);
        fresh.set_policy(AdmitPolicy::Deadline).unwrap();
        fresh
            .admit_with(&pa, 0, AdmitMeta { deadline: 1_000_000, ..Default::default() })
            .unwrap();
        fresh.admit_with(&pb, 0, AdmitMeta { deadline: 10, ..Default::default() }).unwrap();
        let want = fresh.report().unwrap();
        assert!(got.bit_identical(&want));
    }

    #[test]
    fn policy_change_rejected_after_first_admission() {
        let f = fabric();
        let mut s = CosimSession::new(&f);
        s.admit_at(&program(&f, 1), 0).unwrap();
        assert!(s.set_policy(AdmitPolicy::Priority).is_err());
    }

    /// The O(1) span cache must serve the same bits as a fresh fold (and
    /// as a fresh session), surviving an invalidate/re-drain cycle.
    #[test]
    fn span_cache_matches_fold_bitwise() {
        let f = fabric();
        let p1 = program(&f, 21);
        let p2 = program(&f, 22);
        let mut s = CosimSession::new(&f);
        let h1 = s.admit_at(&p1, 0).unwrap();
        let h2 = s.admit_at(&p2, 37).unwrap();
        s.run_to_drain().unwrap();
        let cached = s.span(h1);
        assert!(s.progs[h1.index()].span_cache.is_some(), "cache must be primed");
        let folded = CosimSession::fold_program(
            &s.progs[h1.index()],
            &mut Metrics::new(),
            None,
            None,
        );
        assert!(cached.bit_identical(&folded), "cache vs fold");
        // Invalidate drops the cache; settling rebuilds it with the same
        // bits (time-invariant model).
        s.invalidate(h1).unwrap();
        assert!(s.progs[h1.index()].span_cache.is_none(), "invalidate drops the cache");
        s.run_to_drain().unwrap();
        assert!(s.span(h1).bit_identical(&cached));
        assert!(s.span(h2).bit_identical(&s.report().unwrap().programs[h2.index()]));
    }

    /// Pruning is perf-only: the report after pruning is bit-identical
    /// to an unpruned session, queue footprint stays bounded, id ranges
    /// recycle, and the admission floor rejects time travel into pruned
    /// history.
    #[test]
    fn prune_bounds_queues_and_preserves_reports() {
        let f = fabric();
        let prog = program(&f, 31);
        let solo = cosim(&f, &prog).unwrap();
        let gap = solo.cycles + 50;
        let rounds = 12usize;
        // Unpruned baseline.
        let mut plain = CosimSession::new(&f);
        for k in 0..rounds {
            plain.admit_at(&prog, k as Cycle * gap).unwrap();
            plain.run_to_drain().unwrap();
        }
        let want = plain.report().unwrap();
        let (plain_longest, plain_ids) = plain.queue_footprint();
        // Pruned session: prune after every admission.
        let mut pruned = CosimSession::new(&f);
        let mut max_longest = 0usize;
        for k in 0..rounds {
            let at = k as Cycle * gap;
            pruned.admit_at(&prog, at).unwrap();
            pruned.run_to_drain().unwrap();
            pruned.prune_completed_before(at).unwrap();
            max_longest = max_longest.max(pruned.queue_footprint().0);
        }
        let got = pruned.report().unwrap();
        assert!(got.bit_identical(&want), "pruning changed the report");
        // Footprint: the unpruned queues grow ~linearly with history;
        // the pruned ones never hold more than ~2 programs' steps.
        assert!(plain_longest >= rounds, "baseline must actually grow");
        assert!(
            max_longest <= 2 * plain_longest / rounds + prog.steps.len(),
            "pruned queue footprint grew with history: {max_longest}"
        );
        // Id recycling keeps the table bounded well below the baseline.
        let (_, pruned_ids) = pruned.queue_footprint();
        assert!(pruned_ids < plain_ids, "{pruned_ids} vs {plain_ids}");
        // The floor froze pruned history.
        assert_eq!(pruned.admit_floor(), (rounds - 1) as Cycle * gap);
        assert!(pruned.admit_at(&prog, 0).is_err(), "admission below the floor");
        let early = ProgramHandle(0);
        assert!(pruned.invalidate(early).is_err(), "pruned program is frozen");
        // Spans of pruned programs are still served (from the cache).
        assert_eq!(got.programs[0].admitted_at, 0);
        assert!(pruned.span(early).bit_identical(&got.programs[0]));
    }

    #[test]
    fn discard_pruned_bounds_history_and_keeps_spans() {
        let f = fabric();
        let prog = program(&f, 31);
        let solo = cosim(&f, &prog).unwrap();
        let gap = solo.cycles + 50;
        let rounds = 12usize;
        let mut plain = CosimSession::new(&f);
        for k in 0..rounds {
            plain.admit_at(&prog, k as Cycle * gap).unwrap();
            plain.run_to_drain().unwrap();
        }
        let want = plain.report().unwrap();
        let per_prog = 2 * prog.steps.len(); // steps + recs
        assert_eq!(plain.history_footprint(), rounds * per_prog, "baseline grows with history");
        // Discarding session: prune + drop history after every round.
        let mut discard = CosimSession::new(&f);
        discard.set_discard_pruned(true);
        let mut max_hist = 0usize;
        for k in 0..rounds {
            let at = k as Cycle * gap;
            discard.admit_at(&prog, at).unwrap();
            discard.run_to_drain().unwrap();
            discard.prune_completed_before(at).unwrap();
            max_hist = max_hist.max(discard.history_footprint());
        }
        // Bounded: never more than ~2 live programs' history, however
        // long the run.
        assert!(max_hist <= 2 * per_prog, "history grew with the run: {max_hist}");
        // Spans of discarded programs are still exact (span cache).
        for k in 0..rounds {
            assert!(
                discard.span(ProgramHandle(k)).bit_identical(&want.programs[k]),
                "span {k} diverged after discard"
            );
        }
        // The merged report needs the per-step history and must say so.
        let err = discard.report().unwrap_err().to_string();
        assert!(err.contains("discarded"), "unhelpful error: {err}");
    }

    #[test]
    fn faulty_session_with_empty_plan_is_bitwise_noop() {
        let f = fabric();
        let p1 = program(&f, 1);
        let p2 = program(&f, 2);
        let mut plain = CosimSession::new(&f);
        plain.admit_at(&p1, 0).unwrap();
        plain.admit_at(&p2, 37).unwrap();
        let want = plain.report().unwrap();
        let cfg = FaultConfig::default();
        let mut faulty =
            FaultySession::with_plan(&f, FaultPlan::empty(), &cfg, RecoveryPolicy::Retry).unwrap();
        // An inert plan must not even wrap the cost model.
        assert!(Arc::ptr_eq(faulty.cost_model(), f.cost_model()));
        let h1 = faulty.admit_at(&p1, 0).unwrap();
        faulty.admit_at(&p2, 37).unwrap();
        let got = faulty.report().unwrap();
        assert!(got.bit_identical(&want), "empty plan changed the bits");
        let deg = faulty.degradation(&got);
        assert_eq!(
            (deg.programs, deg.completed, deg.faults_injected, deg.shed),
            (2, 2, 0, 0)
        );
        assert_eq!(deg.availability, 1.0);
        assert!(deg.mean_cycles_between_effective.is_infinite());
        assert!(!faulty.outcome(h1).retried);
    }

    /// A one-step program: a long matmul on `tile`, so fault timing is
    /// under test control (starts at admission, runs for its full
    /// duration).
    fn one_exec(tile: usize) -> FabricProgram {
        FabricProgram {
            steps: vec![Step::Exec {
                tile,
                node: 0,
                compute: crate::accel::Compute::MatMul { m: 64, k: 64, n: 64 },
                precision: Precision::Int8,
                deps: Vec::new(),
            }],
            producer: Vec::new(),
        }
    }

    fn transient(at: Cycle, tile: usize) -> crate::sim::FaultEvent {
        crate::sim::FaultEvent { at, kind: FaultKind::TileTransient { tile } }
    }

    #[test]
    fn transient_retries_with_exponential_backoff() {
        let f = fabric();
        let prog = one_exec(0);
        let base = cosim(&f, &prog).unwrap().cycles;
        assert!(base > 200, "test premise: the step is long ({base})");
        let cfg = FaultConfig::default(); // detect 16, retries 2, backoff 32
        let plan = FaultPlan::from_events(vec![transient(1, 0)]);
        let mut s = FaultySession::with_plan(&f, plan, &cfg, RecoveryPolicy::Retry).unwrap();
        let h = s.admit_at(&prog, 0).unwrap();
        let rep = s.report().unwrap();
        // Restart at fault(1) + detect(16) + backoff(32) = 49.
        assert_eq!(rep.programs[0].finished_at, 49 + base);
        let out = s.outcome(h);
        assert!(out.retried && !out.shed && !out.remapped);
        assert_eq!(out.attempts, 1);
        let deg = s.degradation(&rep);
        assert_eq!((deg.faults_effective, deg.faults_masked, deg.transient_retries), (1, 0, 1));
    }

    #[test]
    fn transient_storm_sheds_after_max_retries() {
        let f = fabric();
        let prog = one_exec(0);
        let base = cosim(&f, &prog).unwrap().cycles;
        assert!(base > 200);
        let cfg = FaultConfig::default();
        // Restarts land at 49 and then 60+16+64 = 140; each later fault
        // strikes the re-run strictly after its start.
        let plan =
            FaultPlan::from_events(vec![transient(1, 0), transient(60, 0), transient(150, 0)]);
        let mut s = FaultySession::with_plan(&f, plan, &cfg, RecoveryPolicy::Retry).unwrap();
        let h = s.admit_at(&prog, 0).unwrap();
        let rep = s.report().unwrap();
        let out = s.outcome(h);
        assert!(out.shed, "third strike exceeds max_retries = 2");
        assert_eq!(out.attempts, 3);
        // A shed program is an empty slot at its arrival: zero span.
        assert_eq!(s.span(h).makespan(), 0);
        let deg = s.degradation(&rep);
        assert_eq!((deg.programs, deg.completed, deg.shed), (1, 0, 1));
        assert_eq!(deg.transient_retries, 3);
        assert_eq!(deg.availability, 0.0);
    }

    #[test]
    fn remap_policy_moves_off_the_suspect_tile() {
        let f = fabric();
        let prog = one_exec(0);
        let base = cosim(&f, &prog).unwrap().cycles;
        let cfg = FaultConfig::default();
        let plan = FaultPlan::from_events(vec![transient(1, 0)]);
        let mut s = FaultySession::with_plan(&f, plan, &cfg, RecoveryPolicy::Remap).unwrap();
        let h = s.admit_at(&prog, 0).unwrap();
        let rep = s.report().unwrap();
        let out = s.outcome(h);
        assert!(out.retried && out.remapped && !out.shed);
        // Homogeneous npu fabric: same duration on the new tile.
        assert_eq!(rep.programs[0].finished_at, 49 + base);
        // The re-run landed on tile 1; the aborted attempt on the
        // suspect tile was retracted, so tile 0 folds no busy time.
        assert_eq!(rep.tile_busy[0], 0);
        assert_eq!(rep.tile_busy[1], base);
    }

    #[test]
    fn deadline_aware_sheds_when_restart_busts_the_deadline() {
        let f = fabric();
        let prog = one_exec(0);
        let cfg = FaultConfig::default();
        let plan = FaultPlan::from_events(vec![transient(1, 0)]);
        let mut s =
            FaultySession::with_plan(&f, plan, &cfg, RecoveryPolicy::DeadlineAware).unwrap();
        // Restart would land at 49 > deadline 40.
        let meta = AdmitMeta { deadline: 40, ..AdmitMeta::default() };
        let h = s.admit_with(&prog, 0, meta).unwrap();
        let rep = s.report().unwrap();
        assert!(s.outcome(h).shed);
        let deg = s.degradation(&rep);
        assert_eq!((deg.shed, deg.deadline_violated), (1, 1));
    }

    #[test]
    fn tile_death_remaps_and_incremental_matches_from_scratch() {
        let f = fabric();
        let p1 = program(&f, 1);
        let p2 = program(&f, 2);
        let solo = cosim(&f, &p1).unwrap();
        let mid = solo.cycles / 2;
        // Kill the tile running p1's final layer: its work is certainly
        // still uncompleted halfway through the episode.
        let victim = p1
            .steps
            .iter()
            .rev()
            .find_map(|s| match s {
                Step::Exec { tile, .. } => Some(*tile),
                _ => None,
            })
            .unwrap();
        let plan = FaultPlan::from_events(vec![crate::sim::FaultEvent {
            at: mid,
            kind: FaultKind::TileDeath { tile: victim },
        }]);
        let cfg = FaultConfig::default();
        let late = mid + 1_000;
        // From-scratch oracle: both programs admitted up front.
        let mut oracle =
            FaultySession::with_plan(&f, plan.clone(), &cfg, RecoveryPolicy::Retry).unwrap();
        oracle.admit_at(&p1, 0).unwrap();
        oracle.admit_at(&p2, late).unwrap();
        let want = oracle.report().unwrap();
        let want_deg = oracle.degradation(&want);
        // Incremental: drain past the death, then admit the second
        // program (its processed-death replay must re-map it too).
        let mut inc = FaultySession::with_plan(&f, plan, &cfg, RecoveryPolicy::Retry).unwrap();
        let h1 = inc.admit_at(&p1, 0).unwrap();
        inc.run_until(mid + 1).unwrap();
        assert_eq!(inc.fault_floor(), mid);
        let h2 = inc.admit_at(&p2, late).unwrap();
        let got = inc.report().unwrap();
        let got_deg = inc.degradation(&got);
        assert!(got.bit_identical(&want), "incremental diverged from the oracle");
        assert_eq!(got_deg, want_deg);
        // The death really afflicted the in-flight program.
        assert!(inc.outcome(h1).remapped);
        assert!(!inc.outcome(h1).shed && !inc.outcome(h2).shed);
        assert_eq!(got_deg.availability, 1.0);
        // No finished work sits on the dead tile after the death: its
        // busy time is strictly less than a fault-free run's.
        let free = {
            let mut s = CosimSession::new(&f);
            s.admit_at(&p1, 0).unwrap();
            s.admit_at(&p2, late).unwrap();
            s.report().unwrap()
        };
        assert!(got.tile_busy[victim] < free.tile_busy[victim]);
    }

    #[test]
    fn admissions_below_the_fault_floor_are_rejected() {
        let f = fabric();
        let prog = one_exec(0);
        let cfg = FaultConfig::default();
        let plan = FaultPlan::from_events(vec![crate::sim::FaultEvent {
            at: 100,
            kind: FaultKind::TileDeath { tile: 3 },
        }]);
        let mut s = FaultySession::with_plan(&f, plan, &cfg, RecoveryPolicy::Retry).unwrap();
        s.admit_at(&prog, 0).unwrap();
        s.run_to_drain().unwrap();
        assert_eq!(s.faults_processed(), 1);
        let err = s.admit_at(&prog, 50).unwrap_err().to_string();
        assert!(err.contains("already-processed"), "unhelpful error: {err}");
        // At the floor itself is fine.
        s.admit_at(&prog, 100).unwrap();
        s.run_to_drain().unwrap();
    }

    /// Shard-parallel drains must replay the sequential engine's bits at
    /// every thread count, including the forced single-shard staged path
    /// (time-invariant model; the time-varying twin is below).
    #[test]
    fn parallel_drain_matches_sequential_bits() {
        let f = fabric();
        let progs: Vec<_> = (0..4).map(|k| program(&f, 40 + k)).collect();
        let times: [Cycle; 4] = [0, 150, 300, 450];
        let run = |threads: usize, shards: Option<&[usize]>| {
            let mut s = CosimSession::new(&f);
            s.set_threads(threads);
            if let Some(b) = shards {
                s.set_shards(Some(b)).unwrap();
            }
            for (p, &t) in progs.iter().zip(&times) {
                s.admit_at(p, t).unwrap();
            }
            s.run_to_drain().unwrap();
            s.report().unwrap()
        };
        let want = run(1, None);
        for threads in [2, 4, 8] {
            let got = run(threads, None);
            assert!(got.bit_identical(&want), "threads = {threads}");
        }
        // threads = 1 with an explicit partition forces the
        // stage/price/merge structure itself through the golden.
        let got = run(1, Some(&[0, 1]));
        assert!(got.bit_identical(&want), "forced single-shard staged path");
    }

    /// The time-varying twin: staggered + retroactive admissions under a
    /// congestion/DVFS model, so parallel drains run inside settle
    /// fixed-point passes and across epoch-crossing batches too.
    #[test]
    fn parallel_drain_matches_sequential_time_varying() {
        use crate::fabric::{CongestionKnobs, DvfsKnobs, VaryingCost};
        let f = fabric();
        let cong = CongestionKnobs { alpha: 0.5, cap: 4.0 };
        let dvfs = DvfsKnobs {
            window: 3,
            warm_frac: 0.4,
            hot_frac: 0.8,
            warm_scale: 0.75,
            hot_scale: 0.5,
        };
        let progs: Vec<_> = (0..4).map(|k| program(&f, 50 + k)).collect();
        let run = |threads: usize| {
            let model: Arc<dyn CostModel> =
                Arc::new(VaryingCost::congestion_dvfs(256, cong, dvfs));
            let mut s = CosimSession::with_model(&f, model);
            s.set_threads(threads);
            s.admit_at(&progs[0], 0).unwrap();
            s.run_until(200).unwrap();
            for (k, p) in progs.iter().enumerate().skip(1) {
                // k = 1 lands at 120 < 200: a retroactive admission, so
                // horizon invalidation and settle run under the parallel
                // drain as well.
                s.admit_at(p, 120 * k as Cycle).unwrap();
            }
            s.run_to_drain().unwrap();
            s.report().unwrap()
        };
        let want = run(1);
        for threads in [2, 4, 8] {
            let got = run(threads);
            assert!(got.bit_identical(&want), "threads = {threads}");
        }
    }

    /// PR 5 follow-up (h): the start-ordered index must produce exactly
    /// the seed set of the retired O(world) scan at every horizon, under
    /// live sessions that admit, partially run, prune and re-admit
    /// (recycled-id aliasing included).
    #[test]
    fn prop_horizon_seed_index_matches_scan() {
        use crate::fabric::{CongestionKnobs, DvfsKnobs, VaryingCost};
        let f = fabric();
        let cong = CongestionKnobs { alpha: 0.5, cap: 4.0 };
        let dvfs = DvfsKnobs {
            window: 3,
            warm_frac: 0.4,
            hot_frac: 0.8,
            warm_scale: 0.75,
            hot_scale: 0.5,
        };
        crate::testutil::prop::check(10, |rng| {
            let model: Arc<dyn CostModel> =
                Arc::new(VaryingCost::congestion_dvfs(256, cong, dvfs));
            let mut s = CosimSession::with_model(&f, model);
            let n = 3 + rng.below(3);
            let mut last: Cycle = 0;
            for k in 0..n {
                let at = last + rng.below(400) as Cycle;
                last = at;
                s.admit_at(&program(&f, 60 + k as u64), at).unwrap();
                if rng.below(2) == 0 {
                    s.run_until(at + rng.below(500) as Cycle).unwrap();
                }
            }
            s.run_to_drain().unwrap();
            if rng.below(2) == 0 {
                // Prune strictly below the last admission, then admit one
                // more program so its steps recycle pruned global ids —
                // the aliasing case the index's prune hook guards.
                s.prune_completed_before(last / 2).unwrap();
                s.admit_at(&program(&f, 99), last + 100).unwrap();
                s.run_to_drain().unwrap();
            }
            for _ in 0..8 {
                let from = rng.below(6000) as Cycle;
                let skip = if rng.below(2) == 0 { usize::MAX } else { rng.below(n) };
                let (mut a, mut b) = (Vec::new(), Vec::new());
                s.collect_horizon_seeds(from, skip, &mut a);
                s.collect_horizon_seeds_scan(from, skip, &mut b);
                a.sort_unstable();
                b.sort_unstable();
                prop_assert!(
                    a == b,
                    "seed sets diverge at from = {from}, skip = {skip}: {a:?} vs {b:?}"
                );
            }
            Ok(())
        });
    }
    /// The `dse::sweep` primitive: swapping the cost model in place must
    /// be observationally identical to a fresh session under the new
    /// model with the same admissions — bit for bit, across
    /// time-dependence changes in both directions and repeated swaps.
    #[test]
    fn set_model_matches_fresh_session_bitwise() {
        use crate::fabric::{CongestionKnobs, DvfsKnobs, InvariantCost, VaryingCost};
        let f = fabric();
        let cong = CongestionKnobs { alpha: 0.5, cap: 4.0 };
        let dvfs = DvfsKnobs {
            window: 3,
            warm_frac: 0.4,
            hot_frac: 0.8,
            warm_scale: 0.75,
            hot_scale: 0.5,
        };
        let invariant = || -> Arc<dyn CostModel> { Arc::new(InvariantCost) };
        let varying = || -> Arc<dyn CostModel> {
            Arc::new(VaryingCost::congestion_dvfs(256, cong, dvfs))
        };
        let progs: Vec<_> = (0..3).map(|k| program(&f, 70 + k)).collect();
        let admit_all = |s: &mut CosimSession| {
            for (k, p) in progs.iter().enumerate() {
                s.admit_at(p, 300 * k as Cycle).unwrap();
            }
        };
        // Invariant -> varying -> invariant: each hop checked against a
        // from-scratch oracle under the then-current model.
        let mut s = CosimSession::with_model(&f, invariant());
        admit_all(&mut s);
        s.run_to_drain().unwrap();
        for (hop, model) in
            [(1, varying()), (2, invariant()), (3, varying())]
        {
            s.set_model(model.clone()).unwrap();
            let got = s.report().unwrap();
            let mut fresh = CosimSession::with_model(&f, model);
            admit_all(&mut fresh);
            let want = fresh.report().unwrap();
            assert!(got.bit_identical(&want), "hop {hop} diverged from fresh session");
        }
    }

    /// `set_model` on a partially drained session (events in flight),
    /// followed by further admissions, still converges to the fresh
    /// oracle — the swap must cancel in-flight completions cleanly.
    #[test]
    fn set_model_mid_flight_then_admit_matches_fresh() {
        use crate::fabric::{CongestionKnobs, DvfsKnobs, VaryingCost};
        let f = fabric();
        let cong = CongestionKnobs { alpha: 0.5, cap: 4.0 };
        let dvfs = DvfsKnobs {
            window: 3,
            warm_frac: 0.4,
            hot_frac: 0.8,
            warm_scale: 0.75,
            hot_scale: 0.5,
        };
        let varying: Arc<dyn CostModel> =
            Arc::new(VaryingCost::congestion_dvfs(256, cong, dvfs));
        let p1 = program(&f, 80);
        let p2 = program(&f, 81);
        let full = {
            let mut s2 = CosimSession::new(&f);
            s2.admit_at(&p1, 0).unwrap();
            s2.report().unwrap().cycles
        };
        let mut s = CosimSession::new(&f);
        s.admit_at(&p1, 0).unwrap();
        s.run_until(full / 2).unwrap();
        assert!(!s.is_quiescent(), "steps must still be in flight");
        s.set_model(varying.clone()).unwrap();
        s.admit_at(&p2, 400).unwrap();
        let got = s.report().unwrap();
        let mut fresh = CosimSession::with_model(&f, varying);
        fresh.admit_at(&p1, 0).unwrap();
        fresh.admit_at(&p2, 400).unwrap();
        let want = fresh.report().unwrap();
        assert!(got.bit_identical(&want));
    }

    #[test]
    fn set_model_rejected_after_prune() {
        use crate::fabric::InvariantCost;
        let f = fabric();
        let mut s = CosimSession::new(&f);
        s.admit_at(&program(&f, 82), 0).unwrap();
        let end = s.report().unwrap().cycles;
        s.admit_at(&program(&f, 83), end + 1000).unwrap();
        s.run_to_drain().unwrap();
        assert!(s.prune_completed_before(end + 500).unwrap() > 0);
        let err = s.set_model(Arc::new(InvariantCost)).unwrap_err().to_string();
        assert!(err.contains("pruned"), "error must explain the rejection: {err}");
    }

    /// ROADMAP follow-up (l): after a drain the default fences follow
    /// the accumulated pricing mass (hot resources get isolated) while
    /// staying a valid partition — and, per the shard contract, the
    /// parallel results stay bit-identical to sequential under them.
    #[test]
    fn load_aware_fences_partition_by_mass_and_preserve_results() {
        let f = fabric();
        let progs: Vec<_> = (0..4).map(|k| program(&f, 90 + k)).collect();
        let run = |threads: usize| {
            let mut s = CosimSession::new(&f);
            s.set_threads(threads);
            for (k, p) in progs.iter().enumerate() {
                s.admit_at(p, 200 * k as Cycle).unwrap();
            }
            s.run_to_drain().unwrap();
            let rep = s.report().unwrap();
            (rep, s.current_fences_for_test())
        };
        let (want, _) = run(1);
        for threads in [2, 4, 8] {
            let (got, fences) = run(threads);
            assert!(got.bit_identical(&want), "threads = {threads}");
            let n = fences.last().copied().unwrap();
            assert_eq!(fences[0], 0);
            assert!(fences.windows(2).all(|w| w[0] < w[1]), "{fences:?}");
            assert_eq!(fences.len() - 1, threads.min(n));
        }
        // Wiring check: after a full drain the default fences are exactly
        // the load-aware partition of the accumulated per-resource mass
        // (not the old uniform count split), and mass was accumulated.
        let mut s = CosimSession::new(&f);
        s.set_threads(4);
        for (k, p) in progs.iter().enumerate() {
            s.admit_at(p, 200 * k as Cycle).unwrap();
        }
        s.run_to_drain().unwrap();
        let loads = s.resource_loads_for_test();
        assert!(loads.iter().sum::<u64>() > 0, "a drained session must carry mass");
        let fences = s.current_fences_for_test();
        assert_eq!(fences, crate::sim::pool::load_fences(&loads, 4));
    }
}
