//! Batch inference server: the leader/worker orchestration half of the
//! coordinator.
//!
//! Worker threads submit single-sample requests over an mpsc channel; the
//! leader drains the queue, forms batches up to `max_batch`, executes the
//! batch through a user-supplied executor (the PJRT artifact in
//! production; a closure in tests), and answers each request on its own
//! reply channel. This is the standard dynamic-batching loop of a serving
//! runtime, sized for the edge-fabric use case.
//!
//! No tokio in the offline image — std::thread + mpsc (DESIGN.md §6).
//!
//! **Simulated-latency serving mode**: [`BatchServer::run_cosim`] pairs
//! the functional executor with a [`CosimExecutor`] — a live
//! [`CosimSession`] that admits one lowered program per formed batch into
//! the shared calendar at its simulated arrival cycle and reports the
//! batch's fabric makespan. The wall-clock latencies answer "how fast is
//! this host"; the simulated cycles answer "how fast would the fabric
//! serve this stream", including cross-batch queueing on shared
//! tiles/HBM/links.
//!
//! **Degraded serving mode**: [`BatchServer::run_degraded`] swaps the
//! timing executor for a [`DegradedExecutor`] — a `FaultySession` under
//! a seeded fault plan — so the same stream is priced on a fabric that
//! glitches, loses tiles and browns out mid-episode. Per-batch
//! [`RequestOutcome`]s (retries, backoff-delayed restarts, re-maps,
//! sheds) and the episode's [`DegradationReport`] quantify how
//! gracefully the configured [`RecoveryPolicy`] degrades.
//!
//! **Open-loop latency accounting**: two clocks, kept separate. The
//! wall-clock side ([`BatchStats::latencies_us`], measured from
//! `Request::submitted` `Instant`s) times the *host* loop; the simulated
//! side times the *fabric*. For the fabric, queueing delay must be
//! measured from the request's **simulated arrival cycle**, not from the
//! wall-clock instant it crossed the channel: both executors expose
//! `execute_batch_open_loop`, which returns the batch's simulated
//! sojourn (completion − open-loop arrival, so a fault-floor bump or an
//! overload backlog shows up as queueing delay; `None` for a shed
//! batch), recorded in [`BatchStats::sim_sojourn_cycles`] with
//! p50/p99/p999 accessors. Sharded steady-state serving — N replicated
//! sessions behind a deterministic request router driven by
//! [`crate::sim::ArrivalGen`] open-loop arrival processes — lives one
//! module over in [`super::shard`], which documents the serving
//! determinism contract (hash routing, canonical merge order, replay
//! guarantee).

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use anyhow::ensure;

use super::admit::{
    CosimSession, DegradationReport, FaultySession, ProgramHandle, RecoveryPolicy, RequestOutcome,
};
use super::exec::ExecReport;
use crate::compiler::FabricProgram;
use crate::fabric::{CostModel, Fabric};
use crate::runtime::Tensor;
use crate::sim::{Cycle, FaultConfig};
use crate::Result;

/// One inference request: a single sample (row-major f32) plus the reply
/// channel.
pub struct Request {
    pub sample: Vec<f32>,
    pub reply: mpsc::Sender<Vec<f32>>,
    pub submitted: Instant,
}

/// Serving statistics.
#[derive(Debug, Clone, Default)]
pub struct BatchStats {
    pub requests: usize,
    pub batches: usize,
    /// Distribution of batch sizes formed.
    pub batch_sizes: Vec<usize>,
    /// Per-request latency, microseconds.
    pub latencies_us: Vec<f64>,
    /// Per-batch simulated fabric makespan, cycles (populated by
    /// [`BatchServer::run_cosim`]; empty in plain wall-clock mode).
    pub sim_cycles: Vec<Cycle>,
    /// Per-batch simulated sojourn, cycles: completion − open-loop
    /// arrival, so simulated queueing delay (fault-floor bumps, overload
    /// backlog) is included — unlike [`BatchStats::sim_cycles`], which
    /// starts the clock at admission. Shed batches are excluded (they
    /// never complete). Populated by the simulated-latency serving
    /// modes; empty in plain wall-clock mode.
    pub sim_sojourn_cycles: Vec<Cycle>,
}

impl BatchStats {
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batch_sizes.iter().sum::<usize>() as f64 / self.batches as f64
        }
    }

    pub fn p50_latency_us(&self) -> f64 {
        percentile(&self.latencies_us, 0.50)
    }

    pub fn p99_latency_us(&self) -> f64 {
        percentile(&self.latencies_us, 0.99)
    }

    pub fn p999_latency_us(&self) -> f64 {
        percentile(&self.latencies_us, 0.999)
    }

    pub fn throughput_rps(&self, wall_s: f64) -> f64 {
        if wall_s <= 0.0 {
            0.0
        } else {
            self.requests as f64 / wall_s
        }
    }

    /// Mean simulated batch makespan in fabric cycles (0 outside the
    /// simulated-latency serving mode).
    pub fn mean_sim_cycles(&self) -> f64 {
        if self.sim_cycles.is_empty() {
            0.0
        } else {
            self.sim_cycles.iter().sum::<Cycle>() as f64 / self.sim_cycles.len() as f64
        }
    }

    /// p99 simulated batch makespan in fabric cycles.
    pub fn p99_sim_cycles(&self) -> f64 {
        let v: Vec<f64> = self.sim_cycles.iter().map(|&c| c as f64).collect();
        percentile(&v, 0.99)
    }

    /// Simulated sojourn percentile in fabric cycles (arrival-anchored;
    /// see [`BatchStats::sim_sojourn_cycles`]).
    pub fn sim_sojourn_percentile(&self, q: f64) -> f64 {
        let v: Vec<f64> = self.sim_sojourn_cycles.iter().map(|&c| c as f64).collect();
        percentile(&v, q)
    }

    pub fn p50_sim_sojourn_cycles(&self) -> f64 {
        self.sim_sojourn_percentile(0.50)
    }

    pub fn p99_sim_sojourn_cycles(&self) -> f64 {
        self.sim_sojourn_percentile(0.99)
    }

    pub fn p999_sim_sojourn_cycles(&self) -> f64 {
        self.sim_sojourn_percentile(0.999)
    }
}

pub(crate) fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    // total_cmp: a NaN latency (clock skew pathologies) must not panic
    // the serving report path (same fix as Metrics::breakdown).
    v.sort_by(f64::total_cmp);
    v[((v.len() - 1) as f64 * q).round() as usize]
}

/// Simulated-latency executor for the batch server: a live
/// [`CosimSession`] admitting one lowered batch-program per formed batch
/// at its simulated arrival cycle (`gap` cycles apart), so consecutive
/// batches queue on the shared tiles/HBM/links exactly as an on-fabric
/// serving loop would. The calendar persists across batches — each
/// admission re-simulates only the new program (tail admission into a
/// quiescent calendar), not the world.
pub struct CosimExecutor<'f> {
    session: CosimSession<'f>,
    prog: FabricProgram,
    /// Simulated cycles between consecutive batch admissions.
    gap: Cycle,
    next_at: Cycle,
}

impl<'f> CosimExecutor<'f> {
    /// `prog` is the lowered program of one full batch; `gap` the
    /// simulated inter-batch arrival distance in fabric cycles. Prices
    /// through the fabric's configured `[fabric.cost]` model.
    pub fn new(fabric: &'f Fabric, prog: FabricProgram, gap: Cycle) -> Self {
        CosimExecutor { session: CosimSession::new(fabric), prog, gap, next_at: 0 }
    }

    /// Like [`CosimExecutor::new`] but pricing through an explicit cost
    /// model — e.g. a congestion/DVFS [`crate::fabric::VaryingCost`], so
    /// the serving loop prices load-dependent latency honestly.
    pub fn with_model(
        fabric: &'f Fabric,
        prog: FabricProgram,
        gap: Cycle,
        model: Arc<dyn CostModel>,
    ) -> Self {
        CosimExecutor { session: CosimSession::with_model(fabric, model), prog, gap, next_at: 0 }
    }

    /// The cost model this executor's session prices through.
    pub fn cost_model(&self) -> &Arc<dyn CostModel> {
        self.session.cost_model()
    }

    /// Worker threads for the session's shard-parallel calendar drains
    /// (default: the fabric's `[session] threads`; 1 = sequential).
    /// Reports are bit-identical at every thread count — see the
    /// determinism contract in [`CosimSession`]'s module docs.
    pub fn set_threads(&mut self, threads: usize) {
        self.session.set_threads(threads);
    }

    /// Admit the next batch at its arrival cycle, simulate to
    /// quiescence, and return the batch's simulated makespan
    /// (admission-to-completion, queueing included).
    pub fn execute_batch(&mut self) -> Result<Cycle> {
        self.execute_batch_open_loop().map(|(m, _)| m)
    }

    /// Like [`CosimExecutor::execute_batch`], additionally returning the
    /// batch's simulated *sojourn* — completion − open-loop arrival.
    /// A plain session admits exactly at the arrival cycle, so sojourn
    /// equals makespan here; the distinction matters for
    /// [`DegradedExecutor::execute_batch_open_loop`], where the
    /// admission clock can be bumped past the arrival.
    pub fn execute_batch_open_loop(&mut self) -> Result<(Cycle, Option<Cycle>)> {
        let h = self.session.admit_at(&self.prog, self.next_at)?;
        self.next_at += self.gap;
        self.session.run_to_drain()?;
        let makespan = self.session.span(h).makespan();
        Ok((makespan, Some(makespan)))
    }

    /// The underlying session (e.g. for a merged
    /// [`super::exec::ExecReport`] via [`CosimSession::report`]).
    pub fn session_mut(&mut self) -> &mut CosimSession<'f> {
        &mut self.session
    }
}

/// Fault-aware timing executor: like [`CosimExecutor`], but batches are
/// admitted into a [`FaultySession`], so the seeded fault plan afflicts
/// the serving timeline and the [`RecoveryPolicy`] (retry with
/// exponential backoff, re-map off dead silicon, shed) shapes every
/// batch's simulated latency. A shed batch reports a zero makespan —
/// the simulated fabric dropped it (the functional executor, being
/// fault-oblivious, still answers the requests).
pub struct DegradedExecutor<'f> {
    session: FaultySession<'f>,
    prog: FabricProgram,
    /// Simulated cycles between consecutive batch admissions.
    gap: Cycle,
    next_at: Cycle,
    handles: Vec<ProgramHandle>,
    /// Actual admission cycle of each batch (arrival after any
    /// fault-floor bump), in batch order.
    admissions: Vec<Cycle>,
}

impl<'f> DegradedExecutor<'f> {
    /// Generate the fault plan from `cfg` and price through the
    /// fabric's configured cost model (wrapped for degraded pricing when
    /// the plan needs it).
    pub fn new(
        fabric: &'f Fabric,
        prog: FabricProgram,
        gap: Cycle,
        cfg: &FaultConfig,
        policy: RecoveryPolicy,
    ) -> Result<Self> {
        Ok(DegradedExecutor {
            session: FaultySession::new(fabric, cfg, policy)?,
            prog,
            gap,
            next_at: 0,
            handles: Vec::new(),
            admissions: Vec::new(),
        })
    }

    /// Wrap an explicitly-built session (recorded plan, explicit base
    /// model, pre-set admission policy).
    pub fn with_session(session: FaultySession<'f>, prog: FabricProgram, gap: Cycle) -> Self {
        DegradedExecutor {
            session,
            prog,
            gap,
            next_at: 0,
            handles: Vec::new(),
            admissions: Vec::new(),
        }
    }

    /// Worker threads for the inner session's shard-parallel calendar
    /// drains (1 = sequential; bit-identical at every thread count).
    pub fn set_threads(&mut self, threads: usize) {
        self.session.set_threads(threads);
    }

    /// Admit the next batch, simulate to quiescence (applying due fault
    /// events), and return the batch's simulated makespan. An arrival
    /// that would land before the fault floor (work backlogged across a
    /// processed fault) is bumped to the floor — the serving clock
    /// cannot admit into frozen fault history.
    pub fn execute_batch(&mut self) -> Result<Cycle> {
        self.execute_batch_open_loop().map(|(m, _)| m)
    }

    /// Like [`DegradedExecutor::execute_batch`], additionally returning
    /// the batch's simulated sojourn measured from its *pre-bump*
    /// open-loop arrival: a fault-floor bump is queueing delay the
    /// request experienced, so it belongs in the latency percentiles
    /// even though the makespan clock only starts at admission. A shed
    /// batch never completes — its sojourn is `None`, not zero (a zero
    /// would deflate the percentiles exactly when the fabric is at its
    /// worst).
    pub fn execute_batch_open_loop(&mut self) -> Result<(Cycle, Option<Cycle>)> {
        let arrival = self.next_at;
        let at = arrival.max(self.session.fault_floor());
        self.next_at = at + self.gap;
        let h = self.session.admit_at(&self.prog, at)?;
        self.handles.push(h);
        self.admissions.push(at);
        self.session.run_to_drain()?;
        let span = self.session.span(h);
        let sojourn = if self.session.outcome(h).shed {
            None
        } else {
            Some(span.finished_at - arrival)
        };
        Ok((span.makespan(), sojourn))
    }

    /// Actual admission cycles in batch order — the open-loop arrival
    /// trace that replays this closed-loop episode exactly (feeding it
    /// to a 1-shard [`super::shard::ShardedServer`] makes every
    /// fault-floor bump a no-op; `tests/serve_golden.rs` builds its
    /// degraded differential on this).
    pub fn admissions(&self) -> &[Cycle] {
        &self.admissions
    }

    /// Recovery outcome of batch `i` (admission order).
    pub fn outcome(&self, i: usize) -> Option<RequestOutcome> {
        self.handles.get(i).map(|&h| self.session.outcome(h))
    }

    /// Per-batch recovery outcomes in admission order.
    pub fn outcomes(&self) -> Vec<RequestOutcome> {
        self.handles.iter().map(|&h| self.session.outcome(h)).collect()
    }

    /// Merged execution report plus the episode's degradation telemetry.
    pub fn report_degraded(&mut self) -> Result<(ExecReport, DegradationReport)> {
        let exec = self.session.report()?;
        let deg = self.session.degradation(&exec);
        Ok((exec, deg))
    }

    pub fn session(&self) -> &FaultySession<'f> {
        &self.session
    }

    pub fn session_mut(&mut self) -> &mut FaultySession<'f> {
        &mut self.session
    }
}

/// The dynamic batcher. `exec(batch_rows) -> output_rows` runs a full
/// batch; the server pads the final partial batch with zero rows (the
/// AOT artifacts have a fixed batch dimension).
pub struct BatchServer {
    sample_len: usize,
    output_len: usize,
    max_batch: usize,
}

impl BatchServer {
    pub fn new(sample_len: usize, output_len: usize, max_batch: usize) -> Self {
        assert!(max_batch > 0);
        BatchServer { sample_len, output_len, max_batch }
    }

    /// Serve until the request channel closes. Returns stats.
    pub fn run(
        &self,
        rx: mpsc::Receiver<Request>,
        exec: impl FnMut(&Tensor) -> Result<Tensor>,
    ) -> Result<BatchStats> {
        self.run_inner(rx, exec, |_| Ok(None))
    }

    /// Serve like [`BatchServer::run`], additionally driving the co-sim
    /// session as the timing executor: every formed batch is admitted to
    /// `sim`'s shared calendar, its simulated makespan recorded in
    /// [`BatchStats::sim_cycles`] and its arrival-anchored sojourn in
    /// [`BatchStats::sim_sojourn_cycles`].
    pub fn run_cosim(
        &self,
        rx: mpsc::Receiver<Request>,
        exec: impl FnMut(&Tensor) -> Result<Tensor>,
        sim: &mut CosimExecutor,
    ) -> Result<BatchStats> {
        self.run_inner(rx, exec, |_| sim.execute_batch_open_loop().map(Some))
    }

    /// Serve like [`BatchServer::run_cosim`], but through the
    /// fault-injected timing executor. Shed batches record a zero
    /// simulated makespan in [`BatchStats::sim_cycles`] and no sojourn
    /// (see [`DegradedExecutor::execute_batch_open_loop`]); query the
    /// executor's [`DegradedExecutor::outcomes`] and
    /// [`DegradedExecutor::report_degraded`] afterwards for the
    /// recovery telemetry.
    pub fn run_degraded(
        &self,
        rx: mpsc::Receiver<Request>,
        exec: impl FnMut(&Tensor) -> Result<Tensor>,
        sim: &mut DegradedExecutor,
    ) -> Result<BatchStats> {
        self.run_inner(rx, exec, |_| sim.execute_batch_open_loop().map(Some))
    }

    fn run_inner(
        &self,
        rx: mpsc::Receiver<Request>,
        mut exec: impl FnMut(&Tensor) -> Result<Tensor>,
        mut on_batch: impl FnMut(usize) -> Result<Option<(Cycle, Option<Cycle>)>>,
    ) -> Result<BatchStats> {
        let mut stats = BatchStats::default();
        let mut pending: Vec<Request> = Vec::new();
        loop {
            // Block for the first request, then drain whatever is queued
            // (batching window = "everything available now").
            if pending.is_empty() {
                match rx.recv() {
                    Ok(r) => pending.push(r),
                    Err(_) => break, // all senders dropped
                }
            }
            while pending.len() < self.max_batch {
                match rx.try_recv() {
                    Ok(r) => pending.push(r),
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => break,
                }
            }
            let take = pending.len().min(self.max_batch);
            let batch: Vec<Request> = pending.drain(..take).collect();
            // Assemble the padded batch tensor.
            let mut data = vec![0.0f32; self.max_batch * self.sample_len];
            for (i, r) in batch.iter().enumerate() {
                ensure!(r.sample.len() == self.sample_len, "bad sample length");
                data[i * self.sample_len..(i + 1) * self.sample_len]
                    .copy_from_slice(&r.sample);
            }
            let input = Tensor::new(vec![self.max_batch, self.sample_len], data)?;
            let out = exec(&input)?;
            ensure!(
                out.len() >= batch.len() * self.output_len,
                "executor output too small"
            );
            let now = Instant::now();
            for (i, r) in batch.iter().enumerate() {
                let row =
                    out.data()[i * self.output_len..(i + 1) * self.output_len].to_vec();
                let _ = r.reply.send(row); // receiver may have given up
                stats
                    .latencies_us
                    .push(now.duration_since(r.submitted).as_secs_f64() * 1e6);
            }
            stats.requests += batch.len();
            stats.batches += 1;
            stats.batch_sizes.push(batch.len());
            if let Some((cycles, sojourn)) = on_batch(batch.len())? {
                stats.sim_cycles.push(cycles);
                if let Some(s) = sojourn {
                    stats.sim_sojourn_cycles.push(s);
                }
            }
        }
        Ok(stats)
    }
}

/// Convenience: spawn `clients` worker threads that each submit `per`
/// requests built by `make_sample(client, idx)`, run the server on the
/// current thread, and return (stats, outputs sorted by client).
pub fn drive_server(
    server: &BatchServer,
    clients: usize,
    per: usize,
    make_sample: impl Fn(usize, usize) -> Vec<f32> + Send + Sync + 'static + Clone,
    exec: impl FnMut(&Tensor) -> Result<Tensor>,
) -> Result<(BatchStats, Vec<Vec<f32>>)> {
    let (tx, rx) = mpsc::channel::<Request>();
    let mut joins = Vec::new();
    let (otx, orx) = mpsc::channel::<(usize, usize, Vec<f32>)>();
    for c in 0..clients {
        let tx = tx.clone();
        let otx = otx.clone();
        let make = make_sample.clone();
        joins.push(std::thread::spawn(move || {
            for i in 0..per {
                let (rtx, rrx) = mpsc::channel();
                tx.send(Request {
                    sample: make(c, i),
                    reply: rtx,
                    submitted: Instant::now(),
                })
                .expect("server alive");
                let out = rrx.recv().expect("reply");
                otx.send((c, i, out)).unwrap();
            }
        }));
    }
    drop(tx);
    drop(otx);
    let stats = server.run(rx, exec)?;
    for j in joins {
        j.join().expect("client thread");
    }
    let mut outs: Vec<(usize, usize, Vec<f32>)> = orx.iter().collect();
    outs.sort_by_key(|&(c, i, _)| (c, i));
    Ok((stats, outs.into_iter().map(|(_, _, o)| o).collect()))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Mock executor: out_row = 2 * first element of in_row, output_len 1.
    fn double_exec(input: &Tensor) -> Result<Tensor> {
        let b = input.dims()[0];
        let s = input.dims()[1];
        let out: Vec<f32> = (0..b).map(|i| input.data()[i * s] * 2.0).collect();
        Tensor::new(vec![b, 1], out)
    }

    #[test]
    fn all_requests_answered_correctly() {
        let server = BatchServer::new(4, 1, 8);
        let (stats, outs) = drive_server(
            &server,
            3,
            10,
            |c, i| vec![(c * 100 + i) as f32, 0.0, 0.0, 0.0],
            double_exec,
        )
        .unwrap();
        assert_eq!(stats.requests, 30);
        assert_eq!(outs.len(), 30);
        for (idx, o) in outs.iter().enumerate() {
            let (c, i) = (idx / 10, idx % 10);
            assert_eq!(o[0], (c * 100 + i) as f32 * 2.0);
        }
    }

    #[test]
    fn batching_actually_batches() {
        // Pre-queue many requests before serving: the first drain should
        // form batches bigger than one.
        let (tx, rx) = mpsc::channel::<Request>();
        let mut replies = Vec::new();
        for i in 0..16 {
            let (rtx, rrx) = mpsc::channel();
            tx.send(Request {
                sample: vec![i as f32, 0.0],
                reply: rtx,
                submitted: Instant::now(),
            })
            .unwrap();
            replies.push(rrx);
        }
        drop(tx);
        let server = BatchServer::new(2, 1, 8);
        let stats = server
            .run(rx, |input| {
                let b = input.dims()[0];
                Tensor::new(vec![b, 1], (0..b).map(|i| input.data()[i * 2]).collect())
            })
            .unwrap();
        assert_eq!(stats.requests, 16);
        assert!(stats.mean_batch() > 4.0, "{}", stats.mean_batch());
        assert!(stats.batches <= 4);
        for r in replies {
            r.recv().unwrap();
        }
    }

    #[test]
    fn batch_never_exceeds_max() {
        let server = BatchServer::new(2, 1, 3);
        let (stats, _) = drive_server(
            &server,
            4,
            6,
            |_, i| vec![i as f32, 0.0],
            |input| {
                let b = input.dims()[0];
                assert_eq!(b, 3, "executor must always see max_batch rows");
                Tensor::new(vec![b, 1], vec![0.0; b])
            },
        )
        .unwrap();
        assert!(stats.batch_sizes.iter().all(|&s| s <= 3));
        assert_eq!(stats.requests, 24);
    }

    #[test]
    fn rejects_bad_sample_length() {
        let (tx, rx) = mpsc::channel::<Request>();
        let (rtx, _rrx) = mpsc::channel();
        tx.send(Request { sample: vec![1.0; 7], reply: rtx, submitted: Instant::now() })
            .unwrap();
        drop(tx);
        let server = BatchServer::new(4, 1, 2);
        assert!(server.run(rx, double_exec).is_err());
    }

    #[test]
    fn latency_stats_populated() {
        let server = BatchServer::new(2, 1, 4);
        let (stats, _) =
            drive_server(&server, 2, 5, |_, _| vec![1.0, 2.0], double_exec).unwrap();
        assert_eq!(stats.latencies_us.len(), 10);
        assert!(stats.p99_latency_us() >= stats.p50_latency_us());
    }

    #[test]
    fn percentile_empty_is_zero() {
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(percentile(&[], 0.99), 0.0);
    }

    #[test]
    fn percentile_single_element_is_that_element() {
        assert_eq!(percentile(&[42.5], 0.0), 42.5);
        assert_eq!(percentile(&[42.5], 0.5), 42.5);
        assert_eq!(percentile(&[42.5], 1.0), 42.5);
    }

    #[test]
    fn percentile_interior_quantiles() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 0.5), 3.0);
        assert_eq!(percentile(&xs, 1.0), 5.0);
    }

    #[test]
    fn percentile_nan_hardened_path_never_panics() {
        // total_cmp sorts positive NaN bit patterns after +inf: low
        // quantiles stay finite, the tail reports the poisoned entry —
        // and nothing panics (the original sort_by(partial_cmp) did).
        let xs = [2.0, f64::NAN, 1.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 0.5), 2.0);
        assert!(percentile(&xs, 1.0).is_nan());
    }

    mod cosim_serving {
        use super::*;
        use crate::accel::Precision;
        use crate::compiler::lowering::lower;
        use crate::compiler::mapper::{map_graph, MapStrategy};
        use crate::config::FabricConfig;
        use crate::workloads;

        #[test]
        fn batch_server_drives_the_cosim_executor() {
            let fabric = Fabric::build(
                FabricConfig::from_toml(
                    "[noc]\nwidth = 3\nheight = 3\n\
                     [[cu]]\nkind = \"npu\"\ntemplate = \"B\"\ncount = 4\n",
                )
                .unwrap(),
            )
            .unwrap();
            let g = workloads::mlp(4, 32, &[16], 8, 1).unwrap();
            let m = map_graph(&g, &fabric, MapStrategy::Greedy, Precision::Int8).unwrap();
            let prog = lower(&g, &fabric, &m).unwrap();
            let mut sim = CosimExecutor::new(&fabric, prog, 1_000);

            // Pre-queue 10 requests so the server forms multiple batches.
            let (tx, rx) = mpsc::channel::<Request>();
            let mut replies = Vec::new();
            for i in 0..10 {
                let (rtx, rrx) = mpsc::channel();
                tx.send(Request {
                    sample: vec![i as f32, 0.0],
                    reply: rtx,
                    submitted: Instant::now(),
                })
                .unwrap();
                replies.push(rrx);
            }
            drop(tx);
            let server = BatchServer::new(2, 1, 4);
            let stats = server
                .run_cosim(
                    rx,
                    |input| {
                        let b = input.dims()[0];
                        Tensor::new(
                            vec![b, 1],
                            (0..b).map(|i| input.data()[i * 2]).collect(),
                        )
                    },
                    &mut sim,
                )
                .unwrap();
            // Request/batch accounting: every request answered, one
            // simulated makespan per formed batch, one admitted program
            // per batch on the live session.
            assert_eq!(stats.requests, 10);
            assert!(stats.batches >= 3, "max_batch 4 over 10 requests");
            assert_eq!(stats.sim_cycles.len(), stats.batches);
            assert_eq!(sim.session_mut().programs(), stats.batches);
            assert!(stats.sim_cycles.iter().all(|&c| c > 0));
            assert!(stats.mean_sim_cycles() > 0.0);
            assert!(stats.p99_sim_cycles() >= stats.mean_sim_cycles() * 0.5);
            for r in replies {
                r.recv().unwrap();
            }
            // The merged report over the whole serving run tiles into
            // one span per batch.
            let rep = sim.session_mut().report().unwrap();
            assert_eq!(rep.programs.len(), stats.batches);
            let sum_steps: usize = rep.programs.iter().map(|p| p.steps).sum();
            assert_eq!(sum_steps, rep.step_done.len());
        }

        #[test]
        fn degraded_executor_with_empty_plan_matches_cosim_executor() {
            use crate::sim::{FaultConfig, FaultPlan};
            let fabric = Fabric::build(
                FabricConfig::from_toml(
                    "[noc]\nwidth = 3\nheight = 3\n\
                     [[cu]]\nkind = \"npu\"\ntemplate = \"B\"\ncount = 4\n",
                )
                .unwrap(),
            )
            .unwrap();
            let g = workloads::mlp(4, 32, &[16], 8, 1).unwrap();
            let m = map_graph(&g, &fabric, MapStrategy::Greedy, Precision::Int8).unwrap();
            let prog = lower(&g, &fabric, &m).unwrap();
            let cfg = FaultConfig::default();
            let session =
                FaultySession::with_plan(&fabric, FaultPlan::empty(), &cfg, RecoveryPolicy::Retry)
                    .unwrap();
            let mut faulty = DegradedExecutor::with_session(session, prog.clone(), 1_000);
            let mut plain = CosimExecutor::new(&fabric, prog, 1_000);
            for i in 0..4 {
                let a = faulty.execute_batch().unwrap();
                let b = plain.execute_batch().unwrap();
                assert_eq!(a, b, "batch {i} diverged under an empty plan");
            }
            let (_, deg) = faulty.report_degraded().unwrap();
            assert_eq!((deg.programs, deg.completed, deg.faults_injected), (4, 4, 0));
            assert!(faulty.outcomes().iter().all(|o| !o.retried && !o.shed));
        }

        #[test]
        fn batch_server_serves_through_a_dying_fabric() {
            use crate::compiler::Step;
            use crate::sim::{FaultConfig, FaultEvent, FaultKind, FaultPlan};
            let fabric = Fabric::build(
                FabricConfig::from_toml(
                    "[noc]\nwidth = 3\nheight = 3\n\
                     [[cu]]\nkind = \"npu\"\ntemplate = \"B\"\ncount = 4\n",
                )
                .unwrap(),
            )
            .unwrap();
            let g = workloads::mlp(4, 32, &[16], 8, 1).unwrap();
            let m = map_graph(&g, &fabric, MapStrategy::Greedy, Precision::Int8).unwrap();
            let prog = lower(&g, &fabric, &m).unwrap();
            // Kill the tile running the program's final layer while the
            // first batch is in flight.
            let victim = prog
                .steps
                .iter()
                .rev()
                .find_map(|s| match s {
                    Step::Exec { tile, .. } => Some(*tile),
                    _ => None,
                })
                .unwrap();
            let plan = FaultPlan::from_events(vec![FaultEvent {
                at: 50,
                kind: FaultKind::TileDeath { tile: victim },
            }]);
            let cfg = FaultConfig::default();
            let session =
                FaultySession::with_plan(&fabric, plan, &cfg, RecoveryPolicy::Retry).unwrap();
            let mut sim = DegradedExecutor::with_session(session, prog, 1_000);

            let (tx, rx) = mpsc::channel::<Request>();
            let mut replies = Vec::new();
            for i in 0..10 {
                let (rtx, rrx) = mpsc::channel();
                tx.send(Request {
                    sample: vec![i as f32, 0.0],
                    reply: rtx,
                    submitted: Instant::now(),
                })
                .unwrap();
                replies.push(rrx);
            }
            drop(tx);
            let server = BatchServer::new(2, 1, 4);
            let stats = server
                .run_degraded(
                    rx,
                    |input| {
                        let b = input.dims()[0];
                        Tensor::new(
                            vec![b, 1],
                            (0..b).map(|i| input.data()[i * 2]).collect(),
                        )
                    },
                    &mut sim,
                )
                .unwrap();
            assert_eq!(stats.requests, 10);
            assert_eq!(stats.sim_cycles.len(), stats.batches);
            for r in replies {
                r.recv().unwrap();
            }
            // Every batch survived by re-mapping off the dead tile; the
            // telemetry is coherent with the batch accounting.
            let outcomes = sim.outcomes();
            assert_eq!(outcomes.len(), stats.batches);
            assert!(outcomes.iter().all(|o| !o.shed), "retry policy must not shed here");
            assert!(outcomes.iter().all(|o| o.remapped), "every batch uses the dead tile");
            let (rep, deg) = sim.report_degraded().unwrap();
            assert_eq!(rep.programs.len(), stats.batches);
            assert_eq!((deg.programs, deg.completed, deg.shed), (stats.batches, stats.batches, 0));
            assert_eq!(deg.availability, 1.0);
            assert_eq!((deg.faults_injected, deg.faults_effective), (1, 1));
            assert_eq!(rep.tile_busy[victim], 0, "no retained work on dead silicon");
        }

        #[test]
        fn open_loop_sojourn_equals_makespan_on_a_plain_session() {
            let fabric = Fabric::build(
                FabricConfig::from_toml(
                    "[noc]\nwidth = 3\nheight = 3\n\
                     [[cu]]\nkind = \"npu\"\ntemplate = \"B\"\ncount = 4\n",
                )
                .unwrap(),
            )
            .unwrap();
            let g = workloads::mlp(4, 32, &[16], 8, 1).unwrap();
            let m = map_graph(&g, &fabric, MapStrategy::Greedy, Precision::Int8).unwrap();
            let prog = lower(&g, &fabric, &m).unwrap();
            let mut sim = CosimExecutor::new(&fabric, prog, 1_000);

            let (tx, rx) = mpsc::channel::<Request>();
            let mut replies = Vec::new();
            for i in 0..6 {
                let (rtx, rrx) = mpsc::channel();
                tx.send(Request {
                    sample: vec![i as f32, 0.0],
                    reply: rtx,
                    submitted: Instant::now(),
                })
                .unwrap();
                replies.push(rrx);
            }
            drop(tx);
            let server = BatchServer::new(2, 1, 2);
            let stats = server
                .run_cosim(
                    rx,
                    |input| {
                        let b = input.dims()[0];
                        Tensor::new(
                            vec![b, 1],
                            (0..b).map(|i| input.data()[i * 2]).collect(),
                        )
                    },
                    &mut sim,
                )
                .unwrap();
            for r in replies {
                r.recv().unwrap();
            }
            // A plain session admits exactly at each arrival, so the
            // arrival-anchored sojourn series is the makespan series.
            assert_eq!(stats.sim_sojourn_cycles, stats.sim_cycles);
            assert!(stats.p999_sim_sojourn_cycles() >= stats.p50_sim_sojourn_cycles());
            assert!(stats.p999_latency_us() >= stats.p99_latency_us());
        }

        #[test]
        fn degraded_sojourn_charges_the_fault_floor_bump_as_queueing() {
            use crate::compiler::Step;
            use crate::sim::{FaultConfig, FaultEvent, FaultKind, FaultPlan};
            let fabric = Fabric::build(
                FabricConfig::from_toml(
                    "[noc]\nwidth = 3\nheight = 3\n\
                     [[cu]]\nkind = \"npu\"\ntemplate = \"B\"\ncount = 4\n",
                )
                .unwrap(),
            )
            .unwrap();
            let g = workloads::mlp(4, 32, &[16], 8, 1).unwrap();
            let m = map_graph(&g, &fabric, MapStrategy::Greedy, Precision::Int8).unwrap();
            let prog = lower(&g, &fabric, &m).unwrap();
            let victim = prog
                .steps
                .iter()
                .rev()
                .find_map(|s| match s {
                    Step::Exec { tile, .. } => Some(*tile),
                    _ => None,
                })
                .unwrap();
            let plan = FaultPlan::from_events(vec![FaultEvent {
                at: 50,
                kind: FaultKind::TileDeath { tile: victim },
            }]);
            let cfg = FaultConfig::default();
            let session =
                FaultySession::with_plan(&fabric, plan, &cfg, RecoveryPolicy::Retry).unwrap();
            // Gap 10 ≪ the death cycle: batch 1's open-loop arrival lands
            // below the fault floor and gets bumped.
            let mut sim = DegradedExecutor::with_session(session, prog, 10);
            let mut rows = Vec::new();
            let mut arrivals = Vec::new();
            let mut arrival = 0;
            for _ in 0..4 {
                arrivals.push(arrival);
                rows.push(sim.execute_batch_open_loop().unwrap());
                arrival = *sim.admissions().last().unwrap() + 10;
            }
            // Sojourn = makespan + the bump (admission − arrival): the
            // delay a request spends waiting out frozen fault history is
            // queueing it experienced, so it belongs in the percentiles.
            let mut bumped = 0;
            for (i, &(makespan, sojourn)) in rows.iter().enumerate() {
                let bump = sim.admissions()[i] - arrivals[i];
                assert_eq!(sojourn, Some(makespan + bump), "batch {i}");
                if bump > 0 {
                    bumped += 1;
                }
            }
            assert!(bumped > 0, "no batch ever waited out the fault floor");
        }

        #[test]
        fn shed_batches_are_excluded_from_sojourn_percentiles() {
            use crate::compiler::Step;
            use crate::sim::{FaultConfig, FaultEvent, FaultKind, FaultPlan};
            let fabric = Fabric::build(
                FabricConfig::from_toml(
                    "[noc]\nwidth = 3\nheight = 3\n\
                     [[cu]]\nkind = \"npu\"\ntemplate = \"B\"\ncount = 4\n",
                )
                .unwrap(),
            )
            .unwrap();
            let g = workloads::mlp(4, 32, &[16], 8, 1).unwrap();
            let m = map_graph(&g, &fabric, MapStrategy::Greedy, Precision::Int8).unwrap();
            let prog = lower(&g, &fabric, &m).unwrap();
            let victim = prog
                .steps
                .iter()
                .rev()
                .find_map(|s| match s {
                    Step::Exec { tile, .. } => Some(*tile),
                    _ => None,
                })
                .unwrap();
            let plan = FaultPlan::from_events(vec![FaultEvent {
                at: 50,
                kind: FaultKind::TileDeath { tile: victim },
            }]);
            let cfg = FaultConfig::default();
            let session =
                FaultySession::with_plan(&fabric, plan, &cfg, RecoveryPolicy::Shed).unwrap();
            let mut sim = DegradedExecutor::with_session(session, prog, 1_000);

            let (tx, rx) = mpsc::channel::<Request>();
            let mut replies = Vec::new();
            for i in 0..8 {
                let (rtx, rrx) = mpsc::channel();
                tx.send(Request {
                    sample: vec![i as f32, 0.0],
                    reply: rtx,
                    submitted: Instant::now(),
                })
                .unwrap();
                replies.push(rrx);
            }
            drop(tx);
            let server = BatchServer::new(2, 1, 4);
            let stats = server
                .run_degraded(
                    rx,
                    |input| {
                        let b = input.dims()[0];
                        Tensor::new(
                            vec![b, 1],
                            (0..b).map(|i| input.data()[i * 2]).collect(),
                        )
                    },
                    &mut sim,
                )
                .unwrap();
            for r in replies {
                r.recv().unwrap();
            }
            // Every batch references the dead tile and the policy sheds:
            // makespans record zeros (one per batch) while the sojourn
            // series stays empty — a shed request never completes, and a
            // zero would deflate the tail exactly when the fabric is at
            // its worst.
            let shed = sim.outcomes().iter().filter(|o| o.shed).count();
            assert_eq!(shed, stats.batches, "shed policy must shed every batch here");
            assert_eq!(stats.sim_cycles.len(), stats.batches);
            assert!(stats.sim_sojourn_cycles.is_empty());
            assert_eq!(stats.p999_sim_sojourn_cycles(), 0.0);
        }

        #[test]
        fn cosim_executor_prices_load_through_a_varying_model() {
            use crate::fabric::{CongestionKnobs, VaryingCost};
            let fabric = Fabric::build(
                FabricConfig::from_toml(
                    "[noc]\nwidth = 3\nheight = 3\n\
                     [[cu]]\nkind = \"npu\"\ntemplate = \"B\"\ncount = 4\n",
                )
                .unwrap(),
            )
            .unwrap();
            let g = workloads::mlp(4, 32, &[16], 8, 1).unwrap();
            let m = map_graph(&g, &fabric, MapStrategy::Greedy, Precision::Int8).unwrap();
            let prog = lower(&g, &fabric, &m).unwrap();
            // Tight gap so consecutive batches overlap and congest.
            let model =
                Arc::new(VaryingCost::congestion(64, CongestionKnobs { alpha: 1.0, cap: 8.0 }));
            let mut hot = CosimExecutor::with_model(&fabric, prog.clone(), 10, model.clone());
            assert_eq!(hot.cost_model().name(), "congestion");
            let mut cold = CosimExecutor::new(&fabric, prog, 10);
            let (mut hot_spans, mut cold_spans) = (Vec::new(), Vec::new());
            for _ in 0..4 {
                hot_spans.push(hot.execute_batch().unwrap());
                cold_spans.push(cold.execute_batch().unwrap());
            }
            // Congestion can only stretch simulated batch latency, and a
            // saturated stream must actually show it somewhere.
            for (h, c) in hot_spans.iter().zip(&cold_spans) {
                assert!(h >= c, "congestion shrank a batch: {h} < {c}");
            }
            assert!(
                hot_spans.iter().zip(&cold_spans).any(|(h, c)| h > c),
                "saturated stream never congested: {hot_spans:?} vs {cold_spans:?}"
            );
        }
    }
}
