//! Batch inference server: the leader/worker orchestration half of the
//! coordinator.
//!
//! Worker threads submit single-sample requests over an mpsc channel; the
//! leader drains the queue, forms batches up to `max_batch`, executes the
//! batch through a user-supplied executor (the PJRT artifact in
//! production; a closure in tests), and answers each request on its own
//! reply channel. This is the standard dynamic-batching loop of a serving
//! runtime, sized for the edge-fabric use case.
//!
//! No tokio in the offline image — std::thread + mpsc (DESIGN.md §6).

use std::sync::mpsc;
use std::time::Instant;

use anyhow::ensure;

use crate::runtime::Tensor;
use crate::Result;

/// One inference request: a single sample (row-major f32) plus the reply
/// channel.
pub struct Request {
    pub sample: Vec<f32>,
    pub reply: mpsc::Sender<Vec<f32>>,
    pub submitted: Instant,
}

/// Serving statistics.
#[derive(Debug, Clone, Default)]
pub struct BatchStats {
    pub requests: usize,
    pub batches: usize,
    /// Distribution of batch sizes formed.
    pub batch_sizes: Vec<usize>,
    /// Per-request latency, microseconds.
    pub latencies_us: Vec<f64>,
}

impl BatchStats {
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batch_sizes.iter().sum::<usize>() as f64 / self.batches as f64
        }
    }

    pub fn p50_latency_us(&self) -> f64 {
        percentile(&self.latencies_us, 0.50)
    }

    pub fn p99_latency_us(&self) -> f64 {
        percentile(&self.latencies_us, 0.99)
    }

    pub fn throughput_rps(&self, wall_s: f64) -> f64 {
        if wall_s <= 0.0 {
            0.0
        } else {
            self.requests as f64 / wall_s
        }
    }
}

fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    // total_cmp: a NaN latency (clock skew pathologies) must not panic
    // the serving report path (same fix as Metrics::breakdown).
    v.sort_by(f64::total_cmp);
    v[((v.len() - 1) as f64 * q).round() as usize]
}

/// The dynamic batcher. `exec(batch_rows) -> output_rows` runs a full
/// batch; the server pads the final partial batch with zero rows (the
/// AOT artifacts have a fixed batch dimension).
pub struct BatchServer {
    sample_len: usize,
    output_len: usize,
    max_batch: usize,
}

impl BatchServer {
    pub fn new(sample_len: usize, output_len: usize, max_batch: usize) -> Self {
        assert!(max_batch > 0);
        BatchServer { sample_len, output_len, max_batch }
    }

    /// Serve until the request channel closes. Returns stats.
    pub fn run(
        &self,
        rx: mpsc::Receiver<Request>,
        mut exec: impl FnMut(&Tensor) -> Result<Tensor>,
    ) -> Result<BatchStats> {
        let mut stats = BatchStats::default();
        let mut pending: Vec<Request> = Vec::new();
        loop {
            // Block for the first request, then drain whatever is queued
            // (batching window = "everything available now").
            if pending.is_empty() {
                match rx.recv() {
                    Ok(r) => pending.push(r),
                    Err(_) => break, // all senders dropped
                }
            }
            while pending.len() < self.max_batch {
                match rx.try_recv() {
                    Ok(r) => pending.push(r),
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => break,
                }
            }
            let take = pending.len().min(self.max_batch);
            let batch: Vec<Request> = pending.drain(..take).collect();
            // Assemble the padded batch tensor.
            let mut data = vec![0.0f32; self.max_batch * self.sample_len];
            for (i, r) in batch.iter().enumerate() {
                ensure!(r.sample.len() == self.sample_len, "bad sample length");
                data[i * self.sample_len..(i + 1) * self.sample_len]
                    .copy_from_slice(&r.sample);
            }
            let input = Tensor::new(vec![self.max_batch, self.sample_len], data)?;
            let out = exec(&input)?;
            ensure!(
                out.len() >= batch.len() * self.output_len,
                "executor output too small"
            );
            let now = Instant::now();
            for (i, r) in batch.iter().enumerate() {
                let row =
                    out.data()[i * self.output_len..(i + 1) * self.output_len].to_vec();
                let _ = r.reply.send(row); // receiver may have given up
                stats
                    .latencies_us
                    .push(now.duration_since(r.submitted).as_secs_f64() * 1e6);
            }
            stats.requests += batch.len();
            stats.batches += 1;
            stats.batch_sizes.push(batch.len());
        }
        Ok(stats)
    }
}

/// Convenience: spawn `clients` worker threads that each submit `per`
/// requests built by `make_sample(client, idx)`, run the server on the
/// current thread, and return (stats, outputs sorted by client).
pub fn drive_server(
    server: &BatchServer,
    clients: usize,
    per: usize,
    make_sample: impl Fn(usize, usize) -> Vec<f32> + Send + Sync + 'static + Clone,
    exec: impl FnMut(&Tensor) -> Result<Tensor>,
) -> Result<(BatchStats, Vec<Vec<f32>>)> {
    let (tx, rx) = mpsc::channel::<Request>();
    let mut joins = Vec::new();
    let (otx, orx) = mpsc::channel::<(usize, usize, Vec<f32>)>();
    for c in 0..clients {
        let tx = tx.clone();
        let otx = otx.clone();
        let make = make_sample.clone();
        joins.push(std::thread::spawn(move || {
            for i in 0..per {
                let (rtx, rrx) = mpsc::channel();
                tx.send(Request {
                    sample: make(c, i),
                    reply: rtx,
                    submitted: Instant::now(),
                })
                .expect("server alive");
                let out = rrx.recv().expect("reply");
                otx.send((c, i, out)).unwrap();
            }
        }));
    }
    drop(tx);
    drop(otx);
    let stats = server.run(rx, exec)?;
    for j in joins {
        j.join().expect("client thread");
    }
    let mut outs: Vec<(usize, usize, Vec<f32>)> = orx.iter().collect();
    outs.sort_by_key(|&(c, i, _)| (c, i));
    Ok((stats, outs.into_iter().map(|(_, _, o)| o).collect()))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Mock executor: out_row = 2 * first element of in_row, output_len 1.
    fn double_exec(input: &Tensor) -> Result<Tensor> {
        let b = input.dims()[0];
        let s = input.dims()[1];
        let out: Vec<f32> = (0..b).map(|i| input.data()[i * s] * 2.0).collect();
        Tensor::new(vec![b, 1], out)
    }

    #[test]
    fn all_requests_answered_correctly() {
        let server = BatchServer::new(4, 1, 8);
        let (stats, outs) = drive_server(
            &server,
            3,
            10,
            |c, i| vec![(c * 100 + i) as f32, 0.0, 0.0, 0.0],
            double_exec,
        )
        .unwrap();
        assert_eq!(stats.requests, 30);
        assert_eq!(outs.len(), 30);
        for (idx, o) in outs.iter().enumerate() {
            let (c, i) = (idx / 10, idx % 10);
            assert_eq!(o[0], (c * 100 + i) as f32 * 2.0);
        }
    }

    #[test]
    fn batching_actually_batches() {
        // Pre-queue many requests before serving: the first drain should
        // form batches bigger than one.
        let (tx, rx) = mpsc::channel::<Request>();
        let mut replies = Vec::new();
        for i in 0..16 {
            let (rtx, rrx) = mpsc::channel();
            tx.send(Request {
                sample: vec![i as f32, 0.0],
                reply: rtx,
                submitted: Instant::now(),
            })
            .unwrap();
            replies.push(rrx);
        }
        drop(tx);
        let server = BatchServer::new(2, 1, 8);
        let stats = server
            .run(rx, |input| {
                let b = input.dims()[0];
                Tensor::new(vec![b, 1], (0..b).map(|i| input.data()[i * 2]).collect())
            })
            .unwrap();
        assert_eq!(stats.requests, 16);
        assert!(stats.mean_batch() > 4.0, "{}", stats.mean_batch());
        assert!(stats.batches <= 4);
        for r in replies {
            r.recv().unwrap();
        }
    }

    #[test]
    fn batch_never_exceeds_max() {
        let server = BatchServer::new(2, 1, 3);
        let (stats, _) = drive_server(
            &server,
            4,
            6,
            |_, i| vec![i as f32, 0.0],
            |input| {
                let b = input.dims()[0];
                assert_eq!(b, 3, "executor must always see max_batch rows");
                Tensor::new(vec![b, 1], vec![0.0; b])
            },
        )
        .unwrap();
        assert!(stats.batch_sizes.iter().all(|&s| s <= 3));
        assert_eq!(stats.requests, 24);
    }

    #[test]
    fn rejects_bad_sample_length() {
        let (tx, rx) = mpsc::channel::<Request>();
        let (rtx, _rrx) = mpsc::channel();
        tx.send(Request { sample: vec![1.0; 7], reply: rtx, submitted: Instant::now() })
            .unwrap();
        drop(tx);
        let server = BatchServer::new(4, 1, 2);
        assert!(server.run(rx, double_exec).is_err());
    }

    #[test]
    fn latency_stats_populated() {
        let server = BatchServer::new(2, 1, 4);
        let (stats, _) =
            drive_server(&server, 2, 5, |_, _| vec![1.0, 2.0], double_exec).unwrap();
        assert_eq!(stats.latencies_us.len(), 10);
        assert!(stats.p99_latency_us() >= stats.p50_latency_us());
    }
}
