//! Timing co-simulation of a lowered FabricProgram — event-driven, on the
//! shared [`crate::sim::EventWheel`] calendar (via [`Calendar`]).
//!
//! # Resource model (event-driven engine)
//!
//! Steps are *events*; tiles, the HBM port and (src, dst) transfer paths
//! are *resources* with in-order wake queues:
//!
//! * every resource serves its steps strictly in program order (the same
//!   contract the retained list scheduler in [`super::refexec`] enforces
//!   implicitly by its one-pass loop): each tile executes one `Exec` at a
//!   time, `Load`s serialize on the single HBM port but overlap with
//!   compute, `Transfer`s serialize per (src, dst) tile pair on the
//!   analytic NoC transport model;
//! * a step *starts* at `max(ready, free)` — the instant its last
//!   dependency completes (`ready`) or its resource's previous occupant
//!   finishes (`free`), whichever is later. Both instants are completion
//!   events, so every start happens while draining a completion batch and
//!   the engine never scans for runnable work;
//! * a step's *completion* is one calendar event: it frees the resource
//!   (waking the next queued step if its dependencies are met) and
//!   decrements each successor's pending-dependency count (waking a
//!   successor whose resource is idle and whose queue turn has come);
//! * the calendar jumps between completion times — no per-cycle stepping,
//!   so a 5000-cycle HBM feed costs one event, and tile/NoC/DRAM event
//!   streams can interleave in the same calendar as the rest of the
//!   simulation stack.
//!
//! Step durations come from the fabric's **cost-model layer**
//! ([`crate::fabric::CostModel`]): the engine holds a model handle
//! ([`cosim`] uses the fabric's configured `[fabric.cost]` model,
//! [`cosim_with`] takes an explicit one) and prices every step at its
//! true start cycle, feeding time-varying models the live
//! [`crate::fabric::Occupancy`] aggregates. Under the default
//! [`crate::fabric::InvariantCost`] the engine is bit-identical to the
//! list scheduler (the differential golden tests in
//! `tests/cosim_golden.rs` enforce it). Under a time-varying model the
//! single greedy pass is *self-consistent by construction* for a t=0
//! program: completion events drain in time order and every start is
//! assigned exactly at its triggering event time, so pricing happens in
//! nondecreasing start order — by the strictly-earlier-epoch occupancy
//! contract (see `fabric::cost`), every price already reads its final
//! occupancy. `tests/costmodel_golden.rs` pins this against the iterated
//! list scheduler and the admission session.
//!
//! Link resources are keyed *sparsely* — a hash over the (src, dst)
//! pairs the program actually uses — instead of the reference's dense
//! `nt * nt` occupancy vector (8 B·nt²: 32 MB at 2k tiles, before a
//! single step runs). Memory here is O(active pairs), and the map is
//! touched only while building the resource table, never while stepping.
//!
//! Why event-driven at all, when the one-pass list scheduler is already
//! O(n)? Because a calendar admits what a single pass cannot: incremental
//! re-simulation (re-enqueue only invalidated steps), batched admission
//! of new programs mid-flight (the serving path), interleaving with
//! the flit-level NoC / bank-level DRAM event streams — and shard-
//! parallel batch execution: the admission session fans each calendar
//! epoch's fires out over resource shards and merges them back in
//! canonical order, reproducing this engine's reports bit-for-bit at
//! every thread count (see `coordinator::admit`'s determinism-contract
//! docs; this single-program engine stays sequential and serves as the
//! oracle).
//!
//! # Admission / invalidation contract (the multi-program layer)
//!
//! [`super::admit`] generalizes this engine to a *persistent* session:
//! programs are admitted into a live calendar at arbitrary simulated
//! times, share the tile/HBM/link resources, and can be replaced (a
//! program or cost-model change) without rebuilding the world. The
//! contract, enforced by `tests/admission_golden.rs`:
//!
//! * **Resource FIFO across programs.** Every resource serves its steps
//!   in ascending `(admit time, admission sequence, step index)` order —
//!   deterministic FIFO tie-breaking: programs admitted at the same time
//!   are ordered by admission sequence. A single program admitted at
//!   t=0 therefore replays [`cosim`] (and [`super::refexec::cosim_ref`])
//!   **bit-identically**, and N programs admitted at t=0 replay `cosim`
//!   on the concatenated program.
//! * **Invalidation.** When a program is admitted, replaced or
//!   re-priced, the steps whose schedule can change are exactly: the
//!   changed program's own (un)started steps, every step positioned
//!   after one of them in a resource queue, and — transitively — the
//!   dependency successors and queue tails of those. Only this closure
//!   is re-enqueued; everything before it keeps its completed state,
//!   byte for byte.
//! * **From-scratch equivalence.** After any admit/replace sequence the
//!   drained report is bit-identical to a fresh session (or a fresh
//!   `cosim` of the merged program, for t=0 batches) built with the same
//!   final programs and admit times — incremental re-simulation is an
//!   optimization, never a semantic.

use std::collections::VecDeque;

use anyhow::ensure;

use crate::compiler::{FabricProgram, Step};
use crate::fabric::{CostModel, Fabric, Occupancy};
use crate::metrics::{Category, Metrics};
use crate::sim::{Calendar, Cycle};
use crate::Result;

/// Per-program slice of a co-simulation: admission-to-finish span plus
/// the program's own step costs. Spans *tile* the merged report the way
/// the per-episode DRAM stats tile the DRAM timeline (PR 3): the integer
/// counters (`steps`, `exec_steps`, `transfer_cycles`, `ops`,
/// `bytes_moved`) sum exactly to the merged totals, and `energy_pj` is
/// the program's step costs folded in its own step order — bit-identical
/// to what a solo run of that program reports before fabric leakage
/// (leakage is charged on the merged makespan, so it lives only in the
/// merged [`ExecReport::metrics`]).
#[derive(Debug, Clone, PartialEq)]
pub struct ProgramSpan {
    /// Simulated cycle the program was admitted (0 for a plain `cosim`).
    pub admitted_at: Cycle,
    /// Completion time of the program's last step (== `admitted_at` for
    /// an empty program).
    pub finished_at: Cycle,
    /// Step counts: total / `Exec` steps only.
    pub steps: usize,
    pub exec_steps: usize,
    /// Sum of the program's NoC + HBM transfer step durations.
    pub transfer_cycles: Cycle,
    pub ops: u64,
    pub bytes_moved: u64,
    /// Program step costs folded in step order (no fabric leakage).
    pub energy_pj: f64,
}

impl ProgramSpan {
    /// Admission-to-completion latency in fabric cycles.
    pub fn makespan(&self) -> Cycle {
        self.finished_at - self.admitted_at
    }

    /// Bit-level equality (energy compared by f64 bit pattern).
    pub fn bit_identical(&self, other: &ProgramSpan) -> bool {
        self.admitted_at == other.admitted_at
            && self.finished_at == other.finished_at
            && self.steps == other.steps
            && self.exec_steps == other.exec_steps
            && self.transfer_cycles == other.transfer_cycles
            && self.ops == other.ops
            && self.bytes_moved == other.bytes_moved
            && self.energy_pj.to_bits() == other.energy_pj.to_bits()
    }
}

/// Co-simulation result.
#[derive(Debug, Clone)]
pub struct ExecReport {
    /// Makespan in fabric cycles.
    pub cycles: Cycle,
    /// Aggregate energy/ops/bytes.
    pub metrics: Metrics,
    /// Per-tile busy cycles (utilization = busy / makespan).
    pub tile_busy: Vec<Cycle>,
    /// Completion time per step.
    pub step_done: Vec<Cycle>,
    /// Total NoC + HBM transfer cycles (overlap included).
    pub transfer_cycles: Cycle,
    pub exec_steps: usize,
    /// One span per admitted program, in admission order (`cosim` /
    /// `cosim_ref` report exactly one; [`super::admit::CosimSession`]
    /// reports one per [`super::admit::CosimSession::admit_at`]).
    pub programs: Vec<ProgramSpan>,
}

impl ExecReport {
    pub fn utilization(&self, tile: usize) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.tile_busy[tile] as f64 / self.cycles as f64
        }
    }

    /// Mean utilization over tiles that did any work.
    pub fn mean_utilization(&self) -> f64 {
        let active: Vec<f64> = (0..self.tile_busy.len())
            .filter(|&t| self.tile_busy[t] > 0)
            .map(|t| self.utilization(t))
            .collect();
        if active.is_empty() {
            0.0
        } else {
            active.iter().sum::<f64>() / active.len() as f64
        }
    }

    /// Field-by-field bit identity with another report — THE golden
    /// contract between the event-driven engine and the retained
    /// [`super::refexec`] list scheduler (energy compared by f64 bit
    /// pattern, per category and in total). The differential tests and
    /// `bench_cosim` all gate on this one definition, so a future
    /// `ExecReport` field only needs to be added here to stay covered.
    pub fn bit_identical(&self, other: &ExecReport) -> bool {
        let (ba, bb) = (self.metrics.breakdown(), other.metrics.breakdown());
        self.cycles == other.cycles
            && self.step_done == other.step_done
            && self.tile_busy == other.tile_busy
            && self.transfer_cycles == other.transfer_cycles
            && self.exec_steps == other.exec_steps
            && self.metrics == other.metrics
            && self.metrics.total_energy_pj().to_bits()
                == other.metrics.total_energy_pj().to_bits()
            && ba.len() == bb.len()
            && ba
                .iter()
                .zip(&bb)
                .all(|((ca, ea), (cb, eb))| ca == cb && ea.to_bits() == eb.to_bits())
            && self.programs.len() == other.programs.len()
            && self
                .programs
                .iter()
                .zip(&other.programs)
                .all(|(a, b)| a.bit_identical(b))
    }
}

/// The event-driven co-simulation engine state.
struct Engine<'a> {
    fabric: &'a Fabric,
    prog: &'a FabricProgram,
    /// The pricing seam: every resource query routes through this.
    model: &'a dyn CostModel,
    /// Live occupancy aggregates (tracking only under a time-varying
    /// model; inert for [`crate::fabric::InvariantCost`]).
    occ: Occupancy,
    /// Resource id serving each step (tile | HBM port | link).
    res_of: Vec<usize>,
    /// Per-resource wake queue of step ids, in program order.
    queue: Vec<VecDeque<usize>>,
    /// Finish time of the last step started on each resource.
    res_free: Vec<Cycle>,
    /// Resource currently occupied by a running step.
    res_busy: Vec<bool>,
    /// Unresolved dependency count per step.
    pending: Vec<u32>,
    /// Max completion time over resolved dependencies, per step.
    ready_at: Vec<Cycle>,
    /// Successor adjacency, CSR over dependency edges.
    succ_off: Vec<usize>,
    succ: Vec<u32>,
    /// Completion time per step.
    done: Vec<Cycle>,
    /// Per-step cost (cycles zeroed), folded into the report totals in
    /// program order so the energy f64 additions replay the reference
    /// scheduler's exact sequence — bit-identical energy accumulators.
    step_cost: Vec<Metrics>,
    tile_busy: Vec<Cycle>,
    transfer_cycles: Cycle,
    exec_steps: usize,
    completed: usize,
}

impl<'a> Engine<'a> {
    fn build(fabric: &'a Fabric, prog: &'a FabricProgram, model: &'a dyn CostModel) -> Self {
        let n = prog.steps.len();
        let nt = fabric.tile_count();
        // Resource ids: 0..nt = tiles, nt = the HBM port, nt+1.. = links,
        // allocated sparsely per active (src, dst) pair.
        let hbm_res = nt;
        let mut link_ids: std::collections::HashMap<(usize, usize), usize> =
            std::collections::HashMap::new();
        let mut n_res = nt + 1;
        let mut res_of = Vec::with_capacity(n);
        for step in &prog.steps {
            let r = match step {
                Step::Load { .. } => hbm_res,
                Step::Exec { tile, .. } => *tile,
                Step::Transfer { from, to, .. } => *link_ids
                    .entry((*from, *to))
                    .or_insert_with(|| {
                        let id = n_res;
                        n_res += 1;
                        id
                    }),
            };
            res_of.push(r);
        }
        let mut queue = vec![VecDeque::new(); n_res];
        for (i, &r) in res_of.iter().enumerate() {
            queue[r].push_back(i);
        }
        // Successor CSR + pending counts (duplicate dep edges are kept on
        // both sides, so the counts stay balanced).
        let mut succ_off = vec![0usize; n + 1];
        for s in &prog.steps {
            for &d in s.deps() {
                succ_off[d + 1] += 1;
            }
        }
        for i in 0..n {
            succ_off[i + 1] += succ_off[i];
        }
        let mut succ = vec![0u32; succ_off[n]];
        let mut cursor: Vec<usize> = succ_off[..n].to_vec();
        let mut pending = vec![0u32; n];
        for (i, s) in prog.steps.iter().enumerate() {
            pending[i] = s.deps().len() as u32;
            for &d in s.deps() {
                succ[cursor[d]] = i as u32;
                cursor[d] += 1;
            }
        }
        let occ = match model.time_dependence().epoch() {
            Some(w) => Occupancy::new(w),
            None => Occupancy::disabled(),
        };
        Engine {
            fabric,
            prog,
            model,
            occ,
            res_of,
            queue,
            res_free: vec![0; n_res],
            res_busy: vec![false; n_res],
            pending,
            ready_at: vec![0; n],
            succ_off,
            succ,
            done: vec![0; n],
            step_cost: vec![Metrics::new(); n],
            tile_busy: vec![0; nt],
            transfer_cycles: 0,
            exec_steps: 0,
            completed: 0,
        }
    }

    /// Start step `i` on its (idle) resource: price it through the cost
    /// model at its true start cycle, register its occupancy span, occupy
    /// the resource, and return the completion time.
    fn start(&mut self, i: usize) -> Result<Cycle> {
        let (fabric, prog, model) = (self.fabric, self.prog, self.model);
        let r = self.res_of[i];
        debug_assert!(!self.res_busy[r] && self.pending[i] == 0);
        let start = self.ready_at[i].max(self.res_free[r]);
        let dur = match &prog.steps[i] {
            Step::Load { tile, bytes, .. } => {
                let cost = model.feed(fabric, *tile, *bytes, start, &self.occ);
                let cyc = cost.cycles;
                self.transfer_cycles += cyc;
                self.step_cost[i] = cost.with_cycles(0);
                cyc
            }
            Step::Transfer { from, to, bytes, .. } => {
                let src = fabric.tiles[*from].node;
                let dst = fabric.tiles[*to].node;
                let cost = model.transport(fabric, src, dst, *bytes, start, &self.occ);
                let cyc = cost.cycles;
                self.transfer_cycles += cyc;
                self.step_cost[i] = cost.with_cycles(0);
                cyc
            }
            Step::Exec { tile, compute, precision, .. } => {
                let cost = model.execute(fabric, *tile, compute, *precision, start, &self.occ)?;
                let cyc = cost.metrics.cycles;
                self.tile_busy[*tile] += cyc;
                self.exec_steps += 1;
                self.step_cost[i] = cost.metrics.with_cycles(0);
                cyc
            }
        };
        if self.occ.is_tracking() {
            self.occ.add_step(&prog.steps[i], start, start + dur);
        }
        let finish = start + dur;
        self.res_free[r] = finish;
        self.res_busy[r] = true;
        Ok(finish)
    }

    /// If resource `r`'s next queued step is dependency-ready, start it.
    /// Returns `Some((step, finish))` when a step launched.
    fn wake_head(&mut self, r: usize) -> Result<Option<(usize, Cycle)>> {
        if self.res_busy[r] {
            return Ok(None);
        }
        let Some(&h) = self.queue[r].front() else {
            return Ok(None);
        };
        if self.pending[h] != 0 {
            return Ok(None);
        }
        self.queue[r].pop_front();
        let finish = self.start(h)?;
        Ok(Some((h, finish)))
    }
}

/// Run the event-driven timing co-simulation under the fabric's
/// configured cost model (`[fabric.cost]`).
pub fn cosim(fabric: &Fabric, prog: &FabricProgram) -> Result<ExecReport> {
    cosim_with(fabric, prog, fabric.cost_model().as_ref())
}

/// Run the event-driven timing co-simulation with an explicit cost
/// model. For a time-varying model the single greedy pass is already the
/// unique self-consistent schedule (see the module docs).
pub fn cosim_with(
    fabric: &Fabric,
    prog: &FabricProgram,
    model: &dyn CostModel,
) -> Result<ExecReport> {
    let n = prog.steps.len();
    let mut e = Engine::build(fabric, prog, model);
    let mut cal: Calendar<usize> = Calendar::with_horizon(256);

    // Seed: launch every resource whose first queued step has no deps.
    for r in 0..e.queue.len() {
        if let Some((i, finish)) = e.wake_head(r)? {
            cal.push(finish, i);
        }
    }

    // Drain completion batches in time order; same-cycle launches append
    // to the live batch so zero-duration steps complete without another
    // calendar round-trip. `batch` is reusable scratch (the wheel's own
    // storage is recycled right after copying out the step ids).
    let mut batch: Vec<usize> = Vec::new();
    while let Some((t, due)) = cal.take_next() {
        batch.clear();
        batch.extend(due.iter().map(|&(_, i)| i));
        cal.recycle(due);
        let mut k = 0;
        while k < batch.len() {
            let i = batch[k];
            k += 1;
            e.done[i] = t;
            e.completed += 1;
            // Free the resource and wake its next queued step, then
            // resolve successors and wake any whose resource-queue turn
            // has come. (An idle resource never holds back a dep-ready
            // head between events, so `wake_head` at both event kinds
            // covers every launch point.)
            let r = e.res_of[i];
            e.res_busy[r] = false;
            if let Some((j, finish)) = e.wake_head(r)? {
                if finish == t {
                    batch.push(j);
                } else {
                    cal.push(finish, j);
                }
            }
            for s in e.succ_off[i]..e.succ_off[i + 1] {
                let j = e.succ[s] as usize;
                e.pending[j] -= 1;
                if e.ready_at[j] < t {
                    e.ready_at[j] = t;
                }
                if e.pending[j] == 0 {
                    if let Some((j2, finish)) = e.wake_head(e.res_of[j])? {
                        if finish == t {
                            batch.push(j2);
                        } else {
                            cal.push(finish, j2);
                        }
                    }
                }
            }
        }
    }
    ensure!(
        e.completed == n,
        "co-sim stalled: {} of {n} steps completed (cyclic or dangling deps?)",
        e.completed
    );

    let makespan = e.done.iter().copied().max().unwrap_or(0);
    // Fold per-step costs in program order: the same absorb sequence the
    // reference list scheduler performs, so energy bits match exactly.
    let mut total = Metrics::new();
    for c in &e.step_cost {
        total.absorb_parallel(c);
    }
    total.cycles = makespan;
    // The single program's span: captured before the fabric leakage term,
    // which is charged on the merged makespan (see ProgramSpan docs).
    let span = ProgramSpan {
        admitted_at: 0,
        finished_at: makespan,
        steps: n,
        exec_steps: e.exec_steps,
        transfer_cycles: e.transfer_cycles,
        ops: total.ops,
        bytes_moved: total.bytes_moved,
        energy_pj: total.total_energy_pj(),
    };
    // Fabric-level leakage over the episode.
    total.add_energy(
        Category::Leakage,
        makespan as f64 * fabric.tile_count() as f64 * 0.5,
    );
    Ok(ExecReport {
        cycles: makespan,
        metrics: total,
        tile_busy: e.tile_busy,
        step_done: e.done,
        transfer_cycles: e.transfer_cycles,
        exec_steps: e.exec_steps,
        programs: vec![span],
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::Precision;
    use crate::compiler::lowering::lower;
    use crate::compiler::mapper::{map_graph, MapStrategy};
    use crate::config::FabricConfig;
    use crate::workloads;

    fn fabric() -> Fabric {
        Fabric::build(
            FabricConfig::from_toml(
                "[noc]\nwidth = 3\nheight = 3\n\
                 [[cu]]\nkind = \"npu\"\ntemplate = \"B\"\ncount = 4\n",
            )
            .unwrap(),
        )
        .unwrap()
    }

    fn run(strategy: MapStrategy) -> ExecReport {
        let g = workloads::mlp(8, 64, &[64, 32], 10, 1).unwrap();
        let f = fabric();
        let m = map_graph(&g, &f, strategy, Precision::Int8).unwrap();
        let p = lower(&g, &f, &m).unwrap();
        cosim(&f, &p).unwrap()
    }

    #[test]
    fn makespan_positive_and_consistent() {
        let r = run(MapStrategy::Greedy);
        assert!(r.cycles > 0);
        assert!(r.exec_steps > 0);
        assert!(r.metrics.total_energy_pj() > 0.0);
        // every step finishes by the makespan
        assert!(r.step_done.iter().all(|&d| d <= r.cycles));
    }

    #[test]
    fn deps_respected() {
        let g = workloads::mlp(4, 32, &[16], 4, 2).unwrap();
        let f = fabric();
        let m = map_graph(&g, &f, MapStrategy::Greedy, Precision::Int8).unwrap();
        let p = lower(&g, &f, &m).unwrap();
        let r = cosim(&f, &p).unwrap();
        for (i, s) in p.steps.iter().enumerate() {
            for &d in s.deps() {
                assert!(r.step_done[d] <= r.step_done[i], "step {i} before dep {d}");
            }
        }
    }

    #[test]
    fn overlap_beats_serial_sum() {
        // The co-simulated makespan must be at most the serial sum of all
        // step durations (and strictly less when parallelism exists).
        let g = workloads::vit(&workloads::VitParams::default(), 3).unwrap();
        let f = fabric();
        let m = map_graph(&g, &f, MapStrategy::Greedy, Precision::Int8).unwrap();
        let p = lower(&g, &f, &m).unwrap();
        let r = cosim(&f, &p).unwrap();
        let serial: Cycle = r.transfer_cycles + r.tile_busy.iter().sum::<Cycle>();
        assert!(r.cycles <= serial, "makespan {} serial {}", r.cycles, serial);
    }

    #[test]
    fn utilization_bounded() {
        let r = run(MapStrategy::Greedy);
        for t in 0..r.tile_busy.len() {
            let u = r.utilization(t);
            assert!((0.0..=1.0).contains(&u), "{u}");
        }
        assert!(r.mean_utilization() > 0.0);
    }

    #[test]
    fn deterministic() {
        let a = run(MapStrategy::Greedy);
        let b = run(MapStrategy::Greedy);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.metrics.total_energy_pj().to_bits(),
                   b.metrics.total_energy_pj().to_bits());
    }

    #[test]
    fn empty_program_reports_zero() {
        let f = fabric();
        let r = cosim(&f, &FabricProgram::default()).unwrap();
        assert_eq!(r.cycles, 0);
        assert_eq!(r.exec_steps, 0);
        assert!(r.step_done.is_empty());
    }
}
