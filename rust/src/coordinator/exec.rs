//! Timing co-simulation of a lowered FabricProgram.
//!
//! Resource model:
//! * each tile executes one `Exec` at a time (per-tile FIFO by program
//!   order);
//! * `Load`s share HBM bandwidth (serialized on the HBM port) but overlap
//!   with compute;
//! * `Transfer`s use the analytic NoC transport model (latency + energy),
//!   serialized per (src, dst) tile pair;
//! * a step starts when its dependencies are done AND its resource is
//!   free — classic resource-constrained list scheduling, which is what
//!   a doorbell-driven fabric run looks like at this abstraction level.

use crate::compiler::{FabricProgram, Step};
use crate::fabric::Fabric;
use crate::metrics::{Category, Metrics};
use crate::sim::Cycle;
use crate::Result;

/// Co-simulation result.
#[derive(Debug, Clone)]
pub struct ExecReport {
    /// Makespan in fabric cycles.
    pub cycles: Cycle,
    /// Aggregate energy/ops/bytes.
    pub metrics: Metrics,
    /// Per-tile busy cycles (utilization = busy / makespan).
    pub tile_busy: Vec<Cycle>,
    /// Completion time per step.
    pub step_done: Vec<Cycle>,
    /// Total NoC + HBM transfer cycles (overlap included).
    pub transfer_cycles: Cycle,
    pub exec_steps: usize,
}

impl ExecReport {
    pub fn utilization(&self, tile: usize) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.tile_busy[tile] as f64 / self.cycles as f64
        }
    }

    /// Mean utilization over tiles that did any work.
    pub fn mean_utilization(&self) -> f64 {
        let active: Vec<f64> = (0..self.tile_busy.len())
            .filter(|&t| self.tile_busy[t] > 0)
            .map(|t| self.utilization(t))
            .collect();
        if active.is_empty() {
            0.0
        } else {
            active.iter().sum::<f64>() / active.len() as f64
        }
    }
}

/// Run the timing co-simulation.
pub fn cosim(fabric: &Fabric, prog: &FabricProgram) -> Result<ExecReport> {
    let n = prog.steps.len();
    let mut done = vec![0 as Cycle; n];
    let mut tile_free = vec![0 as Cycle; fabric.tile_count()];
    let mut tile_busy = vec![0 as Cycle; fabric.tile_count()];
    let mut hbm_free: Cycle = 0;
    // Per-(src tile, dst tile) transfer-path occupancy, flat-indexed by
    // the dense pair id `from * tile_count + to` (same trick as the NoC's
    // precomputed reverse-port map) instead of hashing tuples. O(tiles^2)
    // memory — 8 B * nt^2, fine for the <=256-tile fabrics the configs
    // describe; revisit (sparse or per-src maps) beyond ~2k tiles.
    let nt = fabric.tile_count();
    let mut link_free: Vec<Cycle> = vec![0; nt * nt];
    let mut total = Metrics::new();
    let mut transfer_cycles: Cycle = 0;
    let mut exec_steps = 0usize;

    for (i, step) in prog.steps.iter().enumerate() {
        let ready = step.deps().iter().map(|&d| done[d]).max().unwrap_or(0);
        match step {
            Step::Load { tile, bytes, .. } => {
                let cost = fabric.feed(*tile, *bytes);
                let start = ready.max(hbm_free);
                let finish = start + cost.cycles;
                hbm_free = finish;
                done[i] = finish;
                transfer_cycles += cost.cycles;
                total.absorb_parallel(&cost.with_cycles(0));
            }
            Step::Transfer { from, to, bytes, .. } => {
                let src = fabric.tiles[*from].node;
                let dst = fabric.tiles[*to].node;
                let cost = fabric.transport(src, dst, *bytes);
                let key = *from * nt + *to;
                let start = ready.max(link_free[key]);
                let finish = start + cost.cycles;
                link_free[key] = finish;
                done[i] = finish;
                transfer_cycles += cost.cycles;
                total.absorb_parallel(&cost.with_cycles(0));
            }
            Step::Exec { tile, compute, precision, .. } => {
                let cost = fabric.tiles[*tile].execute(compute, *precision)?;
                let start = ready.max(tile_free[*tile]);
                let finish = start + cost.metrics.cycles;
                tile_free[*tile] = finish;
                tile_busy[*tile] += cost.metrics.cycles;
                done[i] = finish;
                exec_steps += 1;
                total.absorb_parallel(&cost.metrics.with_cycles(0));
            }
        }
    }
    let makespan = done.iter().copied().max().unwrap_or(0);
    total.cycles = makespan;
    // Fabric-level leakage over the episode.
    total.add_energy(
        Category::Leakage,
        makespan as f64 * fabric.tile_count() as f64 * 0.5,
    );
    Ok(ExecReport {
        cycles: makespan,
        metrics: total,
        tile_busy,
        step_done: done,
        transfer_cycles,
        exec_steps,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::Precision;
    use crate::compiler::mapper::{map_graph, MapStrategy};
    use crate::compiler::lowering::lower;
    use crate::config::FabricConfig;
    use crate::workloads;

    fn fabric() -> Fabric {
        Fabric::build(
            FabricConfig::from_toml(
                "[noc]\nwidth = 3\nheight = 3\n\
                 [[cu]]\nkind = \"npu\"\ntemplate = \"B\"\ncount = 4\n",
            )
            .unwrap(),
        )
        .unwrap()
    }

    fn run(strategy: MapStrategy) -> ExecReport {
        let g = workloads::mlp(8, 64, &[64, 32], 10, 1).unwrap();
        let f = fabric();
        let m = map_graph(&g, &f, strategy, Precision::Int8).unwrap();
        let p = lower(&g, &f, &m).unwrap();
        cosim(&f, &p).unwrap()
    }

    #[test]
    fn makespan_positive_and_consistent() {
        let r = run(MapStrategy::Greedy);
        assert!(r.cycles > 0);
        assert!(r.exec_steps > 0);
        assert!(r.metrics.total_energy_pj() > 0.0);
        // every step finishes by the makespan
        assert!(r.step_done.iter().all(|&d| d <= r.cycles));
    }

    #[test]
    fn deps_respected() {
        let g = workloads::mlp(4, 32, &[16], 4, 2).unwrap();
        let f = fabric();
        let m = map_graph(&g, &f, MapStrategy::Greedy, Precision::Int8).unwrap();
        let p = lower(&g, &f, &m).unwrap();
        let r = cosim(&f, &p).unwrap();
        for (i, s) in p.steps.iter().enumerate() {
            for &d in s.deps() {
                assert!(r.step_done[d] <= r.step_done[i], "step {i} before dep {d}");
            }
        }
    }

    #[test]
    fn overlap_beats_serial_sum() {
        // The co-simulated makespan must be at most the serial sum of all
        // step durations (and strictly less when parallelism exists).
        let g = workloads::vit(&workloads::VitParams::default(), 3).unwrap();
        let f = fabric();
        let m = map_graph(&g, &f, MapStrategy::Greedy, Precision::Int8).unwrap();
        let p = lower(&g, &f, &m).unwrap();
        let r = cosim(&f, &p).unwrap();
        let serial: Cycle = r.transfer_cycles + r.tile_busy.iter().sum::<Cycle>();
        assert!(r.cycles <= serial, "makespan {} serial {}", r.cycles, serial);
    }

    #[test]
    fn utilization_bounded() {
        let r = run(MapStrategy::Greedy);
        for t in 0..r.tile_busy.len() {
            let u = r.utilization(t);
            assert!((0.0..=1.0).contains(&u), "{u}");
        }
        assert!(r.mean_utilization() > 0.0);
    }

    #[test]
    fn deterministic() {
        let a = run(MapStrategy::Greedy);
        let b = run(MapStrategy::Greedy);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.metrics.total_energy_pj().to_bits(),
                   b.metrics.total_energy_pj().to_bits());
    }
}
