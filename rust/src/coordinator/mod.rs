//! L3 coordinator: the runtime that drives the Scalable Compute Fabric.
//!
//! Three halves, mirroring how GVSoC/DRAMSys separate function from
//! timing (DESIGN.md §3):
//!
//! * [`exec`] — **timing**: event-driven co-simulation of a lowered
//!   [`crate::compiler::FabricProgram`] on the shared simulation calendar
//!   (steps as events; tiles, the HBM port and (src, dst) links as
//!   resources with in-order wake queues), overlapping transfers with
//!   compute exactly as a doorbell-driven fabric run would.
//! * [`refexec`] — the retained pre-rewrite list scheduler; differential
//!   golden tests pin the event-driven engine to its bit-exact answers
//!   (the `noc::refsim` pattern).
//! * [`serve`] — **function + orchestration**: a leader thread batches
//!   inference requests from worker threads (std::mpsc) and executes the
//!   AOT-compiled PJRT artifacts for bit-exact numerics.
//!
//! The end-to-end driver (examples/uav_vision.rs) runs both: PJRT for the
//! numbers, the co-simulator for latency/energy.

pub mod exec;
pub mod refexec;
pub mod serve;

pub use exec::{cosim, ExecReport};
pub use refexec::cosim_ref;
pub use serve::{BatchServer, BatchStats, Request as ServeRequest};
