//! L3 coordinator: the runtime that drives the Scalable Compute Fabric.
//!
//! Three halves, mirroring how GVSoC/DRAMSys separate function from
//! timing (DESIGN.md §3):
//!
//! * [`exec`] — **timing**: event-driven co-simulation of a lowered
//!   [`crate::compiler::FabricProgram`] on the shared simulation calendar
//!   (steps as events; tiles, the HBM port and (src, dst) links as
//!   resources with in-order wake queues), overlapping transfers with
//!   compute exactly as a doorbell-driven fabric run would.
//! * [`admit`] — **admission**: the multi-program layer that keeps the
//!   calendar alive across requests — batched admission at arbitrary
//!   simulated times, shared resources with deterministic policy-keyed
//!   tie-breaking (FIFO / priority / deadline), incremental
//!   re-simulation (structural closure, widened to the time horizon +
//!   settle fixed point under a time-varying cost model), O(1) span
//!   telemetry and queue pruning for unbounded serving runs.
//!   Single-program t=0 admission is pinned bit-identical to [`exec`]
//!   and [`refexec`] by `tests/admission_golden.rs`; the time-varying
//!   contracts by `tests/costmodel_golden.rs`. All engines price through
//!   the [`crate::fabric::CostModel`] layer (`[fabric.cost]`).
//! * [`refexec`] — the retained pre-rewrite list scheduler; differential
//!   golden tests pin the event-driven engine to its bit-exact answers
//!   (the `noc::refsim` pattern).
//! * [`serve`] — **function + orchestration**: a leader thread batches
//!   inference requests from worker threads (std::mpsc) and executes the
//!   AOT-compiled PJRT artifacts for bit-exact numerics; the co-sim
//!   session plugs in as a simulated-latency executor
//!   ([`serve::CosimExecutor`]), so the batch server can report fabric
//!   latencies for every batch it forms.
//! * [`shard`] — **scale-out**: [`shard::ShardedServer`] replicates
//!   whole sessions across N shards behind a deterministic seeded
//!   request router, drives them with open-loop arrival processes
//!   ([`crate::sim::ArrivalGen`]) under overload admission control
//!   (queue / shed / degrade via the existing policy keys), and merges
//!   per-request records in canonical order — replay-invariant across
//!   OS scheduling and shard execution order, pinned by
//!   `tests/serve_golden.rs` and `bench_serve`.
//!
//! The robustness layer threads through all of it: [`admit`]'s
//! `FaultySession` processes a seeded [`crate::sim::FaultPlan`] against
//! the live calendar (retry / re-map / shed per `RecoveryPolicy`),
//! [`serve::DegradedExecutor`] serves batches through it and surfaces
//! per-request [`admit::RequestOutcome`]s plus an episode-level
//! [`admit::DegradationReport`]; `tests/fault_golden.rs` pins the
//! empty-plan no-op and the incremental ≡ from-scratch replay.
//!
//! The end-to-end driver (examples/uav_vision.rs) runs both: PJRT for the
//! numbers, the co-simulator for latency/energy.

pub mod admit;
pub mod exec;
pub mod refexec;
pub mod serve;
pub mod shard;

pub use admit::{
    AdmissionQueue, AdmitMeta, AdmitPolicy, CosimSession, DegradationReport, FaultySession,
    ProgramHandle, RecoveryPolicy, RequestOutcome, StraddleStats,
};
pub use exec::{cosim, cosim_with, ExecReport, ProgramSpan};
pub use refexec::{cosim_ref, cosim_ref_with};
pub use serve::{BatchServer, BatchStats, CosimExecutor, DegradedExecutor, Request as ServeRequest};
pub use shard::{
    arrival_gen_from_config, AdmitDecision, OverloadPolicy, RequestRecord, ServeReport, ShardExec,
    ShardedServer,
};
