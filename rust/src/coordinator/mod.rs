//! L3 coordinator: the runtime that drives the Scalable Compute Fabric.
//!
//! Two halves, mirroring how GVSoC/DRAMSys separate function from timing
//! (DESIGN.md §3):
//!
//! * [`exec`] — **timing**: dependency-driven co-simulation of a lowered
//!   [`crate::compiler::FabricProgram`] over the fabric's tile / NoC /
//!   HBM models (overlapping transfers with compute, per-tile
//!   serialization, HBM bandwidth sharing).
//! * [`serve`] — **function + orchestration**: a leader thread batches
//!   inference requests from worker threads (std::mpsc) and executes the
//!   AOT-compiled PJRT artifacts for bit-exact numerics.
//!
//! The end-to-end driver (examples/uav_vision.rs) runs both: PJRT for the
//! numbers, the co-simulator for latency/energy.

pub mod exec;
pub mod serve;

pub use exec::{cosim, ExecReport};
pub use serve::{BatchServer, BatchStats, Request as ServeRequest};
