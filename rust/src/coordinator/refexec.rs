//! Reference timing co-simulation: the pre-rewrite one-pass list
//! scheduler, kept verbatim as the differential oracle (the
//! `noc::refsim` pattern) — now parameterized over the cost-model layer.
//!
//! [`super::exec::cosim`] is the event-driven engine that replaced this
//! loop; `cosim_ref` here is the retained original, and the differential
//! golden tests (`tests/cosim_golden.rs`) plus `benches/bench_cosim.rs`
//! require the two to emit **bit-identical** [`ExecReport`]s — makespan,
//! per-tile busy cycles, per-step completion times and energy bit
//! patterns — across workloads, map strategies and both bundled fabric
//! configs. The rewrite may change the clock speed and the memory shape
//! of the simulator, never its answers.
//!
//! Resource model (shared contract with the event-driven engine):
//! * each tile executes one `Exec` at a time (per-tile FIFO by program
//!   order);
//! * `Load`s share HBM bandwidth (serialized on the HBM port) but overlap
//!   with compute;
//! * `Transfer`s use the analytic NoC transport model (latency + energy),
//!   serialized per (src, dst) tile pair;
//! * a step starts when its dependencies are done AND its resource is
//!   free — classic resource-constrained list scheduling, which is what
//!   a doorbell-driven fabric run looks like at this abstraction level.
//!
//! # Time-varying cost models: the iterated list scheduler
//!
//! Under [`crate::fabric::InvariantCost`] the scheduler is a single pass,
//! exactly the pre-cost-layer code path. Under a time-varying model
//! ([`crate::fabric::TimeDependence::VaryingAfter`]), a single pass in
//! *program order* cannot be self-consistent (it prices steps before the
//! occupancy they should read exists), so [`cosim_ref_with`] iterates
//! Jacobi-style: pass `k+1` prices every step against the occupancy of
//! pass `k`'s settled schedule, until two consecutive passes produce the
//! same schedule bit-for-bit. Because models read occupancy of
//! **strictly earlier epochs** only (the `fabric::cost` purity
//! contract), each pass pins at least one more epoch prefix of the
//! unique fixed point, so the loop converges in at most
//! `makespan / epoch + 2` passes; a hard cap guards against models that
//! violate the contract. The converged schedule is the *same* fixed
//! point the event engine and the admission session reach by entirely
//! different routes — `tests/costmodel_golden.rs` pins all three.

use crate::compiler::{FabricProgram, Step};
use crate::fabric::{CostModel, Fabric, Occupancy, TimeDependence};
use crate::metrics::{Category, Metrics};
use crate::sim::Cycle;
use crate::Result;

use super::admit::MAX_SETTLE_PASSES;
use super::exec::ExecReport;

use anyhow::ensure;

/// One list-scheduler pass: prices every step in program order against
/// the (frozen) occupancy `occ`, returning the completion times and the
/// per-step costs/durations. This is the pre-rewrite loop, verbatim.
#[allow(clippy::type_complexity)]
fn pass(
    fabric: &Fabric,
    prog: &FabricProgram,
    model: &dyn CostModel,
    occ: &Occupancy,
) -> Result<(Vec<Cycle>, Vec<Metrics>, Vec<Cycle>)> {
    let n = prog.steps.len();
    let mut done = vec![0 as Cycle; n];
    let mut dur = vec![0 as Cycle; n];
    let mut cost = vec![Metrics::new(); n];
    let mut tile_free = vec![0 as Cycle; fabric.tile_count()];
    let mut hbm_free: Cycle = 0;
    // Per-(src tile, dst tile) transfer-path occupancy, flat-indexed by
    // the dense pair id `from * tile_count + to`. O(tiles^2) memory —
    // kept as-is in the reference; the event-driven engine keys link
    // resources sparsely instead.
    let nt = fabric.tile_count();
    let mut link_free: Vec<Cycle> = vec![0; nt * nt];

    for (i, step) in prog.steps.iter().enumerate() {
        let ready = step.deps().iter().map(|&d| done[d]).max().unwrap_or(0);
        match step {
            Step::Load { tile, bytes, .. } => {
                let start = ready.max(hbm_free);
                let c = model.feed(fabric, *tile, *bytes, start, occ);
                let finish = start + c.cycles;
                hbm_free = finish;
                done[i] = finish;
                dur[i] = c.cycles;
                cost[i] = c.with_cycles(0);
            }
            Step::Transfer { from, to, bytes, .. } => {
                let src = fabric.tiles[*from].node;
                let dst = fabric.tiles[*to].node;
                let key = *from * nt + *to;
                let start = ready.max(link_free[key]);
                let c = model.transport(fabric, src, dst, *bytes, start, occ);
                let finish = start + c.cycles;
                link_free[key] = finish;
                done[i] = finish;
                dur[i] = c.cycles;
                cost[i] = c.with_cycles(0);
            }
            Step::Exec { tile, compute, precision, .. } => {
                let start = ready.max(tile_free[*tile]);
                let c = model.execute(fabric, *tile, compute, *precision, start, occ)?;
                let finish = start + c.metrics.cycles;
                tile_free[*tile] = finish;
                done[i] = finish;
                dur[i] = c.metrics.cycles;
                cost[i] = c.metrics.with_cycles(0);
            }
        }
    }
    Ok((done, cost, dur))
}

/// Build the occupancy aggregates of a settled schedule.
fn occupancy_of(
    prog: &FabricProgram,
    epoch: Cycle,
    done: &[Cycle],
    dur: &[Cycle],
) -> Occupancy {
    let mut occ = Occupancy::new(epoch);
    for (i, step) in prog.steps.iter().enumerate() {
        occ.add_step(step, done[i] - dur[i], done[i]);
    }
    occ
}

/// Run the reference list-scheduler co-simulation under the fabric's
/// configured cost model (`[fabric.cost]`).
pub fn cosim_ref(fabric: &Fabric, prog: &FabricProgram) -> Result<ExecReport> {
    cosim_ref_with(fabric, prog, fabric.cost_model().as_ref())
}

/// Run the reference list scheduler with an explicit cost model:
/// a single pass for an invariant model (the pre-rewrite code path,
/// bit-identical), iterated to the unique fixed point for a
/// time-varying one (see module docs).
pub fn cosim_ref_with(
    fabric: &Fabric,
    prog: &FabricProgram,
    model: &dyn CostModel,
) -> Result<ExecReport> {
    let (done, cost, dur) = match model.time_dependence() {
        TimeDependence::Invariant => pass(fabric, prog, model, &Occupancy::disabled())?,
        TimeDependence::VaryingAfter(epoch) => {
            let mut cur = pass(fabric, prog, model, &Occupancy::new(epoch))?;
            let mut passes = 1usize;
            loop {
                let occ = occupancy_of(prog, epoch, &cur.0, &cur.2);
                let next = pass(fabric, prog, model, &occ)?;
                if next == cur {
                    break;
                }
                cur = next;
                passes += 1;
                ensure!(
                    passes <= MAX_SETTLE_PASSES,
                    "iterated list scheduler did not converge in {MAX_SETTLE_PASSES} passes \
                     (cost model reads non-strictly-earlier epochs?)"
                );
            }
            cur
        }
    };

    let n = prog.steps.len();
    let mut tile_busy = vec![0 as Cycle; fabric.tile_count()];
    let mut transfer_cycles: Cycle = 0;
    let mut exec_steps = 0usize;
    let mut total = Metrics::new();
    for (i, step) in prog.steps.iter().enumerate() {
        // Fold per-step costs in program order — the exact absorb
        // sequence of the pre-cost-layer scheduler, so energy bits match.
        total.absorb_parallel(&cost[i]);
        if let Step::Exec { tile, .. } = step {
            tile_busy[*tile] += dur[i];
            exec_steps += 1;
        } else {
            transfer_cycles += dur[i];
        }
    }
    let makespan = done.iter().copied().max().unwrap_or(0);
    total.cycles = makespan;
    // Single-program span, captured before the leakage term (the same
    // point `exec::cosim` captures it, so the bits agree).
    let span = super::exec::ProgramSpan {
        admitted_at: 0,
        finished_at: makespan,
        steps: n,
        exec_steps,
        transfer_cycles,
        ops: total.ops,
        bytes_moved: total.bytes_moved,
        energy_pj: total.total_energy_pj(),
    };
    // Fabric-level leakage over the episode.
    total.add_energy(
        Category::Leakage,
        makespan as f64 * fabric.tile_count() as f64 * 0.5,
    );
    Ok(ExecReport {
        cycles: makespan,
        metrics: total,
        tile_busy,
        step_done: done,
        transfer_cycles,
        exec_steps,
        programs: vec![span],
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::Precision;
    use crate::compiler::lowering::lower;
    use crate::compiler::mapper::{map_graph, MapStrategy};
    use crate::config::FabricConfig;
    use crate::coordinator::cosim;
    use crate::workloads;

    fn fabric() -> Fabric {
        Fabric::build(
            FabricConfig::from_toml(
                "[noc]\nwidth = 3\nheight = 3\n\
                 [[cu]]\nkind = \"npu\"\ntemplate = \"B\"\ncount = 4\n",
            )
            .unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn reference_still_schedules() {
        let g = workloads::mlp(8, 64, &[64, 32], 10, 1).unwrap();
        let f = fabric();
        let m = map_graph(&g, &f, MapStrategy::Greedy, Precision::Int8).unwrap();
        let p = lower(&g, &f, &m).unwrap();
        let r = cosim_ref(&f, &p).unwrap();
        assert!(r.cycles > 0);
        assert!(r.step_done.iter().all(|&d| d <= r.cycles));
    }

    #[test]
    fn event_engine_matches_reference_on_mlp() {
        let g = workloads::mlp(8, 64, &[64, 32], 10, 1).unwrap();
        let f = fabric();
        for s in [MapStrategy::RoundRobin, MapStrategy::Greedy] {
            let m = map_graph(&g, &f, s, Precision::Int8).unwrap();
            let p = lower(&g, &f, &m).unwrap();
            let a = cosim(&f, &p).unwrap();
            let b = cosim_ref(&f, &p).unwrap();
            assert!(a.bit_identical(&b), "{s:?}: engines diverged");
        }
    }

    #[test]
    fn iterated_scheduler_converges_under_congestion() {
        use crate::fabric::{CongestionKnobs, VaryingCost};
        let g = workloads::mlp(8, 64, &[64, 32], 10, 1).unwrap();
        let f = fabric();
        let m = map_graph(&g, &f, MapStrategy::Greedy, Precision::Int8).unwrap();
        let p = lower(&g, &f, &m).unwrap();
        let model = VaryingCost::congestion(256, CongestionKnobs { alpha: 0.5, cap: 4.0 });
        let a = cosim_ref_with(&f, &p, &model).unwrap();
        let b = cosim_ref_with(&f, &p, &model).unwrap();
        assert!(a.bit_identical(&b), "fixed point must be deterministic");
        let base = cosim_ref(&f, &p).unwrap();
        assert!(a.cycles >= base.cycles, "congestion can only stretch the makespan");
    }
}
