//! Reference timing co-simulation: the pre-rewrite one-pass list
//! scheduler, kept verbatim (the `noc::refsim` pattern).
//!
//! [`super::exec::cosim`] is the event-driven engine that replaced this
//! loop; `cosim_ref` here is the retained original, and the differential
//! golden tests (`tests/cosim_golden.rs`) plus `benches/bench_cosim.rs`
//! require the two to emit **bit-identical** [`ExecReport`]s — makespan,
//! per-tile busy cycles, per-step completion times and energy bit
//! patterns — across workloads, map strategies and both bundled fabric
//! configs. The rewrite may change the clock speed and the memory shape
//! of the simulator, never its answers.
//!
//! Resource model (shared contract with the event-driven engine):
//! * each tile executes one `Exec` at a time (per-tile FIFO by program
//!   order);
//! * `Load`s share HBM bandwidth (serialized on the HBM port) but overlap
//!   with compute;
//! * `Transfer`s use the analytic NoC transport model (latency + energy),
//!   serialized per (src, dst) tile pair;
//! * a step starts when its dependencies are done AND its resource is
//!   free — classic resource-constrained list scheduling, which is what
//!   a doorbell-driven fabric run looks like at this abstraction level.

use crate::compiler::{FabricProgram, Step};
use crate::fabric::Fabric;
use crate::metrics::{Category, Metrics};
use crate::sim::Cycle;
use crate::Result;

use super::exec::ExecReport;

/// Run the reference list-scheduler co-simulation (pre-rewrite code).
pub fn cosim_ref(fabric: &Fabric, prog: &FabricProgram) -> Result<ExecReport> {
    let n = prog.steps.len();
    let mut done = vec![0 as Cycle; n];
    let mut tile_free = vec![0 as Cycle; fabric.tile_count()];
    let mut tile_busy = vec![0 as Cycle; fabric.tile_count()];
    let mut hbm_free: Cycle = 0;
    // Per-(src tile, dst tile) transfer-path occupancy, flat-indexed by
    // the dense pair id `from * tile_count + to`. O(tiles^2) memory —
    // kept as-is in the reference; the event-driven engine keys link
    // resources sparsely instead.
    let nt = fabric.tile_count();
    let mut link_free: Vec<Cycle> = vec![0; nt * nt];
    let mut total = Metrics::new();
    let mut transfer_cycles: Cycle = 0;
    let mut exec_steps = 0usize;

    for (i, step) in prog.steps.iter().enumerate() {
        let ready = step.deps().iter().map(|&d| done[d]).max().unwrap_or(0);
        match step {
            Step::Load { tile, bytes, .. } => {
                let cost = fabric.feed(*tile, *bytes);
                let start = ready.max(hbm_free);
                let finish = start + cost.cycles;
                hbm_free = finish;
                done[i] = finish;
                transfer_cycles += cost.cycles;
                total.absorb_parallel(&cost.with_cycles(0));
            }
            Step::Transfer { from, to, bytes, .. } => {
                let src = fabric.tiles[*from].node;
                let dst = fabric.tiles[*to].node;
                let cost = fabric.transport(src, dst, *bytes);
                let key = *from * nt + *to;
                let start = ready.max(link_free[key]);
                let finish = start + cost.cycles;
                link_free[key] = finish;
                done[i] = finish;
                transfer_cycles += cost.cycles;
                total.absorb_parallel(&cost.with_cycles(0));
            }
            Step::Exec { tile, compute, precision, .. } => {
                let cost = fabric.tiles[*tile].execute(compute, *precision)?;
                let start = ready.max(tile_free[*tile]);
                let finish = start + cost.metrics.cycles;
                tile_free[*tile] = finish;
                tile_busy[*tile] += cost.metrics.cycles;
                done[i] = finish;
                exec_steps += 1;
                total.absorb_parallel(&cost.metrics.with_cycles(0));
            }
        }
    }
    let makespan = done.iter().copied().max().unwrap_or(0);
    total.cycles = makespan;
    // Single-program span, captured before the leakage term (the same
    // point `exec::cosim` captures it, so the bits agree).
    let span = super::exec::ProgramSpan {
        admitted_at: 0,
        finished_at: makespan,
        steps: n,
        exec_steps,
        transfer_cycles,
        ops: total.ops,
        bytes_moved: total.bytes_moved,
        energy_pj: total.total_energy_pj(),
    };
    // Fabric-level leakage over the episode.
    total.add_energy(
        Category::Leakage,
        makespan as f64 * fabric.tile_count() as f64 * 0.5,
    );
    Ok(ExecReport {
        cycles: makespan,
        metrics: total,
        tile_busy,
        step_done: done,
        transfer_cycles,
        exec_steps,
        programs: vec![span],
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::Precision;
    use crate::compiler::lowering::lower;
    use crate::compiler::mapper::{map_graph, MapStrategy};
    use crate::config::FabricConfig;
    use crate::coordinator::cosim;
    use crate::workloads;

    fn fabric() -> Fabric {
        Fabric::build(
            FabricConfig::from_toml(
                "[noc]\nwidth = 3\nheight = 3\n\
                 [[cu]]\nkind = \"npu\"\ntemplate = \"B\"\ncount = 4\n",
            )
            .unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn reference_still_schedules() {
        let g = workloads::mlp(8, 64, &[64, 32], 10, 1).unwrap();
        let f = fabric();
        let m = map_graph(&g, &f, MapStrategy::Greedy, Precision::Int8).unwrap();
        let p = lower(&g, &f, &m).unwrap();
        let r = cosim_ref(&f, &p).unwrap();
        assert!(r.cycles > 0);
        assert!(r.step_done.iter().all(|&d| d <= r.cycles));
    }

    #[test]
    fn event_engine_matches_reference_on_mlp() {
        let g = workloads::mlp(8, 64, &[64, 32], 10, 1).unwrap();
        let f = fabric();
        for s in [MapStrategy::RoundRobin, MapStrategy::Greedy] {
            let m = map_graph(&g, &f, s, Precision::Int8).unwrap();
            let p = lower(&g, &f, &m).unwrap();
            let a = cosim(&f, &p).unwrap();
            let b = cosim_ref(&f, &p).unwrap();
            assert!(a.bit_identical(&b), "{s:?}: engines diverged");
        }
    }
}
