//! Sharded steady-state serving: N replicated fabric sessions behind a
//! deterministic request router.
//!
//! [`super::serve::BatchServer`] drives exactly one
//! [`CosimSession`]/[`FaultySession`] on one thread. This module grows
//! that toward the production shape: a [`ShardedServer`] owns N
//! *replicated* fabric sessions (each its own session over the shared
//! `&Fabric`, optionally multi-threaded internally via `[session]
//! threads` — each shard inherits the fabric default and
//! [`ShardedServer::set_threads`] overrides it), a load-balancing front
//! end routes every request to a shard, each shard simulates its slice
//! of the open-loop stream ([`crate::sim::ArrivalGen`]), and the
//! per-request results merge back in canonical request order.
//!
//! # The serving determinism contract
//!
//! Replay invariance is layered on three legs, each independent of OS
//! scheduling and shard execution order:
//!
//! 1. **Hash routing.** Request `seq` goes to shard
//!    `CounterRng::at3(ROUTE_DOMAIN, seq, 0) % N`: a pure function of
//!    (router seed, request sequence number), never of worker timing.
//!    The domain constant separates the router's draw stream from the
//!    arrival generator's, so shard choice never correlates with gap
//!    length even under a shared seed.
//! 2. **Independent shards.** Each shard owns its whole session; no
//!    state is shared between shards during a serve call, and a shard
//!    processes its requests in ascending `seq` order. A shard's
//!    records are therefore a pure function of (its request subset, its
//!    session history) — identical whether shards run on the
//!    [`crate::sim::WorkerPool`], sequentially, or sequentially in
//!    reverse ([`ShardExec`] is the property-test seam).
//! 3. **Canonical merge.** Records merge by ascending `seq`, and every
//!    [`ServeReport`] field is integer-valued, so report equality is
//!    bitwise.
//!
//! Consequences, pinned by `tests/serve_golden.rs` and `bench_serve`
//! (which panics on divergence in CI):
//!
//! * **N=1 differential**: a 1-shard server fed the uniform arrival
//!   trace `0, gap, 2·gap, …` performs the exact admit/drain sequence
//!   of [`super::serve::CosimExecutor`] — every `ExecReport`,
//!   `ProgramSpan` and energy bit pattern identical, same cost-model
//!   `Arc`. Fed a [`super::serve::DegradedExecutor::admissions`] trace,
//!   a 1-shard degraded server replays `run_degraded` outcome-for-
//!   outcome (the recorded trace makes every fault-floor bump a no-op).
//! * **N>1 replay**: same seed/config ⇒ identical merged report and
//!   identical per-shard `ExecReport`s at any thread count and any
//!   [`ShardExec`] order.
//!
//! # Overload admission control
//!
//! A shard's *backlog* at a request's arrival is `busy_until −
//! arrival`: how far the shard's last completion outruns the open-loop
//! clock. When a backlog cap is set ([`ShardedServer::set_overload`])
//! and exceeded, the [`OverloadPolicy`] decides:
//!
//! * [`OverloadPolicy::Queue`] — admit anyway (unbounded queueing; the
//!   default, and the cap only classifies).
//! * [`OverloadPolicy::Shed`] — drop the request before admission; it
//!   never touches the session, reports a [`AdmitDecision::Shed`]
//!   record, and is excluded from the sojourn percentiles (a zero
//!   would deflate the tail exactly when the fabric is at its worst).
//! * [`OverloadPolicy::Degrade`] — admit as *background* work through
//!   the session's existing Deadline queue keys: the server runs its
//!   sessions under [`AdmitPolicy::Deadline`], normal requests get
//!   `deadline = arrival + cap` (EDF over those is FIFO, since the
//!   deadline is monotone in arrival), and overload arrivals get
//!   `deadline = Cycle::MAX` — they sort after every normal request,
//!   so later normal arrivals preempt them on the shared queues.
//!
//! # Long-run steady state
//!
//! [`ShardedServer::set_prune`] prunes each shard at horizon cadence
//! (`prune_completed_before(arrival − horizon)`, optionally discarding
//! pruned history) so an unbounded serving run retains state
//! proportional to the live window, not to every request ever served —
//! the footprint regression in `tests/serve_golden.rs` holds the probes
//! bounded over ≥10× the horizon under a bursty diurnal trace.

use std::sync::Arc;

use anyhow::ensure;

use super::admit::{
    AdmitMeta, AdmitPolicy, CosimSession, FaultySession, ProgramHandle, RecoveryPolicy,
    RequestOutcome,
};
use super::exec::{ExecReport, ProgramSpan};
use crate::compiler::FabricProgram;
use crate::config::ServeConfig;
use crate::fabric::{CostModel, Fabric};
use crate::sim::{
    ArrivalGen, ArrivalProcess, CounterRng, Cycle, FaultConfig, FaultPlan, StreamingHist,
    WorkerPool,
};
use crate::Result;

/// Domain constant separating the router's counter-RNG stream from the
/// arrival generator's (which draws at plain positions): shard choice
/// must not correlate with gap length under a shared seed.
const ROUTE_DOMAIN: u64 = 0x5EBD_17E0_4A7C_3B21;

/// What to do with a request arriving into an over-cap backlog (module
/// docs, overload section).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum OverloadPolicy {
    /// Admit anyway — unbounded queueing (default).
    #[default]
    Queue,
    /// Drop before admission.
    Shed,
    /// Admit as background work via `deadline = Cycle::MAX`.
    Degrade,
}

/// Shard execution order — the replay-invariance property-test seam.
/// Every variant produces bit-identical reports (module docs, leg 2).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum ShardExec {
    /// Fan shards out on the worker pool (shard 0 inline on the
    /// caller, like the admission drain).
    #[default]
    Parallel,
    /// Run shards 0..N in order on the calling thread.
    Sequential,
    /// Run shards N..0 in reverse on the calling thread.
    SequentialReversed,
}

/// Front-end admission decision for one request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmitDecision {
    /// Admitted normally.
    Served,
    /// Admitted as background work under [`OverloadPolicy::Degrade`].
    Degraded,
    /// Dropped before admission under [`OverloadPolicy::Shed`].
    Shed,
}

/// Per-request serving record, merged in canonical `seq` order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RequestRecord {
    /// Global request sequence number (the routing key).
    pub seq: u64,
    /// Shard the router assigned.
    pub shard: usize,
    /// Open-loop arrival cycle.
    pub arrival: Cycle,
    /// Actual admission cycle (arrival, bumped past any fault floor;
    /// equals `arrival` for shed requests, which are never admitted).
    pub admitted_at: Cycle,
    pub decision: AdmitDecision,
    /// Completion cycle (= `arrival` for overload-shed requests).
    pub finished_at: Cycle,
    /// `finished_at − arrival`: simulated queueing + service, anchored
    /// at the open-loop arrival (0 for overload-shed requests —
    /// excluded from percentiles, not counted as zero).
    pub sojourn: Cycle,
    /// Recovery outcome (fault-injected shards only; `None` on plain
    /// shards and for overload-shed requests).
    pub outcome: Option<RequestOutcome>,
}

impl RequestRecord {
    /// Did the fabric complete this request? False for overload sheds
    /// and fault-policy sheds alike.
    pub fn completed(&self) -> bool {
        !matches!(self.decision, AdmitDecision::Shed)
            && !self.outcome.is_some_and(|o| o.shed)
    }
}

/// Merged serving telemetry of one [`ShardedServer::serve_trace`] call.
/// All fields are integer-valued, so `==` is bitwise replay equality.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ServeReport {
    /// One record per request, ascending `seq`.
    pub records: Vec<RequestRecord>,
    /// Requests admitted normally.
    pub admitted: usize,
    /// Requests shed by the overload policy (never admitted).
    pub shed: usize,
    /// Requests admitted as background work.
    pub degraded: usize,
    /// Requests shed by a shard's fault-recovery policy after admission.
    pub fault_shed: usize,
    /// First open-loop arrival of the trace.
    pub first_arrival: Cycle,
    /// Last completion over all completed requests.
    pub last_finish: Cycle,
    /// Exact histogram of *completed* sojourns, recorded per shard
    /// during the run and merged O(1) per shard at report time —
    /// percentile queries are O(range), not O(n log n) per call.
    /// Histogram equality is multiset equality, so report `==` stays
    /// bitwise replay equality.
    pub sojourn_hist: StreamingHist,
}

impl ServeReport {
    /// Requests the fabric completed (admitted or degraded, minus
    /// fault-policy sheds).
    pub fn completed(&self) -> usize {
        self.records.iter().filter(|r| r.completed()).count()
    }

    /// Simulated span of the episode: last completion − first arrival.
    pub fn span_cycles(&self) -> Cycle {
        self.last_finish.saturating_sub(self.first_arrival)
    }

    /// Sojourn percentile over *completed* requests, fabric cycles.
    ///
    /// Answered as a k-th order statistic over the pre-merged
    /// [`ServeReport::sojourn_hist`] with the exact index rule of
    /// `serve::percentile` (`k = round((n-1)·q)`), so the result is
    /// bit-identical to collecting and sorting the completed sojourns —
    /// pinned by `sojourn_percentiles_match_sorted_vec_bitwise` below —
    /// without the per-call O(n log n) sort the old path paid 3× per
    /// report.
    pub fn sojourn_percentile(&self, q: f64) -> f64 {
        let n = self.sojourn_hist.count();
        if n == 0 {
            return 0.0;
        }
        let k = ((n - 1) as f64 * q).round() as u64;
        self.sojourn_hist.kth(k).expect("percentile index in range") as f64
    }

    pub fn p50_sojourn_cycles(&self) -> f64 {
        self.sojourn_percentile(0.50)
    }

    pub fn p99_sojourn_cycles(&self) -> f64 {
        self.sojourn_percentile(0.99)
    }

    pub fn p999_sojourn_cycles(&self) -> f64 {
        self.sojourn_percentile(0.999)
    }
}

/// One shard's replicated session: plain or fault-injected.
enum Engine<'f> {
    Plain(CosimSession<'f>),
    Faulty(FaultySession<'f>),
}

impl<'f> Engine<'f> {
    fn set_policy(&mut self, p: AdmitPolicy) -> Result<()> {
        match self {
            Engine::Plain(s) => s.set_policy(p),
            Engine::Faulty(s) => s.set_policy(p),
        }
    }

    fn set_threads(&mut self, t: usize) {
        match self {
            Engine::Plain(s) => s.set_threads(t),
            Engine::Faulty(s) => s.set_threads(t),
        }
    }

    fn admit_with(&mut self, prog: &FabricProgram, at: Cycle, meta: AdmitMeta) -> Result<ProgramHandle> {
        match self {
            Engine::Plain(s) => s.admit_with(prog, at, meta),
            Engine::Faulty(s) => s.admit_with(prog, at, meta),
        }
    }

    fn run_to_drain(&mut self) -> Result<()> {
        match self {
            Engine::Plain(s) => s.run_to_drain(),
            Engine::Faulty(s) => s.run_to_drain(),
        }
    }

    fn span(&self, h: ProgramHandle) -> ProgramSpan {
        match self {
            Engine::Plain(s) => s.span(h),
            Engine::Faulty(s) => s.span(h),
        }
    }

    fn fault_floor(&self) -> Cycle {
        match self {
            Engine::Plain(_) => 0,
            Engine::Faulty(s) => s.fault_floor(),
        }
    }

    fn outcome(&self, h: ProgramHandle) -> Option<RequestOutcome> {
        match self {
            Engine::Plain(_) => None,
            Engine::Faulty(s) => Some(s.outcome(h)),
        }
    }

    fn report(&mut self) -> Result<ExecReport> {
        match self {
            Engine::Plain(s) => s.report(),
            Engine::Faulty(s) => s.report(),
        }
    }

    fn cost_model(&self) -> &Arc<dyn CostModel> {
        match self {
            Engine::Plain(s) => s.cost_model(),
            Engine::Faulty(s) => s.cost_model(),
        }
    }

    fn prune_completed_before(&mut self, t: Cycle) -> Result<usize> {
        match self {
            Engine::Plain(s) => s.prune_completed_before(t),
            Engine::Faulty(s) => s.prune_completed_before(t),
        }
    }

    fn set_discard_pruned(&mut self, on: bool) {
        match self {
            Engine::Plain(s) => s.set_discard_pruned(on),
            Engine::Faulty(s) => s.set_discard_pruned(on),
        }
    }

    fn queue_footprint(&self) -> (usize, usize) {
        match self {
            Engine::Plain(s) => s.queue_footprint(),
            Engine::Faulty(s) => s.queue_footprint(),
        }
    }

    fn history_footprint(&self) -> usize {
        match self {
            Engine::Plain(s) => s.history_footprint(),
            Engine::Faulty(s) => s.history_footprint(),
        }
    }
}

struct ShardSlot<'f> {
    engine: Engine<'f>,
    /// Last completion cycle of this shard's completed requests — the
    /// backlog anchor for overload detection.
    busy_until: Cycle,
    /// Last pruning cutoff (prune runs at horizon cadence).
    last_prune: Cycle,
}

/// Routed request: global sequence number + open-loop arrival.
#[derive(Debug, Clone, Copy)]
struct WorkItem {
    seq: u64,
    arrival: Cycle,
}

/// Per-serve-call knobs shared with every shard run.
#[derive(Clone, Copy)]
struct RunCfg {
    overload: OverloadPolicy,
    cap: Cycle,
    prune: Cycle,
}

/// The sharded steady-state serving layer (module docs).
pub struct ShardedServer<'f> {
    shards: Vec<ShardSlot<'f>>,
    rng: CounterRng,
    overload: OverloadPolicy,
    cap: Cycle,
    exec: ShardExec,
    prune_horizon: Cycle,
    pool: Option<WorkerPool>,
    /// Next global request sequence number (the routing key).
    seq: u64,
    last_arrival: Cycle,
}

impl<'f> ShardedServer<'f> {
    /// `nshards` replicated plain sessions pricing through the fabric's
    /// configured cost model; router seed 0 (see
    /// [`ShardedServer::set_seed`]). Each shard inherits the fabric's
    /// `[session] threads` for its internal calendar drains.
    pub fn new(fabric: &'f Fabric, nshards: usize) -> Self {
        Self::build(nshards, |_| Engine::Plain(CosimSession::new(fabric)))
    }

    /// Replicated plain sessions pricing through an explicit cost model
    /// — every shard shares the same `Arc` (pinned by the goldens).
    pub fn with_model(fabric: &'f Fabric, nshards: usize, model: Arc<dyn CostModel>) -> Self {
        Self::build(nshards, |_| {
            Engine::Plain(CosimSession::with_model(fabric, model.clone()))
        })
    }

    /// Replicated fault-injected sessions: each shard generates its own
    /// plan from `cfg` — [`FaultPlan::generate`] is deterministic per
    /// config, so every shard faces the identical fault timeline.
    pub fn degraded(
        fabric: &'f Fabric,
        nshards: usize,
        cfg: &FaultConfig,
        policy: RecoveryPolicy,
    ) -> Result<Self> {
        let mut engines = Vec::with_capacity(nshards);
        for _ in 0..nshards {
            engines.push(Engine::Faulty(FaultySession::new(fabric, cfg, policy)?));
        }
        Ok(Self::from_engines(engines))
    }

    /// Replicated fault-injected sessions over an explicit plan (each
    /// shard gets a clone).
    pub fn degraded_with_plan(
        fabric: &'f Fabric,
        nshards: usize,
        plan: &FaultPlan,
        cfg: &FaultConfig,
        policy: RecoveryPolicy,
    ) -> Result<Self> {
        let mut engines = Vec::with_capacity(nshards);
        for _ in 0..nshards {
            engines.push(Engine::Faulty(FaultySession::with_plan(
                fabric,
                plan.clone(),
                cfg,
                policy,
            )?));
        }
        Ok(Self::from_engines(engines))
    }

    /// Build from the fabric's validated `[serve]` section: shard
    /// count, router seed, overload policy + backlog cap. (Arrival
    /// generation is the caller's side of the open loop — pair with
    /// [`arrival_gen_from_config`].)
    ///
    /// When the config also carries a live `[fault]` section
    /// (non-inert: positive horizon and at least one positive
    /// probability), every shard is a [`FaultySession`] under the
    /// default [`RecoveryPolicy`] — the TOML pair `[serve]` + `[fault]`
    /// means *degraded serving*, not silently-plain sessions. Both
    /// sections are re-validated here so hand-built configs get the
    /// same schema errors as loaded ones. Explicit policies or plans
    /// go through [`ShardedServer::degraded`] /
    /// [`ShardedServer::degraded_with_plan`].
    pub fn from_config(fabric: &'f Fabric) -> Result<Self> {
        let cfg = &fabric.cfg.serve;
        cfg.validate()?;
        let fault = &fabric.cfg.fault;
        let mut srv = if fault.is_inert() {
            Self::new(fabric, cfg.shards)
        } else {
            fault.validate()?;
            Self::degraded(fabric, cfg.shards, fault, RecoveryPolicy::default())?
        };
        srv.set_seed(cfg.seed)?;
        let overload = match cfg.overload.as_str() {
            "queue" => OverloadPolicy::Queue,
            "shed" => OverloadPolicy::Shed,
            "degrade" => OverloadPolicy::Degrade,
            other => anyhow::bail!("serve.overload: unknown policy {other:?}"),
        };
        srv.set_overload(overload, cfg.queue_cap_cycles)?;
        Ok(srv)
    }

    fn build(nshards: usize, mut make: impl FnMut(usize) -> Engine<'f>) -> Self {
        let engines = (0..nshards).map(&mut make).collect();
        Self::from_engines(engines)
    }

    fn from_engines(engines: Vec<Engine<'f>>) -> Self {
        assert!(!engines.is_empty(), "a sharded server needs at least one shard");
        ShardedServer {
            shards: engines
                .into_iter()
                .map(|engine| ShardSlot { engine, busy_until: 0, last_prune: 0 })
                .collect(),
            rng: CounterRng::new(0),
            overload: OverloadPolicy::default(),
            cap: 0,
            exec: ShardExec::default(),
            prune_horizon: 0,
            pool: None,
            seq: 0,
            last_arrival: 0,
        }
    }

    pub fn nshards(&self) -> usize {
        self.shards.len()
    }

    /// Re-seed the request router. Must precede the first request — the
    /// routing of already-served requests is history.
    pub fn set_seed(&mut self, seed: u64) -> Result<()> {
        ensure!(self.seq == 0, "router seed must be set before the first request");
        self.rng = CounterRng::new(seed);
        Ok(())
    }

    /// Select the overload policy and backlog cap (cycles). Must
    /// precede the first request: [`OverloadPolicy::Degrade`] switches
    /// every shard session to [`AdmitPolicy::Deadline`], and queue keys
    /// are baked in at admission. `cap = 0` means unbounded (only legal
    /// for [`OverloadPolicy::Queue`] — a cap-less shed/degrade policy
    /// would never trigger).
    pub fn set_overload(&mut self, policy: OverloadPolicy, cap: Cycle) -> Result<()> {
        ensure!(self.seq == 0, "overload policy must be set before the first request");
        if !matches!(policy, OverloadPolicy::Queue) {
            ensure!(cap > 0, "shed/degrade overload policies need a backlog cap");
        }
        let admit = if matches!(policy, OverloadPolicy::Degrade) {
            AdmitPolicy::Deadline
        } else {
            AdmitPolicy::Fifo
        };
        for s in &mut self.shards {
            s.engine.set_policy(admit)?;
        }
        self.overload = policy;
        self.cap = cap;
        Ok(())
    }

    /// Shard execution order (replay-invariant; default parallel).
    pub fn set_shard_exec(&mut self, exec: ShardExec) {
        self.exec = exec;
    }

    /// Worker threads for every shard's *internal* calendar drains
    /// (orthogonal to shard fan-out; bit-identical at any count).
    pub fn set_threads(&mut self, threads: usize) {
        for s in &mut self.shards {
            s.engine.set_threads(threads);
        }
    }

    /// Enable steady-state pruning: every shard prunes
    /// `completed_before(arrival − horizon)` at horizon cadence;
    /// `discard` additionally drops pruned per-step history
    /// ([`CosimSession::set_discard_pruned`]). `horizon = 0` disables.
    pub fn set_prune(&mut self, horizon: Cycle, discard: bool) {
        self.prune_horizon = horizon;
        for s in &mut self.shards {
            s.engine.set_discard_pruned(discard);
        }
    }

    /// Shard `s`'s cost model (the same `Arc` across shards for
    /// [`ShardedServer::with_model`] servers).
    pub fn shard_cost_model(&self, s: usize) -> &Arc<dyn CostModel> {
        self.shards[s].engine.cost_model()
    }

    /// Shard `s`'s merged execution report (errors if that shard
    /// discarded pruned history).
    pub fn shard_report(&mut self, s: usize) -> Result<ExecReport> {
        self.shards[s].engine.report()
    }

    /// Every shard's merged execution report, shard order.
    pub fn shard_reports(&mut self) -> Result<Vec<ExecReport>> {
        (0..self.shards.len()).map(|s| self.shard_report(s)).collect()
    }

    /// Worst-shard queue footprint `(longest resource queue, id-table
    /// length)` — the steady-state regression probe.
    pub fn queue_footprint(&self) -> (usize, usize) {
        let mut worst = (0, 0);
        for s in &self.shards {
            let (q, ids) = s.engine.queue_footprint();
            worst = (worst.0.max(q), worst.1.max(ids));
        }
        worst
    }

    /// Total retained per-step history across shards.
    pub fn history_footprint(&self) -> usize {
        self.shards.iter().map(|s| s.engine.history_footprint()).sum()
    }

    /// Serve `n` arrivals drawn from the open-loop generator.
    pub fn serve(
        &mut self,
        prog: &FabricProgram,
        gen: &mut ArrivalGen,
        n: usize,
    ) -> Result<ServeReport> {
        let arrivals = gen.take_trace(n);
        self.serve_trace(prog, &arrivals)
    }

    /// Serve an explicit nondecreasing arrival trace (each request one
    /// instance of `prog`): route, execute every shard's slice, merge
    /// records in canonical `seq` order. Arrival times are global
    /// simulated cycles and must not regress across calls.
    pub fn serve_trace(&mut self, prog: &FabricProgram, arrivals: &[Cycle]) -> Result<ServeReport> {
        ensure!(
            arrivals.windows(2).all(|w| w[0] <= w[1]),
            "open-loop arrivals must be nondecreasing"
        );
        if let Some(&first) = arrivals.first() {
            ensure!(
                first >= self.last_arrival,
                "arrival {first} regresses behind already-served cycle {}",
                self.last_arrival
            );
        }
        let n = self.shards.len();
        let mut work: Vec<Vec<WorkItem>> = vec![Vec::new(); n];
        for &arrival in arrivals {
            let seq = self.seq;
            self.seq += 1;
            let shard = (self.rng.at3(ROUTE_DOMAIN, seq, 0) % n as u64) as usize;
            work[shard].push(WorkItem { seq, arrival });
            self.last_arrival = arrival;
        }
        let cfg = RunCfg { overload: self.overload, cap: self.cap, prune: self.prune_horizon };

        let mut outs: Vec<Option<Result<ShardOut>>> = Vec::with_capacity(n);
        outs.resize_with(n, || None);
        match self.exec {
            ShardExec::Sequential => {
                for (s, slot) in self.shards.iter_mut().enumerate() {
                    outs[s] = Some(run_shard(slot, s, prog, &work[s], cfg));
                }
            }
            ShardExec::SequentialReversed => {
                for (s, slot) in self.shards.iter_mut().enumerate().rev() {
                    outs[s] = Some(run_shard(slot, s, prog, &work[s], cfg));
                }
            }
            ShardExec::Parallel => {
                if n == 1 {
                    outs[0] = Some(run_shard(&mut self.shards[0], 0, prog, &work[0], cfg));
                } else {
                    if self.pool.as_ref().map_or(true, |p| p.workers() < n - 1) {
                        self.pool = Some(WorkerPool::new(n - 1));
                    }
                    let pool = self.pool.as_mut().expect("multi-shard serve owns a pool");
                    let work_ro: &[Vec<WorkItem>] = &work;
                    let mut slots: &mut [ShardSlot] = &mut self.shards;
                    let mut outs_rest: &mut [Option<Result<ShardOut>>] = &mut outs;
                    pool.scoped(|scope| {
                        let mut own = None;
                        for s in 0..n {
                            let (slot, rest) =
                                std::mem::take(&mut slots).split_first_mut().expect("slot per shard");
                            slots = rest;
                            let (out, rest) = std::mem::take(&mut outs_rest)
                                .split_first_mut()
                                .expect("out per shard");
                            outs_rest = rest;
                            if s == 0 {
                                // Shard 0 runs on this thread below —
                                // N shards cost N−1 handoffs.
                                own = Some((slot, out));
                            } else {
                                scope.execute(move || {
                                    *out = Some(run_shard(slot, s, prog, &work_ro[s], cfg));
                                });
                            }
                        }
                        let (slot, out) = own.expect("at least one shard");
                        *out = Some(run_shard(slot, 0, prog, &work_ro[0], cfg));
                    });
                }
            }
        }

        // Canonical merge: lowest-shard error surfaces first (a pure
        // function of the routing, not of execution order); records
        // sort by global sequence number; per-shard sojourn histograms
        // merge by count addition (order-independent).
        let mut records = Vec::with_capacity(arrivals.len());
        let mut sojourn_hist = StreamingHist::new();
        for out in outs {
            let shard_out = out.expect("every shard ran")?;
            records.extend(shard_out.records);
            sojourn_hist.merge(&shard_out.sojourns);
        }
        records.sort_unstable_by_key(|r| r.seq);

        let mut report = ServeReport {
            admitted: 0,
            shed: 0,
            degraded: 0,
            fault_shed: 0,
            first_arrival: arrivals.first().copied().unwrap_or(0),
            last_finish: 0,
            records,
            sojourn_hist,
        };
        for r in &report.records {
            match r.decision {
                AdmitDecision::Served => report.admitted += 1,
                AdmitDecision::Degraded => report.degraded += 1,
                AdmitDecision::Shed => report.shed += 1,
            }
            if r.outcome.is_some_and(|o| o.shed) {
                report.fault_shed += 1;
            }
            if r.completed() {
                report.last_finish = report.last_finish.max(r.finished_at);
            }
        }
        Ok(report)
    }
}

/// One shard's contribution to the merged report: its records plus a
/// shard-local histogram of completed sojourns, built as records are
/// produced so report time merges histograms instead of re-sorting.
struct ShardOut {
    records: Vec<RequestRecord>,
    sojourns: StreamingHist,
}

impl ShardOut {
    fn push(&mut self, rec: RequestRecord) {
        if rec.completed() {
            self.sojourns.record(rec.sojourn);
        }
        self.records.push(rec);
    }
}

/// One shard's slice of the trace, in ascending `seq` order: overload
/// classification against the shard backlog, admission (bumped past any
/// fault floor), drain to quiescence, and horizon-cadence pruning.
fn run_shard(
    slot: &mut ShardSlot<'_>,
    shard: usize,
    prog: &FabricProgram,
    work: &[WorkItem],
    cfg: RunCfg,
) -> Result<ShardOut> {
    let mut out = ShardOut {
        records: Vec::with_capacity(work.len()),
        sojourns: StreamingHist::new(),
    };
    for w in work {
        let backlog = slot.busy_until.saturating_sub(w.arrival);
        let overloaded = cfg.cap > 0 && backlog > cfg.cap;
        if overloaded && matches!(cfg.overload, OverloadPolicy::Shed) {
            out.push(RequestRecord {
                seq: w.seq,
                shard,
                arrival: w.arrival,
                admitted_at: w.arrival,
                decision: AdmitDecision::Shed,
                finished_at: w.arrival,
                sojourn: 0,
                outcome: None,
            });
            continue;
        }
        let degraded = overloaded && matches!(cfg.overload, OverloadPolicy::Degrade);
        let meta = if matches!(cfg.overload, OverloadPolicy::Degrade) {
            // Deadline keys carry the policy: normal requests are EDF ≡
            // FIFO (deadline monotone in arrival), background requests
            // sort after every finite deadline.
            AdmitMeta {
                priority: 0,
                deadline: if degraded { Cycle::MAX } else { w.arrival.saturating_add(cfg.cap) },
            }
        } else {
            AdmitMeta::default()
        };
        let at = w.arrival.max(slot.engine.fault_floor());
        let h = slot.engine.admit_with(prog, at, meta)?;
        slot.engine.run_to_drain()?;
        let span = slot.engine.span(h);
        let outcome = slot.engine.outcome(h);
        let fault_shed = outcome.is_some_and(|o| o.shed);
        if !fault_shed {
            slot.busy_until = slot.busy_until.max(span.finished_at);
        }
        out.push(RequestRecord {
            seq: w.seq,
            shard,
            arrival: w.arrival,
            admitted_at: at,
            decision: if degraded { AdmitDecision::Degraded } else { AdmitDecision::Served },
            finished_at: span.finished_at,
            sojourn: span.finished_at.saturating_sub(w.arrival),
            outcome,
        });
        if cfg.prune > 0 {
            let cutoff = w.arrival.saturating_sub(cfg.prune);
            if cutoff > slot.last_prune.saturating_add(cfg.prune) {
                slot.engine.prune_completed_before(cutoff)?;
                slot.last_prune = cutoff;
            }
        }
    }
    Ok(out)
}

/// Build the open-loop arrival generator a validated `[serve]` section
/// describes (the caller's half of [`ShardedServer::from_config`]).
pub fn arrival_gen_from_config(cfg: &ServeConfig) -> Result<ArrivalGen> {
    let process = match cfg.arrival.as_str() {
        "uniform" => ArrivalProcess::Uniform { gap: cfg.mean_gap_cycles },
        "poisson" => ArrivalProcess::Poisson { mean_gap: cfg.mean_gap_cycles },
        "trace" => ArrivalProcess::Trace { gaps: cfg.trace_gaps.clone() },
        other => anyhow::bail!("serve.arrival: unknown process {other:?}"),
    };
    let mut gen = ArrivalGen::new(process, cfg.seed);
    if cfg.diurnal_period_cycles > 0 {
        gen = gen.with_diurnal(cfg.diurnal_period_cycles, cfg.diurnal_amplitude);
    }
    Ok(gen)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::Precision;
    use crate::compiler::lowering::lower;
    use crate::compiler::mapper::{map_graph, MapStrategy};
    use crate::config::FabricConfig;
    use crate::coordinator::serve::CosimExecutor;
    use crate::testutil::prop;
    use crate::workloads;

    fn fabric() -> Fabric {
        Fabric::build(
            FabricConfig::from_toml(
                "[noc]\nwidth = 3\nheight = 3\n\
                 [[cu]]\nkind = \"npu\"\ntemplate = \"B\"\ncount = 4\n",
            )
            .unwrap(),
        )
        .unwrap()
    }

    fn program(fabric: &Fabric) -> FabricProgram {
        let g = workloads::mlp(4, 32, &[16], 8, 1).unwrap();
        let m = map_graph(&g, fabric, MapStrategy::Greedy, Precision::Int8).unwrap();
        lower(&g, fabric, &m).unwrap()
    }

    #[test]
    fn router_is_reasonably_balanced() {
        let rng = CounterRng::new(7);
        for n in [2usize, 4, 8] {
            let mut counts = vec![0usize; n];
            let total = 4_000u64;
            for seq in 0..total {
                counts[(rng.at3(ROUTE_DOMAIN, seq, 0) % n as u64) as usize] += 1;
            }
            let expect = total as usize / n;
            for (s, &c) in counts.iter().enumerate() {
                assert!(
                    c > expect / 2 && c < expect * 2,
                    "shard {s}/{n} got {c} of {total} (expected ~{expect})"
                );
            }
        }
    }

    #[test]
    fn router_replays_and_decorrelates_from_arrivals() {
        prop::check(32, |rng| {
            let seed = rng.next_u64();
            let n = 2 + (rng.next_u64() % 7) as usize;
            let a = CounterRng::new(seed);
            let b = CounterRng::new(seed);
            for seq in 0..256u64 {
                prop_assert!(
                    a.at3(ROUTE_DOMAIN, seq, 0) % n as u64
                        == b.at3(ROUTE_DOMAIN, seq, 0) % n as u64,
                    "router must replay"
                );
            }
            // Domain separation: the router draw differs from the plain
            // positional draw the arrival generator consumes.
            let mut distinct = false;
            for seq in 0..64u64 {
                if a.at3(ROUTE_DOMAIN, seq, 0) != a.at(seq) {
                    distinct = true;
                    break;
                }
            }
            prop_assert!(distinct, "router stream must not alias the arrival stream");
            Ok(())
        });
    }

    #[test]
    fn one_shard_uniform_trace_matches_the_closed_loop_executor() {
        let fab = fabric();
        let prog = program(&fab);
        let gap = 1_000;
        let k = 6;
        let mut srv = ShardedServer::new(&fab, 1);
        let arrivals: Vec<Cycle> = (0..k).map(|i| i as Cycle * gap).collect();
        let rep = srv.serve_trace(&prog, &arrivals).unwrap();
        let mut exec = CosimExecutor::new(&fab, prog, gap);
        for (i, r) in rep.records.iter().enumerate() {
            let (makespan, sojourn) = exec.execute_batch_open_loop().unwrap();
            assert_eq!(r.sojourn, sojourn.unwrap(), "request {i}");
            assert_eq!(r.finished_at - r.admitted_at, makespan, "request {i}");
        }
        assert_eq!(rep.admitted, k);
        assert_eq!((rep.shed, rep.degraded, rep.fault_shed), (0, 0, 0));
    }

    #[test]
    fn overload_shed_drops_and_excludes_from_percentiles() {
        let fab = fabric();
        let prog = program(&fab);
        let mut srv = ShardedServer::new(&fab, 1);
        // Measure one service time, then pick a cap smaller than it so
        // a back-to-back burst overloads immediately.
        let probe = srv.serve_trace(&prog, &[0]).unwrap();
        let service = probe.records[0].sojourn;
        assert!(service > 0);
        let cap = service / 2;
        let mut srv = ShardedServer::new(&fab, 1);
        srv.set_overload(OverloadPolicy::Shed, cap).unwrap();
        // A same-instant burst: request 0 is served; by the time the
        // burst tail arrives (still cycle 0) the backlog exceeds the
        // cap, so late burst requests shed. A request arriving after
        // the backlog clears is served again — the edge case where a
        // request arrives *during* shedding and one arrives after.
        let burst = vec![0, 0, 0, 0];
        let rep = srv.serve_trace(&prog, &burst).unwrap();
        assert_eq!(rep.records[0].decision, AdmitDecision::Served);
        let shed: Vec<u64> = rep
            .records
            .iter()
            .filter(|r| r.decision == AdmitDecision::Shed)
            .map(|r| r.seq)
            .collect();
        assert!(!shed.is_empty(), "burst never overloaded (cap {cap})");
        // Shed requests: zero sojourn recorded, excluded from stats.
        for r in rep.records.iter().filter(|r| r.decision == AdmitDecision::Shed) {
            assert_eq!((r.sojourn, r.finished_at), (0, r.arrival));
            assert!(r.outcome.is_none());
        }
        let served = rep.records.iter().filter(|r| r.completed()).count();
        assert_eq!(served + rep.shed, 4);
        assert!(rep.p50_sojourn_cycles() > 0.0, "sheds must not drag the p50 to zero");
        // The fabric drains; a much later arrival is served normally.
        let clear = srv.serve_trace(&prog, &[rep.last_finish + cap + 1]).unwrap();
        assert_eq!(clear.records[0].decision, AdmitDecision::Served);
        assert_eq!(clear.shed, 0);
    }

    #[test]
    fn overload_degrade_backgrounds_the_burst_tail() {
        let fab = fabric();
        let prog = program(&fab);
        let mut probe = ShardedServer::new(&fab, 1);
        let service = probe.serve_trace(&prog, &[0]).unwrap().records[0].sojourn;
        let cap = service / 2;
        let mut srv = ShardedServer::new(&fab, 1);
        srv.set_overload(OverloadPolicy::Degrade, cap).unwrap();
        let rep = srv.serve_trace(&prog, &[0, 0, 0, 0]).unwrap();
        assert!(rep.degraded > 0, "burst never overloaded (cap {cap})");
        assert_eq!(rep.shed, 0, "degrade admits instead of dropping");
        assert_eq!(rep.completed(), 4, "background work still completes");
        // Background requests finish no earlier than normal ones: their
        // MAX deadline sorts them after every normal queue key.
        let max_norm = rep
            .records
            .iter()
            .filter(|r| r.decision == AdmitDecision::Served)
            .map(|r| r.finished_at)
            .max()
            .unwrap();
        for r in rep.records.iter().filter(|r| r.decision == AdmitDecision::Degraded) {
            assert!(r.finished_at >= max_norm, "background {} outran normal {max_norm}", r.finished_at);
        }
    }

    #[test]
    fn knobs_are_frozen_after_the_first_request() {
        let fab = fabric();
        let prog = program(&fab);
        let mut srv = ShardedServer::new(&fab, 2);
        srv.serve_trace(&prog, &[0]).unwrap();
        assert!(srv.set_seed(1).is_err());
        assert!(srv.set_overload(OverloadPolicy::Shed, 10).is_err());
        assert!(srv.serve_trace(&prog, &[5, 3]).is_err(), "regressing trace");
    }

    #[test]
    fn capless_shed_is_rejected() {
        let fab = fabric();
        let mut srv = ShardedServer::new(&fab, 1);
        assert!(srv.set_overload(OverloadPolicy::Shed, 0).is_err());
        assert!(srv.set_overload(OverloadPolicy::Degrade, 0).is_err());
        assert!(srv.set_overload(OverloadPolicy::Queue, 0).is_ok());
    }

    #[test]
    fn sojourn_percentiles_match_sorted_vec_bitwise() {
        use crate::coordinator::serve::percentile;
        let fab = fabric();
        let prog = program(&fab);
        let mut probe = ShardedServer::new(&fab, 1);
        let service = probe.serve_trace(&prog, &[0]).unwrap().records[0].sojourn;
        // A bursty 3-shard trace under a shedding cap: some requests
        // queue, some shed — the histogram must cover exactly the
        // completed records and reproduce the replaced sort-per-call
        // path bit-for-bit at every quantile.
        let mut srv = ShardedServer::new(&fab, 3);
        srv.set_overload(OverloadPolicy::Shed, service / 2).unwrap();
        let arrivals: Vec<Cycle> =
            (0..48).map(|i| (i as Cycle / 4) * (service / 3).max(1)).collect();
        let rep = srv.serve_trace(&prog, &arrivals).unwrap();
        assert!(rep.shed > 0, "trace never overloaded");
        let sojourns: Vec<f64> = rep
            .records
            .iter()
            .filter(|r| r.completed())
            .map(|r| r.sojourn as f64)
            .collect();
        assert_eq!(rep.sojourn_hist.count() as usize, sojourns.len());
        for q in [0.0, 0.5, 0.9, 0.99, 0.999, 1.0] {
            assert_eq!(
                rep.sojourn_percentile(q).to_bits(),
                percentile(&sojourns, q).to_bits(),
                "quantile {q}"
            );
        }
        // Replay: a fresh server over the same trace reproduces the
        // report — including the embedded histogram — bit-for-bit.
        let mut again = ShardedServer::new(&fab, 3);
        again.set_overload(OverloadPolicy::Shed, service / 2).unwrap();
        assert_eq!(again.serve_trace(&prog, &arrivals).unwrap(), rep);
    }

    #[test]
    fn from_config_wires_fault_sections_into_degraded_shards() {
        let base = "[noc]\nwidth = 3\nheight = 3\n\
                    [[cu]]\nkind = \"npu\"\ntemplate = \"B\"\ncount = 4\n\
                    [serve]\nshards = 2\nseed = 9\n";
        // No [fault] section: plain sessions, no recovery outcomes.
        let fab = Fabric::build(FabricConfig::from_toml(base).unwrap()).unwrap();
        let prog = program(&fab);
        let mut srv = ShardedServer::from_config(&fab).unwrap();
        let rep = srv.serve_trace(&prog, &[0, 1_000]).unwrap();
        assert!(rep.records.iter().all(|r| r.outcome.is_none()));

        // A live [fault] section: every shard must be a fault-injected
        // session — recovery outcomes on every admitted record.
        let faulty =
            format!("{base}[fault]\nhorizon = 2000000\nwindow = 1024\np_transient = 0.01\n");
        let fab = Fabric::build(FabricConfig::from_toml(&faulty).unwrap()).unwrap();
        let prog = program(&fab);
        let mut srv = ShardedServer::from_config(&fab).unwrap();
        let rep = srv.serve_trace(&prog, &[0, 1_000]).unwrap();
        assert!(
            rep.records.iter().all(|r| r.outcome.is_some()),
            "[serve] + live [fault] must build FaultySession shards"
        );

        // An inert [fault] section (all probabilities zero) stays plain.
        let inert = format!("{base}[fault]\nhorizon = 2000000\n");
        let fab = Fabric::build(FabricConfig::from_toml(&inert).unwrap()).unwrap();
        let prog = program(&fab);
        let mut srv = ShardedServer::from_config(&fab).unwrap();
        let rep = srv.serve_trace(&prog, &[0, 1_000]).unwrap();
        assert!(rep.records.iter().all(|r| r.outcome.is_none()));
    }
}
