//! FR-FCFS open-page memory controller with PIM issue support.
//!
//! Scheduling policy (DRAMSys default): ready column commands (row hits)
//! first, oldest-first; otherwise the oldest request drives PRE/ACT.
//! Requests live in per-bank queues (as in real controllers); the FR
//! stage may reorder row hits ahead of misses within a bounded window
//! per bank. Global constraints: one command per cycle on the command
//! bus, tRRD + tFAW between activates, one data burst at a time on the
//! data bus.
//!
//! The simulator is event-driven on the shared [`Calendar`] wheel:
//! every bank with queued work keeps a *ready event* at the earliest
//! cycle it could plausibly issue; a stall jumps straight to the wheel's
//! next event instead of re-scanning every bank (the old `next_wakeup`
//! loop was O(banks) per stall). Ready times are computed per bank from
//! that bank's own state, so they can be optimistic about the *global*
//! constraints (command/data bus, tRRD/tFAW) — that is safe because
//! global constraints only ever delay legality: an early wake simply
//! retries `try_issue` and re-arms at the freshly computed ready time
//! (early-wake-retry, per ROADMAP).
//!
//! # Shard contract (repo determinism contract, ROADMAP (k))
//!
//! The arbiter's bank scans follow the same shard discipline as the NoC
//! stepper and the admission drains: [`DramSim::set_threads`] splits the
//! banks into disjoint ranges (fenced by queue occupancy via
//! [`crate::sim::load_fences`]), each shard scans its range **purely**
//! against a snapshot of the global frame (`now`, `last_col`, the
//! tRRD/tFAW floor) into a per-shard candidate buffer, and a sequential
//! merge takes the minimum sequence number. Sub-command seqs are unique,
//! so the merged winner equals the sequential scan's winner bit for bit
//! at every thread count and every fence partition; all *effects*
//! (issues, energy, queue pops) stay sequential in the caller. The
//! optional command trace ([`DramSim::record_trace`]) pins exactly that.

use std::collections::VecDeque;

use crate::metrics::{Category, Metrics};
use crate::sim::{load_fences, Calendar, Cycle, WorkerPool};

use super::bank::{Bank, BankState};
use super::pim::{PimCommand, PimConfig};
use super::DramTiming;

/// FR reorder window per bank (row hits may overtake at most this many
/// older entries).
const FR_WINDOW: usize = 16;

/// One memory request (split into bursts internally). `pim` requests
/// occupy the target bank with an in-memory operation instead of moving
/// data over the bus.
#[derive(Debug, Clone, Copy)]
pub struct Request {
    pub addr: u64,
    pub bytes: usize,
    pub write: bool,
    pub pim: Option<PimCommand>,
}

impl Request {
    pub fn read(addr: u64, bytes: usize) -> Self {
        Request { addr, bytes, write: false, pim: None }
    }

    pub fn write(addr: u64, bytes: usize) -> Self {
        Request { addr, bytes, write: true, pim: None }
    }

    pub fn pim(addr: u64, cmd: PimCommand) -> Self {
        Request { addr, bytes: 0, write: false, pim: Some(cmd) }
    }
}

#[derive(Debug, Clone, Copy)]
struct SubCmd {
    req: usize,
    seq: u64,
    row: u64,
    write: bool,
    pim: Option<PimCommand>,
}

/// DRAM command class, for trace-equivalence tests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CmdKind {
    Read,
    Write,
    Pim,
    Act,
    Pre,
}

/// One issued command ([`DramSim::record_trace`]): the shard-contract
/// goldens compare full traces across thread counts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceCmd {
    pub at: Cycle,
    pub bank: usize,
    pub kind: CmdKind,
}

/// Issue candidates from one bank-range scan — pure reads; the caller
/// applies effects after the merge (see the module's shard contract).
#[derive(Debug, Clone, Copy, Default)]
struct ShardCand {
    /// Oldest ready column/PIM command on an open row: (seq, bank, qi).
    hit: Option<(u64, usize, usize)>,
    /// Oldest front entry that could drive PRE/ACT: (seq, bank, is_act).
    fcfs: Option<(u64, usize, bool)>,
}

/// Scan the disjoint bank range `[b0, b0 + banks.len())` for issue
/// candidates. Every global input (`now`, `last_col`, `act_at`) is a
/// caller snapshot, so shards share one frame and the min-seq merge of
/// their results equals the sequential whole-array scan bit for bit.
fn scan_shard(
    banks: &[Bank],
    queues: &[VecDeque<SubCmd>],
    b0: usize,
    t: &DramTiming,
    now: Cycle,
    last_col: Cycle,
    act_at: Cycle,
) -> ShardCand {
    let mut out = ShardCand::default();
    for (i, bank) in banks.iter().enumerate() {
        let b = b0 + i;
        let q = &queues[i];
        // FR candidate: oldest hit in this bank's reorder window.
        if let Some(open) = bank.open_row() {
            if bank.col_ok_at(t) <= now {
                for (qi, sc) in q.iter().take(FR_WINDOW).enumerate() {
                    if sc.row != open {
                        continue;
                    }
                    // Non-PIM bursts also need the data bus.
                    if sc.pim.is_none() && now < last_col + t.t_burst {
                        continue;
                    }
                    if out.hit.is_none_or(|(s, _, _)| sc.seq < s) {
                        out.hit = Some((sc.seq, b, qi));
                    }
                    break; // oldest hit in this bank found
                }
            }
        }
        // FCFS candidate: the front entry drives PRE or ACT.
        let Some(sc) = q.front() else { continue };
        match bank.state {
            BankState::Idle => {
                if act_at <= now
                    && bank.act_ok_at(t) <= now
                    && out.fcfs.is_none_or(|(s, _, _)| sc.seq < s)
                {
                    out.fcfs = Some((sc.seq, b, true));
                }
            }
            BankState::Active(open) if open != sc.row => {
                if !q.iter().take(FR_WINDOW).any(|w| w.row == open)
                    && bank.pre_ok_at(t) <= now
                    && out.fcfs.is_none_or(|(s, _, _)| sc.seq < s)
                {
                    out.fcfs = Some((sc.seq, b, false));
                }
            }
            _ => {}
        }
    }
    out
}

/// Aggregate results.
#[derive(Debug, Clone, Default)]
pub struct DramStats {
    pub requests: usize,
    pub completed: usize,
    pub cycles: Cycle,
    pub bytes: u64,
    pub activations: u64,
    pub row_hits: u64,
    pub row_misses: u64,
    pub pim_macs: u64,
    pub avg_latency: f64,
    pub metrics: Metrics,
}

impl DramStats {
    /// Achieved bandwidth, GB/s.
    pub fn bandwidth_gbs(&self, t: &DramTiming) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.bytes as f64 / (self.cycles as f64 / (t.freq_ghz * 1e9)) / 1e9
    }

    pub fn row_hit_rate(&self) -> f64 {
        let total = self.row_hits + self.row_misses;
        if total == 0 {
            0.0
        } else {
            self.row_hits as f64 / total as f64
        }
    }
}

/// Fixed ring of the last 4 ACT timestamps (tRRD / tFAW window). The
/// original `VecDeque` allocated on the heap and was pushed/popped every
/// activate; this is four words in the controller struct.
#[derive(Debug, Clone, Copy, Default)]
struct ActWindow {
    t: [Cycle; 4],
    n: usize,
    pos: usize,
}

impl ActWindow {
    fn push(&mut self, at: Cycle) {
        self.t[self.pos] = at;
        self.pos = (self.pos + 1) % 4;
        if self.n < 4 {
            self.n += 1;
        }
    }

    /// Most recent ACT (tRRD reference).
    fn last(&self) -> Option<Cycle> {
        if self.n == 0 {
            None
        } else {
            Some(self.t[(self.pos + 3) % 4])
        }
    }

    /// Fourth-most-recent ACT (tFAW reference), once the window is full.
    fn fourth_last(&self) -> Option<Cycle> {
        if self.n < 4 {
            None
        } else {
            Some(self.t[self.pos])
        }
    }
}

/// Snapshot of the cumulative counters at the end of the previous
/// reporting episode. [`DramSim::stats`] reports *deltas* against this
/// mark, so back-to-back `run_to_drain` episodes on one simulator no
/// longer double-charge earlier episodes' background energy or mix their
/// byte/latency accounting (ROADMAP: multi-episode stats consistency).
/// Single-episode use is unchanged: the mark starts at zero.
/// Accounting is flow-based: `requests` counts admissions (enqueues)
/// during the episode, `completed`/`avg_latency` count completions during
/// the episode — so a request admitted in one episode and drained in the
/// next shows up exactly once on each side, consistent with where its
/// bytes and energy land.
#[derive(Debug, Clone, Copy, Default)]
struct EpisodeMark {
    cycle: Cycle,
    /// Requests enqueued as of the mark.
    enqueued: usize,
    /// Requests completed as of the mark.
    done: usize,
    /// Sum of completed-request latencies as of the mark (f64 additions
    /// in completion order, accumulated in [`DramSim::complete`]).
    lat_sum: f64,
    bytes: u64,
    pim_macs: u64,
    activations: u64,
    row_hits: u64,
    row_misses: u64,
}

/// The single-channel DRAM simulator.
pub struct DramSim {
    t: DramTiming,
    pim_cfg: PimConfig,
    banks: Vec<Bank>,
    /// Per-bank sub-command queues (FIFO + FR window).
    queues: Vec<VecDeque<SubCmd>>,
    queued: usize,
    next_seq: u64,
    /// Outstanding bursts + bookkeeping per request.
    req_bursts: Vec<usize>,
    req_enqueued: Vec<Cycle>,
    /// Completion cycle per request id (public for co-simulation).
    pub req_done: Vec<Option<Cycle>>,
    /// Last 4 ACT timestamps (tFAW window, tRRD).
    recent_acts: ActWindow,
    /// Per-bank ready events (payload = bank id); see the module docs.
    wakes: Calendar<usize>,
    last_col: Cycle,
    now: Cycle,
    energy: Metrics,
    bytes: u64,
    pim_macs: u64,
    /// Running completion counters (updated in [`DramSim::complete`]) so
    /// per-episode reports are O(1) in history, not a rescan of every
    /// request ever enqueued.
    done_count: usize,
    lat_sum: f64,
    /// Reporting baseline for per-episode stats (see [`EpisodeMark`]).
    ep: EpisodeMark,
    /// Bank-scan parallelism (1 = exact sequential hot path).
    threads: usize,
    pool: Option<WorkerPool>,
    /// Issued-command recorder ([`DramSim::record_trace`]).
    trace: Option<Vec<TraceCmd>>,
}

impl DramSim {
    pub fn new(t: DramTiming) -> Self {
        Self::with_pim(t, PimConfig::default())
    }

    pub fn with_pim(t: DramTiming, pim_cfg: PimConfig) -> Self {
        DramSim {
            banks: (0..t.banks).map(|_| Bank::default()).collect(),
            queues: (0..t.banks).map(|_| VecDeque::new()).collect(),
            t,
            pim_cfg,
            queued: 0,
            next_seq: 0,
            req_bursts: Vec::new(),
            req_enqueued: Vec::new(),
            req_done: Vec::new(),
            recent_acts: ActWindow::default(),
            // Horizon spans the common timing windows (tRCD/tRP/tRC are
            // tens of cycles); longer PIM occupancies just lap the ring.
            wakes: Calendar::with_horizon(64),
            last_col: 0,
            now: 0,
            energy: Metrics::new(),
            bytes: 0,
            pim_macs: 0,
            done_count: 0,
            lat_sum: 0.0,
            ep: EpisodeMark::default(),
            threads: 1,
            pool: None,
            trace: None,
        }
    }

    /// Worker threads for the shard-parallel bank scans (1 = the exact
    /// sequential hot path). Results and command traces are bit-identical
    /// at every value — see the module's shard contract.
    pub fn set_threads(&mut self, threads: usize) {
        self.threads = threads.max(1);
        if self.threads == 1 {
            self.pool = None;
        }
    }

    /// Start recording every issued command (cleared on each call).
    pub fn record_trace(&mut self) {
        self.trace = Some(Vec::new());
    }

    /// Commands issued since [`DramSim::record_trace`] (empty when not
    /// recording).
    pub fn trace(&self) -> &[TraceCmd] {
        self.trace.as_deref().unwrap_or(&[])
    }

    fn record(&mut self, bank: usize, kind: CmdKind) {
        if let Some(tr) = &mut self.trace {
            tr.push(TraceCmd { at: self.now, bank, kind });
        }
    }

    pub fn timing(&self) -> &DramTiming {
        &self.t
    }

    pub fn now(&self) -> Cycle {
        self.now
    }

    /// Pending sub-commands.
    pub fn pending(&self) -> usize {
        self.queued
    }

    /// Address mapping (RoBaCo with bank interleave at row granularity):
    /// col = addr % row_bytes; bank = (addr / row_bytes) % banks;
    /// row = addr / (row_bytes * banks).
    pub fn map(&self, addr: u64) -> (usize, u64) {
        let chunk = addr / self.t.row_bytes as u64;
        let bank = (chunk % self.t.banks as u64) as usize;
        let row = chunk / self.t.banks as u64;
        (bank, row)
    }

    fn push(&mut self, bank: usize, sc: SubCmd) {
        self.queues[bank].push_back(sc);
        self.queued += 1;
    }

    /// Enqueue a request; returns its id.
    pub fn enqueue(&mut self, r: Request) -> usize {
        let id = self.req_bursts.len();
        if let Some(cmd) = r.pim {
            let (bank, row) = self.map(r.addr);
            let seq = self.next_seq;
            self.next_seq += 1;
            self.push(bank, SubCmd { req: id, seq, row, write: false, pim: Some(cmd) });
            self.req_bursts.push(1);
        } else {
            assert!(r.bytes > 0, "zero-byte access");
            let nbursts = r.bytes.div_ceil(self.t.burst_bytes);
            for i in 0..nbursts {
                let addr = r.addr + (i * self.t.burst_bytes) as u64;
                let (bank, row) = self.map(addr);
                let seq = self.next_seq;
                self.next_seq += 1;
                self.push(bank, SubCmd { req: id, seq, row, write: r.write, pim: None });
            }
            self.req_bursts.push(nbursts);
        }
        self.req_enqueued.push(self.now);
        self.req_done.push(None);
        id
    }

    fn act_legal_at(&self) -> Cycle {
        let mut t0 = self.now;
        if let Some(last) = self.recent_acts.last() {
            t0 = t0.max(last + self.t.t_rrd);
        }
        if let Some(fourth) = self.recent_acts.fourth_last() {
            t0 = t0.max(fourth + self.t.t_faw);
        }
        t0
    }

    /// Run the two scan passes over the banks, sequentially or
    /// shard-parallel per the module's shard contract.
    fn scan_banks(&mut self, act_at: Cycle) -> ShardCand {
        let n = self.banks.len();
        let shards = self.threads.clamp(1, n);
        if shards <= 1 {
            return scan_shard(
                &self.banks,
                &self.queues,
                0,
                &self.t,
                self.now,
                self.last_col,
                act_at,
            );
        }
        // Load-aware fences over queue occupancy: empty banks cost
        // nothing to scan, so mass follows the queued commands.
        let weights: Vec<u64> = self.queues.iter().map(|q| q.len() as u64).collect();
        let fences = load_fences(&weights, shards);
        let mut out: Vec<ShardCand> = vec![ShardCand::default(); fences.len() - 1];
        if self.pool.as_ref().is_none_or(|p| p.workers() != shards - 1) {
            self.pool = Some(WorkerPool::new(shards - 1));
        }
        let mut pool = self.pool.take().expect("pool just ensured");
        {
            let (t, now, last_col) = (&self.t, self.now, self.last_col);
            // Disjoint bank-range views, cut at the fences.
            let mut bank_tail: &[Bank] = &self.banks;
            let mut queue_tail: &[VecDeque<SubCmd>] = &self.queues;
            let mut views = Vec::with_capacity(out.len());
            for w in fences.windows(2) {
                let (bs, br) = bank_tail.split_at(w[1] - w[0]);
                let (qs, qr) = queue_tail.split_at(w[1] - w[0]);
                bank_tail = br;
                queue_tail = qr;
                views.push((w[0], bs, qs));
            }
            pool.scoped(|scope| {
                let mut it = views.into_iter().zip(out.iter_mut());
                let head = it.next();
                for ((b0, bs, qs), slot) in it {
                    scope.execute(move || {
                        *slot = scan_shard(bs, qs, b0, t, now, last_col, act_at);
                    });
                }
                if let Some(((b0, bs, qs), slot)) = head {
                    *slot = scan_shard(bs, qs, b0, t, now, last_col, act_at);
                }
            });
        }
        self.pool = Some(pool);
        // Sequential merge in shard order; seqs are unique, so the
        // min-seq winner is partition-independent.
        let mut m = ShardCand::default();
        for s in out {
            if let Some(h) = s.hit {
                if m.hit.is_none_or(|(x, _, _)| h.0 < x) {
                    m.hit = Some(h);
                }
            }
            if let Some(f) = s.fcfs {
                if m.fcfs.is_none_or(|(x, _, _)| f.0 < x) {
                    m.fcfs = Some(f);
                }
            }
        }
        m
    }

    /// Issue the best command at `now` if any; returns the issuing bank,
    /// or `None` if nothing was issuable this cycle (caller jumps time).
    /// Pass 1 (FR): oldest ready column/PIM command on an open row,
    /// searched within each bank's reorder window. Pass 2 (FCFS): oldest
    /// front entry drives PRE or ACT. Both passes are pure scans (the
    /// shard seam); all effects happen here, sequentially.
    fn try_issue(&mut self) -> Option<usize> {
        let act_at = self.act_legal_at();
        let cand = self.scan_banks(act_at);
        if let Some((_, b, qi)) = cand.hit {
            let sc = self.queues[b].remove(qi).unwrap();
            self.queued -= 1;
            let done = if let Some(cmd) = sc.pim {
                let dur = cmd.duration(&self.pim_cfg, &self.t);
                self.energy.add_energy(Category::Dram, cmd.energy_pj(&self.pim_cfg));
                self.pim_macs += cmd.macs();
                self.record(b, CmdKind::Pim);
                self.banks[b].issue_pim(self.now, dur, &self.t)
            } else if sc.write {
                self.energy.add_energy(Category::Dram, self.t.e_wr_pj);
                self.last_col = self.now;
                self.bytes += self.t.burst_bytes as u64;
                self.record(b, CmdKind::Write);
                self.banks[b].issue_wr(self.now, &self.t)
            } else {
                self.energy.add_energy(Category::Dram, self.t.e_rd_pj);
                self.last_col = self.now;
                self.bytes += self.t.burst_bytes as u64;
                self.record(b, CmdKind::Read);
                self.banks[b].issue_rd(self.now, &self.t)
            };
            self.complete(sc.req, done);
            return Some(b);
        }
        if let Some((_, b, is_act)) = cand.fcfs {
            if is_act {
                let row = self.queues[b].front().unwrap().row;
                self.banks[b].issue_act(self.now, row, &self.t);
                self.energy.add_energy(Category::Dram, self.t.e_act_pj);
                self.recent_acts.push(self.now);
                self.record(b, CmdKind::Act);
            } else {
                self.banks[b].issue_pre(self.now, &self.t);
                self.banks[b].row_misses += 1;
                self.energy.add_energy(Category::Dram, self.t.e_pre_pj);
                self.record(b, CmdKind::Pre);
            }
            return Some(b);
        }
        None
    }

    fn complete(&mut self, req: usize, done: Cycle) {
        self.req_bursts[req] -= 1;
        if self.req_bursts[req] == 0 {
            let d = self.req_done[req].get_or_insert(done);
            *d = (*d).max(done);
            self.done_count += 1;
            self.lat_sum += (*d - self.req_enqueued[req]) as f64;
        }
    }

    /// Earliest future cycle at which bank `b` could become issuable,
    /// given its own state and the global constraints *as of now*. `None`
    /// when the bank has no queued work. Later global events (ACTs, data
    /// bursts elsewhere) can only push real legality later, never
    /// earlier, so arming a wake at this time is always safe — at worst
    /// the wake fires early, `try_issue` declines, and the bank re-arms.
    fn bank_ready_at(&self, b: usize) -> Option<Cycle> {
        let front = self.queues[b].front()?;
        let bank = &self.banks[b];
        let t = match bank.state {
            BankState::Active(open) => {
                // PIM commands never touch the data bus, and `try_issue`
                // skips past bus-blocked non-PIM hits within the window,
                // so the bank is issuable at its column-path time the
                // moment *any* window hit is a PIM command; the data-bus
                // term applies only when every hit needs the bus
                // (ROADMAP: PIM wake exactness — the old formula charged
                // PIM hits the bus wait and woke them late).
                let mut hit_any = false;
                let mut hit_pim = false;
                for sc in self.queues[b].iter().take(FR_WINDOW) {
                    if sc.row == open {
                        hit_any = true;
                        if sc.pim.is_some() {
                            hit_pim = true;
                            break;
                        }
                    }
                }
                if hit_pim {
                    bank.col_ok_at(&self.t)
                } else if hit_any {
                    bank.col_ok_at(&self.t).max(self.last_col + self.t.t_burst)
                } else if open != front.row {
                    bank.pre_ok_at(&self.t)
                } else {
                    bank.col_ok_at(&self.t)
                }
            }
            BankState::Idle => bank.act_ok_at(&self.t).max(self.act_legal_at()),
        };
        Some(t.max(self.now + 1))
    }

    /// Re-arm bank `b`'s ready event if it still has queued work.
    fn arm_wake(&mut self, b: usize) {
        if let Some(t) = self.bank_ready_at(b) {
            self.wakes.push(t, b);
        }
    }

    /// Run until all requests complete; returns stats.
    pub fn run_to_drain(&mut self) -> DramStats {
        // Arm a ready event for every bank with queued work. Duplicate
        // or stale wakes (e.g. left over from a previous episode) are
        // consumed below as harmless early retries.
        for b in 0..self.queues.len() {
            self.arm_wake(b);
        }
        while self.queued > 0 {
            if let Some(b) = self.try_issue() {
                // Command bus: next command at now + 1. The issue changed
                // bank b's state (and consumed one of its commands), so
                // its previously armed ready time is void — re-arm.
                self.now += 1;
                self.arm_wake(b);
            } else {
                // Stall: pop per-bank ready events until one is *ripe* —
                // the bank's freshly recomputed ready time still equals
                // the wake's timestamp. Ready times only move later as
                // state accrues (bus traffic, ACT windows), so a wake
                // armed under older state can only be early, never late;
                // an early wake just re-arms at the fresh time without
                // advancing the clock (early-wake-retry). Time therefore
                // advances exactly at the minimum of the banks' *current*
                // ready times — the same jump targets the old O(banks)
                // `next_wakeup` scan produced — and the FR-FCFS arbiter
                // only reruns once a wake is ripe, so a stall costs
                // O(due) bank-local checks instead of a full rescan per
                // stale wake. The wheel invariant (every non-empty bank
                // keeps a pending wake) guarantees the pops terminate:
                // each unripe pop re-arms strictly later, converging on
                // the stall state's true minimum.
                loop {
                    let (t, due) = self
                        .wakes
                        .take_next()
                        .expect("stalled with queued work but no pending bank wake");
                    let ripe = due.iter().any(|&(_, b)| self.bank_ready_at(b) == Some(t));
                    if ripe {
                        // bank_ready_at clamps to now + 1, so t > now.
                        self.now = t;
                    }
                    for &(_, b) in &due {
                        self.arm_wake(b);
                    }
                    self.wakes.recycle(due);
                    if ripe {
                        break;
                    }
                }
            }
        }
        // Completion time of the last data burst may exceed `now`.
        let end = self
            .req_done
            .iter()
            .filter_map(|d| *d)
            .max()
            .unwrap_or(self.now)
            .max(self.now);
        self.now = end;
        self.stats()
    }

    /// Per-episode report: everything since the previous `stats()` call
    /// (or construction). The accumulated energy ledger is *moved* into
    /// the report (no per-report `Metrics` clone) and every cumulative
    /// counter is snapshot-and-delta'd against the episode mark, so a
    /// second `run_to_drain` episode on the same simulator reports only
    /// its own cycles, bytes, latencies and background energy —
    /// back-to-back episodes tile the timeline instead of double-
    /// charging it. [`DramSim::run_to_drain`] calls this once per
    /// drained episode.
    pub fn stats(&mut self) -> DramStats {
        let ep_cycles = self.now - self.ep.cycle;
        let mut m = std::mem::take(&mut self.energy);
        // Background energy over this episode only.
        m.add_energy(
            Category::Leakage,
            ep_cycles as f64 * self.t.banks as f64 * self.t.e_bg_pj_cycle,
        );
        m.cycles = ep_cycles;
        m.bytes_moved = self.bytes - self.ep.bytes;
        m.ops = self.pim_macs - self.ep.pim_macs;
        // Episode completion stats are deltas of the running counters
        // maintained in `complete()` — O(1) in history. Flow-based:
        // completions (and their latencies) belong to the episode they
        // happened in, admissions to the episode they were enqueued in.
        let ep_done = self.done_count - self.ep.done;
        let ep_lat_sum = self.lat_sum - self.ep.lat_sum;
        let (mut hits, mut misses, mut acts) = (0, 0, 0);
        for b in &self.banks {
            hits += b.row_hits;
            misses += b.row_misses;
            acts += b.activations;
        }
        let st = DramStats {
            requests: self.req_bursts.len() - self.ep.enqueued,
            completed: ep_done,
            cycles: ep_cycles,
            bytes: self.bytes - self.ep.bytes,
            activations: acts - self.ep.activations,
            // Net row hits: the raw hit counter also ticks for the access
            // that follows a miss-forced precharge, so subtract misses —
            // same arithmetic as the cumulative report, on episode deltas.
            row_hits: (hits - self.ep.row_hits).saturating_sub(misses - self.ep.row_misses),
            row_misses: misses - self.ep.row_misses,
            pim_macs: self.pim_macs - self.ep.pim_macs,
            avg_latency: if ep_done == 0 { 0.0 } else { ep_lat_sum / ep_done as f64 },
            metrics: m,
        };
        self.ep = EpisodeMark {
            cycle: self.now,
            enqueued: self.req_bursts.len(),
            done: self.done_count,
            lat_sum: self.lat_sum,
            bytes: self.bytes,
            pim_macs: self.pim_macs,
            activations: acts,
            row_hits: hits,
            row_misses: misses,
        };
        st
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::{DramKind, PimCommand, PimConfig};

    fn sim() -> DramSim {
        DramSim::new(DramTiming::new(DramKind::Ddr4_2400))
    }

    #[test]
    fn single_read_latency() {
        let mut s = sim();
        s.enqueue(Request::read(0, 64));
        let st = s.run_to_drain();
        assert_eq!(st.completed, 1);
        let t = DramTiming::new(DramKind::Ddr4_2400);
        // ACT + tRCD + tCL + tBURST (+ command cycles)
        let expect = t.t_rcd + t.t_cl + t.t_burst;
        assert!(st.avg_latency >= expect as f64, "{}", st.avg_latency);
        assert!(st.avg_latency <= (expect + 4) as f64, "{}", st.avg_latency);
    }

    #[test]
    fn streaming_hits_rows_and_approaches_peak_bw() {
        let mut s = sim();
        // 256 KiB sequential = row-buffer friendly.
        let total = 256 * 1024;
        let chunk = 1024;
        for i in 0..(total / chunk) {
            s.enqueue(Request::read((i * chunk) as u64, chunk));
        }
        let st = s.run_to_drain();
        let t = DramTiming::new(DramKind::Ddr4_2400);
        let bw = st.bandwidth_gbs(&t);
        assert!(st.row_hit_rate() > 0.9, "hit rate {}", st.row_hit_rate());
        assert!(
            bw > 0.5 * t.peak_bandwidth_gbs(),
            "bw {bw} vs peak {}",
            t.peak_bandwidth_gbs()
        );
    }

    #[test]
    fn random_far_slower_than_streaming() {
        let t = DramTiming::new(DramKind::Ddr4_2400);
        let mut stream = sim();
        let mut random = sim();
        let mut rng = crate::sim::Rng::new(1);
        for i in 0..512 {
            stream.enqueue(Request::read((i * 64) as u64, 64));
            // random rows in one bank to defeat both row buffer and BLP
            let row = rng.below(1 << 14) as u64;
            random.enqueue(Request::read(row * t.row_bytes as u64 * t.banks as u64, 64));
        }
        let ss = stream.run_to_drain();
        let rs = random.run_to_drain();
        assert!(
            rs.cycles > 3 * ss.cycles,
            "random {} vs stream {}",
            rs.cycles,
            ss.cycles
        );
        assert!(rs.metrics.total_energy_pj() > ss.metrics.total_energy_pj());
    }

    #[test]
    fn bank_parallelism_beats_single_bank() {
        let t = DramTiming::new(DramKind::Ddr4_2400);
        let stride_all = t.row_bytes as u64; // consecutive chunks -> banks
        let stride_one = t.row_bytes as u64 * t.banks as u64; // same bank
        let run = |stride: u64| {
            let mut s = sim();
            for i in 0..64u64 {
                s.enqueue(Request::read(i * stride, 64));
            }
            s.run_to_drain().cycles
        };
        assert!(run(stride_one) > run(stride_all), "BLP should help");
    }

    #[test]
    fn fr_reorders_row_hits_ahead_of_misses() {
        let t = DramTiming::new(DramKind::Ddr4_2400);
        let mut s = sim();
        let bank_stride = t.row_bytes as u64 * t.banks as u64;
        // bank 0: open row 0, then queue a miss (row 5) followed by more
        // hits to row 0 — the hits must complete before the miss forces
        // a precharge.
        let hit1 = s.enqueue(Request::read(0, 64));
        let miss = s.enqueue(Request::read(5 * bank_stride, 64));
        let hit2 = s.enqueue(Request::read(128, 64));
        let st = s.run_to_drain();
        assert_eq!(st.completed, 3);
        let done = |id: usize| s.req_done[id].unwrap();
        assert!(done(hit2) < done(miss), "hit2 {} miss {}", done(hit2), done(miss));
        assert!(done(hit1) < done(miss));
    }

    #[test]
    fn pim_macs_complete_without_bus_traffic() {
        let mut s = sim();
        s.enqueue(Request::pim(0, PimCommand::BankMac { macs: 4096 }));
        let st = s.run_to_drain();
        assert_eq!(st.completed, 1);
        assert_eq!(st.bytes, 0, "PIM must not move bus data");
        assert_eq!(st.pim_macs, 4096);
    }

    #[test]
    fn pim_gemv_beats_fetch_to_core_on_energy() {
        // E3 miniature: y += W.x with W resident in DRAM. Fetch-to-core
        // reads all of W over the bus; PIM runs bank MACs in place.
        let t = DramTiming::new(DramKind::Ddr4_2400);
        let w_bytes: usize = 1 << 20; // 1 MiB weight matrix
        let macs = (w_bytes / 4) as u64;
        let mut fetch = sim();
        let chunk = t.row_bytes;
        for i in 0..(w_bytes / chunk) {
            fetch.enqueue(Request::read((i * chunk) as u64, chunk));
        }
        let fs = fetch.run_to_drain();
        let mut pim = DramSim::new(t);
        let per_bank = macs / t.banks as u64;
        for b in 0..t.banks {
            pim.enqueue(Request::pim(
                (b * t.row_bytes) as u64,
                PimCommand::BankMac { macs: per_bank },
            ));
        }
        let ps = pim.run_to_drain();
        let e_fetch = fs.metrics.total_energy_pj();
        let e_pim = ps.metrics.total_energy_pj();
        assert!(e_pim * 4.0 < e_fetch, "pim {e_pim} vs fetch {e_fetch}");
        assert!(ps.cycles < fs.cycles, "pim {} vs fetch {}", ps.cycles, fs.cycles);
    }

    #[test]
    fn rowcopy_blocks_bank_for_trc() {
        let mut s = sim();
        s.enqueue(Request::pim(0, PimCommand::RowCopy));
        let st = s.run_to_drain();
        let t = DramTiming::new(DramKind::Ddr4_2400);
        assert!(st.cycles >= t.t_rcd + t.t_rc);
    }

    /// PIM wake exactness (ROADMAP): a PIM row hit is ready at the
    /// column path even while the data bus is busy — the bus term must
    /// not appear in its ready time.
    #[test]
    fn pim_window_hit_ready_time_ignores_data_bus() {
        let t = DramTiming::new(DramKind::Ddr4_2400);
        // Bank 0 open on row 0 since cycle 0; the column path unlocks at
        // tRCD, but a burst that just started (last_col = now) owns the
        // data bus until now + tBURST.
        let setup = |probe: Request| {
            let mut s = DramSim::new(t);
            s.banks[0].issue_act(0, 0, &t);
            s.now = t.t_rcd;
            s.last_col = s.now;
            s.enqueue(probe);
            s
        };
        // PIM hit: ready the next cycle (col path passed, no bus need).
        let s = setup(Request::pim(0, PimCommand::BankMac { macs: 8 }));
        assert_eq!(s.bank_ready_at(0), Some(s.now + 1));
        // Non-PIM hit: the data-bus constraint still applies.
        let s = setup(Request::read(0, 64));
        assert_eq!(s.bank_ready_at(0), Some(s.last_col + t.t_burst));
        assert!(s.last_col + t.t_burst > s.now + 1, "bus term must bind here");
    }

    /// `try_issue` skips past a bus-blocked non-PIM hit to a later PIM
    /// hit, so a PIM *anywhere* in the window makes the bank ready at
    /// the column path.
    #[test]
    fn pim_behind_blocked_read_hit_still_wakes_at_col() {
        let t = DramTiming::new(DramKind::Ddr4_2400);
        let mut s = DramSim::new(t);
        s.banks[0].issue_act(0, 0, &t);
        s.now = t.t_rcd;
        s.last_col = s.now;
        s.enqueue(Request::read(0, 64)); // bus-blocked row hit
        s.enqueue(Request::pim(0, PimCommand::BankMac { macs: 8 }));
        assert_eq!(s.bank_ready_at(0), Some(s.now + 1));
    }

    /// Golden pin of a PIM issue time: ACT at 0, column path opens at
    /// tRCD, PIM occupies the bank for its duration — no bus waits
    /// anywhere in the schedule.
    #[test]
    fn pim_issue_time_pinned() {
        let t = DramTiming::new(DramKind::Ddr4_2400);
        let mut s = sim();
        let cmd = PimCommand::BankMac { macs: 100 };
        s.enqueue(Request::pim(0, cmd));
        let st = s.run_to_drain();
        let dur = cmd.duration(&PimConfig::default(), &t);
        assert_eq!(s.req_done[0], Some(t.t_rcd + dur));
        assert_eq!(st.cycles, t.t_rcd + dur);
    }

    /// Multi-episode stats (ROADMAP): a second `run_to_drain` on the
    /// same simulator reports only its own episode — no double-charged
    /// background energy, no re-counted requests or bytes.
    #[test]
    fn back_to_back_episodes_report_per_episode() {
        let t = DramTiming::new(DramKind::Ddr4_2400);
        let mut s = sim();
        let mut run_ep = |s: &mut DramSim, base: u64| {
            for i in 0..64u64 {
                s.enqueue(Request::read(base + i * 4096, 128));
            }
            s.run_to_drain()
        };
        let a = run_ep(&mut s, 0);
        let end_a = s.now();
        let b = run_ep(&mut s, 1 << 26);
        assert_eq!(a.requests, 64);
        assert_eq!(b.requests, 64, "episode 2 must not re-count episode 1");
        assert_eq!(b.completed, 64);
        assert_eq!(a.bytes, b.bytes, "identical per-episode byte traffic");
        // Episodes tile the timeline.
        assert_eq!(a.cycles, end_a);
        assert_eq!(a.cycles + b.cycles, s.now());
        assert!(b.cycles > 0);
        // Background energy is charged for episode 2's cycles only.
        let leak_b = b.metrics.energy(Category::Leakage);
        let expect = b.cycles as f64 * t.banks as f64 * t.e_bg_pj_cycle;
        assert!(
            (leak_b - expect).abs() <= 1e-6 * expect.max(1.0),
            "leakage {leak_b} vs {expect}"
        );
        // Similar workloads → similar totals (the old cumulative report
        // roughly doubled episode 2's background energy).
        assert!(
            b.metrics.total_energy_pj() < 1.5 * a.metrics.total_energy_pj(),
            "ep2 {} vs ep1 {}",
            b.metrics.total_energy_pj(),
            a.metrics.total_energy_pj()
        );
        // Per-episode latency averages stay in the single-episode range.
        assert!(b.avg_latency >= (t.t_cl + t.t_burst) as f64);
    }

    #[test]
    fn deterministic_replay() {
        let run = || {
            let mut s = sim();
            let mut rng = crate::sim::Rng::new(5);
            for _ in 0..200 {
                let addr = (rng.below(1 << 22)) as u64 & !63;
                if rng.chance(0.3) {
                    s.enqueue(Request::write(addr, 64));
                } else {
                    s.enqueue(Request::read(addr, 128));
                }
            }
            let st = s.run_to_drain();
            (st.cycles, st.bytes, st.metrics.total_energy_pj().to_bits())
        };
        assert_eq!(run(), run());
    }

    /// Shard contract (ROADMAP (k)): bank scans over disjoint bank-range
    /// views with a sequential min-seq merge — stats, completion times
    /// and full command traces bit-identical at every thread count.
    #[test]
    fn shard_parallel_scan_is_bit_identical() {
        let run = |threads: usize| {
            let mut s = sim();
            s.set_threads(threads);
            s.record_trace();
            let mut rng = crate::sim::Rng::new(9);
            for _ in 0..300 {
                let addr = (rng.below(1 << 22)) as u64 & !63;
                if rng.chance(0.25) {
                    s.enqueue(Request::write(addr, 64));
                } else if rng.chance(0.1) {
                    s.enqueue(Request::pim(addr, PimCommand::BankMac { macs: 64 }));
                } else {
                    s.enqueue(Request::read(addr, 128));
                }
            }
            let st = s.run_to_drain();
            (
                st.cycles,
                st.bytes,
                st.metrics.total_energy_pj().to_bits(),
                s.req_done.clone(),
                s.trace().to_vec(),
            )
        };
        let base = run(1);
        assert!(!base.4.is_empty(), "trace recorder must capture commands");
        assert!(base.4.iter().any(|c| c.kind == CmdKind::Pim));
        for threads in [2, 3, 4, 8] {
            assert_eq!(run(threads), base, "threads={threads}");
        }
    }

    /// Oversized thread counts clamp to the bank count and stay exact.
    #[test]
    fn shard_threads_clamp_to_banks() {
        let t = DramTiming::new(DramKind::Ddr4_2400);
        let run = |threads: usize| {
            let mut s = sim();
            s.set_threads(threads);
            for i in 0..64u64 {
                s.enqueue(Request::read(i * 4096, 128));
            }
            let st = s.run_to_drain();
            (st.cycles, st.metrics.total_energy_pj().to_bits())
        };
        assert_eq!(run(1), run(t.banks * 4));
    }

    #[test]
    fn all_kinds_drain() {
        for k in [DramKind::Ddr4_2400, DramKind::Lpddr4_3200, DramKind::Hbm2] {
            let mut s = DramSim::new(DramTiming::new(k));
            for i in 0..64u64 {
                s.enqueue(Request::read(i * 4096, 256));
            }
            let st = s.run_to_drain();
            assert_eq!(st.completed, 64, "{k:?}");
        }
    }
}
