//! Bank finite-state machine enforcing the JEDEC timing constraints.

use crate::sim::Cycle;

use super::DramTiming;

/// Open-page bank state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BankState {
    Idle,
    /// Row open (row id).
    Active(u64),
}

/// Per-bank timing bookkeeping. All `*_ok_at` methods return the earliest
/// cycle the command becomes legal; `issue_*` updates state and returns
/// completion info. The controller must only issue at/after the legal
/// cycle (checked with debug_asserts — the legality checker tests rely on
/// them).
#[derive(Debug, Clone)]
pub struct Bank {
    pub state: BankState,
    /// None = never happened (fresh-out-of-reset banks owe no tRC/tRP).
    last_act: Option<Cycle>,
    last_pre: Option<Cycle>,
    /// End of the last read/write data restore affecting PRE.
    write_recovery_until: Cycle,
    /// Earliest next column command (tCCD).
    col_ok: Cycle,
    /// Rows activated (stats).
    pub activations: u64,
    pub row_hits: u64,
    pub row_misses: u64,
}

impl Default for Bank {
    fn default() -> Self {
        Bank {
            state: BankState::Idle,
            last_act: None,
            last_pre: None,
            write_recovery_until: 0,
            col_ok: 0,
            activations: 0,
            row_hits: 0,
            row_misses: 0,
        }
    }
}

impl Bank {
    /// Earliest legal ACT (bank-local constraints: tRP after PRE, tRC
    /// after previous ACT). Bank must be Idle.
    pub fn act_ok_at(&self, t: &DramTiming) -> Cycle {
        debug_assert_eq!(self.state, BankState::Idle);
        let after_pre = self.last_pre.map_or(0, |p| p + t.t_rp);
        let after_act = self.last_act.map_or(0, |a| a + t.t_rc);
        after_pre.max(after_act)
    }

    /// Earliest legal PRE (tRAS after ACT, write recovery done).
    pub fn pre_ok_at(&self, t: &DramTiming) -> Cycle {
        self.last_act
            .map_or(0, |a| a + t.t_ras)
            .max(self.write_recovery_until)
    }

    /// Earliest legal column command (tRCD after ACT, tCCD after last).
    pub fn col_ok_at(&self, t: &DramTiming) -> Cycle {
        self.last_act.map_or(0, |a| a + t.t_rcd).max(self.col_ok)
    }

    pub fn issue_act(&mut self, now: Cycle, row: u64, t: &DramTiming) {
        debug_assert!(now >= self.act_ok_at(t), "ACT violates tRP/tRC");
        self.state = BankState::Active(row);
        self.last_act = Some(now);
        self.activations += 1;
    }

    pub fn issue_pre(&mut self, now: Cycle, t: &DramTiming) {
        debug_assert!(matches!(self.state, BankState::Active(_)));
        debug_assert!(now >= self.pre_ok_at(t), "PRE violates tRAS/tWR");
        self.state = BankState::Idle;
        self.last_pre = Some(now);
    }

    /// Issue RD; returns the cycle the data burst completes on the bus.
    pub fn issue_rd(&mut self, now: Cycle, t: &DramTiming) -> Cycle {
        debug_assert!(matches!(self.state, BankState::Active(_)));
        debug_assert!(now >= self.col_ok_at(t), "RD violates tRCD/tCCD");
        self.col_ok = now + t.t_ccd;
        self.row_hits += 1;
        now + t.t_cl + t.t_burst
    }

    /// Issue WR; returns burst completion. Updates write recovery for PRE.
    pub fn issue_wr(&mut self, now: Cycle, t: &DramTiming) -> Cycle {
        debug_assert!(matches!(self.state, BankState::Active(_)));
        debug_assert!(now >= self.col_ok_at(t), "WR violates tRCD/tCCD");
        self.col_ok = now + t.t_ccd;
        self.row_hits += 1;
        let done = now + t.t_cl + t.t_burst;
        self.write_recovery_until = done + t.t_wr;
        done
    }

    /// Occupy the bank for an in-bank PIM operation of `dur` cycles
    /// starting from an open row. Modeled as column-command-like
    /// occupancy: the bank cannot issue other column commands until done.
    pub fn issue_pim(&mut self, now: Cycle, dur: Cycle, t: &DramTiming) -> Cycle {
        debug_assert!(matches!(self.state, BankState::Active(_)));
        debug_assert!(now >= self.col_ok_at(t));
        self.col_ok = now + dur;
        // PIM writes back in place: extend write recovery.
        self.write_recovery_until = now + dur;
        now + dur
    }

    pub fn open_row(&self) -> Option<u64> {
        match self.state {
            BankState::Active(r) => Some(r),
            BankState::Idle => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::{DramKind, DramTiming};

    fn t() -> DramTiming {
        DramTiming::new(DramKind::Ddr4_2400)
    }

    #[test]
    fn act_to_rd_respects_trcd() {
        let t = t();
        let mut b = Bank::default();
        b.issue_act(100, 7, &t);
        assert_eq!(b.open_row(), Some(7));
        assert_eq!(b.col_ok_at(&t), 100 + t.t_rcd);
        let done = b.issue_rd(100 + t.t_rcd, &t);
        assert_eq!(done, 100 + t.t_rcd + t.t_cl + t.t_burst);
    }

    #[test]
    fn consecutive_reads_gap_tccd() {
        let t = t();
        let mut b = Bank::default();
        b.issue_act(0, 1, &t);
        let first = b.col_ok_at(&t);
        b.issue_rd(first, &t);
        assert_eq!(b.col_ok_at(&t), first + t.t_ccd);
    }

    #[test]
    fn pre_waits_for_tras_and_twr() {
        let t = t();
        let mut b = Bank::default();
        b.issue_act(0, 1, &t);
        assert_eq!(b.pre_ok_at(&t), t.t_ras);
        // a write pushes recovery beyond tRAS
        let wr_at = b.col_ok_at(&t);
        let done = b.issue_wr(wr_at, &t);
        assert_eq!(b.pre_ok_at(&t), (done + t.t_wr).max(t.t_ras));
        b.issue_pre(b.pre_ok_at(&t), &t);
        assert_eq!(b.state, BankState::Idle);
    }

    #[test]
    fn reopen_respects_trp_and_trc() {
        let t = t();
        let mut b = Bank::default();
        b.issue_act(0, 1, &t);
        let pre_at = b.pre_ok_at(&t);
        b.issue_pre(pre_at, &t);
        let next = b.act_ok_at(&t);
        assert_eq!(next, (pre_at + t.t_rp).max(t.t_rc));
        b.issue_act(next, 2, &t);
        assert_eq!(b.open_row(), Some(2));
        assert_eq!(b.activations, 2);
    }

    #[test]
    #[should_panic(expected = "RD violates")]
    #[cfg(debug_assertions)]
    fn early_rd_panics() {
        let t = t();
        let mut b = Bank::default();
        b.issue_act(0, 1, &t);
        b.issue_rd(1, &t); // way before tRCD
    }

    #[test]
    fn pim_occupies_column_path() {
        let t = t();
        let mut b = Bank::default();
        b.issue_act(0, 1, &t);
        let start = b.col_ok_at(&t);
        let done = b.issue_pim(start, 100, &t);
        assert_eq!(done, start + 100);
        assert_eq!(b.col_ok_at(&t), done);
        assert!(b.pre_ok_at(&t) >= done);
    }
}
