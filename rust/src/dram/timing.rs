//! JEDEC timing parameter sets and the IDD-derived energy constants.
//!
//! Numbers are the standard datasheet values DRAMSys ships for DDR4-2400,
//! LPDDR4-3200 and HBM2 (per pseudo-channel), in controller clock cycles
//! and picojoules. The studies use *relations* between configurations, so
//! nominal-corner constants are sufficient (DESIGN.md §2).

/// Supported device generations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DramKind {
    Ddr4_2400,
    Lpddr4_3200,
    Hbm2,
}

/// Timing constraints (cycles) + energy constants (pJ).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DramTiming {
    pub kind: DramKind,
    /// Controller/DRAM clock in GHz (command clock).
    pub freq_ghz: f64,
    /// ACT -> column command.
    pub t_rcd: u64,
    /// PRE -> ACT.
    pub t_rp: u64,
    /// Read latency (CAS).
    pub t_cl: u64,
    /// ACT -> PRE minimum.
    pub t_ras: u64,
    /// ACT -> ACT same bank.
    pub t_rc: u64,
    /// ACT -> ACT different bank.
    pub t_rrd: u64,
    /// Four-activate window.
    pub t_faw: u64,
    /// Write recovery (last write data -> PRE).
    pub t_wr: u64,
    /// Column-to-column (burst gap).
    pub t_ccd: u64,
    /// Data burst duration on the bus.
    pub t_burst: u64,
    /// Banks per channel.
    pub banks: usize,
    /// Row (page) size in bytes.
    pub row_bytes: usize,
    /// Bytes transferred per column burst (BL8 × bus width).
    pub burst_bytes: usize,
    // --- energy (pJ), derived from IDD currents at nominal VDD ---
    pub e_act_pj: f64,
    pub e_pre_pj: f64,
    /// Per read burst (array + I/O).
    pub e_rd_pj: f64,
    pub e_wr_pj: f64,
    /// Background power per bank, pJ per cycle.
    pub e_bg_pj_cycle: f64,
}

impl DramTiming {
    pub fn new(kind: DramKind) -> Self {
        match kind {
            // DDR4-2400R, x8, 1.2 V (micron datasheet / DRAMPower corner).
            DramKind::Ddr4_2400 => DramTiming {
                kind,
                freq_ghz: 1.2,
                t_rcd: 16,
                t_rp: 16,
                t_cl: 16,
                t_ras: 39,
                t_rc: 55,
                t_rrd: 6,
                t_faw: 26,
                t_wr: 18,
                t_ccd: 6,
                t_burst: 4,
                banks: 16,
                row_bytes: 1024,
                burst_bytes: 64,
                e_act_pj: 909.0,
                e_pre_pj: 606.0,
                e_rd_pj: 1690.0,
                e_wr_pj: 1726.0,
                e_bg_pj_cycle: 0.09,
            },
            // LPDDR4-3200, x16, 1.1 V.
            DramKind::Lpddr4_3200 => DramTiming {
                kind,
                freq_ghz: 1.6,
                t_rcd: 29,
                t_rp: 34,
                t_cl: 28,
                t_ras: 67,
                t_rc: 101,
                t_rrd: 16,
                t_faw: 64,
                t_wr: 29,
                t_ccd: 8,
                t_burst: 8,
                banks: 8,
                row_bytes: 2048,
                burst_bytes: 64,
                e_act_pj: 480.0,
                e_pre_pj: 320.0,
                e_rd_pj: 900.0,
                e_wr_pj: 935.0,
                e_bg_pj_cycle: 0.05,
            },
            // HBM2 pseudo-channel, 1 GHz.
            DramKind::Hbm2 => DramTiming {
                kind,
                freq_ghz: 1.0,
                t_rcd: 14,
                t_rp: 14,
                t_cl: 14,
                t_ras: 34,
                t_rc: 48,
                t_rrd: 4,
                t_faw: 16,
                t_wr: 16,
                t_ccd: 2,
                t_burst: 2,
                banks: 16,
                row_bytes: 1024,
                burst_bytes: 32,
                e_act_pj: 460.0,
                e_pre_pj: 310.0,
                e_rd_pj: 550.0,
                e_wr_pj: 560.0,
                e_bg_pj_cycle: 0.07,
            },
        }
    }

    /// Peak data bandwidth, GB/s (bus fully streaming).
    pub fn peak_bandwidth_gbs(&self) -> f64 {
        self.burst_bytes as f64 / (self.t_burst as f64 / (self.freq_ghz * 1e9)) / 1e9
    }

    /// Random-access energy per byte at one burst per ACT (worst case).
    pub fn worst_pj_per_byte(&self) -> f64 {
        (self.e_act_pj + self.e_pre_pj + self.e_rd_pj) / self.burst_bytes as f64
    }

    /// Streaming energy per byte (row fully reused).
    pub fn stream_pj_per_byte(&self) -> f64 {
        let bursts_per_row = (self.row_bytes / self.burst_bytes) as f64;
        ((self.e_act_pj + self.e_pre_pj) / bursts_per_row + self.e_rd_pj)
            / self.burst_bytes as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jedec_invariants_hold_for_all_kinds() {
        for k in [DramKind::Ddr4_2400, DramKind::Lpddr4_3200, DramKind::Hbm2] {
            let t = DramTiming::new(k);
            // tRC >= tRAS + tRP (close-then-reopen).
            assert!(t.t_rc >= t.t_ras + t.t_rp, "{k:?}");
            // tFAW >= 4 activates cannot be faster than 4*tRRD? JEDEC
            // allows tFAW >= tRRD (window constraint dominates); sanity:
            assert!(t.t_faw >= t.t_rrd, "{k:?}");
            assert!(t.t_ras > t.t_rcd, "{k:?}");
            assert!(t.row_bytes % t.burst_bytes == 0, "{k:?}");
            assert!(t.banks >= 8, "{k:?}");
        }
    }

    #[test]
    fn peak_bandwidth_values() {
        // DDR4-2400 x8: 64B / (4 cycles @ 1.2 GHz) = 19.2 GB/s.
        let t = DramTiming::new(DramKind::Ddr4_2400);
        assert!((t.peak_bandwidth_gbs() - 19.2).abs() < 0.1, "{}", t.peak_bandwidth_gbs());
        // HBM2 pseudo-channel: 32B / 2ns = 16 GB/s.
        let h = DramTiming::new(DramKind::Hbm2);
        assert!((h.peak_bandwidth_gbs() - 16.0).abs() < 0.1);
    }

    #[test]
    fn streaming_cheaper_than_random() {
        for k in [DramKind::Ddr4_2400, DramKind::Lpddr4_3200, DramKind::Hbm2] {
            let t = DramTiming::new(k);
            // Row reuse amortizes ACT/PRE; the RD burst itself still
            // dominates, so expect ~25-45% savings, not 2x.
            assert!(t.stream_pj_per_byte() < 0.75 * t.worst_pj_per_byte(), "{k:?}");
        }
    }
}
