//! DRAM subsystem simulator with Processing-In-Memory extensions
//! (paper Sec. IV: "ARCHYTAS aims to augment the DRAMSys tool with PIM and
//! NVM functionalities").
//!
//! The model follows DRAMSys4.0's split: a JEDEC bank state machine that
//! enforces the full timing-constraint set (tRCD/tRP/tCL/tRAS/tRC/tRRD/
//! tFAW/tWR/tCCD/burst), an FR-FCFS open-page controller, an address
//! mapper, and a current-based (IDD-derived) energy model — re-implemented
//! as an event-jumping Rust simulator instead of SystemC TLM-2.0
//! (substitution table, DESIGN.md §2).
//!
//! The PIM extension adds in-bank commands (row-copy à la RowClone and
//! bank-level MAC à la UPMEM / HBM-PIM) that occupy the bank *without*
//! crossing the data bus — the data-movement elimination the paper's
//! Sec. II motivates, measured in experiment E3.

mod bank;
mod controller;
mod pim;
mod timing;

pub use bank::{Bank, BankState};
pub use controller::{CmdKind, DramSim, DramStats, Request, TraceCmd};
pub use pim::{PimCommand, PimConfig};
pub use timing::{DramKind, DramTiming};
