//! Processing-In-Memory command set and device parameters.
//!
//! Two PIM primitives, matching the two families the paper's Sec. II
//! cites:
//! * `RowCopy` — in-array row-to-row copy (RowClone-style): two back-to-
//!   back activates, no bus transfer.
//! * `BankMac` — bank-level MAC engine chewing row-buffer-resident
//!   operands (UPMEM DPU / HBM-PIM style): `macs` multiply-accumulates at
//!   `macs_per_cycle`, reading `bytes` from the open row.

use crate::sim::Cycle;

use super::DramTiming;

/// PIM engine parameters (per bank).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PimConfig {
    /// MACs the in-bank engine retires per DRAM command cycle
    /// (HBM-PIM: ~2 bf16 MAC/cycle/bank; UPMEM DPU scalar: ~1/3).
    pub macs_per_cycle: f64,
    /// Energy per in-bank MAC, pJ (no I/O, short local wires).
    pub e_mac_pj: f64,
    /// Energy per row-copy, pJ (two row cycles, no I/O).
    pub e_rowcopy_pj: f64,
}

impl Default for PimConfig {
    fn default() -> Self {
        // HBM-PIM-class numbers (Kwon et al., ISSCC'21 ballpark).
        PimConfig { macs_per_cycle: 2.0, e_mac_pj: 0.8, e_rowcopy_pj: 600.0 }
    }
}

/// An in-memory operation attached to a bank/row.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PimCommand {
    /// Copy an open row onto another row of the same subarray.
    RowCopy,
    /// MAC over row-buffer operands.
    BankMac { macs: u64 },
}

impl PimCommand {
    /// Bank occupancy in cycles.
    pub fn duration(&self, cfg: &PimConfig, t: &DramTiming) -> Cycle {
        match self {
            // RowClone: ACT-ACT-PRE sequence ~ tRC.
            PimCommand::RowCopy => t.t_rc,
            PimCommand::BankMac { macs } => {
                ((*macs as f64 / cfg.macs_per_cycle).ceil() as Cycle).max(1)
            }
        }
    }

    /// Energy in pJ.
    pub fn energy_pj(&self, cfg: &PimConfig) -> f64 {
        match self {
            PimCommand::RowCopy => cfg.e_rowcopy_pj,
            PimCommand::BankMac { macs } => *macs as f64 * cfg.e_mac_pj,
        }
    }

    /// MAC count (stats).
    pub fn macs(&self) -> u64 {
        match self {
            PimCommand::RowCopy => 0,
            PimCommand::BankMac { macs } => *macs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::DramKind;

    #[test]
    fn bank_mac_duration_scales() {
        let cfg = PimConfig::default();
        let t = DramTiming::new(DramKind::Hbm2);
        let short = PimCommand::BankMac { macs: 10 }.duration(&cfg, &t);
        let long = PimCommand::BankMac { macs: 1000 }.duration(&cfg, &t);
        assert_eq!(short, 5);
        assert_eq!(long, 500);
    }

    #[test]
    fn rowcopy_costs_one_trc_and_no_bus() {
        let cfg = PimConfig::default();
        let t = DramTiming::new(DramKind::Ddr4_2400);
        assert_eq!(PimCommand::RowCopy.duration(&cfg, &t), t.t_rc);
        assert_eq!(PimCommand::RowCopy.macs(), 0);
    }

    #[test]
    fn in_bank_mac_far_cheaper_than_io() {
        // The whole point of PIM: an in-bank MAC (0.8 pJ) is ~30x cheaper
        // than moving its 4 operand bytes over the DDR4 interface
        // (~26 pJ/B streaming).
        let cfg = PimConfig::default();
        let t = DramTiming::new(DramKind::Ddr4_2400);
        let io_pj = 4.0 * t.stream_pj_per_byte();
        assert!(cfg.e_mac_pj * 10.0 < io_pj, "{io_pj}");
    }
}
