//! ARCHYTAS CLI entrypoint (thin shell over `archytas::cli`).

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match archytas::cli::Args::parse(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(2);
        }
    };
    match archytas::cli::dispatch(&args) {
        Ok(report) => print!("{report}"),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}
