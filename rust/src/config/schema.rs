//! Typed configuration schemas for fabric and workload descriptions.
//!
//! A fabric file describes one instance of the ARCHYTAS Scalable Compute
//! Fabric (paper Fig. 1): the NoC, the external memory, and a list of
//! Compute Units, each declaring its accelerator kind and its integration
//! template (A: bare accelerator with NoC interface; B: light-weight
//! wrapper with RISC-V controller + TCDM + DMA; C: PULP-style multi-core
//! cluster).

use super::value::{table_get, Document, Table, Value};
use anyhow::{anyhow, bail, Context, Result};

/// NoC section (`[noc]`). Defaults are FlooNoC-calibrated (DESIGN.md §2).
#[derive(Debug, Clone, PartialEq)]
pub struct NocConfig {
    pub topology: String,
    pub width: usize,
    pub height: usize,
    /// Per-link bandwidth, Gbit/s (FlooNoC: 645).
    pub link_bandwidth_gbps: f64,
    /// Per-hop energy, pJ/bit (FlooNoC: 0.15).
    pub hop_energy_pj_per_bit: f64,
    /// Router pipeline depth in cycles.
    pub router_latency_cycles: u64,
    /// Virtual channels per port.
    pub vcs: usize,
    /// Flit payload size in bytes.
    pub flit_bytes: usize,
    /// Worker threads for shard-parallel NoC stepping (1 = sequential;
    /// reports are bit-identical at any value — see noc/sim.rs docs).
    pub threads: usize,
}

impl Default for NocConfig {
    fn default() -> Self {
        NocConfig {
            topology: "mesh".into(),
            width: 4,
            height: 4,
            link_bandwidth_gbps: 645.0,
            hop_energy_pj_per_bit: 0.15,
            router_latency_cycles: 3,
            vcs: 2,
            flit_bytes: 32,
            threads: 1,
        }
    }
}

/// One `[[cu]]` row: a homogeneous group of compute units.
#[derive(Debug, Clone, PartialEq)]
pub struct CuConfig {
    /// Accelerator kind: `npu | crossbar | photonic | neuromorphic |
    /// pim_dram | cpu`.
    pub kind: String,
    /// Integration template: `A | B | C` (paper Fig. 1).
    pub template: char,
    /// Number of identical units in this group.
    pub count: usize,
    /// Template-C cluster cores (ignored otherwise).
    pub cluster_cores: usize,
    /// Tightly-coupled data memory per unit, KiB (templates B/C).
    pub tcdm_kb: usize,
}

impl Default for CuConfig {
    fn default() -> Self {
        CuConfig { kind: "npu".into(), template: 'B', count: 1, cluster_cores: 8, tcdm_kb: 256 }
    }
}

/// Cost-model section (`[fabric.cost]`): selects the
/// [`crate::fabric::CostModel`] every resource query of the
/// co-simulation stack routes through, and its knobs. `model` is one of
/// `invariant` (default — time-invariant analytic pricing, bit-identical
/// to the pre-cost-layer engines), `congestion`, `dvfs`, or
/// `congestion_dvfs`. The time-varying models quantize occupancy
/// feedback to `epoch_cycles`-long windows and read strictly earlier
/// epochs only (the exactness contract — see `fabric::cost` docs).
#[derive(Debug, Clone, PartialEq)]
pub struct CostConfig {
    pub model: String,
    /// Occupancy epoch length, fabric cycles (time-varying models).
    pub epoch_cycles: u64,
    /// Congestion latency slope per average resident transfer.
    pub alpha: f64,
    /// Congestion factor ceiling.
    pub cap: f64,
    /// DVFS trailing window, in epochs.
    pub window_epochs: u64,
    /// DVFS busy-fraction threshold for the warm throttle band.
    pub warm_frac: f64,
    /// DVFS busy-fraction threshold for the hot throttle band.
    pub hot_frac: f64,
    /// Frequency scale applied in the warm band (0 < scale <= 1).
    pub warm_scale: f64,
    /// Frequency scale applied in the hot band (0 < scale <= 1).
    pub hot_scale: f64,
}

impl Default for CostConfig {
    fn default() -> Self {
        CostConfig {
            model: "invariant".into(),
            epoch_cycles: 2048,
            alpha: 0.25,
            cap: 4.0,
            window_epochs: 4,
            warm_frac: 0.6,
            hot_frac: 0.9,
            warm_scale: 0.75,
            hot_scale: 0.5,
        }
    }
}

impl CostConfig {
    /// Range/NaN validation, with messages naming the `[fabric.cost]`
    /// key at fault. Public so `fabric::cost::model_from_config` can
    /// re-check hand-built configs that never passed the TOML loader.
    pub fn validate(&self) -> Result<()> {
        let known = ["invariant", "congestion", "dvfs", "congestion_dvfs", "kind"];
        if !known.contains(&self.model.as_str()) {
            bail!(
                "unknown fabric.cost.model {:?} (expected one of {known:?})",
                self.model
            );
        }
        // Upper bounds also catch negative TOML values wrapping through
        // the i64 -> u64 cast into huge counts (the noc.threads lesson).
        if self.epoch_cycles == 0 || self.epoch_cycles > 1_000_000_000 {
            bail!(
                "fabric.cost.epoch_cycles must be in 1..=1e9 cycles, got {}",
                self.epoch_cycles
            );
        }
        // is_finite() rejects NaN and the infinities a hand-built config
        // could carry (the loader already refuses non-finite literals).
        let ge = |x: f64, lo: f64| x.is_finite() && x >= lo;
        if !ge(self.alpha, 0.0) {
            bail!("fabric.cost.alpha must be finite and >= 0, got {}", self.alpha);
        }
        if !ge(self.cap, 1.0) {
            bail!("fabric.cost.cap must be finite and >= 1, got {}", self.cap);
        }
        if self.window_epochs == 0 || self.window_epochs > 4096 {
            bail!(
                "fabric.cost.window_epochs must be in 1..=4096, got {}",
                self.window_epochs
            );
        }
        let frac_ok = |f: f64| (0.0..=1.0).contains(&f);
        if !frac_ok(self.warm_frac) || !frac_ok(self.hot_frac) || self.warm_frac > self.hot_frac {
            bail!("fabric.cost: need 0 <= warm_frac <= hot_frac <= 1");
        }
        let scale_ok = |s: f64| s > 0.0 && s <= 1.0;
        if !scale_ok(self.warm_scale) || !scale_ok(self.hot_scale) {
            bail!("fabric.cost: throttle scales must lie in (0, 1]");
        }
        Ok(())
    }
}

/// TOML half of [`crate::sim::FaultConfig`] (`[fault]` section): parsing
/// and validation live here with the rest of the schema code; the type
/// itself is defined in `sim::fault` next to the generator it seeds.
/// The section is opt-in — an absent `[fault]` is the inert default
/// (zero horizon, zero rates: no faults, and the co-sim stack takes the
/// exact fault-free code path).
impl crate::sim::FaultConfig {
    pub fn from_document(doc: &Document) -> Result<Self> {
        let d = Self::default();
        let cfg = Self {
            seed: doc.get_int("fault.seed", d.seed as i64) as u64,
            horizon: doc.get_int("fault.horizon_cycles", d.horizon as i64) as u64,
            window: doc.get_int("fault.window_cycles", d.window as i64) as u64,
            p_transient: doc.get_float("fault.p_transient", d.p_transient),
            p_death: doc.get_float("fault.p_death", d.p_death),
            p_link_degrade: doc.get_float("fault.p_link_degrade", d.p_link_degrade),
            p_link_fail: doc.get_float("fault.p_link_fail", d.p_link_fail),
            p_hbm_brownout: doc.get_float("fault.p_hbm_brownout", d.p_hbm_brownout),
            p_crossbar_drift: doc.get_float("fault.p_crossbar_drift", d.p_crossbar_drift),
            p_photonic_thermal: doc
                .get_float("fault.p_photonic_thermal", d.p_photonic_thermal),
            degrade_factor: doc.get_float("fault.degrade_factor", d.degrade_factor),
            degrade_cycles: doc.get_int("fault.degrade_cycles", d.degrade_cycles as i64) as u64,
            brownout_factor: doc.get_float("fault.brownout_factor", d.brownout_factor),
            brownout_cycles: doc.get_int("fault.brownout_cycles", d.brownout_cycles as i64)
                as u64,
            drift_factor: doc.get_float("fault.drift_factor", d.drift_factor),
            drift_cycles: doc.get_int("fault.drift_cycles", d.drift_cycles as i64) as u64,
            thermal_factor: doc.get_float("fault.thermal_factor", d.thermal_factor),
            thermal_cycles: doc.get_int("fault.thermal_cycles", d.thermal_cycles as i64) as u64,
            detect_cycles: doc.get_int("fault.detect_cycles", d.detect_cycles as i64) as u64,
            max_retries: doc.get_int("fault.max_retries", d.max_retries as i64) as u32,
            backoff_base: doc.get_int("fault.backoff_base", d.backoff_base as i64) as u64,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Range/NaN validation, messages naming the `[fault]` key at fault.
    pub fn validate(&self) -> Result<()> {
        for (key, p) in [
            ("fault.p_transient", self.p_transient),
            ("fault.p_death", self.p_death),
            ("fault.p_link_degrade", self.p_link_degrade),
            ("fault.p_link_fail", self.p_link_fail),
            ("fault.p_hbm_brownout", self.p_hbm_brownout),
            ("fault.p_crossbar_drift", self.p_crossbar_drift),
            ("fault.p_photonic_thermal", self.p_photonic_thermal),
        ] {
            // contains() is false for NaN, so a NaN rate is rejected too.
            if !(0.0..=1.0).contains(&p) {
                bail!("{key} must lie in [0, 1], got {p}");
            }
        }
        for (key, f) in [
            ("fault.degrade_factor", self.degrade_factor),
            ("fault.brownout_factor", self.brownout_factor),
            ("fault.drift_factor", self.drift_factor),
            ("fault.thermal_factor", self.thermal_factor),
        ] {
            if !(f.is_finite() && (1.0..=1.0e6).contains(&f)) {
                bail!("{key} must be finite and lie in [1, 1e6], got {f}");
            }
        }
        // Upper bounds also catch negative TOML values wrapping through
        // the i64 -> u64 cast into huge counts (the noc.threads lesson).
        if self.window == 0 || self.window > 1_000_000_000 {
            bail!("fault.window_cycles must be in 1..=1e9, got {}", self.window);
        }
        if self.horizon > 1_000_000_000_000 {
            bail!("fault.horizon_cycles must be <= 1e12, got {}", self.horizon);
        }
        for (key, c) in [
            ("fault.degrade_cycles", self.degrade_cycles),
            ("fault.brownout_cycles", self.brownout_cycles),
            ("fault.drift_cycles", self.drift_cycles),
            ("fault.thermal_cycles", self.thermal_cycles),
            ("fault.detect_cycles", self.detect_cycles),
            ("fault.backoff_base", self.backoff_base),
        ] {
            if c > 1_000_000_000 {
                bail!("{key} must be <= 1e9 cycles, got {c}");
            }
        }
        if self.max_retries > 1_000_000 {
            bail!("fault.max_retries must be <= 1e6, got {}", self.max_retries);
        }
        Ok(())
    }
}

/// Admission-session knobs (`[session]`).
#[derive(Debug, Clone, PartialEq)]
pub struct SessionConfig {
    /// Worker threads for shard-parallel calendar-epoch execution in
    /// `coordinator::admit` (1 = the exact sequential drain; any value
    /// is bit-identical — see the admit module docs).
    pub threads: usize,
}

impl Default for SessionConfig {
    fn default() -> Self {
        SessionConfig { threads: 1 }
    }
}

/// Sharded-serving knobs (`[serve]`): shard count, router seed,
/// open-loop arrival process and overload policy for
/// `coordinator::shard::ShardedServer` /
/// `coordinator::shard::arrival_gen_from_config`. Each shard's session
/// additionally inherits `[session] threads` for its internal calendar
/// drains — the serving side of ROADMAP follow-up (n).
#[derive(Debug, Clone, PartialEq)]
pub struct ServeConfig {
    /// Replicated fabric sessions behind the request router.
    pub shards: usize,
    /// Arrival process: `uniform | poisson | trace`.
    pub arrival: String,
    /// Mean inter-arrival gap, cycles (`uniform`: the exact gap;
    /// `poisson`: the exponential mean; ignored for `trace`).
    pub mean_gap_cycles: u64,
    /// Seed shared by the request router and the arrival generator
    /// (their draw streams are domain-separated — see shard.rs docs).
    pub seed: u64,
    /// Overload policy: `queue | shed | degrade`.
    pub overload: String,
    /// Backlog cap, cycles, past which the overload policy triggers
    /// (0 = unbounded; only legal for `queue`).
    pub queue_cap_cycles: u64,
    /// Diurnal burst-modulation period, cycles (0 = off).
    pub diurnal_period_cycles: u64,
    /// Diurnal rate amplitude in `[0, 1)`.
    pub diurnal_amplitude: f64,
    /// Base gap sequence for `arrival = "trace"`, replayed cyclically.
    pub trace_gaps: Vec<u64>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            shards: 1,
            arrival: "uniform".into(),
            mean_gap_cycles: 1_000,
            seed: 0,
            overload: "queue".into(),
            queue_cap_cycles: 0,
            diurnal_period_cycles: 0,
            diurnal_amplitude: 0.0,
            trace_gaps: Vec::new(),
        }
    }
}

impl ServeConfig {
    pub fn from_document(doc: &Document) -> Result<Self> {
        let d = Self::default();
        let mut trace_gaps = Vec::new();
        if let Some(v) = doc.get("serve.trace_gaps") {
            let arr = v
                .as_array()
                .ok_or_else(|| anyhow!("serve.trace_gaps must be an array of integers"))?;
            for (i, g) in arr.iter().enumerate() {
                let g = g
                    .as_int()
                    .ok_or_else(|| anyhow!("serve.trace_gaps[{i}] must be an integer"))?;
                if !(0..=1_000_000_000).contains(&g) {
                    bail!("serve.trace_gaps[{i}] must be in 0..=1e9 cycles, got {g}");
                }
                trace_gaps.push(g as u64);
            }
        }
        let cfg = ServeConfig {
            shards: doc.get_int("serve.shards", d.shards as i64) as usize,
            arrival: doc.get_str("serve.arrival", &d.arrival).to_string(),
            mean_gap_cycles: doc.get_int("serve.mean_gap_cycles", d.mean_gap_cycles as i64)
                as u64,
            seed: doc.get_int("serve.seed", d.seed as i64) as u64,
            overload: doc.get_str("serve.overload", &d.overload).to_string(),
            queue_cap_cycles: doc.get_int("serve.queue_cap_cycles", d.queue_cap_cycles as i64)
                as u64,
            diurnal_period_cycles: doc
                .get_int("serve.diurnal_period_cycles", d.diurnal_period_cycles as i64)
                as u64,
            diurnal_amplitude: doc.get_float("serve.diurnal_amplitude", d.diurnal_amplitude),
            trace_gaps,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Range/NaN validation, messages naming the `[serve]` key at fault.
    pub fn validate(&self) -> Result<()> {
        // Upper bounds also catch negative TOML values wrapping through
        // the i64 -> u64/usize casts into huge counts.
        if self.shards == 0 || self.shards > 4096 {
            bail!("serve.shards must be in 1..=4096, got {}", self.shards);
        }
        let arrivals = ["uniform", "poisson", "trace"];
        if !arrivals.contains(&self.arrival.as_str()) {
            bail!(
                "unknown serve.arrival {:?} (expected one of {arrivals:?})",
                self.arrival
            );
        }
        if self.mean_gap_cycles == 0 || self.mean_gap_cycles > 1_000_000_000 {
            bail!(
                "serve.mean_gap_cycles must be in 1..=1e9 cycles, got {}",
                self.mean_gap_cycles
            );
        }
        let policies = ["queue", "shed", "degrade"];
        if !policies.contains(&self.overload.as_str()) {
            bail!(
                "unknown serve.overload {:?} (expected one of {policies:?})",
                self.overload
            );
        }
        if self.queue_cap_cycles > 1_000_000_000_000 {
            bail!(
                "serve.queue_cap_cycles must be <= 1e12 cycles, got {}",
                self.queue_cap_cycles
            );
        }
        if self.overload != "queue" && self.queue_cap_cycles == 0 {
            bail!(
                "serve.overload = {:?} needs serve.queue_cap_cycles > 0 (a cap-less policy never triggers)",
                self.overload
            );
        }
        if self.diurnal_period_cycles > 1_000_000_000_000 {
            bail!(
                "serve.diurnal_period_cycles must be <= 1e12 cycles, got {}",
                self.diurnal_period_cycles
            );
        }
        // is_finite() rejects NaN; contains() keeps the amplitude below
        // 1 so the modulated arrival rate never reaches zero.
        if !self.diurnal_amplitude.is_finite() || !(0.0..1.0).contains(&self.diurnal_amplitude) {
            bail!(
                "serve.diurnal_amplitude must be finite and lie in [0, 1), got {}",
                self.diurnal_amplitude
            );
        }
        if self.arrival == "trace" && self.trace_gaps.is_empty() {
            bail!("serve.arrival = \"trace\" needs a non-empty serve.trace_gaps");
        }
        Ok(())
    }
}

/// Whole-fabric configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct FabricConfig {
    pub name: String,
    /// Fabric clock, GHz.
    pub freq_ghz: f64,
    pub noc: NocConfig,
    pub cus: Vec<CuConfig>,
    /// HBM channels.
    pub hbm_channels: usize,
    /// Per-channel HBM bandwidth, GB/s.
    pub hbm_bandwidth_gbps: f64,
    /// HBM access energy, pJ/byte.
    pub hbm_energy_pj_per_byte: f64,
    /// Cost-model selection (`[fabric.cost]`).
    pub cost: CostConfig,
    /// Fault-injection plan seed (`[fault]`; inert by default).
    pub fault: crate::sim::FaultConfig,
    /// Admission-session knobs (`[session]`).
    pub session: SessionConfig,
    /// Sharded-serving knobs (`[serve]`).
    pub serve: ServeConfig,
}

impl Default for FabricConfig {
    fn default() -> Self {
        FabricConfig {
            name: "default".into(),
            freq_ghz: 1.0,
            noc: NocConfig::default(),
            cus: vec![CuConfig::default()],
            hbm_channels: 4,
            hbm_bandwidth_gbps: 64.0,
            hbm_energy_pj_per_byte: 3.9,
            cost: CostConfig::default(),
            fault: crate::sim::FaultConfig::default(),
            session: SessionConfig::default(),
            serve: ServeConfig::default(),
        }
    }
}

const CU_KINDS: &[&str] = &["npu", "crossbar", "photonic", "neuromorphic", "pim_dram", "cpu"];

impl FabricConfig {
    /// Parse and validate from mini-TOML text.
    pub fn from_toml(text: &str) -> Result<Self> {
        let doc = super::toml::parse_document(text).context("parsing fabric config")?;
        Self::from_document(&doc)
    }

    pub fn from_document(doc: &Document) -> Result<Self> {
        let d = FabricConfig::default();
        let noc = NocConfig {
            topology: doc.get_str("noc.topology", &d.noc.topology).to_string(),
            width: doc.get_int("noc.width", d.noc.width as i64) as usize,
            height: doc.get_int("noc.height", d.noc.height as i64) as usize,
            link_bandwidth_gbps: doc
                .get_float("noc.link_bandwidth_gbps", d.noc.link_bandwidth_gbps),
            hop_energy_pj_per_bit: doc
                .get_float("noc.hop_energy_pj_per_bit", d.noc.hop_energy_pj_per_bit),
            router_latency_cycles: doc
                .get_int("noc.router_latency_cycles", d.noc.router_latency_cycles as i64)
                as u64,
            vcs: doc.get_int("noc.vcs", d.noc.vcs as i64) as usize,
            flit_bytes: doc.get_int("noc.flit_bytes", d.noc.flit_bytes as i64) as usize,
            threads: doc.get_int("noc.threads", d.noc.threads as i64) as usize,
        };
        let mut cus = Vec::new();
        for (i, row) in doc.tables("cu").iter().enumerate() {
            cus.push(parse_cu(row).with_context(|| format!("[[cu]] entry {i}"))?);
        }
        if cus.is_empty() {
            cus = d.cus.clone();
        }
        let cost = CostConfig {
            model: doc.get_str("fabric.cost.model", &d.cost.model).to_string(),
            epoch_cycles: doc.get_int("fabric.cost.epoch_cycles", d.cost.epoch_cycles as i64)
                as u64,
            alpha: doc.get_float("fabric.cost.alpha", d.cost.alpha),
            cap: doc.get_float("fabric.cost.cap", d.cost.cap),
            window_epochs: doc.get_int("fabric.cost.window_epochs", d.cost.window_epochs as i64)
                as u64,
            warm_frac: doc.get_float("fabric.cost.warm_frac", d.cost.warm_frac),
            hot_frac: doc.get_float("fabric.cost.hot_frac", d.cost.hot_frac),
            warm_scale: doc.get_float("fabric.cost.warm_scale", d.cost.warm_scale),
            hot_scale: doc.get_float("fabric.cost.hot_scale", d.cost.hot_scale),
        };
        let cfg = FabricConfig {
            name: doc.get_str("fabric.name", &d.name).to_string(),
            freq_ghz: doc.get_float("fabric.freq_ghz", d.freq_ghz),
            noc,
            cus,
            hbm_channels: doc.get_int("hbm.channels", d.hbm_channels as i64) as usize,
            hbm_bandwidth_gbps: doc.get_float("hbm.bandwidth_gbps", d.hbm_bandwidth_gbps),
            hbm_energy_pj_per_byte: doc
                .get_float("hbm.energy_pj_per_byte", d.hbm_energy_pj_per_byte),
            cost,
            fault: crate::sim::FaultConfig::from_document(doc)
                .context("parsing [fault] section")?,
            session: SessionConfig {
                threads: doc.get_int("session.threads", d.session.threads as i64) as usize,
            },
            serve: ServeConfig::from_document(doc).context("parsing [serve] section")?,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Structural validation (ranges, totals, known enum values).
    pub fn validate(&self) -> Result<()> {
        if self.freq_ghz <= 0.0 {
            bail!("fabric.freq_ghz must be positive");
        }
        if self.noc.width == 0 || self.noc.height == 0 {
            bail!("noc dimensions must be nonzero");
        }
        if self.noc.flit_bytes == 0 || self.noc.vcs == 0 {
            bail!("noc.flit_bytes and noc.vcs must be nonzero");
        }
        // Upper bound also catches negative TOML values wrapping through
        // the i64 -> usize cast into huge counts.
        if self.noc.threads == 0 || self.noc.threads > 1024 {
            bail!(
                "noc.threads must be in 1..=1024 (1 = sequential stepping), got {}",
                self.noc.threads
            );
        }
        if self.session.threads == 0 || self.session.threads > 1024 {
            bail!(
                "session.threads must be in 1..=1024 (1 = sequential drains), got {}",
                self.session.threads
            );
        }
        let known = ["mesh", "torus", "ring", "star", "fattree"];
        if !known.contains(&self.noc.topology.as_str()) {
            bail!("unknown noc.topology {:?} (expected one of {:?})", self.noc.topology, known);
        }
        let total: usize = self.cus.iter().map(|c| c.count).sum();
        if total == 0 {
            bail!("fabric has no compute units");
        }
        if total > self.noc.width * self.noc.height {
            bail!(
                "{} CUs do not fit a {}x{} NoC",
                total,
                self.noc.width,
                self.noc.height
            );
        }
        self.cost.validate()?;
        self.fault.validate()?;
        self.serve.validate()?;
        Ok(())
    }

    /// Total CU count.
    pub fn total_cus(&self) -> usize {
        self.cus.iter().map(|c| c.count).sum()
    }
}

fn parse_cu(row: &Table) -> Result<CuConfig> {
    let d = CuConfig::default();
    let kind = table_get(row, "kind")
        .and_then(Value::as_str)
        .unwrap_or(&d.kind)
        .to_string();
    if !CU_KINDS.contains(&kind.as_str()) {
        bail!("unknown cu kind {kind:?} (expected one of {CU_KINDS:?})");
    }
    let template_s = table_get(row, "template").and_then(Value::as_str).unwrap_or("B");
    let template = template_s
        .chars()
        .next()
        .filter(|c| ['A', 'B', 'C'].contains(c))
        .ok_or_else(|| anyhow!("cu template must be A, B or C, got {template_s:?}"))?;
    Ok(CuConfig {
        kind,
        template,
        count: table_get(row, "count").and_then(Value::as_int).unwrap_or(1) as usize,
        cluster_cores: table_get(row, "cluster_cores")
            .and_then(Value::as_int)
            .unwrap_or(d.cluster_cores as i64) as usize,
        tcdm_kb: table_get(row, "tcdm_kb")
            .and_then(Value::as_int)
            .unwrap_or(d.tcdm_kb as i64) as usize,
    })
}

/// Workload section (`[workload]`).
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadConfig {
    /// `vit_tiny | mlp | cnn_edge`.
    pub model: String,
    pub batch: usize,
    /// `f32 | int8 | analog`.
    pub precision: String,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig { model: "vit_tiny".into(), batch: 4, precision: "f32".into() }
    }
}

impl WorkloadConfig {
    pub fn from_document(doc: &Document) -> Result<Self> {
        let d = WorkloadConfig::default();
        let w = WorkloadConfig {
            model: doc.get_str("workload.model", &d.model).to_string(),
            batch: doc.get_int("workload.batch", d.batch as i64) as usize,
            precision: doc.get_str("workload.precision", &d.precision).to_string(),
        };
        if !["vit_tiny", "mlp", "cnn_edge"].contains(&w.model.as_str()) {
            bail!("unknown workload.model {:?}", w.model);
        }
        if !["f32", "int8", "analog"].contains(&w.precision.as_str()) {
            bail!("unknown workload.precision {:?}", w.precision);
        }
        if w.batch == 0 {
            bail!("workload.batch must be nonzero");
        }
        Ok(w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
[fabric]
name = "edge-16"
freq_ghz = 1.2

[noc]
topology = "torus"
width = 4
height = 4
link_bandwidth_gbps = 645.0
hop_energy_pj_per_bit = 0.15

[hbm]
channels = 8
bandwidth_gbps = 64.0

[[cu]]
kind = "npu"
template = "B"
count = 8

[[cu]]
kind = "crossbar"
template = "A"
count = 4

[[cu]]
kind = "cpu"
template = "C"
count = 2
cluster_cores = 4
"#;

    #[test]
    fn parse_sample() {
        let cfg = FabricConfig::from_toml(SAMPLE).unwrap();
        assert_eq!(cfg.name, "edge-16");
        assert_eq!(cfg.noc.topology, "torus");
        assert_eq!(cfg.cus.len(), 3);
        assert_eq!(cfg.total_cus(), 14);
        assert_eq!(cfg.cus[1].template, 'A');
        assert_eq!(cfg.cus[2].cluster_cores, 4);
        assert_eq!(cfg.hbm_channels, 8);
    }

    #[test]
    fn defaults_apply() {
        let cfg = FabricConfig::from_toml("").unwrap();
        assert_eq!(cfg, FabricConfig::default());
    }

    #[test]
    fn rejects_unknown_topology() {
        let e = FabricConfig::from_toml("[noc]\ntopology = \"hypercube9\"\n").unwrap_err();
        assert!(e.to_string().contains("topology"), "{e}");
    }

    #[test]
    fn rejects_unknown_cu_kind() {
        let e = FabricConfig::from_toml("[[cu]]\nkind = \"quantum\"\n").unwrap_err();
        assert!(format!("{e:#}").contains("unknown cu kind"), "{e:#}");
    }

    #[test]
    fn rejects_overfull_noc() {
        let e = FabricConfig::from_toml(
            "[noc]\nwidth = 2\nheight = 2\n[[cu]]\nkind = \"npu\"\ncount = 5\n",
        )
        .unwrap_err();
        assert!(e.to_string().contains("do not fit"), "{e}");
    }

    #[test]
    fn rejects_bad_template() {
        let e = FabricConfig::from_toml("[[cu]]\nkind = \"npu\"\ntemplate = \"D\"\n")
            .unwrap_err();
        assert!(format!("{e:#}").contains("template"), "{e:#}");
    }

    #[test]
    fn cost_section_parses_and_defaults() {
        let cfg = FabricConfig::from_toml(
            "[fabric.cost]\nmodel = \"congestion_dvfs\"\nepoch_cycles = 512\nalpha = 0.5\n",
        )
        .unwrap();
        assert_eq!(cfg.cost.model, "congestion_dvfs");
        assert_eq!(cfg.cost.epoch_cycles, 512);
        assert_eq!(cfg.cost.alpha, 0.5);
        // Unset knobs keep their defaults.
        assert_eq!(cfg.cost.window_epochs, CostConfig::default().window_epochs);
        // And an absent section is the invariant default.
        assert_eq!(FabricConfig::from_toml("").unwrap().cost, CostConfig::default());
    }

    #[test]
    fn cost_section_rejects_bad_values() {
        for bad in [
            "[fabric.cost]\nmodel = \"psychic\"\n",
            "[fabric.cost]\nmodel = \"congestion\"\nepoch_cycles = 0\n",
            // Negative values must not wrap through the u64 cast.
            "[fabric.cost]\nepoch_cycles = -1\n",
            "[fabric.cost]\nwindow_epochs = -1\n",
            "[fabric.cost]\ncap = 0.5\n",
            "[fabric.cost]\nwarm_frac = 0.95\nhot_frac = 0.6\n",
            "[fabric.cost]\nhot_scale = 0.0\n",
            "[fabric.cost]\nhot_scale = 1.5\n",
        ] {
            assert!(FabricConfig::from_toml(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn session_section_parses_and_validates() {
        let cfg = FabricConfig::from_toml("[session]\nthreads = 4\n").unwrap();
        assert_eq!(cfg.session.threads, 4);
        // Absent section = sequential drains (the exact PR 5 path).
        assert_eq!(FabricConfig::from_toml("").unwrap().session, SessionConfig::default());
        assert_eq!(SessionConfig::default().threads, 1);
        for bad in [
            "[session]\nthreads = 0\n",
            "[session]\nthreads = 2000\n",
            // Negative values must not wrap through the usize cast.
            "[session]\nthreads = -1\n",
        ] {
            let e = FabricConfig::from_toml(bad).unwrap_err();
            assert!(format!("{e:#}").contains("session.threads"), "{bad:?}: {e:#}");
        }
    }

    #[test]
    fn serve_section_parses_and_defaults() {
        let cfg = FabricConfig::from_toml(
            "[serve]\nshards = 4\narrival = \"poisson\"\nmean_gap_cycles = 500\nseed = 9\n\
             overload = \"shed\"\nqueue_cap_cycles = 2000\ndiurnal_period_cycles = 10000\n\
             diurnal_amplitude = 0.5\n",
        )
        .unwrap();
        assert_eq!(cfg.serve.shards, 4);
        assert_eq!(cfg.serve.arrival, "poisson");
        assert_eq!(cfg.serve.mean_gap_cycles, 500);
        assert_eq!(cfg.serve.seed, 9);
        assert_eq!(cfg.serve.overload, "shed");
        assert_eq!(cfg.serve.queue_cap_cycles, 2_000);
        assert_eq!(cfg.serve.diurnal_period_cycles, 10_000);
        assert_eq!(cfg.serve.diurnal_amplitude, 0.5);
        // Trace arrivals carry their gap list through.
        let cfg = FabricConfig::from_toml(
            "[serve]\narrival = \"trace\"\ntrace_gaps = [100, 0, 800]\n",
        )
        .unwrap();
        assert_eq!(cfg.serve.trace_gaps, vec![100, 0, 800]);
        // Absent section = 1 unsharded queue-policy shard.
        assert_eq!(FabricConfig::from_toml("").unwrap().serve, ServeConfig::default());
        assert_eq!(ServeConfig::default().shards, 1);
    }

    #[test]
    fn serve_section_rejects_bad_values_naming_the_key() {
        for (bad, key) in [
            ("[serve]\nshards = 0\n", "serve.shards"),
            // Negative values must not wrap through the usize/u64 casts.
            ("[serve]\nshards = -1\n", "serve.shards"),
            ("[serve]\narrival = \"fractal\"\n", "serve.arrival"),
            ("[serve]\nmean_gap_cycles = 0\n", "serve.mean_gap_cycles"),
            ("[serve]\nmean_gap_cycles = -5\n", "serve.mean_gap_cycles"),
            ("[serve]\noverload = \"explode\"\n", "serve.overload"),
            ("[serve]\nqueue_cap_cycles = -1\n", "serve.queue_cap_cycles"),
            // A cap-less shed/degrade policy would never trigger.
            ("[serve]\noverload = \"shed\"\n", "serve.queue_cap_cycles"),
            ("[serve]\noverload = \"degrade\"\nqueue_cap_cycles = 0\n", "serve.queue_cap_cycles"),
            ("[serve]\ndiurnal_period_cycles = -1\n", "serve.diurnal_period_cycles"),
            // Amplitude 1 would zero the arrival rate at the trough.
            ("[serve]\ndiurnal_amplitude = 1.0\n", "serve.diurnal_amplitude"),
            ("[serve]\ndiurnal_amplitude = -0.2\n", "serve.diurnal_amplitude"),
            ("[serve]\narrival = \"trace\"\n", "serve.trace_gaps"),
            ("[serve]\narrival = \"trace\"\ntrace_gaps = [10, -3]\n", "serve.trace_gaps"),
        ] {
            let e = FabricConfig::from_toml(bad).unwrap_err();
            let msg = format!("{e:#}");
            assert!(msg.contains(key), "error for {bad:?} must name {key}: {msg}");
        }
    }

    #[test]
    fn fault_section_parses_and_defaults() {
        let cfg = FabricConfig::from_toml(
            "[fault]\nseed = 9\nhorizon_cycles = 4096\np_transient = 0.1\n",
        )
        .unwrap();
        assert_eq!(cfg.fault.seed, 9);
        assert_eq!(cfg.fault.horizon, 4096);
        assert_eq!(cfg.fault.p_transient, 0.1);
        // Unset knobs keep their defaults.
        assert_eq!(cfg.fault.window, crate::sim::FaultConfig::default().window);
        assert!(!cfg.fault.is_inert());
        // And an absent section is the inert (no-fault) default.
        assert!(FabricConfig::from_toml("").unwrap().fault.is_inert());
    }

    #[test]
    fn fault_section_rejects_bad_values_naming_the_key() {
        for (bad, key) in [
            ("[fault]\np_death = 1.5\n", "fault.p_death"),
            ("[fault]\np_transient = -0.1\n", "fault.p_transient"),
            ("[fault]\ndegrade_factor = 0.5\n", "fault.degrade_factor"),
            ("[fault]\nbrownout_factor = -2.0\n", "fault.brownout_factor"),
            ("[fault]\nwindow_cycles = 0\n", "fault.window_cycles"),
            // Negative values must not wrap through the u64/u32 casts.
            ("[fault]\nwindow_cycles = -1\n", "fault.window_cycles"),
            ("[fault]\ndetect_cycles = -5\n", "fault.detect_cycles"),
            ("[fault]\nmax_retries = -1\n", "fault.max_retries"),
            ("[fault]\nbackoff_base = -1\n", "fault.backoff_base"),
        ] {
            let e = FabricConfig::from_toml(bad).unwrap_err();
            let msg = format!("{e:#}");
            assert!(msg.contains(key), "error for {bad:?} must name {key}: {msg}");
        }
    }

    #[test]
    fn workload_parse_and_validate() {
        let doc = super::super::toml::parse_document(
            "[workload]\nmodel = \"mlp\"\nbatch = 8\nprecision = \"int8\"\n",
        )
        .unwrap();
        let w = WorkloadConfig::from_document(&doc).unwrap();
        assert_eq!(w.model, "mlp");
        assert_eq!(w.batch, 8);
        let bad = super::super::toml::parse_document("[workload]\nmodel = \"gpt5\"\n").unwrap();
        assert!(WorkloadConfig::from_document(&bad).is_err());
    }
}
