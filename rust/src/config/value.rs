//! Value model for the mini-TOML configuration format.

use std::collections::BTreeMap;
use std::fmt;

/// A scalar or array configuration value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// Floats accept integer literals too (`tiles = 4` where 4.0 is meant).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    /// Array of strings convenience accessor.
    pub fn as_str_array(&self) -> Option<Vec<&str>> {
        self.as_array()?.iter().map(Value::as_str).collect()
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Str(s) => write!(f, "{s:?}"),
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => write!(f, "{x}"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Array(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
        }
    }
}

/// A table entry: scalar value, sub-table, or array of tables.
#[derive(Debug, Clone, PartialEq)]
pub enum Item {
    Value(Value),
    Table(Table),
    ArrayOfTables(Vec<Table>),
}

pub type Table = BTreeMap<String, Item>;

/// A parsed configuration document.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Document {
    pub root: Table,
}

impl Document {
    /// Look up a dotted path (`"noc.topology"`), scalars only.
    pub fn get(&self, path: &str) -> Option<&Value> {
        let mut table = &self.root;
        let parts: Vec<&str> = path.split('.').collect();
        for (i, part) in parts.iter().enumerate() {
            match table.get(*part)? {
                Item::Value(v) if i == parts.len() - 1 => return Some(v),
                Item::Table(t) => table = t,
                _ => return None,
            }
        }
        None
    }

    /// Typed getters with defaults — the common config-consumption shape.
    pub fn get_str<'a>(&'a self, path: &str, default: &'a str) -> &'a str {
        self.get(path).and_then(Value::as_str).unwrap_or(default)
    }

    pub fn get_int(&self, path: &str, default: i64) -> i64 {
        self.get(path).and_then(Value::as_int).unwrap_or(default)
    }

    pub fn get_float(&self, path: &str, default: f64) -> f64 {
        self.get(path).and_then(Value::as_float).unwrap_or(default)
    }

    pub fn get_bool(&self, path: &str, default: bool) -> bool {
        self.get(path).and_then(Value::as_bool).unwrap_or(default)
    }

    /// All tables of an `[[array-of-tables]]` entry.
    pub fn tables(&self, name: &str) -> &[Table] {
        match self.root.get(name) {
            Some(Item::ArrayOfTables(v)) => v,
            _ => &[],
        }
    }

    /// A single `[table]`.
    pub fn table(&self, name: &str) -> Option<&Table> {
        match self.root.get(name)? {
            Item::Table(t) => Some(t),
            _ => None,
        }
    }
}

/// Typed getter on a raw [`Table`] (used for array-of-table rows).
pub fn table_get<'a>(t: &'a Table, key: &str) -> Option<&'a Value> {
    match t.get(key)? {
        Item::Value(v) => Some(v),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_accessors() {
        assert_eq!(Value::Int(3).as_int(), Some(3));
        assert_eq!(Value::Int(3).as_float(), Some(3.0));
        assert_eq!(Value::Float(2.5).as_float(), Some(2.5));
        assert_eq!(Value::Float(2.5).as_int(), None);
        assert_eq!(Value::Str("x".into()).as_str(), Some("x"));
        assert_eq!(Value::Bool(true).as_bool(), Some(true));
        let arr = Value::Array(vec![Value::Str("a".into()), Value::Str("b".into())]);
        assert_eq!(arr.as_str_array(), Some(vec!["a", "b"]));
    }

    #[test]
    fn mixed_array_str_accessor_fails() {
        let arr = Value::Array(vec![Value::Str("a".into()), Value::Int(1)]);
        assert_eq!(arr.as_str_array(), None);
    }

    #[test]
    fn document_dotted_get() {
        let mut inner = Table::new();
        inner.insert("topology".into(), Item::Value(Value::Str("mesh".into())));
        let mut doc = Document::default();
        doc.root.insert("noc".into(), Item::Table(inner));
        assert_eq!(doc.get("noc.topology").and_then(Value::as_str), Some("mesh"));
        assert_eq!(doc.get_str("noc.topology", "ring"), "mesh");
        assert_eq!(doc.get_str("noc.missing", "ring"), "ring");
        assert!(doc.get("noc").is_none()); // table, not a scalar
    }

    #[test]
    fn display_roundtrip_shapes() {
        let v = Value::Array(vec![Value::Int(1), Value::Float(2.5), Value::Bool(false)]);
        assert_eq!(v.to_string(), "[1, 2.5, false]");
    }
}
