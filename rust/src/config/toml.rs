//! Mini-TOML parser (the subset the project uses — see module docs).

use super::value::{Document, Item, Table, Value};
use std::fmt;

/// Parse failure with 1-based line number and message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

fn err<T>(line: usize, msg: impl Into<String>) -> Result<T, ParseError> {
    Err(ParseError { line, msg: msg.into() })
}

/// Parse a mini-TOML document.
pub fn parse_document(text: &str) -> Result<Document, ParseError> {
    let mut doc = Document::default();
    // Path of the table currently being filled. Empty = root.
    let mut cursor: Vec<String> = Vec::new();
    // Whether the cursor tail refers to the last element of an
    // array-of-tables (so inserts go into that element).
    let mut in_aot = false;

    for (lineno, raw) in text.lines().enumerate() {
        let lineno = lineno + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix("[[").and_then(|s| s.strip_suffix("]]")) {
            let name = name.trim();
            if name.is_empty() {
                return err(lineno, "empty array-of-tables name");
            }
            push_aot(&mut doc.root, name, lineno)?;
            cursor = vec![name.to_string()];
            in_aot = true;
        } else if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            let name = name.trim();
            if name.is_empty() {
                return err(lineno, "empty table name");
            }
            make_table(&mut doc.root, name, lineno)?;
            cursor = name.split('.').map(str::to_string).collect();
            in_aot = false;
        } else if let Some(eq) = find_eq(line) {
            let key = line[..eq].trim();
            let vtext = line[eq + 1..].trim();
            if key.is_empty() {
                return err(lineno, "empty key");
            }
            let value = parse_value(vtext, lineno)?;
            let table = resolve_cursor(&mut doc.root, &cursor, in_aot, lineno)?;
            if table
                .insert(key.to_string(), Item::Value(value))
                .is_some()
            {
                return err(lineno, format!("duplicate key {key:?}"));
            }
        } else {
            return err(lineno, format!("expected `key = value` or table header, got {line:?}"));
        }
    }
    Ok(doc)
}

/// Strip a trailing comment, honouring `#` inside quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Position of the key/value `=`, honouring quoted strings.
fn find_eq(line: &str) -> Option<usize> {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '=' if !in_str => return Some(i),
            _ => {}
        }
    }
    None
}

fn push_aot(root: &mut Table, name: &str, line: usize) -> Result<(), ParseError> {
    match root
        .entry(name.to_string())
        .or_insert_with(|| Item::ArrayOfTables(Vec::new()))
    {
        Item::ArrayOfTables(v) => {
            v.push(Table::new());
            Ok(())
        }
        _ => err(line, format!("{name:?} is not an array of tables")),
    }
}

fn make_table(root: &mut Table, dotted: &str, line: usize) -> Result<(), ParseError> {
    let mut table = root;
    for part in dotted.split('.') {
        let part = part.trim();
        if part.is_empty() {
            return err(line, "empty table-path segment");
        }
        let entry = table
            .entry(part.to_string())
            .or_insert_with(|| Item::Table(Table::new()));
        table = match entry {
            Item::Table(t) => t,
            _ => return err(line, format!("{part:?} is not a table")),
        };
    }
    Ok(())
}

fn resolve_cursor<'a>(
    root: &'a mut Table,
    cursor: &[String],
    in_aot: bool,
    line: usize,
) -> Result<&'a mut Table, ParseError> {
    if cursor.is_empty() {
        return Ok(root);
    }
    if in_aot {
        match root.get_mut(&cursor[0]) {
            Some(Item::ArrayOfTables(v)) => {
                return v
                    .last_mut()
                    .ok_or(ParseError { line, msg: "empty array of tables".into() })
            }
            _ => return err(line, "array-of-tables cursor lost"),
        }
    }
    let mut table = root;
    for part in cursor {
        table = match table.get_mut(part) {
            Some(Item::Table(t)) => t,
            _ => return err(line, format!("table {part:?} lost")),
        };
    }
    Ok(table)
}

fn parse_value(text: &str, line: usize) -> Result<Value, ParseError> {
    let text = text.trim();
    if text.is_empty() {
        return err(line, "empty value");
    }
    if let Some(rest) = text.strip_prefix('"') {
        let Some(inner) = rest.strip_suffix('"') else {
            return err(line, "unterminated string");
        };
        if inner.contains('"') {
            return err(line, "embedded quotes unsupported");
        }
        return Ok(Value::Str(unescape(inner)));
    }
    if let Some(inner) = text.strip_prefix('[') {
        let Some(inner) = inner.strip_suffix(']') else {
            return err(line, "unterminated array");
        };
        let mut out = Vec::new();
        for piece in split_array(inner) {
            let piece = piece.trim();
            if piece.is_empty() {
                continue;
            }
            out.push(parse_value(piece, line)?);
        }
        return Ok(Value::Array(out));
    }
    match text {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = text.replace('_', "").parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = text.replace('_', "").parse::<f64>() {
        // Rust's f64 parser accepts "nan"/"inf"/"-inf"; none of them is
        // a meaningful config knob, and letting one through poisons every
        // downstream range check (NaN compares false with everything).
        if !f.is_finite() {
            return err(line, format!("non-finite float {text:?} not allowed"));
        }
        return Ok(Value::Float(f));
    }
    err(line, format!("cannot parse value {text:?}"))
}

fn unescape(s: &str) -> String {
    s.replace("\\n", "\n").replace("\\t", "\t").replace("\\\\", "\\")
}

/// Split a (non-nested) array body on commas outside strings.
fn split_array(body: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut in_str = false;
    let mut start = 0;
    for (i, c) in body.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&body[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&body[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::value::table_get;

    #[test]
    fn scalars_and_comments() {
        let doc = parse_document(
            "# header\n\
             name = \"fabric-a\" # trailing\n\
             tiles = 16\n\
             freq_ghz = 1.2\n\
             enable = true\n\
             big = 1_000_000\n",
        )
        .unwrap();
        assert_eq!(doc.get_str("name", ""), "fabric-a");
        assert_eq!(doc.get_int("tiles", 0), 16);
        assert_eq!(doc.get_float("freq_ghz", 0.0), 1.2);
        assert!(doc.get_bool("enable", false));
        assert_eq!(doc.get_int("big", 0), 1_000_000);
    }

    #[test]
    fn tables_and_dotted_paths() {
        let doc = parse_document(
            "[noc]\ntopology = \"mesh\"\nwidth = 4\n\
             [noc.link]\nbandwidth_gbps = 645.0\n",
        )
        .unwrap();
        assert_eq!(doc.get_str("noc.topology", ""), "mesh");
        assert_eq!(doc.get_float("noc.link.bandwidth_gbps", 0.0), 645.0);
    }

    #[test]
    fn array_of_tables() {
        let doc = parse_document(
            "[[cu]]\nkind = \"npu\"\ncount = 4\n\
             [[cu]]\nkind = \"crossbar\"\ncount = 2\n",
        )
        .unwrap();
        let cus = doc.tables("cu");
        assert_eq!(cus.len(), 2);
        assert_eq!(table_get(&cus[0], "kind").unwrap().as_str(), Some("npu"));
        assert_eq!(table_get(&cus[1], "count").unwrap().as_int(), Some(2));
    }

    #[test]
    fn arrays() {
        let doc = parse_document(
            "inputs = [\"f32[2,2]\", \"f32[4]\"]\nsizes = [1, 2, 3]\nmixed = []\n",
        )
        .unwrap();
        assert_eq!(
            doc.get("inputs").unwrap().as_str_array().unwrap(),
            vec!["f32[2,2]", "f32[4]"]
        );
        assert_eq!(doc.get("sizes").unwrap().as_array().unwrap().len(), 3);
        assert_eq!(doc.get("mixed").unwrap().as_array().unwrap().len(), 0);
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = parse_document("name = \"a#b\"\n").unwrap();
        assert_eq!(doc.get_str("name", ""), "a#b");
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse_document("ok = 1\nbroken\n").unwrap_err();
        assert_eq!(e.line, 2);
        let e = parse_document("x = \"unterminated\n").unwrap_err();
        assert_eq!(e.line, 1);
        let e = parse_document("x = 1\nx = 2\n").unwrap_err();
        assert!(e.msg.contains("duplicate"));
    }

    #[test]
    fn negative_and_exponent_numbers() {
        let doc = parse_document("a = -5\nb = -0.25\nc = 1e3\n").unwrap();
        assert_eq!(doc.get_int("a", 0), -5);
        assert_eq!(doc.get_float("b", 0.0), -0.25);
        assert_eq!(doc.get_float("c", 0.0), 1000.0);
    }

    #[test]
    fn non_finite_floats_rejected_with_line() {
        for (text, line) in
            [("x = nan\n", 1), ("ok = 1\ny = inf\n", 2), ("z = -inf\n", 1), ("w = 1e999\n", 1)]
        {
            let e = parse_document(text).unwrap_err();
            assert_eq!(e.line, line, "{text:?}");
            assert!(e.msg.contains("non-finite"), "{text:?}: {}", e.msg);
        }
    }

    #[test]
    fn manifest_shape_roundtrip() {
        // The exact shape python/compile/aot.py emits.
        let doc = parse_document(
            "[[artifact]]\n\
             name = \"gemm_64\"\n\
             hlo = \"gemm_64.hlo.txt\"\n\
             inputs = [\"f32[64,64]\", \"f32[64,64]\"]\n\
             outputs = [\"f32[64,64]\"]\n\
             golden_in = [\"golden/gemm_64.in0.bin\", \"golden/gemm_64.in1.bin\"]\n\
             golden_out = [\"golden/gemm_64.out0.bin\"]\n",
        )
        .unwrap();
        let a = &doc.tables("artifact")[0];
        assert_eq!(table_get(a, "name").unwrap().as_str(), Some("gemm_64"));
        assert_eq!(
            table_get(a, "golden_in").unwrap().as_str_array().unwrap().len(),
            2
        );
    }
}
