//! Configuration subsystem: a self-contained mini-TOML parser plus the
//! typed schemas for fabric and workload descriptions.
//!
//! The offline build image ships no `serde`/`toml` crates (DESIGN.md §6),
//! so this module implements the TOML subset the project actually uses:
//! comments, top-level keys, `[table]`s, `[[array-of-table]]`s, and values
//! of type string / integer / float / boolean / homogeneous array.

mod schema;
mod toml;
mod value;

pub use schema::{
    CostConfig, CuConfig, FabricConfig, NocConfig, ServeConfig, SessionConfig, WorkloadConfig,
};
pub use toml::{parse_document, ParseError};
pub use value::{table_get, Document, Item, Table, Value};
