//! Neural-network graph IR — the ONNX-like representation the ARCHYTAS
//! compiler stack (paper Sec. V, Fig. 2) operates on.
//!
//! Design points:
//! * Weights are first-class mutable data (`Graph::weights`) so the
//!   pruning / sparsification / quantization passes (Sec. V.B) transform
//!   *real* tensors, not metadata.
//! * Every compute node carries enough shape information for the mapper
//!   to derive an [`crate::accel::Compute`] descriptor.
//! * Node ids are topologically ordered by construction (builder enforces
//!   def-before-use), so passes iterate `0..graph.len()` directly.

use anyhow::ensure;

use crate::Result;

/// Node index.
pub type NodeId = usize;

/// Operator kinds (enough to express the MLP / CNN-as-GEMM / ViT
/// workloads of `workloads/`).
#[derive(Debug, Clone, PartialEq)]
pub enum OpKind {
    /// External input tensor.
    Input,
    /// Weight constant (index into `Graph::weights`).
    Weight { idx: usize },
    /// `inputs[0] [m,k] @ inputs[1] [k,n]`.
    MatMul,
    /// Row-broadcast bias add: `inputs[0] [m,n] + inputs[1] [n]`.
    BiasAdd,
    /// Elementwise binary add (residual).
    Add,
    Relu,
    Gelu,
    /// Row-wise softmax.
    Softmax,
    /// Row-wise layer norm (gain/bias folded into weights idx pair).
    LayerNorm { gain: usize, bias: usize },
    /// Mean over axis 0 blocks of `group` rows (token pooling).
    MeanPool { group: usize },
    /// Scale by a constant.
    Scale { factor: f32 },
}

/// One IR node.
#[derive(Debug, Clone)]
pub struct Node {
    pub id: NodeId,
    pub kind: OpKind,
    pub inputs: Vec<NodeId>,
    /// Output shape `[rows, cols]` (all tensors are 2-D in this IR;
    /// batch/token dims are pre-flattened, as the L2 model does).
    pub shape: [usize; 2],
    pub name: String,
}

/// A weight tensor (row-major 2-D, `[k, n]`; vectors are `[1, n]`).
#[derive(Debug, Clone)]
pub struct WeightTensor {
    pub shape: [usize; 2],
    pub data: Vec<f32>,
}

impl WeightTensor {
    pub fn new(shape: [usize; 2], data: Vec<f32>) -> Result<Self> {
        ensure!(shape[0] * shape[1] == data.len(), "weight shape/data mismatch");
        Ok(WeightTensor { shape, data })
    }

    pub fn zeros(shape: [usize; 2]) -> Self {
        WeightTensor { shape, data: vec![0.0; shape[0] * shape[1]] }
    }
}

/// The graph: nodes in topological order plus the weight store.
#[derive(Debug, Clone, Default)]
pub struct Graph {
    pub nodes: Vec<Node>,
    pub weights: Vec<WeightTensor>,
    pub outputs: Vec<NodeId>,
}

impl Graph {
    pub fn new() -> Self {
        Graph::default()
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    fn push(&mut self, kind: OpKind, inputs: Vec<NodeId>, shape: [usize; 2], name: &str)
        -> Result<NodeId> {
        for &i in &inputs {
            ensure!(i < self.nodes.len(), "use before def: {i} in {name}");
        }
        let id = self.nodes.len();
        self.nodes.push(Node { id, kind, inputs, shape, name: name.to_string() });
        Ok(id)
    }

    pub fn input(&mut self, shape: [usize; 2], name: &str) -> Result<NodeId> {
        self.push(OpKind::Input, vec![], shape, name)
    }

    pub fn weight(&mut self, w: WeightTensor, name: &str) -> Result<NodeId> {
        let idx = self.weights.len();
        let shape = w.shape;
        self.weights.push(w);
        self.push(OpKind::Weight { idx }, vec![], shape, name)
    }

    pub fn matmul(&mut self, a: NodeId, b: NodeId, name: &str) -> Result<NodeId> {
        let (sa, sb) = (self.nodes[a].shape, self.nodes[b].shape);
        ensure!(sa[1] == sb[0], "matmul {name}: {sa:?} x {sb:?}");
        self.push(OpKind::MatMul, vec![a, b], [sa[0], sb[1]], name)
    }

    pub fn bias_add(&mut self, x: NodeId, b: NodeId, name: &str) -> Result<NodeId> {
        let (sx, sb) = (self.nodes[x].shape, self.nodes[b].shape);
        ensure!(sb == [1, sx[1]], "bias {name}: {sx:?} + {sb:?}");
        self.push(OpKind::BiasAdd, vec![x, b], sx, name)
    }

    pub fn add(&mut self, a: NodeId, b: NodeId, name: &str) -> Result<NodeId> {
        ensure!(self.nodes[a].shape == self.nodes[b].shape, "add {name}");
        self.push(OpKind::Add, vec![a, b], self.nodes[a].shape, name)
    }

    pub fn relu(&mut self, x: NodeId, name: &str) -> Result<NodeId> {
        self.push(OpKind::Relu, vec![x], self.nodes[x].shape, name)
    }

    pub fn gelu(&mut self, x: NodeId, name: &str) -> Result<NodeId> {
        self.push(OpKind::Gelu, vec![x], self.nodes[x].shape, name)
    }

    pub fn softmax(&mut self, x: NodeId, name: &str) -> Result<NodeId> {
        self.push(OpKind::Softmax, vec![x], self.nodes[x].shape, name)
    }

    pub fn layer_norm(&mut self, x: NodeId, gain: usize, bias: usize, name: &str)
        -> Result<NodeId> {
        ensure!(gain < self.weights.len() && bias < self.weights.len());
        self.push(OpKind::LayerNorm { gain, bias }, vec![x], self.nodes[x].shape, name)
    }

    pub fn mean_pool(&mut self, x: NodeId, group: usize, name: &str) -> Result<NodeId> {
        let s = self.nodes[x].shape;
        ensure!(group > 0 && s[0] % group == 0, "pool {name}: {s:?} by {group}");
        self.push(OpKind::MeanPool { group }, vec![x], [s[0] / group, s[1]], name)
    }

    pub fn scale(&mut self, x: NodeId, factor: f32, name: &str) -> Result<NodeId> {
        self.push(OpKind::Scale { factor }, vec![x], self.nodes[x].shape, name)
    }

    pub fn mark_output(&mut self, n: NodeId) {
        self.outputs.push(n);
    }

    /// Structural validation: acyclic by construction; check shape rules
    /// and weight indices.
    pub fn validate(&self) -> Result<()> {
        for n in &self.nodes {
            for &i in &n.inputs {
                ensure!(i < n.id, "node {} uses later node {}", n.id, i);
            }
            if let OpKind::Weight { idx } = n.kind {
                ensure!(idx < self.weights.len(), "dangling weight {idx}");
                ensure!(self.weights[idx].shape == n.shape, "weight shape drift");
            }
        }
        for &o in &self.outputs {
            ensure!(o < self.nodes.len(), "dangling output {o}");
        }
        Ok(())
    }

    /// Total MACs of all matmuls (the model's nominal compute).
    pub fn total_macs(&self) -> u64 {
        self.nodes
            .iter()
            .filter(|n| n.kind == OpKind::MatMul)
            .map(|n| {
                let a = self.nodes[n.inputs[0]].shape;
                (a[0] as u64) * (a[1] as u64) * (n.shape[1] as u64)
            })
            .sum()
    }

    /// Total parameter count.
    pub fn total_params(&self) -> usize {
        self.weights.iter().map(|w| w.data.len()).sum()
    }

    /// Users of each node (fan-out lists).
    pub fn users(&self) -> Vec<Vec<NodeId>> {
        let mut u = vec![Vec::new(); self.nodes.len()];
        for n in &self.nodes {
            for &i in &n.inputs {
                u[i].push(n.id);
            }
        }
        u
    }

    /// The weight feeding a matmul's rhs, if it is a direct Weight node.
    pub fn matmul_weight_idx(&self, n: &Node) -> Option<usize> {
        if n.kind != OpKind::MatMul {
            return None;
        }
        match self.nodes[n.inputs[1]].kind {
            OpKind::Weight { idx } => Some(idx),
            _ => None,
        }
    }
}

/// Reference f32 interpreter for the IR: the oracle every compiler pass
/// is validated against (and the accuracy-proxy engine for E5/E6).
pub mod interp {
    use super::*;

    /// Dense row-major matrix value.
    #[derive(Debug, Clone, PartialEq)]
    pub struct Mat {
        pub shape: [usize; 2],
        pub data: Vec<f32>,
    }

    impl Mat {
        pub fn new(shape: [usize; 2], data: Vec<f32>) -> Result<Self> {
            ensure!(shape[0] * shape[1] == data.len(), "mat shape/data mismatch");
            Ok(Mat { shape, data })
        }

        pub fn zeros(shape: [usize; 2]) -> Self {
            Mat { shape, data: vec![0.0; shape[0] * shape[1]] }
        }

        pub fn at(&self, i: usize, j: usize) -> f32 {
            self.data[i * self.shape[1] + j]
        }

        pub fn max_abs_diff(&self, o: &Mat) -> f32 {
            self.data
                .iter()
                .zip(&o.data)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f32::max)
        }

        pub fn rel_err(&self, reference: &Mat) -> f32 {
            let scale = reference.data.iter().fold(1e-12f32, |a, &v| a.max(v.abs()));
            self.max_abs_diff(reference) / scale
        }
    }

    fn matmul(a: &Mat, b: &Mat) -> Mat {
        let ([m, k], [k2, n]) = (a.shape, b.shape);
        assert_eq!(k, k2);
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                let av = a.data[i * k + kk];
                if av == 0.0 {
                    continue;
                }
                let brow = &b.data[kk * n..(kk + 1) * n];
                let orow = &mut out[i * n..(i + 1) * n];
                for j in 0..n {
                    orow[j] += av * brow[j];
                }
            }
        }
        Mat { shape: [m, n], data: out }
    }

    /// Execute the graph on the given inputs (by Input-node order).
    /// `quantize` optionally post-processes every node's output (the
    /// fixed-point simulation hook used by the precision tuner).
    pub fn run_with(
        g: &Graph,
        inputs: &[Mat],
        mut quantize: impl FnMut(NodeId, &mut Mat),
    ) -> Result<Vec<Mat>> {
        g.validate()?;
        let mut vals: Vec<Option<Mat>> = vec![None; g.nodes.len()];
        let mut next_input = 0;
        for node in &g.nodes {
            let get = |id: NodeId| vals[id].as_ref().expect("topo order");
            let mut out = match &node.kind {
                OpKind::Input => {
                    ensure!(next_input < inputs.len(), "missing input {}", node.name);
                    let m = inputs[next_input].clone();
                    ensure!(m.shape == node.shape, "input shape {:?}", m.shape);
                    next_input += 1;
                    m
                }
                OpKind::Weight { idx } => Mat {
                    shape: g.weights[*idx].shape,
                    data: g.weights[*idx].data.clone(),
                },
                OpKind::MatMul => matmul(get(node.inputs[0]), get(node.inputs[1])),
                OpKind::BiasAdd => {
                    let x = get(node.inputs[0]);
                    let b = get(node.inputs[1]);
                    let mut d = x.data.clone();
                    let n = x.shape[1];
                    for (i, v) in d.iter_mut().enumerate() {
                        *v += b.data[i % n];
                    }
                    Mat { shape: x.shape, data: d }
                }
                OpKind::Add => {
                    let a = get(node.inputs[0]);
                    let b = get(node.inputs[1]);
                    let d = a.data.iter().zip(&b.data).map(|(x, y)| x + y).collect();
                    Mat { shape: a.shape, data: d }
                }
                OpKind::Relu => {
                    let x = get(node.inputs[0]);
                    Mat {
                        shape: x.shape,
                        data: x.data.iter().map(|&v| v.max(0.0)).collect(),
                    }
                }
                OpKind::Gelu => {
                    let x = get(node.inputs[0]);
                    let g = |v: f32| {
                        0.5 * v
                            * (1.0
                                + ((0.7978845608 * (v + 0.044715 * v * v * v)) as f32).tanh())
                    };
                    Mat { shape: x.shape, data: x.data.iter().map(|&v| g(v)).collect() }
                }
                OpKind::Softmax => {
                    let x = get(node.inputs[0]);
                    let n = x.shape[1];
                    let mut d = x.data.clone();
                    for row in d.chunks_mut(n) {
                        let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
                        let mut s = 0.0;
                        for v in row.iter_mut() {
                            *v = (*v - mx).exp();
                            s += *v;
                        }
                        for v in row.iter_mut() {
                            *v /= s;
                        }
                    }
                    Mat { shape: x.shape, data: d }
                }
                OpKind::LayerNorm { gain, bias } => {
                    let x = get(node.inputs[0]);
                    let n = x.shape[1];
                    let gw = &g.weights[*gain].data;
                    let bw = &g.weights[*bias].data;
                    let mut d = x.data.clone();
                    for row in d.chunks_mut(n) {
                        let mu = row.iter().sum::<f32>() / n as f32;
                        let var =
                            row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / n as f32;
                        let inv = 1.0 / (var + 1e-6).sqrt();
                        for (j, v) in row.iter_mut().enumerate() {
                            *v = (*v - mu) * inv * gw[j] + bw[j];
                        }
                    }
                    Mat { shape: x.shape, data: d }
                }
                OpKind::MeanPool { group } => {
                    let x = get(node.inputs[0]);
                    let n = x.shape[1];
                    let rows_out = x.shape[0] / group;
                    let mut d = vec![0.0f32; rows_out * n];
                    for i in 0..x.shape[0] {
                        let o = i / group;
                        for j in 0..n {
                            d[o * n + j] += x.at(i, j) / *group as f32;
                        }
                    }
                    Mat { shape: [rows_out, n], data: d }
                }
                OpKind::Scale { factor } => {
                    let x = get(node.inputs[0]);
                    Mat {
                        shape: x.shape,
                        data: x.data.iter().map(|&v| v * factor).collect(),
                    }
                }
            };
            quantize(node.id, &mut out);
            vals[node.id] = Some(out);
        }
        Ok(g.outputs
            .iter()
            .map(|&o| vals[o].clone().expect("output computed"))
            .collect())
    }

    /// Plain f32 execution.
    pub fn run(g: &Graph, inputs: &[Mat]) -> Result<Vec<Mat>> {
        run_with(g, inputs, |_, _| {})
    }
}

#[cfg(test)]
mod tests {
    use super::interp::{run, Mat};
    use super::*;

    fn tiny_mlp() -> Graph {
        let mut g = Graph::new();
        let x = g.input([2, 4], "x").unwrap();
        let w = g
            .weight(
                WeightTensor::new([4, 3], vec![1., 0., 0., 0., 1., 0., 0., 0., 1., 1., 1., 1.])
                    .unwrap(),
                "w0",
            )
            .unwrap();
        let b = g
            .weight(WeightTensor::new([1, 3], vec![0.5, -0.5, 0.0]).unwrap(), "b0")
            .unwrap();
        let mm = g.matmul(x, w, "mm").unwrap();
        let ba = g.bias_add(mm, b, "bias").unwrap();
        let r = g.relu(ba, "relu").unwrap();
        g.mark_output(r);
        g
    }

    #[test]
    fn builder_shape_checks() {
        let mut g = Graph::new();
        let x = g.input([2, 4], "x").unwrap();
        let w = g.weight(WeightTensor::zeros([5, 3]), "w").unwrap();
        assert!(g.matmul(x, w, "bad").is_err());
        let b = g.weight(WeightTensor::zeros([1, 4]), "b").unwrap();
        assert!(g.bias_add(x, b, "ok").is_ok());
    }

    #[test]
    fn interp_mlp_numbers() {
        let g = tiny_mlp();
        g.validate().unwrap();
        let x = Mat::new([2, 4], vec![1., 2., 3., 4., -1., -2., -3., -4.]).unwrap();
        let out = &run(&g, &[x]).unwrap()[0];
        // row0: [1+4, 2+4, 3+4] + bias, relu
        assert_eq!(out.at(0, 0), 5.5);
        assert_eq!(out.at(0, 1), 5.5);
        assert_eq!(out.at(0, 2), 7.0);
        // row1 all negative pre-relu + bias
        assert_eq!(out.at(1, 0), 0.0);
        assert_eq!(out.at(1, 2), 0.0);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut g = Graph::new();
        let x = g.input([3, 5], "x").unwrap();
        let s = g.softmax(x, "sm").unwrap();
        g.mark_output(s);
        let mut rng = crate::sim::Rng::new(1);
        let data: Vec<f32> = (0..15).map(|_| rng.normal() as f32 * 3.0).collect();
        let out = &run(&g, &[Mat::new([3, 5], data).unwrap()]).unwrap()[0];
        for i in 0..3 {
            let s: f32 = (0..5).map(|j| out.at(i, j)).sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn layernorm_normalizes() {
        let mut g = Graph::new();
        let x = g.input([1, 8], "x").unwrap();
        let gain = 0;
        g.weights.push(WeightTensor::new([1, 8], vec![1.0; 8]).unwrap());
        g.weights.push(WeightTensor::new([1, 8], vec![0.0; 8]).unwrap());
        let ln = g.layer_norm(x, gain, 1, "ln").unwrap();
        g.mark_output(ln);
        let out = &run(
            &g,
            &[Mat::new([1, 8], (0..8).map(|i| i as f32).collect()).unwrap()],
        )
        .unwrap()[0];
        let mu: f32 = out.data.iter().sum::<f32>() / 8.0;
        let var: f32 = out.data.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / 8.0;
        assert!(mu.abs() < 1e-5);
        assert!((var - 1.0).abs() < 1e-3);
    }

    #[test]
    fn mean_pool_groups() {
        let mut g = Graph::new();
        let x = g.input([4, 2], "x").unwrap();
        let p = g.mean_pool(x, 2, "pool").unwrap();
        g.mark_output(p);
        assert_eq!(g.nodes[p].shape, [2, 2]);
        let out = &run(
            &g,
            &[Mat::new([4, 2], vec![1., 2., 3., 4., 10., 20., 30., 40.]).unwrap()],
        )
        .unwrap()[0];
        assert_eq!(out.at(0, 0), 2.0);
        assert_eq!(out.at(1, 1), 30.0);
    }

    #[test]
    fn macs_and_params_counted() {
        let g = tiny_mlp();
        assert_eq!(g.total_macs(), 2 * 4 * 3);
        assert_eq!(g.total_params(), 12 + 3);
    }

    #[test]
    fn users_fanout() {
        let mut g = Graph::new();
        let x = g.input([2, 2], "x").unwrap();
        let a = g.relu(x, "a").unwrap();
        let b = g.gelu(x, "b").unwrap();
        let c = g.add(a, b, "c").unwrap();
        g.mark_output(c);
        let u = g.users();
        assert_eq!(u[x], vec![a, b]);
        assert_eq!(u[a], vec![c]);
    }

    #[test]
    fn quantize_hook_sees_every_node() {
        let g = tiny_mlp();
        let x = Mat::new([2, 4], vec![0.5; 8]).unwrap();
        let mut seen = Vec::new();
        interp::run_with(&g, &[x], |id, _| seen.push(id)).unwrap();
        assert_eq!(seen, (0..g.len()).collect::<Vec<_>>());
    }
}
