//! Deterministic fault injection: seeded [`FaultPlan`]s over the shared
//! simulated timeline.
//!
//! ARCHYTAS targets platforms (UAVs, maritime/space systems) where
//! radiation transients, device wear and thermal drift are operating
//! conditions, not tail events — and the post-CMOS accelerator kinds
//! bring their own failure physics (NVM crossbar conductance drift,
//! photonic thermal excursions). This module is the *injection* half of
//! the robustness layer: it decides **what breaks when**. The *recovery*
//! half (retraction, re-mapping, shedding) lives in `coordinator::admit`
//! ([`crate::coordinator::admit`]'s `FaultySession`), and the *pricing*
//! half (how a degraded resource costs) in `fabric::cost::DegradedCost`.
//!
//! # Fault model
//!
//! A [`FaultPlan`] is a time-sorted list of [`FaultEvent`]s drawn from
//! seven kinds:
//!
//! * [`FaultKind::TileTransient`] — a soft upset (SEU-style) on a tile:
//!   whatever step is executing there at the fault instant produced
//!   garbage and must be re-run. No lasting damage.
//! * [`FaultKind::TileDeath`] — permanent tile loss: the tile never
//!   executes again; in-flight and future work must move elsewhere.
//! * [`FaultKind::LinkDegrade`] — a (from, to) tile pair's traffic is
//!   stretched by `factor` for `duration` cycles (marginal SerDes lane,
//!   ECC retries).
//! * [`FaultKind::LinkFail`] — the pair's traffic reroutes for
//!   `duration` cycles at a fixed large penalty.
//! * [`FaultKind::HbmBrownout`] — HBM feeds are stretched by `factor`
//!   for `duration` cycles (channel power droop / refresh storm).
//! * [`FaultKind::CrossbarDrift`] — conductance drift on an
//!   `nvm-crossbar` tile: executes stretched by `factor` for `duration`
//!   cycles (re-programming / verify overhead).
//! * [`FaultKind::PhotonicThermal`] — thermal excursion on a `photonic`
//!   tile: ring resonators need re-locking; executes stretched by
//!   `factor` for `duration` cycles.
//!
//! The first two are *behavioral* (they afflict in-flight work and force
//! recovery); the rest are *pricing* faults, materialized into a
//! `DegradedCost` wrapper so every step **starting** inside an active
//! window is stretched. A step that starts before a window and merely
//! spans it is unaffected — the model prices at start time, which keeps
//! pricing a pure function of `(step, start)` and preserves the cost
//! seam's strictly-earlier-epoch purity contract.
//!
//! # Determinism contract
//!
//! Generation draws through [`super::CounterRng`], the counter-based RNG
//! the parallel-phase determinism contract prescribes: every draw is a
//! pure function of `(seed, kind, window, resource)` — never of call
//! order, thread schedule, or how often the plan is regenerated. Two
//! plans built from the same [`FaultConfig`] and fabric shape are equal
//! element for element; replaying a recorded trace through
//! [`FaultPlan::from_events`] reproduces the same sorted order. Events
//! are sorted by `(time, kind rank, resource)`, so same-cycle faults
//! apply in one canonical order everywhere.
//!
//! An **empty plan is a no-op by construction**: no events, no cost
//! wrapper, nothing on the calendar — `tests/fault_golden.rs` pins
//! empty-plan sessions bit-identical to fault-free ones across the
//! golden matrix.

use super::{CounterRng, Cycle};

/// Draw categories: the first `at3` coordinate of every generation draw.
/// Distinct constants keep the per-kind streams independent.
const CAT_TRANSIENT: u64 = 1;
const CAT_DEATH: u64 = 2;
const CAT_LINK_DEGRADE: u64 = 3;
const CAT_LINK_FAIL: u64 = 4;
const CAT_HBM: u64 = 5;
const CAT_DRIFT: u64 = 6;
const CAT_THERMAL: u64 = 7;
/// Offset mixed into the category for the independent "when in the
/// window" / "which partner tile" sub-draws.
const SUB_OFFSET: u64 = 0x100;
const SUB_PARTNER: u64 = 0x200;

/// Map a raw 64-bit draw to [0, 1) (same 53-bit construction as
/// [`super::CounterRng::uniform_at`], applied to an `at3` draw).
#[inline]
fn u01(draw: u64) -> f64 {
    (draw >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// What broke. Tile/link indices refer to fabric tile ids (the
/// coordinator's resource model); durations/factors ride along so a
/// recorded trace is self-contained and replayable without its config.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// Soft upset: the step in flight on `tile` must re-run.
    TileTransient { tile: usize },
    /// Permanent loss of `tile`.
    TileDeath { tile: usize },
    /// Traffic `from -> to` stretched by `factor` for `duration` cycles.
    LinkDegrade { from: usize, to: usize, factor: f64, duration: Cycle },
    /// Traffic `from -> to` rerouted (fixed penalty) for `duration`.
    LinkFail { from: usize, to: usize, duration: Cycle },
    /// HBM feeds stretched by `factor` for `duration` cycles.
    HbmBrownout { factor: f64, duration: Cycle },
    /// NVM crossbar conductance drift on `tile`.
    CrossbarDrift { tile: usize, factor: f64, duration: Cycle },
    /// Photonic thermal excursion on `tile`.
    PhotonicThermal { tile: usize, factor: f64, duration: Cycle },
}

impl FaultKind {
    /// Canonical same-cycle ordering rank (behavioral faults first, so a
    /// death at `t` is processed before pricing events at `t`).
    pub fn rank(&self) -> u8 {
        match self {
            FaultKind::TileDeath { .. } => 0,
            FaultKind::TileTransient { .. } => 1,
            FaultKind::LinkFail { .. } => 2,
            FaultKind::LinkDegrade { .. } => 3,
            FaultKind::HbmBrownout { .. } => 4,
            FaultKind::CrossbarDrift { .. } => 5,
            FaultKind::PhotonicThermal { .. } => 6,
        }
    }

    /// Primary resource index for the canonical sort (tile id, or the
    /// folded pair for links; 0 for HBM).
    pub fn resource(&self) -> usize {
        match self {
            FaultKind::TileTransient { tile }
            | FaultKind::TileDeath { tile }
            | FaultKind::CrossbarDrift { tile, .. }
            | FaultKind::PhotonicThermal { tile, .. } => *tile,
            FaultKind::LinkDegrade { from, to, .. } | FaultKind::LinkFail { from, to, .. } => {
                from * 65_536 + to
            }
            FaultKind::HbmBrownout { .. } => 0,
        }
    }

    /// The afflicted tile, for the behavioral kinds.
    pub fn tile(&self) -> Option<usize> {
        match self {
            FaultKind::TileTransient { tile } | FaultKind::TileDeath { tile } => Some(*tile),
            _ => None,
        }
    }

    /// True for the kinds that afflict in-flight work (transient/death);
    /// false for the purely pricing kinds.
    pub fn is_behavioral(&self) -> bool {
        matches!(self, FaultKind::TileTransient { .. } | FaultKind::TileDeath { .. })
    }
}

/// One fault at an absolute simulated cycle.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    pub at: Cycle,
    pub kind: FaultKind,
}

/// Knobs of a seeded fault plan plus the recovery parameters the
/// coordinator's recovery layer reads. All probabilities are
/// *per-window, per-resource* Bernoulli rates; the default config has
/// every rate at zero (and a zero horizon), i.e. **no faults** — the
/// `[fault]` TOML section opts in explicitly.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultConfig {
    /// Seed of the counter-based draw stream.
    pub seed: u64,
    /// Faults are drawn for windows covering `[0, horizon)` cycles.
    pub horizon: Cycle,
    /// Window width in cycles (one Bernoulli trial per kind × resource
    /// × window).
    pub window: Cycle,
    pub p_transient: f64,
    pub p_death: f64,
    pub p_link_degrade: f64,
    pub p_link_fail: f64,
    pub p_hbm_brownout: f64,
    /// Drawn only for `nvm-crossbar` tiles.
    pub p_crossbar_drift: f64,
    /// Drawn only for `photonic` tiles.
    pub p_photonic_thermal: f64,
    pub degrade_factor: f64,
    pub degrade_cycles: Cycle,
    pub brownout_factor: f64,
    pub brownout_cycles: Cycle,
    pub drift_factor: f64,
    pub drift_cycles: Cycle,
    pub thermal_factor: f64,
    pub thermal_cycles: Cycle,
    /// Detection latency: recovery restarts no earlier than
    /// `fault time + detect_cycles`.
    pub detect_cycles: Cycle,
    /// Transient retries beyond this many attempts shed the request.
    pub max_retries: u32,
    /// Exponential backoff base: attempt `k` waits `backoff_base << (k-1)`.
    pub backoff_base: Cycle,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            seed: 0,
            horizon: 0,
            window: 1024,
            p_transient: 0.0,
            p_death: 0.0,
            p_link_degrade: 0.0,
            p_link_fail: 0.0,
            p_hbm_brownout: 0.0,
            p_crossbar_drift: 0.0,
            p_photonic_thermal: 0.0,
            degrade_factor: 2.0,
            degrade_cycles: 2048,
            brownout_factor: 1.5,
            brownout_cycles: 2048,
            drift_factor: 1.25,
            drift_cycles: 4096,
            thermal_factor: 1.5,
            thermal_cycles: 1024,
            detect_cycles: 16,
            max_retries: 2,
            backoff_base: 32,
        }
    }
}

impl FaultConfig {
    /// True when this config can never generate an event.
    pub fn is_inert(&self) -> bool {
        self.horizon == 0
            || [
                self.p_transient,
                self.p_death,
                self.p_link_degrade,
                self.p_link_fail,
                self.p_hbm_brownout,
                self.p_crossbar_drift,
                self.p_photonic_thermal,
            ]
            .iter()
            .all(|&p| p <= 0.0)
    }
}

/// A materialized, time-sorted fault trace. Pure data: generating,
/// recording and replaying all meet in this one representation.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// The no-fault plan.
    pub fn empty() -> Self {
        FaultPlan::default()
    }

    /// Wrap a recorded/hand-written trace, restoring the canonical
    /// `(time, kind rank, resource)` order so replays are deterministic
    /// regardless of how the trace was assembled.
    pub fn from_events(mut events: Vec<FaultEvent>) -> Self {
        events.sort_by_key(|e| (e.at, e.kind.rank(), e.kind.resource()));
        FaultPlan { events }
    }

    /// Generate the plan for a fabric with the given per-tile
    /// accelerator kind names (`fabric.tiles[t].accel.name()` order).
    /// Pure in `(cfg, tile_kinds)`: every draw is position-keyed.
    ///
    /// A tile that dies stops drawing tile-targeted faults in later
    /// windows (dead silicon cannot glitch again); link and HBM draws
    /// are independent of tile liveness.
    pub fn generate(cfg: &FaultConfig, tile_kinds: &[&str]) -> Self {
        if cfg.is_inert() || tile_kinds.is_empty() {
            return FaultPlan::empty();
        }
        let rng = CounterRng::new(cfg.seed);
        let window = cfg.window.max(1);
        let windows = cfg.horizon.div_ceil(window);
        let nt = tile_kinds.len();
        let mut dead = vec![false; nt];
        let mut events = Vec::new();
        let offset = |cat: u64, w: u64, r: u64| rng.at3(cat + SUB_OFFSET, w, r) % window;
        for w in 0..windows {
            let wstart = w * window;
            for (t, kind) in tile_kinds.iter().enumerate() {
                if dead[t] {
                    continue;
                }
                let tu = t as u64;
                if u01(rng.at3(CAT_TRANSIENT, w, tu)) < cfg.p_transient {
                    events.push(FaultEvent {
                        at: wstart + offset(CAT_TRANSIENT, w, tu),
                        kind: FaultKind::TileTransient { tile: t },
                    });
                }
                if u01(rng.at3(CAT_DEATH, w, tu)) < cfg.p_death {
                    events.push(FaultEvent {
                        at: wstart + offset(CAT_DEATH, w, tu),
                        kind: FaultKind::TileDeath { tile: t },
                    });
                    dead[t] = true;
                }
                if *kind == "nvm-crossbar" && u01(rng.at3(CAT_DRIFT, w, tu)) < cfg.p_crossbar_drift
                {
                    events.push(FaultEvent {
                        at: wstart + offset(CAT_DRIFT, w, tu),
                        kind: FaultKind::CrossbarDrift {
                            tile: t,
                            factor: cfg.drift_factor,
                            duration: cfg.drift_cycles,
                        },
                    });
                }
                if *kind == "photonic" && u01(rng.at3(CAT_THERMAL, w, tu)) < cfg.p_photonic_thermal
                {
                    events.push(FaultEvent {
                        at: wstart + offset(CAT_THERMAL, w, tu),
                        kind: FaultKind::PhotonicThermal {
                            tile: t,
                            factor: cfg.thermal_factor,
                            duration: cfg.thermal_cycles,
                        },
                    });
                }
            }
            if nt >= 2 {
                // One candidate link fault per window and kind: pick a
                // deterministic (from, to) tile pair.
                for (cat, fail) in [(CAT_LINK_DEGRADE, false), (CAT_LINK_FAIL, true)] {
                    if u01(rng.at3(cat, w, 0)) >= if fail { cfg.p_link_fail } else { cfg.p_link_degrade }
                    {
                        continue;
                    }
                    let from = (rng.at3(cat + SUB_PARTNER, w, 0) % nt as u64) as usize;
                    let to =
                        (from + 1 + (rng.at3(cat + SUB_PARTNER, w, 1) % (nt as u64 - 1)) as usize)
                            % nt;
                    let at = wstart + offset(cat, w, 0);
                    let kind = if fail {
                        FaultKind::LinkFail { from, to, duration: cfg.degrade_cycles }
                    } else {
                        FaultKind::LinkDegrade {
                            from,
                            to,
                            factor: cfg.degrade_factor,
                            duration: cfg.degrade_cycles,
                        }
                    };
                    events.push(FaultEvent { at, kind });
                }
            }
            if u01(rng.at3(CAT_HBM, w, 0)) < cfg.p_hbm_brownout {
                events.push(FaultEvent {
                    at: wstart + offset(CAT_HBM, w, 0),
                    kind: FaultKind::HbmBrownout {
                        factor: cfg.brownout_factor,
                        duration: cfg.brownout_cycles,
                    },
                });
            }
        }
        FaultPlan::from_events(events)
    }

    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// True when no event prices differently (only behavioral kinds, or
    /// no events at all) — such a plan needs no cost wrapper.
    pub fn is_pricing_inert(&self) -> bool {
        self.events.iter().all(|e| e.kind.is_behavioral())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg_all(seed: u64) -> FaultConfig {
        FaultConfig {
            seed,
            horizon: 16 * 1024,
            window: 1024,
            p_transient: 0.2,
            p_death: 0.05,
            p_link_degrade: 0.3,
            p_link_fail: 0.1,
            p_hbm_brownout: 0.2,
            p_crossbar_drift: 0.4,
            p_photonic_thermal: 0.4,
            ..FaultConfig::default()
        }
    }

    const KINDS: [&str; 5] =
        ["digital-npu", "digital-npu", "nvm-crossbar", "photonic", "riscv-cpu"];

    #[test]
    fn empty_and_inert_configs_generate_nothing() {
        assert!(FaultPlan::generate(&FaultConfig::default(), &KINDS).is_empty());
        let zero_horizon = FaultConfig { horizon: 0, ..cfg_all(1) };
        assert!(FaultPlan::generate(&zero_horizon, &KINDS).is_empty());
        assert!(FaultPlan::generate(&cfg_all(1), &[]).is_empty());
        assert!(FaultPlan::empty().is_pricing_inert());
    }

    #[test]
    fn generation_is_deterministic_and_seed_sensitive() {
        let a = FaultPlan::generate(&cfg_all(7), &KINDS);
        let b = FaultPlan::generate(&cfg_all(7), &KINDS);
        assert_eq!(a, b);
        assert!(!a.is_empty(), "rates this high must draw something");
        let c = FaultPlan::generate(&cfg_all(8), &KINDS);
        assert_ne!(a, c, "different seeds must give different traces");
    }

    #[test]
    fn events_are_canonically_sorted_and_in_horizon() {
        let plan = FaultPlan::generate(&cfg_all(3), &KINDS);
        let keys: Vec<_> =
            plan.events().iter().map(|e| (e.at, e.kind.rank(), e.kind.resource())).collect();
        let mut sorted = keys.clone();
        sorted.sort();
        assert_eq!(keys, sorted);
        assert!(plan.events().iter().all(|e| e.at < 16 * 1024));
    }

    #[test]
    fn kind_specific_wear_targets_matching_tiles_only() {
        let plan = FaultPlan::generate(&cfg_all(5), &KINDS);
        for e in plan.events() {
            match e.kind {
                FaultKind::CrossbarDrift { tile, .. } => assert_eq!(KINDS[tile], "nvm-crossbar"),
                FaultKind::PhotonicThermal { tile, .. } => assert_eq!(KINDS[tile], "photonic"),
                _ => {}
            }
        }
    }

    #[test]
    fn dead_tiles_draw_no_further_tile_faults() {
        let cfg = FaultConfig { p_death: 1.0, p_transient: 1.0, ..cfg_all(9) };
        let plan = FaultPlan::generate(&cfg, &KINDS);
        for t in 0..KINDS.len() {
            let deaths =
                plan.events().iter().filter(|e| e.kind == FaultKind::TileDeath { tile: t });
            assert_eq!(deaths.count(), 1, "exactly one death per tile");
            let death_window = plan
                .events()
                .iter()
                .find(|e| e.kind == FaultKind::TileDeath { tile: t })
                .map(|e| e.at / cfg.window)
                .unwrap();
            for e in plan.events() {
                if e.kind.tile() == Some(t) || matches!(e.kind, FaultKind::CrossbarDrift { tile, .. } | FaultKind::PhotonicThermal { tile, .. } if tile == t)
                {
                    assert!(
                        e.at / cfg.window <= death_window,
                        "tile {t} drew a fault after its death window"
                    );
                }
            }
        }
    }

    #[test]
    fn from_events_restores_canonical_order() {
        let ev = |at, tile| FaultEvent { at, kind: FaultKind::TileTransient { tile } };
        let death = FaultEvent { at: 5, kind: FaultKind::TileDeath { tile: 9 } };
        let plan = FaultPlan::from_events(vec![ev(9, 1), death, ev(5, 0), ev(1, 2)]);
        let ats: Vec<_> = plan.events().iter().map(|e| e.at).collect();
        assert_eq!(ats, [1, 5, 5, 9]);
        // Same-cycle: the death (rank 0) precedes the transient (rank 1).
        assert_eq!(plan.events()[1].kind, FaultKind::TileDeath { tile: 9 });
    }

    #[test]
    fn link_pairs_are_distinct_and_in_range() {
        let cfg = FaultConfig { p_link_degrade: 1.0, p_link_fail: 1.0, ..cfg_all(2) };
        let plan = FaultPlan::generate(&cfg, &KINDS);
        let mut saw_link = false;
        for e in plan.events() {
            if let FaultKind::LinkDegrade { from, to, .. } | FaultKind::LinkFail { from, to, .. } =
                e.kind
            {
                saw_link = true;
                assert!(from < KINDS.len() && to < KINDS.len() && from != to);
            }
        }
        assert!(saw_link);
    }
}
