//! Jumpable calendar: an [`EventWheel`] plus a time index, for simulators
//! that *jump* between sparse event times instead of stepping every cycle.
//!
//! The NoC hot loop drains its wheel once per cycle, so it never needs to
//! ask "when is the next event?". The coordinator co-simulation and the
//! DRAM controller have the opposite shape: long quiet stretches (a
//! 5000-cycle HBM feed, a tRP precharge window) where stepping cycle by
//! cycle would dominate the run time. [`Calendar`] pairs the wheel's O(1)
//! push / FIFO-per-cycle semantics with a `BinaryHeap<Reverse<Cycle>>` of
//! pending timestamps so `take_next` can hand back the earliest due batch
//! directly — the wheel stores the events, the heap only stores times.
//!
//! Costs: push is O(log n) for the time index (n = pending events) plus
//! the wheel's O(1); `take_next` pops one heap entry per event at the due
//! cycle and drains exactly one wheel bucket. FIFO tie-break within a
//! cycle is inherited from the wheel, so runs replay bit-identically.
//!
//! # Batches as epoch barriers
//!
//! A [`StampedCalendar::take_due_until`] batch — every live event due at
//! one cycle, in push order — is the unit the shard-parallel admission
//! drain fans out over (`coordinator::admit`, module docs there). The
//! contract this type contributes is ordering: batches surface strictly
//! time-ascending, and within a batch the key order is exactly the push
//! order, cancelled entries skipped without perturbing the survivors.
//! The parallel drain preserves it by re-pushing follow-up events in the
//! same canonical order the sequential loop would, so every later batch
//! drains identically and the calendar never observes which thread count
//! produced it.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::{Cycle, EventWheel};

/// A calendar queue that can jump to the next pending timestamp.
#[derive(Debug)]
pub struct Calendar<T> {
    wheel: EventWheel<T>,
    /// Min-heap of pending event times (one entry per queued event).
    times: BinaryHeap<Reverse<Cycle>>,
}

impl<T> Calendar<T> {
    /// Build over a wheel of at least `min_horizon` buckets. Events past
    /// the horizon are still exact (the wheel retains later laps); the
    /// horizon only sizes the fast path.
    pub fn with_horizon(min_horizon: usize) -> Self {
        Calendar {
            wheel: EventWheel::with_horizon(min_horizon),
            times: BinaryHeap::new(),
        }
    }

    /// Schedule `item` at absolute cycle `at`.
    #[inline]
    pub fn push(&mut self, at: Cycle, item: T) {
        self.wheel.push(at, item);
        self.times.push(Reverse(at));
    }

    /// Earliest pending event time, if any.
    pub fn next_time(&self) -> Option<Cycle> {
        self.times.peek().map(|&Reverse(t)| t)
    }

    /// Remove and return the earliest batch: `(t, events due at t)` in
    /// push order. Hand the `Vec` back via [`Calendar::recycle`].
    pub fn take_next(&mut self) -> Option<(Cycle, Vec<(Cycle, T)>)> {
        let Reverse(t) = self.times.pop()?;
        // One heap entry per event at `t`; drop the rest of the batch.
        while self.times.peek() == Some(&Reverse(t)) {
            self.times.pop();
        }
        let due = self.wheel.take_due(t);
        debug_assert!(!due.is_empty(), "time index out of sync at {t}");
        Some((t, due))
    }

    /// Return batch storage obtained from [`Calendar::take_next`].
    pub fn recycle(&mut self, storage: Vec<(Cycle, T)>) {
        self.wheel.recycle(storage);
    }

    /// Total queued events.
    pub fn len(&self) -> usize {
        self.wheel.len()
    }

    pub fn is_empty(&self) -> bool {
        self.wheel.is_empty()
    }
}

/// A [`Calendar`] with lazy event cancellation by generation stamp, for
/// simulators that must *retract* scheduled work — the admission engine's
/// incremental re-simulation (`coordinator::admit`) cancels the pending
/// completion events of invalidated steps and re-enqueues them at their
/// recomputed times.
///
/// Every event is a `usize` key (the caller's step/entity id) pushed
/// together with the key's current generation. [`StampedCalendar::cancel`]
/// bumps the generation, which invalidates *all* queued events for that
/// key in O(1); stale entries are filtered out (and their storage
/// recycled) when their batch comes due. Re-enqueueing is just a fresh
/// [`StampedCalendar::push`] — it records the new generation. `len` /
/// `is_empty` count **live** events only, so cancellation is observable
/// immediately even though the stale entries are still physically queued.
///
/// FIFO tie-break within a cycle is inherited from the wheel: live events
/// due at the same cycle surface in push order (cancelled entries are
/// skipped without perturbing the order of the survivors).
#[derive(Debug)]
pub struct StampedCalendar {
    cal: Calendar<(usize, u32)>,
    /// Current generation per key (grown on demand).
    gens: Vec<u32>,
    /// Live (non-cancelled) queued events per key.
    queued: Vec<u32>,
    /// Total live queued events.
    live: usize,
}

impl StampedCalendar {
    /// See [`Calendar::with_horizon`].
    pub fn with_horizon(min_horizon: usize) -> Self {
        StampedCalendar {
            cal: Calendar::with_horizon(min_horizon),
            gens: Vec::new(),
            queued: Vec::new(),
            live: 0,
        }
    }

    fn grow(&mut self, key: usize) {
        if key >= self.gens.len() {
            self.gens.resize(key + 1, 0);
            self.queued.resize(key + 1, 0);
        }
    }

    /// Schedule `key` at absolute cycle `at` under its current generation.
    pub fn push(&mut self, at: Cycle, key: usize) {
        self.grow(key);
        self.cal.push(at, (key, self.gens[key]));
        self.queued[key] += 1;
        self.live += 1;
    }

    /// Cancel every queued event for `key` (lazy: stale entries are
    /// dropped when their batch comes due). A later
    /// [`StampedCalendar::push`] re-enqueues the key under the new
    /// generation.
    pub fn cancel(&mut self, key: usize) {
        self.grow(key);
        self.gens[key] = self.gens[key].wrapping_add(1);
        self.live -= self.queued[key] as usize;
        self.queued[key] = 0;
    }

    /// Pop the earliest batch of live events due at or before `until`
    /// (no bound when `None`), writing the keys in push order into `out`
    /// (cleared first). Returns the batch time, or `None` when nothing
    /// live is due in range. Batches whose events were all cancelled are
    /// skipped and their storage recycled.
    pub fn take_due_until(&mut self, until: Option<Cycle>, out: &mut Vec<usize>) -> Option<Cycle> {
        out.clear();
        loop {
            let t = self.cal.next_time()?;
            if let Some(u) = until {
                if t > u {
                    return None;
                }
            }
            let (t, due) = self.cal.take_next().expect("time index out of sync");
            for &(_, (key, gen)) in &due {
                if self.gens[key] == gen {
                    out.push(key);
                    self.queued[key] -= 1;
                    self.live -= 1;
                }
            }
            self.cal.recycle(due);
            if !out.is_empty() {
                return Some(t);
            }
        }
    }

    /// Live (non-cancelled) queued events.
    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jumps_in_time_order_with_fifo_ties() {
        let mut c = Calendar::with_horizon(4);
        c.push(50, "c");
        c.push(7, "a1");
        c.push(7, "a2");
        c.push(23, "b");
        let (t, due) = c.take_next().unwrap();
        assert_eq!(t, 7);
        let got: Vec<_> = due.iter().map(|&(_, x)| x).collect();
        assert_eq!(got, ["a1", "a2"]);
        c.recycle(due);
        assert_eq!(c.take_next().unwrap().0, 23);
        assert_eq!(c.take_next().unwrap().0, 50);
        assert!(c.take_next().is_none());
        assert!(c.is_empty());
    }

    #[test]
    fn far_future_laps_are_exact() {
        let mut c = Calendar::with_horizon(2);
        c.push(1000, 1u32);
        c.push(2, 2u32); // same bucket as 1000 on a 2-slot ring? (2 & 1 = 0, 1000 & 1 = 0)
        let (t, due) = c.take_next().unwrap();
        assert_eq!((t, due[0].1), (2, 2));
        c.recycle(due);
        let (t, due) = c.take_next().unwrap();
        assert_eq!((t, due[0].1), (1000, 1));
    }

    #[test]
    fn stamped_cancel_drops_queued_events() {
        let mut c = StampedCalendar::with_horizon(8);
        let mut out = Vec::new();
        c.push(5, 1);
        c.push(5, 2);
        c.push(9, 3);
        assert_eq!(c.len(), 3);
        c.cancel(2);
        assert_eq!(c.len(), 2);
        assert_eq!(c.take_due_until(None, &mut out), Some(5));
        assert_eq!(out, [1]);
        assert_eq!(c.take_due_until(None, &mut out), Some(9));
        assert_eq!(out, [3]);
        assert!(c.is_empty());
        assert_eq!(c.take_due_until(None, &mut out), None);
    }

    #[test]
    fn stamped_cancel_then_readmit_surfaces_once_at_new_time() {
        let mut c = StampedCalendar::with_horizon(4);
        let mut out = Vec::new();
        c.push(10, 7);
        c.cancel(7);
        c.push(3, 7); // re-enqueued earlier under the new generation
        assert_eq!(c.len(), 1);
        assert_eq!(c.take_due_until(None, &mut out), Some(3));
        assert_eq!(out, [7]);
        // The stale generation-0 entry at t=10 must be skipped entirely.
        assert_eq!(c.take_due_until(None, &mut out), None);
        assert!(c.is_empty());
    }

    #[test]
    fn stamped_until_bound_and_fifo_ties() {
        let mut c = StampedCalendar::with_horizon(8);
        let mut out = Vec::new();
        c.push(4, 11);
        c.push(4, 22);
        c.push(4, 33);
        c.push(6, 44);
        c.cancel(22);
        assert_eq!(c.take_due_until(Some(3), &mut out), None);
        assert_eq!(c.take_due_until(Some(4), &mut out), Some(4));
        assert_eq!(out, [11, 33], "push-order FIFO with the cancelled entry skipped");
        assert_eq!(c.take_due_until(Some(5), &mut out), None);
        assert_eq!(c.take_due_until(Some(6), &mut out), Some(6));
        assert_eq!(out, [44]);
    }

    #[test]
    fn stamped_all_cancelled_batch_is_skipped() {
        let mut c = StampedCalendar::with_horizon(4);
        let mut out = Vec::new();
        c.push(2, 0);
        c.push(5, 1);
        c.cancel(0);
        // The t=2 batch is entirely stale: take must jump to t=5.
        assert_eq!(c.take_due_until(None, &mut out), Some(5));
        assert_eq!(out, [1]);
    }

    #[test]
    fn interleaved_push_take() {
        let mut c = Calendar::with_horizon(8);
        c.push(5, 'x');
        let (t, due) = c.take_next().unwrap();
        assert_eq!(t, 5);
        c.recycle(due);
        c.push(9, 'z');
        c.push(6, 'y');
        assert_eq!(c.next_time(), Some(6));
        assert_eq!(c.take_next().unwrap().0, 6);
        assert_eq!(c.take_next().unwrap().0, 9);
    }
}
