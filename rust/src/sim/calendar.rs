//! Jumpable calendar: an [`EventWheel`] plus a time index, for simulators
//! that *jump* between sparse event times instead of stepping every cycle.
//!
//! The NoC hot loop drains its wheel once per cycle, so it never needs to
//! ask "when is the next event?". The coordinator co-simulation and the
//! DRAM controller have the opposite shape: long quiet stretches (a
//! 5000-cycle HBM feed, a tRP precharge window) where stepping cycle by
//! cycle would dominate the run time. [`Calendar`] pairs the wheel's O(1)
//! push / FIFO-per-cycle semantics with a `BinaryHeap<Reverse<Cycle>>` of
//! pending timestamps so `take_next` can hand back the earliest due batch
//! directly — the wheel stores the events, the heap only stores times.
//!
//! Costs: push is O(log n) for the time index (n = pending events) plus
//! the wheel's O(1); `take_next` pops one heap entry per event at the due
//! cycle and drains exactly one wheel bucket. FIFO tie-break within a
//! cycle is inherited from the wheel, so runs replay bit-identically.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::{Cycle, EventWheel};

/// A calendar queue that can jump to the next pending timestamp.
#[derive(Debug)]
pub struct Calendar<T> {
    wheel: EventWheel<T>,
    /// Min-heap of pending event times (one entry per queued event).
    times: BinaryHeap<Reverse<Cycle>>,
}

impl<T> Calendar<T> {
    /// Build over a wheel of at least `min_horizon` buckets. Events past
    /// the horizon are still exact (the wheel retains later laps); the
    /// horizon only sizes the fast path.
    pub fn with_horizon(min_horizon: usize) -> Self {
        Calendar {
            wheel: EventWheel::with_horizon(min_horizon),
            times: BinaryHeap::new(),
        }
    }

    /// Schedule `item` at absolute cycle `at`.
    #[inline]
    pub fn push(&mut self, at: Cycle, item: T) {
        self.wheel.push(at, item);
        self.times.push(Reverse(at));
    }

    /// Earliest pending event time, if any.
    pub fn next_time(&self) -> Option<Cycle> {
        self.times.peek().map(|&Reverse(t)| t)
    }

    /// Remove and return the earliest batch: `(t, events due at t)` in
    /// push order. Hand the `Vec` back via [`Calendar::recycle`].
    pub fn take_next(&mut self) -> Option<(Cycle, Vec<(Cycle, T)>)> {
        let Reverse(t) = self.times.pop()?;
        // One heap entry per event at `t`; drop the rest of the batch.
        while self.times.peek() == Some(&Reverse(t)) {
            self.times.pop();
        }
        let due = self.wheel.take_due(t);
        debug_assert!(!due.is_empty(), "time index out of sync at {t}");
        Some((t, due))
    }

    /// Return batch storage obtained from [`Calendar::take_next`].
    pub fn recycle(&mut self, storage: Vec<(Cycle, T)>) {
        self.wheel.recycle(storage);
    }

    /// Total queued events.
    pub fn len(&self) -> usize {
        self.wheel.len()
    }

    pub fn is_empty(&self) -> bool {
        self.wheel.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jumps_in_time_order_with_fifo_ties() {
        let mut c = Calendar::with_horizon(4);
        c.push(50, "c");
        c.push(7, "a1");
        c.push(7, "a2");
        c.push(23, "b");
        let (t, due) = c.take_next().unwrap();
        assert_eq!(t, 7);
        let got: Vec<_> = due.iter().map(|&(_, x)| x).collect();
        assert_eq!(got, ["a1", "a2"]);
        c.recycle(due);
        assert_eq!(c.take_next().unwrap().0, 23);
        assert_eq!(c.take_next().unwrap().0, 50);
        assert!(c.take_next().is_none());
        assert!(c.is_empty());
    }

    #[test]
    fn far_future_laps_are_exact() {
        let mut c = Calendar::with_horizon(2);
        c.push(1000, 1u32);
        c.push(2, 2u32); // same bucket as 1000 on a 2-slot ring? (2 & 1 = 0, 1000 & 1 = 0)
        let (t, due) = c.take_next().unwrap();
        assert_eq!((t, due[0].1), (2, 2));
        c.recycle(due);
        let (t, due) = c.take_next().unwrap();
        assert_eq!((t, due[0].1), (1000, 1));
    }

    #[test]
    fn interleaved_push_take() {
        let mut c = Calendar::with_horizon(8);
        c.push(5, 'x');
        let (t, due) = c.take_next().unwrap();
        assert_eq!(t, 5);
        c.recycle(due);
        c.push(9, 'z');
        c.push(6, 'y');
        assert_eq!(c.next_time(), Some(6));
        assert_eq!(c.take_next().unwrap().0, 6);
        assert_eq!(c.take_next().unwrap().0, 9);
    }
}
