//! Open-loop arrival processes for steady-state serving.
//!
//! The serving layer (`coordinator::shard`) replaces fixed closed-loop
//! batches with *open-loop* request streams: arrival times are exogenous
//! — they do not wait on the fabric — so queueing delay under overload
//! is visible instead of being absorbed by the driver's pacing. This
//! module generates those streams deterministically.
//!
//! # Determinism contract
//!
//! Every draw is position-keyed through [`CounterRng`]: arrival `i`'s
//! inter-arrival gap is a pure function of `(seed, i, current time)`,
//! never of sampling order or thread interleaving. Two generators built
//! from the same `(process, seed, diurnal)` configuration emit the same
//! trace cycle-for-cycle, which is the first leg of the serving replay
//! guarantee (the other two — hash routing and canonical merge order —
//! live in `coordinator::shard`).
//!
//! Three processes:
//!
//! * [`ArrivalProcess::Uniform`] — fixed gaps. With no diurnal
//!   modulation this reproduces the closed-loop pacing of
//!   [`crate::coordinator::CosimExecutor`] exactly (arrivals at `0, g,
//!   2g, …`), which is what pins the 1-shard server bit-identical to
//!   `BatchServer::run_cosim` in `tests/serve_golden.rs`.
//! * [`ArrivalProcess::Poisson`] — exponential inter-arrival gaps via
//!   inverse-CDF sampling of position-keyed uniforms (memoryless open
//!   loop; mean gap = `mean_gap`).
//! * [`ArrivalProcess::Trace`] — a recorded base gap sequence replayed
//!   cyclically (trace-driven load).
//!
//! Any process composes with *diurnal burst modulation*: a sinusoidal
//! rate multiplier `m(t) = 1 + A·sin(2πt/P)` divides the raw gap, so the
//! peak of each period packs arrivals `1+A` times denser (bursts) and
//! the trough stretches them out (lulls). `A` must lie in `[0, 1)` so
//! the rate never reaches zero; `[serve]` validation enforces the same
//! range on `serve.diurnal_amplitude`.

use super::rng::CounterRng;
use super::Cycle;

/// The inter-arrival law of an open-loop request stream.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalProcess {
    /// Fixed `gap` cycles between arrivals (closed-loop-compatible).
    Uniform { gap: Cycle },
    /// Exponential gaps with the given mean (Poisson arrivals).
    Poisson { mean_gap: Cycle },
    /// Recorded base gaps, replayed cyclically.
    Trace { gaps: Vec<Cycle> },
}

/// Deterministic open-loop arrival generator: an infinite iterator of
/// nondecreasing arrival cycles, starting at 0. See the module docs for
/// the determinism contract.
#[derive(Debug, Clone)]
pub struct ArrivalGen {
    process: ArrivalProcess,
    rng: CounterRng,
    /// Diurnal modulation period in cycles (0 = off).
    period: Cycle,
    /// Diurnal amplitude in `[0, 1)`.
    amplitude: f64,
    next_at: Cycle,
    idx: u64,
}

impl ArrivalGen {
    /// A generator for `process`, drawing position-keyed randomness from
    /// `seed` (only [`ArrivalProcess::Poisson`] consumes draws; the
    /// others are seed-independent).
    pub fn new(process: ArrivalProcess, seed: u64) -> Self {
        if let ArrivalProcess::Trace { gaps } = &process {
            assert!(!gaps.is_empty(), "a trace arrival process needs at least one gap");
        }
        ArrivalGen { process, rng: CounterRng::new(seed), period: 0, amplitude: 0.0, next_at: 0, idx: 0 }
    }

    /// Add diurnal burst modulation: rate multiplier
    /// `1 + amplitude·sin(2πt/period)`. `period = 0` disables it;
    /// `amplitude` must lie in `[0, 1)`.
    pub fn with_diurnal(mut self, period: Cycle, amplitude: f64) -> Self {
        assert!(
            amplitude.is_finite() && (0.0..1.0).contains(&amplitude),
            "diurnal amplitude must lie in [0, 1), got {amplitude}"
        );
        self.period = period;
        self.amplitude = amplitude;
        self.next_at = 0;
        self.idx = 0;
        self
    }

    /// Arrival index of the next emitted arrival (the position key of
    /// its gap draw) — also the count emitted so far.
    pub fn emitted(&self) -> u64 {
        self.idx
    }

    /// Collect the next `n` arrival cycles.
    pub fn take_trace(&mut self, n: usize) -> Vec<Cycle> {
        (0..n).map(|_| self.next_arrival()).collect()
    }

    /// Emit the next arrival cycle and advance.
    pub fn next_arrival(&mut self) -> Cycle {
        let t = self.next_at;
        let raw = match &self.process {
            ArrivalProcess::Uniform { gap } => *gap as f64,
            ArrivalProcess::Poisson { mean_gap } => {
                // Inverse-CDF exponential: u ∈ [0, 1) so 1-u ∈ (0, 1]
                // and the log is finite. Keyed by arrival index — the
                // draw replays identically from any resume point.
                let u = self.rng.uniform_at(self.idx);
                -(1.0 - u).ln() * *mean_gap as f64
            }
            ArrivalProcess::Trace { gaps } => gaps[self.idx as usize % gaps.len()] as f64,
        };
        // Diurnal rate multiplier at the current time: bursts (m > 1)
        // compress gaps, lulls (m < 1) stretch them. amplitude < 1
        // keeps m > 0.
        let m = if self.period > 0 {
            let phase = (t % self.period) as f64 / self.period as f64;
            1.0 + self.amplitude * (std::f64::consts::TAU * phase).sin()
        } else {
            1.0
        };
        let gap = (raw / m).round().max(0.0) as Cycle;
        self.idx += 1;
        self.next_at = t + gap;
        t
    }
}

impl Iterator for ArrivalGen {
    type Item = Cycle;

    fn next(&mut self) -> Option<Cycle> {
        Some(self.next_arrival())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_and_config_replays_the_exact_trace() {
        for process in [
            ArrivalProcess::Uniform { gap: 700 },
            ArrivalProcess::Poisson { mean_gap: 900 },
            ArrivalProcess::Trace { gaps: vec![100, 50, 800, 5] },
        ] {
            let mut a = ArrivalGen::new(process.clone(), 42).with_diurnal(10_000, 0.6);
            let mut b = ArrivalGen::new(process, 42).with_diurnal(10_000, 0.6);
            assert_eq!(a.take_trace(500), b.take_trace(500));
        }
    }

    #[test]
    fn different_seeds_decorrelate_poisson_traces() {
        let mut a = ArrivalGen::new(ArrivalProcess::Poisson { mean_gap: 500 }, 1);
        let mut b = ArrivalGen::new(ArrivalProcess::Poisson { mean_gap: 500 }, 2);
        assert_ne!(a.take_trace(64), b.take_trace(64));
    }

    #[test]
    fn uniform_without_diurnal_is_exact_closed_loop_pacing() {
        let mut g = ArrivalGen::new(ArrivalProcess::Uniform { gap: 1_000 }, 7);
        let trace = g.take_trace(32);
        for (i, &t) in trace.iter().enumerate() {
            assert_eq!(t, i as Cycle * 1_000);
        }
    }

    #[test]
    fn arrivals_are_nondecreasing_from_zero() {
        let mut g =
            ArrivalGen::new(ArrivalProcess::Poisson { mean_gap: 300 }, 9).with_diurnal(5_000, 0.9);
        let trace = g.take_trace(2_000);
        assert_eq!(trace[0], 0);
        assert!(trace.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn poisson_moments_are_sane() {
        // Exponential gaps: mean ≈ mean_gap, coefficient of variation
        // ≈ 1 (the memoryless signature a Uniform process fails).
        let mean_gap = 1_000.0;
        let mut g = ArrivalGen::new(ArrivalProcess::Poisson { mean_gap: 1_000 }, 1234);
        let trace = g.take_trace(20_001);
        let gaps: Vec<f64> =
            trace.windows(2).map(|w| (w[1] - w[0]) as f64).collect();
        let n = gaps.len() as f64;
        let mean = gaps.iter().sum::<f64>() / n;
        let var = gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / n;
        let cv = var.sqrt() / mean;
        assert!((mean - mean_gap).abs() < 0.05 * mean_gap, "mean {mean}");
        assert!((cv - 1.0).abs() < 0.05, "cv {cv}");
    }

    #[test]
    fn diurnal_modulation_bursts_and_lulls() {
        // With a pure Uniform base, gaps near the sine peak must be
        // shorter than gaps near the trough — and both differ from the
        // unmodulated gap.
        let period = 100_000;
        let mut g = ArrivalGen::new(ArrivalProcess::Uniform { gap: 1_000 }, 0)
            .with_diurnal(period, 0.8);
        let trace = g.take_trace(1_000);
        let gap_at = |t: Cycle| -> bool {
            let phase = (t % period) as f64 / period as f64;
            (0.15..0.35).contains(&phase) // around the sine peak
        };
        let mut burst = Vec::new();
        let mut lull = Vec::new();
        for w in trace.windows(2) {
            let phase = (w[0] % period) as f64 / period as f64;
            if gap_at(w[0]) {
                burst.push(w[1] - w[0]);
            } else if (0.65..0.85).contains(&phase) {
                lull.push(w[1] - w[0]);
            }
        }
        assert!(!burst.is_empty() && !lull.is_empty());
        let bmax = burst.iter().max().unwrap();
        let lmin = lull.iter().min().unwrap();
        assert!(bmax < lmin, "burst gaps {bmax} must undercut lull gaps {lmin}");
    }

    #[test]
    fn trace_process_cycles_through_base_gaps() {
        let mut g = ArrivalGen::new(ArrivalProcess::Trace { gaps: vec![10, 20, 30] }, 0);
        assert_eq!(g.take_trace(7), vec![0, 10, 30, 60, 70, 90, 120]);
    }

    #[test]
    #[should_panic(expected = "at least one gap")]
    fn empty_trace_is_rejected() {
        let _ = ArrivalGen::new(ArrivalProcess::Trace { gaps: vec![] }, 0);
    }

    #[test]
    #[should_panic(expected = "amplitude must lie in [0, 1)")]
    fn saturating_amplitude_is_rejected() {
        let _ = ArrivalGen::new(ArrivalProcess::Uniform { gap: 10 }, 0).with_diurnal(100, 1.0);
    }
}
