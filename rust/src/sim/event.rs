//! Deterministic discrete-event queue.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::Cycle;

/// Time-ordered event queue with deterministic FIFO tie-breaking: two
/// events scheduled for the same cycle pop in scheduling order, so a run
/// with the same seed replays bit-identically (the property every
/// regression test in the simulators leans on).
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Reverse<Entry<E>>>,
    seq: u64,
    now: Cycle,
}

#[derive(Debug)]
struct Entry<E> {
    at: Cycle,
    seq: u64,
    ev: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0, now: 0 }
    }

    /// Current simulation time (time of the last popped event).
    pub fn now(&self) -> Cycle {
        self.now
    }

    /// Schedule `ev` at absolute cycle `at`. Scheduling in the past is a
    /// logic error and panics in debug builds; in release it clamps to
    /// `now` (the component models guard against this themselves).
    pub fn schedule_at(&mut self, at: Cycle, ev: E) {
        debug_assert!(at >= self.now, "scheduling into the past: {at} < {}", self.now);
        let at = at.max(self.now);
        self.heap.push(Reverse(Entry { at, seq: self.seq, ev }));
        self.seq += 1;
    }

    /// Schedule `ev` `delay` cycles from now.
    pub fn schedule_in(&mut self, delay: Cycle, ev: E) {
        self.schedule_at(self.now + delay, ev);
    }

    /// Pop the next event, advancing `now`.
    pub fn pop(&mut self) -> Option<(Cycle, E)> {
        let Reverse(e) = self.heap.pop()?;
        self.now = e.at;
        Some((e.at, e.ev))
    }

    /// Time of the next event without popping.
    pub fn peek_time(&self) -> Option<Cycle> {
        self.heap.peek().map(|Reverse(e)| e.at)
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(30, "c");
        q.schedule_at(10, "a");
        q.schedule_at(20, "b");
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(order, vec![(10, "a"), (20, "b"), (30, "c")]);
    }

    #[test]
    fn fifo_tie_break() {
        let mut q = EventQueue::new();
        q.schedule_at(5, 1);
        q.schedule_at(5, 2);
        q.schedule_at(5, 3);
        assert_eq!(q.pop().unwrap().1, 1);
        assert_eq!(q.pop().unwrap().1, 2);
        assert_eq!(q.pop().unwrap().1, 3);
    }

    #[test]
    fn now_advances() {
        let mut q = EventQueue::new();
        q.schedule_in(7, ());
        assert_eq!(q.now(), 0);
        q.pop();
        assert_eq!(q.now(), 7);
        q.schedule_in(3, ());
        assert_eq!(q.peek_time(), Some(10));
    }

    #[test]
    #[should_panic(expected = "scheduling into the past")]
    #[cfg(debug_assertions)]
    fn past_scheduling_panics_in_debug() {
        let mut q = EventQueue::new();
        q.schedule_at(10, ());
        q.pop();
        q.schedule_at(5, ());
    }

    #[test]
    fn interleaved_schedule_pop() {
        let mut q = EventQueue::new();
        q.schedule_at(1, "x");
        q.pop();
        q.schedule_at(4, "z");
        q.schedule_at(2, "y");
        assert_eq!(q.pop().unwrap(), (2, "y"));
        assert_eq!(q.pop().unwrap(), (4, "z"));
        assert!(q.is_empty());
    }
}
