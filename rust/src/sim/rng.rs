//! Deterministic PRNG: xoshiro256** (Blackman & Vigna), self-contained
//! because the offline image only ships `rand_core` (traits, no generators),
//! plus [`CounterRng`], the counter-based generator parallel simulation
//! phases must use (draws keyed by position, not by call order).

/// xoshiro256** with SplitMix64 seeding and uniform/normal helpers.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller sample.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Seed via SplitMix64 so small seeds give well-mixed states.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s, spare_normal: None }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> double mantissa.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). n must be nonzero.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Rejection-free for our (non-cryptographic) simulation use.
        (self.uniform() * n as f64) as usize % n
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare_normal.take() {
            return v;
        }
        let (u1, u2) = (self.uniform().max(1e-300), self.uniform());
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Bernoulli with probability p.
    pub fn chance(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Fork a child stream (deterministic per label).
    pub fn fork(&mut self, label: u64) -> Rng {
        Rng::new(self.next_u64() ^ label.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

/// SplitMix64 finalizer (Stafford mix 13) — full-avalanche bijection.
#[inline]
fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Counter-based (stateless) RNG: every draw is a pure function of
/// `(key, position)`.
///
/// Sequential generators like [`Rng`] make draw values depend on *how
/// many* draws happened before — which, in a shard-parallel simulation
/// phase, would make them depend on the thread schedule. A `CounterRng`
/// keys each draw by its position instead (e.g. `(cycle, node, k)`
/// folded via [`CounterRng::at3`]), so any future stochastic router or
/// controller behavior stays bit-reproducible at every thread count.
/// This is the RNG the NoC determinism contract prescribes for code
/// running inside a parallel phase (`noc/sim.rs` module docs).
#[derive(Debug, Clone, Copy)]
pub struct CounterRng {
    key: u64,
}

impl CounterRng {
    pub fn new(seed: u64) -> Self {
        // Decorrelate small seeds the same way Rng's seeding does.
        CounterRng { key: mix64(seed.wrapping_add(0x9E3779B97F4A7C15)) }
    }

    /// The draw at `position`. Pure: same (key, position) -> same value.
    #[inline]
    pub fn at(&self, position: u64) -> u64 {
        mix64(self.key ^ position.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Fold a (cycle, node, draw-index) style triple into one position.
    /// Injective enough in practice: each component is spread by an odd
    /// multiplier before xor-folding.
    #[inline]
    pub fn at3(&self, a: u64, b: u64, c: u64) -> u64 {
        self.at(
            a.wrapping_mul(0xD1B54A32D192ED03)
                ^ b.wrapping_mul(0xAEF17502108EF2D9)
                ^ c.wrapping_mul(0x2545F4914F6CDD1D),
        )
    }

    /// Uniform in [0, 1) at `position` (same 53-bit construction as
    /// [`Rng::uniform`]).
    #[inline]
    pub fn uniform_at(&self, position: u64) -> f64 {
        (self.at(position) >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) at `position`. n must be nonzero.
    #[inline]
    pub fn below_at(&self, position: u64, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.uniform_at(position) * n as f64) as usize % n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_in_range_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7);
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>(), "seed 5 must permute");
    }

    #[test]
    fn fork_streams_independent() {
        let mut r = Rng::new(9);
        let mut c1 = r.fork(1);
        let mut c2 = r.fork(2);
        assert_ne!(c1.next_u64(), c2.next_u64());
    }

    #[test]
    fn counter_rng_is_position_keyed_not_order_keyed() {
        let r = CounterRng::new(42);
        // Draw the same positions in two different orders: identical values.
        let fwd: Vec<u64> = (0..100).map(|p| r.at(p)).collect();
        let rev: Vec<u64> = (0..100).rev().map(|p| r.at(p)).collect();
        assert!(fwd.iter().eq(rev.iter().rev()));
        // Re-draws are idempotent (stateless).
        assert_eq!(r.at(7), r.at(7));
        assert_ne!(r.at(7), r.at(8));
        // Distinct seeds give distinct streams.
        assert_ne!(CounterRng::new(1).at(0), CounterRng::new(2).at(0));
    }

    #[test]
    fn counter_rng_uniform_and_below_bounds() {
        let r = CounterRng::new(3);
        let mut sum = 0.0;
        let n = 20_000u64;
        for p in 0..n {
            let u = r.uniform_at(p);
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
        let mut seen = [false; 5];
        for p in 0..500 {
            let v = r.below_at(p, 5);
            assert!(v < 5);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn counter_rng_at3_components_matter() {
        let r = CounterRng::new(11);
        assert_eq!(r.at3(1, 2, 3), r.at3(1, 2, 3));
        assert_ne!(r.at3(1, 2, 3), r.at3(3, 2, 1));
        assert_ne!(r.at3(1, 2, 3), r.at3(1, 2, 4));
        assert_ne!(r.at3(1, 2, 3), r.at3(2, 2, 3));
    }
}
