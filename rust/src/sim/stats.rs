//! Streaming sample statistics for simulator report paths.
//!
//! The pre-rewrite report code collected every per-packet latency in a
//! `Vec<u64>` and sorted it at report time — O(n log n) and O(n) memory
//! in delivered packets. [`StreamingHist`] is the replacement: an *exact*
//! counting histogram with a flat dense front (a plain `Vec<u64>` of
//! counts that scans/vectorizes — the ROADMAP's "SIMD-friendly metrics"
//! shape) and an exact sparse tail for outliers. Quantiles come out as
//! k-th order statistics over the counts, so they are bit-identical to
//! indexing the sorted vector, while `record` is O(1) and memory is
//! O(latency range), not O(samples).

use std::collections::BTreeMap;

use super::Cycle;

/// Dense-count coverage: values below this live in the flat array
/// (8 buckets/cache line, 32 KiB total); rarer, larger values fall into
/// the exact sparse tail.
const DENSE_LIMIT: usize = 1 << 12;

/// Exact streaming histogram of `u64` samples.
///
/// Derived equality is multiset equality: the dense front's length is
/// `next_power_of_two` of the largest dense value ever recorded (resize
/// on record and on merge use the same rule), so two histograms built
/// from the same samples in any record/merge order compare equal — which
/// lets report types embedding a histogram keep bitwise `==` replay
/// semantics.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct StreamingHist {
    /// counts[v] = occurrences of value v, for v < DENSE_LIMIT. Grown
    /// lazily in powers of two up to the limit.
    dense: Vec<u64>,
    /// Exact counts for values >= DENSE_LIMIT (ordered, usually tiny).
    tail: BTreeMap<Cycle, u64>,
    count: u64,
    sum: u64,
}

impl StreamingHist {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one sample. O(1) amortized.
    #[inline]
    pub fn record(&mut self, v: Cycle) {
        self.count += 1;
        self.sum += v;
        let i = v as usize;
        if v < DENSE_LIMIT as Cycle {
            if self.dense.len() <= i {
                self.dense.resize((i + 1).next_power_of_two().min(DENSE_LIMIT), 0);
            }
            self.dense[i] += 1;
        } else {
            *self.tail.entry(v).or_insert(0) += 1;
        }
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sum of all recorded samples (exact; u64 like the sorted-Vec sum).
    pub fn sum(&self) -> u64 {
        self.sum
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Mean as `sum as f64 / count as f64` — the same two f64 conversions
    /// and single division the sorted-Vec code performed, so the result
    /// is bit-identical. 0.0 when empty.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// k-th smallest recorded sample (0-based), i.e. `sorted[k]`.
    /// `None` when `k >= count`.
    pub fn kth(&self, k: u64) -> Option<Cycle> {
        if k >= self.count {
            return None;
        }
        let mut cum = 0u64;
        for (v, &c) in self.dense.iter().enumerate() {
            cum += c;
            if cum > k {
                return Some(v as Cycle);
            }
        }
        for (&v, &c) in &self.tail {
            cum += c;
            if cum > k {
                return Some(v);
            }
        }
        None
    }

    /// Merge another histogram into this one (counts add; exact). Order
    /// statistics over summed counts equal those over the concatenated
    /// sample streams, so partial histograms merged in any order
    /// reproduce the single-histogram mean and quantiles bit-for-bit.
    /// (The parallel NoC step currently records into one global
    /// histogram at merge time rather than per-shard; this is the
    /// reduction primitive for consumers that do keep partials — e.g.
    /// DSE sweep aggregation or a future sharded report path.)
    pub fn merge(&mut self, other: &StreamingHist) {
        self.count += other.count;
        self.sum += other.sum;
        if self.dense.len() < other.dense.len() {
            self.dense.resize(other.dense.len(), 0);
        }
        for (d, &c) in self.dense.iter_mut().zip(&other.dense) {
            *d += c;
        }
        for (&v, &c) in &other.tail {
            *self.tail.entry(v).or_insert(0) += c;
        }
    }

    /// `sorted[(len - 1).min(len * p_num / p_den)]` — the exact indexing
    /// rule the NoC report paths use for p99 (`p_num/p_den` = 99/100).
    /// 0.0 when empty, matching the replaced code.
    pub fn quantile_indexed(&self, p_num: u64, p_den: u64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let k = (self.count - 1).min(self.count * p_num / p_den);
        self.kth(k).expect("k < count") as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reference implementation: the sorted vector the histogram replaces.
    fn sorted_ref(vals: &[u64]) -> (f64, f64) {
        let mut lats = vals.to_vec();
        lats.sort_unstable();
        let avg = if lats.is_empty() {
            0.0
        } else {
            lats.iter().sum::<u64>() as f64 / lats.len() as f64
        };
        let p99 = if lats.is_empty() {
            0.0
        } else {
            lats[(lats.len() - 1).min(lats.len() * 99 / 100)] as f64
        };
        (avg, p99)
    }

    #[test]
    fn matches_sorted_vector_bitwise() {
        let mut rng = crate::sim::Rng::new(17);
        for case in 0..50 {
            let n = rng.below(300);
            let mut vals = Vec::new();
            let mut h = StreamingHist::new();
            for _ in 0..n {
                // mix of small (dense) and huge (tail) samples
                let v = if rng.chance(0.9) {
                    rng.below(2000) as u64
                } else {
                    5000 + rng.below(1 << 20) as u64
                };
                vals.push(v);
                h.record(v);
            }
            let (avg, p99) = sorted_ref(&vals);
            assert_eq!(h.mean().to_bits(), avg.to_bits(), "case {case} avg");
            assert_eq!(
                h.quantile_indexed(99, 100).to_bits(),
                p99.to_bits(),
                "case {case} p99"
            );
        }
    }

    #[test]
    fn kth_is_order_statistic() {
        let mut h = StreamingHist::new();
        for v in [5u64, 1, 5, 100_000, 3] {
            h.record(v);
        }
        assert_eq!(h.kth(0), Some(1));
        assert_eq!(h.kth(1), Some(3));
        assert_eq!(h.kth(2), Some(5));
        assert_eq!(h.kth(3), Some(5));
        assert_eq!(h.kth(4), Some(100_000));
        assert_eq!(h.kth(5), None);
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum(), 100_014);
    }

    #[test]
    fn empty_reports_zero() {
        let h = StreamingHist::new();
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.quantile_indexed(99, 100), 0.0);
        assert!(h.is_empty());
    }

    #[test]
    fn merge_with_empty_is_identity() {
        let mut h = StreamingHist::new();
        for v in [1u64, 9, 9, 700_000] {
            h.record(v);
        }
        let snapshot = (h.count(), h.sum(), h.kth(0), h.kth(3));
        h.merge(&StreamingHist::new());
        assert_eq!((h.count(), h.sum(), h.kth(0), h.kth(3)), snapshot);
        let mut empty = StreamingHist::new();
        empty.merge(&h);
        assert_eq!(empty.count(), h.count());
        assert_eq!(empty.sum(), h.sum());
        assert_eq!(empty.kth(2), h.kth(2));
    }

    #[test]
    fn merge_overlapping_tails_adds_counts() {
        let mut a = StreamingHist::new();
        let mut b = StreamingHist::new();
        // Same tail value recorded on both sides, plus disjoint ones.
        a.record(1 << 20);
        a.record(5);
        b.record(1 << 20);
        b.record(1 << 21);
        a.merge(&b);
        assert_eq!(a.count(), 4);
        assert_eq!(a.kth(0), Some(5));
        assert_eq!(a.kth(1), Some(1 << 20));
        assert_eq!(a.kth(2), Some(1 << 20), "overlapping tail count doubled");
        assert_eq!(a.kth(3), Some(1 << 21));
    }

    #[test]
    fn merged_shards_match_single_hist_bitwise() {
        // Split one sample stream across 4 shard-local hists, merge in
        // order: mean/p99 must equal the single-hist (and sorted-vec)
        // bits — the parallel-stepping reduction contract.
        let mut rng = crate::sim::Rng::new(23);
        for case in 0..20 {
            let n = rng.below(400) + 1;
            let mut single = StreamingHist::new();
            let mut shards = vec![StreamingHist::new(); 4];
            let mut vals = Vec::new();
            for i in 0..n {
                let v = if rng.chance(0.85) {
                    rng.below(3000) as u64
                } else {
                    4000 + rng.below(1 << 22) as u64
                };
                vals.push(v);
                single.record(v);
                shards[i % 4].record(v);
            }
            let mut merged = StreamingHist::new();
            for s in &shards {
                merged.merge(s);
            }
            let (avg, p99) = sorted_ref(&vals);
            assert_eq!(merged.mean().to_bits(), single.mean().to_bits(), "case {case}");
            assert_eq!(merged.mean().to_bits(), avg.to_bits(), "case {case}");
            assert_eq!(
                merged.quantile_indexed(99, 100).to_bits(),
                p99.to_bits(),
                "case {case}"
            );
        }
    }
}
