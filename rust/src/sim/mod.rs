//! Discrete-event simulation substrate shared by the NoC, DRAM and fabric
//! simulators (the GVSoC-role of the stack, DESIGN.md §1).
//!
//! * [`EventQueue`] — a deterministic time-ordered queue (ties broken by
//!   insertion sequence, so identical runs replay identically).
//! * [`EventWheel`] — a bucketed calendar queue for the bounded-delay hot
//!   loops (NoC flit arrivals / credit returns, DRAM wakeups): O(1) push,
//!   O(due) drain, reusable bucket storage, same FIFO tie-break contract
//!   as [`EventQueue`].
//! * [`Calendar`] — an [`EventWheel`] plus a pending-time index, for the
//!   simulators that jump between sparse event times (coordinator co-sim
//!   step completions, DRAM per-bank ready events) instead of stepping
//!   every cycle.
//! * [`StampedCalendar`] — a [`Calendar`] with generation-stamped lazy
//!   cancellation, for simulators that retract scheduled work (the
//!   admission engine's incremental re-simulation cancels and re-enqueues
//!   invalidated step completions).
//! * [`StreamingHist`] — exact streaming histogram (flat counts + sparse
//!   tail) behind the report-path latency quantiles; mergeable, so
//!   shard-local histograms reduce to the same bits as a single one.
//! * [`Rng`] — xoshiro256** PRNG with uniform/normal helpers; every
//!   stochastic component seeds one of these, never OS entropy.
//! * [`CounterRng`] — counter-based (stateless) draws that depend only on
//!   (key, position), never on call order: the RNG contract parallel
//!   simulation phases must use (see `noc/sim.rs` determinism docs).
//! * [`FaultPlan`] ([`fault`]) — seeded, position-keyed fault traces
//!   (transients, tile death, link/HBM degradation, accelerator wear):
//!   the deterministic injection half of the robustness layer, consumed
//!   by `coordinator::admit`'s recovery engine and `fabric::cost`'s
//!   `DegradedCost` pricing wrapper.
//! * [`WorkerPool`] — persistent scoped worker pool (std-only) behind the
//!   NoC's shard-parallel stepping.
//! * [`ArrivalGen`] ([`arrival`]) — deterministic open-loop arrival
//!   processes (uniform / Poisson / trace-driven, with diurnal burst
//!   modulation) feeding the sharded serving layer
//!   (`coordinator::shard`); position-keyed via [`CounterRng`], so
//!   arrival traces replay bit-identically.

pub mod arrival;
mod calendar;
mod event;
mod event_wheel;
pub mod fault;
mod pool;
mod rng;
mod stats;

pub use arrival::{ArrivalGen, ArrivalProcess};
pub use calendar::{Calendar, StampedCalendar};
pub use event::EventQueue;
pub use event_wheel::EventWheel;
pub use fault::{FaultConfig, FaultEvent, FaultKind, FaultPlan};
pub use pool::{load_fences, Scope, WorkerPool};
pub use rng::{CounterRng, Rng};
pub use stats::StreamingHist;

/// Simulated time in clock cycles of the component's own clock domain.
pub type Cycle = u64;
