//! Discrete-event simulation substrate shared by the NoC, DRAM and fabric
//! simulators (the GVSoC-role of the stack, DESIGN.md §1).
//!
//! * [`EventQueue`] — a deterministic time-ordered queue (ties broken by
//!   insertion sequence, so identical runs replay identically).
//! * [`EventWheel`] — a bucketed calendar queue for the bounded-delay hot
//!   loops (NoC flit arrivals / credit returns, DRAM wakeups): O(1) push,
//!   O(due) drain, reusable bucket storage, same FIFO tie-break contract
//!   as [`EventQueue`].
//! * [`Rng`] — xoshiro256** PRNG with uniform/normal helpers; every
//!   stochastic component seeds one of these, never OS entropy.

mod event;
mod event_wheel;
mod rng;

pub use event::EventQueue;
pub use event_wheel::EventWheel;
pub use rng::Rng;

/// Simulated time in clock cycles of the component's own clock domain.
pub type Cycle = u64;
