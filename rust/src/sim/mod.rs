//! Discrete-event simulation substrate shared by the NoC, DRAM and fabric
//! simulators (the GVSoC-role of the stack, DESIGN.md §1).
//!
//! * [`EventQueue`] — a deterministic time-ordered queue (ties broken by
//!   insertion sequence, so identical runs replay identically).
//! * [`Rng`] — xoshiro256** PRNG with uniform/normal helpers; every
//!   stochastic component seeds one of these, never OS entropy.

mod event;
mod rng;

pub use event::EventQueue;
pub use rng::Rng;

/// Simulated time in clock cycles of the component's own clock domain.
pub type Cycle = u64;
